//! End-to-end TCP serving driver: boots the search service behind the
//! hardened network front-end (`repro::net::NetServer`) on an ephemeral
//! loopback port, then walks a well-behaved client through the full
//! session lifecycle a production tenant would see:
//!
//! 1. connect and serve a batch of real queries over the wire;
//! 2. burn through the tenant's token-bucket quota until a query is
//!    shed with a typed `quota` error carrying `retry_after_ms`;
//! 3. honour the advertised backoff and retry — the retry is admitted
//!    (the horizon is exact, not advisory);
//! 4. drain the server under an open connection — the session ends with
//!    a clean EOF and every in-flight response delivered.
//!
//! Run with: `cargo run --release --example net_e2e`
//! Optional: `-- --ref-len 60000 --queries 12 --quota-rate 4 --quota-burst 6`

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use repro::coordinator::protocol::{ErrorKind, ErrorResponse, QueryResponse};
use repro::coordinator::{QueryRequest, Service, ServiceConfig};
use repro::data::{extract_queries, Dataset};
use repro::distances::metric::Metric;
use repro::net::{NetConfig, NetServer};
use repro::search::suite::Suite;
use repro::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let ref_len = args.usize_or("ref-len", 60_000)?;
    let n_queries = args.usize_or("queries", 12)?;
    let shards = args.usize_or("shards", 2)?;
    let qlen = args.usize_or("qlen", 256)?;
    let quota_rate = args.f64_or("quota-rate", 4.0)?;
    let quota_burst = args.f64_or("quota-burst", n_queries as f64)?;

    println!("== boot ==");
    let reference = Dataset::Ecg.generate(ref_len, 2026);
    let queries = extract_queries(&reference, n_queries, qlen, 0.1, 7);
    let svc = Arc::new(Service::new(
        reference,
        &ServiceConfig { shards, batch_window: 4, batch_deadline_ms: 2, ..Default::default() },
    )?);
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetConfig { quota_rate, quota_burst, ..NetConfig::default() },
    )?;
    let addr = server.local_addr();
    println!(
        "service up behind TCP front-end on {addr}: reference {} points, {shards} shards, \
         quota {quota_rate}/s burst {quota_burst}",
        svc.reference_len()
    );

    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut wire = stream.try_clone()?;
    let mut send = |req: &QueryRequest| -> anyhow::Result<String> {
        wire.write_all(req.to_json().as_bytes())?;
        wire.write_all(b"\n")?;
        let mut line = String::new();
        reader.read_line(&mut line)?;
        Ok(line.trim_end().to_string())
    };
    let request = |id: u64| QueryRequest {
        id,
        query: queries[id as usize % queries.len()].clone(),
        window_ratio: 0.1,
        suite: Suite::UcrMon,
        k: 1,
        metric: Metric::Cdtw,
        deadline_ms: None,
        tenant: Some("acme".into()),
    };

    println!("\n== serve {n_queries} queries over the wire ==");
    let mut latencies = Vec::with_capacity(n_queries);
    for id in 0..n_queries as u64 {
        let resp = QueryResponse::from_json(&send(&request(id))?)?;
        latencies.push(resp.latency_ms);
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).expect("no NaN"));
    println!(
        "served {} queries | latency p50 {:.2}ms max {:.2}ms",
        latencies.len(),
        latencies[(latencies.len() - 1) / 2],
        latencies[latencies.len() - 1],
    );

    println!("\n== exhaust the quota ==");
    // the burst is spent by the batch above (plus refill trickle); hammer
    // until the bucket runs dry and the front-end sheds
    let mut shed = None;
    for id in 0..10_000u64 {
        let line = send(&request(1_000 + id))?;
        if ErrorResponse::is_error_line(&line) {
            let err = ErrorResponse::from_json(&line)?;
            anyhow::ensure!(err.kind == Some(ErrorKind::Quota), "unexpected error: {line}");
            shed = Some(err);
            break;
        }
    }
    let shed = shed.expect("quota never exhausted — raise the query count");
    let retry_ms = shed.retry_after_ms.expect("quota sheds carry retry_after_ms");
    println!(
        "shed with typed quota error after the burst: retry_after_ms={retry_ms} ({})",
        shed.error
    );

    println!("\n== honour the backoff and retry ==");
    std::thread::sleep(Duration::from_millis(retry_ms + 10));
    let resp = QueryResponse::from_json(&send(&request(2_000))?)?;
    println!(
        "retry admitted after {retry_ms}ms backoff: match at pos {} ({:.3})",
        resp.pos, resp.dist
    );

    println!("\n== graceful drain under an open connection ==");
    server.drain();
    let mut line = String::new();
    anyhow::ensure!(reader.read_line(&mut line)? == 0, "expected clean EOF after drain");
    println!(
        "drained cleanly: EOF on the open session, {} queries served end to end.",
        svc.queries_served()
    );
    Ok(())
}
