//! Perf probes. Two families:
//!
//! * `staged | generic | plain` — the original minimal reproducer for the
//!   staged-vs-generic kernel anomaly (time one DTW core over a fixed
//!   candidate set).
//! * `strips` (default) — the scan front-end A/B: run the same top-k
//!   subsequence search through the legacy scalar loop and the
//!   strip-mined pipeline on every synthetic dataset, verify the results
//!   are bitwise identical, and print the scalar-vs-strip DTW-call
//!   reduction the batched bounds + LB-ordered evaluation deliver.
//! * `cohort` — the batch front-end A/B: the same batch of same-shape
//!   queries through `Engine::search_batch_sequential` (query-major) and
//!   `Engine::search_batch` (cohort strip-major), printing the per-query
//!   DTW-call and strip-stat-load reduction as the batch grows.
use repro::data::{extract_queries, Dataset};
use repro::index::{Engine, EngineConfig, Query, TopKResult};
use repro::distances::dtw::cdtw_ws;
use repro::distances::eap_dtw::eap_cdtw;
use repro::distances::elastic::core::{eap_elastic, DtwAsElastic};
use repro::distances::metric::Metric;
use repro::distances::DtwWorkspace;
use repro::metrics::Counters;
use repro::norm::znorm::znorm;
use repro::search::subsequence::{
    search_subsequence_topk_metric_mode, window_cells, ScanMode,
};
use repro::search::suite::Suite;

fn kernel_probe(mode: &str) {
    let n = 512; let w = n/5;
    let r = Dataset::Ecg.generate(50 * n + 4000, 11);
    let q = znorm(&extract_queries(&r, 1, n, 0.1, 5).remove(0));
    let cands: Vec<Vec<f64>> = (0..30).map(|i| znorm(&r[i*n..i*n+n])).collect();
    let mut ws = DtwWorkspace::default();
    let reps = 2000;
    let t = std::time::Instant::now();
    let mut acc = 0.0;
    match mode {
        "staged" => for _ in 0..reps { for c in &cands {
            acc += std::hint::black_box(eap_cdtw(&q, c, w, f64::INFINITY, None, &mut ws)); } },
        "generic" => for _ in 0..reps { for c in &cands {
            acc += std::hint::black_box(eap_elastic(&DtwAsElastic{li:&q, co:c}, w, f64::INFINITY, &mut ws)); } },
        "plain" => for _ in 0..reps { for c in &cands {
            acc += std::hint::black_box(cdtw_ws(&q, c, w, &mut ws)); } },
        _ => unreachable!(),
    }
    println!("{mode}: {:?} acc={acc}", t.elapsed());
}

fn strip_probe() {
    let (ref_len, qlen, ratio, k) = (20_000usize, 256usize, 0.1, 5usize);
    let w = window_cells(qlen, ratio);
    let suite = Suite::UcrMon;
    println!("scan front-end A/B (qlen {qlen}, w {w}, k {k}, suite {}):", suite.name());
    println!(
        "{:<8} {:>9} {:>9} {:>9} {:>7} | {:>10} {:>10}",
        "dataset", "dtw_scal", "dtw_strip", "saved", "cut%", "scalar", "strip"
    );
    let (mut tot_scalar, mut tot_strip) = (0u64, 0u64);
    for d in Dataset::ALL {
        let r = d.generate(ref_len, 11);
        let q = extract_queries(&r, 1, qlen, 0.1, 5).remove(0);
        let mut run = |mode: ScanMode| {
            let mut c = Counters::new();
            let t = std::time::Instant::now();
            let m = search_subsequence_topk_metric_mode(
                &r, &q, w, k, Metric::Cdtw, suite, mode, &mut c,
            );
            (m, c, t.elapsed())
        };
        let (ms, cs, ts) = run(ScanMode::Scalar);
        let (mt, ct, tt) = run(ScanMode::Strip);
        assert_eq!(ms, mt, "{}: modes diverged", d.name());
        tot_scalar += cs.dtw_calls;
        tot_strip += ct.dtw_calls;
        let cut = 100.0 * (cs.dtw_calls as f64 - ct.dtw_calls as f64)
            / cs.dtw_calls.max(1) as f64;
        println!(
            "{:<8} {:>9} {:>9} {:>9} {:>6.1}% | {:>10.2?} {:>10.2?}",
            d.name(),
            cs.dtw_calls,
            ct.dtw_calls,
            ct.lb_order_saved_dtw_calls,
            cut,
            ts,
            tt
        );
        if d == Dataset::Ppg {
            println!("  {}", ct.strip_report());
        }
    }
    let cut = 100.0 * (tot_scalar as f64 - tot_strip as f64) / tot_scalar.max(1) as f64;
    println!("total DTW calls: scalar {tot_scalar} vs strip {tot_strip} — reduction {cut:.1}%");
}

fn cohort_probe() {
    let (ref_len, qlen, ratio, k) = (20_000usize, 128usize, 0.1, 5usize);
    let r = Dataset::Ecg.generate(ref_len, 11);
    let queries: Vec<Query> = extract_queries(&r, 64, qlen, 0.1, 5)
        .into_iter()
        .map(|q| Query::new(q, ratio))
        .collect();
    let engine = Engine::new(r, &EngineConfig { shards: 2, ..Default::default() }).unwrap();
    let merged = |rs: &[TopKResult]| {
        let mut c = Counters::new();
        for r in rs {
            c.merge(&r.counters);
        }
        c
    };
    println!("batch front-end A/B (ECG, qlen {qlen}, k {k}): per-query cost vs batch size");
    println!(
        "{:>5} | {:>9} {:>9} {:>6} | {:>10} {:>10} {:>6} | {:>7}",
        "batch", "dtw/q seq", "dtw/q coh", "cut%", "stats/q seq", "stats/q coh", "cut%", "retired"
    );
    for b in [1usize, 4, 16, 64] {
        let batch = &queries[..b];
        let seq = engine.search_batch_sequential(batch, k).unwrap();
        let coh = engine.search_batch(batch, k).unwrap();
        for (a, c) in seq.iter().zip(&coh) {
            assert_eq!(a.matches.len(), c.matches.len(), "modes diverged");
            for (x, y) in a.matches.iter().zip(&c.matches) {
                assert!(x.pos == y.pos && x.dist.to_bits() == y.dist.to_bits(), "modes diverged");
            }
        }
        let (cs, cc) = (merged(&seq), merged(&coh));
        // stat-lane loads: sequential pulls every candidate's (mean, std)
        // once per query; the cohort pulls each strip once for everyone
        let (seq_loads, coh_loads) = (cs.candidates, cc.candidates - cc.strip_stat_loads_saved);
        let pct = |old: f64, new: f64| 100.0 * (old - new) / old.max(1e-12);
        let bq = b as f64;
        println!(
            "{:>5} | {:>9.0} {:>9.0} {:>5.1}% | {:>10.0} {:>10.0} {:>5.1}% | {:>7}",
            b,
            cs.dtw_calls as f64 / bq,
            cc.dtw_calls as f64 / bq,
            pct(cs.dtw_calls as f64, cc.dtw_calls as f64),
            seq_loads as f64 / bq,
            coh_loads as f64 / bq,
            pct(seq_loads as f64 / bq, coh_loads as f64 / bq),
            cc.cohort_retired_queries,
        );
        if b == 64 {
            println!("  {}", cc.cohort_report());
        }
    }
}

fn main() {
    let mode = std::env::args().nth(1).unwrap_or_else(|| "strips".to_string());
    match mode.as_str() {
        "staged" | "generic" | "plain" => kernel_probe(&mode),
        "strips" => strip_probe(),
        "cohort" => cohort_probe(),
        _ => panic!("mode: strips|cohort|staged|generic|plain"),
    }
}
