// Minimal reproducer for the staged-vs-generic anomaly.
use repro::distances::eap_dtw::eap_cdtw;
use repro::distances::elastic::core::{eap_elastic, DtwAsElastic};
use repro::distances::dtw::cdtw_ws;
use repro::distances::DtwWorkspace;
use repro::norm::znorm::znorm;
use repro::data::{extract_queries, Dataset};

fn main() {
    let n = 512; let w = n/5;
    let r = Dataset::Ecg.generate(50 * n + 4000, 11);
    let q = znorm(&extract_queries(&r, 1, n, 0.1, 5).remove(0));
    let cands: Vec<Vec<f64>> = (0..30).map(|i| znorm(&r[i*n..i*n+n])).collect();
    let mut ws = DtwWorkspace::default();
    let mode = std::env::args().nth(1).unwrap_or_default();
    let reps = 2000;
    let t = std::time::Instant::now();
    let mut acc = 0.0;
    match mode.as_str() {
        "staged" => for _ in 0..reps { for c in &cands {
            acc += std::hint::black_box(eap_cdtw(&q, c, w, f64::INFINITY, None, &mut ws)); } },
        "generic" => for _ in 0..reps { for c in &cands {
            acc += std::hint::black_box(eap_elastic(&DtwAsElastic{li:&q, co:c}, w, f64::INFINITY, &mut ws)); } },
        "plain" => for _ in 0..reps { for c in &cands {
            acc += std::hint::black_box(cdtw_ws(&q, c, w, &mut ws)); } },
        _ => panic!("mode: staged|generic|plain"),
    }
    println!("{mode}: {:?} acc={acc}", t.elapsed());
}
