//! Perf probes. Two families:
//!
//! * `staged | generic | plain` — the original minimal reproducer for the
//!   staged-vs-generic kernel anomaly (time one DTW core over a fixed
//!   candidate set).
//! * `strips` (default) — the scan front-end A/B: run the same top-k
//!   subsequence search through the legacy scalar loop and the
//!   strip-mined pipeline on every synthetic dataset, verify the results
//!   are bitwise identical, and print the scalar-vs-strip DTW-call
//!   reduction the batched bounds + LB-ordered evaluation deliver.
use repro::data::{extract_queries, Dataset};
use repro::distances::dtw::cdtw_ws;
use repro::distances::eap_dtw::eap_cdtw;
use repro::distances::elastic::core::{eap_elastic, DtwAsElastic};
use repro::distances::metric::Metric;
use repro::distances::DtwWorkspace;
use repro::metrics::Counters;
use repro::norm::znorm::znorm;
use repro::search::subsequence::{
    search_subsequence_topk_metric_mode, window_cells, ScanMode,
};
use repro::search::suite::Suite;

fn kernel_probe(mode: &str) {
    let n = 512; let w = n/5;
    let r = Dataset::Ecg.generate(50 * n + 4000, 11);
    let q = znorm(&extract_queries(&r, 1, n, 0.1, 5).remove(0));
    let cands: Vec<Vec<f64>> = (0..30).map(|i| znorm(&r[i*n..i*n+n])).collect();
    let mut ws = DtwWorkspace::default();
    let reps = 2000;
    let t = std::time::Instant::now();
    let mut acc = 0.0;
    match mode {
        "staged" => for _ in 0..reps { for c in &cands {
            acc += std::hint::black_box(eap_cdtw(&q, c, w, f64::INFINITY, None, &mut ws)); } },
        "generic" => for _ in 0..reps { for c in &cands {
            acc += std::hint::black_box(eap_elastic(&DtwAsElastic{li:&q, co:c}, w, f64::INFINITY, &mut ws)); } },
        "plain" => for _ in 0..reps { for c in &cands {
            acc += std::hint::black_box(cdtw_ws(&q, c, w, &mut ws)); } },
        _ => unreachable!(),
    }
    println!("{mode}: {:?} acc={acc}", t.elapsed());
}

fn strip_probe() {
    let (ref_len, qlen, ratio, k) = (20_000usize, 256usize, 0.1, 5usize);
    let w = window_cells(qlen, ratio);
    let suite = Suite::UcrMon;
    println!("scan front-end A/B (qlen {qlen}, w {w}, k {k}, suite {}):", suite.name());
    println!(
        "{:<8} {:>9} {:>9} {:>9} {:>7} | {:>10} {:>10}",
        "dataset", "dtw_scal", "dtw_strip", "saved", "cut%", "scalar", "strip"
    );
    let (mut tot_scalar, mut tot_strip) = (0u64, 0u64);
    for d in Dataset::ALL {
        let r = d.generate(ref_len, 11);
        let q = extract_queries(&r, 1, qlen, 0.1, 5).remove(0);
        let mut run = |mode: ScanMode| {
            let mut c = Counters::new();
            let t = std::time::Instant::now();
            let m = search_subsequence_topk_metric_mode(
                &r, &q, w, k, Metric::Cdtw, suite, mode, &mut c,
            );
            (m, c, t.elapsed())
        };
        let (ms, cs, ts) = run(ScanMode::Scalar);
        let (mt, ct, tt) = run(ScanMode::Strip);
        assert_eq!(ms, mt, "{}: modes diverged", d.name());
        tot_scalar += cs.dtw_calls;
        tot_strip += ct.dtw_calls;
        let cut = 100.0 * (cs.dtw_calls as f64 - ct.dtw_calls as f64)
            / cs.dtw_calls.max(1) as f64;
        println!(
            "{:<8} {:>9} {:>9} {:>9} {:>6.1}% | {:>10.2?} {:>10.2?}",
            d.name(),
            cs.dtw_calls,
            ct.dtw_calls,
            ct.lb_order_saved_dtw_calls,
            cut,
            ts,
            tt
        );
        if d == Dataset::Ppg {
            println!("  {}", ct.strip_report());
        }
    }
    let cut = 100.0 * (tot_scalar as f64 - tot_strip as f64) / tot_scalar.max(1) as f64;
    println!("total DTW calls: scalar {tot_scalar} vs strip {tot_strip} — reduction {cut:.1}%");
}

fn main() {
    let mode = std::env::args().nth(1).unwrap_or_else(|| "strips".to_string());
    match mode.as_str() {
        "staged" | "generic" | "plain" => kernel_probe(&mode),
        "strips" => strip_probe(),
        _ => panic!("mode: strips|staged|generic|plain"),
    }
}
