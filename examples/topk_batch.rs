//! Batched top-k similarity search through the reference-side index: one
//! `Engine` over an indexed reference stream answers a whole batch of
//! queries, each asking for its k best matches — the serving shape the
//! `index` layer exists for.
//!
//! Run with: `cargo run --release --example topk_batch`
//! Optional: `-- --ref-len 80000 --batch 16 --k 5 --qlen 256 --ratio 0.1`

use repro::data::{extract_queries, Dataset};
use repro::index::{Engine, EngineConfig, Query};
use repro::metrics::{Counters, Timer};
use repro::search::subsequence::{search_subsequence, window_cells};
use repro::search::suite::Suite;
use repro::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let ref_len = args.usize_or("ref-len", 60_000)?;
    let batch = args.usize_or("batch", 16)?;
    let k = args.usize_or("k", 5)?;
    let qlen = args.usize_or("qlen", 256)?;
    let ratio = args.f64_or("ratio", 0.1)?;
    let shards = args.usize_or("shards", 2)?;

    let dataset = Dataset::Ecg;
    let reference = dataset.generate(ref_len, 42);
    let queries: Vec<Query> = extract_queries(&reference, batch, qlen, 0.1, 7)
        .into_iter()
        .map(|q| Query::new(q, ratio))
        .collect();

    println!(
        "top-{k} batch search: {} x {ref_len} points, {batch} queries (qlen {qlen}, ratio {ratio}), {shards} shards\n",
        dataset.name()
    );

    let engine = Engine::new(
        reference.clone(),
        &EngineConfig { shards, suite: Suite::UcrMon, ..Default::default() },
    )?;
    let t = Timer::start();
    let results = engine.search_batch(&queries, k)?;
    let secs = t.elapsed_secs();

    let mut total = Counters::new();
    for (i, res) in results.iter().enumerate() {
        total.merge(&res.counters);
        let ranked: Vec<String> = res
            .matches
            .iter()
            .map(|m| format!("pos {} (d={:.4})", m.pos, m.dist))
            .collect();
        println!("query {i:>2}: {}", ranked.join(", "));
    }
    println!(
        "\n{batch} queries in {:.3}s ({:.1} q/s); {}",
        secs,
        batch as f64 / secs,
        total.index_report()
    );

    // sanity: rank 1 of each query agrees with the seed's scalar search
    let w = window_cells(qlen, ratio);
    for (q, res) in queries.iter().zip(&results) {
        let mut c = Counters::new();
        let want = search_subsequence(&reference, &q.query, w, Suite::UcrMon, &mut c);
        assert_eq!(res.best().pos, want.pos, "top-1 must equal the scalar best-so-far search");
    }
    println!("verified: every query's rank-1 equals the unbatched scalar search.");
    Ok(())
}
