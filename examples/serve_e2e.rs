//! End-to-end serving driver (DESIGN.md's E2E validation): boots the full
//! three-layer stack — reference stream, shard workers, and the AOT XLA
//! prefilter engine if `artifacts/` exists — then serves a batch of real
//! queries and reports latency percentiles and throughput per suite.
//!
//! This is the "all layers compose" proof: Layer 1/2 (Pallas/JAX graphs,
//! AOT-lowered) execute inside the Layer-3 Rust service on the request
//! path, with Python nowhere in sight.
//!
//! Run with: `cargo run --release --example serve_e2e`
//! Optional: `-- --ref-len 100000 --queries 40 --shards 4`

use std::path::PathBuf;

use repro::coordinator::{QueryRequest, Service, ServiceConfig};
use repro::data::{extract_queries, Dataset};
use repro::distances::metric::Metric;
use repro::metrics::Timer;
use repro::search::suite::Suite;
use repro::util::cli::Args;

fn percentile(sorted: &[f64], p: f64) -> f64 {
    sorted[((sorted.len() - 1) as f64 * p) as usize]
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let ref_len = args.usize_or("ref-len", 80_000)?;
    let n_queries = args.usize_or("queries", 24)?;
    let shards = args.usize_or("shards", 2)?;
    let qlen = args.usize_or("qlen", 256)?;
    let ratio = args.f64_or("ratio", 0.1)?;
    let artifacts = PathBuf::from(
        args.get_or("artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")),
    );

    println!("== boot ==");
    let reference = Dataset::Ecg.generate(ref_len, 2026);
    let queries = extract_queries(&reference, n_queries, qlen, 0.1, 7);
    let have_artifacts = artifacts.join("manifest.json").exists();
    let svc = Service::new(
        reference,
        &ServiceConfig {
            shards,
            artifacts_dir: have_artifacts.then(|| artifacts.clone()),
            ..Default::default()
        },
    )?;
    println!(
        "service up: reference {} points, {shards} shards, XLA engine: {}",
        svc.reference_len(),
        if svc.has_engine() { "loaded" } else { "absent (run `make artifacts`)" }
    );

    let mut suites = vec![Suite::Ucr, Suite::UcrMon, Suite::UcrMonNoLb];
    if svc.has_engine() {
        suites.push(Suite::UcrMonXla);
    }

    println!("\n== serving {n_queries} queries x {} suites ==", suites.len());
    let mut reference_answers: Vec<(usize, f64)> = Vec::new();
    for suite in suites {
        let mut latencies = Vec::with_capacity(n_queries);
        let wall = Timer::start();
        let mut answers = Vec::new();
        for (i, q) in queries.iter().enumerate() {
            let resp = svc.submit(&QueryRequest {
                id: i as u64,
                query: q.clone(),
                window_ratio: ratio,
                suite,
                k: 1,
                metric: Metric::Cdtw,
                deadline_ms: None,
                tenant: None,
            })?;
            latencies.push(resp.latency_ms);
            answers.push((resp.pos, resp.dist));
        }
        let wall = wall.elapsed_secs();
        latencies.sort_by(|a, b| a.partial_cmp(b).expect("no NaN"));
        println!(
            "{:<13} throughput {:>6.2} q/s | latency p50 {:>7.2}ms p95 {:>7.2}ms max {:>7.2}ms",
            suite.name(),
            n_queries as f64 / wall,
            percentile(&latencies, 0.50),
            percentile(&latencies, 0.95),
            latencies[latencies.len() - 1],
        );
        // cross-suite agreement — the E2E correctness check
        if reference_answers.is_empty() {
            reference_answers = answers;
        } else {
            for (i, (got, want)) in answers.iter().zip(&reference_answers).enumerate() {
                assert_eq!(got.0, want.0, "query {i}: {} disagrees", suite.name());
                assert!((got.1 - want.1).abs() < 1e-3 + want.1 * 1e-3, "query {i} distance");
            }
        }
    }
    println!(
        "\nserved {} queries total; every suite returned identical matches — \
         all three layers compose.",
        svc.queries_served()
    );
    Ok(())
}
