//! Quickstart: the 60-second tour — compute DTW distances with every
//! variant, see early abandoning in action on the paper's own worked
//! example, then run one real subsequence search.
//!
//! Run with: `cargo run --release --example quickstart`

use repro::data::{extract_queries, Dataset};
use repro::distances::dtw::dtw;
use repro::distances::eap_dtw::{eap_cdtw_counted, eap_dtw};
use repro::distances::DtwWorkspace;
use repro::metrics::Counters;
use repro::search::subsequence::{search_subsequence, window_cells};
use repro::search::suite::Suite;

fn main() {
    // --- the paper's worked example (Fig. 2): S, T with DTW = 9 ---
    let s = [3.0, 1.0, 4.0, 4.0, 1.0, 1.0];
    let t = [1.0, 3.0, 2.0, 1.0, 2.0, 2.0];
    println!("DTW(S,T)                  = {}", dtw(&s, &t));
    println!("EAPrunedDTW(S,T, ub=inf)  = {}", eap_dtw(&s, &t, f64::INFINITY));
    println!("EAPrunedDTW(S,T, ub=9)    = {}  (tie kept — paper Fig. 4a)", eap_dtw(&s, &t, 9.0));
    println!("EAPrunedDTW(S,T, ub=6)    = {}  (early abandoned — Fig. 4b)", eap_dtw(&s, &t, 6.0));

    // --- pruning in numbers: DP cells actually computed ---
    let mut ws = DtwWorkspace::default();
    let (_, cells_full) = eap_cdtw_counted(&s, &t, 6, f64::INFINITY, None, &mut ws);
    let (_, cells_ub9) = eap_cdtw_counted(&s, &t, 6, 9.0, None, &mut ws);
    println!("\nDP cells: {cells_full} without a bound, {cells_ub9} with ub=9 (6x6=36 matrix)");

    // --- one real search: a noisy ECG excerpt against its stream ---
    let reference = Dataset::Ecg.generate(50_000, 42);
    let query = extract_queries(&reference, 1, 256, 0.1, 7).remove(0);
    let w = window_cells(query.len(), 0.1);
    for suite in [Suite::Ucr, Suite::UcrMon, Suite::UcrMonNoLb] {
        let mut c = Counters::new();
        let t0 = std::time::Instant::now();
        let m = search_subsequence(&reference, &query, w, suite, &mut c);
        println!(
            "{:<13} -> pos {:>6} dist {:.4} in {:>7.2?}  (DTW reached {:.1}% of {} candidates)",
            suite.name(),
            m.pos,
            m.dist,
            t0.elapsed(),
            c.prune_fractions().4 * 100.0,
            c.candidates
        );
    }
    println!("\nAll suites return the identical match — they differ only in speed.");
}
