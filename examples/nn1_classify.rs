//! NN1-DTW classification — the paper's motivating scenario (§1: NN1-DTW
//! is a component of EE, Proximity Forest, TS-CHIEF; §6: EAPrunedDTW makes
//! it affordable). Builds a labelled synthetic "activity snippets" set
//! (one class per dataset generator) and classifies held-out snippets,
//! comparing the DTW cores' speed at identical accuracy.
//!
//! Run with: `cargo run --release --example nn1_classify`

use repro::data::Dataset;
use repro::metrics::{Counters, Timer};
use repro::norm::znorm::znorm;
use repro::search::nn1::nn1_classify;
use repro::search::suite::Suite;

const SNIPPET: usize = 256;

fn snippets(d: Dataset, count: usize, seed: u64) -> Vec<Vec<f64>> {
    let r = d.generate(count * SNIPPET * 3 + 1000, seed);
    (0..count)
        .map(|i| znorm(&r[i * SNIPPET * 3..i * SNIPPET * 3 + SNIPPET]))
        .collect()
}

fn main() {
    let classes = [Dataset::Ecg, Dataset::Ppg, Dataset::FoG, Dataset::Refit];
    let per_class_train = 30;
    let per_class_test = 10;
    let w = SNIPPET / 10;

    let mut train: Vec<(usize, Vec<f64>)> = Vec::new();
    let mut test: Vec<(usize, Vec<f64>)> = Vec::new();
    for (label, d) in classes.into_iter().enumerate() {
        for s in snippets(d, per_class_train, 100 + label as u64) {
            train.push((label, s));
        }
        for s in snippets(d, per_class_test, 900 + label as u64) {
            test.push((label, s));
        }
    }
    println!(
        "NN1-DTW: {} train, {} test, {} classes, snippet {}, w={}",
        train.len(),
        test.len(),
        classes.len(),
        SNIPPET,
        w
    );

    for suite in [Suite::Ucr, Suite::UcrUsp, Suite::UcrMon] {
        let mut correct = 0usize;
        let mut counters = Counters::new();
        let t = Timer::start();
        for (label, q) in &test {
            let got = nn1_classify(q, &train, w, suite, &mut counters).expect("non-empty train");
            if got == *label {
                correct += 1;
            }
        }
        let secs = t.elapsed_secs();
        println!(
            "{:<9} accuracy {:>5.1}% in {:>7.3}s — DTW called on {:.1}% of candidates, {:.1}% abandoned",
            suite.name(),
            100.0 * correct as f64 / test.len() as f64,
            secs,
            100.0 * counters.dtw_calls as f64 / counters.candidates.max(1) as f64,
            100.0 * counters.dtw_abandons as f64 / counters.dtw_calls.max(1) as f64,
        );
    }
    println!("\nSame accuracy by construction (exact NN1) — the cores only differ in time.");
}
