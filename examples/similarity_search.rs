//! The paper's core workload (§5): subsequence similarity search on every
//! dataset, comparing the four suites — a miniature of Figure 5 you can
//! run in under a minute.
//!
//! Run with: `cargo run --release --example similarity_search`
//! Optional: `-- --ref-len 100000 --qlen 512 --ratio 0.2`

use repro::data::{extract_queries, Dataset};
use repro::metrics::{Counters, Timer};
use repro::search::subsequence::{search_subsequence, window_cells};
use repro::search::suite::Suite;
use repro::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let ref_len = args.usize_or("ref-len", 60_000)?;
    let qlen = args.usize_or("qlen", 256)?;
    let ratio = args.f64_or("ratio", 0.1)?;
    let w = window_cells(qlen, ratio);

    println!(
        "subsequence search: ref_len={ref_len}, qlen={qlen}, ratio={ratio} (w={w})\n"
    );
    println!(
        "{:<8} {:>14} {:>14} {:>14} {:>14}",
        "dataset",
        Suite::Ucr.name(),
        Suite::UcrUsp.name(),
        Suite::UcrMon.name(),
        Suite::UcrMonNoLb.name()
    );
    let mut totals = [0.0f64; 4];
    for d in Dataset::ALL {
        let reference = d.generate(ref_len, 42);
        let query = extract_queries(&reference, 1, qlen, 0.1, 7).remove(0);
        let mut row = format!("{:<8}", d.name());
        let mut pos_check = None;
        for (i, suite) in Suite::ALL.into_iter().enumerate() {
            let mut c = Counters::new();
            let t = Timer::start();
            let m = search_subsequence(&reference, &query, w, suite, &mut c);
            let secs = t.elapsed_secs();
            totals[i] += secs;
            row.push_str(&format!(" {:>13.3}s", secs));
            match pos_check {
                None => pos_check = Some(m.pos),
                Some(p) => assert_eq!(p, m.pos, "suites disagree!"),
            }
        }
        println!("{row}");
    }
    println!(
        "\ntotals: UCR {:.2}s | USP {:.2}s | MON {:.2}s | MON-nolb {:.2}s",
        totals[0], totals[1], totals[2], totals[3]
    );
    println!(
        "speedups vs UCR: USP {:.2}x, MON {:.2}x, MON-nolb {:.2}x  (paper: 4.3x, 8.8x, 6.4x at full scale)",
        totals[0] / totals[1],
        totals[0] / totals[2],
        totals[0] / totals[3]
    );
    Ok(())
}
