//! The paper's future work, §6, realised: the EAPruned scheme applied to
//! other elastic distances (ERP, MSM, TWE, WDTW) via the generalised
//! skeleton. For each measure we run NN1 search with and without early
//! abandoning and report the saving — "we should be able to speed up most
//! of elastic distances" made concrete.
//!
//! Run with: `cargo run --release --example elastic_extensions`

use repro::data::Dataset;
use repro::distances::elastic::erp::{eap_erp, erp_naive};
use repro::distances::elastic::msm::{eap_msm, msm_naive};
use repro::distances::elastic::twe::{eap_twe, twe_naive};
use repro::distances::elastic::wdtw::{eap_wdtw, wdtw_naive};
use repro::distances::DtwWorkspace;
use repro::metrics::Timer;
use repro::norm::znorm::znorm;

const LEN: usize = 128;
const CANDS: usize = 200;

fn main() {
    let r = Dataset::Pamap2.generate(CANDS * LEN * 2 + 4000, 7);
    let candidates: Vec<Vec<f64>> =
        (0..CANDS).map(|i| znorm(&r[i * LEN * 2..i * LEN * 2 + LEN])).collect();
    let query = znorm(&r[999..999 + LEN]);
    let mut ws = DtwWorkspace::default();

    type NaiveFn = Box<dyn Fn(&[f64], &[f64]) -> f64>;
    type EapFn = Box<dyn Fn(&[f64], &[f64], f64, &mut DtwWorkspace) -> f64>;
    let measures: Vec<(&str, NaiveFn, EapFn)> = vec![
        (
            "ERP(g=0)",
            Box::new(|a, b| erp_naive(a, b, 0.0, LEN)),
            Box::new(|a, b, ub, ws| eap_erp(a, b, 0.0, LEN, ub, ws)),
        ),
        (
            "MSM(c=0.5)",
            Box::new(|a, b| msm_naive(a, b, 0.5, LEN)),
            Box::new(|a, b, ub, ws| eap_msm(a, b, 0.5, LEN, ub, ws)),
        ),
        (
            "TWE(nu=1e-3,l=1)",
            Box::new(|a, b| twe_naive(a, b, 0.001, 1.0, LEN)),
            Box::new(|a, b, ub, ws| eap_twe(a, b, 0.001, 1.0, LEN, ub, ws)),
        ),
        (
            "WDTW(g=0.05)",
            Box::new(|a, b| wdtw_naive(a, b, 0.05, LEN)),
            Box::new(|a, b, ub, ws| eap_wdtw(a, b, 0.05, LEN, ub, ws)),
        ),
    ];

    println!(
        "NN1 over {CANDS} candidates, series length {LEN} — naive full-matrix vs EAPruned\n"
    );
    println!(
        "{:<17} {:>12} {:>12} {:>9} {:>11}",
        "measure", "naive", "EAPruned", "speedup", "abandoned"
    );
    for (name, naive, eap) in measures {
        // naive NN1: full matrix every time
        let t = Timer::start();
        let mut best_naive = (0usize, f64::INFINITY);
        for (i, c) in candidates.iter().enumerate() {
            let d = naive(&query, c);
            if d < best_naive.1 {
                best_naive = (i, d);
            }
        }
        let t_naive = t.elapsed_secs();

        // EAPruned NN1: shrinking upper bound
        let t = Timer::start();
        let mut best_eap = (0usize, f64::INFINITY);
        let mut abandoned = 0usize;
        for (i, c) in candidates.iter().enumerate() {
            let d = eap(&query, c, best_eap.1, &mut ws);
            if d.is_infinite() {
                abandoned += 1;
            } else if d < best_eap.1 {
                best_eap = (i, d);
            }
        }
        let t_eap = t.elapsed_secs();

        assert_eq!(best_naive.0, best_eap.0, "{name}: EAPruned changed the NN!");
        assert!((best_naive.1 - best_eap.1).abs() < 1e-9);
        println!(
            "{:<17} {:>11.2}ms {:>11.2}ms {:>8.2}x {:>10.1}%",
            name,
            t_naive * 1e3,
            t_eap * 1e3,
            t_naive / t_eap,
            100.0 * abandoned as f64 / CANDS as f64
        );
    }
    println!(
        "\nIdentical nearest neighbours, large fractions of candidates abandoned —\n\
         the paper's §6 claim demonstrated beyond DTW."
    );
}
