//! K1 — Layer-1/2 runtime micro-bench: throughput of the AOT-compiled
//! graphs (znorm, LB_Keogh prefilter, wavefront DTW) through PJRT, vs the
//! scalar Rust equivalents, per query length. Also reports compile (first
//! call) vs steady-state cost, i.e. what the executable cache buys.
//!
//! Skips politely when `artifacts/` is missing.

use std::path::Path;

use repro::bench_support::harness::{bench, fmt_secs};
use repro::bench_support::report::BenchJson;
use repro::bounds::envelope::envelopes;
use repro::bounds::lb_keogh::{lb_keogh_eq, reorder, sort_order};
use repro::data::{extract_queries, Dataset};
use repro::metrics::Timer;
use repro::norm::znorm::{stats, znorm};
use repro::runtime::XlaEngine;
use repro::util::json::Json;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts — run `make artifacts`");
        return;
    }
    let mut engine = XlaEngine::open(&dir).unwrap();
    let b = engine.batch();
    let lengths = engine.manifest().lengths.clone();
    let mut json = BenchJson::new("xla_runtime");
    println!("xla runtime micro (batch={b}):");
    println!(
        "{:>5} | {:>10} {:>12} {:>12} | {:>12} {:>14}",
        "n", "compile", "prefilter", "dtw(w=n/5)", "scalar LB", "LB speedup"
    );
    for &n in &lengths {
        let r = Dataset::Ecg.generate(b + n + 100, 5);
        let q = znorm(&extract_queries(&r, 1, n, 0.1, 3).remove(0));
        let w = n / 5;
        let (u, l) = envelopes(&q, w);
        let u32v: Vec<f32> = u.iter().map(|&v| v as f32).collect();
        let l32v: Vec<f32> = l.iter().map(|&v| v as f32).collect();
        let q32: Vec<f32> = q.iter().map(|&v| v as f32).collect();
        let mut panel = vec![0f32; b * n];
        for k in 0..b {
            for j in 0..n {
                panel[k * n + j] = r[k + j] as f32;
            }
        }
        // compile cost = first call
        let t0 = Timer::start();
        engine.prefilter(n, &u32v, &l32v, &panel).unwrap();
        let compile = t0.elapsed_secs();
        let pf = bench(2, 10, || engine.prefilter(n, &u32v, &l32v, &panel).unwrap());
        let zn = engine.znorm(n, &panel).unwrap();
        let dtw = bench(1, 3, || engine.batched_dtw(n, &q32, w, &zn).unwrap());
        // scalar comparator: LB_Keogh EQ over the same b windows
        let order = sort_order(&q);
        let uo = reorder(&u, &order);
        let lo = reorder(&l, &order);
        let mut cb = vec![0.0; n];
        let scalar = bench(2, 10, || {
            let mut acc = 0.0;
            for k in 0..b {
                let window = &r[k..k + n];
                let (mean, std) = stats(window);
                acc += lb_keogh_eq(&order, &uo, &lo, window, mean, std, f64::INFINITY, &mut cb);
            }
            acc
        });
        println!(
            "{:>5} | {:>10} {:>12} {:>12} | {:>12} {:>13.2}x",
            n,
            fmt_secs(compile),
            fmt_secs(pf.median),
            fmt_secs(dtw.median),
            fmt_secs(scalar.median),
            scalar.median / pf.median,
        );
        for (stage, secs) in [
            ("compile", compile),
            ("prefilter", pf.median),
            ("dtw", dtw.median),
            ("scalar_lb", scalar.median),
        ] {
            json.push(vec![
                ("suite", Json::Str(stage.to_string())),
                ("dataset", Json::Str("ECG".to_string())),
                ("qlen", Json::Num(n as f64)),
                ("batch", Json::Num(b as f64)),
                ("ns_per_op", Json::Num(secs * 1e9)),
            ]);
        }
    }
    println!("\n(prefilter throughput is the UcrMonXla admission rate; dtw is the A3 full-resolve cost)");
    json.write_and_announce();
}
