//! Ablation A2 — what the **stage decomposition** (1-/2-dependency cell
//! updates) buys, holding everything else fixed: `eap_cdtw` (specialised
//! stages) vs the same EAP logic run through the generic elastic skeleton
//! with DTW costs (`DtwAsElastic`: 3-way min everywhere, per-move cost
//! closures). Identical pruning decisions, different inner loops — the
//! paper's "saving considerable computation" claim isolated.

use repro::bench_support::harness::{bench, fmt_secs};
use repro::bench_support::report::BenchJson;
use repro::data::{extract_queries, Dataset};
use repro::distances::dtw::cdtw;
use repro::distances::eap_dtw::eap_cdtw;
use repro::distances::elastic::core::{eap_elastic, DtwAsElastic};
use repro::distances::DtwWorkspace;
use repro::norm::znorm::znorm;
use repro::util::json::Json;

fn main() {
    let mut json = BenchJson::new("ablation_stages");
    println!("ablation A2: staged EAPrunedDTW vs generic-skeleton EAP (3-way min)");
    println!(
        "{:<8} {:>5} {:>6} | {:>10} {:>10} {:>8}",
        "dataset", "n", "ub", "staged", "generic", "speedup"
    );
    for d in [Dataset::Ecg, Dataset::Refit, Dataset::Ppg] {
        for n in [128usize, 512] {
            let w = n / 5;
            let r = d.generate(50 * n + 4000, 11);
            let q = znorm(&extract_queries(&r, 1, n, 0.1, 5).remove(0));
            let cands: Vec<Vec<f64>> = (0..30).map(|i| znorm(&r[i * n..i * n + n])).collect();
            let mut dists: Vec<f64> = cands.iter().map(|c| cdtw(&q, c, w)).collect();
            dists.sort_by(|a, b| a.partial_cmp(b).expect("no NaN"));
            for (label, ub) in
                [("inf", f64::INFINITY), ("p25", dists[dists.len() / 4])]
            {
                let mut ws = DtwWorkspace::default();
                // correctness cross-check before timing
                for c in &cands {
                    let a = eap_cdtw(&q, c, w, ub, None, &mut ws);
                    let b = eap_elastic(&DtwAsElastic { li: &q, co: c }, w, ub, &mut ws);
                    assert!(
                        (a == b) || (a - b).abs() < 1e-9 || (a.is_infinite() && b.is_infinite()),
                        "staged {a} vs generic {b}"
                    );
                }
                let t_staged = bench(1, 7, || {
                    for c in &cands {
                        std::hint::black_box(eap_cdtw(&q, c, w, ub, None, &mut ws));
                    }
                });
                let t_generic = bench(1, 7, || {
                    for c in &cands {
                        std::hint::black_box(eap_elastic(
                            &DtwAsElastic { li: &q, co: c },
                            w,
                            ub,
                            &mut ws,
                        ));
                    }
                });
                println!(
                    "{:<8} {:>5} {:>6} | {:>10} {:>10} {:>7.2}x",
                    d.name(),
                    n,
                    label,
                    fmt_secs(t_staged.median),
                    fmt_secs(t_generic.median),
                    t_generic.median / t_staged.median
                );
                for (core, stats) in [("staged", &t_staged), ("generic", &t_generic)] {
                    json.push(vec![
                        ("suite", Json::Str(core.to_string())),
                        ("dataset", Json::Str(d.name().to_string())),
                        ("qlen", Json::Num(n as f64)),
                        ("ub", Json::Str(label.to_string())),
                        ("ns_per_op", Json::Num(stats.median * 1e9)),
                    ]);
                }
            }
        }
    }
    println!("\n(speedup > 1 = the stage decomposition itself, not the pruning, paying off)");
    json.write_and_announce();
}
