//! Figure 5b: average runtime per dataset by **window ratio** (averaged
//! over query lengths), four suites. The paper's shape: varying the window
//! has much less impact on the MON suites than on UCR/USP (pruning absorbs
//! the extra cells as w grows — §5's closing observation, REFIT excepted).

use repro::bench_support::grid::{experiments, run_experiment, Workload};
use repro::bench_support::grid_from_env;
use repro::bench_support::report::{fig5_table, BenchJson};
use repro::search::suite::Suite;

fn main() {
    let (mut grid, datasets) = grid_from_env(20_000);
    // Fig 5b averages over lengths; trim the length axis by default
    if std::env::var("REPRO_QLENS").is_err() {
        grid.query_lengths = vec![128, 512];
    }
    eprintln!(
        "fig5b: ref_len={} queries={} lengths={:?} ratios={:?}",
        grid.ref_len, grid.queries, grid.query_lengths, grid.window_ratios
    );
    let mut results = Vec::new();
    for &d in &datasets {
        let w = Workload::build(d, &grid);
        for exp in experiments(&grid, &[d]) {
            for s in Suite::ALL {
                results.push(run_experiment(&w, &exp, s));
            }
        }
        eprintln!("  {} done", d.name());
    }
    let xs: Vec<usize> = grid.window_ratios.iter().map(|r| (r * 100.0).round() as usize).collect();
    println!(
        "{}",
        fig5_table(&results, &Suite::ALL, &xs, "window ratio %", |r| {
            (r.exp.ratio * 100.0).round() as usize
        })
    );
    // window sensitivity: max/min runtime across ratios, per suite
    println!("\nwindow sensitivity (max/min across ratios, all datasets pooled):");
    for s in Suite::ALL {
        let mut per_ratio: std::collections::BTreeMap<usize, f64> = Default::default();
        for r in results.iter().filter(|r| r.suite == s) {
            *per_ratio.entry((r.exp.ratio * 100.0).round() as usize).or_insert(0.0) += r.seconds;
        }
        let mx = per_ratio.values().cloned().fold(f64::MIN, f64::max);
        let mn = per_ratio.values().cloned().fold(f64::MAX, f64::min);
        println!("  {:<13} {:.2}x", s.name(), mx / mn);
    }
    println!("(paper: MON suites markedly flatter than UCR/USP)");
    let mut json = BenchJson::new("fig5b_window_ratio");
    for r in &results {
        json.push_result(r);
    }
    json.write_and_announce();
}
