//! The §5 headline table: total time per suite over the whole grid,
//! speedups vs UCR and UCR-USP, and the slower-case statistics (the paper
//! reports MON slower than UCR in 7.3% of 600 runs, by small margins —
//! versus USP slower than UCR in 18% by up to ~986s).

use repro::bench_support::grid::{experiments, run_experiment, Workload};
use repro::bench_support::grid_from_env;
use repro::bench_support::report::{speedup_summary, BenchJson};
use repro::search::suite::Suite;

fn main() {
    let (mut grid, datasets) = grid_from_env(20_000);
    if std::env::var("REPRO_QLENS").is_err() {
        grid.query_lengths = vec![128, 256, 512, 1024];
    }
    if std::env::var("REPRO_RATIOS").is_err() {
        grid.window_ratios = vec![0.1, 0.3, 0.5];
    }
    eprintln!(
        "speedup grid: ref_len={} queries={} lengths={:?} ratios={:?}",
        grid.ref_len, grid.queries, grid.query_lengths, grid.window_ratios
    );
    let mut results = Vec::new();
    for &d in &datasets {
        let w = Workload::build(d, &grid);
        for exp in experiments(&grid, &[d]) {
            for s in Suite::ALL {
                results.push(run_experiment(&w, &exp, s));
            }
        }
        eprintln!("  {} done", d.name());
    }
    println!("== §5 totals & speedups (paper: MON 8.78x vs UCR, 2.04x vs USP; nolb 6.44x/1.49x) ==");
    println!("{}", speedup_summary(&results));
    let mut json = BenchJson::new("table_speedups");
    for r in &results {
        json.push_result(r);
    }
    json.write_and_announce();
}
