//! Strip-mined scan throughput: the scalar per-candidate loop vs the
//! strip pipeline (batched SoA bounds + LB-ordered survivors +
//! single-pass z-normalisation), A/B'd through the same entry point on
//! all six synthetic datasets. Verifies on every run that both modes
//! return bitwise-identical top-k results, reports wall time and the
//! full-DTW-call reduction LB-ordering buys, and emits
//! `BENCH_strip_throughput.json` for cross-PR tracking.
//!
//! Scaling knobs (env): `REPRO_REF_LEN` (default 20000), `REPRO_QUERIES`,
//! `REPRO_DATASETS`, `REPRO_QLENS`, `REPRO_RATIOS`.

use repro::bench_support::grid_from_env;
use repro::bench_support::harness::{bench, fmt_secs};
use repro::bench_support::report::BenchJson;
use repro::data::extract_queries;
use repro::distances::metric::Metric;
use repro::metrics::Counters;
use repro::obs::MetricsSnapshot;
use repro::search::subsequence::{
    search_subsequence_topk_metric_mode, window_cells, ScanMode,
};
use repro::search::suite::Suite;
use repro::util::json::Json;

fn main() {
    let (mut grid, datasets) = grid_from_env(20_000);
    if std::env::var("REPRO_QLENS").is_err() {
        grid.query_lengths = vec![128, 256];
    }
    if std::env::var("REPRO_RATIOS").is_err() {
        grid.window_ratios = vec![0.1];
    }
    let suite = Suite::UcrMon;
    let k = 5;
    let metric = Metric::Cdtw;
    println!(
        "strip throughput (suite {}, k={k}, ref_len {}, {} queries/cell): scalar vs strip scan",
        suite.name(),
        grid.ref_len,
        grid.queries
    );
    println!(
        "{:<8} {:>2} {:>5} {:>4} | {:>10} {:>10} {:>8} | {:>9} {:>9} {:>7} {:>7}",
        "dataset", "q", "qlen", "w%", "scalar", "strip", "speedup", "dtw_scal", "dtw_strip", "saved", "batch%"
    );
    let mut json = BenchJson::new("strip_throughput");
    let mut total = Counters::new();
    let (mut total_scalar_dtw, mut total_strip_dtw) = (0u64, 0u64);
    for &d in &datasets {
        let reference = d.generate(grid.ref_len, grid.seed);
        for &qlen in &grid.query_lengths {
            let queries =
                extract_queries(&reference, grid.queries, qlen, grid.query_noise, grid.seed ^ 5);
            for (qi, q) in queries.iter().enumerate() {
                for &ratio in &grid.window_ratios {
                    let w = window_cells(qlen, ratio);
                    let mut run = |mode: ScanMode| {
                        let mut counters = Counters::new();
                        let mut matches = Vec::new();
                        let stats = bench(0, 3, || {
                            counters = Counters::new();
                            matches = search_subsequence_topk_metric_mode(
                                &reference, q, w, k, metric, suite, mode, &mut counters,
                            );
                        });
                        (stats, counters, matches)
                    };
                    let (ts, cs, ms) = run(ScanMode::Scalar);
                    let (tt, ct, mt) = run(ScanMode::Strip);
                    // exactness gate: the bench is meaningless if the
                    // modes ever diverge
                    assert_eq!(ms.len(), mt.len(), "{} q{qi} qlen={qlen}", d.name());
                    for (a, b) in ms.iter().zip(&mt) {
                        assert_eq!(a.pos, b.pos, "{} q{qi} qlen={qlen}", d.name());
                        assert_eq!(
                            a.dist.to_bits(),
                            b.dist.to_bits(),
                            "{} q{qi} qlen={qlen}",
                            d.name()
                        );
                    }
                    total_scalar_dtw += cs.dtw_calls;
                    total_strip_dtw += ct.dtw_calls;
                    total.merge(&cs);
                    total.merge(&ct);
                    let lb_total =
                        ct.lb_kim_prunes + ct.lb_keogh_eq_prunes + ct.lb_keogh_ec_prunes;
                    let batch_pct = if lb_total > 0 {
                        100.0 * ct.batch_lb_prunes as f64 / lb_total as f64
                    } else {
                        0.0
                    };
                    println!(
                        "{:<8} {:>2} {:>5} {:>4} | {:>10} {:>10} {:>7.2}x | {:>9} {:>9} {:>7} {:>6.1}%",
                        d.name(),
                        qi,
                        qlen,
                        (ratio * 100.0).round() as usize,
                        fmt_secs(ts.median),
                        fmt_secs(tt.median),
                        ts.median / tt.median,
                        cs.dtw_calls,
                        ct.dtw_calls,
                        ct.lb_order_saved_dtw_calls,
                        batch_pct,
                    );
                    for (mode, stats, c) in [("scalar", &ts, &cs), ("strip", &tt, &ct)] {
                        json.push(vec![
                            ("suite", Json::Str(suite.name().to_string())),
                            ("scan_mode", Json::Str(mode.to_string())),
                            ("dataset", Json::Str(d.name().to_string())),
                            ("query_idx", Json::Num(qi as f64)),
                            ("qlen", Json::Num(qlen as f64)),
                            ("ratio", Json::Num(ratio)),
                            ("k", Json::Num(k as f64)),
                            ("seconds", Json::Num(stats.median)),
                            ("ns_per_op", Json::Num(stats.median * 1e9)),
                            ("counters", BenchJson::counters_json(c)),
                        ]);
                    }
                }
            }
        }
    }
    let reduction = if total_scalar_dtw > 0 {
        100.0 * (total_scalar_dtw.saturating_sub(total_strip_dtw)) as f64
            / total_scalar_dtw as f64
    } else {
        0.0
    };
    println!(
        "\ntotals: scalar {} vs strip {} full-DTW calls — LB-ordering cut {reduction:.1}%",
        total_scalar_dtw, total_strip_dtw
    );
    if total_strip_dtw > total_scalar_dtw {
        eprintln!(
            "WARNING: strip mode reached DTW more often than scalar — LB-ordering \
             lost to threshold staleness on this grid"
        );
    }
    // embed the whole-run counter totals as a pinned-schema snapshot so
    // tools/bench_diff.py can audit the conservation identities offline
    json.set_stats(&MetricsSnapshot::from_counters(&total));
    json.write_and_announce();
}
