//! Cohort-batched serving throughput: `Engine::search_batch` in
//! sequential (query-major) vs cohort (strip-major) mode as the batch
//! grows — batch ∈ {1, 4, 16, 64}. Verifies on every run that the two
//! modes return bitwise-identical results, reports queries/sec and the
//! **reference bytes streamed per query** (the stat-lane traffic the
//! cohort scan exists to amortise: 16 bytes of `(mean, std)` per
//! candidate position, computed exactly from the counters as
//! `candidates − strip_stat_loads_saved`), asserts that bytes/query
//! strictly decreases as the batch grows, and emits
//! `BENCH_cohort_throughput.json` for cross-PR tracking.
//!
//! Scaling knobs (env): `REPRO_REF_LEN` (default 20000), `REPRO_DATASETS`
//! (default ECG,PPG), `REPRO_QLENS` (first entry; default 128).

use repro::bench_support::grid_from_env;
use repro::bench_support::harness::{bench, fmt_secs};
use repro::bench_support::report::BenchJson;
use repro::data::extract_queries;
use repro::index::{Engine, EngineConfig, Query, TopKResult};
use repro::metrics::Counters;
use repro::obs::MetricsSnapshot;
use repro::util::json::Json;

/// Bytes of stat-lane traffic per candidate position: mean + std, f64.
const STAT_LANE_BYTES: f64 = 16.0;

fn merged(results: &[TopKResult]) -> Counters {
    let mut c = Counters::new();
    for r in results {
        c.merge(&r.counters);
    }
    c
}

fn main() {
    let (grid, mut datasets) = grid_from_env(20_000);
    if std::env::var("REPRO_DATASETS").is_err() {
        datasets.truncate(2); // default: a quick two-dataset A/B
    }
    let qlen = *grid.query_lengths.first().unwrap_or(&128);
    let (ratio, k) = (0.1, 5usize);
    let batches = [1usize, 4, 16, 64];
    println!(
        "cohort throughput (qlen {qlen}, ratio {ratio}, k={k}, ref_len {}): sequential vs cohort batch serving",
        grid.ref_len
    );
    println!(
        "{:<8} {:>5} | {:>10} {:>10} {:>8} | {:>10} {:>10} | {:>9} {:>9} {:>8}",
        "dataset", "batch", "seq", "cohort", "speedup", "seq q/s", "coh q/s", "B/q seq", "B/q coh", "retired"
    );
    let mut json = BenchJson::new("cohort_throughput");
    let mut total = Counters::new();
    for &d in &datasets {
        let reference = d.generate(grid.ref_len, grid.seed);
        let queries: Vec<Query> = extract_queries(
            &reference,
            *batches.last().unwrap(),
            qlen,
            grid.query_noise,
            grid.seed ^ 7,
        )
        .into_iter()
        .map(|q| Query::new(q, ratio))
        .collect();
        let engine =
            Engine::new(reference, &EngineConfig { shards: 2, ..Default::default() }).unwrap();
        let mut last_cohort_bytes_per_query = f64::INFINITY;
        for &b in &batches {
            let batch = &queries[..b];
            let mut run = |cohort: bool| {
                let mut results = Vec::new();
                let stats = bench(0, 3, || {
                    results = if cohort {
                        engine.search_batch(batch, k).unwrap()
                    } else {
                        engine.search_batch_sequential(batch, k).unwrap()
                    };
                });
                (stats, results)
            };
            let (ts, rs) = run(false);
            let (tc, rc) = run(true);
            // exactness gate: the bench is meaningless if the modes diverge
            for (i, (a, c)) in rs.iter().zip(&rc).enumerate() {
                assert_eq!(a.matches.len(), c.matches.len(), "{} b={b} q{i}", d.name());
                for (x, y) in a.matches.iter().zip(&c.matches) {
                    assert_eq!(x.pos, y.pos, "{} b={b} q{i}", d.name());
                    assert_eq!(x.dist.to_bits(), y.dist.to_bits(), "{} b={b} q{i}", d.name());
                }
            }
            let (cs, cc) = (merged(&rs), merged(&rc));
            total.merge(&cs);
            total.merge(&cc);
            // stat-lane traffic: sequential loads every candidate's
            // (mean, std) per query; the cohort loads each strip once
            let seq_bytes_per_query = cs.candidates as f64 * STAT_LANE_BYTES / b as f64;
            let cohort_loads = cc.candidates - cc.strip_stat_loads_saved;
            let cohort_bytes_per_query = cohort_loads as f64 * STAT_LANE_BYTES / b as f64;
            assert!(
                cohort_bytes_per_query < last_cohort_bytes_per_query,
                "{} b={b}: reference bytes/query must strictly decrease as the batch grows \
                 ({cohort_bytes_per_query} vs {last_cohort_bytes_per_query})",
                d.name()
            );
            last_cohort_bytes_per_query = cohort_bytes_per_query;
            let (seq_qps, coh_qps) = (b as f64 / ts.median, b as f64 / tc.median);
            println!(
                "{:<8} {:>5} | {:>10} {:>10} {:>7.2}x | {:>10.1} {:>10.1} | {:>9.0} {:>9.0} {:>8}",
                d.name(),
                b,
                fmt_secs(ts.median),
                fmt_secs(tc.median),
                ts.median / tc.median,
                seq_qps,
                coh_qps,
                seq_bytes_per_query,
                cohort_bytes_per_query,
                cc.cohort_retired_queries,
            );
            for (mode, stats, c, bytes, qps) in [
                ("sequential", &ts, &cs, seq_bytes_per_query, seq_qps),
                ("cohort", &tc, &cc, cohort_bytes_per_query, coh_qps),
            ] {
                json.push(vec![
                    ("dataset", Json::Str(d.name().to_string())),
                    ("batch_mode", Json::Str(mode.to_string())),
                    ("batch", Json::Num(b as f64)),
                    ("qlen", Json::Num(qlen as f64)),
                    ("ratio", Json::Num(ratio)),
                    ("k", Json::Num(k as f64)),
                    ("seconds", Json::Num(stats.median)),
                    ("queries_per_sec", Json::Num(qps)),
                    ("ref_bytes_per_query", Json::Num(bytes)),
                    ("counters", BenchJson::counters_json(c)),
                ]);
            }
        }
        println!("  {}", merged(&engine.search_batch(&queries, k).unwrap()).cohort_report());
    }
    // embed the whole-run counter totals as a pinned-schema snapshot so
    // tools/bench_diff.py can audit the conservation identities offline
    json.set_stats(&MetricsSnapshot::from_counters(&total));
    json.write_and_announce();
}
