//! K2 — reference-side amortization: what the shared `RefIndex` buys as
//! the query batch grows. The unindexed path re-does all reference-side
//! work per query (streamed window stats, per-query data envelopes — the
//! seed behaviour); the indexed path pays one build on the batch's first
//! query and serves every later one from cache. Amortized per-query cost
//! must *fall* with batch size on the indexed path and stay flat on the
//! unindexed one.
//!
//! Scaling knobs (env): `REPRO_REF_LEN` (default 20000), `REPRO_DATASETS`.

use repro::bench_support::grid_from_env;
use repro::bench_support::harness::{bench, fmt_secs};
use repro::bench_support::report::BenchJson;
use repro::data::extract_queries;
use repro::index::{Engine, EngineConfig, Query};
use repro::metrics::Counters;
use repro::search::subsequence::{search_subsequence, window_cells};
use repro::search::suite::Suite;
use repro::util::json::Json;

const QLEN: usize = 128;
const RATIO: f64 = 0.1;
const BATCHES: [usize; 3] = [1, 8, 64];

fn main() {
    let (grid, datasets) = grid_from_env(20_000);
    let suite = Suite::UcrMon;
    let mut json = BenchJson::new("index_amortization");
    println!(
        "index amortization (qlen {QLEN}, ratio {RATIO}, suite {}, ref_len {}):",
        suite.name(),
        grid.ref_len
    );
    println!(
        "{:<8} {:>6} | {:>14} {:>14} | {:>9}",
        "dataset", "batch", "unindexed /q", "indexed /q", "speedup"
    );
    for &d in &datasets {
        let reference = d.generate(grid.ref_len, grid.seed);
        let all_queries = extract_queries(&reference, *BATCHES.iter().max().unwrap(), QLEN, 0.1, grid.seed ^ 3);
        let mut indexed_per_q = Vec::new();
        for &batch in &BATCHES {
            let queries = &all_queries[..batch];
            let w = window_cells(QLEN, RATIO);

            // seed path: every query rebuilds envelopes + streams stats
            let un = bench(1, 3, || {
                let mut c = Counters::new();
                for q in queries {
                    std::hint::black_box(search_subsequence(&reference, q, w, suite, &mut c));
                }
                c.candidates
            });

            // indexed path: a fresh engine per rep, so the index build is
            // *inside* the measurement and amortizes across the batch
            let engine_queries: Vec<Query> =
                queries.iter().map(|q| Query::new(q.clone(), RATIO)).collect();
            let ix = bench(1, 3, || {
                let engine = Engine::new(
                    reference.clone(),
                    &EngineConfig { shards: 1, suite, ..Default::default() },
                )
                .expect("engine");
                engine.search_batch(&engine_queries, 1).expect("batch")
            });

            let un_q = un.median / batch as f64;
            let ix_q = ix.median / batch as f64;
            indexed_per_q.push(ix_q);
            println!(
                "{:<8} {:>6} | {:>14} {:>14} | {:>8.2}x",
                d.name(),
                batch,
                fmt_secs(un_q),
                fmt_secs(ix_q),
                un_q / ix_q
            );
            for (path, per_q) in [("unindexed", un_q), ("indexed", ix_q)] {
                json.push(vec![
                    ("suite", Json::Str(path.to_string())),
                    ("dataset", Json::Str(d.name().to_string())),
                    ("qlen", Json::Num(QLEN as f64)),
                    ("ratio", Json::Num(RATIO)),
                    ("batch", Json::Num(batch as f64)),
                    ("ns_per_op", Json::Num(per_q * 1e9)),
                ]);
            }
        }
        let falling = indexed_per_q.windows(2).all(|p| p[1] <= p[0] * 1.10);
        println!(
            "  -> indexed per-query cost {} with batch size",
            if falling { "falls (amortized)" } else { "did NOT fall — investigate" }
        );
    }
    json.write_and_announce();
}
