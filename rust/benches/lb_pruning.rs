//! Figure 5's inset rows: the proportion of candidates pruned by each
//! lower bound and the proportion reaching the DTW core, per dataset —
//! the paper's point that our algorithm only sees cascade *survivors*
//! (and that MON-nolb is "100% DTW").

use repro::bench_support::grid::{experiments, run_experiment, Workload};
use repro::bench_support::grid_from_env;
use repro::bench_support::report::{pruning_table, BenchJson};
use repro::search::suite::Suite;

fn main() {
    let (mut grid, datasets) = grid_from_env(20_000);
    if std::env::var("REPRO_QLENS").is_err() {
        grid.query_lengths = vec![256];
    }
    if std::env::var("REPRO_RATIOS").is_err() {
        grid.window_ratios = vec![0.1, 0.3, 0.5];
    }
    let mut results = Vec::new();
    for &d in &datasets {
        let w = Workload::build(d, &grid);
        for exp in experiments(&grid, &[d]) {
            for s in [Suite::UcrMon, Suite::UcrMonNoLb] {
                results.push(run_experiment(&w, &exp, s));
            }
        }
        eprintln!("  {} done", d.name());
    }
    println!("== Fig 5 inset: cascade pruning proportions ==");
    println!("{}", pruning_table(&results));
    println!("(UCR-MON-nolb rows must show dtw% = 100 — no lower bounds at all)");
    let mut json = BenchJson::new("lb_pruning");
    for r in &results {
        json.push_result(r);
    }
    json.write_and_announce();
}
