//! TCP front-end load: open-loop arrivals over a real loopback socket.
//! A sender thread paces queries by wall clock (it never waits for a
//! reply — open loop, so server-side queueing shows up as latency
//! instead of silently throttling the offered load) while the main
//! thread collects responses and measures end-to-end latency through
//! the full stack: framing → quota check → dispatcher coalescing →
//! `Service::submit_batch_timed` → writer queue → socket.
//!
//! Reports offered vs achieved q/s and p50/p95/max latency per offered
//! rate, verifies every query is answered in order, pulls the closing
//! metrics snapshot **over the wire** (a `{"cmd":"stats"}` frame, like
//! any client) and emits `BENCH_net_load.json` for cross-PR tracking
//! via `tools/bench_diff.py`.
//!
//! Scaling knobs (env): `REPRO_REF_LEN` (default 20000), `REPRO_DATASETS`
//! (first entry; default ECG), `REPRO_QLENS` (first entry; default 128).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use repro::bench_support::grid_from_env;
use repro::bench_support::report::BenchJson;
use repro::coordinator::protocol::{QueryRequest, QueryResponse};
use repro::coordinator::{Service, ServiceConfig};
use repro::data::extract_queries;
use repro::distances::metric::Metric;
use repro::net::{NetConfig, NetServer};
use repro::obs::MetricsSnapshot;
use repro::search::suite::Suite;
use repro::util::json::Json;

fn main() {
    let (grid, datasets) = grid_from_env(20_000);
    let d = datasets[0];
    let qlen = *grid.query_lengths.first().unwrap_or(&128);
    let reference = d.generate(grid.ref_len, grid.seed);
    let queries = extract_queries(&reference, 16, qlen, grid.query_noise, grid.seed ^ 11);
    let svc = Arc::new(
        Service::new(
            reference,
            &ServiceConfig {
                shards: 2,
                batch_window: 4,
                batch_deadline_ms: 2,
                ..Default::default()
            },
        )
        .unwrap(),
    );
    let server =
        NetServer::start(Arc::clone(&svc), "127.0.0.1:0", NetConfig::default()).unwrap();
    let addr = server.local_addr();
    println!(
        "net load (dataset {}, qlen {qlen}, ref_len {}, batch window 4/2ms): \
         open-loop arrivals over loopback",
        d.name(),
        grid.ref_len
    );
    println!(
        "{:>11} {:>8} {:>12} | {:>9} {:>9} {:>9}",
        "offered q/s", "queries", "achieved q/s", "p50 ms", "p95 ms", "max ms"
    );
    let mut json = BenchJson::new("net_load");
    for &rate in &[50.0f64, 200.0, 800.0] {
        let n: usize = 120;
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let lines: Vec<String> = (0..n)
            .map(|i| {
                QueryRequest {
                    id: i as u64,
                    query: queries[i % queries.len()].clone(),
                    window_ratio: 0.1,
                    suite: Suite::UcrMon,
                    k: 1,
                    metric: Metric::Cdtw,
                    deadline_ms: None,
                    tenant: Some("bench".into()),
                }
                .to_json()
            })
            .collect();
        let t0 = Instant::now();
        let sender = std::thread::spawn({
            let mut stream = stream.try_clone().unwrap();
            move || {
                let mut sent = Vec::with_capacity(lines.len());
                for (i, l) in lines.iter().enumerate() {
                    // open-loop pacing: send at the scheduled instant no
                    // matter how far behind the responses are
                    let due = t0 + Duration::from_secs_f64(i as f64 / rate);
                    if let Some(wait) = due.checked_duration_since(Instant::now()) {
                        std::thread::sleep(wait);
                    }
                    sent.push(Instant::now());
                    stream.write_all(l.as_bytes()).unwrap();
                    stream.write_all(b"\n").unwrap();
                }
                sent
            }
        });
        let mut recv = Vec::with_capacity(n);
        for i in 0..n {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let resp = QueryResponse::from_json(line.trim_end()).expect("query response");
            // one connection: responses come back in frame order
            assert_eq!(resp.id, i as u64, "response order broke");
            recv.push(Instant::now());
        }
        let wall = t0.elapsed().as_secs_f64();
        let sent = sender.join().expect("sender");
        let mut lats: Vec<f64> = recv
            .iter()
            .zip(&sent)
            .map(|(r, s)| r.duration_since(*s).as_secs_f64() * 1e3)
            .collect();
        lats.sort_by(|a, b| a.partial_cmp(b).expect("no NaN"));
        let pct = |p: f64| lats[((lats.len() - 1) as f64 * p) as usize];
        let achieved = n as f64 / wall;
        println!(
            "{:>11.0} {:>8} {:>12.1} | {:>9.2} {:>9.2} {:>9.2}",
            rate,
            n,
            achieved,
            pct(0.5),
            pct(0.95),
            pct(1.0)
        );
        json.push(vec![
            ("dataset", Json::Str(d.name().to_string())),
            ("qlen", Json::Num(qlen as f64)),
            ("offered_qps", Json::Num(rate)),
            ("queries", Json::Num(n as f64)),
            ("achieved_qps", Json::Num(achieved)),
            ("p50_ms", Json::Num(pct(0.5))),
            ("p95_ms", Json::Num(pct(0.95))),
            ("max_ms", Json::Num(pct(1.0))),
        ]);
    }
    // the closing snapshot travels the wire like any other frame, so the
    // bench JSON carries the same counters a live operator would see
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut s = &stream;
    s.write_all(b"{\"cmd\":\"stats\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let snap = MetricsSnapshot::from_json(&Json::parse(line.trim_end()).expect("stats json"))
        .expect("pinned stats schema");
    assert!(snap.counters.conns_accepted >= 4, "every bench connection was counted");
    json.set_stats(&snap);
    drop((reader, stream));
    server.drain();
    json.write_and_announce();
}
