//! Ablation A3 — the cascade itself: UCR-MON with every subset of the
//! lower-bound cascade (none / kim / +keoghEQ / +keoghEC / +improved =
//! full) and with upper-bound tightening on/off. Quantifies the paper's
//! headline §5 finding: with EAPrunedDTW, lower bounds still help but
//! are *dispensable*.

use repro::bench_support::harness::{bench, fmt_secs};
use repro::bench_support::report::BenchJson;
use repro::bounds::cascade::CascadePolicy;
use repro::data::{extract_queries, Dataset};
use repro::metrics::Counters;
use repro::search::subsequence::{scan_policy, window_cells, DataEnvelopes, QueryContext};
use repro::search::suite::Suite;
use repro::util::json::Json;

fn main() {
    let ref_len = std::env::var("REPRO_REF_LEN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20_000usize);
    let qlen = 256;
    let ratio = 0.2;
    let w = window_cells(qlen, ratio);
    let policies: [(&str, CascadePolicy); 6] = [
        ("none (nolb)", CascadePolicy::none()),
        ("kim only", CascadePolicy { kim: true, ..CascadePolicy::none() }),
        (
            "kim+EQ",
            CascadePolicy { kim: true, keogh_eq: true, tighten: true, ..CascadePolicy::none() },
        ),
        ("full", CascadePolicy::full()),
        ("full, no improved", CascadePolicy { improved: false, ..CascadePolicy::full() }),
        ("full, no tighten", CascadePolicy { tighten: false, ..CascadePolicy::full() }),
    ];
    let mut json = BenchJson::new("ablation_cascade");
    println!("ablation A3: cascade subsets with the EAPrunedDTW core (ref_len={ref_len}, qlen={qlen}, w={w})");
    println!(
        "{:<8} {:<17} {:>10} {:>8} {:>9}",
        "dataset", "cascade", "time", "dtw%", "abandon%"
    );
    for d in Dataset::ALL {
        let r = d.generate(ref_len, 3);
        let q = extract_queries(&r, 1, qlen, 0.1, 5).remove(0);
        let denv = DataEnvelopes::new(&r, w);
        let total = r.len() - qlen + 1;
        let mut baseline_pos = None;
        for (name, pol) in policies {
            let mut counters = Counters::new();
            let mut pos = 0usize;
            let stats = bench(0, 3, || {
                let mut ctx = QueryContext::new(&q, w);
                counters = Counters::new();
                let m = scan_policy(
                    &r,
                    0,
                    total,
                    &mut ctx,
                    Some(&denv),
                    Suite::UcrMon,
                    pol,
                    f64::INFINITY,
                    &mut counters,
                )
                .expect("match");
                pos = m.pos;
                m.dist
            });
            match baseline_pos {
                None => baseline_pos = Some(pos),
                Some(p) => assert_eq!(p, pos, "{name} changed the result"),
            }
            println!(
                "{:<8} {:<17} {:>10} {:>7.1}% {:>8.1}%",
                d.name(),
                name,
                fmt_secs(stats.median),
                100.0 * counters.dtw_calls as f64 / counters.candidates.max(1) as f64,
                100.0 * counters.dtw_abandons as f64 / counters.dtw_calls.max(1) as f64,
            );
            json.push(vec![
                ("suite", Json::Str(name.to_string())),
                ("dataset", Json::Str(d.name().to_string())),
                ("qlen", Json::Num(qlen as f64)),
                ("ratio", Json::Num(ratio)),
                ("ns_per_op", Json::Num(stats.median * 1e9)),
                ("counters", BenchJson::counters_json(&counters)),
            ]);
        }
    }
    println!("\n(paper §5: 'none' stays within ~1.5x of 'full' — bounds help, but are dispensable)");
    json.write_and_announce();
}
