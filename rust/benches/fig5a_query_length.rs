//! Figure 5a: average runtime per dataset by **query length** (averaged
//! over window ratios), four suites. The paper's shape to reproduce:
//! UCR-MON fastest at every length, the gap growing with length (3.7–9.7×
//! over UCR at length 1024); UCR-MON-nolb beating UCR-USP overall.
//!
//! Scale with REPRO_REF_LEN / REPRO_QUERIES / REPRO_DATASETS (see
//! bench_support::grid_from_env).

use repro::bench_support::grid::{experiments, run_experiment, Workload};
use repro::bench_support::grid_from_env;
use repro::bench_support::report::{fig5_table, BenchJson};
use repro::search::suite::Suite;

fn main() {
    let (mut grid, datasets) = grid_from_env(20_000);
    // Fig 5a averages over ratios; trim the ratio axis if unset to keep
    // default runs minutes-scale
    if std::env::var("REPRO_RATIOS").is_err() {
        grid.window_ratios = vec![0.1, 0.3, 0.5];
    }
    eprintln!(
        "fig5a: ref_len={} queries={} lengths={:?} ratios={:?}",
        grid.ref_len, grid.queries, grid.query_lengths, grid.window_ratios
    );
    let mut results = Vec::new();
    for &d in &datasets {
        let w = Workload::build(d, &grid);
        for exp in experiments(&grid, &[d]) {
            for s in Suite::ALL {
                results.push(run_experiment(&w, &exp, s));
            }
        }
        eprintln!("  {} done", d.name());
    }
    println!(
        "{}",
        fig5_table(&results, &Suite::ALL, &grid.query_lengths, "query length", |r| r.exp.qlen)
    );
    // the paper's headline shape, asserted loosely: MON total <= UCR total
    let total = |s: Suite| -> f64 {
        results.iter().filter(|r| r.suite == s).map(|r| r.seconds).sum()
    };
    let (ucr, mon) = (total(Suite::Ucr), total(Suite::UcrMon));
    println!("totals: UCR {ucr:.2}s vs UCR-MON {mon:.2}s — speedup {:.2}x", ucr / mon);
    let mut json = BenchJson::new("fig5a_query_length");
    for r in &results {
        json.push_result(r);
    }
    json.write_and_announce();
}
