//! Distance-core micro-benchmarks: ns/call for every DTW variant across
//! query lengths, window ratios and upper-bound tightness — the paper's
//! §2.4 "overheads" discussion in numbers, and the perf pass's primary
//! probe (EXPERIMENTS.md §Perf).

use repro::bench_support::harness::{bench, fmt_secs};
use repro::bench_support::report::BenchJson;
use repro::data::{extract_queries, Dataset};
use repro::distances::dtw::{cdtw_ws, cdtw};
use repro::distances::dtw_ea::dtw_ea;
use repro::distances::eap_dtw::eap_cdtw;
use repro::distances::pruned_dtw::pruned_cdtw;
use repro::distances::DtwWorkspace;
use repro::norm::znorm::znorm;
use repro::util::json::Json;

fn main() {
    let mut json = BenchJson::new("distance_micro");
    println!("distance micro (median of reps, per call):");
    println!(
        "{:>5} {:>5} {:>5} | {:>10} {:>10} {:>10} {:>10}",
        "n", "w", "ub", "dtw", "dtw_ea", "pruned", "eap"
    );
    for n in [128usize, 512, 1024] {
        let r = Dataset::Pamap2.generate(4 * n + 2000, 9);
        let q = znorm(&extract_queries(&r, 1, n, 0.1, 3).remove(0));
        let c = znorm(&r[2 * n..3 * n]);
        for ratio in [0.1, 0.5] {
            let w = (ratio * n as f64) as usize;
            let exact = cdtw(&q, &c, w);
            for (label, ub) in [("inf", f64::INFINITY), ("1.2d", exact * 1.2), ("0.5d", exact * 0.5)]
            {
                let mut ws = DtwWorkspace::with_capacity(n);
                let reps = if n >= 1024 { 20 } else { 50 };
                let t_dtw = bench(2, reps, || cdtw_ws(&q, &c, w, &mut ws));
                let t_ea = bench(2, reps, || dtw_ea(&q, &c, w, ub, None, &mut ws));
                let t_pr = bench(2, reps, || pruned_cdtw(&q, &c, w, ub, None, &mut ws));
                let t_eap = bench(2, reps, || eap_cdtw(&q, &c, w, ub, None, &mut ws));
                println!(
                    "{:>5} {:>5} {:>5} | {:>10} {:>10} {:>10} {:>10}",
                    n,
                    w,
                    label,
                    fmt_secs(t_dtw.median),
                    fmt_secs(t_ea.median),
                    fmt_secs(t_pr.median),
                    fmt_secs(t_eap.median),
                );
                for (core, stats) in
                    [("dtw", &t_dtw), ("dtw_ea", &t_ea), ("pruned", &t_pr), ("eap", &t_eap)]
                {
                    json.push(vec![
                        ("suite", Json::Str(core.to_string())),
                        ("dataset", Json::Str("PAMAP2".to_string())),
                        ("qlen", Json::Num(n as f64)),
                        ("w", Json::Num(w as f64)),
                        ("ub", Json::Str(label.to_string())),
                        ("ns_per_op", Json::Num(stats.median * 1e9)),
                    ]);
                }
            }
        }
    }
    println!("\n(ub=inf rows expose pure overhead vs plain dtw; 0.5d rows expose abandon speed)");
    json.write_and_announce();
}
