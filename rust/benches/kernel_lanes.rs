//! Multi-candidate wavefront kernel throughput: cohort-batched
//! `Engine::search_batch` with the scalar kernel (lanes = 1) vs lane
//! widths {2, 4, 8}, in f64 and f32 DP precision. Gates on every run:
//!
//! * **f64, any lane width** — matches are bitwise-identical to the
//!   scalar kernel's (positions and distance bits);
//! * **f32** — distances track the f64 oracle within a relative epsilon
//!   and the best match's position is preserved (f32 thresholds only
//!   ever widen, so the f32 scan can over-admit but never over-prune);
//! * **occupancy** — every multi-lane engine actually packed groups:
//!   `kernel_multi_calls > 0` and
//!   `kernel_lanes_filled >= 2 * kernel_multi_calls`.
//!
//! Emits `BENCH_kernel_lanes.json` with the whole-run counter totals as
//! a pinned-schema snapshot, so `tools/bench_diff.py` audits the lane
//! occupancy and conservation identities offline.
//!
//! Scaling knobs (env): `REPRO_REF_LEN` (default 12000), `REPRO_DATASETS`
//! (default ECG,PPG), `REPRO_QLENS` (first entry; default 128).

use repro::bench_support::grid_from_env;
use repro::bench_support::harness::{bench, fmt_secs};
use repro::bench_support::report::BenchJson;
use repro::data::extract_queries;
use repro::distances::kernel::Precision;
use repro::index::{Engine, EngineConfig, Query, TopKResult};
use repro::metrics::Counters;
use repro::obs::MetricsSnapshot;
use repro::search::subsequence::ScanTuning;
use repro::util::json::Json;

/// Relative tolerance for f32 DP lines against the f64 oracle. The
/// conformance suite pins ~1e-4 on single kernel calls; the bench allows
/// a little slack for the worst window over a whole scan.
const F32_REL_TOL: f64 = 1e-3;

fn merged(results: &[TopKResult]) -> Counters {
    let mut c = Counters::new();
    for r in results {
        c.merge(&r.counters);
    }
    c
}

fn assert_bitwise(oracle: &[TopKResult], got: &[TopKResult], what: &str) {
    for (i, (a, b)) in oracle.iter().zip(got).enumerate() {
        assert_eq!(a.matches.len(), b.matches.len(), "{what} q{i}");
        for (x, y) in a.matches.iter().zip(&b.matches) {
            assert_eq!(x.pos, y.pos, "{what} q{i}");
            assert_eq!(x.dist.to_bits(), y.dist.to_bits(), "{what} q{i}");
        }
    }
}

fn assert_epsilon(oracle: &[TopKResult], got: &[TopKResult], what: &str) {
    for (i, (a, b)) in oracle.iter().zip(got).enumerate() {
        assert_eq!(a.matches.len(), b.matches.len(), "{what} q{i}");
        // the best match is unambiguous on noisy synthetic data; deeper
        // ranks may legally swap when f32 rounding reorders near-ties,
        // so the tail is gated on distances only (sorted on both sides)
        assert_eq!(a.best().pos, b.best().pos, "{what} q{i}");
        for (x, y) in a.matches.iter().zip(&b.matches) {
            let scale = x.dist.abs().max(1.0);
            assert!(
                (x.dist - y.dist).abs() <= F32_REL_TOL * scale,
                "{what} q{i}: f32 dist {} drifted from f64 {}",
                y.dist,
                x.dist
            );
        }
    }
}

fn main() {
    let (grid, mut datasets) = grid_from_env(12_000);
    if std::env::var("REPRO_DATASETS").is_err() {
        datasets.truncate(2); // default: a quick two-dataset sweep
    }
    let qlen = *grid.query_lengths.first().unwrap_or(&128);
    let (ratio, k, batch) = (0.1, 5usize, 8usize);
    let lane_widths = [1usize, 2, 4, 8];
    println!(
        "kernel lanes (qlen {qlen}, ratio {ratio}, k={k}, batch {batch}, ref_len {}): \
         scalar vs wavefront lane widths, f64 + f32",
        grid.ref_len
    );
    println!(
        "{:<8} {:>5} {:>4} | {:>10} {:>8} | {:>11} {:>11} {:>9}",
        "dataset", "lanes", "prec", "time", "speedup", "multi_calls", "lanes_fill", "occupancy"
    );
    let mut json = BenchJson::new("kernel_lanes");
    let mut total = Counters::new();
    for &d in &datasets {
        let reference = d.generate(grid.ref_len, grid.seed);
        let queries: Vec<Query> =
            extract_queries(&reference, batch, qlen, grid.query_noise, grid.seed ^ 11)
                .into_iter()
                .map(|q| Query::new(q, ratio))
                .collect();
        let mut oracle: Option<Vec<TopKResult>> = None;
        let mut scalar_median = 0.0f64;
        for precision in [Precision::F64, Precision::F32] {
            for &lanes in &lane_widths {
                let engine = Engine::new(
                    reference.clone(),
                    &EngineConfig {
                        shards: 2,
                        tuning: ScanTuning::default()
                            .with_lanes(lanes)
                            .with_precision(precision),
                        ..Default::default()
                    },
                )
                .unwrap();
                let mut results = Vec::new();
                let stats = bench(0, 3, || {
                    results = engine.search_batch(&queries, k).unwrap();
                });
                let c = merged(&results);
                total.merge(&c);
                match (precision, &oracle) {
                    // the very first run (f64, lanes = 1) IS the oracle
                    (Precision::F64, None) => {
                        scalar_median = stats.median;
                        oracle = Some(results.clone());
                    }
                    (Precision::F64, Some(o)) => {
                        assert_bitwise(o, &results, &format!("{} lanes={lanes}", d.name()));
                    }
                    (Precision::F32, Some(o)) => {
                        assert_epsilon(
                            o,
                            &results,
                            &format!("{} lanes={lanes} f32", d.name()),
                        );
                    }
                    (Precision::F32, None) => unreachable!("f64 sweep runs first"),
                }
                if lanes >= 2 {
                    assert!(
                        c.kernel_multi_calls > 0,
                        "{} lanes={lanes} {}: no lane group ever packed",
                        d.name(),
                        precision.name()
                    );
                    assert!(
                        c.kernel_lanes_filled >= 2 * c.kernel_multi_calls,
                        "{} lanes={lanes} {}: occupancy below 2",
                        d.name(),
                        precision.name()
                    );
                } else {
                    assert_eq!(c.kernel_multi_calls, 0, "scalar engine packed lanes");
                }
                let occupancy = if c.kernel_multi_calls > 0 {
                    c.kernel_lanes_filled as f64 / c.kernel_multi_calls as f64
                } else {
                    0.0
                };
                println!(
                    "{:<8} {:>5} {:>4} | {:>10} {:>7.2}x | {:>11} {:>11} {:>9.2}",
                    d.name(),
                    lanes,
                    precision.name(),
                    fmt_secs(stats.median),
                    scalar_median / stats.median,
                    c.kernel_multi_calls,
                    c.kernel_lanes_filled,
                    occupancy,
                );
                json.push(vec![
                    ("dataset", Json::Str(d.name().to_string())),
                    ("lanes", Json::Num(lanes as f64)),
                    ("precision", Json::Str(precision.name().to_string())),
                    ("batch", Json::Num(batch as f64)),
                    ("qlen", Json::Num(qlen as f64)),
                    ("ratio", Json::Num(ratio)),
                    ("k", Json::Num(k as f64)),
                    ("seconds", Json::Num(stats.median)),
                    ("lane_occupancy", Json::Num(occupancy)),
                    ("counters", BenchJson::counters_json(&c)),
                ]);
            }
        }
    }
    // embed the whole-run counter totals as a pinned-schema snapshot so
    // tools/bench_diff.py can audit occupancy + conservation offline
    json.set_stats(&MetricsSnapshot::from_counters(&total));
    json.write_and_announce();
}
