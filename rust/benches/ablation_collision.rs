//! Ablation A1 — what the **border-collision early abandon** buys over the
//! row-minimum abandon (the paper's §4 argument for why EAPrunedDTW
//! abandons earlier than PrunedDTW).
//!
//! Protocol: DTW calls as they occur inside a real search — candidate
//! windows from each dataset, the upper bound set at quantiles of the true
//! distance distribution (tight ub = late in a search; loose = early).
//! Reports wall time and DP cells for PrunedDTW (row-min EA, 3-way min)
//! vs EAPrunedDTW (collision EA, staged updates).

use repro::bench_support::harness::{bench, fmt_secs};
use repro::bench_support::report::BenchJson;
use repro::data::{extract_queries, Dataset};
use repro::distances::dtw::cdtw;
use repro::distances::eap_dtw::eap_cdtw_counted;
use repro::distances::pruned_dtw::pruned_cdtw_counted;
use repro::distances::DtwWorkspace;
use repro::norm::znorm::znorm;
use repro::util::json::Json;

fn main() {
    let n = 512;
    let w = n / 5;
    let per_dataset = 40;
    let mut json = BenchJson::new("ablation_collision");
    println!("ablation A1: PrunedDTW (row-min EA) vs EAPrunedDTW (collision EA), n={n} w={w}");
    println!(
        "{:<8} {:>6} | {:>10} {:>12} | {:>10} {:>12} | {:>7} {:>7}",
        "dataset", "ub@q", "usp time", "usp cells", "eap time", "eap cells", "t-ratio", "c-ratio"
    );
    for d in Dataset::ALL {
        let r = d.generate(per_dataset * n * 2 + 2000, 7);
        let q = znorm(&extract_queries(&r, 1, n, 0.1, 3).remove(0));
        let cands: Vec<Vec<f64>> =
            (0..per_dataset).map(|i| znorm(&r[i * n * 2..i * n * 2 + n])).collect();
        let mut dists: Vec<f64> = cands.iter().map(|c| cdtw(&q, c, w)).collect();
        dists.sort_by(|a, b| a.partial_cmp(b).expect("no NaN"));
        for (label, qt) in [("p05", 0.05), ("p50", 0.50)] {
            let ub = dists[((dists.len() - 1) as f64 * qt) as usize];
            let mut ws = DtwWorkspace::default();
            let mut usp_cells = 0u64;
            let t_usp = bench(1, 5, || {
                usp_cells = 0;
                for c in &cands {
                    let (_, cc) = pruned_cdtw_counted(&q, c, w, ub, None, &mut ws);
                    usp_cells += cc;
                }
            });
            let mut eap_cells = 0u64;
            let t_eap = bench(1, 5, || {
                eap_cells = 0;
                for c in &cands {
                    let (_, cc) = eap_cdtw_counted(&q, c, w, ub, None, &mut ws);
                    eap_cells += cc;
                }
            });
            println!(
                "{:<8} {:>6} | {:>10} {:>12} | {:>10} {:>12} | {:>6.2}x {:>6.2}x",
                d.name(),
                label,
                fmt_secs(t_usp.median),
                usp_cells,
                fmt_secs(t_eap.median),
                eap_cells,
                t_usp.median / t_eap.median,
                usp_cells as f64 / eap_cells.max(1) as f64,
            );
            for (core, stats, cells) in
                [("pruned", &t_usp, usp_cells), ("eap", &t_eap, eap_cells)]
            {
                json.push(vec![
                    ("suite", Json::Str(core.to_string())),
                    ("dataset", Json::Str(d.name().to_string())),
                    ("qlen", Json::Num(n as f64)),
                    ("ub", Json::Str(label.to_string())),
                    ("ns_per_op", Json::Num(stats.median * 1e9)),
                    ("dp_cells", Json::Num(cells as f64)),
                ]);
            }
        }
    }
    println!("\n(expect c-ratio > 1: the collision abandon cuts rows the row-min check keeps)");
    json.write_and_announce();
}
