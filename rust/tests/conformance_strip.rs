//! Strip-scan conformance suite: the strip-mined pipeline must return
//! **bitwise-identical** top-k results (same positions, same distance
//! bits) to the legacy scalar scan — across all six synthetic datasets,
//! all six metric kinds and k ∈ {1, 5, 16} — because batching, the
//! evaluation order and the single-pass z-normalisation are throughput
//! changes, never semantic ones. Only evaluation order, and therefore
//! prune attribution in the counters, may differ.

use repro::data::Dataset;
use repro::distances::metric::Metric;
use repro::metrics::Counters;
use repro::search::subsequence::{
    search_subsequence_topk_metric_mode, window_cells, Match, ScanMode,
};
use repro::search::suite::Suite;
use repro::util::proptest::{arb_series, run_prop};

fn run(
    r: &[f64],
    q: &[f64],
    w: usize,
    k: usize,
    metric: Metric,
    suite: Suite,
    mode: ScanMode,
) -> (Vec<Match>, Counters) {
    let mut c = Counters::new();
    let m = search_subsequence_topk_metric_mode(r, q, w, k, metric, suite, mode, &mut c);
    (m, c)
}

fn assert_bitwise_equal(a: &[Match], b: &[Match], tag: &str) {
    assert_eq!(a.len(), b.len(), "result count: {tag}");
    for (rank, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.pos, y.pos, "pos at rank {rank}: {tag}");
        assert_eq!(
            x.dist.to_bits(),
            y.dist.to_bits(),
            "dist bits at rank {rank}: {x:?} vs {y:?}: {tag}"
        );
    }
}

#[test]
fn strip_topk_is_bitwise_identical_on_every_dataset_metric_and_k() {
    let qlen = 48;
    let w = window_cells(qlen, 0.1);
    for ds in Dataset::ALL {
        let r = ds.generate(700, 0xBEEF ^ ds as u64);
        let q = repro::data::extract_queries(&r, 1, qlen, 0.1, 7 + ds as u64).remove(0);
        for metric in Metric::all_default() {
            for k in [1usize, 5, 16] {
                let tag = format!("{} {} k={k}", ds.name(), metric.name());
                let (scalar, cs) = run(&r, &q, w, k, metric, Suite::UcrMon, ScanMode::Scalar);
                let (strip, ct) = run(&r, &q, w, k, metric, Suite::UcrMon, ScanMode::Strip);
                assert_eq!(scalar.len(), k.min(r.len() - qlen + 1), "{tag}");
                assert_bitwise_equal(&scalar, &strip, &tag);
                // both modes examined the whole candidate space; the strip
                // path did so strip-wise
                assert_eq!(cs.candidates, ct.candidates, "{tag}");
                assert!(ct.strip_batches > 0, "{tag}");
                // prune attribution may differ, totals must balance:
                // every candidate is pruned, abandoned, or scored
                let accounted = ct.lb_kim_prunes
                    + ct.lb_keogh_eq_prunes
                    + ct.lb_keogh_ec_prunes
                    + ct.lb_improved_prunes
                    + ct.dtw_calls;
                assert_eq!(accounted, ct.candidates, "{tag}: {ct:?}");
            }
        }
    }
}

#[test]
fn strip_scan_agrees_across_suites_too() {
    // the cascade policy differs per suite (full vs none) — the strip
    // front-end must track all of them
    let qlen = 64;
    let w = window_cells(qlen, 0.2);
    let r = Dataset::Refit.generate(900, 5);
    let q = repro::data::extract_queries(&r, 1, qlen, 0.1, 6).remove(0);
    for suite in Suite::ALL {
        for k in [1usize, 8] {
            let tag = format!("{} k={k}", suite.name());
            let (scalar, _) = run(&r, &q, w, k, Metric::Cdtw, suite, ScanMode::Scalar);
            let (strip, _) = run(&r, &q, w, k, Metric::Cdtw, suite, ScanMode::Strip);
            assert_bitwise_equal(&scalar, &strip, &tag);
        }
    }
}

#[test]
fn exact_distance_ties_resolve_identically_in_both_modes() {
    // plant an exact duplicate of one window so two candidates share the
    // same distance bits: LB-ordered evaluation visits them in a
    // different order than the scalar scan, yet the returned set (and the
    // smaller-position tie winner) must be identical. The reference is
    // integer-valued so the streaming running sums are *exact* — the two
    // copies then z-normalise to bit-identical windows even though their
    // window statistics accumulate along different prefixes.
    let qlen = 32;
    let mut x = 13u64;
    let mut r: Vec<f64> = (0..600)
        .map(|_| {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x % 17) as f64 - 8.0
        })
        .collect();
    let dup: Vec<f64> = r[100..100 + qlen].to_vec();
    r[400..400 + qlen].copy_from_slice(&dup);
    let q: Vec<f64> = r[100..100 + qlen].to_vec();
    let w = window_cells(qlen, 0.2);
    for k in [1usize, 2, 3] {
        let tag = format!("planted tie k={k}");
        let (scalar, _) = run(&r, &q, w, k, Metric::Cdtw, Suite::UcrMon, ScanMode::Scalar);
        let (strip, _) = run(&r, &q, w, k, Metric::Cdtw, Suite::UcrMon, ScanMode::Strip);
        assert_bitwise_equal(&scalar, &strip, &tag);
    }
    // sanity: the two planted copies really do tie at distance ~0
    let (top2, _) = run(&r, &q, w, 2, Metric::Cdtw, Suite::UcrMon, ScanMode::Scalar);
    assert_eq!(top2[0].pos, 100);
    assert_eq!(top2[1].pos, 400);
    assert_eq!(top2[0].dist.to_bits(), top2[1].dist.to_bits());
}

#[test]
fn prop_lb_ordered_evaluation_never_changes_the_returned_set() {
    // the satellite property: random workloads, random shapes — the
    // strip pipeline's LB-ordered evaluation returns exactly the scalar
    // scan's set, bit for bit
    #[derive(Debug)]
    struct Case {
        r: Vec<f64>,
        q: Vec<f64>,
        w: usize,
        k: usize,
        metric: Metric,
    }
    run_prop(
        "strip == scalar",
        0x51121,
        25,
        |rng| {
            let r = arb_series(rng, 300, 500);
            let qlen = 16 + rng.below(33) as usize;
            let start = rng.below((r.len() - qlen) as u64) as usize;
            let mut q: Vec<f64> = r[start..start + qlen].to_vec();
            // mild noise so the planted window is near, not exact
            for v in q.iter_mut() {
                *v += 0.05 * rng.normal();
            }
            let w = rng.below((qlen / 2) as u64) as usize;
            let k = 1 + rng.below(9) as usize;
            let metric = Metric::all_default()[rng.below(Metric::COUNT as u64) as usize];
            Case { r, q, w, k, metric }
        },
        |case| {
            let (scalar, _) = run(
                &case.r,
                &case.q,
                case.w,
                case.k,
                case.metric,
                Suite::UcrMon,
                ScanMode::Scalar,
            );
            let (strip, _) = run(
                &case.r,
                &case.q,
                case.w,
                case.k,
                case.metric,
                Suite::UcrMon,
                ScanMode::Strip,
            );
            if scalar.len() != strip.len() {
                return Err(format!("{} vs {} results", scalar.len(), strip.len()));
            }
            for (x, y) in scalar.iter().zip(&strip) {
                if x.pos != y.pos || x.dist.to_bits() != y.dist.to_bits() {
                    return Err(format!("diverged: {x:?} vs {y:?}"));
                }
            }
            Ok(())
        },
    );
}
