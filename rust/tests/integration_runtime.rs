//! Runtime integration: the AOT artifacts, loaded through PJRT, must agree
//! numerically with the Rust scalar implementations — the L1/L2 ⇄ L3
//! contract. Requires `make artifacts`; tests auto-skip (with a loud note)
//! when the artifacts directory is missing so `cargo test` works in a
//! fresh checkout.

use std::path::{Path, PathBuf};

use repro::bounds::envelope::envelopes;
use repro::bounds::lb_keogh::{lb_keogh_eq, reorder, sort_order};
use repro::coordinator::batcher::{xla_search, xla_search_full, F32_SAFETY};
use repro::data::{extract_queries, Dataset};
use repro::distances::dtw::cdtw;
use repro::metrics::Counters;
use repro::norm::znorm::{znorm, znorm_point, stats};
use repro::runtime::XlaEngine;
use repro::search::subsequence::{search_subsequence, window_cells};
use repro::search::suite::Suite;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        None
    }
}

#[test]
fn engine_loads_and_lists_expected_graphs() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = XlaEngine::open(&dir).unwrap();
    let m = engine.manifest();
    assert!(m.batch >= 8);
    for n in &m.lengths {
        for fam in ["znorm", "lb_keogh", "prefilter", "dtw", "prefilter_verify"] {
            let name = m.graph_name(fam, *n);
            assert!(m.find(&name).is_some(), "missing {name}");
        }
    }
}

#[test]
fn xla_znorm_matches_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = XlaEngine::open(&dir).unwrap();
    let b = engine.batch();
    let n = 128;
    let r = Dataset::Ecg.generate(b * n + 500, 31);
    let mut panel = vec![0f32; b * n];
    for k in 0..b {
        for j in 0..n {
            panel[k * n + j] = r[k * 7 + j] as f32;
        }
    }
    let out = engine.znorm(n, &panel).unwrap();
    for k in 0..b {
        let window: Vec<f64> = (0..n).map(|j| r[k * 7 + j]).collect();
        let want = znorm(&window);
        for j in 0..n {
            let got = out[k * n + j] as f64;
            assert!(
                (got - want[j]).abs() < 1e-3,
                "row {k} col {j}: {got} vs {}",
                want[j]
            );
        }
    }
}

#[test]
fn xla_lb_keogh_matches_rust_scalar_bound() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = XlaEngine::open(&dir).unwrap();
    let b = engine.batch();
    let n = 128;
    let w = 12;
    let r = Dataset::Ppg.generate(b + n + 10, 33);
    let q = znorm(&extract_queries(&r, 1, n, 0.1, 3).remove(0));
    let (u, l) = envelopes(&q, w);
    let u32v: Vec<f32> = u.iter().map(|&v| v as f32).collect();
    let l32v: Vec<f32> = l.iter().map(|&v| v as f32).collect();
    // raw panel of consecutive windows
    let mut panel = vec![0f32; b * n];
    for k in 0..b {
        for j in 0..n {
            panel[k * n + j] = r[k + j] as f32;
        }
    }
    let bounds = engine.prefilter(n, &u32v, &l32v, &panel).unwrap();
    // scalar path: znorm window then LB_Keogh EQ
    let order = sort_order(&q);
    let uo = reorder(&u, &order);
    let lo = reorder(&l, &order);
    for k in 0..b {
        let window = &r[k..k + n];
        let (mean, std) = stats(window);
        let mut cb = vec![0.0; n];
        let want = lb_keogh_eq(&order, &uo, &lo, window, mean, std, f64::INFINITY, &mut cb);
        let got = bounds[k] as f64;
        let tol = 1e-2 + want * 2e-3;
        assert!((got - want).abs() < tol, "row {k}: {got} vs {want}");
        // the deflated bound never exceeds the true bound by the margin
        assert!(got * (1.0 - F32_SAFETY) <= want + 1e-6, "safety margin violated");
    }
}

#[test]
fn xla_batched_dtw_matches_rust_cdtw() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = XlaEngine::open(&dir).unwrap();
    let b = engine.batch();
    let n = 128;
    let r = Dataset::Pamap2.generate(b + n + 10, 35);
    let q = znorm(&extract_queries(&r, 1, n, 0.1, 5).remove(0));
    let q32: Vec<f32> = q.iter().map(|&v| v as f32).collect();
    for w in [0usize, 12, 64] {
        let mut panel = vec![0f32; b * n];
        let mut zrows: Vec<Vec<f64>> = Vec::new();
        for k in 0..b {
            let window = &r[k..k + n];
            let (mean, std) = stats(window);
            let z: Vec<f64> = window.iter().map(|&x| znorm_point(x, mean, std)).collect();
            for j in 0..n {
                panel[k * n + j] = z[j] as f32;
            }
            zrows.push(z);
        }
        let got = engine.batched_dtw(n, &q32, w, &panel).unwrap();
        for k in 0..b {
            let want = cdtw(&q, &zrows[k], w);
            let tol = 1e-2 + want * 5e-3;
            assert!(
                (got[k] as f64 - want).abs() < tol,
                "w={w} row {k}: {} vs {want}",
                got[k]
            );
        }
    }
}

#[test]
fn xla_search_agrees_with_scalar_suites() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = XlaEngine::open(&dir).unwrap();
    let r = Dataset::Ecg.generate(12_000, 41);
    let q = extract_queries(&r, 1, 128, 0.1, 6).remove(0);
    let w = window_cells(q.len(), 0.1);
    let mut c_scalar = Counters::new();
    let want = search_subsequence(&r, &q, w, Suite::UcrMon, &mut c_scalar);
    let mut c_xla = Counters::new();
    let got = xla_search(&mut engine, &r, &q, w, &mut c_xla).unwrap();
    assert_eq!(got.pos, want.pos);
    assert!((got.dist - want.dist).abs() < 1e-6);
    assert!(c_xla.xla_prunes > 0, "prefilter should prune: {c_xla:?}");
}

#[test]
fn xla_search_full_finds_same_match_in_f32() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = XlaEngine::open(&dir).unwrap();
    let r = Dataset::Ppg.generate(4_000, 43);
    let q = extract_queries(&r, 1, 128, 0.1, 8).remove(0);
    let w = window_cells(q.len(), 0.2);
    let mut c1 = Counters::new();
    let want = search_subsequence(&r, &q, w, Suite::UcrMon, &mut c1);
    let mut c2 = Counters::new();
    let got = xla_search_full(&mut engine, &r, &q, w, &mut c2).unwrap();
    assert_eq!(got.pos, want.pos);
    assert!((got.dist - want.dist).abs() < 1e-3 + want.dist * 1e-3);
    assert_eq!(c2.dtw_calls, c2.candidates, "full path verifies everything");
}

#[test]
fn unsupported_length_is_a_clean_error() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = XlaEngine::open(&dir).unwrap();
    let r = Dataset::Ecg.generate(2000, 1);
    let q = vec![0.0; 100]; // not an AOT length
    let mut c = Counters::new();
    let err = xla_search(&mut engine, &r, &q, 10, &mut c).unwrap_err();
    assert!(err.to_string().contains("not in AOT artifact set"), "{err}");
}
