//! Failure-model conformance: drives the compiled-in fault sites
//! (`--features fault-inject`, see `src/fault.rs`) through the public
//! service API and pins the robustness contract:
//!
//! * a fault never deadlocks fan-in and never kills the process — the
//!   affected query answers with a typed error (or a `partial` top-k)
//!   and the service keeps serving;
//! * dead worker threads are respawned and the query retried once, so a
//!   single thread death is invisible to the caller;
//! * the counter conservation identities survive every fault (panicked
//!   jobs flush nothing; truncated scans flush only whole strips):
//!   `candidates == Σ prunes + dtw_calls` and
//!   `dtw_calls == dtw_abandons + dtw_completions`.
//!
//! The fault registry is process-global, so every test serialises on
//! [`FAULT_LOCK`] and resets the registry on entry and exit — cargo's
//! parallel runner must never interleave two armed tests.

use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

use repro::coordinator::protocol::{DeadlineExceeded, WorkerPanicked};
use repro::coordinator::{ErrorKind, ErrorResponse, QueryRequest, Service, ServiceConfig};
use repro::data::{extract_queries, Dataset};
use repro::distances::metric::Metric;
use repro::fault;
use repro::metrics::Counters;
use repro::search::subsequence::{search_subsequence_topk, window_cells, ScanMode};
use repro::search::suite::Suite;

static FAULT_LOCK: Mutex<()> = Mutex::new(());

/// Take the suite-wide lock (poison-tolerant: a failed test must not
/// cascade into every later one) and start from a disarmed registry.
fn armed_section() -> MutexGuard<'static, ()> {
    let guard = FAULT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    fault::reset();
    guard
}

fn service(r: &[f64], shards: usize, mode: ScanMode) -> Service {
    Service::new(r.to_vec(), &ServiceConfig { shards, scan_mode: mode, ..Default::default() })
        .expect("service")
}

fn request(id: u64, q: &[f64], k: usize, deadline_ms: Option<f64>) -> QueryRequest {
    QueryRequest {
        id,
        query: q.to_vec(),
        window_ratio: 0.1,
        suite: Suite::UcrMon,
        k,
        metric: Metric::Cdtw,
        deadline_ms,
        tenant: None,
    }
}

/// The registry-wide conservation identities every snapshot must satisfy
/// — under faults included, because panicked jobs flush no counters and
/// deadline-truncated scans flush only completed strips.
fn assert_conserved(c: &Counters) {
    assert_eq!(
        c.candidates,
        c.lb_kim_prunes
            + c.lb_keogh_eq_prunes
            + c.lb_keogh_ec_prunes
            + c.lb_improved_prunes
            + c.xla_prunes
            + c.dtw_calls,
        "candidate conservation broke: {c:?}"
    );
    assert_eq!(
        c.dtw_calls,
        c.dtw_abandons + c.dtw_completions,
        "dtw outcome conservation broke: {c:?}"
    );
}

fn expected_topk(r: &[f64], q: &[f64], k: usize) -> Vec<repro::search::subsequence::Match> {
    let mut c = Counters::new();
    search_subsequence_topk(r, q, window_cells(q.len(), 0.1), k, Suite::UcrMon, &mut c)
}

#[test]
fn worker_panic_is_contained_to_one_query() {
    let _lock = armed_section();
    let r = Dataset::Ecg.generate(3000, 41);
    let q = extract_queries(&r, 1, 96, 0.1, 42).remove(0);
    let svc = service(&r, 2, ScanMode::Strip);

    fault::arm(fault::WORKER_PANIC, 1);
    let err = svc.submit(&request(1, &q, 3, None)).expect_err("poisoned shard must fail");
    let p = err.root_cause().downcast_ref::<WorkerPanicked>().expect("typed panic error");
    assert!(p.message.contains("injected fault"), "payload survives: {p:?}");
    assert_eq!(ErrorResponse::new(1, &err).kind, Some(ErrorKind::Internal));

    let snap = svc.metrics();
    assert_eq!(snap.counters.worker_panics, 1);
    assert_conserved(&snap.counters);

    // the panic domain is per-job: the same pool answers the next query
    // bitwise-correctly, no respawn needed (the thread never died)
    let resp = svc.submit(&request(2, &q, 3, None)).expect("service keeps serving");
    let want = expected_topk(&r, &q, 3);
    assert_eq!(resp.matches.len(), want.len());
    for (g, m) in resp.matches.iter().zip(&want) {
        assert_eq!(g.pos, m.pos);
        assert_eq!(g.dist.to_bits(), m.dist.to_bits());
    }
    assert_eq!(svc.metrics().counters.worker_respawns, 0);
    assert_eq!(svc.queries_served(), 1);
    fault::reset();
}

#[test]
fn cohort_panic_fails_the_cohort_but_not_the_service() {
    let _lock = armed_section();
    let r = Dataset::Refit.generate(4000, 43);
    let qs = extract_queries(&r, 3, 128, 0.1, 44);
    let svc = service(&r, 2, ScanMode::Strip);
    let reqs: Vec<QueryRequest> =
        qs.iter().enumerate().map(|(i, q)| request(i as u64, q, 2, None)).collect();

    fault::arm(fault::WORKER_PANIC, 1);
    // same-shape queries form one cohort; one shard job panicking fails
    // the whole cohort (there is no partial answer to salvage) — but the
    // batch call itself completes and the pool survives
    let got = svc.submit_batch(&reqs);
    assert_eq!(got.len(), 3);
    for member in &got {
        let err = member.as_ref().expect_err("every cohort member fails together");
        assert!(format!("{err:#}").contains("panicked"), "unexpected error: {err:#}");
    }
    let snap = svc.metrics();
    assert_eq!(snap.counters.worker_panics, 1);
    assert_conserved(&snap.counters);

    // retried batch answers every member bitwise like a solo submit
    let again = svc.submit_batch(&reqs);
    for (i, member) in again.iter().enumerate() {
        let resp = member.as_ref().expect("healthy batch");
        let want = expected_topk(&r, &qs[i], 2);
        for (g, m) in resp.matches.iter().zip(&want) {
            assert_eq!(g.pos, m.pos);
            assert_eq!(g.dist.to_bits(), m.dist.to_bits());
        }
    }
    fault::reset();
}

#[test]
fn exited_worker_is_respawned_and_the_query_retried() {
    let _lock = armed_section();
    let r = Dataset::FoG.generate(3000, 45);
    let q = extract_queries(&r, 1, 96, 0.1, 46).remove(0);
    let svc = service(&r, 2, ScanMode::Strip);

    // the worker thread returns on job receipt: fan-in sees a closed
    // channel, the supervisor respawns the shard, and the retry answers
    // — the caller never observes the death
    fault::arm(fault::WORKER_EXIT, 1);
    let resp = svc.submit(&request(1, &q, 3, None)).expect("retry hides the dead worker");
    let want = expected_topk(&r, &q, 3);
    for (g, m) in resp.matches.iter().zip(&want) {
        assert_eq!(g.pos, m.pos);
        assert_eq!(g.dist.to_bits(), m.dist.to_bits());
    }
    let snap = svc.metrics();
    assert!(snap.counters.worker_respawns >= 1, "dead shard must be respawned");
    assert_eq!(snap.counters.worker_panics, 0, "a clean exit is not a panic");
    assert_conserved(&snap.counters);

    // the respawned pool is a full-strength pool
    assert!(svc.submit(&request(2, &q, 3, None)).is_ok());
    fault::reset();
}

#[test]
fn dropped_reply_is_retried_without_respawning_a_live_worker() {
    let _lock = armed_section();
    let r = Dataset::Ppg.generate(3000, 47);
    let q = extract_queries(&r, 1, 96, 0.1, 48).remove(0);
    let svc = service(&r, 2, ScanMode::Strip);

    // the job is dropped without a reply but the thread lives on: fan-in
    // reports a lost worker, the supervision sweep finds nothing dead,
    // and the retry goes to the same (healthy) pool
    fault::arm(fault::REPLY_DROP, 1);
    let resp = svc.submit(&request(1, &q, 1, None)).expect("retry answers");
    let want = expected_topk(&r, &q, 1);
    assert_eq!(resp.pos, want[0].pos);
    assert_eq!(resp.dist.to_bits(), want[0].dist.to_bits());
    let snap = svc.metrics();
    assert_eq!(snap.counters.worker_respawns, 0, "no thread died, none respawned");
    assert_conserved(&snap.counters);
    fault::reset();
}

#[test]
fn stalled_strips_honour_the_deadline_without_deadlock() {
    let _lock = armed_section();
    let r = Dataset::Pamap2.generate(6000, 49);
    let q = extract_queries(&r, 1, 128, 0.1, 50).remove(0);
    let svc = service(&r, 2, ScanMode::Strip);

    // every strip boundary sleeps 40ms — far beyond the 25ms budget, and
    // armed deep enough that an exhaustive scan would take minutes; the
    // deadline check at the same boundary must cut the scan short
    fault::arm_stall(fault::STRIP_STALL, 40, 1_000_000);
    let t0 = Instant::now();
    let outcome = svc.submit(&request(1, &q, 2, Some(25.0)));
    let elapsed = t0.elapsed();
    assert!(
        elapsed.as_secs() < 30,
        "stalled scan must be abandoned at a strip boundary, took {elapsed:?}"
    );
    match outcome {
        Ok(resp) => {
            assert!(resp.partial, "an in-budget answer is impossible while stalled");
            assert!(resp.matches.iter().all(|m| m.dist.is_finite()));
        }
        Err(e) => {
            assert!(
                e.root_cause().downcast_ref::<DeadlineExceeded>().is_some(),
                "unexpected error: {e:#}"
            );
            assert_eq!(ErrorResponse::new(1, &e).kind, Some(ErrorKind::Timeout));
        }
    }
    let snap = svc.metrics();
    assert_eq!(snap.counters.deadline_timeouts, 1);
    assert_conserved(&snap.counters);

    // disarmed, the same service answers the same query exhaustively and
    // bitwise-correctly — the stall left no residue
    fault::reset();
    let full = svc.submit(&request(2, &q, 2, None)).expect("recovered");
    assert!(!full.partial);
    let want = expected_topk(&r, &q, 2);
    for (g, m) in full.matches.iter().zip(&want) {
        assert_eq!(g.pos, m.pos);
        assert_eq!(g.dist.to_bits(), m.dist.to_bits());
    }
}

#[test]
fn counters_conserve_across_a_faulty_session() {
    let _lock = armed_section();
    let r = Dataset::Soccer.generate(5000, 51);
    let qs = extract_queries(&r, 4, 128, 0.1, 52);
    let svc = service(&r, 3, ScanMode::Strip);

    // a session mixing every fault class: one panicked query, one lost
    // worker (hidden by the retry), one stalled deadline query, and
    // healthy traffic before/after
    assert!(svc.submit(&request(0, &qs[0], 2, None)).is_ok());

    fault::arm(fault::WORKER_PANIC, 1);
    assert!(svc.submit(&request(1, &qs[1], 2, None)).is_err());

    fault::arm(fault::WORKER_EXIT, 1);
    assert!(svc.submit(&request(2, &qs[2], 2, None)).is_ok());

    fault::arm_stall(fault::STRIP_STALL, 40, 1_000_000);
    let _ = svc.submit(&request(3, &qs[3], 2, Some(25.0)));
    fault::reset();

    let snap = svc.metrics();
    assert_conserved(&snap.counters);
    assert_eq!(snap.counters.worker_panics, 1);
    assert!(snap.counters.worker_respawns >= 1);
    assert_eq!(snap.counters.deadline_timeouts, 1);
    assert_eq!(snap.counters.shed_queries, 0);

    // and the scarred service still serves bitwise-correct answers
    let resp = svc.submit(&request(9, &qs[0], 2, None)).expect("still serving");
    let want = expected_topk(&r, &qs[0], 2);
    for (g, m) in resp.matches.iter().zip(&want) {
        assert_eq!(g.pos, m.pos);
        assert_eq!(g.dist.to_bits(), m.dist.to_bits());
    }
}
