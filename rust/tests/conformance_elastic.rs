//! Cross-metric conformance suite: for every [`Metric`] kind × all six
//! synthetic datasets, the EAPruned kernel must
//!
//! 1. equal its naive full-matrix oracle at `ub = inf`,
//! 2. stay exact at ties (`ub =` the exact distance — strict-above
//!    abandoning preserves ties, paper §2.2), and
//! 3. abandon (return the `+inf` sentinel) for any `ub` strictly below
//!    the exact distance.
//!
//! The suite is table-driven over [`Metric::all_default`] plus extra
//! parameterisations, so covering a new metric is one enum arm (and one
//! grid row) away.

use repro::data::Dataset;
use repro::distances::metric::Metric;
use repro::distances::DtwWorkspace;
use repro::norm::znorm::znorm;
use repro::search::suite::Suite;

/// The conformance grid: every kind with default parameters, plus a
/// second parameterisation of each parameterised kind so the parameter
/// plumbing is exercised too.
fn grid() -> Vec<Metric> {
    let mut g = Metric::all_default().to_vec();
    g.extend([
        Metric::Wdtw { g: 0.2 },
        Metric::Erp { gap: 0.5 },
        Metric::Msm { cost: 1.0 },
        Metric::Twe { nu: 0.001, lambda: 0.25 },
    ]);
    g
}

/// Two z-normalised same-length excerpts of one dataset, far enough apart
/// to be genuinely different series.
fn pair_from(ds: Dataset, seed: u64, n: usize) -> (Vec<f64>, Vec<f64>) {
    let r = ds.generate(3 * n + 64, seed);
    (znorm(&r[7..7 + n]), znorm(&r[2 * n + 19..2 * n + 19 + n]))
}

#[test]
fn every_metric_matches_oracle_ties_and_abandons_on_all_datasets() {
    let mut ws = DtwWorkspace::default();
    for metric in grid() {
        for ds in Dataset::ALL {
            for (n, w) in [(21usize, 5usize), (34, 9), (47, 47)] {
                let (a, b) = pair_from(ds, 0xC0DE ^ ((n as u64) << 3), n);
                let tag = format!("{} on {} n={n} w={w}", metric.name(), ds.name());

                let want = metric.exact(&a, &b, w);
                assert!(want.is_finite(), "oracle must be finite: {tag}");
                assert!(want >= 0.0, "distances are non-negative: {tag}");

                // 1. exact at ub = inf
                let got = metric.eval(&a, &b, w, f64::INFINITY, None, Suite::UcrMon, &mut ws);
                assert!(
                    (got - want).abs() <= 1e-9 * want.max(1.0),
                    "kernel vs oracle: {got} vs {want} ({tag})"
                );

                // 2. exact at the tie
                let tie = metric.eval(&a, &b, w, want, None, Suite::UcrMon, &mut ws);
                assert!(
                    (tie - want).abs() <= 1e-9 * want.max(1.0),
                    "tie broken: {tie} vs {want} ({tag})"
                );

                // 3. sentinel strictly below
                if want > 0.0 {
                    let below = want * (1.0 - 1e-9) - 1e-12;
                    let ab = metric.eval(&a, &b, w, below, None, Suite::UcrMon, &mut ws);
                    assert_eq!(ab, f64::INFINITY, "no abandon below the tie ({tag})");
                }
            }
        }
    }
}

#[test]
fn identity_is_zero_for_every_metric_on_every_dataset() {
    let mut ws = DtwWorkspace::default();
    for metric in grid() {
        for ds in Dataset::ALL {
            let (a, _) = pair_from(ds, 99, 40);
            let d = metric.eval(&a, &a, 40, f64::INFINITY, None, Suite::UcrMon, &mut ws);
            // TWE pays stiffness on the diagonal matches of identical
            // series only through the drift term, which is 0 at |i-j|=0;
            // every metric's self-distance is exactly 0
            assert_eq!(d, 0.0, "{} on {}", metric.name(), ds.name());
        }
    }
}

#[test]
fn kernel_is_exact_through_every_dtw_core_suite() {
    // the dispatch layer must hold for every ablation suite, not just
    // UCR-MON: cDTW routes through the suite's own core
    let mut ws = DtwWorkspace::default();
    let (a, b) = pair_from(Dataset::Ecg, 7, 30);
    let w = 6;
    let want = Metric::Cdtw.exact(&a, &b, w);
    for suite in Suite::ALL {
        let got = Metric::Cdtw.eval(&a, &b, w, f64::INFINITY, None, suite, &mut ws);
        assert!((got - want).abs() < 1e-9, "{}: {got} vs {want}", suite.name());
    }
}

#[test]
fn banded_elastic_metrics_respect_window_monotonicity() {
    // widening the band can only lower (or keep) a banded metric's
    // distance — the conformance analogue of cDTW's window monotonicity
    let mut ws = DtwWorkspace::default();
    let banded = [
        Metric::Cdtw,
        Metric::Erp { gap: 0.0 },
        Metric::Msm { cost: 0.5 },
        Metric::Twe { nu: 0.05, lambda: 1.0 },
    ];
    for metric in banded {
        for ds in Dataset::ALL {
            let (a, b) = pair_from(ds, 0xBEEF, 28);
            let mut last = f64::INFINITY;
            for w in [2usize, 7, 14, 28] {
                let d = metric.eval(&a, &b, w, f64::INFINITY, None, Suite::UcrMon, &mut ws);
                assert!(
                    d <= last + 1e-9,
                    "{} on {}: w={w} rose to {d} from {last}",
                    metric.name(),
                    ds.name()
                );
                last = d;
            }
        }
    }
}
