//! Conformance suite for the multi-candidate wavefront kernel and the
//! opt-in f32 DP precision (`distances/kernel.rs`).
//!
//! **f64 contract — bitwise.** A multi-lane evaluation advances N
//! candidates in row lockstep but shares no DP state between lanes, and
//! a lane's cell values never depend on its threshold (the threshold
//! only gates control flow). So every lane's outcome — distance bits
//! *and* abandoned flag — must equal a scalar [`eap_kernel`] call with
//! the same `(model, w, ub, cb)`. The property is pinned across all six
//! metric cost models, random lane counts, and mixed per-lane bounds
//! (`inf` / exact tie / 0 / half-exact), including lanes retired
//! mid-group and a planted first-block abandon.
//!
//! **f32 contract — epsilon, over-admit only.** f32 lines round, so the
//! gate is relative error against the f64 oracle plus the pruning
//! direction: thresholds are inflated on narrowing, hence an f32 run may
//! evaluate a candidate f64 would have abandoned (over-admit) but must
//! never abandon a candidate f64 completes (over-prune).

use repro::distances::kernel::{
    eap_kernel, eap_kernel_f32, eap_kernel_multi, eap_kernel_multi_dyn, CostModel, DtwCost,
    KernelEval, MultiWorkspace, Precision, LANE_REFRESH_ROWS, MAX_LANES,
};
use repro::distances::elastic::erp::Erp;
use repro::distances::elastic::msm::Msm;
use repro::distances::elastic::twe::Twe;
use repro::distances::elastic::wdtw::Wdtw;
use repro::distances::DtwWorkspace;
use repro::index::{Engine, EngineConfig, Query};
use repro::metrics::Counters;
use repro::search::subsequence::ScanTuning;

fn xorshift(seed: u64) -> impl FnMut() -> f64 {
    let mut x = seed;
    move || {
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        (x as f64 / u64::MAX as f64) * 2.0 - 1.0
    }
}

fn series(rnd: &mut impl FnMut() -> f64, n: usize) -> Vec<f64> {
    (0..n).map(|_| rnd()).collect()
}

/// Mixed per-lane upper bounds cycling through the interesting regimes:
/// no bound, the exact tie (must still complete — strict `>` abandon),
/// a planted first-rows abandon, and a mid-scan abandon.
fn mixed_ub(lane: usize, exact: f64) -> f64 {
    match lane % 4 {
        0 => f64::INFINITY,
        1 => exact,
        2 => 0.0,
        _ => exact * 0.5,
    }
}

/// Evaluate `models` through the multi-lane path and through per-lane
/// scalar calls, asserting bitwise-identical outcomes lane by lane.
fn assert_lanes_match_scalar<C: CostModel>(
    models: &[C],
    w: usize,
    ubs: &[f64],
    mws: &mut MultiWorkspace,
    ws: &mut DtwWorkspace,
    tag: &str,
) -> Vec<KernelEval> {
    let cbs = vec![None::<&[f64]>; models.len()];
    let mut out = Vec::new();
    eap_kernel_multi_dyn::<f64, C>(models, w, ubs, &cbs, mws, |l| ubs[l], &mut out);
    assert_eq!(out.len(), models.len(), "{tag}: one outcome per lane");
    for (lane, e) in out.iter().enumerate() {
        let want = eap_kernel(&models[lane], w, ubs[lane], None, ws);
        assert_eq!(e.dist.to_bits(), want.dist.to_bits(), "{tag} lane {lane}");
        assert_eq!(e.abandoned, want.abandoned, "{tag} lane {lane}");
    }
    out
}

/// The tentpole f64 property: across all six metric cost models, random
/// lane counts in `2..=MAX_LANES`, and mixed per-lane bounds, every lane
/// of a wavefront evaluation is bitwise-identical to the scalar kernel.
#[test]
fn multi_lane_f64_bitwise_matches_scalar_for_all_six_metrics() {
    let mut ws = DtwWorkspace::default();
    let mut mws = MultiWorkspace::default();
    for seed in 1..=5u64 {
        let mut rnd = xorshift(0x1A7E5 ^ (seed << 9));
        let lanes = 2 + (seed as usize * 3) % (MAX_LANES - 1); // 2..=8
        for n in [11usize, 27] {
            let q = series(&mut rnd, n);
            let cands: Vec<Vec<f64>> = (0..lanes).map(|_| series(&mut rnd, n)).collect();
            let w = (n / 4).max(1);
            let tag = |m: &str| format!("{m} seed={seed} lanes={lanes} n={n}");
            macro_rules! pin {
                ($name:literal, $mk:expr, $w:expr) => {{
                    let models: Vec<_> = cands.iter().map($mk).collect();
                    let exact: Vec<f64> = models
                        .iter()
                        .map(|mo| eap_kernel(mo, $w, f64::INFINITY, None, &mut ws).dist)
                        .collect();
                    let ubs: Vec<f64> =
                        (0..lanes).map(|l| mixed_ub(l, exact[l])).collect();
                    assert_lanes_match_scalar(
                        &models, $w, &ubs, &mut mws, &mut ws, &tag($name),
                    );
                }};
            }
            pin!("cdtw", |c: &Vec<f64>| DtwCost { li: &q, co: c }, w);
            pin!("dtw", |c: &Vec<f64>| DtwCost { li: &q, co: c }, n);
            pin!("wdtw", |c: &Vec<f64>| Wdtw::new(&q, c, 0.05), n);
            pin!("erp", |c: &Vec<f64>| Erp::new(&q, c, 0.25), w);
            pin!("msm", |c: &Vec<f64>| Msm::new(&q, c, 0.5), w);
            pin!("twe", |c: &Vec<f64>| Twe::new(&q, c, 0.05, 1.0), w);
        }
    }
}

/// A lane retired mid-group must not perturb its siblings: plant one
/// candidate far from the query (abandons in the first rows under a
/// modest bound) between two unbounded lanes and pin all three bitwise.
#[test]
fn planted_first_block_abandon_retires_lane_without_perturbing_siblings() {
    let mut ws = DtwWorkspace::default();
    let mut mws = MultiWorkspace::default();
    let mut rnd = xorshift(0xD15C);
    let n = 40;
    let q = series(&mut rnd, n);
    let near = series(&mut rnd, n);
    // offset +100: every cell costs >= ~9801, so any finite bound from
    // the near candidates' scale collapses the band on the first row
    let far: Vec<f64> = series(&mut rnd, n).iter().map(|v| v + 100.0).collect();
    let near2 = series(&mut rnd, n);
    let models = [
        DtwCost { li: &q, co: &near },
        DtwCost { li: &q, co: &far },
        DtwCost { li: &q, co: &near2 },
    ];
    let ubs = [f64::INFINITY, 1.0, f64::INFINITY];
    mws.warm(3, n, Precision::F64);
    let out =
        assert_lanes_match_scalar(&models, n, &ubs, &mut mws, &mut ws, "planted-abandon");
    assert!(out[1].abandoned, "the planted far candidate must abandon");
    assert!(!out[0].abandoned && !out[2].abandoned, "siblings must complete");
    assert_eq!(mws.regrows(), 0, "pre-warmed lanes must not regrow");
}

#[test]
fn const_width_wrapper_delegates_to_dyn() {
    let mut ws = DtwWorkspace::default();
    let mut mws = MultiWorkspace::default();
    let mut rnd = xorshift(0xC0457);
    let n = 16;
    let q = series(&mut rnd, n);
    let cands: Vec<Vec<f64>> = (0..4).map(|_| series(&mut rnd, n)).collect();
    let models: [DtwCost; 4] = std::array::from_fn(|i| DtwCost { li: &q, co: &cands[i] });
    let exact = eap_kernel(&models[1], n, f64::INFINITY, None, &mut ws).dist;
    let ubs = [f64::INFINITY, exact, 0.0, exact * 0.5];
    let mut out = Vec::new();
    eap_kernel_multi::<_, 4>(&models, n, &ubs, &mut mws, &mut out);
    for (lane, e) in out.iter().enumerate() {
        let want = eap_kernel(&models[lane], n, ubs[lane], None, &mut ws);
        assert_eq!(e.dist.to_bits(), want.dist.to_bits(), "lane {lane}");
        assert_eq!(e.abandoned, want.abandoned, "lane {lane}");
    }
}

/// The mid-kernel refresh cadence (`LANE_REFRESH_ROWS`) folds re-read
/// thresholds in with `min`: a refresh that returns the frozen bound or
/// anything looser is a no-op (bitwise), and a refresh that tightens to
/// 0 retires every lane still in flight at the cadence row.
#[test]
fn mid_kernel_threshold_refresh_only_tightens() {
    let mut ws = DtwWorkspace::default();
    let mut mws = MultiWorkspace::default();
    let mut rnd = xorshift(0x5713F);
    let n = LANE_REFRESH_ROWS + 36; // the refresh fires mid-evaluation
    let q = series(&mut rnd, n);
    let cands: Vec<Vec<f64>> = (0..3).map(|_| series(&mut rnd, n)).collect();
    let models: Vec<DtwCost> = cands.iter().map(|c| DtwCost { li: &q, co: c }).collect();
    let exact: Vec<f64> = models
        .iter()
        .map(|mo| eap_kernel(mo, n, f64::INFINITY, None, &mut ws).dist)
        .collect();
    let ubs = [f64::INFINITY, exact[1], exact[2] * 2.0];
    let cbs = [None::<&[f64]>; 3];
    // looser refresh (2x the frozen bound, inf stays inf): ignored
    let loosen = |l: usize| ubs[l] * 2.0;
    let mut out = Vec::new();
    eap_kernel_multi_dyn::<f64, _>(&models, n, &ubs, &cbs, &mut mws, loosen, &mut out);
    for (lane, e) in out.iter().enumerate() {
        let want = eap_kernel(&models[lane], n, ubs[lane], None, &mut ws);
        assert_eq!(e.dist.to_bits(), want.dist.to_bits(), "loosened lane {lane}");
        assert_eq!(e.abandoned, want.abandoned, "loosened lane {lane}");
    }
    // tightened-to-0 refresh: every lane survives to the cadence row
    // (bounds above are all >= exact), then collapses on it
    eap_kernel_multi_dyn::<f64, _>(&models, n, &ubs, &cbs, &mut mws, |_| 0.0, &mut out);
    for (lane, e) in out.iter().enumerate() {
        assert!(e.abandoned, "tightened lane {lane} must retire at the refresh row");
    }
}

/// End-to-end f64 identity: a lanes=4 engine returns bitwise-identical
/// top-k results to the scalar lanes=1 engine, actually packs groups
/// (`kernel_multi_calls > 0`), and keeps the occupancy and conservation
/// identities that `tools/bench_diff.py` audits offline.
#[test]
fn engine_with_lanes_is_bitwise_identical_to_scalar_and_packs_groups() {
    let (reference, queries) = engine_workload();
    let k = 3;
    let scalar = engine_with(&reference, ScanTuning::default());
    let lanes4 = engine_with(&reference, ScanTuning::default().with_lanes(4));
    let want = scalar.search_batch(&queries, k).unwrap();
    let got = lanes4.search_batch(&queries, k).unwrap();
    for (i, (a, b)) in want.iter().zip(&got).enumerate() {
        assert_eq!(a.matches.len(), b.matches.len(), "q{i}");
        for (x, y) in a.matches.iter().zip(&b.matches) {
            assert_eq!(x.pos, y.pos, "q{i}");
            assert_eq!(x.dist.to_bits(), y.dist.to_bits(), "q{i}");
        }
    }
    let base = merged(&want);
    assert_eq!(base.kernel_multi_calls, 0, "scalar engine must not pack lanes");
    assert_eq!(base.kernel_lanes_filled, 0);
    let c = merged(&got);
    assert!(c.kernel_multi_calls > 0, "lanes=4 engine never packed a group");
    assert!(
        c.kernel_lanes_filled >= 2 * c.kernel_multi_calls,
        "mean occupancy below 2: {} filled / {} calls",
        c.kernel_lanes_filled,
        c.kernel_multi_calls
    );
    assert!(c.kernel_lane_abandons <= c.kernel_lanes_filled);
    assert!(c.kernel_lane_abandons <= c.dtw_abandons, "lane abandons are a subset");
    // multi-lane calls fold into the conservation identity unchanged
    assert_eq!(c.dtw_calls, c.dtw_abandons + c.dtw_completions);
    assert_eq!(c.kernel_workspace_regrows, 0, "lane packing must not regrow");
}

/// f32 epsilon contract at the kernel level: multi-lane f32 is bitwise
/// per-lane f32-scalar (same lockstep argument as f64); against the f64
/// oracle it is epsilon-close and prunes only in the sound direction —
/// a tie bound f64 completes must complete in f32 too.
#[test]
fn f32_lanes_bitwise_match_f32_scalar_and_track_f64_within_epsilon() {
    let mut ws = DtwWorkspace::default();
    let mut mws = MultiWorkspace::default();
    for seed in 1..=3u64 {
        let mut rnd = xorshift(0xF32 ^ (seed << 11));
        let n = 33;
        let q = series(&mut rnd, n);
        let cands: Vec<Vec<f64>> = (0..4).map(|_| series(&mut rnd, n)).collect();
        let models: Vec<DtwCost> = cands.iter().map(|c| DtwCost { li: &q, co: c }).collect();
        let d64: Vec<f64> = models
            .iter()
            .map(|mo| eap_kernel(mo, n, f64::INFINITY, None, &mut ws).dist)
            .collect();
        // lane 1 carries the f64-exact tie: f64 completes at that bound,
        // so the inflated f32 threshold must complete too (over-admit
        // only); lane 3's half-exact bound must still abandon.
        let ubs = [f64::INFINITY, d64[1], f64::INFINITY, d64[3] * 0.5];
        let cbs = [None::<&[f64]>; 4];
        let mut out = Vec::new();
        eap_kernel_multi_dyn::<f32, _>(&models, n, &ubs, &cbs, &mut mws, |l| ubs[l], &mut out);
        for (lane, e) in out.iter().enumerate() {
            let want = eap_kernel_f32(&models[lane], n, ubs[lane], None, &mut ws);
            assert_eq!(e.dist.to_bits(), want.dist.to_bits(), "seed={seed} lane {lane}");
            assert_eq!(e.abandoned, want.abandoned, "seed={seed} lane {lane}");
        }
        assert!(!out[0].abandoned && !out[2].abandoned);
        assert!(!out[1].abandoned, "f32 over-pruned the exact-tie lane");
        assert!(out[3].abandoned, "half-exact bound must abandon in f32 too");
        for (lane, e) in out.iter().enumerate() {
            if !e.abandoned {
                let rel = (e.dist - d64[lane]).abs() / d64[lane].abs().max(1e-12);
                assert!(rel <= 1e-4, "seed={seed} lane {lane} rel={rel}");
            }
        }
    }
}

/// End-to-end f32: a `--precision f32` engine (scalar and lanes=4)
/// returns the same top-k positions as the f64 oracle on well-separated
/// synthetic data, with distances epsilon-close.
#[test]
fn engine_f32_precision_tracks_f64_oracle_within_epsilon() {
    let (reference, queries) = engine_workload();
    let k = 3;
    let oracle = engine_with(&reference, ScanTuning::default());
    let want = oracle.search_batch(&queries, k).unwrap();
    for lanes in [1usize, 4] {
        let engine = engine_with(
            &reference,
            ScanTuning::default().with_lanes(lanes).with_precision(Precision::F32),
        );
        let got = engine.search_batch(&queries, k).unwrap();
        for (i, (a, b)) in want.iter().zip(&got).enumerate() {
            assert_eq!(a.matches.len(), b.matches.len(), "lanes={lanes} q{i}");
            assert_eq!(a.best().pos, b.best().pos, "lanes={lanes} q{i}");
            for (x, y) in a.matches.iter().zip(&b.matches) {
                let scale = x.dist.abs().max(1.0);
                assert!(
                    (x.dist - y.dist).abs() <= 1e-3 * scale,
                    "lanes={lanes} q{i}: f32 dist {} vs f64 {}",
                    y.dist,
                    x.dist
                );
            }
        }
        let c = merged(&got);
        assert_eq!(c.kernel_workspace_regrows, 0, "f32 lines must be pre-warmed");
        if lanes >= 2 {
            assert!(c.kernel_multi_calls > 0, "f32 lanes engine never packed a group");
        }
    }
}

fn merged(results: &[repro::index::TopKResult]) -> Counters {
    let mut c = Counters::new();
    for r in results {
        c.merge(&r.counters);
    }
    c
}

fn engine_with(reference: &[f64], tuning: ScanTuning) -> Engine {
    Engine::new(reference.to_vec(), &EngineConfig { shards: 2, tuning, ..Default::default() })
        .unwrap()
}

/// A small strip-scan workload with well-separated matches: a noisy
/// multi-tone reference and near-copy queries cut from it.
fn engine_workload() -> (Vec<f64>, Vec<Query>) {
    let mut rnd = xorshift(0xE26);
    let n = 2000;
    let reference: Vec<f64> = (0..n)
        .map(|i| {
            let t = i as f64;
            (t * 0.031).sin() + 0.5 * (t * 0.0071).cos() + 0.05 * rnd()
        })
        .collect();
    let qlen = 64;
    let queries = (0..8)
        .map(|qi| {
            let start = (qi * 211) % (n - qlen);
            let q: Vec<f64> =
                reference[start..start + qlen].iter().map(|v| v + 0.02 * rnd()).collect();
            Query::new(q, 0.1)
        })
        .collect();
    (reference, queries)
}
