//! End-to-end search integration: every suite returns the identical match
//! on every dataset (the paper's correctness requirement — the suites
//! differ only in *speed*), and the counters tell the Fig-5-inset story.

use repro::data::{extract_queries, Dataset};
use repro::metrics::Counters;
use repro::search::nn1::nn1_search;
use repro::search::subsequence::{search_subsequence, window_cells};
use repro::search::suite::Suite;
use repro::norm::znorm::znorm;

#[test]
fn suites_agree_on_every_dataset() {
    for d in Dataset::ALL {
        let r = d.generate(6000, 99);
        let q = extract_queries(&r, 1, 256, 0.1, 7).remove(0);
        let w = window_cells(q.len(), 0.1);
        let mut base = None;
        for s in Suite::ALL {
            let mut c = Counters::new();
            let m = search_subsequence(&r, &q, w, s, &mut c);
            match base {
                None => base = Some(m),
                Some(b) => {
                    assert_eq!(m.pos, b.pos, "{} on {}", s.name(), d.name());
                    assert!(
                        (m.dist - b.dist).abs() < 1e-9,
                        "{} on {}: {} vs {}",
                        s.name(),
                        d.name(),
                        m.dist,
                        b.dist
                    );
                }
            }
        }
    }
}

#[test]
fn mon_does_fewer_dp_work_than_baselines_via_abandon_rate() {
    // EAPrunedDTW abandons reliably; the UCR core only on row minima.
    // On the DTW calls that survive the cascade, MON must abandon at
    // least as often as UCR.
    let d = Dataset::Pamap2;
    let r = d.generate(8000, 5);
    let q = extract_queries(&r, 1, 256, 0.1, 11).remove(0);
    let w = window_cells(q.len(), 0.2);
    let mut c_ucr = Counters::new();
    let mut c_mon = Counters::new();
    search_subsequence(&r, &q, w, Suite::Ucr, &mut c_ucr);
    search_subsequence(&r, &q, w, Suite::UcrMon, &mut c_mon);
    assert_eq!(c_ucr.dtw_calls, c_mon.dtw_calls, "same cascade → same survivors");
    assert!(
        c_mon.dtw_abandons >= c_ucr.dtw_abandons,
        "mon {} < ucr {}",
        c_mon.dtw_abandons,
        c_ucr.dtw_abandons
    );
}

#[test]
fn window_ratio_zero_equals_euclidean_matching() {
    let r = Dataset::Ppg.generate(3000, 1);
    let q = extract_queries(&r, 1, 128, 0.05, 2).remove(0);
    let mut c = Counters::new();
    let m = search_subsequence(&r, &q, 0, Suite::UcrMon, &mut c);
    // brute force squared euclidean on z-normalised windows
    let qz = znorm(&q);
    let mut best = (0usize, f64::INFINITY);
    for pos in 0..=(r.len() - q.len()) {
        let cz = znorm(&r[pos..pos + q.len()]);
        let d: f64 = qz.iter().zip(&cz).map(|(a, b)| (a - b) * (a - b)).sum();
        if d < best.1 {
            best = (pos, d);
        }
    }
    assert_eq!(m.pos, best.0);
    assert!((m.dist - best.1).abs() < 1e-9);
}

#[test]
fn counters_partition_candidates() {
    // every candidate is either pruned by exactly one stage or reaches DTW
    for s in Suite::ALL {
        let r = Dataset::Ecg.generate(5000, 3);
        let q = extract_queries(&r, 1, 128, 0.1, 4).remove(0);
        let mut c = Counters::new();
        search_subsequence(&r, &q, window_cells(q.len(), 0.3), s, &mut c);
        assert_eq!(
            c.candidates,
            c.lb_kim_prunes + c.lb_keogh_eq_prunes + c.lb_keogh_ec_prunes + c.dtw_calls,
            "{}: {c:?}",
            s.name()
        );
    }
}

#[test]
fn larger_windows_cost_more_dtw_cells_but_same_result() {
    let r = Dataset::Soccer.generate(4000, 8);
    let q = extract_queries(&r, 1, 128, 0.1, 9).remove(0);
    let mut prev_dist = f64::INFINITY;
    for ratio in [0.1, 0.2, 0.3, 0.4, 0.5] {
        let w = window_cells(q.len(), ratio);
        let mut c = Counters::new();
        let m = search_subsequence(&r, &q, w, Suite::UcrMon, &mut c);
        // more window ⇒ match can only improve (monotone in w)
        assert!(m.dist <= prev_dist + 1e-9, "ratio={ratio}");
        prev_dist = m.dist;
    }
}

#[test]
fn nn1_all_suites_agree_on_dataset_snippets() {
    let r = Dataset::FoG.generate(40_000, 12);
    let cands: Vec<Vec<f64>> =
        (0..40).map(|i| znorm(&r[i * 900..i * 900 + 256])).collect();
    let q = znorm(&r[777..1033]);
    let mut base = None;
    for s in Suite::ALL {
        let mut c = Counters::new();
        let got = nn1_search(&q, &cands, 25, s, &mut c).unwrap();
        match base {
            None => base = Some(got),
            Some(b) => {
                assert_eq!(got.index, b.index, "{}", s.name());
                assert!((got.dist - b.dist).abs() < 1e-9);
            }
        }
    }
}
