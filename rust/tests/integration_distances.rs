//! Cross-module distance integration: every DTW variant and every elastic
//! extension agrees with its oracle on realistic (dataset-derived) series,
//! at sizes larger than the unit tests use.

use repro::data::Dataset;
use repro::distances::dtw::{cdtw, dtw_oracle};
use repro::distances::dtw_ea::dtw_ea;
use repro::distances::eap_dtw::{eap_cdtw, eap_cdtw_counted, eap_dtw};
use repro::distances::elastic::erp::{eap_erp, erp_naive};
use repro::distances::elastic::msm::{eap_msm, msm_naive};
use repro::distances::elastic::twe::{eap_twe, twe_naive};
use repro::distances::elastic::wdtw::{eap_wdtw, wdtw_naive};
use repro::distances::left_prune::left_pruned_dtw;
use repro::distances::pruned_dtw::pruned_cdtw;
use repro::distances::DtwWorkspace;
use repro::norm::znorm::znorm;

fn pairs() -> Vec<(Vec<f64>, Vec<f64>)> {
    let mut out = Vec::new();
    for (i, d) in Dataset::ALL.into_iter().enumerate() {
        let r = d.generate(4096, 17 + i as u64);
        out.push((znorm(&r[100..356]), znorm(&r[2000..2256])));
    }
    out
}

#[test]
fn all_dtw_variants_agree_on_real_series() {
    let mut ws = DtwWorkspace::default();
    for (a, b) in pairs() {
        for w in [12usize, 64, 256] {
            let want = cdtw(&a, &b, w);
            let oracle = dtw_oracle(&a, &b, Some(w));
            assert!((want - oracle).abs() < 1e-9);
            let ea = dtw_ea(&a, &b, w, f64::INFINITY, None, &mut ws);
            assert!((ea - want).abs() < 1e-9, "dtw_ea w={w}");
            let pr = pruned_cdtw(&a, &b, w, f64::INFINITY, None, &mut ws);
            assert!((pr - want).abs() < 1e-9, "pruned w={w}");
            let eap = eap_cdtw(&a, &b, w, f64::INFINITY, None, &mut ws);
            assert!((eap - want).abs() < 1e-9, "eap w={w}");
            // ties are never abandoned by any variant
            for (name, got) in [
                ("dtw_ea", dtw_ea(&a, &b, w, want, None, &mut ws)),
                ("pruned", pruned_cdtw(&a, &b, w, want, None, &mut ws)),
                ("eap", eap_cdtw(&a, &b, w, want, None, &mut ws)),
            ] {
                assert!((got - want).abs() < 1e-9, "{name} tie w={w}");
            }
            // EAP (the paper's algorithm) abandons *reliably* below
            let below = eap_cdtw(&a, &b, w, want * (1.0 - 1e-9) - 1e-12, None, &mut ws);
            assert_eq!(below, f64::INFINITY, "eap below w={w}");
        }
    }
}

#[test]
fn unwindowed_entry_points_match() {
    let mut ws = DtwWorkspace::default();
    for (a, b) in pairs().into_iter().take(2) {
        let want = cdtw(&a, &b, a.len().max(b.len()));
        assert!((eap_dtw(&a, &b, f64::INFINITY) - want).abs() < 1e-9);
        assert!((left_pruned_dtw(&a, &b, f64::INFINITY, &mut ws) - want).abs() < 1e-9);
    }
}

#[test]
fn eap_with_tight_ub_computes_fewer_cells() {
    let mut ws = DtwWorkspace::default();
    for (a, b) in pairs() {
        let w = 64;
        let exact = cdtw(&a, &b, w);
        let (_, loose) = eap_cdtw_counted(&a, &b, w, f64::INFINITY, None, &mut ws);
        let (d, tight) = eap_cdtw_counted(&a, &b, w, exact, None, &mut ws);
        assert!((d - exact).abs() < 1e-9);
        assert!(tight <= loose);
    }
}

#[test]
fn elastic_extensions_match_oracles_on_real_series() {
    let mut ws = DtwWorkspace::default();
    for (a, b) in pairs().into_iter().take(3) {
        let a = &a[..96];
        let b = &b[..96];
        let n = a.len();
        let cases: Vec<(&str, f64, f64)> = vec![
            ("erp", erp_naive(a, b, 0.0, n), eap_erp(a, b, 0.0, n, f64::INFINITY, &mut ws)),
            ("msm", msm_naive(a, b, 0.5, n), eap_msm(a, b, 0.5, n, f64::INFINITY, &mut ws)),
            (
                "twe",
                twe_naive(a, b, 0.001, 1.0, n),
                eap_twe(a, b, 0.001, 1.0, n, f64::INFINITY, &mut ws),
            ),
            ("wdtw", wdtw_naive(a, b, 0.05, n), eap_wdtw(a, b, 0.05, n, f64::INFINITY, &mut ws)),
        ];
        for (name, want, got) in cases {
            assert!((got - want).abs() < 1e-9, "{name}: {got} vs {want}");
        }
    }
}

#[test]
fn elastic_extensions_early_abandon_correctly() {
    // paper §6: the EAP scheme transfers to other elastic measures —
    // exact at ties, +inf (or never below) under tight bounds
    let mut ws = DtwWorkspace::default();
    for (a, b) in pairs().into_iter().take(2) {
        let a = &a[..64];
        let b = &b[..64];
        let n = a.len();
        let erp = erp_naive(a, b, 0.0, n);
        assert!((eap_erp(a, b, 0.0, n, erp, &mut ws) - erp).abs() < 1e-9);
        let msm = msm_naive(a, b, 0.5, n);
        assert!((eap_msm(a, b, 0.5, n, msm, &mut ws) - msm).abs() < 1e-9);
        let twe = twe_naive(a, b, 0.001, 1.0, n);
        assert!((eap_twe(a, b, 0.001, 1.0, n, twe, &mut ws) - twe).abs() < 1e-9);
        let wdtw = wdtw_naive(a, b, 0.05, n);
        assert!((eap_wdtw(a, b, 0.05, n, wdtw, &mut ws) - wdtw).abs() < 1e-9);
        // reliable abandon below (all have infinite or gated borders)
        assert_eq!(eap_msm(a, b, 0.5, n, msm * 0.99 - 1e-9, &mut ws), f64::INFINITY);
        assert_eq!(eap_twe(a, b, 0.001, 1.0, n, twe * 0.99 - 1e-9, &mut ws), f64::INFINITY);
        assert_eq!(eap_wdtw(a, b, 0.05, n, wdtw * 0.99 - 1e-9, &mut ws), f64::INFINITY);
    }
}
