//! Index-subsystem invariants: the top-k collector generalises the scalar
//! best-so-far *conservatively* — k = 1 is bit-identical to the seed loop,
//! any k is a prefix of the brute-force ranking, and the batched engine
//! reproduces the unbatched search.

use repro::data::rng::Rng;
use repro::data::{extract_queries, Dataset};
use repro::distances::dtw::cdtw_ws;
use repro::distances::metric::Metric;
use repro::distances::DtwWorkspace;
use repro::index::{Engine, EngineConfig, Query, TopK};
use repro::metrics::Counters;
use repro::norm::znorm::{znorm, znorm_point, WindowStats};
use repro::search::nn1::{nn1_search, nn1_topk, nn1_topk_metric};
use repro::search::subsequence::{
    search_subsequence, search_subsequence_topk, search_subsequence_topk_metric, window_cells,
    Match,
};
use repro::search::suite::Suite;
use repro::util::proptest::run_prop;

fn arb_dataset(rng: &mut Rng) -> Dataset {
    Dataset::ALL[rng.below(6) as usize]
}

/// The seed's scalar best-so-far scan, replicated from public primitives
/// (no lower bounds, so the whole loop is expressible outside the crate):
/// stream window stats, z-normalise, DTW against the running bsf.
fn scalar_best_so_far(reference: &[f64], query_raw: &[f64], w: usize) -> Match {
    let q = znorm(query_raw);
    let n = q.len();
    let mut ws = DtwWorkspace::with_capacity(n);
    let mut stats = WindowStats::new(reference, n);
    let mut bsf = f64::INFINITY;
    let mut best = Match { pos: 0, dist: f64::INFINITY };
    let mut zbuf = Vec::with_capacity(n);
    loop {
        let pos = stats.pos();
        let (mean, std) = stats.mean_std();
        zbuf.clear();
        zbuf.extend(stats.window().iter().map(|&x| znorm_point(x, mean, std)));
        let d = Suite::UcrMonNoLb.dtw(&q, &zbuf, w, bsf, None, &mut ws);
        if d.is_finite() && d < bsf {
            bsf = d;
            best = Match { pos, dist: d };
        }
        if !stats.advance() {
            break;
        }
    }
    best
}

#[test]
fn prop_topk_k1_bit_identical_to_scalar_best_so_far() {
    #[derive(Debug)]
    struct Case {
        dataset: Dataset,
        seed: u64,
    }
    run_prop(
        "topk k=1 == scalar bsf (bitwise)",
        0xB1,
        18,
        |rng| Case { dataset: arb_dataset(rng), seed: rng.next_u64() },
        |c| {
            let r = c.dataset.generate(1200, c.seed);
            let q = extract_queries(&r, 1, 64, 0.1, c.seed ^ 7).remove(0);
            let w = 6;
            let want = scalar_best_so_far(&r, &q, w);
            let mut cnt = Counters::new();
            let got = search_subsequence_topk(&r, &q, w, 1, Suite::UcrMonNoLb, &mut cnt);
            // bit-identical: same position AND the exact same f64
            if got != vec![want] {
                return Err(format!("{got:?} vs {want:?} on {}", c.dataset.name()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_topk_k1_equals_search_subsequence_all_suites() {
    #[derive(Debug)]
    struct Case {
        dataset: Dataset,
        seed: u64,
        suite: Suite,
    }
    run_prop(
        "topk k=1 == search_subsequence",
        0xB2,
        12,
        |rng| Case {
            dataset: arb_dataset(rng),
            seed: rng.next_u64(),
            suite: Suite::ALL[rng.below(4) as usize],
        },
        |c| {
            let r = c.dataset.generate(1500, c.seed);
            let q = extract_queries(&r, 1, 64, 0.1, c.seed ^ 11).remove(0);
            let w = 6;
            let mut c1 = Counters::new();
            let want = search_subsequence(&r, &q, w, c.suite, &mut c1);
            let mut c2 = Counters::new();
            let got = search_subsequence_topk(&r, &q, w, 1, c.suite, &mut c2);
            if got != vec![want] {
                return Err(format!("{got:?} vs {want:?} under {}", c.suite.name()));
            }
            if c1.dtw_calls != c2.dtw_calls || c1.candidates != c2.candidates {
                return Err(format!("counter drift: {c1:?} vs {c2:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_topk_matches_brute_force_for_k_1_5_16() {
    #[derive(Debug)]
    struct Case {
        dataset: Dataset,
        seed: u64,
    }
    run_prop(
        "topk == brute-force prefix",
        0xB3,
        8,
        |rng| Case { dataset: arb_dataset(rng), seed: rng.next_u64() },
        |c| {
            let r = c.dataset.generate(900, c.seed);
            let q = extract_queries(&r, 1, 48, 0.12, c.seed ^ 13).remove(0);
            let w = 5;
            // brute-force ranking of every candidate by (dist, pos)
            let qz = znorm(&q);
            let mut ws = DtwWorkspace::default();
            let mut all: Vec<Match> = (0..=(r.len() - q.len()))
                .map(|pos| {
                    let z = znorm(&r[pos..pos + q.len()]);
                    Match { pos, dist: cdtw_ws(&qz, &z, w, &mut ws) }
                })
                .collect();
            all.sort_by(|a, b| {
                a.dist.partial_cmp(&b.dist).expect("no NaN").then(a.pos.cmp(&b.pos))
            });
            for k in [1usize, 5, 16] {
                let mut cnt = Counters::new();
                let got = search_subsequence_topk(&r, &q, w, k, Suite::UcrMon, &mut cnt);
                if got.len() != k {
                    return Err(format!("k={k}: got {} results", got.len()));
                }
                for (rank, (g, want)) in got.iter().zip(&all).enumerate() {
                    if g.pos != want.pos || (g.dist - want.dist).abs() > 1e-9 {
                        return Err(format!(
                            "k={k} rank={rank}: {g:?} vs {want:?} on {}",
                            c.dataset.name()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_nn1_topk_k1_bit_identical_to_scalar_nn1() {
    // independent scalar oracle: best-first by LB_Keogh, strict < updates
    fn scalar_nn1(query: &[f64], cands: &[Vec<f64>], w: usize) -> (usize, f64) {
        use repro::bounds::envelope::envelopes;
        use repro::bounds::lb_keogh::{reorder, sort_order};
        let (u, l) = envelopes(query, w);
        let order = sort_order(query);
        let uo = reorder(&u, &order);
        let lo = reorder(&l, &order);
        let mut idx: Vec<(usize, f64)> = cands
            .iter()
            .enumerate()
            .map(|(i, c)| {
                let mut lb = 0.0;
                for (kk, &j) in order.iter().enumerate() {
                    let x = c[j];
                    if x > uo[kk] {
                        lb += (x - uo[kk]) * (x - uo[kk]);
                    } else if x < lo[kk] {
                        lb += (x - lo[kk]) * (x - lo[kk]);
                    }
                }
                (i, lb)
            })
            .collect();
        idx.sort_by(|a, b| a.1.partial_cmp(&b.1).expect("no NaN"));
        let mut ws = DtwWorkspace::with_capacity(query.len());
        let mut best = (idx[0].0, f64::INFINITY);
        for &(i, lb) in &idx {
            if lb > best.1 {
                continue;
            }
            let d = Suite::UcrMon.dtw(query, &cands[i], w, best.1, None, &mut ws);
            if d.is_finite() && d < best.1 {
                best = (i, d);
            }
        }
        best
    }

    run_prop(
        "nn1 topk k=1 == scalar nn1 (bitwise)",
        0xB4,
        15,
        |rng| rng.next_u64(),
        |seed| {
            let mut rng = Rng::new(*seed);
            let n = 48;
            let q = znorm(&(0..n).map(|_| rng.normal()).collect::<Vec<_>>());
            let cands: Vec<Vec<f64>> = (0..25)
                .map(|_| znorm(&(0..n).map(|_| rng.normal()).collect::<Vec<_>>()))
                .collect();
            let w = 5;
            let (wi, wd) = scalar_nn1(&q, &cands, w);
            let mut cnt = Counters::new();
            let got = nn1_search(&q, &cands, w, Suite::UcrMon, &mut cnt).expect("nonempty");
            if got.index != wi || got.dist != wd {
                return Err(format!("({}, {}) vs ({wi}, {wd})", got.index, got.dist));
            }
            let top = nn1_topk(&q, &cands, w, 1, Suite::UcrMon, &mut cnt);
            if top.len() != 1 || top[0] != got {
                return Err(format!("{top:?} vs {got:?}"));
            }
            Ok(())
        },
    );
}

/// Acceptance: `Engine::search_batch` with k = 1, batch = 1 reproduces
/// `search_subsequence` exactly — position and distance — on every synth
/// dataset. With one shard the indexed stats table makes the two paths
/// bit-identical; with several shards the result is still exact in
/// position and at f64 round-off in distance.
#[test]
fn engine_batch1_k1_reproduces_search_subsequence_on_all_datasets() {
    for d in Dataset::ALL {
        let r = d.generate(4000, 23);
        let q = extract_queries(&r, 1, 128, 0.1, 29).remove(0);
        let ratio = 0.1;
        let w = window_cells(q.len(), ratio);
        let mut c = Counters::new();
        let want = search_subsequence(&r, &q, w, Suite::UcrMon, &mut c);

        let single = Engine::new(r.clone(), &EngineConfig { shards: 1, ..Default::default() })
            .unwrap();
        let res = single
            .search_batch(&[Query::new(q.clone(), ratio)], 1)
            .unwrap()
            .remove(0);
        assert_eq!(res.matches.len(), 1, "{}", d.name());
        assert_eq!(res.best().pos, want.pos, "{}", d.name());
        assert_eq!(
            res.best().dist.to_bits(),
            want.dist.to_bits(),
            "{}: single-shard indexed scan must be bit-identical",
            d.name()
        );
        assert_eq!(res.counters.candidates, c.candidates, "{}", d.name());

        let sharded = Engine::new(r.clone(), &EngineConfig { shards: 3, ..Default::default() })
            .unwrap();
        let res = sharded.search_batch(&[Query::new(q.clone(), ratio)], 1).unwrap().remove(0);
        assert_eq!(res.best().pos, want.pos, "{} sharded", d.name());
        assert!((res.best().dist - want.dist).abs() < 1e-9, "{} sharded", d.name());
    }
}

#[test]
fn engine_topk_contains_best_and_is_ranked() {
    let r = Dataset::Pamap2.generate(5000, 41);
    let qs: Vec<Query> = extract_queries(&r, 4, 128, 0.1, 43)
        .into_iter()
        .map(|q| Query::new(q, 0.2))
        .collect();
    let engine = Engine::new(r.clone(), &EngineConfig { shards: 2, ..Default::default() })
        .unwrap();
    let k = 16;
    for (q, res) in qs.iter().zip(engine.search_batch(&qs, k).unwrap()) {
        assert_eq!(res.matches.len(), k);
        let mut c = Counters::new();
        let want = search_subsequence(&r, &q.query, window_cells(q.query.len(), 0.2), Suite::UcrMon, &mut c);
        assert_eq!(res.best().pos, want.pos);
        for pair in res.matches.windows(2) {
            assert!(
                pair[0].dist < pair[1].dist
                    || (pair[0].dist == pair[1].dist && pair[0].pos < pair[1].pos)
            );
        }
    }
}

/// Edge cases the serving layer must absorb without panicking or
/// hanging: k beyond the candidate count (short ranked list), a query
/// longer than the reference (empty list), and both at once — across the
/// direct scan, the engine, and every metric.
#[test]
fn degenerate_shapes_return_short_or_empty_ranked_lists() {
    let r = Dataset::Soccer.generate(150, 9);
    let engine = Engine::new(r.clone(), &EngineConfig { shards: 3, ..Default::default() }).unwrap();

    // k far beyond the candidate count: every window, ranked, no hang
    let q = extract_queries(&r, 1, 128, 0.1, 10).remove(0);
    let windows = r.len() - q.len() + 1;
    for metric in [Metric::Cdtw, Metric::Erp { gap: 0.0 }] {
        let res = engine.search_one(&Query::with_metric(q.clone(), 0.1, metric), 500).unwrap();
        assert_eq!(res.matches.len(), windows, "{}", metric.name());
        for pair in res.matches.windows(2) {
            assert!(
                pair[0].dist < pair[1].dist
                    || (pair[0].dist == pair[1].dist && pair[0].pos < pair[1].pos),
                "{}",
                metric.name()
            );
        }
        let mut c = Counters::new();
        let direct = search_subsequence_topk_metric(
            &r,
            &q,
            window_cells(q.len(), 0.1),
            500,
            metric,
            Suite::UcrMon,
            &mut c,
        );
        assert_eq!(direct.len(), windows, "{}", metric.name());
    }

    // query longer than the reference: empty, not an error
    let long: Vec<f64> = (0..300).map(|i| (i as f64 * 0.1).sin()).collect();
    let res = engine.search_batch(&[Query::new(long.clone(), 0.1)], 4).unwrap();
    assert!(res[0].matches.is_empty());
    let mut c = Counters::new();
    assert!(search_subsequence_topk_metric(
        &r,
        &long,
        12,
        4,
        Metric::Twe { nu: 0.05, lambda: 1.0 },
        Suite::UcrMon,
        &mut c
    )
    .is_empty());

    // nn1 with k beyond the candidate count: all candidates ranked
    let cands: Vec<Vec<f64>> = (0..5).map(|i| znorm(&r[i * 20..i * 20 + 40])).collect();
    let got = nn1_topk_metric(
        &znorm(&r[3..43]),
        &cands,
        4,
        99,
        Metric::Msm { cost: 0.5 },
        Suite::UcrMon,
        &mut c,
    );
    assert_eq!(got.len(), 5);
}

#[test]
fn topk_collector_never_regresses_threshold() {
    // the threshold is monotone non-increasing under offers — the property
    // the whole cascade relies on for soundness
    let mut rng = Rng::new(0xB5);
    let mut t = TopK::new(8);
    let mut last = t.threshold();
    for pos in 0..500 {
        t.offer(Match { pos, dist: rng.uniform() * 100.0 });
        let now = t.threshold();
        assert!(now <= last, "threshold rose: {last} -> {now}");
        last = now;
    }
    assert_eq!(t.to_sorted().len(), 8);
}
