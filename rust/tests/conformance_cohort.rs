//! Cohort-scan conformance suite: a cohort-batched
//! [`Engine::search_batch`] must return, for every query, results
//! **bitwise-identical** (same positions, same distance bits) to an
//! independent [`Engine::search_one`] call — across all six synthetic
//! datasets, all six metric kinds, k ∈ {1, 5, 16} and batch sizes
//! {1, 3, 17} — because sharing the reference's strip walk between
//! queries is a memory-bandwidth optimisation, never a semantic one.
//! Per-query thresholds are private; only counter attribution (who paid
//! for a strip's stat load) and retirement (skipping strips a query can
//! provably never win) may differ.
//!
//! Also pins the `search_batch` result-ordering contract: results align
//! index-for-index with the input slice even when cohort grouping
//! reorders evaluation (mixed-length / mixed-metric batches, including a
//! batch that splits into three cohorts, and a property test).

use repro::data::{extract_queries, Dataset};
use repro::distances::metric::Metric;
use repro::index::{BatchMode, Engine, EngineConfig, Query, TopKResult};
use repro::util::proptest::{arb_series, run_prop};

fn assert_bitwise(got: &TopKResult, want: &TopKResult, tag: &str) {
    assert_eq!(got.matches.len(), want.matches.len(), "result count: {tag}");
    for (rank, (x, y)) in got.matches.iter().zip(&want.matches).enumerate() {
        assert_eq!(x.pos, y.pos, "pos at rank {rank}: {tag}");
        assert_eq!(
            x.dist.to_bits(),
            y.dist.to_bits(),
            "dist bits at rank {rank}: {x:?} vs {y:?}: {tag}"
        );
    }
}

#[test]
fn cohort_batches_are_bitwise_identical_to_search_one_everywhere() {
    for ds in Dataset::ALL {
        let r = ds.generate(420, 0xC0 ^ ds as u64);
        let engine =
            Engine::new(r.clone(), &EngineConfig { shards: 2, ..Default::default() }).unwrap();
        assert_eq!(engine.batch_mode(), BatchMode::Cohort);
        let pool = extract_queries(&r, 17, 32, 0.1, 5 + ds as u64);
        for metric in Metric::all_default() {
            for k in [1usize, 5, 16] {
                for b in [1usize, 3, 17] {
                    let tag = format!("{} {} k={k} b={b}", ds.name(), metric.name());
                    let qs: Vec<Query> = pool[..b]
                        .iter()
                        .map(|q| Query::with_metric(q.clone(), 0.1, metric))
                        .collect();
                    let got = engine.search_batch(&qs, k).unwrap();
                    assert_eq!(got.len(), b, "{tag}");
                    let mut saved = 0u64;
                    for (q, g) in qs.iter().zip(&got) {
                        let want = engine.search_one(q, k).unwrap();
                        assert_bitwise(g, &want, &tag);
                        saved += g.counters.strip_stat_loads_saved;
                    }
                    if b > 1 {
                        assert!(saved > 0, "{tag}: cohort must share stat-lane loads");
                    } else {
                        assert_eq!(saved, 0, "{tag}: a singleton takes the solo path");
                    }
                }
            }
        }
    }
}

#[test]
fn mixed_batch_splits_into_three_cohorts_and_aligns_index_for_index() {
    let r = Dataset::Refit.generate(800, 7);
    let engine = Engine::new(r.clone(), &EngineConfig::default()).unwrap();
    let a = extract_queries(&r, 2, 48, 0.1, 11); // cohort 1: qlen 48, cDTW
    let b = extract_queries(&r, 2, 64, 0.1, 12); // cohort 2: qlen 64, cDTW
    let c = extract_queries(&r, 2, 48, 0.1, 13); // cohort 3: qlen 48, MSM
    let msm = Metric::Msm { cost: 0.5 };
    // interleaved on purpose: grouping must reorder evaluation but the
    // results must still land index-for-index
    let qs = vec![
        Query::new(a[0].clone(), 0.1),
        Query::new(b[0].clone(), 0.1),
        Query::with_metric(c[0].clone(), 0.1, msm),
        Query::new(a[1].clone(), 0.1),
        Query::new(b[1].clone(), 0.1),
        Query::with_metric(c[1].clone(), 0.1, msm),
    ];
    let got = engine.search_batch(&qs, 5).unwrap();
    assert_eq!(got.len(), qs.len());
    for (i, (q, g)) in qs.iter().zip(&got).enumerate() {
        let want = engine.search_one(q, 5).unwrap();
        assert_bitwise(g, &want, &format!("mixed batch index {i}"));
    }
    // every query was cohort-served (three cohorts of two): each cohort
    // performed one shared stat load per strip and saved the other
    let total_saved: u64 = got.iter().map(|g| g.counters.strip_stat_loads_saved).sum();
    let total_strips: u64 = got.iter().map(|g| g.counters.cohort_strips).sum();
    assert!(total_saved > 0);
    assert!(total_strips > 0);
    let total_candidates: u64 = got.iter().map(|g| g.counters.candidates).sum();
    // cohorts of two, no retirement: exactly half the stat loads saved
    assert_eq!(total_saved * 2, total_candidates);
}

#[test]
fn planted_exact_ties_resolve_identically_in_cohort_and_solo() {
    // integer-valued reference with an exact duplicate window (same
    // construction as conformance_strip): two candidates share distance
    // bits exactly, and the tie-heavy query retires mid-scan at k <= 2
    let qlen = 32;
    let mut x = 13u64;
    let mut r: Vec<f64> = (0..600)
        .map(|_| {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x % 17) as f64 - 8.0
        })
        .collect();
    let dup: Vec<f64> = r[100..100 + qlen].to_vec();
    r[400..400 + qlen].copy_from_slice(&dup);
    let q: Vec<f64> = r[100..100 + qlen].to_vec();
    let other = extract_queries(&r, 1, qlen, 0.1, 3).remove(0);
    // one shard: exact-tie resolution is deterministic for both paths
    // (the router's cross-shard tie caveat applies to both identically)
    let engine =
        Engine::new(r.clone(), &EngineConfig { shards: 1, ..Default::default() }).unwrap();
    for k in [1usize, 2, 3] {
        let qs = vec![
            Query::new(q.clone(), 0.2),
            Query::new(other.clone(), 0.2),
            Query::new(q.clone(), 0.2),
        ];
        let got = engine.search_batch(&qs, k).unwrap();
        for (i, (qq, g)) in qs.iter().zip(&got).enumerate() {
            let want = engine.search_one(qq, k).unwrap();
            assert_bitwise(g, &want, &format!("planted tie k={k} index {i}"));
        }
        if k <= 2 {
            // the exact-copy queries hit a 0 threshold and retired early
            let retired: u64 = got.iter().map(|g| g.counters.cohort_retired_queries).sum();
            assert!(retired >= 1, "k={k}: exact-match queries must retire");
        }
    }
    // sanity: the two planted copies really do tie at distance 0
    let top2 = engine.search_one(&Query::new(q, 0.2), 2).unwrap();
    assert_eq!(top2.matches[0].pos, 100);
    assert_eq!(top2.matches[1].pos, 400);
    assert_eq!(top2.matches[0].dist.to_bits(), top2.matches[1].dist.to_bits());
}

#[test]
fn exact_match_retirement_is_a_pure_win_across_shards() {
    let r = Dataset::FoG.generate(3000, 9);
    let exact: Vec<f64> = r[120..120 + 128].to_vec();
    let noisy = extract_queries(&r, 1, 128, 0.1, 10).remove(0);
    let engine =
        Engine::new(r.clone(), &EngineConfig { shards: 3, ..Default::default() }).unwrap();
    let qs = vec![Query::new(exact, 0.1), Query::new(noisy, 0.1)];
    let got = engine.search_batch(&qs, 1).unwrap();
    for (q, g) in qs.iter().zip(&got) {
        let want = engine.search_one(q, 1).unwrap();
        assert_bitwise(g, &want, "retirement batch");
    }
    assert_eq!(got[0].matches[0].pos, 120);
    assert_eq!(got[0].matches[0].dist, 0.0);
    assert!(got[0].counters.cohort_retired_queries >= 1);
    // the shard holding the exact match provably skipped its tail strips
    assert!(
        got[0].counters.candidates < (r.len() - 128 + 1) as u64,
        "retired member must not examine every candidate"
    );
    // its partner kept scanning everything
    assert_eq!(got[1].counters.candidates, (r.len() - 128 + 1) as u64);
}

#[test]
fn prop_mixed_length_batches_align_index_for_index() {
    #[derive(Debug)]
    struct Case {
        r: Vec<f64>,
        qs: Vec<(Vec<f64>, f64, Metric)>,
        k: usize,
        shards: usize,
    }
    run_prop(
        "cohort batch == sequential search_one",
        0xC0408,
        10,
        |rng| {
            let r = arb_series(rng, 300, 450);
            let nq = 3 + rng.below(5) as usize;
            let qs = (0..nq)
                .map(|_| {
                    let qlen = [16usize, 24, 32][rng.below(3) as usize];
                    let start = rng.below((r.len() - qlen) as u64) as usize;
                    let mut q: Vec<f64> = r[start..start + qlen].to_vec();
                    for v in q.iter_mut() {
                        *v += 0.05 * rng.normal();
                    }
                    let ratio = [0.1, 0.3][rng.below(2) as usize];
                    let metric = Metric::all_default()[rng.below(Metric::COUNT as u64) as usize];
                    (q, ratio, metric)
                })
                .collect();
            Case { r, qs, k: 1 + rng.below(6) as usize, shards: 1 + rng.below(3) as usize }
        },
        |case| {
            let engine = Engine::new(
                case.r.clone(),
                &EngineConfig { shards: case.shards, ..Default::default() },
            )
            .map_err(|e| e.to_string())?;
            let queries: Vec<Query> = case
                .qs
                .iter()
                .map(|(q, ratio, m)| Query::with_metric(q.clone(), *ratio, *m))
                .collect();
            let got = engine.search_batch(&queries, case.k).map_err(|e| e.to_string())?;
            if got.len() != queries.len() {
                return Err(format!("{} results for {} queries", got.len(), queries.len()));
            }
            for (i, (q, g)) in queries.iter().zip(&got).enumerate() {
                let want = engine.search_one(q, case.k).map_err(|e| e.to_string())?;
                if g.matches.len() != want.matches.len() {
                    return Err(format!(
                        "index {i}: {} vs {} matches",
                        g.matches.len(),
                        want.matches.len()
                    ));
                }
                for (x, y) in g.matches.iter().zip(&want.matches) {
                    if x.pos != y.pos || x.dist.to_bits() != y.dist.to_bits() {
                        return Err(format!("index {i} diverged: {x:?} vs {y:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}
