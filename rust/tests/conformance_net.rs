//! TCP front-end conformance (`--features fault-inject`): hostile-client
//! behaviour over real loopback sockets. Pins the contract of
//! `rust/src/net/`:
//!
//! * a slow-loris sender is cut off by the read budget without pinning a
//!   thread — the service keeps serving other connections;
//! * an oversized frame is answered with a typed `frame_too_large` error
//!   the moment the cap is crossed, never buffered;
//! * a mid-frame disconnect poisons nothing;
//! * quota exhaustion sheds with `retry_after_ms` and zero scan work,
//!   and honouring the backoff is sufficient for readmission;
//! * graceful drain completes every in-flight query with a response
//!   byte-identical to in-process `Service::handle_line` (wall-clock
//!   timing fields aside);
//! * the counter conservation identities survive a faulty session with
//!   the `conn.*` / `accept.*` sites armed.
//!
//! The fault registry is process-global, so every test here serialises
//! on [`FAULT_LOCK`] (same discipline as `conformance_faults.rs`).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use repro::coordinator::protocol::{ErrorKind, ErrorResponse, QueryRequest, QueryResponse};
use repro::coordinator::{Service, ServiceConfig};
use repro::data::{extract_queries, Dataset};
use repro::distances::metric::Metric;
use repro::fault;
use repro::metrics::Counters;
use repro::net::{NetConfig, NetServer};
use repro::search::suite::Suite;
use repro::util::json::Json;

static FAULT_LOCK: Mutex<()> = Mutex::new(());

/// Take the suite-wide lock (poison-tolerant) and start from a disarmed
/// registry. Every test takes it — even the ones that arm nothing —
/// because an armed site from a concurrent test would otherwise fire
/// inside the wrong session.
fn armed_section() -> MutexGuard<'static, ()> {
    let guard = FAULT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    fault::reset();
    guard
}

fn service(shards: usize, window: usize) -> Arc<Service> {
    let r = Dataset::Ecg.generate(3000, 41);
    Arc::new(
        Service::new(
            r,
            &ServiceConfig {
                shards,
                batch_window: window,
                batch_deadline_ms: if window > 1 { 5 } else { 0 },
                ..Default::default()
            },
        )
        .expect("service"),
    )
}

fn request_line(id: u64) -> String {
    let r = Dataset::Ecg.generate(3000, 41);
    let q = extract_queries(&r, 1, 96, 0.1, 42 + id).remove(0);
    QueryRequest {
        id,
        query: q,
        window_ratio: 0.1,
        suite: Suite::UcrMon,
        k: 2,
        metric: Metric::Cdtw,
        deadline_ms: None,
        tenant: None,
    }
    .to_json()
}

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn send(&mut self, line: &str) {
        self.stream.write_all(line.as_bytes()).expect("send");
        self.stream.write_all(b"\n").expect("send newline");
    }

    fn recv(&mut self) -> String {
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("recv");
        line.trim_end().to_string()
    }

    /// Next read yields end-of-stream (the server closed the session).
    fn expect_eof(&mut self) {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).unwrap_or(0);
        assert_eq!(n, 0, "expected EOF, got {line:?}");
    }
}

/// Strip the wall-clock fields (`latency_ms`, `queue_ms`) that cannot
/// match across serving paths; everything else must be byte-identical.
fn normalized(line: &str) -> String {
    match Json::parse(line).expect("valid response json") {
        Json::Obj(mut m) => {
            m.remove("latency_ms");
            m.remove("queue_ms");
            Json::Obj(m).to_string()
        }
        other => other.to_string(),
    }
}

/// The registry-wide conservation identities (same as the fault suite):
/// they must hold across net-layer faults too, because a dropped
/// connection or shed query must flush either all of a scan's counters
/// or none of them.
fn assert_conserved(c: &Counters) {
    assert_eq!(
        c.candidates,
        c.lb_kim_prunes
            + c.lb_keogh_eq_prunes
            + c.lb_keogh_ec_prunes
            + c.lb_improved_prunes
            + c.xla_prunes
            + c.dtw_calls,
        "candidate conservation broke: {c:?}"
    );
    assert_eq!(
        c.dtw_calls,
        c.dtw_abandons + c.dtw_completions,
        "dtw outcome conservation broke: {c:?}"
    );
}

#[test]
fn slow_loris_is_cut_by_the_read_budget() {
    let _lock = armed_section();
    let svc = service(2, 1);
    let cfg = NetConfig {
        read_timeout_ms: 150,
        idle_timeout_ms: 60_000,
        ..NetConfig::default()
    };
    let server = NetServer::start(Arc::clone(&svc), "127.0.0.1:0", cfg).unwrap();
    let mut loris = Client::connect(server.local_addr());
    // half a frame, then silence: the read budget must cut the session
    loris.stream.write_all(b"{\"id\":1,\"query\":[0.1,").unwrap();
    let t0 = Instant::now();
    loris.expect_eof();
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "cut took {:?} — the budget did not fire",
        t0.elapsed()
    );
    let snap = svc.metrics();
    assert_eq!(snap.counters.conn_read_timeouts, 1);
    // the thread the loris held is free again: a well-behaved client is
    // served immediately on a fresh connection
    let mut ok = Client::connect(server.local_addr());
    ok.send(&request_line(2));
    assert_eq!(QueryResponse::from_json(&ok.recv()).unwrap().id, 2);
    assert_conserved(&svc.metrics().counters);
    server.drain();
}

#[test]
fn oversized_frame_is_refused_at_the_cap_not_buffered() {
    let _lock = armed_section();
    let svc = service(1, 1);
    let cfg = NetConfig { max_frame_bytes: 256, ..NetConfig::default() };
    let server = NetServer::start(Arc::clone(&svc), "127.0.0.1:0", cfg).unwrap();

    // a newline-terminated frame over the cap answers the typed error…
    let mut c = Client::connect(server.local_addr());
    c.send(&format!("{{\"id\":9,\"pad\":\"{}\"}}", "x".repeat(400)));
    let err = ErrorResponse::from_json(&c.recv()).expect("typed reply");
    assert_eq!(err.kind, Some(ErrorKind::FrameTooLarge));
    assert_eq!(err.id, None, "an unbuffered frame has no id to echo");
    c.expect_eof();

    // …and a newline-free flood is refused the moment the cap is
    // crossed, while the sender is still mid-flood
    let mut flood = Client::connect(server.local_addr());
    flood.stream.write_all(&[b'z'; 8 * 1024]).unwrap();
    let err = ErrorResponse::from_json(&flood.recv()).expect("typed reply mid-flood");
    assert_eq!(err.kind, Some(ErrorKind::FrameTooLarge));
    flood.expect_eof();

    // no scan work happened for either; the service is unharmed
    assert_eq!(svc.queries_served(), 0);
    assert_conserved(&svc.metrics().counters);
    let mut ok = Client::connect(server.local_addr());
    ok.send(&request_line(1));
    assert_eq!(QueryResponse::from_json(&ok.recv()).unwrap().id, 1);
    server.drain();
}

#[test]
fn mid_frame_disconnect_poisons_nothing() {
    let _lock = armed_section();
    let svc = service(2, 1);
    let server = NetServer::start(Arc::clone(&svc), "127.0.0.1:0", NetConfig::default()).unwrap();
    for _ in 0..3 {
        let mut c = Client::connect(server.local_addr());
        // half a frame, then the client vanishes
        c.stream.write_all(b"{\"id\":7,\"query\":[0.25,0.5").unwrap();
        drop(c);
    }
    // the service keeps serving, bitwise-correctly, on both a fresh
    // connection and the in-process path
    let mut ok = Client::connect(server.local_addr());
    let line = request_line(3);
    ok.send(&line);
    let over_wire = ok.recv();
    assert_eq!(normalized(&over_wire), normalized(&svc.handle_line(&line)));
    assert_conserved(&svc.metrics().counters);
    server.drain();
}

#[test]
fn quota_exhaustion_sheds_before_scan_work_and_backoff_readmits() {
    let _lock = armed_section();
    let svc = service(1, 1);
    let cfg = NetConfig { quota_rate: 20.0, quota_burst: 2.0, ..NetConfig::default() };
    let server = NetServer::start(Arc::clone(&svc), "127.0.0.1:0", cfg).unwrap();
    let mut c = Client::connect(server.local_addr());
    let line = request_line(0).replacen('{', "{\"tenant\":\"acme\",", 1);
    for id in 0..2u64 {
        c.send(&line.replace("\"id\":0", &format!("\"id\":{id}")));
        assert!(QueryResponse::from_json(&c.recv()).is_ok(), "burst admitted");
    }
    let candidates_before = svc.metrics().counters.candidates;
    // the burst is spent: the next query sheds with the backoff horizon,
    // before any scan work
    c.send(&line.replace("\"id\":0", "\"id\":40"));
    let shed = ErrorResponse::from_json(&c.recv()).expect("typed shed");
    assert_eq!(shed.kind, Some(ErrorKind::Quota));
    assert_eq!(shed.id, Some(40));
    let retry_ms = shed.retry_after_ms.expect("shed carries retry_after_ms");
    assert!(retry_ms >= 1);
    let snap = svc.metrics();
    assert_eq!(snap.counters.quota_shed_queries, 1);
    assert_eq!(snap.counters.candidates, candidates_before, "shed did zero scan work");
    // honouring the advertised backoff is sufficient for readmission
    std::thread::sleep(Duration::from_millis(retry_ms + 20));
    c.send(&line.replace("\"id\":0", "\"id\":41"));
    assert_eq!(QueryResponse::from_json(&c.recv()).unwrap().id, 41);
    assert_conserved(&svc.metrics().counters);
    server.drain();
}

#[test]
fn drain_under_load_answers_in_flight_byte_identical() {
    let _lock = armed_section();
    let svc = service(2, 1);
    let server = NetServer::start(Arc::clone(&svc), "127.0.0.1:0", NetConfig::default()).unwrap();
    let mut c = Client::connect(server.local_addr());
    let line = request_line(11);
    // hold the frame in the reader for 300ms so the drain below starts
    // while the query is demonstrably still in flight
    fault::arm_stall(fault::CONN_STALL, 300, 1);
    c.send(&line);
    // give the reader time to pick the frame up and enter the stall
    std::thread::sleep(Duration::from_millis(60));
    server.drain();
    // the stalled query was finished under drain and its response
    // delivered before the connection closed — byte-identical to the
    // in-process path, modulo wall clocks
    let over_wire = c.recv();
    assert_eq!(QueryResponse::from_json(&over_wire).unwrap().id, 11);
    assert_eq!(normalized(&over_wire), normalized(&svc.handle_line(&line)));
    c.expect_eof();
    assert_conserved(&svc.metrics().counters);
    fault::reset();
}

#[test]
fn faulty_session_keeps_counters_conserved() {
    let _lock = armed_section();
    let svc = service(2, 1);
    let cfg = NetConfig { max_conns: 2, ..NetConfig::default() };
    let server = NetServer::start(Arc::clone(&svc), "127.0.0.1:0", cfg).unwrap();

    // an injected transient accept failure: the socket is dropped
    // without a reply, and nothing is registered for it
    fault::arm(fault::ACCEPT_FAIL, 1);
    let mut dropped_at_accept = Client::connect(server.local_addr());
    dropped_at_accept.expect_eof();

    // an injected mid-session vanish: the first parsed frame closes the
    // connection as if the client disappeared — no reply, no poison
    fault::arm(fault::CONN_DROP, 1);
    let mut vanished = Client::connect(server.local_addr());
    vanished.send(&request_line(1));
    vanished.expect_eof();

    // a normal session through the same server still serves
    let mut ok = Client::connect(server.local_addr());
    for id in 2..4u64 {
        ok.send(&request_line(id));
        assert_eq!(QueryResponse::from_json(&ok.recv()).unwrap().id, id);
    }

    let snap = svc.metrics();
    // the accept-failed socket was never registered; the other two were
    assert_eq!(snap.counters.conns_accepted, 2);
    assert_eq!(snap.counters.conns_rejected, 0);
    assert_eq!(snap.counters.quota_shed_queries, 0);
    assert_eq!(svc.queries_served(), 2);
    assert_conserved(&snap.counters);
    server.drain();
    fault::reset();
}
