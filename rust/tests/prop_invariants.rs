//! Property tests on the system's core invariants (DESIGN.md §6), using
//! the in-tree harness (`repro::util::proptest`) since the proptest crate
//! is unavailable offline. Every failure prints seed + case + input.

use repro::bounds::envelope::{envelopes, envelopes_naive};
use repro::bounds::lb_keogh::{cumulate_bound, lb_keogh_ec, lb_keogh_eq, reorder, sort_order};
use repro::bounds::lb_kim::lb_kim_hierarchy;
use repro::data::rng::Rng;
use repro::data::{extract_queries, Dataset};
use repro::distances::dtw::{cdtw, dtw_oracle};
use repro::distances::dtw_ea::dtw_ea;
use repro::distances::eap_dtw::eap_cdtw;
use repro::distances::metric::Metric;
use repro::distances::pruned_dtw::pruned_cdtw;
use repro::distances::DtwWorkspace;
use repro::index::ref_index::BucketStats;
use repro::metrics::Counters;
use repro::norm::znorm::{stats, znorm, znorm_point, WindowStats};
use repro::search::cohort::{scan_cohort_topk, CohortMember, CohortPool, CohortScratch};
use repro::search::subsequence::{
    scan, search_subsequence, search_subsequence_topk_metric,
    search_subsequence_topk_metric_mode, DataEnvelopes, Match, QueryContext, ScanMode,
};
use repro::search::suite::Suite;
use repro::util::proptest::{arb_series, arb_window, run_prop};

const CASES: usize = 120;

#[derive(Debug)]
struct Pair {
    a: Vec<f64>,
    b: Vec<f64>,
    w: usize,
}

fn arb_pair(rng: &mut Rng) -> Pair {
    let a = arb_series(rng, 1, 48);
    let b = arb_series(rng, 1, 48);
    let w = arb_window(rng, a.len().max(b.len()));
    Pair { a, b, w }
}

#[test]
fn prop_eap_equals_cdtw_with_infinite_ub() {
    run_prop("eap == cdtw @ ub=inf", 0xA1, CASES, arb_pair, |p| {
        let mut ws = DtwWorkspace::default();
        let want = cdtw(&p.a, &p.b, p.w);
        let got = eap_cdtw(&p.a, &p.b, p.w, f64::INFINITY, None, &mut ws);
        if (got - want).abs() > 1e-9 && got != want {
            return Err(format!("{got} != {want}"));
        }
        Ok(())
    });
}

#[test]
fn prop_eap_exact_at_tie_and_abandons_below() {
    run_prop("eap tie/below", 0xA2, CASES, arb_pair, |p| {
        let mut ws = DtwWorkspace::default();
        let want = cdtw(&p.a, &p.b, p.w);
        if !want.is_finite() {
            return Ok(());
        }
        let tie = eap_cdtw(&p.a, &p.b, p.w, want, None, &mut ws);
        if (tie - want).abs() > 1e-9 {
            return Err(format!("tie broken: {tie} != {want}"));
        }
        if want > 0.0 {
            let below = eap_cdtw(&p.a, &p.b, p.w, want * (1.0 - 1e-12) - 1e-300, None, &mut ws);
            // EAP abandons *reliably* (this is the paper's headline claim)
            if below.is_finite() && below < want {
                return Err(format!("underestimate {below} < {want}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_all_variants_sandwich_the_truth() {
    run_prop("variants never underestimate", 0xA3, CASES, arb_pair, |p| {
        let mut ws = DtwWorkspace::default();
        let want = cdtw(&p.a, &p.b, p.w);
        if !want.is_finite() {
            return Ok(());
        }
        let mut rng = Rng::new(p.a.len() as u64 * 31 + p.b.len() as u64);
        let ub = want * rng.range(0.25, 1.5);
        if p.a.len() == p.b.len() {
            let ea = dtw_ea(&p.a, &p.b, p.w, ub, None, &mut ws);
            if ea.is_finite() && ea < want - 1e-9 {
                return Err(format!("dtw_ea underestimates: {ea} < {want}"));
            }
        }
        let pr = pruned_cdtw(&p.a, &p.b, p.w, ub, None, &mut ws);
        if pr.is_finite() && pr < want - 1e-9 {
            return Err(format!("pruned underestimates: {pr} < {want}"));
        }
        let eap = eap_cdtw(&p.a, &p.b, p.w, ub, None, &mut ws);
        if eap.is_finite() && eap < want - 1e-9 {
            return Err(format!("eap underestimates: {eap} < {want}"));
        }
        // and above-ub results from EAP are exactly +inf or exact
        if eap.is_finite() && (eap - want).abs() > 1e-9 {
            return Err(format!("eap inexact: {eap} vs {want}"));
        }
        Ok(())
    });
}

#[test]
fn prop_window_monotonicity() {
    run_prop("cdtw monotone in w", 0xA4, CASES, arb_pair, |p| {
        let d1 = cdtw(&p.a, &p.b, p.w);
        let d2 = cdtw(&p.a, &p.b, p.w + 1);
        if d2 > d1 + 1e-9 {
            return Err(format!("w={} -> {d1}, w+1 -> {d2}", p.w));
        }
        Ok(())
    });
}

#[test]
fn prop_envelopes_match_naive_and_bound_dtw() {
    #[derive(Debug)]
    struct Env {
        q: Vec<f64>,
        c: Vec<f64>,
        w: usize,
    }
    run_prop(
        "envelopes + lb_keogh <= dtw",
        0xA5,
        60,
        |rng| {
            let n = 4 + rng.below(40) as usize;
            Env {
                q: znorm(&(0..n).map(|_| rng.normal()).collect::<Vec<_>>()),
                c: (0..n).map(|_| rng.normal() * 2.0 + 0.5).collect(),
                w: arb_window(rng, n / 2),
            }
        },
        |e| {
            let (u, l) = envelopes(&e.q, e.w);
            let (nu, nl) = envelopes_naive(&e.q, e.w);
            if u != nu || l != nl {
                return Err("lemire != naive".into());
            }
            let (mean, std) = stats(&e.c);
            let zc: Vec<f64> = e.c.iter().map(|&x| znorm_point(x, mean, std)).collect();
            let d = dtw_oracle(&e.q, &zc, Some(e.w));
            let order = sort_order(&e.q);
            let uo = reorder(&u, &order);
            let lo = reorder(&l, &order);
            let mut cb = vec![0.0; e.q.len()];
            let lb1 = lb_keogh_eq(&order, &uo, &lo, &e.c, mean, std, f64::INFINITY, &mut cb);
            if lb1 > d + 1e-6 {
                return Err(format!("lb_eq {lb1} > dtw {d}"));
            }
            let (du, dl) = envelopes(&e.c, e.w);
            let qo = reorder(&e.q, &order);
            let lb2 = lb_keogh_ec(&order, &qo, &du, &dl, mean, std, f64::INFINITY, &mut cb);
            if lb2 > d + 1e-6 {
                return Err(format!("lb_ec {lb2} > dtw {d}"));
            }
            let kim = lb_kim_hierarchy(&e.q, &e.c, mean, std, f64::INFINITY);
            if kim > d + 1e-6 {
                return Err(format!("lb_kim {kim} > dtw {d}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cb_tightened_dtw_stays_exact_below_ub() {
    #[derive(Debug)]
    struct Case {
        q: Vec<f64>,
        c: Vec<f64>,
        w: usize,
    }
    run_prop(
        "cb tightening preserves exactness",
        0xA6,
        60,
        |rng| {
            let n = 8 + rng.below(40) as usize;
            Case {
                q: znorm(&(0..n).map(|_| rng.normal()).collect::<Vec<_>>()),
                c: (0..n).map(|_| rng.normal()).collect(),
                w: 1 + arb_window(rng, n / 2),
            }
        },
        |e| {
            let (mean, std) = stats(&e.c);
            let zc: Vec<f64> = e.c.iter().map(|&x| znorm_point(x, mean, std)).collect();
            let exact = cdtw(&e.q, &zc, e.w);
            let (u, l) = envelopes(&e.q, e.w);
            let order = sort_order(&e.q);
            let uo = reorder(&u, &order);
            let lo = reorder(&l, &order);
            let mut cb = vec![0.0; e.q.len()];
            lb_keogh_eq(&order, &uo, &lo, &e.c, mean, std, f64::INFINITY, &mut cb);
            let mut cbc = Vec::new();
            cumulate_bound(&cb, &mut cbc);
            let mut ws = DtwWorkspace::default();
            // ub = exact: must stay exact with cb plugged in, for every core
            for (name, got) in [
                ("eap", eap_cdtw(&e.q, &zc, e.w, exact, Some(&cbc), &mut ws)),
                ("pruned", pruned_cdtw(&e.q, &zc, e.w, exact, Some(&cbc), &mut ws)),
                ("ea", dtw_ea(&e.q, &zc, e.w, exact, Some(&cbc), &mut ws)),
            ] {
                if (got - exact).abs() > 1e-9 {
                    return Err(format!("{name}: {got} != {exact}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_streaming_stats_equal_batch_stats() {
    run_prop(
        "windowstats == batch",
        0xA7,
        40,
        |rng| {
            let len = 50 + rng.below(200) as usize;
            let n = 5 + rng.below(30) as usize;
            let s: Vec<f64> = (0..len).map(|_| rng.normal() * 10.0).collect();
            (s, n.min(len))
        },
        |(s, n)| {
            let mut wsx = WindowStats::new(s, *n);
            loop {
                let (m1, d1) = wsx.mean_std();
                let (m2, d2) = stats(wsx.window());
                if (m1 - m2).abs() > 1e-7 || (d1 - d2).abs() > 1e-7 {
                    return Err(format!("pos {}: ({m1},{d1}) vs ({m2},{d2})", wsx.pos()));
                }
                if !wsx.advance() {
                    return Ok(());
                }
            }
        },
    );
}

#[test]
fn prop_sharded_scan_equals_full_scan() {
    #[derive(Debug)]
    struct Case {
        seed: u64,
        shards: usize,
        dataset: Dataset,
    }
    run_prop(
        "shard == full",
        0xA8,
        12,
        |rng| Case {
            seed: rng.next_u64(),
            shards: 1 + rng.below(6) as usize,
            dataset: Dataset::ALL[rng.below(6) as usize],
        },
        |c| {
            let r = c.dataset.generate(1500, c.seed);
            let q = extract_queries(&r, 1, 64, 0.1, c.seed ^ 5).remove(0);
            let w = 6;
            let suite = Suite::UcrMon;
            let mut cnt = Counters::new();
            let want = search_subsequence(&r, &q, w, suite, &mut cnt);
            let denv = DataEnvelopes::new(&r, w);
            let total = r.len() - q.len() + 1;
            let mut best: Option<repro::search::subsequence::Match> = None;
            let mut bsf = f64::INFINITY;
            let mut cnt2 = Counters::new();
            for s in 0..c.shards {
                let (a, b) = (s * total / c.shards, (s + 1) * total / c.shards);
                let mut ctx = QueryContext::new(&q, w);
                if let Some(m) = scan(&r, a, b, &mut ctx, Some(&denv), suite, bsf, &mut cnt2) {
                    if best.is_none() || m.dist < best.unwrap().dist {
                        best = Some(m);
                        bsf = m.dist;
                    }
                }
            }
            let got = best.ok_or("no match")?;
            if got.pos != want.pos || (got.dist - want.dist).abs() > 1e-9 {
                return Err(format!("{got:?} vs {want:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_topk_any_metric_equals_bruteforce_ranking() {
    // top-k search under any metric == brute-force sort of per-window
    // exact (naive-oracle) distances, for k in {1, 5, 16}
    #[derive(Debug)]
    struct Case {
        seed: u64,
        metric: Metric,
        dataset: Dataset,
    }
    run_prop(
        "metric topk == brute prefix",
        0xAA,
        10,
        |rng| Case {
            seed: rng.next_u64(),
            metric: Metric::all_default()[rng.below(Metric::COUNT as u64) as usize],
            dataset: Dataset::ALL[rng.below(6) as usize],
        },
        |c| {
            let r = c.dataset.generate(420, c.seed);
            let q = extract_queries(&r, 1, 32, 0.12, c.seed ^ 3).remove(0);
            let w = 4;
            let qz = znorm(&q);
            let weff = c.metric.effective_window(qz.len(), w);
            let exact_at = |pos: usize| {
                let cz = znorm(&r[pos..pos + q.len()]);
                c.metric.exact(&qz, &cz, weff)
            };
            let mut all: Vec<(usize, f64)> =
                (0..=(r.len() - q.len())).map(|pos| (pos, exact_at(pos))).collect();
            all.sort_by(|a, b| a.1.partial_cmp(&b.1).expect("no NaN").then(a.0.cmp(&b.0)));
            for k in [1usize, 5, 16] {
                let mut cnt = Counters::new();
                let got =
                    search_subsequence_topk_metric(&r, &q, w, k, c.metric, Suite::UcrMon, &mut cnt);
                if got.len() != k {
                    return Err(format!("{} k={k}: got {}", c.metric.name(), got.len()));
                }
                for (rank, (g, want)) in got.iter().zip(&all).enumerate() {
                    if (g.dist - want.1).abs() > 1e-9 {
                        return Err(format!(
                            "{} on {} k={k} rank={rank}: dist {} vs {}",
                            c.metric.name(),
                            c.dataset.name(),
                            g.dist,
                            want.1
                        ));
                    }
                    // position must match, except across an exact fp tie,
                    // where any candidate at the tied distance is valid
                    if g.pos != want.0 && (exact_at(g.pos) - want.1).abs() > 1e-9 {
                        return Err(format!(
                            "{} on {} k={k} rank={rank}: pos {} vs {}",
                            c.metric.name(),
                            c.dataset.name(),
                            g.pos,
                            want.0
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cdtw_dispatch_k1_bit_identical_to_scalar_cascade_loop() {
    // the pre-refactor scalar path, replicated from public primitives:
    // full UCR cascade + cb tightening + suite DTW core + strict-< bsf.
    // The metric dispatch layer with Metric::Cdtw must reproduce it down
    // to the f64 bits.
    fn scalar_cascade_search(reference: &[f64], query_raw: &[f64], w: usize) -> Match {
        let q = znorm(query_raw);
        let n = q.len();
        let order = sort_order(&q);
        let (u, l) = envelopes(&q, w);
        let uo = reorder(&u, &order);
        let lo = reorder(&l, &order);
        let qo = reorder(&q, &order);
        let (du, dl) = envelopes(reference, w);
        let mut cb1 = vec![0.0; n];
        let mut cb2 = vec![0.0; n];
        let mut cbc = vec![0.0; n + 1];
        let mut zbuf: Vec<f64> = Vec::with_capacity(n);
        let mut ws = DtwWorkspace::with_capacity(n);
        let mut stats = WindowStats::new(reference, n);
        let mut best = Match { pos: 0, dist: f64::INFINITY };
        loop {
            let pos = stats.pos();
            let window = stats.window();
            let (mean, std) = stats.mean_std();
            let bsf = best.dist;
            // one candidate through the full cascade; `None` = pruned
            let d = (|| {
                if lb_kim_hierarchy(&q, window, mean, std, bsf) > bsf {
                    return None;
                }
                let lb1 = lb_keogh_eq(&order, &uo, &lo, window, mean, std, bsf, &mut cb1);
                if lb1 > bsf {
                    return None;
                }
                let lb2 = lb_keogh_ec(
                    &order,
                    &qo,
                    &du[pos..pos + n],
                    &dl[pos..pos + n],
                    mean,
                    std,
                    bsf,
                    &mut cb2,
                );
                if lb2 > bsf {
                    return None;
                }
                let src = if lb2 > lb1 { &cb2 } else { &cb1 };
                cumulate_bound(src, &mut cbc);
                zbuf.clear();
                zbuf.extend(window.iter().map(|&x| znorm_point(x, mean, std)));
                Some(Suite::UcrMon.dtw(&q, &zbuf, w, bsf, Some(&cbc), &mut ws))
            })();
            if let Some(d) = d {
                if d.is_finite() && d < bsf {
                    best = Match { pos, dist: d };
                }
            }
            if !stats.advance() {
                break;
            }
        }
        best
    }

    #[derive(Debug)]
    struct Case {
        seed: u64,
        dataset: Dataset,
    }
    run_prop(
        "cdtw dispatch k=1 == scalar cascade (bitwise)",
        0xAB,
        10,
        |rng| Case { seed: rng.next_u64(), dataset: Dataset::ALL[rng.below(6) as usize] },
        |c| {
            let r = c.dataset.generate(1200, c.seed);
            let q = extract_queries(&r, 1, 64, 0.1, c.seed ^ 17).remove(0);
            let w = 6;
            let want = scalar_cascade_search(&r, &q, w);
            let mut cnt = Counters::new();
            let got =
                search_subsequence_topk_metric(&r, &q, w, 1, Metric::Cdtw, Suite::UcrMon, &mut cnt);
            if got.len() != 1 {
                return Err(format!("got {} results", got.len()));
            }
            if got[0].pos != want.pos || got[0].dist.to_bits() != want.dist.to_bits() {
                return Err(format!(
                    "{got:?} vs {want:?} on {} (bitwise)",
                    c.dataset.name()
                ));
            }
            // the whole scan was tallied as cDTW kernel work
            if cnt.metric_calls[Metric::Cdtw.index()] != cnt.dtw_calls {
                return Err(format!("per-metric tally drift: {cnt:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_counter_conservation_every_mode_and_metric() {
    // the observability plane's accounting invariants: every candidate is
    // accounted for exactly once (pruned at one cascade stage or handed
    // to the kernel), every kernel call either abandoned or completed,
    // and the per-metric tallies sum to the aggregates — across both scan
    // front-ends, the cohort path, random suites and all six metrics.
    // tools/bench_diff.py enforces the same identities on exported
    // snapshots; this test is why it may.
    fn check(c: &Counters, what: &str) -> Result<(), String> {
        let pruned = c.lb_kim_prunes
            + c.lb_keogh_eq_prunes
            + c.lb_keogh_ec_prunes
            + c.lb_improved_prunes
            + c.xla_prunes;
        if c.candidates != pruned + c.dtw_calls {
            return Err(format!(
                "{what}: candidates {} != prunes {pruned} + dtw_calls {}",
                c.candidates, c.dtw_calls
            ));
        }
        if c.dtw_calls != c.dtw_abandons + c.dtw_completions {
            return Err(format!(
                "{what}: dtw_calls {} != abandons {} + completions {}",
                c.dtw_calls, c.dtw_abandons, c.dtw_completions
            ));
        }
        let mcalls: u64 = c.metric_calls.iter().sum();
        let mabandons: u64 = c.metric_abandons.iter().sum();
        if mcalls != c.dtw_calls || mabandons != c.dtw_abandons {
            return Err(format!(
                "{what}: per-metric tallies drift: {mcalls}/{mabandons} vs {}/{}",
                c.dtw_calls, c.dtw_abandons
            ));
        }
        if c.cost_model_rebuilds != 0 {
            return Err(format!("{what}: {} cost-model rebuilds", c.cost_model_rebuilds));
        }
        Ok(())
    }

    #[derive(Debug)]
    struct Case {
        seed: u64,
        metric: Metric,
        dataset: Dataset,
        mode: ScanMode,
        suite: Suite,
    }
    run_prop(
        "counter conservation",
        0xAC,
        18,
        |rng| Case {
            seed: rng.next_u64(),
            metric: Metric::all_default()[rng.below(Metric::COUNT as u64) as usize],
            dataset: Dataset::ALL[rng.below(6) as usize],
            mode: if rng.below(2) == 0 { ScanMode::Scalar } else { ScanMode::Strip },
            suite: Suite::ALL[rng.below(4) as usize],
        },
        |c| {
            let r = c.dataset.generate(900, c.seed);
            let qlen = 64;
            let w = 6;
            let q = extract_queries(&r, 1, qlen, 0.1, c.seed ^ 11).remove(0);
            let mut cnt = Counters::new();
            let got = search_subsequence_topk_metric_mode(
                &r, &q, w, 3, c.metric, c.suite, c.mode, &mut cnt,
            );
            if got.is_empty() {
                return Err("no matches".into());
            }
            check(
                &cnt,
                &format!("{:?}/{}/{}", c.mode, c.metric.name(), c.suite.name()),
            )?;
            // the cohort path preserves the same conservation per member
            let queries = extract_queries(&r, 3, qlen, 0.1, c.seed ^ 13);
            let stats = BucketStats::build(&r, qlen);
            let weff = c.metric.effective_window(qlen, w);
            let denv = c
                .metric
                .wants_data_envelopes(c.suite)
                .then(|| DataEnvelopes::new(&r, weff));
            let mut members: Vec<CohortMember> = queries
                .iter()
                .map(|q| {
                    CohortMember::new(QueryContext::with_metric_pooled(q, w, c.metric), 3)
                })
                .collect();
            let mut scratch = CohortScratch::default();
            let mut pool = CohortPool::default();
            scan_cohort_topk(
                &r,
                0,
                r.len() - qlen + 1,
                &mut members,
                &stats,
                denv.as_ref(),
                c.suite,
                1024,
                &mut scratch,
                &mut pool,
            );
            for (i, m) in members.iter().enumerate() {
                check(
                    &m.counters,
                    &format!("cohort[{i}]/{}/{}", c.metric.name(), c.suite.name()),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_search_result_is_true_minimum() {
    // randomised small-scale end-to-end: the suite result equals the
    // brute-force minimum over all positions
    #[derive(Debug)]
    struct Case {
        seed: u64,
        suite: Suite,
    }
    run_prop(
        "search == brute min",
        0xA9,
        10,
        |rng| Case {
            seed: rng.next_u64(),
            suite: Suite::ALL[rng.below(4) as usize],
        },
        |c| {
            let r = Dataset::Ecg.generate(800, c.seed);
            let q = extract_queries(&r, 1, 48, 0.15, c.seed ^ 9).remove(0);
            let w = 5;
            let mut cnt = Counters::new();
            let got = search_subsequence(&r, &q, w, c.suite, &mut cnt);
            let qz = znorm(&q);
            let mut best = (0usize, f64::INFINITY);
            for pos in 0..=(r.len() - q.len()) {
                let cz = znorm(&r[pos..pos + q.len()]);
                let d = cdtw(&qz, &cz, w);
                if d < best.1 {
                    best = (pos, d);
                }
            }
            if got.pos != best.0 || (got.dist - best.1).abs() > 1e-9 {
                return Err(format!("{got:?} vs {best:?} under {}", c.suite.name()));
            }
            Ok(())
        },
    );
}
