//! Coordinator integration: the threaded service returns exactly what the
//! single-threaded search returns, for every suite, under concurrency; the
//! wire protocol round-trips; shard arithmetic covers every candidate.

use std::sync::Arc;

use repro::coordinator::router::shard_ranges;
use repro::coordinator::{QueryRequest, QueryResponse, Service, ServiceConfig};
use repro::data::{extract_queries, Dataset};
use repro::distances::metric::Metric;
use repro::metrics::Counters;
use repro::search::subsequence::{search_subsequence, window_cells, Match, ScanMode};
use repro::search::suite::Suite;

fn service(r: &[f64], shards: usize) -> Service {
    Service::new(r.to_vec(), &ServiceConfig { shards, ..Default::default() }).unwrap()
}

#[test]
fn service_equals_direct_search_for_all_scalar_suites() {
    let r = Dataset::Refit.generate(6000, 77);
    let q = extract_queries(&r, 1, 256, 0.1, 78).remove(0);
    let svc = service(&r, 3);
    for s in Suite::ALL {
        let resp = svc
            .submit(&QueryRequest {
                id: 0,
                query: q.clone(),
                window_ratio: 0.2,
                suite: s,
                k: 1,
                metric: Metric::Cdtw,
                deadline_ms: None,
                tenant: None,
            })
            .unwrap();
        let mut c = Counters::new();
        let want = search_subsequence(&r, &q, window_cells(q.len(), 0.2), s, &mut c);
        assert_eq!(resp.pos, want.pos, "{}", s.name());
        assert!((resp.dist - want.dist).abs() < 1e-9, "{}", s.name());
        // sharding never examines more candidates than the direct scan
        assert_eq!(resp.candidates, c.candidates, "{}", s.name());
    }
}

#[test]
fn shard_count_does_not_change_results() {
    let r = Dataset::FoG.generate(5000, 5);
    let q = extract_queries(&r, 1, 128, 0.1, 6).remove(0);
    let mut results = Vec::new();
    for shards in [1usize, 2, 5, 9] {
        let svc = service(&r, shards);
        let resp = svc
            .submit(&QueryRequest {
                id: 0,
                query: q.clone(),
                window_ratio: 0.1,
                suite: Suite::UcrMon,
                k: 1,
                metric: Metric::Cdtw,
                deadline_ms: None,
                tenant: None,
            })
            .unwrap();
        results.push((shards, resp.pos, resp.dist));
    }
    for w in results.windows(2) {
        assert_eq!(w[0].1, w[1].1, "{:?}", results);
        assert!((w[0].2 - w[1].2).abs() < 1e-9);
    }
}

#[test]
fn many_concurrent_clients_one_service() {
    let r = Dataset::Ecg.generate(4000, 21);
    let svc = Arc::new(service(&r, 2));
    let qs = extract_queries(&r, 8, 128, 0.1, 22);
    // compute expected answers serially first
    let expected: Vec<_> = qs
        .iter()
        .map(|q| {
            let mut c = Counters::new();
            search_subsequence(&r, q, window_cells(q.len(), 0.1), Suite::UcrMon, &mut c)
        })
        .collect();
    let mut handles = Vec::new();
    for (i, q) in qs.into_iter().enumerate() {
        let svc = Arc::clone(&svc);
        handles.push(std::thread::spawn(move || {
            (
                i,
                svc.submit(&QueryRequest {
                    id: i as u64,
                    query: q,
                    window_ratio: 0.1,
                    suite: Suite::UcrMon,
                    k: 1,
                    metric: Metric::Cdtw,
                    deadline_ms: None,
                    tenant: None,
                })
                .unwrap(),
            )
        }));
    }
    for h in handles {
        let (i, resp) = h.join().unwrap();
        assert_eq!(resp.pos, expected[i].pos, "query {i}");
        assert!((resp.dist - expected[i].dist).abs() < 1e-9);
    }
    assert_eq!(svc.queries_served(), 8);
    // the busy gauge is decremented *after* the reply is sent — give the
    // workers a beat to settle
    for _ in 0..100 {
        if svc.busy_workers() == 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(svc.busy_workers(), 0, "workers idle after drain");
}

#[test]
fn protocol_survives_the_wire() {
    let req = QueryRequest {
        id: 99,
        query: vec![1.5, -2.0, 0.0, 3.25],
        window_ratio: 0.35,
        suite: Suite::UcrMonNoLb,
        k: 3,
        metric: Metric::Erp { gap: 0.25 },
        deadline_ms: None,
        tenant: None,
    };
    let line = req.to_json();
    assert!(!line.contains('\n'), "line-delimited");
    let back = QueryRequest::from_json(&line).unwrap();
    assert_eq!(back, req);

    let resp = QueryResponse {
        id: 99,
        pos: 1234,
        dist: 0.5,
        matches: vec![
            Match { pos: 1234, dist: 0.5 },
            Match { pos: 88, dist: 0.75 },
            Match { pos: 9, dist: 1.5 },
        ],
        latency_ms: 3.125,
        queue_ms: None,
        candidates: 1000,
        pruned: 900,
        dtw_calls: 100,
        cohort: 1,
        partial: false,
    };
    assert_eq!(QueryResponse::from_json(&resp.to_json()).unwrap(), resp);
}

/// Acceptance: a wire request with no `metric` field — the entire PR-1
/// request format — parses to cDTW and returns results bit-identical to
/// the pre-metric service (single shard + indexed stats makes the scan
/// deterministic down to the f64 bits; `search_subsequence_topk` is the
/// PR-1 behaviour, itself bit-locked to the seed's scalar loop by
/// `integration_index`).
#[test]
fn request_without_metric_is_bit_identical_to_pr1_cdtw() {
    let r = Dataset::Ecg.generate(2500, 61);
    let q = extract_queries(&r, 1, 96, 0.1, 62).remove(0);
    let qjson: Vec<String> = q.iter().map(|v| format!("{v}")).collect();
    let legacy_line = format!(
        r#"{{"id":4,"window_ratio":0.2,"suite":"mon","k":3,"query":[{}]}}"#,
        qjson.join(",")
    );
    let req = QueryRequest::from_json(&legacy_line).unwrap();
    assert_eq!(req.metric, Metric::Cdtw, "absent metric must parse as cDTW");

    // the PR-1 service only had the scalar front-end: pin it so the
    // dtw_calls tally below compares like with like (result *contents*
    // are mode-independent, prune/call attribution is not)
    let svc = Service::new(
        r.to_vec(),
        &ServiceConfig { shards: 1, scan_mode: ScanMode::Scalar, ..Default::default() },
    )
    .unwrap();
    let resp = svc.submit(&req).unwrap();
    let mut c = Counters::new();
    let want = repro::search::subsequence::search_subsequence_topk(
        &r,
        &req.query,
        window_cells(req.query.len(), 0.2),
        3,
        Suite::UcrMon,
        &mut c,
    );
    assert_eq!(resp.matches.len(), want.len());
    for (g, m) in resp.matches.iter().zip(&want) {
        assert_eq!(g.pos, m.pos);
        assert_eq!(g.dist.to_bits(), m.dist.to_bits(), "distance must be bit-identical");
    }
    assert_eq!(resp.candidates, c.candidates);
    assert_eq!(resp.dtw_calls, c.dtw_calls);
}

#[test]
fn shard_ranges_match_candidate_space() {
    let r = Dataset::Ppg.generate(3000, 9);
    let qlen = 128;
    let total = r.len() - qlen + 1;
    for shards in [1usize, 3, 7] {
        let ranges = shard_ranges(total, shards);
        assert_eq!(ranges.first().unwrap().0, 0);
        assert_eq!(ranges.last().unwrap().1, total);
        for pair in ranges.windows(2) {
            assert_eq!(pair[0].1, pair[1].0, "contiguous");
        }
    }
}

#[test]
fn empty_and_oversized_queries_error_cleanly() {
    let r = Dataset::Ecg.generate(500, 2);
    let svc = service(&r, 2);
    // oversized
    let req = QueryRequest {
        id: 1,
        query: vec![0.0; 1000],
        window_ratio: 0.1,
        suite: Suite::UcrMon,
        k: 1,
        metric: Metric::Cdtw,
        deadline_ms: None,
        tenant: None,
    };
    assert!(svc.submit(&req).is_err());
}

#[test]
fn topk_over_service_is_ranked_and_consistent_across_shards() {
    let r = Dataset::Soccer.generate(5000, 31);
    let q = extract_queries(&r, 1, 128, 0.1, 32).remove(0);
    let k = 7;
    let mut baseline: Option<Vec<Match>> = None;
    for shards in [1usize, 2, 6] {
        let svc = service(&r, shards);
        let resp = svc
            .submit(&QueryRequest {
                id: 0,
                query: q.clone(),
                window_ratio: 0.2,
                suite: Suite::UcrMon,
                k,
                metric: Metric::Cdtw,
                deadline_ms: None,
                tenant: None,
            })
            .unwrap();
        assert_eq!(resp.matches.len(), k);
        for pair in resp.matches.windows(2) {
            assert!(
                pair[0].dist < pair[1].dist
                    || (pair[0].dist == pair[1].dist && pair[0].pos < pair[1].pos),
                "unsorted: {:?}",
                resp.matches
            );
        }
        assert_eq!(resp.pos, resp.matches[0].pos);
        if let Some(want) = baseline.as_deref() {
            for (g, m) in resp.matches.iter().zip(want) {
                assert_eq!(g.pos, m.pos, "shards={shards}");
                assert!((g.dist - m.dist).abs() < 1e-9, "shards={shards}");
            }
        } else {
            baseline = Some(resp.matches);
        }
    }
}
