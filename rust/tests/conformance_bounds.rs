//! Bounds conformance suite: every lower-bound stage the cascade can run
//! — LB_KimFL, LB_Keogh EQ/EC (sorted and unordered/batched), and
//! LB_Improved's second pass — is pinned **admissible** (never above the
//! exact windowed DTW) over random series, lengths and windows, including
//! the degenerate windows `w = 0`, `w >= len` and `len = 1`. The batched
//! SoA stages are pinned against their scalar counterparts, and two
//! planted adversaries pin the whole point of the two-pass bound: a pair
//! where LB_Keogh is loose but LB_Improved prunes, and a pair where no
//! stage may prune.

use repro::bounds::batch::{lb_keogh_ec_unordered, lb_keogh_eq_unordered};
use repro::bounds::envelope::envelopes;
use repro::bounds::lb_improved::{
    lb_improved_tail_ec, lb_improved_tail_ec_raw, lb_improved_tail_eq, ImprovedScratch,
};
use repro::bounds::lb_keogh::{lb_keogh_ec, lb_keogh_eq, reorder, sort_order};
use repro::bounds::lb_kim::lb_kim_hierarchy;
use repro::data::rng::Rng;
use repro::distances::cost::sqed;
use repro::distances::dtw::dtw_oracle;
use repro::metrics::Counters;
use repro::norm::znorm::{stats, znorm, znorm_point};
use repro::search::nn1::nn1_topk;
use repro::search::suite::Suite;
use repro::util::proptest::{arb_window, run_prop};

/// A z-normalised query against a raw candidate window, with a window
/// that deliberately hits the degenerate cases (`0`, `>= len`) often.
#[derive(Debug)]
struct Case {
    q: Vec<f64>,
    c: Vec<f64>,
    w: usize,
}

fn arb_case(rng: &mut Rng) -> Case {
    let n = 1 + rng.below(48) as usize;
    let q = znorm(&(0..n).map(|_| rng.normal()).collect::<Vec<_>>());
    let c: Vec<f64> = (0..n).map(|_| rng.normal() * 2.5 + 0.75).collect();
    let w = match rng.below(5) {
        0 => 0,
        1 => n + rng.below(4) as usize,
        _ => arb_window(rng, n),
    };
    Case { q, c, w }
}

/// All scalar stage values for one case, plus the exact windowed DTW.
struct Stages {
    dtw: f64,
    kim: f64,
    eq: f64,
    ec: f64,
    tail: f64,
}

fn stage_values(t: &Case) -> Stages {
    let n = t.q.len();
    let (mean, std) = stats(&t.c);
    let zc: Vec<f64> = t.c.iter().map(|&x| znorm_point(x, mean, std)).collect();
    let dtw = dtw_oracle(&t.q, &zc, Some(t.w));
    let kim = lb_kim_hierarchy(&t.q, &t.c, mean, std, f64::INFINITY);
    let (u, l) = envelopes(&t.q, t.w);
    let (du, dl) = envelopes(&t.c, t.w);
    let order = sort_order(&t.q);
    let uo = reorder(&u, &order);
    let lo = reorder(&l, &order);
    let qo = reorder(&t.q, &order);
    let mut cb = vec![0.0; n];
    let eq = lb_keogh_eq(&order, &uo, &lo, &t.c, mean, std, f64::INFINITY, &mut cb);
    let ec = lb_keogh_ec(&order, &qo, &du, &dl, mean, std, f64::INFINITY, &mut cb);
    let mut s = ImprovedScratch::new();
    let tail = lb_improved_tail_ec(&mut s, &t.q, &du, &dl, mean, std, &zc, t.w, f64::INFINITY);
    Stages { dtw, kim, eq, ec, tail }
}

#[test]
fn prop_every_cascade_stage_is_admissible() {
    run_prop("every stage <= dtw", 0xB001, 140, arb_case, |t| {
        let s = stage_values(t);
        let eps = 1e-6;
        // LB_Kim's front/back 2- and 3-point stages charge the path's
        // 2nd/3rd cells from each end; those cell sets are pairwise
        // disjoint only from length 6 (at n = 3 or 5 a diagonal path's
        // middle cell is claimed by both ends), so the hierarchy is
        // asserted at the lengths where it is provably a bound
        let kim = if t.q.len() >= 6 { s.kim } else { 0.0 };
        for (name, lb) in [
            ("kim", kim),
            ("keogh_eq", s.eq),
            ("keogh_ec", s.ec),
            ("improved_tail", s.tail),
            ("keogh_ec + improved_tail", s.ec + s.tail),
        ] {
            if lb > s.dtw + eps {
                return Err(format!("{name}: {lb} > dtw {} (n={} w={})", s.dtw, t.q.len(), t.w));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_eq_side_two_pass_sum_is_admissible() {
    // the NN1 direction: both series pre-normalised, candidate projected
    // onto the *query* envelope
    run_prop("eq + eq_tail <= dtw", 0xB002, 120, arb_case, |t| {
        let (mean, std) = stats(&t.c);
        let zc: Vec<f64> = t.c.iter().map(|&x| znorm_point(x, mean, std)).collect();
        let (u, l) = envelopes(&t.q, t.w);
        let mut first = 0.0;
        for (i, &x) in zc.iter().enumerate() {
            first += if x > u[i] {
                sqed(x, u[i])
            } else if x < l[i] {
                sqed(x, l[i])
            } else {
                0.0
            };
        }
        let mut s = ImprovedScratch::new();
        let tail = lb_improved_tail_eq(&mut s, &zc, &u, &l, &t.q, t.w, f64::INFINITY);
        let d = dtw_oracle(&t.q, &zc, Some(t.w));
        if first + tail > d + 1e-6 {
            return Err(format!("{} + {tail} > dtw {d} (w={})", first, t.w));
        }
        Ok(())
    });
}

#[test]
fn prop_cascade_ordering_is_monotone() {
    // the provable orderings: every stage's tail is non-negative, so the
    // two-pass sum dominates its own first pass, and the cascade's
    // running max over enabled stages can only grow as stages are added
    run_prop("cascade max monotone", 0xB003, 120, arb_case, |t| {
        let s = stage_values(t);
        if s.tail < 0.0 {
            return Err(format!("negative tail {}", s.tail));
        }
        if s.ec + s.tail < s.ec {
            return Err("two-pass sum below its first pass".into());
        }
        let m1 = s.kim;
        let m2 = m1.max(s.eq);
        let m3 = m2.max(s.ec);
        let m4 = m3.max(s.ec + s.tail);
        if !(m1 <= m2 && m2 <= m3 && m3 <= m4) {
            return Err(format!("cascade max not monotone: {m1} {m2} {m3} {m4}"));
        }
        Ok(())
    });
}

#[test]
fn prop_batched_stages_agree_with_scalar_and_never_overprune() {
    run_prop("batch == scalar", 0xB004, 120, arb_case, |t| {
        let (mean, std) = stats(&t.c);
        let zc: Vec<f64> = t.c.iter().map(|&x| znorm_point(x, mean, std)).collect();
        let s = stage_values(t);
        let (u, l) = envelopes(&t.q, t.w);
        let (du, dl) = envelopes(&t.c, t.w);
        // the unordered sums add the same non-negative terms in natural
        // order: equal up to summation-order rounding
        let equ = lb_keogh_eq_unordered(&u, &l, &t.c, mean, std);
        let ecu = lb_keogh_ec_unordered(&t.q, &du, &dl, mean, std);
        if (equ - s.eq).abs() > 1e-9 * (1.0 + s.eq) {
            return Err(format!("eq unordered {equ} vs sorted {}", s.eq));
        }
        if (ecu - s.ec).abs() > 1e-9 * (1.0 + s.ec) {
            return Err(format!("ec unordered {ecu} vs sorted {}", s.ec));
        }
        // the batch stages prune at `lb * (1 - 1e-9) > threshold`: that
        // discounted decision must imply the scalar sum also exceeds the
        // threshold, for thresholds tight against the bound
        for f in [0.25, 0.5, 0.9, 0.999_999, 1.0] {
            let th = s.eq * f;
            if equ * (1.0 - 1e-9) > th && s.eq <= th {
                return Err(format!("eq batch overprunes at {th}"));
            }
            let th = s.ec * f;
            if ecu * (1.0 - 1e-9) > th && s.ec <= th {
                return Err(format!("ec batch overprunes at {th}"));
            }
        }
        // the raw-window tail (batch lanes) is bit-identical to the
        // pre-normalised tail (scalar survivor path)
        let mut s1 = ImprovedScratch::new();
        let mut s2 = ImprovedScratch::new();
        for budget in [f64::INFINITY, s.dtw * 0.5, 1e-6] {
            let a = lb_improved_tail_ec(&mut s1, &t.q, &du, &dl, mean, std, &zc, t.w, budget);
            let b = lb_improved_tail_ec_raw(&mut s2, &t.q, &du, &dl, mean, std, &t.c, t.w, budget);
            if a.to_bits() != b.to_bits() {
                return Err(format!("tail raw {b} != pre-normalised {a} @ {budget}"));
            }
        }
        Ok(())
    });
}

#[test]
fn degenerate_windows_stay_admissible() {
    // len = 1 and w = 0 / w >= len, deterministically
    for (q, c) in [
        (vec![0.0], vec![4.2]),
        (vec![-1.0, 1.0], vec![3.0, 5.0]),
        (vec![0.5, -1.2, 0.7], vec![2.0, 2.0, 2.0]),
    ] {
        let q = znorm(&q);
        let (mean, std) = stats(&c);
        let zc: Vec<f64> = c.iter().map(|&x| znorm_point(x, mean, std)).collect();
        for w in [0usize, 1, q.len(), q.len() + 5] {
            let t = Case { q: q.clone(), c: c.clone(), w };
            let s = stage_values(&t);
            for lb in [s.kim, s.eq, s.ec, s.ec + s.tail] {
                assert!(lb <= s.dtw + 1e-9, "n={} w={w}: {lb} > {}", q.len(), s.dtw);
            }
        }
    }
}

#[test]
fn planted_adversary_improved_prunes_where_keogh_ec_cannot() {
    // flat query inside a wildly oscillating candidate's envelope: the
    // first EC pass sees nothing, the projection tail sees everything
    let n = 16;
    let w = 2;
    let q = vec![0.0; n];
    let c: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 3.0 } else { -3.0 }).collect();
    let (mean, std) = stats(&c);
    assert!(mean.abs() < 1e-12 && (std - 3.0).abs() < 1e-12);
    let zc: Vec<f64> = c.iter().map(|&x| znorm_point(x, mean, std)).collect();
    let (du, dl) = envelopes(&c, w);
    let order = sort_order(&q);
    let qo = reorder(&q, &order);
    let mut cb = vec![0.0; n];
    let ec = lb_keogh_ec(&order, &qo, &du, &dl, mean, std, f64::INFINITY, &mut cb);
    assert_eq!(ec, 0.0, "the flat query sits inside the candidate envelope");
    let mut s = ImprovedScratch::new();
    let tail = lb_improved_tail_ec(&mut s, &q, &du, &dl, mean, std, &zc, w, f64::INFINITY);
    let d = dtw_oracle(&q, &zc, Some(w));
    assert_eq!(tail, n as f64, "second pass charges every oscillation");
    assert_eq!(d, n as f64, "…and here it is exactly tight");
    let bsf = n as f64 / 2.0;
    assert!(ec <= bsf, "LB_Keogh EC alone must NOT prune this pair");
    assert!(ec + tail > bsf, "LB_Improved must prune it");
}

#[test]
fn planted_adversary_improved_prunes_where_keogh_eq_cannot() {
    // the EQ/NN1 direction, end-to-end: a flat candidate inside an
    // oscillating query's envelope survives LB_Keogh with bound 0, and
    // only the second pass stops it from reaching the kernel
    let n = 16;
    let w = 2;
    // alternating ±1: mean 0, std 1 — already z-normalised
    let q: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let flat = vec![0.0; n];
    let (u, l) = envelopes(&q, w);
    // first pass is exactly 0: the flat candidate sits inside the envelope
    for (i, &x) in flat.iter().enumerate() {
        assert!(l[i] <= x && x <= u[i], "flat candidate escapes the envelope at {i}");
    }
    let mut s = ImprovedScratch::new();
    let tail = lb_improved_tail_eq(&mut s, &flat, &u, &l, &q, w, f64::INFINITY);
    let d = dtw_oracle(&q, &flat, Some(w));
    assert_eq!(tail, n as f64);
    assert_eq!(d, n as f64);
    // end-to-end: an exact copy answers the query first (k-th best hits
    // 0), then the flat adversary is pruned by the improved stage alone
    let cands = vec![q.clone(), flat];
    let mut cnt = Counters::new();
    let got = nn1_topk(&q, &cands, w, 1, Suite::UcrMon, &mut cnt);
    assert_eq!(got[0].index, 0);
    assert_eq!(got[0].dist, 0.0);
    assert_eq!(cnt.lb_improved_prunes, 1, "{cnt:?}");
    assert_eq!(cnt.lb_keogh_eq_prunes, 0, "{cnt:?}");
    assert_eq!(cnt.dtw_calls, 1, "{cnt:?}");
}

#[test]
fn planted_pair_where_no_stage_may_prune() {
    // identical series: DTW is exactly 0, so every admissible bound is
    // exactly 0 and nothing may prune at any positive threshold
    let n = 16;
    let w = 2;
    let q: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let t = Case { q: q.clone(), c: q.clone(), w };
    let s = stage_values(&t);
    assert_eq!(s.dtw, 0.0);
    assert_eq!(s.kim, 0.0);
    assert_eq!(s.eq, 0.0);
    assert_eq!(s.ec, 0.0);
    assert_eq!(s.tail, 0.0);
    let mut is = ImprovedScratch::new();
    let (u, l) = envelopes(&q, w);
    assert_eq!(lb_improved_tail_eq(&mut is, &q, &u, &l, &q, w, f64::INFINITY), 0.0);
}
