//! `repro` — the Layer-3 leader binary: similarity search, the serving
//! loop, the paper's experiment grid, data generation, and artifact
//! introspection. Run `repro help` for usage.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use repro::bench_support::grid::{experiments, run_experiment, Workload};
use repro::bench_support::report::{fig5_table, pruning_table, speedup_summary};
use repro::config::Config;
use repro::coordinator::{ErrorResponse, QueryRequest, Service, ServiceConfig};
use repro::data::{extract_queries, Dataset};
use repro::distances::metric::Metric;
use repro::metrics::{Counters, Timer};
#[cfg(feature = "xla")]
use repro::runtime::XlaEngine;
use repro::search::subsequence::{search_subsequence, window_cells, ScanMode, ScanTuning};
use repro::search::suite::Suite;
use repro::util::cli::Args;

const USAGE: &str = "\
repro — EAPrunedDTW similarity search (Herrmann & Webb 2020 reproduction)

USAGE: repro <command> [options]

COMMANDS
  search      locate a query in a reference stream
              --dataset <name|file> --qlen N --ratio R --suite S
              [--ref-len N] [--seed N] [--config F]
  serve       run the search service: synthetic queries by default,
              --stdin for a wire session on stdin/stdout, --listen for
              the TCP front-end
              --dataset <name> [--queries N] [--shards N] [--suite S]
              [--k N] [--metric M] [--scan-mode strip|scalar]
              [--lanes N] [--precision f64|f32]
              [--batch-window N] [--batch-deadline-ms N]
              [--max-pending N] [--default-deadline-ms N]
              [--stats-every N] [--ref-len N] [--artifacts DIR]
              [--stdin] [--max-frame-bytes N]
              [--listen [ADDR]] [--max-conns N] [--read-timeout-ms N]
              [--idle-timeout-ms N] [--write-queue N]
              [--quota-rate R] [--quota-burst N]
  bench-suite run the paper's experiment grid and print Fig 5a/5b + tables
              [--axis length|window|all] [--ref-len N] [--datasets a,b]
              [--qlens 128,256] [--ratios 0.1,0.2] [--queries N]
              [--suites ucr,usp,mon,nolb]
  gen-data    write a synthetic dataset to disk
              --dataset <name> --out FILE [--len N] [--seed N]
  info        check artifacts + runtime (loads the PJRT engine)
              [--artifacts DIR]
  help        this text

Suites: ucr | usp | mon | nolb | xla     Datasets: FoG Soccer PAMAP2 ECG REFIT PPG
Metrics: cdtw (default) | dtw | wdtw | erp | msm | twe (default parameters;
         per-request parameters travel in the protocol's metric object)
Scan modes: strip (default; batched bounds + LB-ordered DTW) | scalar
         (the legacy per-candidate loop — same results, A/B baseline)
Kernel:  --lanes N packs up to N cascade survivors per strip into one
         multi-candidate wavefront kernel pass (1 = scalar kernel, the
         default; same top-k results, bitwise). --precision f32 stores
         the kernel's DP lines in f32 (opt-in; distances track f64
         within a relative epsilon and pruning only ever loosens)
Batching: --batch-window N coalesces N in-flight queries; same-shape
         queries form cohorts served by one shared strip pass over the
         reference (same results as solo serving, bitwise).
         --batch-deadline-ms N flushes a partial window once its oldest
         query has waited N ms, instead of holding it for the window to
         fill (0 = wait for the window, the default)
Robustness: --max-pending N sheds queries beyond N in flight with an
         overloaded error line (0 = unbounded, the default).
         --default-deadline-ms N gives every query without its own
         deadline_ms an N-ms budget; out-of-time queries answer with a
         partial top-k (\"partial\":true) or a timeout error line
         (0 = no budget, the default — exhaustive scans)
Stats:   --stats-every N emits the live registry's metrics snapshot
         (pinned schema repro.metrics.v1, one JSON line on stderr) after
         every N responses, and once more at end of input (0 = off, the
         default). Wire front-ends answer {\"cmd\":\"stats\"} lines from
         the same registry (Service::handle_line)
Wire:    --stdin serves newline-delimited JSON frames from stdin, one
         reply line per frame (unparseable frames answer \"id\":null;
         frames over --max-frame-bytes answer frame_too_large and the
         stream resyncs at the next newline).
         --listen [ADDR] serves the same protocol over TCP (default
         address from the [net] config section) with hostile-client
         hardening: --max-conns bounds open connections (excess accepts
         answer overloaded and close), --read-timeout-ms cuts slow-loris
         senders, --idle-timeout-ms closes idle sessions, --write-queue
         disconnects clients that stop reading, and --quota-rate /
         --quota-burst token-bucket quotas per tenant (the optional
         \"tenant\" request field) shed with retry_after_ms before any
         scan work. Stdin becomes the control plane: \"drain\" or EOF
         shuts down gracefully (in-flight queries answered, every
         connection joined), \"stats\" prints a snapshot";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let cmd = args.command.clone().unwrap_or_else(|| "help".to_string());
    let r = match cmd.as_str() {
        "search" => cmd_search(&args),
        "serve" => cmd_serve(&args),
        "bench-suite" => cmd_bench_suite(&args),
        "gen-data" => cmd_gen_data(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(anyhow!("unknown command {other:?}\n{USAGE}")),
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_reference(name: &str, ref_len: usize, seed: u64) -> Result<Vec<f64>> {
    match Dataset::from_name(name) {
        Some(d) => Ok(d.generate(ref_len, seed)),
        None => {
            let p = Path::new(name);
            if p.exists() {
                repro::data::loader::read_series(p)
            } else {
                bail!("{name:?} is neither a dataset name nor a file")
            }
        }
    }
}

fn parse_suite(s: &str) -> Result<Suite> {
    Suite::from_name(s).ok_or_else(|| anyhow!("unknown suite {s:?} (ucr|usp|mon|nolb|xla)"))
}

#[cfg(feature = "xla")]
fn search_xla(
    dir: &Path,
    reference: &[f64],
    query: &[f64],
    w: usize,
    counters: &mut Counters,
) -> Result<repro::search::subsequence::Match> {
    let mut engine = XlaEngine::open(dir)?;
    repro::coordinator::batcher::xla_search(&mut engine, reference, query, w, counters)
}

#[cfg(not(feature = "xla"))]
fn search_xla(
    _dir: &Path,
    _reference: &[f64],
    _query: &[f64],
    _w: usize,
    _counters: &mut Counters,
) -> Result<repro::search::subsequence::Match> {
    bail!("suite xla unavailable: rebuild with `cargo build --features xla`")
}

fn cmd_search(args: &Args) -> Result<()> {
    let cfg = Config::load_or_default(args.get("config").map(Path::new))?;
    let dataset = args.get_or("dataset", &cfg.search.dataset).to_string();
    let qlen = args.usize_or("qlen", cfg.search.query_len)?;
    let ratio = args.f64_or("ratio", cfg.search.window_ratio)?;
    let suite = parse_suite(args.get_or("suite", &cfg.search.suite))?;
    let ref_len = args.usize_or("ref-len", cfg.grid.ref_len)?;
    let seed = args.u64_or("seed", cfg.grid.seed)?;

    let reference = load_reference(&dataset, ref_len, seed)?;
    let query = extract_queries(&reference, 1, qlen, cfg.grid.query_noise, seed ^ 1).remove(0);
    let w = window_cells(qlen, ratio);
    println!(
        "searching {dataset} (len {}) for a {qlen}-point query, w={w} ({ratio}), suite {}",
        reference.len(),
        suite.name()
    );
    let mut counters = Counters::new();
    let t = Timer::start();
    let m = if suite == Suite::UcrMonXla {
        let dir = PathBuf::from(args.get_or("artifacts", &cfg.serve.artifacts_dir));
        search_xla(&dir, &reference, &query, w, &mut counters)?
    } else {
        search_subsequence(&reference, &query, w, suite, &mut counters)
    };
    let secs = t.elapsed_secs();
    println!("best match: pos={} dist={:.6} in {:.3}s", m.pos, m.dist, secs);
    let (kim, eq, ec, imp, xla, dtw) = counters.prune_fractions();
    println!(
        "candidates={} | pruned: kim {:.1}% keoghEQ {:.1}% keoghEC {:.1}% keoghIMP {:.1}% \
         xla {:.1}% | dtw reached {:.1}% ({} calls, {} abandoned)",
        counters.candidates,
        kim * 100.0,
        eq * 100.0,
        ec * 100.0,
        imp * 100.0,
        xla * 100.0,
        dtw * 100.0,
        counters.dtw_calls,
        counters.dtw_abandons
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = Config::load_or_default(args.get("config").map(Path::new))?;
    let dataset = args.get_or("dataset", &cfg.search.dataset).to_string();
    let ref_len = args.usize_or("ref-len", cfg.grid.ref_len)?;
    let seed = args.u64_or("seed", cfg.grid.seed)?;
    let shards = args.usize_or("shards", cfg.serve.shards)?;
    let n_queries = args.usize_or("queries", 20)?;
    let qlen = args.usize_or("qlen", cfg.search.query_len)?;
    let ratio = args.f64_or("ratio", cfg.search.window_ratio)?;
    let k = args.usize_or("k", 1)?;
    let suite = parse_suite(args.get_or("suite", &cfg.search.suite))?;
    let metric = match args.get("metric") {
        Some(name) => Metric::from_name(name)
            .ok_or_else(|| anyhow!("unknown metric {name:?} (try cdtw|dtw|wdtw|erp|msm|twe)"))?,
        None => Metric::Cdtw,
    };
    let scan_mode = match args.get("scan-mode") {
        Some(name) => ScanMode::from_name(name)
            .ok_or_else(|| anyhow!("unknown scan mode {name:?} (strip|scalar)"))?,
        None => ScanMode::default(),
    };
    let lanes = args.usize_or("lanes", cfg.serve.lanes)?.max(1);
    let precision = {
        let name = args.get_or("precision", &cfg.serve.precision).to_string();
        repro::distances::kernel::Precision::from_name(&name)
            .ok_or_else(|| anyhow!("unknown precision {name:?} (f64|f32)"))?
    };
    let batch_window = args.usize_or("batch-window", cfg.serve.batch_window)?.max(1);
    let batch_deadline_ms = args.u64_or("batch-deadline-ms", cfg.serve.batch_deadline_ms)?;
    let max_pending = args.usize_or("max-pending", cfg.serve.max_pending)?;
    let default_deadline_ms = args.f64_or("default-deadline-ms", cfg.serve.default_deadline_ms)?;
    let stats_every = args.usize_or("stats-every", 0)?;
    let artifacts = PathBuf::from(args.get_or("artifacts", &cfg.serve.artifacts_dir));

    let reference = load_reference(&dataset, ref_len, seed)?;
    let queries = extract_queries(&reference, n_queries, qlen, cfg.grid.query_noise, seed ^ 2);
    let svc = std::sync::Arc::new(Service::new(
        reference,
        &ServiceConfig {
            shards,
            scan_mode,
            batch_window,
            batch_deadline_ms,
            max_pending,
            default_deadline_ms,
            artifacts_dir: artifacts.join("manifest.json").exists().then_some(artifacts),
            tuning: ScanTuning::default().with_lanes(lanes).with_precision(precision),
            ..Default::default()
        },
    )?);
    if args.flag("listen") || args.get("listen").is_some() {
        return serve_listen(args, &cfg, svc);
    }
    if args.flag("stdin") {
        let max_frame = args.usize_or("max-frame-bytes", cfg.net.max_frame_bytes)?;
        eprintln!(
            "serving wire frames from stdin (max frame {max_frame} bytes, one reply per frame)"
        );
        let answered = repro::net::serve_frames(
            &svc,
            std::io::stdin().lock(),
            &mut std::io::stdout().lock(),
            max_frame,
            stats_every,
        )?;
        eprintln!("end of input after {answered} frames");
        return Ok(());
    }
    println!(
        "serving {n_queries} queries (qlen {qlen}, ratio {ratio}, suite {}, metric {}, top-{k}, {} scan, batch window {}, deadline {}, max-pending {}, default-deadline {}) over {shards} shards",
        suite.name(),
        metric.name(),
        scan_mode.name(),
        svc.batch_window(),
        match svc.batch_deadline() {
            Some(d) => format!("{}ms", d.as_millis()),
            None => "none".into(),
        },
        match svc.max_pending() {
            0 => "unbounded".into(),
            n => n.to_string(),
        },
        match svc.default_deadline_ms() {
            Some(ms) => format!("{ms}ms"),
            None => "none".into(),
        },
    );
    let mut latencies = Vec::new();
    let t = Timer::start();
    let reqs: Vec<QueryRequest> = queries
        .into_iter()
        .enumerate()
        .map(|(i, q)| QueryRequest {
            id: i as u64,
            query: q,
            window_ratio: ratio,
            suite,
            k,
            metric,
            deadline_ms: None,
            tenant: None,
        })
        .collect();
    // a failing request answers with the protocol's error line and the
    // service keeps serving — one bad query must not end the session
    let mut since_stats = 0usize;
    let mut serve_batch = |batch: &[(QueryRequest, std::time::Instant)]| {
        for ((req, _), result) in batch.iter().zip(svc.submit_batch_timed(batch)) {
            match result {
                Ok(resp) => {
                    println!("{}", resp.to_json());
                    latencies.push(resp.latency_ms);
                }
                Err(e) => println!("{}", ErrorResponse::new(req.id, &e).to_json()),
            }
            since_stats += 1;
            if stats_every > 0 && since_stats >= stats_every {
                eprintln!("{}", svc.stats_json());
                since_stats = 0;
            }
        }
    };
    // coalesce up to batch_window in-flight queries per submit (same-shape
    // queries inside a window share one strip pass over the reference); a
    // deadline flushes a partial window once its oldest query has waited
    // long enough, so a sparse arrival stream is never stalled
    let mut coalescer = repro::coordinator::BatchCoalescer::new(
        svc.batch_window(),
        svc.batch_deadline(),
    );
    for req in reqs {
        if let Some(batch) = coalescer.push(req, std::time::Instant::now()) {
            serve_batch(&batch);
        }
        if let Some(batch) = coalescer.poll(std::time::Instant::now()) {
            serve_batch(&batch);
        }
        svc.set_coalescer_pending(coalescer.pending() as u64);
    }
    if let Some(batch) = coalescer.flush() {
        serve_batch(&batch);
    }
    svc.set_coalescer_pending(0);
    if stats_every > 0 {
        eprintln!("{}", svc.stats_json());
    }
    let wall = t.elapsed_secs();
    if latencies.is_empty() {
        bail!("no query served successfully");
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).expect("no NaN"));
    let pct = |p: f64| latencies[((latencies.len() - 1) as f64 * p) as usize];
    println!(
        "served {} queries in {:.3}s — throughput {:.2} q/s, latency p50 {:.1}ms p95 {:.1}ms max {:.1}ms",
        latencies.len(),
        wall,
        latencies.len() as f64 / wall,
        pct(0.5),
        pct(0.95),
        latencies[latencies.len() - 1],
    );
    Ok(())
}

/// The TCP front-end mode of `repro serve`: start the hardened listener,
/// then turn stdin into the control plane — "drain" (or EOF) shuts down
/// gracefully, "stats" prints a live metrics snapshot to stderr.
fn serve_listen(args: &Args, cfg: &Config, svc: std::sync::Arc<Service>) -> Result<()> {
    let addr = args.get_or("listen", &cfg.net.listen).to_string();
    let net_cfg = repro::net::NetConfig {
        max_conns: args.usize_or("max-conns", cfg.net.max_conns)?,
        max_frame_bytes: args.usize_or("max-frame-bytes", cfg.net.max_frame_bytes)?,
        read_timeout_ms: args.u64_or("read-timeout-ms", cfg.net.read_timeout_ms)?,
        idle_timeout_ms: args.u64_or("idle-timeout-ms", cfg.net.idle_timeout_ms)?,
        write_queue: args.usize_or("write-queue", cfg.net.write_queue)?,
        quota_rate: args.f64_or("quota-rate", cfg.net.quota_rate)?,
        quota_burst: args.f64_or("quota-burst", cfg.net.quota_burst)?,
    };
    let quotas = if net_cfg.quota_rate > 0.0 {
        format!("{}/s burst {}", net_cfg.quota_rate, net_cfg.quota_burst)
    } else {
        "off".into()
    };
    let server = repro::net::NetServer::start(std::sync::Arc::clone(&svc), &addr, net_cfg.clone())?;
    eprintln!(
        "listening on {} (max-conns {}, frame cap {} bytes, read budget {}ms, idle budget {}ms, \
         write queue {}, quotas {quotas}) — control plane on stdin: drain | stats",
        server.local_addr(),
        net_cfg.max_conns,
        net_cfg.max_frame_bytes,
        net_cfg.read_timeout_ms,
        net_cfg.idle_timeout_ms,
        net_cfg.write_queue,
    );
    let stdin = std::io::stdin();
    let mut line = String::new();
    loop {
        line.clear();
        if stdin.read_line(&mut line)? == 0 {
            break;
        }
        match line.trim() {
            "drain" | "quit" | "exit" => break,
            "stats" => eprintln!("{}", svc.stats_json()),
            "" => {}
            other => eprintln!("unknown control command {other:?} (drain | stats)"),
        }
    }
    eprintln!("draining…");
    server.drain();
    eprintln!("drained cleanly after {} queries", svc.queries_served());
    Ok(())
}

fn parse_list<T: std::str::FromStr>(s: &str) -> Result<Vec<T>>
where
    T::Err: std::fmt::Display,
{
    s.split(',')
        .map(|x| x.trim().parse().map_err(|e| anyhow!("bad list item {x:?}: {e}")))
        .collect()
}

fn cmd_bench_suite(args: &Args) -> Result<()> {
    let cfg = Config::load_or_default(args.get("config").map(Path::new))?;
    let mut grid = cfg.grid.clone();
    grid.ref_len = args.usize_or("ref-len", grid.ref_len)?;
    grid.queries = args.usize_or("queries", grid.queries)?;
    if let Some(q) = args.get("qlens") {
        grid.query_lengths = parse_list(q)?;
    }
    if let Some(r) = args.get("ratios") {
        grid.window_ratios = parse_list(r)?;
    }
    let datasets: Vec<Dataset> = match args.get("datasets") {
        Some(list) => list
            .split(',')
            .map(|d| Dataset::from_name(d.trim()).ok_or_else(|| anyhow!("unknown dataset {d:?}")))
            .collect::<Result<_>>()?,
        None => Dataset::ALL.to_vec(),
    };
    let suites: Vec<Suite> = match args.get("suites") {
        Some(list) => list.split(',').map(parse_suite).collect::<Result<_>>()?,
        None => Suite::ALL.to_vec(),
    };
    let axis = args.get_or("axis", "all").to_string();

    eprintln!(
        "grid: {} datasets × {} queries × {:?} lengths × {:?} ratios × {} suites (ref_len {})",
        datasets.len(),
        grid.queries,
        grid.query_lengths,
        grid.window_ratios,
        suites.len(),
        grid.ref_len
    );
    let mut results = Vec::new();
    for &d in &datasets {
        eprintln!("building workload {}...", d.name());
        let w = Workload::build(d, &grid);
        for exp in experiments(&grid, &[d]) {
            for &s in &suites {
                let r = run_experiment(&w, &exp, s);
                eprintln!(
                    "  {} q{} len{} w{:.1} {}: {:.3}s (dtw {:.1}%)",
                    d.name(),
                    exp.query_idx,
                    exp.qlen,
                    exp.ratio,
                    s.name(),
                    r.seconds,
                    r.counters.prune_fractions().5 * 100.0
                );
                results.push(r);
            }
        }
    }
    if axis == "length" || axis == "all" {
        println!(
            "{}",
            fig5_table(&results, &suites, &grid.query_lengths, "query length", |r| r.exp.qlen)
        );
    }
    if axis == "window" || axis == "all" {
        let xs: Vec<usize> =
            grid.window_ratios.iter().map(|r| (r * 100.0).round() as usize).collect();
        println!(
            "{}",
            fig5_table(&results, &suites, &xs, "window ratio %", |r| {
                (r.exp.ratio * 100.0).round() as usize
            })
        );
    }
    println!("\n== §5 totals & speedups ==\n{}", speedup_summary(&results));
    println!("\n== Fig 5 inset: cascade pruning ==\n{}", pruning_table(&results));
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let name = args.get("dataset").ok_or_else(|| anyhow!("--dataset required"))?;
    let out = args.get("out").ok_or_else(|| anyhow!("--out required"))?;
    let len = args.usize_or("len", 200_000)?;
    let seed = args.u64_or("seed", 0xDA7A5E7)?;
    let d = Dataset::from_name(name).ok_or_else(|| anyhow!("unknown dataset {name:?}"))?;
    let series = d.generate(len, seed);
    repro::data::loader::write_series(Path::new(out), &series)?;
    println!("wrote {} points of {} to {out}", series.len(), d.name());
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_info(_args: &Args) -> Result<()> {
    bail!("info inspects the PJRT runtime: rebuild with `cargo build --features xla`")
}

#[cfg(feature = "xla")]
fn cmd_info(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    println!("artifacts dir: {}", dir.display());
    let mut engine = XlaEngine::open(&dir)?;
    let m = engine.manifest().clone();
    println!("batch={} lengths={:?} artifacts={}", m.batch, m.lengths, m.artifacts.len());
    for a in &m.artifacts {
        println!("  {} ({} bytes)", a.name, a.bytes);
    }
    // smoke: run the smallest prefilter
    let n = *m.lengths.iter().min().ok_or_else(|| anyhow!("empty manifest"))?;
    let u = vec![1.0f32; n];
    let l = vec![-1.0f32; n];
    let raw = vec![0.5f32; m.batch * n];
    let t = Timer::start();
    let out = engine.prefilter(n, &u, &l, &raw)?;
    println!(
        "smoke prefilter n={n}: ok ({} bounds, all-zero={}, {:.1}ms incl. compile)",
        out.len(),
        out.iter().all(|&v| v == 0.0),
        t.elapsed_secs() * 1e3
    );
    Ok(())
}
