//! Search instrumentation (system S14): what the paper's Fig. 5 insets
//! report — how many candidates each cascade stage prunes and how many
//! reach the DTW core — plus wall-clock timers and DP cell counts for the
//! ablations.

use std::time::{Duration, Instant};

use crate::distances::metric::Metric;

/// Per-search counters. Plain `u64`s mutated on the hot path (no atomics);
/// the coordinator aggregates per-worker copies with [`Counters::merge`].
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct Counters {
    /// candidate windows examined
    pub candidates: u64,
    /// pruned by LB_KimFL
    pub lb_kim_prunes: u64,
    /// pruned by LB_Keogh (query envelope)
    pub lb_keogh_eq_prunes: u64,
    /// pruned by LB_Keogh (data envelope)
    pub lb_keogh_ec_prunes: u64,
    /// pruned by LB_Improved's second pass (Lemire's two-pass bound): the
    /// candidate survived LB_Keogh but the first-pass sum plus the
    /// role-swapped second pass exceeded the threshold
    pub lb_improved_prunes: u64,
    /// pruned by the batched XLA prefilter
    pub xla_prunes: u64,
    /// DTW core invocations (cascade survivors)
    pub dtw_calls: u64,
    /// DTW calls that early abandoned
    pub dtw_abandons: u64,
    /// DTW calls that ran to completion — an exact distance or a proven
    /// infeasible band, i.e. everything that did not early abandon, so
    /// `dtw_calls == dtw_abandons + dtw_completions` always
    pub dtw_completions: u64,
    /// best-so-far improvements
    pub ub_updates: u64,
    /// DP cells computed (only filled by counted distance variants)
    pub dp_cells: u64,
    /// reference-index cache hits (stats buckets + envelope tables served
    /// without rebuilding — the index subsystem's amortisation win)
    pub index_hits: u64,
    /// top-k collector insertions/replacements (== `ub_updates` at k = 1)
    pub topk_updates: u64,
    /// LB_Keogh EC prunes achieved with *index-shared* reference
    /// envelopes (a subset of `lb_keogh_ec_prunes`): the pruning power
    /// attributable to the shared index rather than per-query work
    pub index_ec_prunes: u64,
    /// strips processed by the strip-mined scan (0 on the scalar path)
    pub strip_batches: u64,
    /// candidates pruned by the *batched* SoA bound stages (LB_Kim +
    /// unordered LB_Keogh over whole strips) — a subset of the per-bound
    /// prune counters above, attributing them to the batch front-end
    pub batch_lb_prunes: u64,
    /// full-DTW calls avoided by LB-ordered survivor evaluation: the
    /// survivor passed the batch bounds at the strip-entry threshold but
    /// was pruned against the threshold tightened *within* the strip by
    /// earlier (lower-bound-ordered) evaluations
    pub lb_order_saved_dtw_calls: u64,
    /// strips processed by a query-cohort scan — counted once per strip
    /// per shard (attributed to the first live member), so the total over
    /// a batch is the number of shared stat-strip loads actually performed
    pub cohort_strips: u64,
    /// per-shard query retirements in a cohort scan: the query's k-th
    /// best distance reached 0, so no later candidate can be accepted and
    /// its lanes drop out of the shard's remaining strips (a query
    /// retiring in every shard counts once per shard)
    pub cohort_retired_queries: u64,
    /// per-position window-stat loads a cohort scan avoided because the
    /// strip's shared (mean, std) lanes were loaded once for the whole
    /// cohort instead of once per query — `strip_len × (live members − 1)`
    /// per strip, attributed to the members that were served for free
    pub strip_stat_loads_saved: u64,
    /// raw-sample reads a cohort scan avoided because the strip's
    /// z-normalised LB_Kim endpoint lanes were loaded once for the whole
    /// cohort instead of per member — `endpoint reads per lane × strip_len
    /// × (live members − 1)` per strip, same invariant shape as
    /// `strip_stat_loads_saved` (loads performed + saved = sequential
    /// loads absent retirement)
    pub strip_sample_loads_saved: u64,
    /// kernel-workspace regrowth events observed by a cohort scan's
    /// shared pool: a warmed pool must reuse its capacity for every
    /// member of every strip, so this is asserted 0 within a cohort in
    /// debug builds — nonzero in release means the pool warm-up is wrong
    pub kernel_workspace_regrows: u64,
    /// eval-time rebuilds of cached cost-model tables (WDTW weights, ERP
    /// query-side prefix sums): a `QueryContext` prepares its
    /// [`crate::distances::cache::CostModelCache`] once per query, so any
    /// rebuild during candidate scoring means the hoisting regressed —
    /// asserted zero-per-query in the cohort conformance tests
    pub cost_model_rebuilds: u64,
    /// shard-worker panics caught by the worker loop's panic domain (or
    /// observed at shutdown join): the query maps to an `internal`
    /// `ErrorResponse` instead of deadlocking fan-in, and the supervisor
    /// respawns the thread — nonzero here means a scan bug fired, not
    /// that the service misbehaved
    pub worker_panics: u64,
    /// dead shard-worker threads respawned by the service supervisor (a
    /// panicked or exited worker is replaced before the query is retried)
    pub worker_respawns: u64,
    /// queries shed at admission because the pending-work budget
    /// (`--max-pending`) was exhausted — answered with an `overloaded`
    /// `ErrorResponse` instead of buffering unboundedly
    pub shed_queries: u64,
    /// queries whose deadline budget expired — at admission or at a strip
    /// boundary mid-scan — answered with a `timeout` error or a
    /// `partial: true` top-k
    pub deadline_timeouts: u64,
    /// TCP connections admitted by the network front-end's accept loop
    pub conns_accepted: u64,
    /// TCP connections refused at accept because the bounded registry
    /// (`--max-conns`) was full — answered with an `overloaded`
    /// `ErrorResponse` and closed, never buffered
    pub conns_rejected: u64,
    /// connections cut off because a frame stayed incomplete past the
    /// read timeout (slow-loris defence) — the reader thread is released,
    /// never pinned
    pub conn_read_timeouts: u64,
    /// queries shed by a per-tenant token bucket before any scan work —
    /// answered with a `quota` `ErrorResponse` carrying `retry_after_ms`
    pub quota_shed_queries: u64,
    /// multi-lane wavefront kernel invocations (lane groups of ≥ 2
    /// candidates evaluated in lockstep; lone survivors fall through to
    /// the scalar kernel and are not counted here)
    pub kernel_multi_calls: u64,
    /// candidate lanes evaluated across all multi-lane invocations — each
    /// lane also counts into `dtw_calls` (and its per-metric tally), so
    /// the `dtw_calls == dtw_abandons + dtw_completions` identity holds
    /// unchanged; `kernel_lanes_filled / kernel_multi_calls` is the mean
    /// lane occupancy the benches gate on
    pub kernel_lanes_filled: u64,
    /// lanes retired by per-lane early abandon inside a multi-lane
    /// invocation (a subset of `dtw_abandons`; `<= kernel_lanes_filled`)
    pub kernel_lane_abandons: u64,
    /// distance-kernel calls per metric kind, indexed by
    /// [`Metric::index`] (every entry also counts into `dtw_calls`)
    pub metric_calls: [u64; Metric::COUNT],
    /// early abandons per metric kind, same indexing (each also counts
    /// into `dtw_abandons`) — together with `metric_calls` this is the
    /// per-metric pruning-power tally the cross-metric benches compare
    pub metric_abandons: [u64; Metric::COUNT],
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one distance-kernel invocation under `metric` (feeds both
    /// the legacy `dtw_calls` aggregate and the per-metric tally).
    #[inline]
    pub fn record_metric_call(&mut self, metric: Metric) {
        self.dtw_calls += 1;
        self.metric_calls[metric.index()] += 1;
    }

    /// Record one early abandon under `metric`.
    #[inline]
    pub fn record_metric_abandon(&mut self, metric: Metric) {
        self.dtw_abandons += 1;
        self.metric_abandons[metric.index()] += 1;
    }

    /// Record the outcome of a kernel invocation already counted by
    /// [`Counters::record_metric_call`]: an early abandon or a completed
    /// evaluation — keeping `dtw_calls == dtw_abandons + dtw_completions`
    /// an invariant rather than a convention.
    #[inline]
    pub fn record_metric_outcome(&mut self, metric: Metric, abandoned: bool) {
        if abandoned {
            self.record_metric_abandon(metric);
        } else {
            self.dtw_completions += 1;
        }
    }

    /// Scalar counter fields, in declaration order — the fixed prefix of
    /// the slot mapping below.
    pub const SCALAR_SLOTS: usize = 34;

    /// Slot index of `worker_panics` — the service records supervision
    /// events straight into its [`crate::obs::ObsCell`] by slot (they
    /// never flow through a scan's `Counters`).
    pub const SLOT_WORKER_PANICS: usize = 23;
    /// Slot index of `worker_respawns`.
    pub const SLOT_WORKER_RESPAWNS: usize = 24;
    /// Slot index of `shed_queries`.
    pub const SLOT_SHED_QUERIES: usize = 25;
    /// Slot index of `deadline_timeouts`.
    pub const SLOT_DEADLINE_TIMEOUTS: usize = 26;
    /// Slot index of `conns_accepted` — the network front-end records
    /// connection events straight into the service cell by slot, like
    /// the supervision events above.
    pub const SLOT_CONNS_ACCEPTED: usize = 27;
    /// Slot index of `conns_rejected`.
    pub const SLOT_CONNS_REJECTED: usize = 28;
    /// Slot index of `conn_read_timeouts`.
    pub const SLOT_CONN_READ_TIMEOUTS: usize = 29;
    /// Slot index of `quota_shed_queries`.
    pub const SLOT_QUOTA_SHED_QUERIES: usize = 30;
    /// Slot index of `kernel_multi_calls`.
    pub const SLOT_KERNEL_MULTI_CALLS: usize = 31;
    /// Slot index of `kernel_lanes_filled`.
    pub const SLOT_KERNEL_LANES_FILLED: usize = 32;
    /// Slot index of `kernel_lane_abandons`.
    pub const SLOT_KERNEL_LANE_ABANDONS: usize = 33;

    /// Total number of slots in the canonical flat form: every scalar
    /// field plus the per-metric call/abandon tallies.
    pub const SLOT_COUNT: usize = Self::SCALAR_SLOTS + 2 * Metric::COUNT;

    /// Canonical slot names, index-aligned with [`Counters::slots`] /
    /// [`Counters::from_slots`]. This is the ONE field list the
    /// observability registry's atomic cells, the snapshot JSON schema and
    /// the bench reports all share — adding a counter means adding it
    /// here, to the two mapping functions, and to [`Counters::merge`].
    pub const SLOT_NAMES: [&'static str; Self::SLOT_COUNT] = [
        "candidates",
        "lb_kim_prunes",
        "lb_keogh_eq_prunes",
        "lb_keogh_ec_prunes",
        "lb_improved_prunes",
        "xla_prunes",
        "dtw_calls",
        "dtw_abandons",
        "dtw_completions",
        "ub_updates",
        "dp_cells",
        "index_hits",
        "topk_updates",
        "index_ec_prunes",
        "strip_batches",
        "batch_lb_prunes",
        "lb_order_saved_dtw_calls",
        "cohort_strips",
        "cohort_retired_queries",
        "strip_stat_loads_saved",
        "strip_sample_loads_saved",
        "kernel_workspace_regrows",
        "cost_model_rebuilds",
        "worker_panics",
        "worker_respawns",
        "shed_queries",
        "deadline_timeouts",
        "conns_accepted",
        "conns_rejected",
        "conn_read_timeouts",
        "quota_shed_queries",
        "kernel_multi_calls",
        "kernel_lanes_filled",
        "kernel_lane_abandons",
        "metric_calls_cdtw",
        "metric_calls_dtw",
        "metric_calls_wdtw",
        "metric_calls_erp",
        "metric_calls_msm",
        "metric_calls_twe",
        "metric_abandons_cdtw",
        "metric_abandons_dtw",
        "metric_abandons_wdtw",
        "metric_abandons_erp",
        "metric_abandons_msm",
        "metric_abandons_twe",
    ];

    /// Flatten into the canonical slot array (same order as
    /// [`Counters::SLOT_NAMES`]).
    pub fn slots(&self) -> [u64; Self::SLOT_COUNT] {
        let mut s = [0u64; Self::SLOT_COUNT];
        s[0] = self.candidates;
        s[1] = self.lb_kim_prunes;
        s[2] = self.lb_keogh_eq_prunes;
        s[3] = self.lb_keogh_ec_prunes;
        s[4] = self.lb_improved_prunes;
        s[5] = self.xla_prunes;
        s[6] = self.dtw_calls;
        s[7] = self.dtw_abandons;
        s[8] = self.dtw_completions;
        s[9] = self.ub_updates;
        s[10] = self.dp_cells;
        s[11] = self.index_hits;
        s[12] = self.topk_updates;
        s[13] = self.index_ec_prunes;
        s[14] = self.strip_batches;
        s[15] = self.batch_lb_prunes;
        s[16] = self.lb_order_saved_dtw_calls;
        s[17] = self.cohort_strips;
        s[18] = self.cohort_retired_queries;
        s[19] = self.strip_stat_loads_saved;
        s[20] = self.strip_sample_loads_saved;
        s[21] = self.kernel_workspace_regrows;
        s[22] = self.cost_model_rebuilds;
        s[Self::SLOT_WORKER_PANICS] = self.worker_panics;
        s[Self::SLOT_WORKER_RESPAWNS] = self.worker_respawns;
        s[Self::SLOT_SHED_QUERIES] = self.shed_queries;
        s[Self::SLOT_DEADLINE_TIMEOUTS] = self.deadline_timeouts;
        s[Self::SLOT_CONNS_ACCEPTED] = self.conns_accepted;
        s[Self::SLOT_CONNS_REJECTED] = self.conns_rejected;
        s[Self::SLOT_CONN_READ_TIMEOUTS] = self.conn_read_timeouts;
        s[Self::SLOT_QUOTA_SHED_QUERIES] = self.quota_shed_queries;
        s[Self::SLOT_KERNEL_MULTI_CALLS] = self.kernel_multi_calls;
        s[Self::SLOT_KERNEL_LANES_FILLED] = self.kernel_lanes_filled;
        s[Self::SLOT_KERNEL_LANE_ABANDONS] = self.kernel_lane_abandons;
        for i in 0..Metric::COUNT {
            s[Self::SCALAR_SLOTS + i] = self.metric_calls[i];
            s[Self::SCALAR_SLOTS + Metric::COUNT + i] = self.metric_abandons[i];
        }
        s
    }

    /// Rebuild from the canonical slot array — the exact inverse of
    /// [`Counters::slots`].
    pub fn from_slots(s: &[u64; Self::SLOT_COUNT]) -> Self {
        let mut c = Counters {
            candidates: s[0],
            lb_kim_prunes: s[1],
            lb_keogh_eq_prunes: s[2],
            lb_keogh_ec_prunes: s[3],
            lb_improved_prunes: s[4],
            xla_prunes: s[5],
            dtw_calls: s[6],
            dtw_abandons: s[7],
            dtw_completions: s[8],
            ub_updates: s[9],
            dp_cells: s[10],
            index_hits: s[11],
            topk_updates: s[12],
            index_ec_prunes: s[13],
            strip_batches: s[14],
            batch_lb_prunes: s[15],
            lb_order_saved_dtw_calls: s[16],
            cohort_strips: s[17],
            cohort_retired_queries: s[18],
            strip_stat_loads_saved: s[19],
            strip_sample_loads_saved: s[20],
            kernel_workspace_regrows: s[21],
            cost_model_rebuilds: s[22],
            worker_panics: s[Self::SLOT_WORKER_PANICS],
            worker_respawns: s[Self::SLOT_WORKER_RESPAWNS],
            shed_queries: s[Self::SLOT_SHED_QUERIES],
            deadline_timeouts: s[Self::SLOT_DEADLINE_TIMEOUTS],
            conns_accepted: s[Self::SLOT_CONNS_ACCEPTED],
            conns_rejected: s[Self::SLOT_CONNS_REJECTED],
            conn_read_timeouts: s[Self::SLOT_CONN_READ_TIMEOUTS],
            quota_shed_queries: s[Self::SLOT_QUOTA_SHED_QUERIES],
            kernel_multi_calls: s[Self::SLOT_KERNEL_MULTI_CALLS],
            kernel_lanes_filled: s[Self::SLOT_KERNEL_LANES_FILLED],
            kernel_lane_abandons: s[Self::SLOT_KERNEL_LANE_ABANDONS],
            ..Default::default()
        };
        for i in 0..Metric::COUNT {
            c.metric_calls[i] = s[Self::SCALAR_SLOTS + i];
            c.metric_abandons[i] = s[Self::SCALAR_SLOTS + Metric::COUNT + i];
        }
        c
    }

    /// Proportion of candidates each stage removed, as fractions of the
    /// total: (kim, keogh_eq, keogh_ec, improved, xla, dtw_reached) — the
    /// Fig. 5 inset row.
    pub fn prune_fractions(&self) -> (f64, f64, f64, f64, f64, f64) {
        let t = self.candidates.max(1) as f64;
        (
            self.lb_kim_prunes as f64 / t,
            self.lb_keogh_eq_prunes as f64 / t,
            self.lb_keogh_ec_prunes as f64 / t,
            self.lb_improved_prunes as f64 / t,
            self.xla_prunes as f64 / t,
            self.dtw_calls as f64 / t,
        )
    }

    /// Aggregate another worker's counters into this one.
    pub fn merge(&mut self, o: &Counters) {
        self.candidates += o.candidates;
        self.lb_kim_prunes += o.lb_kim_prunes;
        self.lb_keogh_eq_prunes += o.lb_keogh_eq_prunes;
        self.lb_keogh_ec_prunes += o.lb_keogh_ec_prunes;
        self.lb_improved_prunes += o.lb_improved_prunes;
        self.xla_prunes += o.xla_prunes;
        self.dtw_calls += o.dtw_calls;
        self.dtw_abandons += o.dtw_abandons;
        self.dtw_completions += o.dtw_completions;
        self.ub_updates += o.ub_updates;
        self.dp_cells += o.dp_cells;
        self.index_hits += o.index_hits;
        self.topk_updates += o.topk_updates;
        self.index_ec_prunes += o.index_ec_prunes;
        self.strip_batches += o.strip_batches;
        self.batch_lb_prunes += o.batch_lb_prunes;
        self.lb_order_saved_dtw_calls += o.lb_order_saved_dtw_calls;
        self.cohort_strips += o.cohort_strips;
        self.cohort_retired_queries += o.cohort_retired_queries;
        self.strip_stat_loads_saved += o.strip_stat_loads_saved;
        self.strip_sample_loads_saved += o.strip_sample_loads_saved;
        self.kernel_workspace_regrows += o.kernel_workspace_regrows;
        self.cost_model_rebuilds += o.cost_model_rebuilds;
        self.worker_panics += o.worker_panics;
        self.worker_respawns += o.worker_respawns;
        self.shed_queries += o.shed_queries;
        self.deadline_timeouts += o.deadline_timeouts;
        self.conns_accepted += o.conns_accepted;
        self.conns_rejected += o.conns_rejected;
        self.conn_read_timeouts += o.conn_read_timeouts;
        self.quota_shed_queries += o.quota_shed_queries;
        self.kernel_multi_calls += o.kernel_multi_calls;
        self.kernel_lanes_filled += o.kernel_lanes_filled;
        self.kernel_lane_abandons += o.kernel_lane_abandons;
        for i in 0..Metric::COUNT {
            self.metric_calls[i] += o.metric_calls[i];
            self.metric_abandons[i] += o.metric_abandons[i];
        }
    }

    /// One-line per-metric pruning-power report: kernel calls and the
    /// abandon rate for every metric that was actually exercised.
    pub fn metric_report(&self) -> String {
        let parts: Vec<String> = Metric::KIND_NAMES
            .iter()
            .enumerate()
            .filter(|&(i, _)| self.metric_calls[i] > 0)
            .map(|(i, name)| {
                let calls = self.metric_calls[i];
                let ab = self.metric_abandons[i];
                let rate = 100.0 * ab as f64 / calls as f64;
                format!("{name}: {calls} calls, {ab} abandons ({rate:.1}%)")
            })
            .collect();
        if parts.is_empty() {
            "no distance kernel calls".to_string()
        } else {
            parts.join(" | ")
        }
    }

    /// One-line report of the index subsystem's contribution: cache hits,
    /// heap activity, and how much of the EC pruning the shared envelopes
    /// delivered.
    pub fn index_report(&self) -> String {
        let ec_share = if self.lb_keogh_ec_prunes > 0 {
            100.0 * self.index_ec_prunes as f64 / self.lb_keogh_ec_prunes as f64
        } else {
            0.0
        };
        format!(
            "index: {} cache hits | top-k: {} heap updates | EC prunes via shared envelopes: {} ({ec_share:.1}% of EC) | strips: {} batches, {} batch-LB prunes, {} DTW calls saved by LB order",
            self.index_hits,
            self.topk_updates,
            self.index_ec_prunes,
            self.strip_batches,
            self.batch_lb_prunes,
            self.lb_order_saved_dtw_calls
        )
    }

    /// One-line report of the strip-mined scan front-end: how much of the
    /// pruning the batched bounds delivered and what LB-ordering saved.
    pub fn strip_report(&self) -> String {
        if self.strip_batches == 0 {
            return "strip scan not used (scalar path)".to_string();
        }
        let lb_total = self.lb_kim_prunes
            + self.lb_keogh_eq_prunes
            + self.lb_keogh_ec_prunes
            + self.lb_improved_prunes;
        let batch_share = if lb_total > 0 {
            100.0 * self.batch_lb_prunes as f64 / lb_total as f64
        } else {
            0.0
        };
        format!(
            "strips: {} batches | batch-LB prunes: {} ({batch_share:.1}% of all LB prunes) | DTW calls saved by LB order: {}",
            self.strip_batches, self.batch_lb_prunes, self.lb_order_saved_dtw_calls
        )
    }

    /// One-line report of the query-cohort batch scan: how much
    /// reference-side streaming the cohort amortised across its members.
    /// The stat-lane share is `loads saved / lane reads the cohort's
    /// members made` — the fraction of the cohort's own stat-lane reads
    /// served from the shared strip instead of loaded per query. With no
    /// retirement this equals the saving vs a sequential batch; a retired
    /// member stops reading entirely (an even bigger saving, but one with
    /// no per-read denominator to report against).
    pub fn cohort_report(&self) -> String {
        if self.cohort_strips == 0 {
            return "cohort scan not used (queries served solo)".to_string();
        }
        // the cohort performed (candidates − saved) of its members'
        // `candidates` lane reads itself; the rest came from sharing
        let share = if self.candidates > 0 {
            100.0 * self.strip_stat_loads_saved as f64 / self.candidates as f64
        } else {
            0.0
        };
        format!(
            "cohort: {} shared strips | stat-lane loads saved: {} ({share:.1}% of lane reads) | raw-sample loads saved: {} | per-shard query retirements: {} | workspace regrows: {}",
            self.cohort_strips,
            self.strip_stat_loads_saved,
            self.strip_sample_loads_saved,
            self.cohort_retired_queries,
            self.kernel_workspace_regrows
        )
    }
}

/// Simple scope timer for the bench reporters.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_sum_to_one_when_exhaustive() {
        let c = Counters {
            candidates: 100,
            lb_kim_prunes: 50,
            lb_keogh_eq_prunes: 25,
            lb_keogh_ec_prunes: 10,
            lb_improved_prunes: 5,
            xla_prunes: 0,
            dtw_calls: 10,
            ..Default::default()
        };
        let (a, b, d, im, x, e) = c.prune_fractions();
        assert!((im - 0.05).abs() < 1e-12);
        assert!((a + b + d + im + x + e - 1.0).abs() < 1e-12);
    }

    #[test]
    fn merge_adds() {
        let mut a = Counters { candidates: 3, dtw_calls: 1, topk_updates: 2, ..Default::default() };
        let b = Counters {
            candidates: 5,
            dtw_calls: 2,
            dp_cells: 7,
            index_hits: 4,
            topk_updates: 1,
            index_ec_prunes: 6,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.candidates, 8);
        assert_eq!(a.dtw_calls, 3);
        assert_eq!(a.dp_cells, 7);
        assert_eq!(a.index_hits, 4);
        assert_eq!(a.topk_updates, 3);
        assert_eq!(a.index_ec_prunes, 6);
    }

    #[test]
    fn strip_counters_merge_and_report() {
        let mut a = Counters { strip_batches: 2, batch_lb_prunes: 5, ..Default::default() };
        let b = Counters {
            strip_batches: 3,
            batch_lb_prunes: 7,
            lb_order_saved_dtw_calls: 4,
            lb_kim_prunes: 10,
            lb_keogh_eq_prunes: 14,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.strip_batches, 5);
        assert_eq!(a.batch_lb_prunes, 12);
        assert_eq!(a.lb_order_saved_dtw_calls, 4);
        let r = a.strip_report();
        assert!(r.contains("5 batches"), "{r}");
        assert!(r.contains("batch-LB prunes: 12"), "{r}");
        assert!(r.contains("saved by LB order: 4"), "{r}");
        assert!(r.contains("50.0% of all LB prunes"), "{r}");
        assert_eq!(Counters::new().strip_report(), "strip scan not used (scalar path)");
        // the index report mentions the strip counters too
        assert!(a.index_report().contains("5 batches"), "{}", a.index_report());
    }

    #[test]
    fn cohort_counters_merge_and_report() {
        let mut a = Counters {
            cohort_strips: 4,
            strip_stat_loads_saved: 100,
            strip_sample_loads_saved: 30,
            candidates: 400,
            ..Default::default()
        };
        let b = Counters {
            cohort_strips: 1,
            cohort_retired_queries: 2,
            strip_stat_loads_saved: 50,
            strip_sample_loads_saved: 12,
            kernel_workspace_regrows: 1,
            candidates: 200,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.cohort_strips, 5);
        assert_eq!(a.cohort_retired_queries, 2);
        assert_eq!(a.strip_stat_loads_saved, 150);
        assert_eq!(a.strip_sample_loads_saved, 42);
        assert_eq!(a.kernel_workspace_regrows, 1);
        let r = a.cohort_report();
        assert!(r.contains("5 shared strips"), "{r}");
        assert!(r.contains("stat-lane loads saved: 150"), "{r}");
        assert!(r.contains("25.0% of lane reads"), "{r}");
        assert!(r.contains("raw-sample loads saved: 42"), "{r}");
        assert!(r.contains("retirements: 2"), "{r}");
        assert!(r.contains("workspace regrows: 1"), "{r}");
        assert_eq!(
            Counters::new().cohort_report(),
            "cohort scan not used (queries served solo)"
        );
    }

    #[test]
    fn index_report_mentions_all_counters() {
        let c = Counters {
            index_hits: 3,
            topk_updates: 9,
            lb_keogh_ec_prunes: 10,
            index_ec_prunes: 5,
            ..Default::default()
        };
        let r = c.index_report();
        assert!(r.contains("3 cache hits"), "{r}");
        assert!(r.contains("9 heap updates"), "{r}");
        assert!(r.contains("50.0% of EC"), "{r}");
    }

    #[test]
    fn per_metric_tallies_feed_aggregates_and_merge() {
        let mut a = Counters::new();
        a.record_metric_call(Metric::Cdtw);
        a.record_metric_call(Metric::Erp { gap: 0.0 });
        a.record_metric_abandon(Metric::Erp { gap: 0.0 });
        assert_eq!(a.dtw_calls, 2);
        assert_eq!(a.dtw_abandons, 1);
        assert_eq!(a.metric_calls[Metric::Cdtw.index()], 1);
        assert_eq!(a.metric_calls[Metric::Erp { gap: 0.0 }.index()], 1);
        assert_eq!(a.metric_abandons[Metric::Erp { gap: 0.0 }.index()], 1);
        let mut b = Counters::new();
        b.record_metric_call(Metric::Erp { gap: 0.5 });
        b.merge(&a);
        assert_eq!(b.metric_calls[Metric::Erp { gap: 0.0 }.index()], 2);
        let r = b.metric_report();
        assert!(r.contains("erp: 2 calls"), "{r}");
        assert!(r.contains("cdtw: 1 calls"), "{r}");
        assert_eq!(Counters::new().metric_report(), "no distance kernel calls");
    }

    #[test]
    fn slot_mapping_round_trips_and_covers_every_field() {
        let mut c = Counters::new();
        // give every slot a distinct value so a swapped index can't pass
        let mut v = 1u64;
        c.candidates = v;
        for f in [
            &mut c.lb_kim_prunes,
            &mut c.lb_keogh_eq_prunes,
            &mut c.lb_keogh_ec_prunes,
            &mut c.lb_improved_prunes,
            &mut c.xla_prunes,
            &mut c.dtw_calls,
            &mut c.dtw_abandons,
            &mut c.dtw_completions,
            &mut c.ub_updates,
            &mut c.dp_cells,
            &mut c.index_hits,
            &mut c.topk_updates,
            &mut c.index_ec_prunes,
            &mut c.strip_batches,
            &mut c.batch_lb_prunes,
            &mut c.lb_order_saved_dtw_calls,
            &mut c.cohort_strips,
            &mut c.cohort_retired_queries,
            &mut c.strip_stat_loads_saved,
            &mut c.strip_sample_loads_saved,
            &mut c.kernel_workspace_regrows,
            &mut c.cost_model_rebuilds,
            &mut c.worker_panics,
            &mut c.worker_respawns,
            &mut c.shed_queries,
            &mut c.deadline_timeouts,
            &mut c.conns_accepted,
            &mut c.conns_rejected,
            &mut c.conn_read_timeouts,
            &mut c.quota_shed_queries,
            &mut c.kernel_multi_calls,
            &mut c.kernel_lanes_filled,
            &mut c.kernel_lane_abandons,
        ] {
            v += 1;
            *f = v;
        }
        for i in 0..Metric::COUNT {
            v += 1;
            c.metric_calls[i] = v;
        }
        for i in 0..Metric::COUNT {
            v += 1;
            c.metric_abandons[i] = v;
        }
        let s = c.slots();
        // all distinct → nothing collapsed, nothing dropped
        assert_eq!(s.len(), Counters::SLOT_COUNT);
        let mut sorted = s.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), Counters::SLOT_COUNT);
        assert_eq!(Counters::from_slots(&s), c);
        // names are index-aligned and the per-metric suffixes match the
        // metric kind names
        assert_eq!(Counters::SLOT_NAMES.len(), Counters::SLOT_COUNT);
        for (i, name) in Metric::KIND_NAMES.iter().enumerate() {
            assert_eq!(
                Counters::SLOT_NAMES[Counters::SCALAR_SLOTS + i],
                format!("metric_calls_{name}")
            );
            assert_eq!(
                Counters::SLOT_NAMES[Counters::SCALAR_SLOTS + Metric::COUNT + i],
                format!("metric_abandons_{name}")
            );
        }
    }

    #[test]
    fn robustness_slot_constants_are_name_aligned() {
        // the service records supervision events by slot index; a drifted
        // constant would silently credit the wrong counter
        for (slot, name) in [
            (Counters::SLOT_WORKER_PANICS, "worker_panics"),
            (Counters::SLOT_WORKER_RESPAWNS, "worker_respawns"),
            (Counters::SLOT_SHED_QUERIES, "shed_queries"),
            (Counters::SLOT_DEADLINE_TIMEOUTS, "deadline_timeouts"),
            (Counters::SLOT_CONNS_ACCEPTED, "conns_accepted"),
            (Counters::SLOT_CONNS_REJECTED, "conns_rejected"),
            (Counters::SLOT_CONN_READ_TIMEOUTS, "conn_read_timeouts"),
            (Counters::SLOT_QUOTA_SHED_QUERIES, "quota_shed_queries"),
            (Counters::SLOT_KERNEL_MULTI_CALLS, "kernel_multi_calls"),
            (Counters::SLOT_KERNEL_LANES_FILLED, "kernel_lanes_filled"),
            (Counters::SLOT_KERNEL_LANE_ABANDONS, "kernel_lane_abandons"),
        ] {
            assert_eq!(Counters::SLOT_NAMES[slot], name);
            assert!(slot < Counters::SCALAR_SLOTS);
        }
    }

    #[test]
    fn outcome_recording_keeps_calls_equal_abandons_plus_completions() {
        let mut c = Counters::new();
        for abandoned in [true, false, false, true, false] {
            c.record_metric_call(Metric::Cdtw);
            c.record_metric_outcome(Metric::Cdtw, abandoned);
        }
        assert_eq!(c.dtw_calls, 5);
        assert_eq!(c.dtw_abandons, 2);
        assert_eq!(c.dtw_completions, 3);
        assert_eq!(c.dtw_calls, c.dtw_abandons + c.dtw_completions);
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::hint::black_box((0..1000).sum::<u64>());
        assert!(t.elapsed_secs() >= 0.0);
    }
}
