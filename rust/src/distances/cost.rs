//! Point-wise cost functions: the paper (and the UCR suite) use squared
//! Euclidean; the elastic cost models reuse these for gap/match costs.

/// Squared Euclidean distance between two points — the default DTW cost.
#[inline(always)]
pub fn sqed(a: f64, b: f64) -> f64 {
    let d = a - b;
    d * d
}

/// Absolute difference — the classic MSM/TWE point cost.
#[inline(always)]
pub fn absd(a: f64, b: f64) -> f64 {
    (a - b).abs()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sqed_basic() {
        assert_eq!(sqed(3.0, 1.0), 4.0);
        assert_eq!(sqed(1.0, 3.0), 4.0);
        assert_eq!(sqed(2.5, 2.5), 0.0);
    }

    #[test]
    fn absd_basic() {
        assert_eq!(absd(3.0, 1.0), 2.0);
        assert_eq!(absd(-1.0, 1.0), 2.0);
    }
}
