//! Algorithm 2 of the paper: pruning and early abandoning **from the
//! left** only — the pedagogical stepping stone to Algorithm 3. Discard
//! points (`> ub` runs at the left border) advance `next_start`; a fully
//! swallowed line abandons (paper Fig. 3b).

use super::{lines_cols, DtwWorkspace};
use crate::distances::cost::sqed;

/// Paper Algorithm 2, verbatim (unwindowed). Returns `+inf` if the true
/// DTW strictly exceeds `ub`, the exact distance otherwise.
pub fn left_pruned_dtw(a: &[f64], b: &[f64], ub: f64, ws: &mut DtwWorkspace) -> f64 {
    if a.is_empty() || b.is_empty() {
        return if a.len() == b.len() { 0.0 } else { f64::INFINITY };
    }
    let (li, co) = lines_cols(a, b);
    let m = co.len();
    ws.reset(m);
    ws.curr[0] = 0.0;
    let mut next_start = 1usize;
    for i in 1..=li.len() {
        std::mem::swap(&mut ws.prev, &mut ws.curr);
        let v = li[i - 1];
        let mut j = next_start;
        ws.curr[j - 1] = f64::INFINITY;
        // Stage 1: advance over discard points — the left neighbour is
        // known `> ub`, so only two dependencies (Algorithm 2 line 12).
        while j == next_start && j <= m {
            let c = sqed(v, co[j - 1]);
            let d = c + ws.prev[j].min(ws.prev[j - 1]);
            ws.curr[j] = d;
            if d > ub {
                next_start += 1;
            }
            j += 1;
        }
        // Early abandon: the border crossed the whole line (line 15).
        if j > m && next_start == j {
            return f64::INFINITY;
        }
        // Stage 2: plain DTW for the rest of the line.
        while j <= m {
            let c = sqed(v, co[j - 1]);
            ws.curr[j] = c + ws.curr[j - 1].min(ws.prev[j]).min(ws.prev[j - 1]);
            j += 1;
        }
    }
    ws.curr[m]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::dtw::dtw;

    const S: [f64; 6] = [3.0, 1.0, 4.0, 4.0, 1.0, 1.0];
    const T: [f64; 6] = [1.0, 3.0, 2.0, 1.0, 2.0, 2.0];

    fn lp(a: &[f64], b: &[f64], ub: f64) -> f64 {
        left_pruned_dtw(a, b, ub, &mut DtwWorkspace::default())
    }

    #[test]
    fn paper_fig3a_ub9_no_abandon() {
        // ub = 9 = DTW(S,T): pruning happens but the exact value survives.
        assert_eq!(lp(&S, &T, 9.0), 9.0);
    }

    #[test]
    fn paper_fig3b_ub6_abandons() {
        // ub = 6 < 9: the paper shows early abandon at the end of line 5.
        assert_eq!(lp(&S, &T, 6.0), f64::INFINITY);
    }

    #[test]
    fn infinite_ub_is_exact_dtw() {
        assert_eq!(lp(&S, &T, f64::INFINITY), dtw(&S, &T));
    }

    #[test]
    fn random_exactness() {
        let mut x = 7u64;
        let mut rnd = move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 2.0 - 1.0
        };
        for n in [4usize, 12, 33, 64] {
            let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
            let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
            let exact = dtw(&a, &b);
            assert!((lp(&a, &b, f64::INFINITY) - exact).abs() < 1e-12);
            assert!((lp(&a, &b, exact) - exact).abs() < 1e-12, "tie kept");
            // below the true distance: abandon is opportunistic for the
            // left-only algorithm — it may return an (over-approximated)
            // value > ub instead, but never an underestimate
            let lo = lp(&a, &b, exact - exact.abs() * 1e-6 - 1e-9);
            assert!(lo.is_infinite() || lo >= exact - 1e-9, "{lo} vs {exact}");
            // any ub above the distance keeps exactness
            assert!((lp(&a, &b, exact * 1.5 + 1.0) - exact).abs() < 1e-12);
        }
    }

    #[test]
    fn unequal_lengths() {
        let a = [1.0, 2.0, 3.0, 2.0, 1.0, 0.0, 1.0];
        let b = [1.0, 3.0, 1.0];
        assert_eq!(lp(&a, &b, f64::INFINITY), dtw(&a, &b));
    }
}
