//! Per-query cost-model table cache (PR 5 follow-up): WDTW's sigmoid
//! weight table and ERP's query-side gap prefix sums depend only on the
//! query (and the metric's parameters), yet the owned cost models rebuild
//! — and reallocate — them for **every candidate**. A scan holds one
//! [`CostModelCache`] in its `QueryContext`, prepares it once, and scores
//! candidates through [`crate::distances::metric::Metric::eval_outcome_cached`]:
//! bitwise identical to the owned path (both route through the same table
//! builders) with zero per-candidate allocation. Eval-time rebuilds —
//! which should never happen within one query — are counted and surfaced
//! as [`crate::metrics::Counters::cost_model_rebuilds`], asserted zero in
//! the cohort conformance tests.

use crate::distances::elastic::erp::erp_acc_into;
use crate::distances::elastic::wdtw::wdtw_weights_into;
use crate::distances::metric::Metric;

/// Cached query-side tables for the parameterised cost models. The cache
/// belongs to exactly one query context: the ERP column table holds
/// prefix sums of the *values* of the query it was prepared with, so
/// reusing a cache across different queries of the same length without
/// re-preparing would be wrong — `QueryContext::build` prepares it, and
/// the eval path only ever passes that context's query back in.
#[derive(Debug, Default, Clone)]
pub struct CostModelCache {
    /// `(len, g.to_bits())` the weight table was built for.
    wdtw_key: Option<(usize, u64)>,
    wdtw_weights: Vec<f64>,
    /// `(qlen, gap.to_bits())` the column table was built for.
    erp_key: Option<(usize, u64)>,
    erp_col_acc: Vec<f64>,
    /// Candidate-side prefix sums, rebuilt in place per candidate (the
    /// values change with every candidate; only the allocation is hoisted).
    erp_row_acc: Vec<f64>,
    rebuilds: u64,
}

impl CostModelCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build the query-side tables for scoring candidates of `q`'s length
    /// under `metric`. A no-op for metrics without query-side tables.
    pub fn prepare(&mut self, metric: Metric, q: &[f64]) {
        match metric {
            Metric::Wdtw { g } => {
                // Subsequence candidates share the query's length, so the
                // weight table for `q.len()` serves every evaluation.
                wdtw_weights_into(q.len(), g, &mut self.wdtw_weights);
                self.wdtw_key = Some((q.len(), g.to_bits()));
            }
            Metric::Erp { gap } => {
                erp_acc_into(q, gap, &mut self.erp_col_acc);
                self.erp_key = Some((q.len(), gap.to_bits()));
                self.erp_row_acc.clear();
                self.erp_row_acc.reserve(q.len() + 1);
            }
            _ => {}
        }
    }

    /// The WDTW weight table for `(len, g)`, rebuilding (and counting a
    /// rebuild) on a key miss — e.g. an NN1 candidate longer than the
    /// query.
    #[inline]
    pub(crate) fn wdtw_weights(&mut self, len: usize, g: f64) -> &[f64] {
        if self.wdtw_key != Some((len, g.to_bits())) {
            if self.wdtw_key.is_some() {
                self.rebuilds += 1;
            }
            wdtw_weights_into(len, g, &mut self.wdtw_weights);
            self.wdtw_key = Some((len, g.to_bits()));
        }
        &self.wdtw_weights
    }

    /// The ERP border tables for query `q` and candidate `c`: the column
    /// table from the cache (rebuilt, counted, on a key miss), the row
    /// table recomputed into the reused buffer. Returns `(col, row)`.
    #[inline]
    pub(crate) fn erp_accs(&mut self, q: &[f64], c: &[f64], gap: f64) -> (&[f64], &[f64]) {
        if self.erp_key != Some((q.len(), gap.to_bits())) {
            if self.erp_key.is_some() {
                self.rebuilds += 1;
            }
            erp_acc_into(q, gap, &mut self.erp_col_acc);
            self.erp_key = Some((q.len(), gap.to_bits()));
        }
        erp_acc_into(c, gap, &mut self.erp_row_acc);
        (&self.erp_col_acc, &self.erp_row_acc)
    }

    /// Drain the eval-time rebuild count (see
    /// [`crate::metrics::Counters::cost_model_rebuilds`]).
    #[inline]
    pub fn take_rebuilds(&mut self) -> u64 {
        std::mem::take(&mut self.rebuilds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepare_then_hit_counts_no_rebuilds() {
        let q = [0.5, -0.25, 1.0, 0.0];
        let mut cache = CostModelCache::new();
        cache.prepare(Metric::Wdtw { g: 0.05 }, &q);
        for _ in 0..3 {
            let w = cache.wdtw_weights(q.len(), 0.05);
            assert_eq!(w.len(), q.len() + 1);
        }
        assert_eq!(cache.take_rebuilds(), 0);
        // a different length is a miss and counts
        cache.wdtw_weights(q.len() + 2, 0.05);
        assert_eq!(cache.take_rebuilds(), 1);
    }

    #[test]
    fn erp_column_table_caches_and_row_table_rebuilds_in_place() {
        let q = [1.0, 2.0, 3.0];
        let c1 = [0.0, 1.0, 0.5];
        let c2 = [2.0, -1.0, 0.25];
        let mut cache = CostModelCache::new();
        cache.prepare(Metric::Erp { gap: 0.0 }, &q);
        let (col_a, row_a) = cache.erp_accs(&q, &c1, 0.0);
        assert_eq!(col_a.len(), q.len() + 1);
        let row_a = row_a.to_vec();
        let (_, row_b) = cache.erp_accs(&q, &c2, 0.0);
        assert_ne!(row_a, row_b.to_vec());
        assert_eq!(cache.take_rebuilds(), 0);
        // changing the gap invalidates the column table
        cache.erp_accs(&q, &c1, 0.5);
        assert_eq!(cache.take_rebuilds(), 1);
    }

    #[test]
    fn unprepared_cache_builds_without_counting_a_rebuild() {
        let q = [1.0, 0.0];
        let mut cache = CostModelCache::new();
        cache.wdtw_weights(q.len(), 0.1);
        cache.erp_accs(&q, &q, 0.0);
        assert_eq!(cache.take_rebuilds(), 0);
    }
}
