//! Early-abandoned DTW, UCR-suite style (paper §2.2 and [14]): banded DTW
//! keeping each line's minimum, abandoning once it *strictly* exceeds the
//! upper bound (strictness keeps ties), with optional per-line tightening
//! from the cumulative LB_Keogh tail `cb`. The `Suite::Ucr` comparator
//! core — a distinct algorithm, deliberately NOT folded into the unified
//! EAPruned kernel.

use super::DtwWorkspace;
use crate::distances::cost::sqed;

/// Early-abandoned banded DTW. `query` plays the lines, `cand` the columns;
/// both must have equal length (the subsequence-search setting). `cb`, if
/// given, is the cumulative LB_Keogh tail over `cand` positions
/// (`cb[j] = sum of per-position bound contributions from j to end`,
/// `cb.len() == cand.len() + 1`, `cb[len] = 0`).
pub fn dtw_ea(
    query: &[f64],
    cand: &[f64],
    w: usize,
    ub: f64,
    cb: Option<&[f64]>,
    ws: &mut DtwWorkspace,
) -> f64 {
    let n = query.len();
    let m = cand.len();
    debug_assert_eq!(n, m, "subsequence search uses equal lengths");
    if n == 0 {
        return 0.0;
    }
    if let Some(cb) = cb {
        debug_assert_eq!(cb.len(), m + 1);
    }
    ws.reset(m);
    ws.curr[0] = 0.0;
    for i in 1..=n {
        std::mem::swap(&mut ws.prev, &mut ws.curr);
        let lo = i.saturating_sub(w).max(1);
        let hi = (i + w).min(m);
        ws.curr[lo - 1] = f64::INFINITY;
        let v = query[i - 1];
        let mut line_min = f64::INFINITY;
        let mut left = f64::INFINITY; // register-carried curr[j-1]
        for j in lo..=hi {
            let c = sqed(v, cand[j - 1]);
            let bp = ws.prev[j].min(ws.prev[j - 1]);
            let d = c + left.min(bp);
            ws.curr[j] = d;
            left = d;
            if d < line_min {
                line_min = d;
            }
        }
        if hi + 1 <= m {
            ws.curr[hi + 1] = f64::INFINITY;
        }
        // UCR-style abandon: future cost of any path through this line is
        // at least cb[min(i+w+1, m)] (0 without cb).
        let future = cb.map_or(0.0, |cb| cb[(i + w + 1).min(m)]);
        if line_min + future > ub {
            return f64::INFINITY;
        }
    }
    ws.curr[m]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::dtw::{cdtw, dtw};

    const S: [f64; 6] = [3.0, 1.0, 4.0, 4.0, 1.0, 1.0];
    const T: [f64; 6] = [1.0, 3.0, 2.0, 1.0, 2.0, 2.0];

    fn ea(q: &[f64], c: &[f64], w: usize, ub: f64) -> f64 {
        dtw_ea(q, c, w, ub, None, &mut DtwWorkspace::default())
    }

    #[test]
    fn no_ub_matches_dtw() {
        assert_eq!(ea(&S, &T, 6, f64::INFINITY), dtw(&S, &T));
        for w in 0..=6 {
            assert_eq!(ea(&S, &T, w, f64::INFINITY), cdtw(&S, &T, w));
        }
    }

    #[test]
    fn exact_when_at_most_ub() {
        // ub equal to the true distance: ties must NOT be abandoned.
        assert_eq!(ea(&S, &T, 6, 9.0), 9.0);
    }

    #[test]
    fn never_underestimates_below_ub() {
        // Row-min early abandon is *opportunistic* (the paper's point in
        // §4: PrunedDTW/UCR-style EA can fail to trigger): with ub below
        // the true distance the result is either +inf (abandoned) or the
        // exact value — never something smaller.
        for ub in [0.0, 3.0, 6.0, 8.999] {
            let got = ea(&S, &T, 6, ub);
            assert!(got.is_infinite() || got == 9.0, "ub={ub}: {got}");
        }
    }

    #[test]
    fn cb_tail_triggers_earlier_abandon_but_stays_exact() {
        // A valid cb (all zeros) must not change the result.
        let cb = vec![0.0; T.len() + 1];
        let got = dtw_ea(&S, &T, 6, 9.0, Some(&cb), &mut DtwWorkspace::default());
        assert_eq!(got, 9.0);
    }

    #[test]
    fn random_equivalence_with_cdtw() {
        let mut x = 99u64;
        let mut rnd = move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 2.0 - 1.0
        };
        for n in [8usize, 16, 31] {
            let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
            let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
            for w in [1usize, n / 4, n] {
                let exact = cdtw(&a, &b, w);
                assert!((ea(&a, &b, w, f64::INFINITY) - exact).abs() < 1e-12);
                assert_eq!(ea(&a, &b, w, exact), exact, "tie must be kept");
                let below = ea(&a, &b, w, exact * 0.999 - 1e-9);
                assert!(
                    below.is_infinite() || (below - exact).abs() < 1e-12,
                    "opportunistic abandon must not underestimate: {below} vs {exact}"
                );
            }
        }
    }
}
