//! Generalised EAPruned skeleton: Algorithm 3 lifted over an arbitrary
//! elastic cost structure.
//!
//! Differences from the DTW-specialised [`crate::distances::eap_dtw`]:
//!
//! * Moves carry distinct costs (`diag`/`top`/`left` into cell `(i,j)`).
//! * Borders may be **finite** (ERP's row 0 / column 0 accumulate gap
//!   penalties). Finite borders interact with pruning: a discard point may
//!   only extend the left border if everything to its left — including the
//!   border column — exceeds the threshold, so stage 1 gates
//!   `next_start += 1` on `curr[j-1] > ub` (the sentinel `+inf` for
//!   DTW-like models, the live border value for ERP). Likewise the initial
//!   pruning point is the first row-0 border cell above `ub` rather than 1.
//! * Border functions MUST be non-decreasing (all move costs are `>= 0`),
//!   which every model here satisfies; `debug_assert`ed in the scan.
//!
//! Stage 1 keeps the three-way min (the left dependency may be a live
//! border) — the extensions trade a little of the paper's stage-1 saving
//! for generality; stages 3 and 4 keep the 1-/2-dependency updates.

use crate::distances::DtwWorkspace;

/// An elastic distance's cost structure. Indices are 1-based (DP
/// convention); implementations read their series with `[i-1]`.
pub trait ElasticModel {
    /// Number of points in the "lines" series.
    fn n_lines(&self) -> usize;
    /// Number of points in the "columns" series.
    fn n_cols(&self) -> usize;
    /// Cost of the diagonal (match) move into `(i, j)`.
    fn diag(&self, i: usize, j: usize) -> f64;
    /// Cost of the vertical move into `(i, j)` (consume line point `i`).
    fn top(&self, i: usize, j: usize) -> f64;
    /// Cost of the horizontal move into `(i, j)` (consume column point `j`).
    fn left(&self, i: usize, j: usize) -> f64;
    /// Border row `D(0, j)`, `j >= 1`; non-decreasing in `j`.
    fn border_row(&self, _j: usize) -> f64 {
        f64::INFINITY
    }
    /// Border column `D(i, 0)`, `i >= 1`; non-decreasing in `i`.
    fn border_col(&self, _i: usize) -> f64 {
        f64::INFINITY
    }
}

/// EAPruned evaluation of an [`ElasticModel`] under a Sakoe-Chiba band `w`:
/// exact distance when it is `<= ub`, `+inf` once provably above.
pub fn eap_elastic<M: ElasticModel>(
    model: &M,
    w: usize,
    ub: f64,
    ws: &mut DtwWorkspace,
) -> f64 {
    let n = model.n_lines();
    let m = model.n_cols();
    if n == 0 || m == 0 {
        return if n == m { 0.0 } else { f64::INFINITY };
    }
    if n.abs_diff(m) > w {
        return f64::INFINITY;
    }
    ws.reset(m);
    // Row 0: the border row. The initial pruning point is the first border
    // cell strictly above ub (everything after it stays above — borders are
    // non-decreasing).
    ws.curr[0] = 0.0;
    // Row-0 cells beyond the band (j > w) are unreachable (+inf), so the
    // initial pruning point is the first border cell above ub, else one
    // past the last in-band border cell.
    let row0_end = m.min(w);
    let mut ppp = row0_end + 1;
    let mut prev_border = 0.0f64;
    for j in 1..=row0_end {
        let b = model.border_row(j);
        debug_assert!(b >= prev_border, "border_row must be non-decreasing");
        prev_border = b;
        ws.curr[j] = b;
        if b > ub {
            ppp = j;
            break;
        }
    }

    let mut next_start = 1usize;
    let mut pp = 0usize;

    for i in 1..=n {
        std::mem::swap(&mut ws.prev, &mut ws.curr);
        let band_lo = i.saturating_sub(w).max(1);
        let band_hi = i.checked_add(w).map_or(m, |x| x.min(m));
        if band_lo > next_start {
            next_start = band_lo;
        }
        let prev = &mut ws.prev;
        let curr = &mut ws.curr;
        let mut j = next_start;
        // Left sentinel: the live border for column 0, +inf otherwise.
        // `left` register-carries curr[j-1] across the stages (see
        // eap_dtw.rs — keeps the loop-carried FP chain short).
        let mut left = if j == 1 { model.border_col(i) } else { f64::INFINITY };
        curr[j - 1] = left;

        // Stage 1: discard-point region. Three-way min (the left value may
        // be a finite border); next_start may advance only while the left
        // value is itself above the threshold (continuity over borders).
        while j == next_start && j < ppp {
            let left_v = left;
            let d = (prev[j] + model.top(i, j))
                .min(prev[j - 1] + model.diag(i, j))
                .min(left_v + model.left(i, j));
            curr[j] = d;
            left = d;
            if d <= ub {
                pp = j + 1;
            } else if left_v > ub {
                next_start += 1;
            }
            j += 1;
        }
        // Stage 2: interior.
        while j < ppp {
            let bp = (prev[j] + model.top(i, j)).min(prev[j - 1] + model.diag(i, j));
            let d = bp.min(left + model.left(i, j));
            curr[j] = d;
            left = d;
            if d <= ub {
                pp = j + 1;
            }
            j += 1;
        }
        // Stage 3: the previous pruning point's column (top dep excluded —
        // cells (i-1, j' >= ppp) are all above ub).
        if j <= band_hi {
            let left_v = left;
            let d = (prev[j - 1] + model.diag(i, j)).min(left_v + model.left(i, j));
            curr[j] = d;
            left = d;
            if d <= ub {
                pp = j + 1;
            } else if j == next_start && left_v > ub {
                // Border collision: everything left of this cell — including
                // a possibly-finite border column — exceeds ub, and so does
                // this cell: nothing viable remains. (A live ERP border
                // `<= ub` blocks the abandon: paths may still re-enter.)
                return f64::INFINITY;
            }
            j += 1;
        } else if j == next_start {
            // Discard points swallowed the line. Sound even with finite
            // borders: stage 1 only advances next_start over cells whose
            // left value is above ub.
            return f64::INFINITY;
        }
        // Stage 4: right of the pruning point (left dep only).
        while j == pp && j <= band_hi {
            let d = left + model.left(i, j);
            curr[j] = d;
            left = d;
            if d <= ub {
                pp = j + 1;
            }
            j += 1;
        }
        ppp = pp;
    }
    if ppp > m {
        ws.curr[m]
    } else {
        f64::INFINITY
    }
}

/// Naive full-matrix evaluation of an [`ElasticModel`] — the oracle.
pub fn naive_elastic<M: ElasticModel>(model: &M, w: usize) -> f64 {
    let n = model.n_lines();
    let m = model.n_cols();
    if n == 0 || m == 0 {
        return if n == m { 0.0 } else { f64::INFINITY };
    }
    let mut d = vec![vec![f64::INFINITY; m + 1]; n + 1];
    d[0][0] = 0.0;
    for j in 1..=m.min(w) {
        d[0][j] = model.border_row(j);
    }
    for i in 1..=n.min(w) {
        d[i][0] = model.border_col(i);
    }
    for i in 1..=n {
        for j in 1..=m {
            if i.abs_diff(j) > w {
                continue;
            }
            let mut best = f64::INFINITY;
            if d[i - 1][j].is_finite() {
                best = best.min(d[i - 1][j] + model.top(i, j));
            }
            if d[i - 1][j - 1].is_finite() {
                best = best.min(d[i - 1][j - 1] + model.diag(i, j));
            }
            if d[i][j - 1].is_finite() {
                best = best.min(d[i][j - 1] + model.left(i, j));
            }
            d[i][j] = best;
        }
    }
    d[n][m]
}

/// DTW expressed as an [`ElasticModel`] — the sanity anchor for the
/// skeleton, and the A2 ablation comparator: running DTW through the
/// generic skeleton keeps EAP's borders/pruning/collision logic but gives
/// up the specialised 1-/2-dependency stage updates, isolating what the
/// paper's stage decomposition itself is worth.
pub struct DtwAsElastic<'a> {
    pub li: &'a [f64],
    pub co: &'a [f64],
}

impl ElasticModel for DtwAsElastic<'_> {
    fn n_lines(&self) -> usize {
        self.li.len()
    }
    fn n_cols(&self) -> usize {
        self.co.len()
    }
    fn diag(&self, i: usize, j: usize) -> f64 {
        crate::distances::cost::sqed(self.li[i - 1], self.co[j - 1])
    }
    fn top(&self, i: usize, j: usize) -> f64 {
        self.diag(i, j)
    }
    fn left(&self, i: usize, j: usize) -> f64 {
        self.diag(i, j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::dtw::cdtw;

    type DtwModel<'a> = DtwAsElastic<'a>;

    const S: [f64; 6] = [3.0, 1.0, 4.0, 4.0, 1.0, 1.0];
    const T: [f64; 6] = [1.0, 3.0, 2.0, 1.0, 2.0, 2.0];

    #[test]
    fn skeleton_reproduces_dtw() {
        let model = DtwModel { li: &S, co: &T };
        let mut ws = DtwWorkspace::default();
        assert_eq!(eap_elastic(&model, 6, f64::INFINITY, &mut ws), 9.0);
        assert_eq!(eap_elastic(&model, 6, 9.0, &mut ws), 9.0);
        assert_eq!(eap_elastic(&model, 6, 6.0, &mut ws), f64::INFINITY);
        for w in 0..=6usize {
            assert_eq!(
                eap_elastic(&model, w, f64::INFINITY, &mut ws),
                cdtw(&S, &T, w),
                "w={w}"
            );
        }
    }

    #[test]
    fn skeleton_matches_naive_random() {
        let mut x = 31u64;
        let mut rnd = move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 2.0 - 1.0
        };
        let mut ws = DtwWorkspace::default();
        for n in [6usize, 13, 25] {
            let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
            let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
            let model = DtwModel { li: &a, co: &b };
            for w in [1usize, n / 2, n] {
                let want = naive_elastic(&model, w);
                let got = eap_elastic(&model, w, f64::INFINITY, &mut ws);
                assert!((got - want).abs() < 1e-12, "n={n} w={w}");
                let tie = eap_elastic(&model, w, want, &mut ws);
                assert!((tie - want).abs() < 1e-12);
                assert_eq!(
                    eap_elastic(&model, w, want - want.abs() * 1e-6 - 1e-9, &mut ws),
                    f64::INFINITY
                );
            }
        }
    }
}
