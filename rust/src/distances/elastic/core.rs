//! Compatibility surface of the generalised EAPruned skeleton, now a thin
//! veneer over the unified band kernel: the retired generic skeleton
//! survives only as a bitwise test oracle in `kernel.rs`, its trait is
//! today's [`CostModel`] (re-exported under the historical name
//! [`ElasticModel`]), and [`eap_elastic`] / [`naive_elastic`] are the old
//! entry points delegating to the kernel.

use crate::distances::kernel::{eap_kernel, CostModel};
use crate::distances::KernelWorkspace;

pub use crate::distances::kernel::naive_kernel as naive_elastic;
pub use crate::distances::kernel::CostModel as ElasticModel;

/// EAPruned evaluation of a [`CostModel`]: the historical distance-only
/// entry point; callers that want exact abandon attribution use
/// [`eap_kernel`] directly.
pub fn eap_elastic<M: CostModel>(
    model: &M,
    w: usize,
    ub: f64,
    ws: &mut KernelWorkspace,
) -> f64 {
    eap_kernel(model, w, ub, None, ws).dist
}

/// DTW expressed as a **non-uniform** [`CostModel`] — the A2 ablation
/// comparator: running DTW through the generalised stage bodies keeps
/// EAP's borders/pruning/collision logic but gives up the specialised
/// 1-/2-dependency updates the `UNIFORM` const enables, isolating what
/// the paper's stage decomposition itself is worth
/// (`benches/ablation_stages.rs`).
pub struct DtwAsElastic<'a> {
    pub li: &'a [f64],
    pub co: &'a [f64],
}

impl CostModel for DtwAsElastic<'_> {
    fn n_lines(&self) -> usize {
        self.li.len()
    }
    fn n_cols(&self) -> usize {
        self.co.len()
    }
    fn diag(&self, i: usize, j: usize) -> f64 {
        crate::distances::cost::sqed(self.li[i - 1], self.co[j - 1])
    }
    fn top(&self, i: usize, j: usize) -> f64 {
        self.diag(i, j)
    }
    fn left(&self, i: usize, j: usize) -> f64 {
        self.diag(i, j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::dtw::cdtw;
    use crate::distances::DtwWorkspace;

    type DtwModel<'a> = DtwAsElastic<'a>;

    const S: [f64; 6] = [3.0, 1.0, 4.0, 4.0, 1.0, 1.0];
    const T: [f64; 6] = [1.0, 3.0, 2.0, 1.0, 2.0, 2.0];

    #[test]
    fn skeleton_reproduces_dtw() {
        let model = DtwModel { li: &S, co: &T };
        let mut ws = DtwWorkspace::default();
        assert_eq!(eap_elastic(&model, 6, f64::INFINITY, &mut ws), 9.0);
        assert_eq!(eap_elastic(&model, 6, 9.0, &mut ws), 9.0);
        assert_eq!(eap_elastic(&model, 6, 6.0, &mut ws), f64::INFINITY);
        for w in 0..=6usize {
            assert_eq!(
                eap_elastic(&model, w, f64::INFINITY, &mut ws),
                cdtw(&S, &T, w),
                "w={w}"
            );
        }
    }

    #[test]
    fn skeleton_matches_naive_random() {
        let mut x = 31u64;
        let mut rnd = move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 2.0 - 1.0
        };
        let mut ws = DtwWorkspace::default();
        for n in [6usize, 13, 25] {
            let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
            let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
            let model = DtwModel { li: &a, co: &b };
            for w in [1usize, n / 2, n] {
                let want = naive_elastic(&model, w);
                let got = eap_elastic(&model, w, f64::INFINITY, &mut ws);
                assert!((got - want).abs() < 1e-12, "n={n} w={w}");
                let tie = eap_elastic(&model, w, want, &mut ws);
                assert!((tie - want).abs() < 1e-12);
                assert_eq!(
                    eap_elastic(&model, w, want - want.abs() * 1e-6 - 1e-9, &mut ws),
                    f64::INFINITY
                );
            }
        }
    }
}
