//! TWE — Time Warp Edit distance (Marteau, 2009 — the paper's motivating
//! example of a measure *without* cheap lower bounds) as a [`CostModel`]
//! instantiation of the unified kernel. Stiffness `nu` penalises
//! timestamp drift, `lambda` deletes; infinite borders, 0-padded series.

use super::core::{eap_elastic, naive_elastic};
use crate::distances::cost::sqed;
use crate::distances::kernel::CostModel;
use crate::distances::DtwWorkspace;

/// TWE cost structure with stiffness `nu` and deletion penalty `lambda`.
pub struct Twe<'a> {
    li: &'a [f64],
    co: &'a [f64],
    nu: f64,
    lambda: f64,
}

impl<'a> Twe<'a> {
    pub fn new(li: &'a [f64], co: &'a [f64], nu: f64, lambda: f64) -> Self {
        Self { li, co, nu, lambda }
    }
    #[inline(always)]
    fn li_at(&self, i: usize) -> f64 {
        // 0-padding convention: x(0) = 0
        if i == 0 {
            0.0
        } else {
            self.li[i - 1]
        }
    }
    #[inline(always)]
    fn co_at(&self, j: usize) -> f64 {
        if j == 0 {
            0.0
        } else {
            self.co[j - 1]
        }
    }
}

impl CostModel for Twe<'_> {
    fn n_lines(&self) -> usize {
        self.li.len()
    }
    fn n_cols(&self) -> usize {
        self.co.len()
    }
    fn diag(&self, i: usize, j: usize) -> f64 {
        // match: d(a_i, b_j) + d(a_{i-1}, b_{j-1}) + 2*nu*|i-j|
        sqed(self.li_at(i), self.co_at(j))
            + sqed(self.li_at(i - 1), self.co_at(j - 1))
            + 2.0 * self.nu * (i.abs_diff(j) as f64)
    }
    fn top(&self, i: usize, _j: usize) -> f64 {
        // delete in lines: d(a_i, a_{i-1}) + nu + lambda
        sqed(self.li_at(i), self.li_at(i - 1)) + self.nu + self.lambda
    }
    fn left(&self, _i: usize, j: usize) -> f64 {
        sqed(self.co_at(j), self.co_at(j - 1)) + self.nu + self.lambda
    }
}

/// Early-abandoning pruned TWE: exact when `<= ub`, `+inf` once provably
/// above.
pub fn eap_twe(
    a: &[f64],
    b: &[f64],
    nu: f64,
    lambda: f64,
    w: usize,
    ub: f64,
    ws: &mut DtwWorkspace,
) -> f64 {
    eap_elastic(&Twe::new(a, b, nu, lambda), w, ub, ws)
}

/// Full-matrix TWE oracle.
pub fn twe_naive(a: &[f64], b: &[f64], nu: f64, lambda: f64, w: usize) -> f64 {
    naive_elastic(&Twe::new(a, b, nu, lambda), w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_zero() {
        let a = [1.0, 2.0, 1.0, 0.5];
        assert_eq!(
            eap_twe(&a, &a, 0.001, 1.0, 4, f64::INFINITY, &mut DtwWorkspace::default()),
            0.0
        );
    }

    #[test]
    fn exactness_sweep_vs_naive() {
        let mut x = 808u64;
        let mut rnd = move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 2.0 - 1.0
        };
        let mut ws = DtwWorkspace::default();
        for n in [5usize, 13, 21] {
            let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
            let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
            for (nu, lambda) in [(0.001, 1.0), (0.1, 0.5)] {
                for w in [2usize, n / 2, n] {
                    let want = twe_naive(&a, &b, nu, lambda, w);
                    let got = eap_twe(&a, &b, nu, lambda, w, f64::INFINITY, &mut ws);
                    assert!((got - want).abs() < 1e-12, "n={n} nu={nu} w={w}");
                    let tie = eap_twe(&a, &b, nu, lambda, w, want, &mut ws);
                    assert!((tie - want).abs() < 1e-12);
                    if want > 0.0 {
                        assert_eq!(
                            eap_twe(&a, &b, nu, lambda, w, want * (1.0 - 1e-9) - 1e-12, &mut ws),
                            f64::INFINITY
                        );
                    }
                }
            }
        }
    }
}
