//! WDTW — Weighted DTW (Jeong, Jeong & Omitaomu, 2011) as a [`CostModel`]
//! instantiation of the unified kernel: every move pays the point cost
//! scaled by a sigmoid weight of `|i-j|` (a soft band). Kept
//! non-`UNIFORM` — it has always run on the generalised stage bodies, and
//! staying there preserves bit-for-bit compatibility with its retired
//! kernel (the conformance suites' contract).

use super::core::{eap_elastic, naive_elastic};
use crate::distances::cost::sqed;
use crate::distances::kernel::CostModel;
use crate::distances::DtwWorkspace;

/// Maximum weight (the UEA/tsml convention).
const WMAX: f64 = 1.0;

/// Fill `out` with the WDTW sigmoid weight table for series length `len`:
/// `out[d] = WMAX / (1 + exp(-g * (d - len/2)))` for `d in 0..=len`. The
/// table depends on `(len, g)` only, so callers scoring many candidates
/// of one length build it once (see `distances::cache`); [`Wdtw::new`]
/// routes through here so the cached and owned forms are bitwise
/// identical by construction.
pub fn wdtw_weights_into(len: usize, g: f64, out: &mut Vec<f64>) {
    out.clear();
    out.reserve(len + 1);
    let mid = len as f64 / 2.0;
    out.extend((0..=len).map(|d| WMAX / (1.0 + (-g * (d as f64 - mid)).exp())));
}

#[inline(always)]
fn wdtw_cost(li: &[f64], co: &[f64], weights: &[f64], i: usize, j: usize) -> f64 {
    weights[i.abs_diff(j)] * sqed(li[i - 1], co[j - 1])
}

/// WDTW cost structure; `g` is the sigmoid steepness (commonly 0.05).
pub struct Wdtw<'a> {
    li: &'a [f64],
    co: &'a [f64],
    /// weights[d] = WMAX / (1 + exp(-g * (d - mid)))
    weights: Vec<f64>,
}

impl<'a> Wdtw<'a> {
    pub fn new(li: &'a [f64], co: &'a [f64], g: f64) -> Self {
        let mut weights = Vec::new();
        wdtw_weights_into(li.len().max(co.len()), g, &mut weights);
        Self { li, co, weights }
    }
}

impl CostModel for Wdtw<'_> {
    fn n_lines(&self) -> usize {
        self.li.len()
    }
    fn n_cols(&self) -> usize {
        self.co.len()
    }
    fn diag(&self, i: usize, j: usize) -> f64 {
        wdtw_cost(self.li, self.co, &self.weights, i, j)
    }
    fn top(&self, i: usize, j: usize) -> f64 {
        wdtw_cost(self.li, self.co, &self.weights, i, j)
    }
    fn left(&self, i: usize, j: usize) -> f64 {
        wdtw_cost(self.li, self.co, &self.weights, i, j)
    }
}

/// [`Wdtw`] over a caller-owned weight table (built with
/// [`wdtw_weights_into`]): the allocation-free form the per-query cost
/// cache evaluates candidates through. `weights.len()` must be at least
/// `max(li.len(), co.len()) + 1`.
pub struct WdtwRef<'a> {
    li: &'a [f64],
    co: &'a [f64],
    weights: &'a [f64],
}

impl<'a> WdtwRef<'a> {
    pub fn new(li: &'a [f64], co: &'a [f64], weights: &'a [f64]) -> Self {
        debug_assert!(weights.len() > li.len().max(co.len()));
        Self { li, co, weights }
    }
}

impl CostModel for WdtwRef<'_> {
    fn n_lines(&self) -> usize {
        self.li.len()
    }
    fn n_cols(&self) -> usize {
        self.co.len()
    }
    fn diag(&self, i: usize, j: usize) -> f64 {
        wdtw_cost(self.li, self.co, self.weights, i, j)
    }
    fn top(&self, i: usize, j: usize) -> f64 {
        wdtw_cost(self.li, self.co, self.weights, i, j)
    }
    fn left(&self, i: usize, j: usize) -> f64 {
        wdtw_cost(self.li, self.co, self.weights, i, j)
    }
}

/// Early-abandoning pruned WDTW: exact when `<= ub`, `+inf` once provably
/// above. WDTW is conventionally unwindowed (the weights do the banding);
/// pass `w = len` for that.
pub fn eap_wdtw(a: &[f64], b: &[f64], g: f64, w: usize, ub: f64, ws: &mut DtwWorkspace) -> f64 {
    eap_elastic(&Wdtw::new(a, b, g), w, ub, ws)
}

/// Full-matrix WDTW oracle.
pub fn wdtw_naive(a: &[f64], b: &[f64], g: f64, w: usize) -> f64 {
    naive_elastic(&Wdtw::new(a, b, g), w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::dtw::dtw;

    #[test]
    fn identity_zero() {
        let a = [1.0, -1.0, 2.0];
        assert_eq!(eap_wdtw(&a, &a, 0.05, 3, f64::INFINITY, &mut DtwWorkspace::default()), 0.0);
    }

    #[test]
    fn flat_weights_recover_scaled_dtw() {
        // g=0 makes every weight 0.5: WDTW = 0.5 * DTW
        let a = [3.0, 1.0, 4.0, 4.0, 1.0, 1.0];
        let b = [1.0, 3.0, 2.0, 1.0, 2.0, 2.0];
        let got = eap_wdtw(&a, &b, 0.0, 6, f64::INFINITY, &mut DtwWorkspace::default());
        assert!((got - 0.5 * dtw(&a, &b)).abs() < 1e-12);
    }

    #[test]
    fn exactness_sweep_vs_naive() {
        let mut x = 2024u64;
        let mut rnd = move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 2.0 - 1.0
        };
        let mut ws = DtwWorkspace::default();
        for n in [6usize, 14, 22] {
            let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
            let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
            for g in [0.05, 0.25] {
                for w in [n / 2, n] {
                    let want = wdtw_naive(&a, &b, g, w);
                    let got = eap_wdtw(&a, &b, g, w, f64::INFINITY, &mut ws);
                    assert!((got - want).abs() < 1e-12, "n={n} g={g} w={w}");
                    let tie = eap_wdtw(&a, &b, g, w, want, &mut ws);
                    assert!((tie - want).abs() < 1e-12);
                    if want > 0.0 {
                        assert_eq!(
                            eap_wdtw(&a, &b, g, w, want * (1.0 - 1e-9) - 1e-12, &mut ws),
                            f64::INFINITY
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn borrowed_weight_table_is_bitwise_the_owned_form() {
        let mut x = 99u64;
        let mut rnd = move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 2.0 - 1.0
        };
        let mut ws = DtwWorkspace::default();
        let mut ws2 = DtwWorkspace::default();
        let mut weights = Vec::new();
        for n in [7usize, 19] {
            let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
            let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
            for g in [0.05, 0.3] {
                wdtw_weights_into(n, g, &mut weights);
                for ub in [f64::INFINITY, 1.0, 0.0] {
                    let want = crate::distances::kernel::eap_kernel(
                        &Wdtw::new(&a, &b, g),
                        n,
                        ub,
                        None,
                        &mut ws2,
                    );
                    let got = crate::distances::kernel::eap_kernel(
                        &WdtwRef::new(&a, &b, &weights),
                        n,
                        ub,
                        None,
                        &mut ws,
                    );
                    assert_eq!(got.dist.to_bits(), want.dist.to_bits(), "n={n} g={g} ub={ub}");
                    assert_eq!(got.abandoned, want.abandoned, "n={n} g={g} ub={ub}");
                }
            }
        }
    }
}
