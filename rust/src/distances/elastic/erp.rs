//! ERP — Edit distance with Real Penalty (Chen & Ng, 2004) as a
//! [`CostModel`] instantiation of the unified kernel. Unlike DTW its
//! borders are *finite* (`D(i,0)` / `D(0,j)` accumulate gap penalties) —
//! exactly the case the kernel's gated non-`UNIFORM` pruning handles.

use super::core::{eap_elastic, naive_elastic};
use crate::distances::cost::sqed;
use crate::distances::kernel::CostModel;
use crate::distances::DtwWorkspace;

/// Fill `out` with the gap-penalty prefix sums for `s` under gap value
/// `g`: `out[j] = sum_{k<j} (s[k]-g)^2`, `out[0] = 0`. These are ERP's
/// finite borders; [`Erp::new`] routes through here so the cached and
/// owned forms accumulate in the same order (bitwise identity).
pub fn erp_acc_into(s: &[f64], g: f64, out: &mut Vec<f64>) {
    out.clear();
    out.reserve(s.len() + 1);
    out.push(0.0);
    let mut a = 0.0;
    for &x in s {
        a += sqed(x, g);
        out.push(a);
    }
}

/// ERP cost structure over two series with gap value `g`.
pub struct Erp<'a> {
    li: &'a [f64],
    co: &'a [f64],
    g: f64,
    /// prefix sums of gap penalties: `row_acc[j] = sum_{k<=j} (co[k]-g)^2`
    row_acc: Vec<f64>,
    col_acc: Vec<f64>,
}

impl<'a> Erp<'a> {
    pub fn new(li: &'a [f64], co: &'a [f64], g: f64) -> Self {
        let mut row_acc = Vec::new();
        let mut col_acc = Vec::new();
        erp_acc_into(co, g, &mut row_acc);
        erp_acc_into(li, g, &mut col_acc);
        Self { li, co, g, row_acc, col_acc }
    }
}

impl CostModel for Erp<'_> {
    fn n_lines(&self) -> usize {
        self.li.len()
    }
    fn n_cols(&self) -> usize {
        self.co.len()
    }
    fn diag(&self, i: usize, j: usize) -> f64 {
        sqed(self.li[i - 1], self.co[j - 1])
    }
    fn top(&self, i: usize, _j: usize) -> f64 {
        sqed(self.li[i - 1], self.g)
    }
    fn left(&self, _i: usize, j: usize) -> f64 {
        sqed(self.co[j - 1], self.g)
    }
    fn border_row(&self, j: usize) -> f64 {
        self.row_acc[j]
    }
    fn border_col(&self, i: usize) -> f64 {
        self.col_acc[i]
    }
}

/// [`Erp`] over caller-owned prefix-sum tables (built with
/// [`erp_acc_into`]): the allocation-free form the per-query cost cache
/// evaluates candidates through — `col_acc` (the query-side border) is
/// built once per query, `row_acc` (candidate-side) into a reused buffer.
pub struct ErpRef<'a> {
    li: &'a [f64],
    co: &'a [f64],
    g: f64,
    row_acc: &'a [f64],
    col_acc: &'a [f64],
}

impl<'a> ErpRef<'a> {
    pub fn new(
        li: &'a [f64],
        co: &'a [f64],
        g: f64,
        row_acc: &'a [f64],
        col_acc: &'a [f64],
    ) -> Self {
        debug_assert_eq!(row_acc.len(), co.len() + 1);
        debug_assert_eq!(col_acc.len(), li.len() + 1);
        Self { li, co, g, row_acc, col_acc }
    }
}

impl CostModel for ErpRef<'_> {
    fn n_lines(&self) -> usize {
        self.li.len()
    }
    fn n_cols(&self) -> usize {
        self.co.len()
    }
    fn diag(&self, i: usize, j: usize) -> f64 {
        sqed(self.li[i - 1], self.co[j - 1])
    }
    fn top(&self, i: usize, _j: usize) -> f64 {
        sqed(self.li[i - 1], self.g)
    }
    fn left(&self, _i: usize, j: usize) -> f64 {
        sqed(self.co[j - 1], self.g)
    }
    fn border_row(&self, j: usize) -> f64 {
        self.row_acc[j]
    }
    fn border_col(&self, i: usize) -> f64 {
        self.col_acc[i]
    }
}

/// Early-abandoning pruned ERP: exact when `<= ub`, `+inf` once provably
/// above. `w` is the Sakoe-Chiba band.
pub fn eap_erp(a: &[f64], b: &[f64], g: f64, w: usize, ub: f64, ws: &mut DtwWorkspace) -> f64 {
    eap_elastic(&Erp::new(a, b, g), w, ub, ws)
}

/// Full-matrix ERP oracle.
pub fn erp_naive(a: &[f64], b: &[f64], g: f64, w: usize) -> f64 {
    naive_elastic(&Erp::new(a, b, g), w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_zero() {
        let a = [1.0, 2.0, 3.0, 2.0];
        assert_eq!(eap_erp(&a, &a, 0.0, 4, f64::INFINITY, &mut DtwWorkspace::default()), 0.0);
    }

    #[test]
    fn pure_gap_alignment() {
        // one series empty of information: ERP vs itself shifted
        let a = [0.0, 0.0, 5.0];
        let b = [5.0, 0.0, 0.0];
        let d = erp_naive(&a, &b, 0.0, 3);
        let got = eap_erp(&a, &b, 0.0, 3, f64::INFINITY, &mut DtwWorkspace::default());
        assert!((got - d).abs() < 1e-12);
    }

    #[test]
    fn exactness_sweep_vs_naive() {
        let mut x = 77u64;
        let mut rnd = move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 2.0 - 1.0
        };
        let mut ws = DtwWorkspace::default();
        for n in [5usize, 11, 23] {
            let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
            let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
            for g in [0.0, 0.5] {
                for w in [2usize, n / 2, n] {
                    let want = erp_naive(&a, &b, g, w);
                    let got = eap_erp(&a, &b, g, w, f64::INFINITY, &mut ws);
                    assert!((got - want).abs() < 1e-12, "n={n} g={g} w={w}: {got} vs {want}");
                    let tie = eap_erp(&a, &b, g, w, want, &mut ws);
                    assert!((tie - want).abs() < 1e-12, "tie n={n} g={g} w={w}");
                    if want > 0.0 {
                        assert_eq!(
                            eap_erp(&a, &b, g, w, want * (1.0 - 1e-9) - 1e-12, &mut ws),
                            f64::INFINITY,
                            "abandon n={n} g={g} w={w}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn borrowed_acc_tables_are_bitwise_the_owned_form() {
        let mut x = 404u64;
        let mut rnd = move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 2.0 - 1.0
        };
        let mut ws = DtwWorkspace::default();
        let mut ws2 = DtwWorkspace::default();
        let (mut row, mut col) = (Vec::new(), Vec::new());
        for n in [6usize, 17] {
            let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
            let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
            for g in [0.0, 0.5] {
                erp_acc_into(&b, g, &mut row);
                erp_acc_into(&a, g, &mut col);
                for w in [2usize, n] {
                    for ub in [f64::INFINITY, 0.5, 0.0] {
                        let want = crate::distances::kernel::eap_kernel(
                            &Erp::new(&a, &b, g),
                            w,
                            ub,
                            None,
                            &mut ws2,
                        );
                        let got = crate::distances::kernel::eap_kernel(
                            &ErpRef::new(&a, &b, g, &row, &col),
                            w,
                            ub,
                            None,
                            &mut ws,
                        );
                        assert_eq!(got.dist.to_bits(), want.dist.to_bits(), "n={n} g={g} w={w}");
                        assert_eq!(got.abandoned, want.abandoned, "n={n} g={g} w={w}");
                    }
                }
            }
        }
    }

    #[test]
    fn finite_border_paths_survive_pruning() {
        // A series pair whose optimal path hugs the border column: the
        // gated discard logic must not cut it off.
        let a = [10.0, 10.0, 10.0, 0.0];
        let b = [0.0, 0.1, 0.0, 0.05];
        let want = erp_naive(&a, &b, 0.0, 4);
        let got = eap_erp(&a, &b, 0.0, 4, want + 1.0, &mut DtwWorkspace::default());
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }
}
