//! MSM — Move-Split-Merge (Stefan, Athitsos & Das, 2013) as a
//! [`CostModel`] instantiation of the unified kernel: moves cost the
//! absolute difference, splits/merges a constant `c` plus an
//! out-of-between penalty. Infinite borders, distinct step costs
//! (non-`UNIFORM`).

use super::core::{eap_elastic, naive_elastic};
use crate::distances::cost::absd;
use crate::distances::kernel::CostModel;
use crate::distances::DtwWorkspace;

#[inline(always)]
fn msm_cost(x: f64, y: f64, z: f64, c: f64) -> f64 {
    // cost of splitting/merging x relative to neighbours y and z
    if (y <= x && x <= z) || (z <= x && x <= y) {
        c
    } else {
        c + (x - y).abs().min((x - z).abs())
    }
}

/// MSM cost structure with split/merge cost `c`.
pub struct Msm<'a> {
    li: &'a [f64],
    co: &'a [f64],
    c: f64,
}

impl<'a> Msm<'a> {
    pub fn new(li: &'a [f64], co: &'a [f64], c: f64) -> Self {
        Self { li, co, c }
    }
}

impl CostModel for Msm<'_> {
    fn n_lines(&self) -> usize {
        self.li.len()
    }
    fn n_cols(&self) -> usize {
        self.co.len()
    }
    fn diag(&self, i: usize, j: usize) -> f64 {
        absd(self.li[i - 1], self.co[j - 1])
    }
    fn top(&self, i: usize, j: usize) -> f64 {
        // consume li[i]: split/merge against its predecessor and co[j].
        // i == 1 can only be reached from the infinite border: cost value
        // is irrelevant but must be finite-safe.
        if i < 2 {
            return f64::INFINITY;
        }
        msm_cost(self.li[i - 1], self.li[i - 2], self.co[j - 1], self.c)
    }
    fn left(&self, i: usize, j: usize) -> f64 {
        if j < 2 {
            return f64::INFINITY;
        }
        msm_cost(self.co[j - 1], self.co[j - 2], self.li[i - 1], self.c)
    }
}

/// Early-abandoning pruned MSM: exact when `<= ub`, `+inf` once provably
/// above.
pub fn eap_msm(a: &[f64], b: &[f64], c: f64, w: usize, ub: f64, ws: &mut DtwWorkspace) -> f64 {
    eap_elastic(&Msm::new(a, b, c), w, ub, ws)
}

/// Full-matrix MSM oracle.
pub fn msm_naive(a: &[f64], b: &[f64], c: f64, w: usize) -> f64 {
    naive_elastic(&Msm::new(a, b, c), w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_zero() {
        let a = [1.0, 2.0, 3.0];
        assert_eq!(eap_msm(&a, &a, 0.5, 3, f64::INFINITY, &mut DtwWorkspace::default()), 0.0);
    }

    #[test]
    fn known_small_case() {
        // a=[1], b=[2]: single match, cost |1-2| = 1.
        assert_eq!(eap_msm(&[1.0], &[2.0], 0.5, 1, f64::INFINITY, &mut DtwWorkspace::default()), 1.0);
    }

    #[test]
    fn split_cheaper_than_big_move() {
        // aligning [0, 10] to [0]: consume the 10 via split/merge
        let d = msm_naive(&[0.0, 10.0], &[0.0], 0.1, 2);
        // split cost = c + min(|10-0|, |10-0|) = 0.1 + 10 ... or match 10->0 = 10
        // naive DP picks the min; EAP must agree.
        let got = eap_msm(&[0.0, 10.0], &[0.0], 0.1, 2, f64::INFINITY, &mut DtwWorkspace::default());
        assert!((got - d).abs() < 1e-12);
    }

    #[test]
    fn exactness_sweep_vs_naive() {
        let mut x = 555u64;
        let mut rnd = move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 2.0 - 1.0
        };
        let mut ws = DtwWorkspace::default();
        for n in [5usize, 12, 24] {
            let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
            let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
            for c in [0.1, 1.0] {
                for w in [2usize, n / 2, n] {
                    let want = msm_naive(&a, &b, c, w);
                    let got = eap_msm(&a, &b, c, w, f64::INFINITY, &mut ws);
                    assert!((got - want).abs() < 1e-12, "n={n} c={c} w={w}");
                    let tie = eap_msm(&a, &b, c, w, want, &mut ws);
                    assert!((tie - want).abs() < 1e-12);
                    if want > 0.0 {
                        assert_eq!(
                            eap_msm(&a, &b, c, w, want * (1.0 - 1e-9) - 1e-12, &mut ws),
                            f64::INFINITY
                        );
                    }
                }
            }
        }
    }
}
