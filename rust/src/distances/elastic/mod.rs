//! The paper's future-work claim, §6: *"many elastic measures share the
//! same structure as DTW, only differing in their cost function"* — so the
//! EAPruned early-abandon/pruning scheme should transfer to them.
//!
//! [`core`] generalises Algorithm 3 over an [`core::ElasticModel`]: per-move
//! costs (diagonal/match, top/delete, left/insert) plus finite or infinite
//! border rows/columns (ERP's gap borders are finite!). The concrete
//! models:
//!
//! * [`erp`] — Edit distance with Real Penalty (gap value `g`)
//! * [`msm`] — Move-Split-Merge (split/merge cost `c`)
//! * [`twe`] — Time Warp Edit distance (stiffness `nu`, penalty `lambda`)
//! * [`wdtw`] — Weighted DTW (sigmoid weight steepness `g`)
//!
//! Each module ships a naive full-matrix oracle; tests check the EAPruned
//! version is exact for `ub = inf`, exact at ties, and abandons below.

pub mod core;
pub mod erp;
pub mod msm;
pub mod twe;
pub mod wdtw;
