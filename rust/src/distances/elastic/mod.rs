//! The paper's future-work claim, §6: *"many elastic measures share the
//! same structure as DTW, only differing in their cost function"*. Each
//! measure here is a [`crate::distances::kernel::CostModel`] — per-move
//! costs plus finite or infinite borders (ERP's gap borders are finite!)
//! — evaluated by the ONE unified band kernel; [`core`] keeps the
//! historical `eap_elastic`/`ElasticModel` names as re-exports.
//!
//! * [`erp`] — Edit distance with Real Penalty (gap value `g`)
//! * [`msm`] — Move-Split-Merge (split/merge cost `c`)
//! * [`twe`] — Time Warp Edit distance (stiffness `nu`, penalty `lambda`)
//! * [`wdtw`] — Weighted DTW (sigmoid weight steepness `g`)
//!
//! Each module ships a naive full-matrix oracle; tests check the EAPruned
//! version is exact for `ub = inf`, exact at ties, and abandons below.

pub mod core;
pub mod erp;
pub mod msm;
pub mod twe;
pub mod wdtw;
