//! The distance zoo: every DTW variant the paper builds on, compares
//! against, or contributes (DESIGN.md §2, systems S1–S6).
//!
//! All functions use `f64` and the squared-Euclidean point cost (the UCR
//! suite convention). Every early-abandoning variant takes an upper bound
//! `ub` and returns `f64::INFINITY` when it can prove the true distance
//! *strictly* exceeds `ub` (strictness preserves ties — paper §2.2).
//!
//! | module | algorithm | role |
//! |--------|-----------|------|
//! | [`dtw`] | Algorithm 1 (+ Sakoe-Chiba band) | baseline & oracle |
//! | [`dtw_ea`] | UCR row-min early abandon (+ cb tightening) | UCR suite |
//! | [`pruned_dtw`] | PrunedDTW as in UCR-USP [19,20] | prior art |
//! | [`left_prune`] | Algorithm 2 (left pruning only) | stepping stone |
//! | [`eap_dtw`] | **Algorithm 3 — EAPrunedDTW** | the contribution |
//! | [`elastic`] | EAPruned skeleton on ERP/MSM/TWE/WDTW | future work §6 |
//! | [`metric`] | [`metric::Metric`] dispatch over the whole zoo | serving layer |

pub mod cost;
pub mod dtw;
pub mod dtw_ea;
pub mod eap_dtw;
pub mod elastic;
pub mod left_prune;
pub mod metric;
pub mod pruned_dtw;

/// Workspace reused across distance calls to keep the hot path
/// allocation-free: two DP lines of `len + 1` cells.
#[derive(Debug, Default, Clone)]
pub struct DtwWorkspace {
    pub(crate) prev: Vec<f64>,
    pub(crate) curr: Vec<f64>,
}

impl DtwWorkspace {
    /// Workspace able to handle series up to `cap` points.
    pub fn with_capacity(cap: usize) -> Self {
        Self { prev: Vec::with_capacity(cap + 1), curr: Vec::with_capacity(cap + 1) }
    }

    /// (Re)initialise both lines to `len + 1` cells of `+inf`.
    #[inline]
    pub(crate) fn reset(&mut self, len: usize) {
        self.prev.clear();
        self.prev.resize(len + 1, f64::INFINITY);
        self.curr.clear();
        self.curr.resize(len + 1, f64::INFINITY);
    }
}

/// Order two series as (lines, columns) = (longest, shortest): the DP lines
/// match the shortest series so the O(n)-space buffers are minimal
/// (paper Algorithm 1, lines 1–2). DTW is symmetric so this is free.
#[inline]
pub(crate) fn lines_cols<'a>(a: &'a [f64], b: &'a [f64]) -> (&'a [f64], &'a [f64]) {
    if a.len() >= b.len() {
        (a, b)
    } else {
        (b, a)
    }
}
