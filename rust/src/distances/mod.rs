//! The distance zoo (DESIGN.md §2, systems S1–S6). Every early-abandoning
//! variant takes an upper bound `ub` and returns `f64::INFINITY` when the
//! true distance *strictly* exceeds it (strictness preserves ties, §2.2).
//! Every EAPruned evaluation — cDTW/DTW, WDTW, ERP, MSM, TWE — runs
//! through the ONE band core in [`kernel`] (`eap_kernel` over a
//! [`kernel::CostModel`]); the per-metric modules are zero-cost cost-model
//! instantiations, not kernel copies (see `distances/README.md`).
//!
//! | module | algorithm | role |
//! |--------|-----------|------|
//! | [`kernel`] | **the unified EAPruned band core** | every EAP evaluation |
//! | [`dtw`] | Algorithm 1 (+ Sakoe-Chiba band) | baseline & oracle |
//! | [`dtw_ea`] | UCR row-min early abandon (+ cb tightening) | UCR comparator |
//! | [`pruned_dtw`] | PrunedDTW as in UCR-USP [19,20] | prior-art comparator |
//! | [`left_prune`] | Algorithm 2 (left pruning only) | stepping stone |
//! | [`eap_dtw`] | Algorithm 3 wrappers over [`kernel`] | the contribution |
//! | [`elastic`] | ERP/MSM/TWE/WDTW cost models over [`kernel`] | §6 extensions |
//! | [`metric`] | [`metric::Metric`] dispatch over the whole zoo | serving layer |

pub mod cache;
pub mod cost;
pub mod dtw;
pub mod dtw_ea;
pub mod eap_dtw;
pub mod elastic;
pub mod kernel;
pub mod left_prune;
pub mod metric;
pub mod pruned_dtw;

/// Workspace reused across distance calls to keep the hot path
/// allocation-free: two DP lines of `len + 1` cells. One type serves
/// every kernel in the zoo, so pools
/// ([`crate::search::cohort::CohortPool`]) size it once per cohort and
/// swap it into any evaluation. The f32 line pair backs the opt-in
/// [`kernel::Precision::F32`] storage mode and stays empty (no
/// allocation) on the default f64 paths.
#[derive(Debug, Default, Clone)]
pub struct KernelWorkspace {
    pub(crate) prev: Vec<f64>,
    pub(crate) curr: Vec<f64>,
    pub(crate) prev32: Vec<f32>,
    pub(crate) curr32: Vec<f32>,
    /// times [`KernelWorkspace::reset`] / [`KernelWorkspace::reset32`]
    /// grew a line beyond capacity — pooled workspaces must never regrow
    /// after warm-up
    /// ([`crate::metrics::Counters::kernel_workspace_regrows`]).
    regrows: u64,
}

/// Historical name of [`KernelWorkspace`], kept so every pre-unification
/// call site (examples, benches, tests, downstream users) still compiles.
pub type DtwWorkspace = KernelWorkspace;

impl KernelWorkspace {
    /// Workspace able to handle series up to `cap` points.
    pub fn with_capacity(cap: usize) -> Self {
        Self {
            prev: Vec::with_capacity(cap + 1),
            curr: Vec::with_capacity(cap + 1),
            prev32: Vec::new(),
            curr32: Vec::new(),
            regrows: 0,
        }
    }

    /// (Re)initialise both lines to `len + 1` cells of `+inf`.
    #[inline]
    pub(crate) fn reset(&mut self, len: usize) {
        if self.prev.capacity() < len + 1 || self.curr.capacity() < len + 1 {
            self.regrows += 1;
        }
        self.prev.clear();
        self.prev.resize(len + 1, f64::INFINITY);
        self.curr.clear();
        self.curr.resize(len + 1, f64::INFINITY);
    }

    /// (Re)initialise the f32 line pair to `len + 1` cells of `+inf`
    /// (the [`kernel::Precision::F32`] storage mode).
    #[inline]
    pub(crate) fn reset32(&mut self, len: usize) {
        if self.prev32.capacity() < len + 1 || self.curr32.capacity() < len + 1 {
            self.regrows += 1;
        }
        self.prev32.clear();
        self.prev32.resize(len + 1, f32::INFINITY);
        self.curr32.clear();
        self.curr32.resize(len + 1, f32::INFINITY);
    }

    /// Pre-size the f64 line pair for series of `len` points *without*
    /// counting a regrow — the pool warm-up path.
    pub(crate) fn warm(&mut self, len: usize) {
        if self.prev.capacity() < len + 1 {
            self.prev.reserve(len + 1 - self.prev.len());
        }
        if self.curr.capacity() < len + 1 {
            self.curr.reserve(len + 1 - self.curr.len());
        }
    }

    /// [`KernelWorkspace::warm`] for the f32 line pair.
    pub(crate) fn warm32(&mut self, len: usize) {
        if self.prev32.capacity() < len + 1 {
            self.prev32.reserve(len + 1 - self.prev32.len());
        }
        if self.curr32.capacity() < len + 1 {
            self.curr32.reserve(len + 1 - self.curr32.len());
        }
    }

    /// How often a reset had to allocate; a pooled workspace warmed to the
    /// cohort's query length must keep this constant across the cohort.
    #[inline]
    pub(crate) fn regrows(&self) -> u64 {
        self.regrows
    }
}

/// Order two series as (lines, columns) = (longest, shortest) so the
/// O(n)-space buffers are minimal (Algorithm 1; DTW is symmetric).
#[inline]
pub(crate) fn lines_cols<'a>(a: &'a [f64], b: &'a [f64]) -> (&'a [f64], &'a [f64]) {
    if a.len() >= b.len() {
        (a, b)
    } else {
        (b, a)
    }
}
