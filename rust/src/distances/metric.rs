//! The metric dispatch layer: one enum naming every elastic distance the
//! search stack can score candidates under, with its parameters. The
//! subsequence scan, NN1, the [`crate::index::Engine`] and the wire
//! protocol all dispatch through [`Metric::eval`] into the unified band
//! kernel. Lower-bound applicability is explicit, not assumed: LB_Kim /
//! LB_Keogh lower-bound (banded) DTW only, and reusing the DTW cascade
//! for WDTW/ERP/MSM/TWE would *over-prune* — [`Metric::uses_envelopes`]
//! is the single source of truth; metrics outside the DTW family run the
//! bound-free scan, still threshold-driven by the top-k collector.

use anyhow::{anyhow, bail, Result};

use crate::distances::cache::CostModelCache;
use crate::distances::dtw::dtw_oracle;
use crate::distances::elastic::erp::{erp_naive, Erp, ErpRef};
use crate::distances::elastic::msm::{msm_naive, Msm};
use crate::distances::elastic::twe::{twe_naive, Twe};
use crate::distances::elastic::wdtw::{wdtw_naive, Wdtw, WdtwRef};
use crate::distances::kernel::{eap_kernel, KernelEval};
use crate::distances::DtwWorkspace;
use crate::search::suite::Suite;
use crate::util::json::{obj, Json};

/// Default WDTW sigmoid steepness (the UEA convention).
pub const DEFAULT_WDTW_G: f64 = 0.05;
/// Default ERP gap value (0 on z-normalised data).
pub const DEFAULT_ERP_GAP: f64 = 0.0;
/// Default MSM split/merge cost.
pub const DEFAULT_MSM_COST: f64 = 0.5;
/// Default TWE stiffness.
pub const DEFAULT_TWE_NU: f64 = 0.05;
/// Default TWE deletion penalty.
pub const DEFAULT_TWE_LAMBDA: f64 = 1.0;

/// An elastic distance plus its parameters — everything a request needs to
/// say to pick how candidates are scored.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum Metric {
    /// Sakoe-Chiba-banded DTW — the paper's metric and the wire default.
    #[default]
    Cdtw,
    /// Unbanded DTW (the request's window ratio is ignored).
    Dtw,
    /// Weighted DTW; the sigmoid weights replace the hard band, so the
    /// request's window ratio is ignored.
    Wdtw { g: f64 },
    /// Edit distance with Real Penalty, gap value `gap`.
    Erp { gap: f64 },
    /// Move-Split-Merge, split/merge cost `cost`.
    Msm { cost: f64 },
    /// Time Warp Edit distance, stiffness `nu` and deletion penalty
    /// `lambda`.
    Twe { nu: f64, lambda: f64 },
}

impl Metric {
    /// Number of metric kinds — the width of the per-metric counter
    /// arrays in [`crate::metrics::Counters`].
    pub const COUNT: usize = 6;

    /// Kind names indexed by [`Metric::index`].
    pub const KIND_NAMES: [&'static str; Metric::COUNT] =
        ["cdtw", "dtw", "wdtw", "erp", "msm", "twe"];

    /// Wire name of this metric's kind (parameters travel as sibling
    /// JSON fields, see [`Metric::to_json`]).
    pub fn name(&self) -> &'static str {
        Self::KIND_NAMES[self.index()]
    }

    /// Dense kind index, for the per-metric counter arrays.
    pub fn index(&self) -> usize {
        match self {
            Metric::Cdtw => 0,
            Metric::Dtw => 1,
            Metric::Wdtw { .. } => 2,
            Metric::Erp { .. } => 3,
            Metric::Msm { .. } => 4,
            Metric::Twe { .. } => 5,
        }
    }

    /// Can LB_Kim / LB_Keogh prune for this metric? True only for the
    /// banded/unbanded DTW pair; every other metric must run bound-free
    /// (the envelope bounds are not lower bounds of WDTW/ERP/MSM/TWE).
    pub fn uses_envelopes(&self) -> bool {
        matches!(self, Metric::Cdtw | Metric::Dtw)
    }

    /// Will a scan under this metric and `suite` actually consume
    /// reference-side data envelopes? The single predicate the direct
    /// scan, the coordinator's fallback build and the shared index all
    /// route through — keep them agreeing by construction.
    pub fn wants_data_envelopes(&self, suite: Suite) -> bool {
        self.uses_envelopes() && suite.cascade().needs_data_envelopes()
    }

    /// The warping window actually used for a query of `qlen` points:
    /// DTW and WDTW are unbanded by convention, the rest honour `w`.
    pub fn effective_window(&self, qlen: usize, w: usize) -> usize {
        match self {
            Metric::Dtw | Metric::Wdtw { .. } => qlen,
            _ => w,
        }
    }

    /// Evaluate the metric between `q` and `c` under upper bound `ub`:
    /// the exact distance when it is `<= ub`, `+inf` once provably above.
    /// `suite` picks the DTW core for the DTW family; `cb` is the
    /// cascade's cumulative-bound tail, meaningful for DTW cores only.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub fn eval(
        &self,
        q: &[f64],
        c: &[f64],
        w: usize,
        ub: f64,
        cb: Option<&[f64]>,
        suite: Suite,
        ws: &mut DtwWorkspace,
    ) -> f64 {
        self.eval_outcome(q, c, w, ub, cb, suite, ws).dist
    }

    /// [`Metric::eval`] with the full [`KernelEval`] outcome. Every
    /// metric runs through the ONE unified band kernel — the DTW family
    /// via [`Suite::dtw_eval`], the rest as direct cost-model
    /// instantiations — so the per-metric abandon attribution comes from
    /// the core itself, not from `is_infinite()` at the dispatch site.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub fn eval_outcome(
        &self,
        q: &[f64],
        c: &[f64],
        w: usize,
        ub: f64,
        cb: Option<&[f64]>,
        suite: Suite,
        ws: &mut DtwWorkspace,
    ) -> KernelEval {
        match *self {
            Metric::Cdtw => suite.dtw_eval(q, c, w, ub, cb, ws),
            Metric::Dtw => suite.dtw_eval(q, c, q.len().max(c.len()), ub, cb, ws),
            Metric::Wdtw { g } => {
                eap_kernel(&Wdtw::new(q, c, g), q.len().max(c.len()), ub, None, ws)
            }
            Metric::Erp { gap } => eap_kernel(&Erp::new(q, c, gap), w, ub, None, ws),
            Metric::Msm { cost } => eap_kernel(&Msm::new(q, c, cost), w, ub, None, ws),
            Metric::Twe { nu, lambda } => {
                eap_kernel(&Twe::new(q, c, nu, lambda), w, ub, None, ws)
            }
        }
    }

    /// [`Metric::eval_outcome`] through a per-query [`CostModelCache`]:
    /// WDTW scores against the cached weight table and ERP against the
    /// cached query-side border table (candidate-side prefix sums go into
    /// the cache's reused buffer) — no per-candidate allocation. Bitwise
    /// identical to the uncached path: both forms build their tables with
    /// the same helpers and run the same unified kernel. Metrics without
    /// query-side tables delegate unchanged.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub fn eval_outcome_cached(
        &self,
        q: &[f64],
        c: &[f64],
        w: usize,
        ub: f64,
        cb: Option<&[f64]>,
        suite: Suite,
        ws: &mut DtwWorkspace,
        cache: &mut CostModelCache,
    ) -> KernelEval {
        match *self {
            Metric::Wdtw { g } => {
                let len = q.len().max(c.len());
                let weights = cache.wdtw_weights(len, g);
                eap_kernel(&WdtwRef::new(q, c, weights), len, ub, None, ws)
            }
            Metric::Erp { gap } => {
                let (col, row) = cache.erp_accs(q, c, gap);
                eap_kernel(&ErpRef::new(q, c, gap, row, col), w, ub, None, ws)
            }
            _ => self.eval_outcome(q, c, w, ub, cb, suite, ws),
        }
    }

    /// Naive full-matrix oracle for this metric — the conformance-test
    /// ground truth, never used on a hot path.
    pub fn exact(&self, q: &[f64], c: &[f64], w: usize) -> f64 {
        match *self {
            Metric::Cdtw => dtw_oracle(q, c, Some(w)),
            Metric::Dtw => dtw_oracle(q, c, None),
            Metric::Wdtw { g } => wdtw_naive(q, c, g, q.len().max(c.len())),
            Metric::Erp { gap } => erp_naive(q, c, gap, w),
            Metric::Msm { cost } => msm_naive(q, c, cost, w),
            Metric::Twe { nu, lambda } => twe_naive(q, c, nu, lambda, w),
        }
    }

    /// Parameter sanity: finite, and non-negative where the measure
    /// requires it (a negative MSM cost or TWE penalty breaks the
    /// metric's triangle-free soundness; a negative WDTW steepness makes
    /// the weights decreasing).
    pub fn validate(&self) -> Result<()> {
        let finite = |name: &str, v: f64| -> Result<()> {
            anyhow::ensure!(v.is_finite(), "metric parameter {name:?} must be finite, got {v}");
            Ok(())
        };
        let non_negative = |name: &str, v: f64| -> Result<()> {
            finite(name, v)?;
            anyhow::ensure!(v >= 0.0, "metric parameter {name:?} must be >= 0, got {v}");
            Ok(())
        };
        match *self {
            Metric::Cdtw | Metric::Dtw => Ok(()),
            Metric::Wdtw { g } => non_negative("g", g),
            Metric::Erp { gap } => finite("gap", gap),
            Metric::Msm { cost } => non_negative("cost", cost),
            Metric::Twe { nu, lambda } => {
                non_negative("nu", nu)?;
                non_negative("lambda", lambda)
            }
        }
    }

    /// The wire form: `{"name":"twe","nu":0.05,"lambda":1}` — kind name
    /// plus the kind's parameters as sibling fields.
    pub fn to_json(&self) -> Json {
        let name = ("name", Json::Str(self.name().to_string()));
        match *self {
            Metric::Cdtw | Metric::Dtw => obj(vec![name]),
            Metric::Wdtw { g } => obj(vec![name, ("g", Json::Num(g))]),
            Metric::Erp { gap } => obj(vec![name, ("gap", Json::Num(gap))]),
            Metric::Msm { cost } => obj(vec![name, ("cost", Json::Num(cost))]),
            Metric::Twe { nu, lambda } => {
                obj(vec![name, ("nu", Json::Num(nu)), ("lambda", Json::Num(lambda))])
            }
        }
    }

    /// Parse the wire form. Missing parameters take the documented
    /// defaults; unknown kinds, unknown parameter keys (a misspelled
    /// parameter must not silently fall back to a default) and malformed
    /// parameters error.
    pub fn from_json(v: &Json) -> Result<Metric> {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("metric missing name"))?;
        let num = |key: &str, default: f64| -> Result<f64> {
            match v.get(key) {
                Some(x) => {
                    x.as_f64().ok_or_else(|| anyhow!("metric parameter {key:?} must be a number"))
                }
                None => Ok(default),
            }
        };
        let (m, allowed): (Metric, &[&str]) = match name.to_ascii_lowercase().as_str() {
            "cdtw" => (Metric::Cdtw, &["name"]),
            "dtw" => (Metric::Dtw, &["name"]),
            "wdtw" => (Metric::Wdtw { g: num("g", DEFAULT_WDTW_G)? }, &["name", "g"]),
            "erp" => (Metric::Erp { gap: num("gap", DEFAULT_ERP_GAP)? }, &["name", "gap"]),
            "msm" => (Metric::Msm { cost: num("cost", DEFAULT_MSM_COST)? }, &["name", "cost"]),
            "twe" => (
                Metric::Twe {
                    nu: num("nu", DEFAULT_TWE_NU)?,
                    lambda: num("lambda", DEFAULT_TWE_LAMBDA)?,
                },
                &["name", "nu", "lambda"],
            ),
            other => bail!("unknown metric {other:?}"),
        };
        if let Some(map) = v.as_obj() {
            for key in map.keys() {
                anyhow::ensure!(
                    allowed.contains(&key.as_str()),
                    "metric {:?} has no parameter {key:?} (expected one of {allowed:?})",
                    m.name()
                );
            }
        }
        m.validate()?;
        Ok(m)
    }

    /// Parse a bare kind name with default parameters (the CLI form).
    pub fn from_name(s: &str) -> Option<Metric> {
        Metric::from_json(&obj(vec![("name", Json::Str(s.to_string()))])).ok()
    }

    /// One default-parameterised instance of every kind — what the
    /// conformance and property suites iterate.
    pub fn all_default() -> [Metric; Metric::COUNT] {
        [
            Metric::Cdtw,
            Metric::Dtw,
            Metric::Wdtw { g: DEFAULT_WDTW_G },
            Metric::Erp { gap: DEFAULT_ERP_GAP },
            Metric::Msm { cost: DEFAULT_MSM_COST },
            Metric::Twe { nu: DEFAULT_TWE_NU, lambda: DEFAULT_TWE_LAMBDA },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_and_indices_are_dense_and_round_trip() {
        for (i, m) in Metric::all_default().iter().enumerate() {
            assert_eq!(m.index(), i);
            assert_eq!(m.name(), Metric::KIND_NAMES[i]);
            assert_eq!(Metric::from_name(m.name()), Some(*m), "{}", m.name());
        }
        assert_eq!(Metric::from_name("zzz"), None);
    }

    #[test]
    fn json_round_trip_preserves_parameters() {
        for m in [
            Metric::Cdtw,
            Metric::Dtw,
            Metric::Wdtw { g: 0.125 },
            Metric::Erp { gap: -0.5 },
            Metric::Msm { cost: 2.0 },
            Metric::Twe { nu: 0.001, lambda: 0.25 },
        ] {
            let back = Metric::from_json(&m.to_json()).unwrap();
            assert_eq!(back, m, "{}", m.name());
        }
    }

    #[test]
    fn json_defaults_fill_missing_parameters() {
        let m = Metric::from_json(&Json::parse(r#"{"name":"twe"}"#).unwrap()).unwrap();
        assert_eq!(m, Metric::Twe { nu: DEFAULT_TWE_NU, lambda: DEFAULT_TWE_LAMBDA });
        let m = Metric::from_json(&Json::parse(r#"{"name":"msm","cost":3}"#).unwrap()).unwrap();
        assert_eq!(m, Metric::Msm { cost: 3.0 });
    }

    #[test]
    fn json_rejects_bad_metrics() {
        for line in [
            r#"{"name":"nope"}"#,
            r#"{}"#,
            r#"{"name":"msm","cost":-1}"#,
            r#"{"name":"wdtw","g":"x"}"#,
            r#"{"name":"twe","nu":-0.1}"#,
            // misspelled / misplaced parameter keys must not silently
            // fall back to the defaults
            r#"{"name":"wdtw","steepness":0.3}"#,
            r#"{"name":"erp","cost":0.9}"#,
            r#"{"name":"cdtw","g":0.1}"#,
        ] {
            assert!(Metric::from_json(&Json::parse(line).unwrap()).is_err(), "{line}");
        }
    }

    #[test]
    fn envelope_support_is_dtw_family_only() {
        assert!(Metric::Cdtw.uses_envelopes());
        assert!(Metric::Dtw.uses_envelopes());
        for m in &Metric::all_default()[2..] {
            assert!(!m.uses_envelopes(), "{}", m.name());
        }
    }

    #[test]
    fn effective_window_unbands_dtw_and_wdtw() {
        assert_eq!(Metric::Cdtw.effective_window(128, 12), 12);
        assert_eq!(Metric::Dtw.effective_window(128, 12), 128);
        assert_eq!(Metric::Wdtw { g: 0.05 }.effective_window(128, 12), 128);
        assert_eq!(Metric::Erp { gap: 0.0 }.effective_window(128, 12), 12);
    }

    #[test]
    fn eval_matches_exact_and_abandons_for_every_kind() {
        let a = [3.0, 1.0, 4.0, 4.0, 1.0, 1.0];
        let b = [1.0, 3.0, 2.0, 1.0, 2.0, 2.0];
        let mut ws = DtwWorkspace::default();
        for m in Metric::all_default() {
            let want = m.exact(&a, &b, 3);
            assert!(want.is_finite(), "{}", m.name());
            let got = m.eval(&a, &b, 3, f64::INFINITY, None, Suite::UcrMon, &mut ws);
            assert!((got - want).abs() < 1e-12, "{}: {got} vs {want}", m.name());
            let tie = m.eval(&a, &b, 3, want, None, Suite::UcrMon, &mut ws);
            assert!((tie - want).abs() < 1e-12, "{} tie", m.name());
            if want > 0.0 {
                let ub = want * (1.0 - 1e-9) - 1e-12;
                let below = m.eval(&a, &b, 3, ub, None, Suite::UcrMon, &mut ws);
                assert_eq!(below, f64::INFINITY, "{} abandon", m.name());
            }
        }
    }

    #[test]
    fn cached_eval_is_bitwise_the_uncached_eval_for_every_kind() {
        let a = [0.5, -1.25, 2.0, 0.0, 1.0, -0.75, 0.25, 1.5];
        let b = [1.0, 0.25, -0.5, 1.75, -1.0, 0.5, 0.0, -0.25];
        let c = [0.0, 0.5, 1.0, -1.5, 0.75, -0.25, 2.0, 1.25];
        let mut ws1 = DtwWorkspace::default();
        let mut ws2 = DtwWorkspace::default();
        for m in Metric::all_default() {
            let mut cache = CostModelCache::new();
            cache.prepare(m, &a);
            // several candidates through one cache — the production shape
            for cand in [&b[..], &c[..], &b[..]] {
                for w in [3usize, 8] {
                    for ub in [f64::INFINITY, 2.0, 0.0] {
                        let want = m.eval_outcome(&a, cand, w, ub, None, Suite::UcrMon, &mut ws2);
                        let got = m.eval_outcome_cached(
                            &a, cand, w, ub, None, Suite::UcrMon, &mut ws1, &mut cache,
                        );
                        assert_eq!(
                            got.dist.to_bits(),
                            want.dist.to_bits(),
                            "{} w={w} ub={ub}",
                            m.name()
                        );
                        assert_eq!(got.abandoned, want.abandoned, "{} w={w} ub={ub}", m.name());
                    }
                }
            }
            assert_eq!(cache.take_rebuilds(), 0, "{}: same-length candidates must hit", m.name());
        }
    }

    #[test]
    fn cdtw_eval_is_the_suite_core_verbatim() {
        // the dispatch arm must be bitwise the suite's DTW core — the
        // bit-identity guarantee of every pre-metric code path
        let a = [0.5, -1.25, 2.0, 0.0, 1.0, -0.75, 0.25, 1.5];
        let b = [1.0, 0.25, -0.5, 1.75, -1.0, 0.5, 0.0, -0.25];
        let mut ws1 = DtwWorkspace::default();
        let mut ws2 = DtwWorkspace::default();
        for suite in Suite::ALL {
            for w in [1usize, 3, 8] {
                for ub in [f64::INFINITY, 10.0, 1.0] {
                    let got = Metric::Cdtw.eval(&a, &b, w, ub, None, suite, &mut ws1);
                    let want = suite.dtw(&a, &b, w, ub, None, &mut ws2);
                    assert_eq!(got.to_bits(), want.to_bits(), "{} w={w} ub={ub}", suite.name());
                }
            }
        }
    }
}
