//! **EAPrunedDTW** — Algorithm 3 of the paper, the system's core
//! contribution: thin wrappers over the unified band kernel instantiated
//! with the uniform squared-Euclidean cost model ([`kernel::DtwCost`]).
//! The DTW-specialised kernel copy that lived here is retired — the
//! `UNIFORM` const makes [`kernel::eap_kernel`] const-fold the same
//! 1-/2-dependency stage updates, bitwise- and cost-equivalent to the old
//! code (pinned by the property tests in `kernel.rs` against a verbatim
//! copy). The wrappers keep Algorithm 3's two production extensions
//! (§5): the Sakoe-Chiba band `w` and per-line threshold tightening from
//! the cumulative LB_Keogh tail `cb`.

use super::kernel::{eap_kernel, eap_kernel_counted, eap_kernel_f32, DtwCost, KernelEval};
use super::{lines_cols, KernelWorkspace};

/// Unwindowed EAPrunedDTW — the paper's Algorithm 3 exactly: exact DTW when
/// the distance is `<= ub`, `+inf` once it can prove it strictly exceeds it.
pub fn eap_dtw(a: &[f64], b: &[f64], ub: f64) -> f64 {
    eap_cdtw(a, b, a.len().max(b.len()), ub, None, &mut KernelWorkspace::default())
}

/// Windowed EAPrunedDTW with optional cumulative-bound tightening — the
/// production distance of the UCR-MON suites. `w` is the Sakoe-Chiba band
/// (length differences beyond it have no admissible path → `+inf`); `cb`
/// the cumulative LB_Keogh tail over the *column* positions.
pub fn eap_cdtw(
    a: &[f64],
    b: &[f64],
    w: usize,
    ub: f64,
    cb: Option<&[f64]>,
    ws: &mut KernelWorkspace,
) -> f64 {
    eap_cdtw_eval(a, b, w, ub, cb, ws).dist
}

/// [`eap_cdtw`] returning the full [`KernelEval`] outcome — distance plus
/// whether an `+inf` was a threshold-driven early abandon. The serving
/// layers route through this for exact abandon attribution.
pub(crate) fn eap_cdtw_eval(
    a: &[f64],
    b: &[f64],
    w: usize,
    ub: f64,
    cb: Option<&[f64]>,
    ws: &mut KernelWorkspace,
) -> KernelEval {
    let (li, co) = lines_cols(a, b);
    eap_kernel(&DtwCost { li, co }, w, ub, cb, ws)
}

/// [`eap_cdtw_eval`] on f32 DP lines — the opt-in `--precision f32`
/// storage mode. Thresholds are inflated on narrowing so this may only
/// over-admit relative to the exact run (never over-prune); the returned
/// distance is epsilon-close to the f64 value, not bitwise.
pub(crate) fn eap_cdtw_eval_f32(
    a: &[f64],
    b: &[f64],
    w: usize,
    ub: f64,
    cb: Option<&[f64]>,
    ws: &mut KernelWorkspace,
) -> KernelEval {
    let (li, co) = lines_cols(a, b);
    eap_kernel_f32(&DtwCost { li, co }, w, ub, cb, ws)
}

/// [`eap_cdtw`] that also reports how many DP cells were actually
/// computed (the A1/A2 ablation instrumentation).
pub fn eap_cdtw_counted(
    a: &[f64],
    b: &[f64],
    w: usize,
    ub: f64,
    cb: Option<&[f64]>,
    ws: &mut KernelWorkspace,
) -> (f64, u64) {
    let (li, co) = lines_cols(a, b);
    let (e, cells) = eap_kernel_counted(&DtwCost { li, co }, w, ub, cb, ws);
    (e.dist, cells)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::dtw::{cdtw, dtw, dtw_oracle};
    use crate::distances::DtwWorkspace;

    const S: [f64; 6] = [3.0, 1.0, 4.0, 4.0, 1.0, 1.0];
    const T: [f64; 6] = [1.0, 3.0, 2.0, 1.0, 2.0, 2.0];

    #[test]
    fn paper_fig4a_ub9_exact() {
        // ub = 9 = DTW(S,T): pruning but no abandon; exact value returned.
        assert_eq!(eap_dtw(&S, &T, 9.0), 9.0);
    }

    #[test]
    fn paper_fig4b_ub6_abandons() {
        assert_eq!(eap_dtw(&S, &T, 6.0), f64::INFINITY);
    }

    #[test]
    fn infinite_ub_is_exact_dtw() {
        assert_eq!(eap_dtw(&S, &T, f64::INFINITY), dtw(&S, &T));
    }

    #[test]
    fn counted_prunes_cells() {
        let mut ws = DtwWorkspace::default();
        let (d_loose, c_loose) =
            eap_cdtw_counted(&S, &T, 6, f64::INFINITY, None, &mut ws);
        let (d_tight, c_tight) = eap_cdtw_counted(&S, &T, 6, 9.0, None, &mut ws);
        assert_eq!(d_loose, 9.0);
        assert_eq!(d_tight, 9.0);
        assert!(c_tight < c_loose, "{c_tight} !< {c_loose}");
        assert_eq!(c_loose, 36); // full 6x6 matrix when nothing prunes
    }

    #[test]
    fn windowed_matches_cdtw() {
        for w in 0..=6 {
            let exact = cdtw(&S, &T, w);
            let got = eap_cdtw(&S, &T, w, f64::INFINITY, None, &mut DtwWorkspace::default());
            assert_eq!(got, exact, "w={w}");
        }
    }

    #[test]
    fn tie_is_kept_under_window() {
        for w in 1..=6 {
            let exact = cdtw(&S, &T, w);
            let got = eap_cdtw(&S, &T, w, exact, None, &mut DtwWorkspace::default());
            assert_eq!(got, exact, "w={w}");
        }
    }

    fn xorshift(seed: u64) -> impl FnMut() -> f64 {
        let mut x = seed;
        move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 2.0 - 1.0
        }
    }

    #[test]
    fn random_exactness_sweep() {
        let mut ws = DtwWorkspace::default();
        for seed in 1..=5u64 {
            let mut rnd = xorshift(seed);
            for n in [7usize, 16, 33] {
                let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
                let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
                for w in [1usize, 3, n / 2, n] {
                    let exact = cdtw(&a, &b, w);
                    let loose = eap_cdtw(&a, &b, w, f64::INFINITY, None, &mut ws);
                    assert!((loose - exact).abs() < 1e-12, "seed={seed} n={n} w={w}");
                    let tie = eap_cdtw(&a, &b, w, exact, None, &mut ws);
                    assert!((tie - exact).abs() < 1e-12, "tie seed={seed} n={n} w={w}");
                    let above = eap_cdtw(&a, &b, w, exact * 1.25 + 0.5, None, &mut ws);
                    assert!((above - exact).abs() < 1e-12);
                    let below = eap_cdtw(&a, &b, w, exact * 0.999 - 1e-9, None, &mut ws);
                    assert_eq!(below, f64::INFINITY);
                }
            }
        }
    }

    #[test]
    fn unequal_lengths_and_band_feasibility() {
        let a = [0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [0.0, 2.0, 4.0];
        assert_eq!(eap_dtw(&a, &b, f64::INFINITY), dtw(&a, &b));
        let mut ws = DtwWorkspace::default();
        // |7-3| = 4 > w=2: infeasible band
        assert_eq!(eap_cdtw(&a, &b, 2, f64::INFINITY, None, &mut ws), f64::INFINITY);
        assert_eq!(
            eap_cdtw(&a, &b, 4, f64::INFINITY, None, &mut ws),
            dtw_oracle(&a, &b, Some(4))
        );
    }

    #[test]
    fn valid_cb_preserves_exactness() {
        // all-zero cb is always valid and must change nothing
        let mut ws = DtwWorkspace::default();
        let cb = vec![0.0; T.len() + 1];
        for w in 1..=6 {
            let exact = cdtw(&S, &T, w);
            let got = eap_cdtw(&S, &T, w, exact, Some(&cb), &mut ws);
            assert_eq!(got, exact);
        }
    }

    #[test]
    fn f32_eval_tracks_f64_and_keeps_the_tie() {
        let mut ws = DtwWorkspace::default();
        let exact = eap_cdtw(&S, &T, 6, f64::INFINITY, None, &mut ws);
        let e32 = eap_cdtw_eval_f32(&S, &T, 6, f64::INFINITY, None, &mut ws);
        assert!(!e32.abandoned);
        assert!((e32.dist - exact).abs() / exact <= 1e-4);
        // the f32 contract: an ub the f64 run completes under must also
        // complete in f32 (inflated thresholds over-admit, never over-prune)
        let tie = eap_cdtw_eval_f32(&S, &T, 6, exact, None, &mut ws);
        assert!(!tie.abandoned);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(eap_dtw(&[], &[], 1.0), 0.0);
        assert_eq!(eap_dtw(&[1.0], &[], 1.0), f64::INFINITY);
    }
}
