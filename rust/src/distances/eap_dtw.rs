//! **EAPrunedDTW** — Algorithm 3 of the paper, the system's core
//! contribution.
//!
//! The DP scan is decomposed into four per-line stages:
//!
//! 1. **Left border extension** — while the line still starts at
//!    `next_start`, cells whose value exceeds the threshold are *discard
//!    points*: the left border moves right, permanently (`next_start += 1`).
//!    Cells here have no viable left neighbour, so only two dependencies.
//! 2. **Interior** — ordinary three-way-min DTW cells, up to the previous
//!    line's *pruning point*.
//! 3. **The pruning-point column** — where the left and right borders can
//!    *collide*. If the cell sits right after a discard point it depends on
//!    its diagonal only, and a value above the threshold proves every
//!    remaining alignment exceeds `ub` → **early abandon** (paper Fig. 4b,
//!    blue cell). This collision test is what lets EAPrunedDTW abandon
//!    earlier than PrunedDTW's row-minimum check.
//! 4. **Right of the pruning point** — cells here can only depend on their
//!    left neighbour (everything above is `> ub`), so the line is cut as
//!    soon as one exceeds the threshold, creating the new pruning point.
//!
//! Stages 1 and 4 update cells from one or two previous values instead of
//! the three-way min — the paper's second headline saving.
//!
//! This implementation extends Algorithm 3 with the two features the
//! UCR-MON suite needs (paper §5): a Sakoe-Chiba band `w`, folded into the
//! borders (band-left merges into `next_start`, band-right caps the line),
//! and per-line upper-bound tightening from the cumulative LB_Keogh tail
//! `cb` (any path through line `i` still pays `cb[min(i+w+1, m)]` in the
//! future, so the effective line threshold is `ub - cb[...]`).

use super::{lines_cols, DtwWorkspace};
use crate::distances::cost::sqed;

/// Unwindowed EAPrunedDTW — the paper's Algorithm 3 exactly: exact DTW when
/// the distance is `<= ub`, `+inf` once it can prove it strictly exceeds it.
pub fn eap_dtw(a: &[f64], b: &[f64], ub: f64) -> f64 {
    let mut ws = DtwWorkspace::default();
    eap_dtw_ws(a, b, ub, &mut ws)
}

/// [`eap_dtw`] with a caller-provided workspace.
pub fn eap_dtw_ws(a: &[f64], b: &[f64], ub: f64, ws: &mut DtwWorkspace) -> f64 {
    let w = a.len().max(b.len());
    let mut cells = 0u64;
    eap_impl::<false>(a, b, w, ub, None, ws, &mut cells)
}

/// Windowed EAPrunedDTW with optional cumulative-bound tightening — the
/// production distance of the UCR-MON suites.
///
/// * `w` — Sakoe-Chiba band (cells). Series whose length difference
///   exceeds `w` have no admissible path → `+inf`.
/// * `cb` — cumulative LB_Keogh tail over the *column* series positions
///   (`cb.len() == min_len + 1`, `cb[min_len] == 0`, non-increasing).
pub fn eap_cdtw(
    a: &[f64],
    b: &[f64],
    w: usize,
    ub: f64,
    cb: Option<&[f64]>,
    ws: &mut DtwWorkspace,
) -> f64 {
    let mut cells = 0u64;
    eap_impl::<false>(a, b, w, ub, cb, ws, &mut cells)
}

/// [`eap_cdtw`] that also reports how many DP cells were actually computed
/// — the instrumentation behind the pruning-effectiveness ablations (A1/A2).
/// Monomorphised separately so the production path pays nothing for it.
pub fn eap_cdtw_counted(
    a: &[f64],
    b: &[f64],
    w: usize,
    ub: f64,
    cb: Option<&[f64]>,
    ws: &mut DtwWorkspace,
) -> (f64, u64) {
    let mut cells = 0u64;
    let d = eap_impl::<true>(a, b, w, ub, cb, ws, &mut cells);
    (d, cells)
}

#[inline(always)]
fn eap_impl<const COUNT: bool>(
    a: &[f64],
    b: &[f64],
    w: usize,
    ub: f64,
    cb: Option<&[f64]>,
    ws: &mut DtwWorkspace,
    cells: &mut u64,
) -> f64 {
    if a.is_empty() || b.is_empty() {
        return if a.len() == b.len() { 0.0 } else { f64::INFINITY };
    }
    let (li, co) = lines_cols(a, b);
    let n = li.len();
    let m = co.len();
    if n - m > w {
        return f64::INFINITY;
    }
    if let Some(cb) = cb {
        debug_assert_eq!(cb.len(), m + 1);
        debug_assert!(cb[m] == 0.0);
    }
    ws.reset(m);
    ws.curr[0] = 0.0;

    let mut next_start = 1usize; // first non-discarded column (left border)
    let mut ppp = 1usize; // previous line's pruning point
    let mut pp = 0usize; // pruning point being built on the current line

    for i in 1..=n {
        std::mem::swap(&mut ws.prev, &mut ws.curr);
        let v = li[i - 1];
        let band_lo = i.saturating_sub(w).max(1);
        let band_hi = i.checked_add(w).map_or(m, |x| x.min(m));
        // Band-left is an INF border: folding it into next_start is safe
        // because both only ever move right.
        if band_lo > next_start {
            next_start = band_lo;
        }
        // Per-line threshold: ub minus the future cost any path through
        // this line must still pay (0 without cb).
        let th = match cb {
            Some(cb) => {
                let idx = i
                    .checked_add(w)
                    .and_then(|x| x.checked_add(1))
                    .map_or(m, |x| x.min(m));
                ub - cb[idx]
            }
            None => ub,
        };
        let prev = &mut ws.prev;
        let curr = &mut ws.curr;
        let mut j = next_start;
        curr[j - 1] = f64::INFINITY; // left-border sentinel; next line's diagonal
        // `left` carries curr[j-1] in a register across all four stages so
        // the loop-carried FP chain is min+add, not a memory round-trip
        // plus min+min+add (see dtw.rs; IEEE-exact reassociation).
        let mut left = f64::INFINITY;

        // Stage 1: discard points — no left dependency.
        while j == next_start && j < ppp {
            let d = sqed(v, co[j - 1]) + prev[j].min(prev[j - 1]);
            curr[j] = d;
            left = d;
            if COUNT {
                *cells += 1;
            }
            if d <= th {
                pp = j + 1;
            } else {
                next_start += 1;
            }
            j += 1;
        }
        // Stage 2: interior — classic three-way min.
        while j < ppp {
            let bp = prev[j].min(prev[j - 1]);
            let d = sqed(v, co[j - 1]) + left.min(bp);
            curr[j] = d;
            left = d;
            if COUNT {
                *cells += 1;
            }
            if d <= th {
                pp = j + 1;
            }
            j += 1;
        }
        // Stage 3: the previous pruning point's column.
        if j <= band_hi {
            let c = sqed(v, co[j - 1]);
            if j == next_start {
                // Right after a discard point: diagonal dependency only.
                // A value above the threshold collides the borders →
                // nothing viable remains anywhere: early abandon.
                let d = c + prev[j - 1];
                curr[j] = d;
                left = d;
                if COUNT {
                    *cells += 1;
                }
                if d <= th {
                    pp = j + 1;
                } else {
                    return f64::INFINITY;
                }
            } else {
                let d = c + left.min(prev[j - 1]);
                curr[j] = d;
                left = d;
                if COUNT {
                    *cells += 1;
                }
                if d <= th {
                    pp = j + 1;
                }
            }
            j += 1;
        } else if j == next_start {
            // The discard points swallowed the whole (banded) line:
            // same abandon as Algorithm 2.
            return f64::INFINITY;
        }
        // Stage 4: right of the pruning point — left dependency only;
        // the first value above the threshold prunes the rest of the line.
        while j == pp && j <= band_hi {
            let d = sqed(v, co[j - 1]) + left;
            curr[j] = d;
            left = d;
            if COUNT {
                *cells += 1;
            }
            if d <= th {
                pp = j + 1;
            }
            j += 1;
        }
        ppp = pp;
    }
    // Exact only if the last line's pruning point cleared the last column.
    if ppp > m {
        ws.curr[m]
    } else {
        f64::INFINITY
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::dtw::{cdtw, dtw, dtw_oracle};

    const S: [f64; 6] = [3.0, 1.0, 4.0, 4.0, 1.0, 1.0];
    const T: [f64; 6] = [1.0, 3.0, 2.0, 1.0, 2.0, 2.0];

    #[test]
    fn paper_fig4a_ub9_exact() {
        // ub = 9 = DTW(S,T): pruning but no abandon; exact value returned.
        assert_eq!(eap_dtw(&S, &T, 9.0), 9.0);
    }

    #[test]
    fn paper_fig4b_ub6_abandons() {
        assert_eq!(eap_dtw(&S, &T, 6.0), f64::INFINITY);
    }

    #[test]
    fn infinite_ub_is_exact_dtw() {
        assert_eq!(eap_dtw(&S, &T, f64::INFINITY), dtw(&S, &T));
    }

    #[test]
    fn counted_prunes_cells() {
        let mut ws = DtwWorkspace::default();
        let (d_loose, c_loose) =
            eap_cdtw_counted(&S, &T, 6, f64::INFINITY, None, &mut ws);
        let (d_tight, c_tight) = eap_cdtw_counted(&S, &T, 6, 9.0, None, &mut ws);
        assert_eq!(d_loose, 9.0);
        assert_eq!(d_tight, 9.0);
        assert!(c_tight < c_loose, "{c_tight} !< {c_loose}");
        assert_eq!(c_loose, 36); // full 6x6 matrix when nothing prunes
    }

    #[test]
    fn windowed_matches_cdtw() {
        for w in 0..=6 {
            let exact = cdtw(&S, &T, w);
            let got = eap_cdtw(&S, &T, w, f64::INFINITY, None, &mut DtwWorkspace::default());
            assert_eq!(got, exact, "w={w}");
        }
    }

    #[test]
    fn tie_is_kept_under_window() {
        for w in 1..=6 {
            let exact = cdtw(&S, &T, w);
            let got = eap_cdtw(&S, &T, w, exact, None, &mut DtwWorkspace::default());
            assert_eq!(got, exact, "w={w}");
        }
    }

    fn xorshift(seed: u64) -> impl FnMut() -> f64 {
        let mut x = seed;
        move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 2.0 - 1.0
        }
    }

    #[test]
    fn random_exactness_sweep() {
        let mut ws = DtwWorkspace::default();
        for seed in 1..=5u64 {
            let mut rnd = xorshift(seed);
            for n in [7usize, 16, 33] {
                let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
                let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
                for w in [1usize, 3, n / 2, n] {
                    let exact = cdtw(&a, &b, w);
                    let loose = eap_cdtw(&a, &b, w, f64::INFINITY, None, &mut ws);
                    assert!((loose - exact).abs() < 1e-12, "seed={seed} n={n} w={w}");
                    let tie = eap_cdtw(&a, &b, w, exact, None, &mut ws);
                    assert!((tie - exact).abs() < 1e-12, "tie seed={seed} n={n} w={w}");
                    let above = eap_cdtw(&a, &b, w, exact * 1.25 + 0.5, None, &mut ws);
                    assert!((above - exact).abs() < 1e-12);
                    let below = eap_cdtw(&a, &b, w, exact * 0.999 - 1e-9, None, &mut ws);
                    assert_eq!(below, f64::INFINITY);
                }
            }
        }
    }

    #[test]
    fn unequal_lengths_and_band_feasibility() {
        let a = [0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [0.0, 2.0, 4.0];
        assert_eq!(eap_dtw(&a, &b, f64::INFINITY), dtw(&a, &b));
        let mut ws = DtwWorkspace::default();
        // |7-3| = 4 > w=2: infeasible band
        assert_eq!(eap_cdtw(&a, &b, 2, f64::INFINITY, None, &mut ws), f64::INFINITY);
        assert_eq!(
            eap_cdtw(&a, &b, 4, f64::INFINITY, None, &mut ws),
            dtw_oracle(&a, &b, Some(4))
        );
    }

    #[test]
    fn valid_cb_preserves_exactness() {
        // all-zero cb is always valid and must change nothing
        let mut ws = DtwWorkspace::default();
        let cb = vec![0.0; T.len() + 1];
        for w in 1..=6 {
            let exact = cdtw(&S, &T, w);
            let got = eap_cdtw(&S, &T, w, exact, Some(&cb), &mut ws);
            assert_eq!(got, exact);
        }
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(eap_dtw(&[], &[], 1.0), 0.0);
        assert_eq!(eap_dtw(&[1.0], &[], 1.0), f64::INFINITY);
    }
}
