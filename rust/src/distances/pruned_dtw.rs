//! PrunedDTW — the prior-art comparator of the UCR-USP suite (Silva &
//! Batista [19]; [20], paper §2.3): left (`sc`) / right (`ec`) pruning
//! with a **row-minimum** abandon and the classic three-way min in every
//! cell — exactly the two things EAPrunedDTW improves on (§4), so this
//! implementation keeps them faithfully (INF back-fill included) and is
//! deliberately NOT folded into the unified kernel.

use super::DtwWorkspace;
use crate::distances::cost::sqed;

/// Windowed PrunedDTW with row-minimum early abandon and optional
/// cumulative-bound tightening (same `cb` contract as
/// [`crate::distances::eap_dtw::eap_cdtw`]). Equal-length inputs are not
/// required, but `|len(a)-len(b)| <= w` is.
pub fn pruned_cdtw(
    a: &[f64],
    b: &[f64],
    w: usize,
    ub: f64,
    cb: Option<&[f64]>,
    ws: &mut DtwWorkspace,
) -> f64 {
    let mut cells = 0u64;
    pruned_impl::<false>(a, b, w, ub, cb, ws, &mut cells)
}

/// Unwindowed PrunedDTW.
pub fn pruned_dtw(a: &[f64], b: &[f64], ub: f64, ws: &mut DtwWorkspace) -> f64 {
    let w = a.len().max(b.len());
    pruned_cdtw(a, b, w, ub, None, ws)
}

/// [`pruned_cdtw`] that also reports the number of DP cells computed
/// (ablation instrumentation, monomorphised separately).
pub fn pruned_cdtw_counted(
    a: &[f64],
    b: &[f64],
    w: usize,
    ub: f64,
    cb: Option<&[f64]>,
    ws: &mut DtwWorkspace,
) -> (f64, u64) {
    let mut cells = 0u64;
    let d = pruned_impl::<true>(a, b, w, ub, cb, ws, &mut cells);
    (d, cells)
}

#[inline(always)]
fn pruned_impl<const COUNT: bool>(
    a: &[f64],
    b: &[f64],
    w: usize,
    ub: f64,
    cb: Option<&[f64]>,
    ws: &mut DtwWorkspace,
    cells: &mut u64,
) -> f64 {
    if a.is_empty() || b.is_empty() {
        return if a.len() == b.len() { 0.0 } else { f64::INFINITY };
    }
    let (li, co) = super::lines_cols(a, b);
    let n = li.len();
    let m = co.len();
    if n - m > w {
        return f64::INFINITY;
    }
    ws.reset(m);
    ws.curr[0] = 0.0;
    let mut sc = 1usize; // start column (left pruning, persistent)
    let mut ec = 1usize; // previous row's end column (right pruning)

    for i in 1..=n {
        std::mem::swap(&mut ws.prev, &mut ws.curr);
        let v = li[i - 1];
        let band_lo = i.saturating_sub(w).max(1);
        let band_hi = i.checked_add(w).map_or(m, |x| x.min(m));
        let beg = sc.max(band_lo);
        let th = match cb {
            Some(cb) => {
                let idx = i
                    .checked_add(w)
                    .and_then(|x| x.checked_add(1))
                    .map_or(m, |x| x.min(m));
                ub - cb[idx]
            }
            None => ub,
        };
        let prev = &mut ws.prev;
        let curr = &mut ws.curr;
        curr[beg - 1] = f64::INFINITY;
        let mut smaller_found = false;
        let mut ec_next = beg;
        let mut row_min = f64::INFINITY;
        let mut left = f64::INFINITY; // register-carried curr[j-1]
        let mut j = beg;
        while j <= band_hi {
            let c = sqed(v, co[j - 1]);
            // PrunedDTW keeps the full three-way min in every cell — the
            // overhead the EAPrunedDTW stage decomposition removes.
            // (Loop-carried value enters the chain last; see dtw.rs.)
            let bp = prev[j].min(prev[j - 1]);
            let d = c + left.min(bp);
            curr[j] = d;
            left = d;
            if COUNT {
                *cells += 1;
            }
            if d > th {
                if !smaller_found {
                    sc = j + 1;
                }
                if j >= ec {
                    // Right prune: everything further on this row exceeds
                    // the threshold. Back-fill so the next row's stale
                    // reads see INF (part of PrunedDTW's bookkeeping cost).
                    for k in j + 1..=band_hi {
                        curr[k] = f64::INFINITY;
                    }
                    j = band_hi; // loop epilogue advances past band_hi
                }
            } else {
                smaller_found = true;
                ec_next = j + 1;
                if d < row_min {
                    row_min = d;
                }
            }
            j += 1;
        }
        // Band growth sentinel (next row's band can extend one column).
        if band_hi + 1 <= m {
            curr[band_hi + 1] = f64::INFINITY;
        }
        // Row-minimum early abandon — PrunedDTW's abandon test (§2.3/§4).
        if row_min > th {
            return f64::INFINITY;
        }
        if sc > band_hi {
            return f64::INFINITY;
        }
        ec = ec_next;
    }
    ws.curr[m]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::dtw::{cdtw, dtw, dtw_oracle};

    const S: [f64; 6] = [3.0, 1.0, 4.0, 4.0, 1.0, 1.0];
    const T: [f64; 6] = [1.0, 3.0, 2.0, 1.0, 2.0, 2.0];

    #[test]
    fn exact_with_infinite_ub() {
        assert_eq!(pruned_dtw(&S, &T, f64::INFINITY, &mut DtwWorkspace::default()), 9.0);
    }

    #[test]
    fn exact_at_tie() {
        assert_eq!(pruned_dtw(&S, &T, 9.0, &mut DtwWorkspace::default()), 9.0);
    }

    #[test]
    fn never_underestimates_below_ub() {
        // PrunedDTW's row-min abandon is opportunistic (paper §4): below
        // the true distance we get +inf or an over-approximation, never
        // an underestimate.
        for ub in [0.0, 6.0, 8.9] {
            let got = pruned_dtw(&S, &T, ub, &mut DtwWorkspace::default());
            assert!(got.is_infinite() || got >= 9.0, "ub={ub}: {got}");
        }
    }

    #[test]
    fn windowed_matches_cdtw() {
        let mut ws = DtwWorkspace::default();
        for w in 0..=6 {
            assert_eq!(
                pruned_cdtw(&S, &T, w, f64::INFINITY, None, &mut ws),
                cdtw(&S, &T, w),
                "w={w}"
            );
        }
    }

    #[test]
    fn random_exactness_sweep() {
        let mut x = 4242u64;
        let mut rnd = move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 2.0 - 1.0
        };
        let mut ws = DtwWorkspace::default();
        for n in [9usize, 17, 32] {
            let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
            let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
            for w in [1usize, n / 3, n] {
                let exact = cdtw(&a, &b, w);
                assert!((pruned_cdtw(&a, &b, w, f64::INFINITY, None, &mut ws) - exact).abs() < 1e-12);
                assert!((pruned_cdtw(&a, &b, w, exact, None, &mut ws) - exact).abs() < 1e-12);
                let below = pruned_cdtw(&a, &b, w, exact * 0.999 - 1e-9, None, &mut ws);
                assert!(
                    below.is_infinite() || below >= exact - 1e-9,
                    "underestimate: {below} vs {exact}"
                );
            }
        }
    }

    #[test]
    fn counted_prunes_fewer_than_full_matrix() {
        let mut ws = DtwWorkspace::default();
        let (d, c_full) = pruned_cdtw_counted(&S, &T, 6, f64::INFINITY, None, &mut ws);
        assert_eq!(d, 9.0);
        assert_eq!(c_full, 36);
        let (d2, c_pruned) = pruned_cdtw_counted(&S, &T, 6, 9.0, None, &mut ws);
        assert_eq!(d2, 9.0);
        assert!(c_pruned < c_full);
    }

    #[test]
    fn unequal_lengths() {
        let a = [0.0, 1.0, 2.0, 1.0, 0.0, -1.0, 0.5];
        let b = [0.0, 2.0, 0.0];
        assert_eq!(pruned_dtw(&a, &b, f64::INFINITY, &mut DtwWorkspace::default()), dtw(&a, &b));
        let mut ws = DtwWorkspace::default();
        assert_eq!(
            pruned_cdtw(&a, &b, 4, f64::INFINITY, None, &mut ws),
            dtw_oracle(&a, &b, Some(4))
        );
    }
}
