//! Baseline DTW — Algorithm 1 of the paper (O(n) space, no pruning), its
//! Sakoe-Chiba-banded variant (§2.1), and a full-matrix oracle for tests.

use super::{lines_cols, DtwWorkspace};
use crate::distances::cost::sqed;

/// Unconstrained DTW, O(n) space — the paper's Algorithm 1, verbatim.
pub fn dtw(a: &[f64], b: &[f64]) -> f64 {
    let mut ws = DtwWorkspace::default();
    dtw_ws(a, b, &mut ws)
}

/// [`dtw`] with a caller-provided workspace (allocation-free hot path).
/// Algorithm 1 is the `w >= len` case of the banded scan — same cell
/// formula, same sentinels — so this is [`cdtw_ws`] with a full-width
/// band, bitwise (one loop body to maintain instead of two).
pub fn dtw_ws(a: &[f64], b: &[f64], ws: &mut DtwWorkspace) -> f64 {
    cdtw_ws(a, b, a.len().max(b.len()), ws)
}

/// Sakoe-Chiba-banded DTW (cDTW): warping paths deviate at most `w` cells
/// from the diagonal; `w >= max(len)` degenerates to [`dtw`], a length
/// difference beyond `w` has no admissible path (`+inf`).
pub fn cdtw(a: &[f64], b: &[f64], w: usize) -> f64 {
    let mut ws = DtwWorkspace::default();
    cdtw_ws(a, b, w, &mut ws)
}

/// [`cdtw`] with a caller-provided workspace.
pub fn cdtw_ws(a: &[f64], b: &[f64], w: usize, ws: &mut DtwWorkspace) -> f64 {
    if a.is_empty() || b.is_empty() {
        return if a.len() == b.len() { 0.0 } else { f64::INFINITY };
    }
    let (li, co) = lines_cols(a, b);
    if li.len() - co.len() > w {
        return f64::INFINITY;
    }
    let m = co.len();
    ws.reset(m);
    ws.curr[0] = 0.0;
    for i in 1..=li.len() {
        std::mem::swap(&mut ws.prev, &mut ws.curr);
        let lo = i.saturating_sub(w).max(1);
        let hi = (i + w).min(m);
        // Borders of the band: the cell left of the band start must not
        // leak a value from two lines ago.
        ws.curr[lo - 1] = f64::INFINITY;
        let v = li[i - 1];
        let mut left = f64::INFINITY; // register-carried curr[j-1]
        for j in lo..=hi {
            let c = sqed(v, co[j - 1]);
            let bp = ws.prev[j].min(ws.prev[j - 1]);
            let d = c + left.min(bp);
            ws.curr[j] = d;
            left = d;
        }
        // Cell one past the band end is read as prev[j] by the next line
        // (whose band can extend one further right): kill the stale value.
        if hi + 1 <= m {
            ws.curr[hi + 1] = f64::INFINITY;
        }
    }
    ws.curr[m]
}

/// Full-matrix DP oracle; returns the whole (n+1)×(m+1) matrix so tests
/// can check individual cells against the paper's worked examples.
pub fn dtw_matrix(a: &[f64], b: &[f64], w: Option<usize>) -> Vec<Vec<f64>> {
    let (n, m) = (a.len(), b.len());
    let w = w.unwrap_or(n.max(m));
    let mut d = vec![vec![f64::INFINITY; m + 1]; n + 1];
    d[0][0] = 0.0;
    for i in 1..=n {
        for j in 1..=m {
            if i.abs_diff(j) > w {
                continue;
            }
            let c = sqed(a[i - 1], b[j - 1]);
            let best = d[i - 1][j].min(d[i][j - 1]).min(d[i - 1][j - 1]);
            if best.is_finite() {
                d[i][j] = c + best;
            }
        }
    }
    d
}

/// Oracle distance: last cell of [`dtw_matrix`].
pub fn dtw_oracle(a: &[f64], b: &[f64], w: Option<usize>) -> f64 {
    let d = dtw_matrix(a, b, w);
    d[a.len()][b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    const S: [f64; 6] = [3.0, 1.0, 4.0, 4.0, 1.0, 1.0];
    const T: [f64; 6] = [1.0, 3.0, 2.0, 1.0, 2.0, 2.0];

    #[test]
    fn paper_worked_example() {
        // Fig. 2: DTW(S, T) = 9.
        assert_eq!(dtw(&S, &T), 9.0);
        assert_eq!(dtw_oracle(&S, &T, None), 9.0);
    }

    #[test]
    fn paper_matrix_cells() {
        // Fig. 2a spot checks (colours run 0..=22 in the paper figure).
        let d = dtw_matrix(&S, &T, None);
        assert_eq!(d[1][1], 4.0); // (3-1)^2
        assert_eq!(d[6][6], 9.0);
        // max value 22 appears in the matrix
        let mx = d
            .iter()
            .flatten()
            .copied()
            .filter(|v| v.is_finite())
            .fold(0.0f64, f64::max);
        assert_eq!(mx, 22.0);
    }

    #[test]
    fn symmetric() {
        assert_eq!(dtw(&S, &T), dtw(&T, &S));
        assert_eq!(cdtw(&S, &T, 2), cdtw(&T, &S, 2));
    }

    #[test]
    fn identity_zero() {
        assert_eq!(dtw(&S, &S), 0.0);
        assert_eq!(cdtw(&S, &S, 0), 0.0);
    }

    #[test]
    fn window_zero_is_sqed() {
        let want: f64 = S.iter().zip(T.iter()).map(|(x, y)| sqed(*x, *y)).sum();
        assert_eq!(cdtw(&S, &T, 0), want);
    }

    #[test]
    fn window_full_is_dtw() {
        assert_eq!(cdtw(&S, &T, 6), dtw(&S, &T));
        assert_eq!(cdtw(&S, &T, 100), dtw(&S, &T));
    }

    #[test]
    fn window_monotone() {
        let mut prev = f64::INFINITY;
        for w in 0..=6 {
            let v = cdtw(&S, &T, w);
            assert!(v <= prev, "w={w}: {v} > {prev}");
            prev = v;
        }
    }

    #[test]
    fn unequal_lengths() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [1.0, 3.0, 5.0];
        assert_eq!(dtw(&a, &b), dtw_oracle(&a, &b, None));
        // band narrower than the length gap: no valid path
        assert_eq!(cdtw(&a, &b, 1), f64::INFINITY);
        assert_eq!(cdtw(&a, &b, 2), dtw_oracle(&a, &b, Some(2)));
    }

    #[test]
    fn banded_matches_oracle_random() {
        let mut x = 1234u64;
        let mut rnd = move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 4.0 - 2.0
        };
        for n in [5usize, 9, 17, 33] {
            let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
            let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
            for w in [0usize, 1, 2, n / 2, n] {
                let got = cdtw(&a, &b, w);
                let want = dtw_oracle(&a, &b, Some(w));
                assert!((got - want).abs() < 1e-9, "n={n} w={w}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn empty_series() {
        assert_eq!(dtw(&[], &[]), 0.0);
        assert_eq!(dtw(&[], &[1.0]), f64::INFINITY);
        assert_eq!(cdtw(&[1.0], &[], 3), f64::INFINITY);
    }
}
