//! **The unified EAPruned band kernel** — Algorithm 3 of the paper as ONE
//! generic pruned-band recurrence over an inlineable [`CostModel`]. Every
//! EAPruned evaluation in the crate — cDTW/DTW ([`super::eap_dtw`]),
//! WDTW/ERP/MSM/TWE ([`super::elastic`]) — is a zero-cost instantiation
//! of [`eap_kernel`]: one copy of the band bookkeeping
//! (`next_start`/`pp`/`ppp`), one abandon condition, one place to
//! optimise. [`CostModel::UNIFORM`] marks the DTW family and const-folds
//! the paper's specialised 1-/2-dependency stage updates; non-uniform
//! models (possibly finite borders, distinct step costs) keep the
//! generalised bodies — `benches/ablation_stages.rs` measures exactly
//! that toggle. Returned distances `<= ub` are exact; `+inf` with
//! [`KernelEval::abandoned`] set means proven strictly above `ub`
//! (strict `>` preserves ties, paper §2.2). The stage walk, band
//! invariants and abandon conditions are documented in
//! `distances/README.md`; bitwise identity with the retired specialised
//! kernels is pinned by the property tests below.
//!
//! Two widening axes sit on top of the scalar core (the paper brackets
//! its contribution "vectorization and approximation aside" — this is
//! exactly that headroom):
//!
//! * **Multi-candidate wavefront** ([`eap_kernel_multi`] /
//!   [`eap_kernel_multi_dyn`]): N same-shape candidates advance their
//!   band recurrences in row lockstep, one candidate per *lane*, each
//!   lane carrying its own upper bound, `next_start`/`pp`/`ppp` band
//!   state and DP lines ([`MultiWorkspace`]). A lane that abandons is
//!   retired from the active set immediately (swap-remove compaction),
//!   so dead candidates stop costing row work. The f64 multi-lane path
//!   is **bitwise identical** to evaluating each lane through the scalar
//!   kernel (`tests/conformance_lanes.rs`) — the DP cell values never
//!   depend on the threshold, only the control flow does.
//! * **Opt-in f32 storage** ([`Precision::F32`], [`eap_kernel_f32`]):
//!   the core is generic over a [`Scalar`] line type. `f64` is the
//!   bitwise-pinned default; `f32` halves line bandwidth and is gated by
//!   an epsilon contract instead — thresholds are *inflated* by
//!   [`F32_UB_REL_MARGIN`] (and rounded up one ulp) when narrowed, so
//!   accumulated f32 rounding can only over-admit, never over-prune.

use super::KernelWorkspace;
use crate::distances::cost::sqed;

/// An elastic distance's cost structure over two series. Indices are
/// 1-based (DP convention); implementations read their series with
/// `[i - 1]`. All step costs must be `>= 0` and the border functions
/// non-decreasing (debug-asserted) — that monotonicity is what makes
/// discard points permanent and the collision abandon sound.
pub trait CostModel {
    /// All three step costs identical and both borders infinite — the DTW
    /// family. Enables the specialised 1-/2-dependency stage updates via
    /// const propagation, and is required for `cb` threshold tightening
    /// (the cascade's bounds lower-bound DTW only).
    const UNIFORM: bool = false;
    fn n_lines(&self) -> usize;
    fn n_cols(&self) -> usize;
    /// Cost of the diagonal (match) move into `(i, j)`.
    fn diag(&self, i: usize, j: usize) -> f64;
    /// Cost of the vertical move into `(i, j)` (consume line point `i`).
    fn top(&self, i: usize, j: usize) -> f64;
    /// Cost of the horizontal move into `(i, j)` (consume column point `j`).
    fn left(&self, i: usize, j: usize) -> f64;
    /// Border row `D(0, j)`, `j >= 1`; non-decreasing in `j`.
    fn border_row(&self, _j: usize) -> f64 {
        f64::INFINITY
    }
    /// Border column `D(i, 0)`, `i >= 1`; non-decreasing in `i`.
    fn border_col(&self, _i: usize) -> f64 {
        f64::INFINITY
    }
}

/// Outcome of one kernel evaluation: the distance plus whether an `+inf`
/// was a *threshold-driven early abandon* — as opposed to an infeasible
/// band or a length-mismatched empty input. This is what makes the
/// per-metric abandon counters exact instead of inferred from
/// `is_infinite()` at the dispatch site.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelEval {
    pub dist: f64,
    pub abandoned: bool,
}

impl KernelEval {
    fn done(dist: f64) -> Self {
        Self { dist, abandoned: false }
    }
    fn abandon() -> Self {
        Self { dist: f64::INFINITY, abandoned: true }
    }
    fn infeasible() -> Self {
        Self { dist: f64::INFINITY, abandoned: false }
    }
}

/// DP line storage width. `F64` is the default and is bitwise-pinned
/// against the retired kernels; `F32` is the opt-in approximate mode
/// (`--precision f32`), gated by the epsilon contract in
/// `tests/conformance_lanes.rs` — it may only over-admit, never
/// over-prune, so a completed f32 evaluation is a true
/// `<= ub`-or-slightly-above distance, and an f32 abandon is sound.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    #[default]
    F64,
    F32,
}

impl Precision {
    pub fn name(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "f64" => Some(Precision::F64),
            "f32" => Some(Precision::F32),
            _ => None,
        }
    }
}

/// Relative slack added to every threshold when narrowing it to f32:
/// `th32 = next_up((th + MARGIN * |th|) as f32)`. Accumulated f32
/// rounding over a DP line is orders of magnitude below 1e-3 relative,
/// so an f32 comparison `d32 <= th32` admits every cell the exact f64
/// run would admit — the f32 path can only *over-admit* (evaluate a
/// candidate fully where f64 would have abandoned), never over-prune.
pub const F32_UB_REL_MARGIN: f64 = 1e-3;

/// `f32::next_up` polyfill (stable only since Rust 1.86; the crate pins
/// 1.82): the smallest f32 strictly greater than `x`, with `-0.0`/`0.0`
/// both mapping to the smallest positive subnormal.
#[inline(always)]
fn next_up_f32(x: f32) -> f32 {
    if x.is_nan() || x == f32::INFINITY {
        return x;
    }
    if x == 0.0 {
        return f32::from_bits(1);
    }
    let bits = x.to_bits();
    if x > 0.0 {
        f32::from_bits(bits + 1)
    } else {
        f32::from_bits(bits - 1)
    }
}

/// The DP line scalar the band core is generic over. The `f64` impl is a
/// pure pass-through — instantiating the core at `f64` is *code motion*,
/// not a behaviour change, and stays bitwise-pinned by the retired-kernel
/// property tests. The `f32` impl narrows costs on load and inflates
/// thresholds ([`F32_UB_REL_MARGIN`]) so pruning stays admissible.
pub trait Scalar: Copy + PartialOrd + std::fmt::Debug + 'static {
    const ZERO: Self;
    const INF: Self;
    const NAME: &'static str;
    /// Narrow a cost-model value (step cost or border) onto the line.
    fn from_cost(v: f64) -> Self;
    /// Narrow an upper bound / line threshold. Must never round down
    /// below the exact value (f32 inflates and rounds up one ulp).
    fn threshold(v: f64) -> Self;
    fn to_f64(self) -> f64;
    fn add(self, o: Self) -> Self;
    fn min2(self, o: Self) -> Self;
    /// (Re)initialise this scalar's two DP lines in `ws` to `len + 1`
    /// cells of `+inf` (counts a regrow exactly like the f64 reset).
    fn reset_lines(ws: &mut KernelWorkspace, len: usize);
    fn swap_lines(ws: &mut KernelWorkspace);
    fn lines_mut(ws: &mut KernelWorkspace) -> (&mut [Self], &mut [Self]);
    fn final_cell(ws: &KernelWorkspace, m: usize) -> Self;
}

impl Scalar for f64 {
    const ZERO: Self = 0.0;
    const INF: Self = f64::INFINITY;
    const NAME: &'static str = "f64";
    #[inline(always)]
    fn from_cost(v: f64) -> Self {
        v
    }
    #[inline(always)]
    fn threshold(v: f64) -> Self {
        v
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline(always)]
    fn add(self, o: Self) -> Self {
        self + o
    }
    #[inline(always)]
    fn min2(self, o: Self) -> Self {
        self.min(o)
    }
    #[inline(always)]
    fn reset_lines(ws: &mut KernelWorkspace, len: usize) {
        ws.reset(len);
    }
    #[inline(always)]
    fn swap_lines(ws: &mut KernelWorkspace) {
        std::mem::swap(&mut ws.prev, &mut ws.curr);
    }
    #[inline(always)]
    fn lines_mut(ws: &mut KernelWorkspace) -> (&mut [Self], &mut [Self]) {
        (&mut ws.prev, &mut ws.curr)
    }
    #[inline(always)]
    fn final_cell(ws: &KernelWorkspace, m: usize) -> Self {
        ws.curr[m]
    }
}

impl Scalar for f32 {
    const ZERO: Self = 0.0;
    const INF: Self = f32::INFINITY;
    const NAME: &'static str = "f32";
    #[inline(always)]
    fn from_cost(v: f64) -> Self {
        v as f32
    }
    #[inline(always)]
    fn threshold(v: f64) -> Self {
        if !v.is_finite() {
            return v as f32;
        }
        next_up_f32((v + F32_UB_REL_MARGIN * v.abs()) as f32)
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline(always)]
    fn add(self, o: Self) -> Self {
        self + o
    }
    #[inline(always)]
    fn min2(self, o: Self) -> Self {
        self.min(o)
    }
    #[inline(always)]
    fn reset_lines(ws: &mut KernelWorkspace, len: usize) {
        ws.reset32(len);
    }
    #[inline(always)]
    fn swap_lines(ws: &mut KernelWorkspace) {
        std::mem::swap(&mut ws.prev32, &mut ws.curr32);
    }
    #[inline(always)]
    fn lines_mut(ws: &mut KernelWorkspace) -> (&mut [Self], &mut [Self]) {
        (&mut ws.prev32, &mut ws.curr32)
    }
    #[inline(always)]
    fn final_cell(ws: &KernelWorkspace, m: usize) -> Self {
        ws.curr32[m]
    }
}

/// EAPruned evaluation of a [`CostModel`] under Sakoe-Chiba band `w` and
/// upper bound `ub`. `cb`, valid for [`CostModel::UNIFORM`] models only,
/// is the cumulative lower-bound tail over column positions
/// (`cb.len() == n_cols + 1`, `cb[n_cols] == 0`, non-increasing): any
/// path through line `i` still pays `cb[min(i+w+1, m)]` in the future.
#[inline]
pub fn eap_kernel<C: CostModel>(
    model: &C,
    w: usize,
    ub: f64,
    cb: Option<&[f64]>,
    ws: &mut KernelWorkspace,
) -> KernelEval {
    let mut cells = 0u64;
    eap_core::<f64, C, false>(model, w, ub, cb, ws, &mut cells)
}

/// [`eap_kernel`] that also reports how many DP cells were computed (the
/// A1/A2 ablation instrumentation); monomorphised separately so the
/// production path pays nothing for it.
pub fn eap_kernel_counted<C: CostModel>(
    model: &C,
    w: usize,
    ub: f64,
    cb: Option<&[f64]>,
    ws: &mut KernelWorkspace,
) -> (KernelEval, u64) {
    let mut cells = 0u64;
    let e = eap_core::<f64, C, true>(model, w, ub, cb, ws, &mut cells);
    (e, cells)
}

/// [`eap_kernel`] on f32 DP lines — the opt-in [`Precision::F32`] mode.
/// Costs narrow on load; `ub`/`cb` thresholds are inflated on narrowing
/// ([`F32_UB_REL_MARGIN`]) so the run may only over-admit relative to the
/// exact f64 evaluation. The returned distance is the f32 accumulation
/// widened back to f64 — epsilon-close to exact, not bitwise.
#[inline]
pub fn eap_kernel_f32<C: CostModel>(
    model: &C,
    w: usize,
    ub: f64,
    cb: Option<&[f64]>,
    ws: &mut KernelWorkspace,
) -> KernelEval {
    let mut cells = 0u64;
    eap_core::<f32, C, false>(model, w, ub, cb, ws, &mut cells)
}

/// Per-lane band bookkeeping: the left discard frontier, the pruning
/// point being built on the current line, and the previous line's
/// pruning point (Algorithm 3's `next_start` / `pp` / `ppp`).
#[derive(Debug, Clone, Copy, Default)]
struct BandState {
    next_start: usize,
    pp: usize,
    ppp: usize,
}

/// Row 0 of the DP table: uniform models have the classic +inf border
/// row (initial pruning point right after the origin); finite border
/// rows (ERP) are materialised up to the band edge, the initial pruning
/// point landing on the first border cell strictly above `ub` (borders
/// non-decreasing). Returns the initial `ppp`.
#[inline(always)]
fn init_row0<S: Scalar, C: CostModel>(model: &C, w: usize, ub: S, curr: &mut [S]) -> usize {
    let m = model.n_cols();
    let mut ppp = 1usize;
    if !C::UNIFORM {
        let row0_end = m.min(w);
        ppp = row0_end + 1;
        let mut prev_border = 0.0f64;
        for j in 1..=row0_end {
            let bf = model.border_row(j);
            debug_assert!(bf >= prev_border, "border_row must be non-decreasing");
            prev_border = bf;
            let b = S::from_cost(bf);
            curr[j] = b;
            if b > ub {
                ppp = j;
                break;
            }
        }
    }
    ppp
}

/// Line threshold for row `i`: ub minus the future cost any path still
/// pays. `cb` is a DTW lower bound, so it is const-folded away for
/// non-UNIFORM models — tightening ERP/MSM/TWE/WDTW with it would
/// over-prune (the debug_assert at the call sites catches the misuse,
/// this makes it harmless in release builds too).
#[inline(always)]
fn line_threshold<C: CostModel>(ub: f64, cb: Option<&[f64]>, i: usize, w: usize, m: usize) -> f64 {
    match cb {
        Some(cb) if C::UNIFORM => {
            let idx = i.checked_add(w).and_then(|x| x.checked_add(1)).map_or(m, |x| x.min(m));
            ub - cb[idx]
        }
        _ => ub,
    }
}

/// Advance one candidate's recurrence through row `i`: the four-stage
/// banded walk of Algorithm 3, verbatim from the pre-wavefront scalar
/// kernel (shared by the scalar and multi-lane paths — pure code
/// motion, so the f64 scalar path stays bitwise-pinned). Returns `true`
/// iff the band collapsed on this row (early abandon).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn advance_row<S: Scalar, C: CostModel, const COUNT: bool>(
    model: &C,
    i: usize,
    w: usize,
    m: usize,
    th: S,
    st: &mut BandState,
    prev: &[S],
    curr: &mut [S],
    cells: &mut u64,
) -> bool {
    let band_lo = i.saturating_sub(w).max(1);
    let band_hi = i.checked_add(w).map_or(m, |x| x.min(m));
    // band-left folds into next_start: both only ever move right
    if band_lo > st.next_start {
        st.next_start = band_lo;
    }
    let mut j = st.next_start;
    // Left sentinel (the live border for column 0, +inf otherwise);
    // `left` register-carries curr[j-1] across all four stages (see
    // dtw.rs — IEEE-exact reassociation).
    let mut left = if j == 1 { S::from_cost(model.border_col(i)) } else { S::INF };
    curr[j - 1] = left;

    // Stage 1: the discard-point region. Uniform models have no
    // viable left neighbour here (two-dependency update, every
    // above-threshold cell advances the border); a possibly-live
    // finite border keeps the 3-way min and gates the advance.
    while j == st.next_start && j < st.ppp {
        let left_v = left;
        let d = if C::UNIFORM {
            S::from_cost(model.diag(i, j)).add(prev[j].min2(prev[j - 1]))
        } else {
            prev[j]
                .add(S::from_cost(model.top(i, j)))
                .min2(prev[j - 1].add(S::from_cost(model.diag(i, j))))
                .min2(left_v.add(S::from_cost(model.left(i, j))))
        };
        curr[j] = d;
        left = d;
        if COUNT {
            *cells += 1;
        }
        if d <= th {
            st.pp = j + 1;
        } else if C::UNIFORM || left_v > th {
            st.next_start += 1;
        }
        j += 1;
    }
    // Stage 2: interior — the classic three-way min.
    while j < st.ppp {
        let d = if C::UNIFORM {
            let bp = prev[j].min2(prev[j - 1]);
            S::from_cost(model.diag(i, j)).add(left.min2(bp))
        } else {
            prev[j]
                .add(S::from_cost(model.top(i, j)))
                .min2(prev[j - 1].add(S::from_cost(model.diag(i, j))))
                .min2(left.add(S::from_cost(model.left(i, j))))
        };
        curr[j] = d;
        left = d;
        if COUNT {
            *cells += 1;
        }
        if d <= th {
            st.pp = j + 1;
        }
        j += 1;
    }
    // Stage 3: the previous pruning point's column (top dependency
    // excluded — prev cells at/right of ppp are above the threshold).
    // The borders can collide here: everything left above the
    // threshold too → nothing viable remains, abandon (Fig. 4b).
    if j <= band_hi {
        let left_v = left;
        let d = if C::UNIFORM {
            if j == st.next_start {
                S::from_cost(model.diag(i, j)).add(prev[j - 1])
            } else {
                S::from_cost(model.diag(i, j)).add(left_v.min2(prev[j - 1]))
            }
        } else {
            prev[j - 1]
                .add(S::from_cost(model.diag(i, j)))
                .min2(left_v.add(S::from_cost(model.left(i, j))))
        };
        curr[j] = d;
        left = d;
        if COUNT {
            *cells += 1;
        }
        if d <= th {
            st.pp = j + 1;
        } else if j == st.next_start && (C::UNIFORM || left_v > th) {
            return true;
        }
        j += 1;
    } else if j == st.next_start {
        // Discard points swallowed the whole banded line (Algorithm
        // 2's abandon); sound with finite borders because stage 1
        // gates the advance on the left value.
        return true;
    }
    // Stage 4: right of the pruning point — left dependency only;
    // the first above-threshold value prunes the rest of the line.
    while j == st.pp && j <= band_hi {
        let d = left.add(S::from_cost(model.left(i, j)));
        curr[j] = d;
        left = d;
        if COUNT {
            *cells += 1;
        }
        if d <= th {
            st.pp = j + 1;
        }
        j += 1;
    }
    st.ppp = st.pp;
    false
}

#[inline(always)]
fn eap_core<S: Scalar, C: CostModel, const COUNT: bool>(
    model: &C,
    w: usize,
    ub: f64,
    cb: Option<&[f64]>,
    ws: &mut KernelWorkspace,
    cells: &mut u64,
) -> KernelEval {
    let n = model.n_lines();
    let m = model.n_cols();
    if n == 0 || m == 0 {
        return if n == m { KernelEval::done(0.0) } else { KernelEval::infeasible() };
    }
    if n.abs_diff(m) > w {
        return KernelEval::infeasible();
    }
    debug_assert!(cb.is_none() || C::UNIFORM, "cb tightening needs a uniform-cost model");
    if let Some(cb) = cb {
        debug_assert_eq!(cb.len(), m + 1);
        debug_assert!(cb[m] == 0.0);
    }
    S::reset_lines(ws, m);
    let ppp = {
        let (_, curr) = S::lines_mut(ws);
        curr[0] = S::ZERO;
        init_row0::<S, C>(model, w, S::threshold(ub), curr)
    };
    let mut st = BandState { next_start: 1, pp: 0, ppp };

    for i in 1..=n {
        S::swap_lines(ws);
        let th = S::threshold(line_threshold::<C>(ub, cb, i, w, m));
        let (prev, curr) = S::lines_mut(ws);
        if advance_row::<S, C, COUNT>(model, i, w, m, th, &mut st, prev, curr, cells) {
            return KernelEval::abandon();
        }
    }
    // Exact only if the last line's pruning point cleared the last column.
    if st.ppp > m {
        KernelEval::done(S::final_cell(ws, m).to_f64())
    } else {
        KernelEval::abandon()
    }
}

/// Widest lane group the packers form (`ScanTuning::lanes` is clamped to
/// this). 8 f64 lines fit comfortably in L1 for serving-sized queries.
pub const MAX_LANES: usize = 8;

/// Row cadence at which a multi-lane evaluation re-reads each live
/// lane's threshold through the `refresh` closure — the same
/// strip-boundary cadence the deadline checks use ([`crate::bounds::batch::DEFAULT_STRIP`]).
/// A refresh may only *tighten* (it is folded in with `min`), so any
/// completed lane still returns the exact bitwise distance.
pub const LANE_REFRESH_ROWS: usize = 64;

/// Per-lane state for a multi-candidate wavefront evaluation: one
/// [`KernelWorkspace`] (DP line pair) per lane, the band bookkeeping,
/// the live upper bounds, and the compacting active-lane set. Reused
/// across groups; [`MultiWorkspace::warm`] pre-sizes everything so the
/// scan hot path never allocates.
#[derive(Debug, Default, Clone)]
pub struct MultiWorkspace {
    lanes: Vec<KernelWorkspace>,
    states: Vec<BandState>,
    ubs: Vec<f64>,
    /// indices of lanes still advancing; abandoned lanes are
    /// swap-removed so the row loop never touches them again
    active: Vec<usize>,
}

impl MultiWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, lanes: usize) {
        while self.lanes.len() < lanes {
            self.lanes.push(KernelWorkspace::default());
        }
        if self.states.len() < lanes {
            self.states.resize(lanes, BandState::default());
        }
        if self.ubs.len() < lanes {
            self.ubs.resize(lanes, f64::INFINITY);
        }
    }

    /// Pre-size `lanes` lane workspaces for candidates of `len` points
    /// without counting a regrow (the pool warm-up path).
    pub fn warm(&mut self, lanes: usize, len: usize, precision: Precision) {
        self.ensure(lanes);
        for lw in &mut self.lanes[..lanes] {
            match precision {
                Precision::F64 => lw.warm(len),
                Precision::F32 => lw.warm32(len),
            }
        }
        if self.active.capacity() < lanes {
            self.active.reserve(lanes - self.active.len());
        }
    }

    /// Borrow one lane's workspace directly — the lone-survivor
    /// fall-through evaluates through the scalar kernel on lane 0.
    pub fn lane_ws(&mut self, lane: usize) -> &mut KernelWorkspace {
        self.ensure(lane + 1);
        &mut self.lanes[lane]
    }

    /// Total regrowth tally across lanes (see
    /// [`crate::metrics::Counters::kernel_workspace_regrows`]).
    pub fn regrows(&self) -> u64 {
        self.lanes.iter().map(KernelWorkspace::regrows).sum()
    }
}

/// Multi-candidate wavefront evaluation: advance `models.len()` same-shape
/// candidates' band recurrences in row lockstep, one candidate per lane.
/// Each lane carries its own upper bound (`ubs`), optional cumulative
/// bound tail (`cbs`, [`CostModel::UNIFORM`] only) and band state; a lane
/// whose band collapses is retired and compacted out of the active set.
/// Every [`LANE_REFRESH_ROWS`] rows each live lane's threshold is
/// re-read through `refresh(lane)` and folded in with `min` (monotone —
/// a refresh can only tighten; pass `|l| ubs[l]` for a no-op).
///
/// `out` is filled with one [`KernelEval`] per lane, index-aligned with
/// `models`. On f64 every lane's outcome is bitwise-identical to a
/// scalar [`eap_kernel`] call with the same (model, w, ub, cb) — lanes
/// share no DP state, only the row loop.
///
/// All models must share one `(n_lines, n_cols)` shape — that is what
/// makes a lane group (cohorts and strip survivors already guarantee it).
#[allow(clippy::too_many_arguments)]
pub fn eap_kernel_multi_dyn<S: Scalar, C: CostModel>(
    models: &[C],
    w: usize,
    ubs: &[f64],
    cbs: &[Option<&[f64]>],
    ws: &mut MultiWorkspace,
    mut refresh: impl FnMut(usize) -> f64,
    out: &mut Vec<KernelEval>,
) {
    let lanes = models.len();
    assert_eq!(ubs.len(), lanes, "one ub per lane");
    assert_eq!(cbs.len(), lanes, "one cb slot per lane");
    out.clear();
    if lanes == 0 {
        return;
    }
    let n = models[0].n_lines();
    let m = models[0].n_cols();
    debug_assert!(
        models.iter().all(|mo| mo.n_lines() == n && mo.n_cols() == m),
        "lane group must share one (n_lines, n_cols) shape"
    );
    if n == 0 || m == 0 {
        let e = if n == m { KernelEval::done(0.0) } else { KernelEval::infeasible() };
        out.resize(lanes, e);
        return;
    }
    if n.abs_diff(m) > w {
        out.resize(lanes, KernelEval::infeasible());
        return;
    }
    ws.ensure(lanes);
    // abandon placeholders: lanes retired mid-scan keep this outcome,
    // surviving lanes overwrite it after the row loop
    out.resize(lanes, KernelEval::abandon());
    ws.active.clear();
    for lane in 0..lanes {
        debug_assert!(
            cbs[lane].is_none() || C::UNIFORM,
            "cb tightening needs a uniform-cost model"
        );
        if let Some(cb) = cbs[lane] {
            debug_assert_eq!(cb.len(), m + 1);
            debug_assert!(cb[m] == 0.0);
        }
        ws.ubs[lane] = ubs[lane];
        let lw = &mut ws.lanes[lane];
        S::reset_lines(lw, m);
        let (_, curr) = S::lines_mut(lw);
        curr[0] = S::ZERO;
        let ppp = init_row0::<S, C>(&models[lane], w, S::threshold(ubs[lane]), curr);
        ws.states[lane] = BandState { next_start: 1, pp: 0, ppp };
        ws.active.push(lane);
    }
    let mut cells = 0u64;
    for i in 1..=n {
        if ws.active.is_empty() {
            break;
        }
        // Threshold staleness fix: a group is packed with thresholds
        // frozen at formation time, so a sibling finishing early (in an
        // earlier group, or via the owner's top-k tightening) would go
        // unnoticed for the rest of the evaluation. Re-reading here at
        // strip-boundary cadence folds fresher bounds in monotonically.
        if i % LANE_REFRESH_ROWS == 0 {
            for k in 0..ws.active.len() {
                let lane = ws.active[k];
                let t = refresh(lane);
                if t < ws.ubs[lane] {
                    ws.ubs[lane] = t;
                }
            }
        }
        let mut idx = 0;
        while idx < ws.active.len() {
            let lane = ws.active[idx];
            let th_f = line_threshold::<C>(ws.ubs[lane], cbs[lane], i, w, m);
            let lw = &mut ws.lanes[lane];
            S::swap_lines(lw);
            let th = S::threshold(th_f);
            let (prev, curr) = S::lines_mut(lw);
            let dead = advance_row::<S, C, false>(
                &models[lane],
                i,
                w,
                m,
                th,
                &mut ws.states[lane],
                prev,
                curr,
                &mut cells,
            );
            if dead {
                // retire + compact: the abandoned candidate stops
                // costing row work from the very next row
                ws.active.swap_remove(idx);
            } else {
                idx += 1;
            }
        }
    }
    for &lane in &ws.active {
        out[lane] = if ws.states[lane].ppp > m {
            KernelEval::done(S::final_cell(&ws.lanes[lane], m).to_f64())
        } else {
            KernelEval::abandon()
        };
    }
}

/// Const-width convenience wrapper over [`eap_kernel_multi_dyn`]: f64
/// lanes, no `cb` tails, thresholds frozen at the call (no refresh).
pub fn eap_kernel_multi<C: CostModel, const LANES: usize>(
    models: &[C; LANES],
    w: usize,
    ubs: &[f64; LANES],
    ws: &mut MultiWorkspace,
    out: &mut Vec<KernelEval>,
) {
    let cbs = [None::<&[f64]>; LANES];
    eap_kernel_multi_dyn::<f64, C>(models, w, ubs, &cbs, ws, |lane| ubs[lane], out);
}

/// DTW's cost structure — squared-Euclidean cost on every move, infinite
/// borders: the `UNIFORM` instantiation behind [`super::eap_dtw`].
pub struct DtwCost<'a> {
    pub li: &'a [f64],
    pub co: &'a [f64],
}

impl CostModel for DtwCost<'_> {
    const UNIFORM: bool = true;
    #[inline(always)]
    fn n_lines(&self) -> usize {
        self.li.len()
    }
    #[inline(always)]
    fn n_cols(&self) -> usize {
        self.co.len()
    }
    #[inline(always)]
    fn diag(&self, i: usize, j: usize) -> f64 {
        sqed(self.li[i - 1], self.co[j - 1])
    }
    #[inline(always)]
    fn top(&self, i: usize, j: usize) -> f64 {
        self.diag(i, j)
    }
    #[inline(always)]
    fn left(&self, i: usize, j: usize) -> f64 {
        self.diag(i, j)
    }
}

/// Naive full-matrix evaluation of a [`CostModel`] — the slow,
/// obviously-correct oracle behind every conformance suite.
pub fn naive_kernel<C: CostModel>(model: &C, w: usize) -> f64 {
    let n = model.n_lines();
    let m = model.n_cols();
    if n == 0 || m == 0 {
        return if n == m { 0.0 } else { f64::INFINITY };
    }
    let mut d = vec![vec![f64::INFINITY; m + 1]; n + 1];
    d[0][0] = 0.0;
    for j in 1..=m.min(w) {
        d[0][j] = model.border_row(j);
    }
    for i in 1..=n.min(w) {
        d[i][0] = model.border_col(i);
    }
    for i in 1..=n {
        for j in 1..=m {
            if i.abs_diff(j) > w {
                continue;
            }
            let mut best = f64::INFINITY;
            if d[i - 1][j].is_finite() {
                best = best.min(d[i - 1][j] + model.top(i, j));
            }
            if d[i - 1][j - 1].is_finite() {
                best = best.min(d[i - 1][j - 1] + model.diag(i, j));
            }
            if d[i][j - 1].is_finite() {
                best = best.min(d[i][j - 1] + model.left(i, j));
            }
            d[i][j] = best;
        }
    }
    d[n][m]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::dtw::cdtw;
    use crate::distances::eap_dtw::{eap_cdtw, eap_dtw};
    use crate::distances::elastic::erp::Erp;
    use crate::distances::elastic::msm::Msm;
    use crate::distances::elastic::twe::Twe;
    use crate::distances::elastic::wdtw::Wdtw;
    use crate::distances::{lines_cols, DtwWorkspace};

    /// The **retired specialised kernels**, kept verbatim as bitwise
    /// oracles: the pre-unification DTW-specialised `eap_impl` of
    /// `eap_dtw.rs` and the generic `eap_elastic` of `elastic/core.rs`.
    /// The property tests below pin the unified kernel against them bit
    /// for bit; they exist nowhere else anymore.
    mod retired {
        use super::super::CostModel;
        use crate::distances::cost::sqed;
        use crate::distances::{lines_cols, DtwWorkspace};

        /// Pre-unification `eap_dtw.rs::eap_impl` (COUNT stripped).
        pub fn eap_impl(
            a: &[f64],
            b: &[f64],
            w: usize,
            ub: f64,
            cb: Option<&[f64]>,
            ws: &mut DtwWorkspace,
        ) -> f64 {
            if a.is_empty() || b.is_empty() {
                return if a.len() == b.len() { 0.0 } else { f64::INFINITY };
            }
            let (li, co) = lines_cols(a, b);
            let n = li.len();
            let m = co.len();
            if n - m > w {
                return f64::INFINITY;
            }
            ws.reset(m);
            ws.curr[0] = 0.0;
            let mut next_start = 1usize;
            let mut ppp = 1usize;
            let mut pp = 0usize;
            for i in 1..=n {
                std::mem::swap(&mut ws.prev, &mut ws.curr);
                let v = li[i - 1];
                let band_lo = i.saturating_sub(w).max(1);
                let band_hi = i.checked_add(w).map_or(m, |x| x.min(m));
                if band_lo > next_start {
                    next_start = band_lo;
                }
                let th = match cb {
                    Some(cb) => {
                        let idx = i
                            .checked_add(w)
                            .and_then(|x| x.checked_add(1))
                            .map_or(m, |x| x.min(m));
                        ub - cb[idx]
                    }
                    None => ub,
                };
                let prev = &mut ws.prev;
                let curr = &mut ws.curr;
                let mut j = next_start;
                curr[j - 1] = f64::INFINITY;
                let mut left = f64::INFINITY;
                while j == next_start && j < ppp {
                    let d = sqed(v, co[j - 1]) + prev[j].min(prev[j - 1]);
                    curr[j] = d;
                    left = d;
                    if d <= th {
                        pp = j + 1;
                    } else {
                        next_start += 1;
                    }
                    j += 1;
                }
                while j < ppp {
                    let bp = prev[j].min(prev[j - 1]);
                    let d = sqed(v, co[j - 1]) + left.min(bp);
                    curr[j] = d;
                    left = d;
                    if d <= th {
                        pp = j + 1;
                    }
                    j += 1;
                }
                if j <= band_hi {
                    let c = sqed(v, co[j - 1]);
                    if j == next_start {
                        let d = c + prev[j - 1];
                        curr[j] = d;
                        left = d;
                        if d <= th {
                            pp = j + 1;
                        } else {
                            return f64::INFINITY;
                        }
                    } else {
                        let d = c + left.min(prev[j - 1]);
                        curr[j] = d;
                        left = d;
                        if d <= th {
                            pp = j + 1;
                        }
                    }
                    j += 1;
                } else if j == next_start {
                    return f64::INFINITY;
                }
                while j == pp && j <= band_hi {
                    let d = sqed(v, co[j - 1]) + left;
                    curr[j] = d;
                    left = d;
                    if d <= th {
                        pp = j + 1;
                    }
                    j += 1;
                }
                ppp = pp;
            }
            if ppp > m {
                ws.curr[m]
            } else {
                f64::INFINITY
            }
        }

        /// Pre-unification `elastic/core.rs::eap_elastic`.
        pub fn eap_elastic<M: CostModel>(
            model: &M,
            w: usize,
            ub: f64,
            ws: &mut DtwWorkspace,
        ) -> f64 {
            let n = model.n_lines();
            let m = model.n_cols();
            if n == 0 || m == 0 {
                return if n == m { 0.0 } else { f64::INFINITY };
            }
            if n.abs_diff(m) > w {
                return f64::INFINITY;
            }
            ws.reset(m);
            ws.curr[0] = 0.0;
            let row0_end = m.min(w);
            let mut ppp = row0_end + 1;
            for j in 1..=row0_end {
                let b = model.border_row(j);
                ws.curr[j] = b;
                if b > ub {
                    ppp = j;
                    break;
                }
            }
            let mut next_start = 1usize;
            let mut pp = 0usize;
            for i in 1..=n {
                std::mem::swap(&mut ws.prev, &mut ws.curr);
                let band_lo = i.saturating_sub(w).max(1);
                let band_hi = i.checked_add(w).map_or(m, |x| x.min(m));
                if band_lo > next_start {
                    next_start = band_lo;
                }
                let prev = &mut ws.prev;
                let curr = &mut ws.curr;
                let mut j = next_start;
                let mut left = if j == 1 { model.border_col(i) } else { f64::INFINITY };
                curr[j - 1] = left;
                while j == next_start && j < ppp {
                    let left_v = left;
                    let d = (prev[j] + model.top(i, j))
                        .min(prev[j - 1] + model.diag(i, j))
                        .min(left_v + model.left(i, j));
                    curr[j] = d;
                    left = d;
                    if d <= ub {
                        pp = j + 1;
                    } else if left_v > ub {
                        next_start += 1;
                    }
                    j += 1;
                }
                while j < ppp {
                    let bp =
                        (prev[j] + model.top(i, j)).min(prev[j - 1] + model.diag(i, j));
                    let d = bp.min(left + model.left(i, j));
                    curr[j] = d;
                    left = d;
                    if d <= ub {
                        pp = j + 1;
                    }
                    j += 1;
                }
                if j <= band_hi {
                    let left_v = left;
                    let d = (prev[j - 1] + model.diag(i, j)).min(left_v + model.left(i, j));
                    curr[j] = d;
                    left = d;
                    if d <= ub {
                        pp = j + 1;
                    } else if j == next_start && left_v > ub {
                        return f64::INFINITY;
                    }
                    j += 1;
                } else if j == next_start {
                    return f64::INFINITY;
                }
                while j == pp && j <= band_hi {
                    let d = left + model.left(i, j);
                    curr[j] = d;
                    left = d;
                    if d <= ub {
                        pp = j + 1;
                    }
                    j += 1;
                }
                ppp = pp;
            }
            if ppp > m {
                ws.curr[m]
            } else {
                f64::INFINITY
            }
        }
    }

    fn xorshift(seed: u64) -> impl FnMut() -> f64 {
        let mut x = seed;
        move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 2.0 - 1.0
        }
    }

    /// ub grid of the pinning property: exact DTW at +inf, the tie, and a
    /// 0 bound that must abandon everywhere (identity pairs excluded by
    /// random data).
    fn ub_grid(exact: f64) -> [f64; 3] {
        [f64::INFINITY, exact, 0.0]
    }

    #[track_caller]
    fn assert_bits(got: f64, want: f64, tag: &str) {
        assert_eq!(got.to_bits(), want.to_bits(), "{tag}: {got} vs {want}");
    }

    /// The satellite property test: the unified kernel is **bitwise**
    /// identical to the retired specialised kernels over random series,
    /// all six metrics, ub ∈ {inf, tight, 0}.
    #[test]
    fn unified_kernel_bitwise_matches_retired_kernels_for_all_six_metrics() {
        let mut ws = DtwWorkspace::default();
        let mut ws2 = DtwWorkspace::default();
        for seed in 1..=4u64 {
            let mut rnd = xorshift(0x5EED ^ (seed << 8));
            for n in [5usize, 13, 29] {
                let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
                let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
                for w in [1usize, 3, n / 2, n] {
                    let tag = |m: &str, ub: f64| format!("{m} seed={seed} n={n} w={w} ub={ub}");
                    // cdtw (uniform flow, via the public wrapper)
                    let exact = retired::eap_impl(&a, &b, w, f64::INFINITY, None, &mut ws2);
                    for ub in ub_grid(exact) {
                        let got = eap_cdtw(&a, &b, w, ub, None, &mut ws);
                        let want = retired::eap_impl(&a, &b, w, ub, None, &mut ws2);
                        assert_bits(got, want, &tag("cdtw", ub));
                    }
                    // cdtw with a valid (all-zero) cb tail
                    let cb = vec![0.0; n + 1];
                    let got = eap_cdtw(&a, &b, w, exact, Some(&cb), &mut ws);
                    let want = retired::eap_impl(&a, &b, w, exact, Some(&cb), &mut ws2);
                    assert_bits(got, want, &tag("cdtw+cb", exact));
                    // dtw (unwindowed uniform flow)
                    let exact = retired::eap_impl(&a, &b, n, f64::INFINITY, None, &mut ws2);
                    for ub in ub_grid(exact) {
                        let got = eap_dtw(&a, &b, ub);
                        let want = retired::eap_impl(&a, &b, n, ub, None, &mut ws2);
                        assert_bits(got, want, &tag("dtw", ub));
                    }
                    // the four non-uniform cost models
                    let wdtw = Wdtw::new(&a, &b, 0.05);
                    let erp = Erp::new(&a, &b, 0.25);
                    let msm = Msm::new(&a, &b, 0.5);
                    let twe = Twe::new(&a, &b, 0.05, 1.0);
                    macro_rules! pin {
                        ($name:literal, $model:expr, $w:expr) => {
                            let exact =
                                retired::eap_elastic(&$model, $w, f64::INFINITY, &mut ws2);
                            for ub in ub_grid(exact) {
                                let got = eap_kernel(&$model, $w, ub, None, &mut ws).dist;
                                let want = retired::eap_elastic(&$model, $w, ub, &mut ws2);
                                assert_bits(got, want, &tag($name, ub));
                            }
                        };
                    }
                    pin!("wdtw", wdtw, n); // WDTW is conventionally unwindowed
                    pin!("erp", erp, w);
                    pin!("msm", msm, w);
                    pin!("twe", twe, w);
                }
            }
        }
    }

    #[test]
    fn uniform_flow_matches_cdtw_oracle_and_reports_abandons() {
        let mut ws = DtwWorkspace::default();
        let mut rnd = xorshift(0xABCD);
        for n in [6usize, 17] {
            let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
            let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
            for w in [2usize, n] {
                let (li, co) = lines_cols(&a, &b);
                let model = DtwCost { li, co };
                let want = cdtw(&a, &b, w);
                let e = eap_kernel(&model, w, f64::INFINITY, None, &mut ws);
                assert!((e.dist - want).abs() < 1e-12, "n={n} w={w}");
                assert!(!e.abandoned);
                let tie = eap_kernel(&model, w, want, None, &mut ws);
                assert_eq!(tie.dist.to_bits(), want.to_bits());
                assert!(!tie.abandoned);
                if want > 0.0 {
                    let below = eap_kernel(&model, w, want * 0.5, None, &mut ws);
                    assert_eq!(below.dist, f64::INFINITY);
                    assert!(below.abandoned, "threshold-driven inf must be an abandon");
                }
            }
        }
    }

    #[test]
    fn infeasible_band_and_empty_inputs_are_not_abandons() {
        let mut ws = DtwWorkspace::default();
        let a = [0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [0.0, 2.0, 4.0];
        let (li, co) = lines_cols(&a, &b);
        let e = eap_kernel(&DtwCost { li, co }, 2, f64::INFINITY, None, &mut ws);
        assert_eq!(e.dist, f64::INFINITY);
        assert!(!e.abandoned, "|7-3| > w=2 is infeasible, not abandoned");
        let e = eap_kernel(&DtwCost { li: &[], co: &[] }, 1, 1.0, None, &mut ws);
        assert_eq!(e.dist, 0.0);
        assert!(!e.abandoned);
        let e = eap_kernel(&DtwCost { li: &a, co: &[] }, 7, 1.0, None, &mut ws);
        assert_eq!(e.dist, f64::INFINITY);
        assert!(!e.abandoned);
    }

    #[test]
    fn counted_cells_shrink_with_a_tight_bound() {
        let mut ws = DtwWorkspace::default();
        let s = [3.0, 1.0, 4.0, 4.0, 1.0, 1.0];
        let t = [1.0, 3.0, 2.0, 1.0, 2.0, 2.0];
        let model = DtwCost { li: &s, co: &t };
        let (loose, c_loose) = eap_kernel_counted(&model, 6, f64::INFINITY, None, &mut ws);
        let (tight, c_tight) = eap_kernel_counted(&model, 6, 9.0, None, &mut ws);
        assert_eq!(loose.dist, 9.0);
        assert_eq!(tight.dist, 9.0);
        assert_eq!(c_loose, 36);
        assert!(c_tight < c_loose);
    }

    #[test]
    fn naive_kernel_agrees_with_eap_for_every_model_shape() {
        let mut ws = DtwWorkspace::default();
        let mut rnd = xorshift(77);
        let n = 15;
        let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
        let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
        for w in [3usize, n] {
            let erp = Erp::new(&a, &b, 0.0);
            let want = naive_kernel(&erp, w);
            let got = eap_kernel(&erp, w, f64::INFINITY, None, &mut ws).dist;
            assert!((got - want).abs() < 1e-12, "erp w={w}");
            let (li, co) = lines_cols(&a, &b);
            let dtw = DtwCost { li, co };
            let want = naive_kernel(&dtw, w);
            let got = eap_kernel(&dtw, w, f64::INFINITY, None, &mut ws).dist;
            assert!((got - want).abs() < 1e-12, "dtw w={w}");
        }
    }

    #[test]
    fn multi_lane_f64_matches_scalar_lanes_bitwise() {
        // quick in-file smoke check; the cross-metric, random-lane-count
        // property suite lives in tests/conformance_lanes.rs
        let mut ws = DtwWorkspace::default();
        let mut mws = MultiWorkspace::default();
        let mut rnd = xorshift(0xFACE);
        let n = 23;
        let q: Vec<f64> = (0..n).map(|_| rnd()).collect();
        let cands: Vec<Vec<f64>> = (0..4).map(|_| (0..n).map(|_| rnd()).collect()).collect();
        let w = 5;
        let exact: Vec<f64> = cands
            .iter()
            .map(|c| eap_kernel(&DtwCost { li: &q, co: c }, w, f64::INFINITY, None, &mut ws).dist)
            .collect();
        // mixed per-lane bounds: inf / tie / a planted first-rows abandon
        // (ub = 0 retires mid-group) / a tight bound
        let ubs = [f64::INFINITY, exact[1], 0.0, exact[3] * 0.5];
        let models: Vec<DtwCost> = cands.iter().map(|c| DtwCost { li: &q, co: c }).collect();
        let cbs = [None::<&[f64]>; 4];
        let mut out = Vec::new();
        eap_kernel_multi_dyn::<f64, _>(&models, w, &ubs, &cbs, &mut mws, |l| ubs[l], &mut out);
        assert_eq!(out.len(), 4);
        for (lane, e) in out.iter().enumerate() {
            let want = eap_kernel(&models[lane], w, ubs[lane], None, &mut ws);
            assert_eq!(e.dist.to_bits(), want.dist.to_bits(), "lane {lane}");
            assert_eq!(e.abandoned, want.abandoned, "lane {lane}");
        }
        assert!(out[2].abandoned && out[3].abandoned);
        assert!(!out[0].abandoned && !out[1].abandoned);
    }

    #[test]
    fn const_lane_wrapper_delegates_to_dyn() {
        let mut ws = DtwWorkspace::default();
        let mut mws = MultiWorkspace::default();
        let mut rnd = xorshift(0xBEEF);
        let n = 12;
        let q: Vec<f64> = (0..n).map(|_| rnd()).collect();
        let c0: Vec<f64> = (0..n).map(|_| rnd()).collect();
        let c1: Vec<f64> = (0..n).map(|_| rnd()).collect();
        let models = [DtwCost { li: &q, co: &c0 }, DtwCost { li: &q, co: &c1 }];
        let mut out = Vec::new();
        eap_kernel_multi::<_, 2>(&models, n, &[f64::INFINITY; 2], &mut mws, &mut out);
        for (lane, e) in out.iter().enumerate() {
            let want = eap_kernel(&models[lane], n, f64::INFINITY, None, &mut ws);
            assert_eq!(e.dist.to_bits(), want.dist.to_bits(), "lane {lane}");
        }
    }

    #[test]
    fn f32_thresholds_only_widen() {
        assert_eq!(<f32 as Scalar>::threshold(f64::INFINITY), f32::INFINITY);
        assert!(<f32 as Scalar>::threshold(1.0) > 1.0_f32);
        assert!(<f32 as Scalar>::threshold(0.0) > 0.0_f32);
        assert!(<f32 as Scalar>::threshold(-1.0) > -1.0_f32);
        assert_eq!(next_up_f32(0.0), f32::from_bits(1));
        assert_eq!(next_up_f32(-0.0), f32::from_bits(1));
        assert!(next_up_f32(-f32::MIN_POSITIVE) > -f32::MIN_POSITIVE);
        assert_eq!(next_up_f32(f32::INFINITY), f32::INFINITY);
    }

    #[test]
    fn f32_kernel_tracks_f64_within_epsilon_and_never_over_prunes() {
        let mut ws = DtwWorkspace::default();
        let mut rnd = xorshift(0xF32F);
        for n in [9usize, 31] {
            let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
            let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
            for w in [2usize, n] {
                let model = DtwCost { li: &a, co: &b };
                let d64 = eap_kernel(&model, w, f64::INFINITY, None, &mut ws).dist;
                let e32 = eap_kernel_f32(&model, w, f64::INFINITY, None, &mut ws);
                assert!(!e32.abandoned);
                let rel = (e32.dist - d64).abs() / d64.abs().max(1e-12);
                assert!(rel <= 1e-4, "n={n} w={w} rel={rel}");
                // exact-tie bound: f64 completes, so the inflated-f32 run
                // must complete too (over-admit, never over-prune)
                let tie = eap_kernel_f32(&model, w, d64, None, &mut ws);
                assert!(!tie.abandoned, "n={n} w={w}");
                if d64 > 0.0 {
                    let below = eap_kernel_f32(&model, w, d64 * 0.5, None, &mut ws);
                    assert!(below.abandoned, "n={n} w={w}");
                }
            }
        }
    }
}
