//! **The unified EAPruned band kernel** — Algorithm 3 of the paper as ONE
//! generic pruned-band recurrence over an inlineable [`CostModel`]. Every
//! EAPruned evaluation in the crate — cDTW/DTW ([`super::eap_dtw`]),
//! WDTW/ERP/MSM/TWE ([`super::elastic`]) — is a zero-cost instantiation
//! of [`eap_kernel`]: one copy of the band bookkeeping
//! (`next_start`/`pp`/`ppp`), one abandon condition, one place to
//! optimise. [`CostModel::UNIFORM`] marks the DTW family and const-folds
//! the paper's specialised 1-/2-dependency stage updates; non-uniform
//! models (possibly finite borders, distinct step costs) keep the
//! generalised bodies — `benches/ablation_stages.rs` measures exactly
//! that toggle. Returned distances `<= ub` are exact; `+inf` with
//! [`KernelEval::abandoned`] set means proven strictly above `ub`
//! (strict `>` preserves ties, paper §2.2). The stage walk, band
//! invariants and abandon conditions are documented in
//! `distances/README.md`; bitwise identity with the retired specialised
//! kernels is pinned by the property tests below.

use super::KernelWorkspace;
use crate::distances::cost::sqed;

/// An elastic distance's cost structure over two series. Indices are
/// 1-based (DP convention); implementations read their series with
/// `[i - 1]`. All step costs must be `>= 0` and the border functions
/// non-decreasing (debug-asserted) — that monotonicity is what makes
/// discard points permanent and the collision abandon sound.
pub trait CostModel {
    /// All three step costs identical and both borders infinite — the DTW
    /// family. Enables the specialised 1-/2-dependency stage updates via
    /// const propagation, and is required for `cb` threshold tightening
    /// (the cascade's bounds lower-bound DTW only).
    const UNIFORM: bool = false;
    fn n_lines(&self) -> usize;
    fn n_cols(&self) -> usize;
    /// Cost of the diagonal (match) move into `(i, j)`.
    fn diag(&self, i: usize, j: usize) -> f64;
    /// Cost of the vertical move into `(i, j)` (consume line point `i`).
    fn top(&self, i: usize, j: usize) -> f64;
    /// Cost of the horizontal move into `(i, j)` (consume column point `j`).
    fn left(&self, i: usize, j: usize) -> f64;
    /// Border row `D(0, j)`, `j >= 1`; non-decreasing in `j`.
    fn border_row(&self, _j: usize) -> f64 {
        f64::INFINITY
    }
    /// Border column `D(i, 0)`, `i >= 1`; non-decreasing in `i`.
    fn border_col(&self, _i: usize) -> f64 {
        f64::INFINITY
    }
}

/// Outcome of one kernel evaluation: the distance plus whether an `+inf`
/// was a *threshold-driven early abandon* — as opposed to an infeasible
/// band or a length-mismatched empty input. This is what makes the
/// per-metric abandon counters exact instead of inferred from
/// `is_infinite()` at the dispatch site.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelEval {
    pub dist: f64,
    pub abandoned: bool,
}

impl KernelEval {
    fn done(dist: f64) -> Self {
        Self { dist, abandoned: false }
    }
    fn abandon() -> Self {
        Self { dist: f64::INFINITY, abandoned: true }
    }
    fn infeasible() -> Self {
        Self { dist: f64::INFINITY, abandoned: false }
    }
}

/// EAPruned evaluation of a [`CostModel`] under Sakoe-Chiba band `w` and
/// upper bound `ub`. `cb`, valid for [`CostModel::UNIFORM`] models only,
/// is the cumulative lower-bound tail over column positions
/// (`cb.len() == n_cols + 1`, `cb[n_cols] == 0`, non-increasing): any
/// path through line `i` still pays `cb[min(i+w+1, m)]` in the future.
#[inline]
pub fn eap_kernel<C: CostModel>(
    model: &C,
    w: usize,
    ub: f64,
    cb: Option<&[f64]>,
    ws: &mut KernelWorkspace,
) -> KernelEval {
    let mut cells = 0u64;
    eap_core::<C, false>(model, w, ub, cb, ws, &mut cells)
}

/// [`eap_kernel`] that also reports how many DP cells were computed (the
/// A1/A2 ablation instrumentation); monomorphised separately so the
/// production path pays nothing for it.
pub fn eap_kernel_counted<C: CostModel>(
    model: &C,
    w: usize,
    ub: f64,
    cb: Option<&[f64]>,
    ws: &mut KernelWorkspace,
) -> (KernelEval, u64) {
    let mut cells = 0u64;
    let e = eap_core::<C, true>(model, w, ub, cb, ws, &mut cells);
    (e, cells)
}

#[inline(always)]
fn eap_core<C: CostModel, const COUNT: bool>(
    model: &C,
    w: usize,
    ub: f64,
    cb: Option<&[f64]>,
    ws: &mut KernelWorkspace,
    cells: &mut u64,
) -> KernelEval {
    let n = model.n_lines();
    let m = model.n_cols();
    if n == 0 || m == 0 {
        return if n == m { KernelEval::done(0.0) } else { KernelEval::infeasible() };
    }
    if n.abs_diff(m) > w {
        return KernelEval::infeasible();
    }
    debug_assert!(cb.is_none() || C::UNIFORM, "cb tightening needs a uniform-cost model");
    if let Some(cb) = cb {
        debug_assert_eq!(cb.len(), m + 1);
        debug_assert!(cb[m] == 0.0);
    }
    ws.reset(m);
    ws.curr[0] = 0.0;

    // Row 0. Uniform models have the classic +inf border row (initial
    // pruning point right after the origin); finite border rows (ERP) are
    // materialised up to the band edge, the initial pruning point landing
    // on the first border cell strictly above ub (borders non-decreasing).
    let mut ppp = 1usize;
    if !C::UNIFORM {
        let row0_end = m.min(w);
        ppp = row0_end + 1;
        let mut prev_border = 0.0f64;
        for j in 1..=row0_end {
            let b = model.border_row(j);
            debug_assert!(b >= prev_border, "border_row must be non-decreasing");
            prev_border = b;
            ws.curr[j] = b;
            if b > ub {
                ppp = j;
                break;
            }
        }
    }

    let mut next_start = 1usize; // first non-discarded column (left border)
    let mut pp = 0usize; // pruning point being built on the current line

    for i in 1..=n {
        std::mem::swap(&mut ws.prev, &mut ws.curr);
        let band_lo = i.saturating_sub(w).max(1);
        let band_hi = i.checked_add(w).map_or(m, |x| x.min(m));
        // band-left folds into next_start: both only ever move right
        if band_lo > next_start {
            next_start = band_lo;
        }
        // Line threshold: ub minus the future cost any path still pays.
        // cb is a DTW lower bound, so it is const-folded away for
        // non-UNIFORM models — tightening ERP/MSM/TWE/WDTW with it would
        // over-prune (the debug_assert above catches the misuse, this
        // makes it harmless in release builds too).
        let th = match cb {
            Some(cb) if C::UNIFORM => {
                let idx = i
                    .checked_add(w)
                    .and_then(|x| x.checked_add(1))
                    .map_or(m, |x| x.min(m));
                ub - cb[idx]
            }
            _ => ub,
        };
        let prev = &mut ws.prev;
        let curr = &mut ws.curr;
        let mut j = next_start;
        // Left sentinel (the live border for column 0, +inf otherwise);
        // `left` register-carries curr[j-1] across all four stages (see
        // dtw.rs — IEEE-exact reassociation).
        let mut left = if j == 1 { model.border_col(i) } else { f64::INFINITY };
        curr[j - 1] = left;

        // Stage 1: the discard-point region. Uniform models have no
        // viable left neighbour here (two-dependency update, every
        // above-threshold cell advances the border); a possibly-live
        // finite border keeps the 3-way min and gates the advance.
        while j == next_start && j < ppp {
            let left_v = left;
            let d = if C::UNIFORM {
                model.diag(i, j) + prev[j].min(prev[j - 1])
            } else {
                (prev[j] + model.top(i, j))
                    .min(prev[j - 1] + model.diag(i, j))
                    .min(left_v + model.left(i, j))
            };
            curr[j] = d;
            left = d;
            if COUNT {
                *cells += 1;
            }
            if d <= th {
                pp = j + 1;
            } else if C::UNIFORM || left_v > th {
                next_start += 1;
            }
            j += 1;
        }
        // Stage 2: interior — the classic three-way min.
        while j < ppp {
            let d = if C::UNIFORM {
                let bp = prev[j].min(prev[j - 1]);
                model.diag(i, j) + left.min(bp)
            } else {
                (prev[j] + model.top(i, j))
                    .min(prev[j - 1] + model.diag(i, j))
                    .min(left + model.left(i, j))
            };
            curr[j] = d;
            left = d;
            if COUNT {
                *cells += 1;
            }
            if d <= th {
                pp = j + 1;
            }
            j += 1;
        }
        // Stage 3: the previous pruning point's column (top dependency
        // excluded — prev cells at/right of ppp are above the threshold).
        // The borders can collide here: everything left above the
        // threshold too → nothing viable remains, abandon (Fig. 4b).
        if j <= band_hi {
            let left_v = left;
            let d = if C::UNIFORM {
                if j == next_start {
                    model.diag(i, j) + prev[j - 1]
                } else {
                    model.diag(i, j) + left_v.min(prev[j - 1])
                }
            } else {
                (prev[j - 1] + model.diag(i, j)).min(left_v + model.left(i, j))
            };
            curr[j] = d;
            left = d;
            if COUNT {
                *cells += 1;
            }
            if d <= th {
                pp = j + 1;
            } else if j == next_start && (C::UNIFORM || left_v > th) {
                return KernelEval::abandon();
            }
            j += 1;
        } else if j == next_start {
            // Discard points swallowed the whole banded line (Algorithm
            // 2's abandon); sound with finite borders because stage 1
            // gates the advance on the left value.
            return KernelEval::abandon();
        }
        // Stage 4: right of the pruning point — left dependency only;
        // the first above-threshold value prunes the rest of the line.
        while j == pp && j <= band_hi {
            let d = left + model.left(i, j);
            curr[j] = d;
            left = d;
            if COUNT {
                *cells += 1;
            }
            if d <= th {
                pp = j + 1;
            }
            j += 1;
        }
        ppp = pp;
    }
    // Exact only if the last line's pruning point cleared the last column.
    if ppp > m {
        KernelEval::done(ws.curr[m])
    } else {
        KernelEval::abandon()
    }
}

/// DTW's cost structure — squared-Euclidean cost on every move, infinite
/// borders: the `UNIFORM` instantiation behind [`super::eap_dtw`].
pub struct DtwCost<'a> {
    pub li: &'a [f64],
    pub co: &'a [f64],
}

impl CostModel for DtwCost<'_> {
    const UNIFORM: bool = true;
    #[inline(always)]
    fn n_lines(&self) -> usize {
        self.li.len()
    }
    #[inline(always)]
    fn n_cols(&self) -> usize {
        self.co.len()
    }
    #[inline(always)]
    fn diag(&self, i: usize, j: usize) -> f64 {
        sqed(self.li[i - 1], self.co[j - 1])
    }
    #[inline(always)]
    fn top(&self, i: usize, j: usize) -> f64 {
        self.diag(i, j)
    }
    #[inline(always)]
    fn left(&self, i: usize, j: usize) -> f64 {
        self.diag(i, j)
    }
}

/// Naive full-matrix evaluation of a [`CostModel`] — the slow,
/// obviously-correct oracle behind every conformance suite.
pub fn naive_kernel<C: CostModel>(model: &C, w: usize) -> f64 {
    let n = model.n_lines();
    let m = model.n_cols();
    if n == 0 || m == 0 {
        return if n == m { 0.0 } else { f64::INFINITY };
    }
    let mut d = vec![vec![f64::INFINITY; m + 1]; n + 1];
    d[0][0] = 0.0;
    for j in 1..=m.min(w) {
        d[0][j] = model.border_row(j);
    }
    for i in 1..=n.min(w) {
        d[i][0] = model.border_col(i);
    }
    for i in 1..=n {
        for j in 1..=m {
            if i.abs_diff(j) > w {
                continue;
            }
            let mut best = f64::INFINITY;
            if d[i - 1][j].is_finite() {
                best = best.min(d[i - 1][j] + model.top(i, j));
            }
            if d[i - 1][j - 1].is_finite() {
                best = best.min(d[i - 1][j - 1] + model.diag(i, j));
            }
            if d[i][j - 1].is_finite() {
                best = best.min(d[i][j - 1] + model.left(i, j));
            }
            d[i][j] = best;
        }
    }
    d[n][m]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::dtw::cdtw;
    use crate::distances::eap_dtw::{eap_cdtw, eap_dtw};
    use crate::distances::elastic::erp::Erp;
    use crate::distances::elastic::msm::Msm;
    use crate::distances::elastic::twe::Twe;
    use crate::distances::elastic::wdtw::Wdtw;
    use crate::distances::{lines_cols, DtwWorkspace};

    /// The **retired specialised kernels**, kept verbatim as bitwise
    /// oracles: the pre-unification DTW-specialised `eap_impl` of
    /// `eap_dtw.rs` and the generic `eap_elastic` of `elastic/core.rs`.
    /// The property tests below pin the unified kernel against them bit
    /// for bit; they exist nowhere else anymore.
    mod retired {
        use super::super::CostModel;
        use crate::distances::cost::sqed;
        use crate::distances::{lines_cols, DtwWorkspace};

        /// Pre-unification `eap_dtw.rs::eap_impl` (COUNT stripped).
        pub fn eap_impl(
            a: &[f64],
            b: &[f64],
            w: usize,
            ub: f64,
            cb: Option<&[f64]>,
            ws: &mut DtwWorkspace,
        ) -> f64 {
            if a.is_empty() || b.is_empty() {
                return if a.len() == b.len() { 0.0 } else { f64::INFINITY };
            }
            let (li, co) = lines_cols(a, b);
            let n = li.len();
            let m = co.len();
            if n - m > w {
                return f64::INFINITY;
            }
            ws.reset(m);
            ws.curr[0] = 0.0;
            let mut next_start = 1usize;
            let mut ppp = 1usize;
            let mut pp = 0usize;
            for i in 1..=n {
                std::mem::swap(&mut ws.prev, &mut ws.curr);
                let v = li[i - 1];
                let band_lo = i.saturating_sub(w).max(1);
                let band_hi = i.checked_add(w).map_or(m, |x| x.min(m));
                if band_lo > next_start {
                    next_start = band_lo;
                }
                let th = match cb {
                    Some(cb) => {
                        let idx = i
                            .checked_add(w)
                            .and_then(|x| x.checked_add(1))
                            .map_or(m, |x| x.min(m));
                        ub - cb[idx]
                    }
                    None => ub,
                };
                let prev = &mut ws.prev;
                let curr = &mut ws.curr;
                let mut j = next_start;
                curr[j - 1] = f64::INFINITY;
                let mut left = f64::INFINITY;
                while j == next_start && j < ppp {
                    let d = sqed(v, co[j - 1]) + prev[j].min(prev[j - 1]);
                    curr[j] = d;
                    left = d;
                    if d <= th {
                        pp = j + 1;
                    } else {
                        next_start += 1;
                    }
                    j += 1;
                }
                while j < ppp {
                    let bp = prev[j].min(prev[j - 1]);
                    let d = sqed(v, co[j - 1]) + left.min(bp);
                    curr[j] = d;
                    left = d;
                    if d <= th {
                        pp = j + 1;
                    }
                    j += 1;
                }
                if j <= band_hi {
                    let c = sqed(v, co[j - 1]);
                    if j == next_start {
                        let d = c + prev[j - 1];
                        curr[j] = d;
                        left = d;
                        if d <= th {
                            pp = j + 1;
                        } else {
                            return f64::INFINITY;
                        }
                    } else {
                        let d = c + left.min(prev[j - 1]);
                        curr[j] = d;
                        left = d;
                        if d <= th {
                            pp = j + 1;
                        }
                    }
                    j += 1;
                } else if j == next_start {
                    return f64::INFINITY;
                }
                while j == pp && j <= band_hi {
                    let d = sqed(v, co[j - 1]) + left;
                    curr[j] = d;
                    left = d;
                    if d <= th {
                        pp = j + 1;
                    }
                    j += 1;
                }
                ppp = pp;
            }
            if ppp > m {
                ws.curr[m]
            } else {
                f64::INFINITY
            }
        }

        /// Pre-unification `elastic/core.rs::eap_elastic`.
        pub fn eap_elastic<M: CostModel>(
            model: &M,
            w: usize,
            ub: f64,
            ws: &mut DtwWorkspace,
        ) -> f64 {
            let n = model.n_lines();
            let m = model.n_cols();
            if n == 0 || m == 0 {
                return if n == m { 0.0 } else { f64::INFINITY };
            }
            if n.abs_diff(m) > w {
                return f64::INFINITY;
            }
            ws.reset(m);
            ws.curr[0] = 0.0;
            let row0_end = m.min(w);
            let mut ppp = row0_end + 1;
            for j in 1..=row0_end {
                let b = model.border_row(j);
                ws.curr[j] = b;
                if b > ub {
                    ppp = j;
                    break;
                }
            }
            let mut next_start = 1usize;
            let mut pp = 0usize;
            for i in 1..=n {
                std::mem::swap(&mut ws.prev, &mut ws.curr);
                let band_lo = i.saturating_sub(w).max(1);
                let band_hi = i.checked_add(w).map_or(m, |x| x.min(m));
                if band_lo > next_start {
                    next_start = band_lo;
                }
                let prev = &mut ws.prev;
                let curr = &mut ws.curr;
                let mut j = next_start;
                let mut left = if j == 1 { model.border_col(i) } else { f64::INFINITY };
                curr[j - 1] = left;
                while j == next_start && j < ppp {
                    let left_v = left;
                    let d = (prev[j] + model.top(i, j))
                        .min(prev[j - 1] + model.diag(i, j))
                        .min(left_v + model.left(i, j));
                    curr[j] = d;
                    left = d;
                    if d <= ub {
                        pp = j + 1;
                    } else if left_v > ub {
                        next_start += 1;
                    }
                    j += 1;
                }
                while j < ppp {
                    let bp =
                        (prev[j] + model.top(i, j)).min(prev[j - 1] + model.diag(i, j));
                    let d = bp.min(left + model.left(i, j));
                    curr[j] = d;
                    left = d;
                    if d <= ub {
                        pp = j + 1;
                    }
                    j += 1;
                }
                if j <= band_hi {
                    let left_v = left;
                    let d = (prev[j - 1] + model.diag(i, j)).min(left_v + model.left(i, j));
                    curr[j] = d;
                    left = d;
                    if d <= ub {
                        pp = j + 1;
                    } else if j == next_start && left_v > ub {
                        return f64::INFINITY;
                    }
                    j += 1;
                } else if j == next_start {
                    return f64::INFINITY;
                }
                while j == pp && j <= band_hi {
                    let d = left + model.left(i, j);
                    curr[j] = d;
                    left = d;
                    if d <= ub {
                        pp = j + 1;
                    }
                    j += 1;
                }
                ppp = pp;
            }
            if ppp > m {
                ws.curr[m]
            } else {
                f64::INFINITY
            }
        }
    }

    fn xorshift(seed: u64) -> impl FnMut() -> f64 {
        let mut x = seed;
        move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 2.0 - 1.0
        }
    }

    /// ub grid of the pinning property: exact DTW at +inf, the tie, and a
    /// 0 bound that must abandon everywhere (identity pairs excluded by
    /// random data).
    fn ub_grid(exact: f64) -> [f64; 3] {
        [f64::INFINITY, exact, 0.0]
    }

    #[track_caller]
    fn assert_bits(got: f64, want: f64, tag: &str) {
        assert_eq!(got.to_bits(), want.to_bits(), "{tag}: {got} vs {want}");
    }

    /// The satellite property test: the unified kernel is **bitwise**
    /// identical to the retired specialised kernels over random series,
    /// all six metrics, ub ∈ {inf, tight, 0}.
    #[test]
    fn unified_kernel_bitwise_matches_retired_kernels_for_all_six_metrics() {
        let mut ws = DtwWorkspace::default();
        let mut ws2 = DtwWorkspace::default();
        for seed in 1..=4u64 {
            let mut rnd = xorshift(0x5EED ^ (seed << 8));
            for n in [5usize, 13, 29] {
                let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
                let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
                for w in [1usize, 3, n / 2, n] {
                    let tag = |m: &str, ub: f64| format!("{m} seed={seed} n={n} w={w} ub={ub}");
                    // cdtw (uniform flow, via the public wrapper)
                    let exact = retired::eap_impl(&a, &b, w, f64::INFINITY, None, &mut ws2);
                    for ub in ub_grid(exact) {
                        let got = eap_cdtw(&a, &b, w, ub, None, &mut ws);
                        let want = retired::eap_impl(&a, &b, w, ub, None, &mut ws2);
                        assert_bits(got, want, &tag("cdtw", ub));
                    }
                    // cdtw with a valid (all-zero) cb tail
                    let cb = vec![0.0; n + 1];
                    let got = eap_cdtw(&a, &b, w, exact, Some(&cb), &mut ws);
                    let want = retired::eap_impl(&a, &b, w, exact, Some(&cb), &mut ws2);
                    assert_bits(got, want, &tag("cdtw+cb", exact));
                    // dtw (unwindowed uniform flow)
                    let exact = retired::eap_impl(&a, &b, n, f64::INFINITY, None, &mut ws2);
                    for ub in ub_grid(exact) {
                        let got = eap_dtw(&a, &b, ub);
                        let want = retired::eap_impl(&a, &b, n, ub, None, &mut ws2);
                        assert_bits(got, want, &tag("dtw", ub));
                    }
                    // the four non-uniform cost models
                    let wdtw = Wdtw::new(&a, &b, 0.05);
                    let erp = Erp::new(&a, &b, 0.25);
                    let msm = Msm::new(&a, &b, 0.5);
                    let twe = Twe::new(&a, &b, 0.05, 1.0);
                    macro_rules! pin {
                        ($name:literal, $model:expr, $w:expr) => {
                            let exact =
                                retired::eap_elastic(&$model, $w, f64::INFINITY, &mut ws2);
                            for ub in ub_grid(exact) {
                                let got = eap_kernel(&$model, $w, ub, None, &mut ws).dist;
                                let want = retired::eap_elastic(&$model, $w, ub, &mut ws2);
                                assert_bits(got, want, &tag($name, ub));
                            }
                        };
                    }
                    pin!("wdtw", wdtw, n); // WDTW is conventionally unwindowed
                    pin!("erp", erp, w);
                    pin!("msm", msm, w);
                    pin!("twe", twe, w);
                }
            }
        }
    }

    #[test]
    fn uniform_flow_matches_cdtw_oracle_and_reports_abandons() {
        let mut ws = DtwWorkspace::default();
        let mut rnd = xorshift(0xABCD);
        for n in [6usize, 17] {
            let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
            let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
            for w in [2usize, n] {
                let (li, co) = lines_cols(&a, &b);
                let model = DtwCost { li, co };
                let want = cdtw(&a, &b, w);
                let e = eap_kernel(&model, w, f64::INFINITY, None, &mut ws);
                assert!((e.dist - want).abs() < 1e-12, "n={n} w={w}");
                assert!(!e.abandoned);
                let tie = eap_kernel(&model, w, want, None, &mut ws);
                assert_eq!(tie.dist.to_bits(), want.to_bits());
                assert!(!tie.abandoned);
                if want > 0.0 {
                    let below = eap_kernel(&model, w, want * 0.5, None, &mut ws);
                    assert_eq!(below.dist, f64::INFINITY);
                    assert!(below.abandoned, "threshold-driven inf must be an abandon");
                }
            }
        }
    }

    #[test]
    fn infeasible_band_and_empty_inputs_are_not_abandons() {
        let mut ws = DtwWorkspace::default();
        let a = [0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [0.0, 2.0, 4.0];
        let (li, co) = lines_cols(&a, &b);
        let e = eap_kernel(&DtwCost { li, co }, 2, f64::INFINITY, None, &mut ws);
        assert_eq!(e.dist, f64::INFINITY);
        assert!(!e.abandoned, "|7-3| > w=2 is infeasible, not abandoned");
        let e = eap_kernel(&DtwCost { li: &[], co: &[] }, 1, 1.0, None, &mut ws);
        assert_eq!(e.dist, 0.0);
        assert!(!e.abandoned);
        let e = eap_kernel(&DtwCost { li: &a, co: &[] }, 7, 1.0, None, &mut ws);
        assert_eq!(e.dist, f64::INFINITY);
        assert!(!e.abandoned);
    }

    #[test]
    fn counted_cells_shrink_with_a_tight_bound() {
        let mut ws = DtwWorkspace::default();
        let s = [3.0, 1.0, 4.0, 4.0, 1.0, 1.0];
        let t = [1.0, 3.0, 2.0, 1.0, 2.0, 2.0];
        let model = DtwCost { li: &s, co: &t };
        let (loose, c_loose) = eap_kernel_counted(&model, 6, f64::INFINITY, None, &mut ws);
        let (tight, c_tight) = eap_kernel_counted(&model, 6, 9.0, None, &mut ws);
        assert_eq!(loose.dist, 9.0);
        assert_eq!(tight.dist, 9.0);
        assert_eq!(c_loose, 36);
        assert!(c_tight < c_loose);
    }

    #[test]
    fn naive_kernel_agrees_with_eap_for_every_model_shape() {
        let mut ws = DtwWorkspace::default();
        let mut rnd = xorshift(77);
        let n = 15;
        let a: Vec<f64> = (0..n).map(|_| rnd()).collect();
        let b: Vec<f64> = (0..n).map(|_| rnd()).collect();
        for w in [3usize, n] {
            let erp = Erp::new(&a, &b, 0.0);
            let want = naive_kernel(&erp, w);
            let got = eap_kernel(&erp, w, f64::INFINITY, None, &mut ws).dist;
            assert!((got - want).abs() < 1e-12, "erp w={w}");
            let (li, co) = lines_cols(&a, &b);
            let dtw = DtwCost { li, co };
            let want = naive_kernel(&dtw, w);
            let got = eap_kernel(&dtw, w, f64::INFINITY, None, &mut ws).dist;
            assert!((got - want).abs() < 1e-12, "dtw w={w}");
        }
    }
}
