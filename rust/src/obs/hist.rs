//! Fixed-bucket log₂ histograms: 64 buckets covering the whole `u64`
//! range, so recording is two shifts and three relaxed atomic adds — O(1),
//! allocation-free, and mergeable *exactly* (bucket-wise addition loses
//! nothing, unlike quantile sketches). Bucket 0 holds the value 0; bucket
//! `b >= 1` holds `[2^(b-1), 2^b)`, with the last bucket absorbing the
//! tail. Quantiles are therefore bucket-resolution approximations (within
//! 2× of the true value); `max` is exact.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of buckets: one per leading-zero count of a `u64`, plus zero.
pub const BUCKETS: usize = 64;

/// Bucket index for a value: 0 for 0, else `64 - leading_zeros`, capped.
#[inline]
pub fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros() as usize).min(BUCKETS - 1)
    }
}

/// Inclusive upper bound of the values bucket `b` can hold (the
/// representative a quantile query reports).
#[inline]
pub fn bucket_ceil(b: usize) -> u64 {
    match b {
        0 => 0,
        _ if b >= BUCKETS - 1 => u64::MAX,
        _ => (1u64 << b) - 1,
    }
}

/// The shared-cell histogram: plain relaxed atomics, written concurrently
/// by whoever owns the cell, drained with [`AtomicHist::snapshot`].
/// Recording never locks, never allocates, and never reads the clock
/// itself — callers hand it finished measurements.
#[derive(Debug)]
pub struct AtomicHist {
    buckets: [AtomicU64; BUCKETS],
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for AtomicHist {
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

impl AtomicHist {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one observation. O(1): one bucket add, one sum add, one
    /// max.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Materialise the current contents as a plain [`Histogram`].
    pub fn snapshot(&self) -> Histogram {
        let mut h = Histogram::default();
        for (b, slot) in self.buckets.iter().enumerate() {
            h.buckets[b] = slot.load(Ordering::Relaxed);
        }
        h.sum = self.sum.load(Ordering::Relaxed);
        h.max = self.max.load(Ordering::Relaxed);
        h
    }
}

/// The owned/merged form: what snapshots carry and the JSON plane
/// serialises. Merging is exact — bucket-wise addition.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Histogram {
    pub buckets: [u64; BUCKETS],
    pub sum: u64,
    pub max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self { buckets: [0; BUCKETS], sum: 0, max: 0 }
    }
}

impl Histogram {
    /// Record into the owned form (single-threaded accumulation paths).
    #[inline]
    pub fn record(&mut self, v: u64) {
        self.buckets[bucket_of(v)] += 1;
        self.sum += v;
        self.max = self.max.max(v);
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.iter().all(|&b| b == 0)
    }

    /// Exact merge: per-bucket addition, sum addition, max of maxes.
    pub fn merge(&mut self, o: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&o.buckets) {
            *a += b;
        }
        self.sum += o.sum;
        self.max = self.max.max(o.max);
    }

    /// Bucket-resolution quantile: the inclusive upper bound of the
    /// bucket containing the `q`-th observation, clamped to the exact
    /// observed `max` (so `quantile(1.0) == max`). Returns 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (b, &n) in self.buckets.iter().enumerate() {
            cum += n;
            if cum >= target {
                return bucket_ceil(b).min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }
    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_powers_of_two() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        // every bucket's ceiling lands back in that bucket
        for b in 0..BUCKETS - 1 {
            assert_eq!(bucket_of(bucket_ceil(b)), b, "b={b}");
        }
    }

    #[test]
    fn record_count_sum_max() {
        let mut h = Histogram::default();
        for v in [0u64, 1, 5, 5, 900, 17] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.sum, 928);
        assert_eq!(h.max, 900);
        assert!(!h.is_empty());
        assert!(Histogram::default().is_empty());
    }

    #[test]
    fn merge_is_exact() {
        let mut a = Histogram::default();
        let mut b = Histogram::default();
        let mut whole = Histogram::default();
        for v in [3u64, 8, 1000, 0] {
            a.record(v);
            whole.record(v);
        }
        for v in [7u64, 2_000_000, 9] {
            b.record(v);
            whole.record(v);
        }
        a.merge(&b);
        assert_eq!(a, whole);
    }

    #[test]
    fn quantiles_are_bucket_resolution_and_max_is_exact() {
        let mut h = Histogram::default();
        for v in 1..=100u64 {
            h.record(v);
        }
        assert_eq!(h.max, 100);
        assert_eq!(h.quantile(1.0), 100);
        // p50 of 1..=100 is 50, whose bucket [32,64) reports ceil 63
        assert_eq!(h.p50(), 63);
        assert_eq!(h.p99(), 100); // capped at the exact max
        assert_eq!(Histogram::default().p50(), 0);
        // a single observation is its own every-quantile
        let mut one = Histogram::default();
        one.record(42);
        assert_eq!(one.p50(), 42.min(bucket_ceil(bucket_of(42))));
        assert_eq!(one.quantile(0.01), one.quantile(0.99));
    }

    #[test]
    fn atomic_hist_snapshot_matches_plain_recording() {
        let ah = AtomicHist::new();
        let mut h = Histogram::default();
        for v in [0u64, 1, 2, 3, 100, 65_536, 123_456_789] {
            ah.record(v);
            h.record(v);
        }
        assert_eq!(ah.snapshot(), h);
    }
}
