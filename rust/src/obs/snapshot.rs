//! The snapshot/export plane: one point-in-time, merge-of-all-cells view
//! of the registry with a **pinned JSON schema** (`repro.metrics.v1`).
//! The same document is returned by `Service::stats_json`, emitted
//! periodically by `repro serve --stats-every N`, served to the
//! `{"cmd":"stats"}` wire request, and embedded in `BENCH_*.json` — one
//! schema, four consumers. `tools/bench_diff.py` checks counter
//! invariants over it in CI.
//!
//! Values are carried as JSON numbers (f64): exact for counts below
//! 2^53, which bounds every realistic run by orders of magnitude.

use anyhow::{anyhow, ensure, Result};

use crate::metrics::Counters;
use crate::obs::hist::Histogram;
use crate::obs::{DistKind, Gauge, Stage};
use crate::util::json::{obj, Json};

/// The pinned schema identifier. Bump only with a documented migration
/// in `obs/README.md`.
pub const SCHEMA: &str = "repro.metrics.v1";

/// A merged point-in-time view of every registry cell.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub counters: Counters,
    pub gauges: [u64; Gauge::COUNT],
    pub stages: [Histogram; Stage::COUNT],
    pub dists: [Histogram; DistKind::COUNT],
}

impl Default for MetricsSnapshot {
    fn default() -> Self {
        Self {
            counters: Counters::new(),
            gauges: [0; Gauge::COUNT],
            stages: std::array::from_fn(|_| Histogram::default()),
            dists: std::array::from_fn(|_| Histogram::default()),
        }
    }
}

fn hist_to_json(h: &Histogram) -> Json {
    let buckets: Vec<Json> = h
        .buckets
        .iter()
        .enumerate()
        .filter(|&(_, &n)| n > 0)
        .map(|(b, &n)| Json::Arr(vec![Json::Num(b as f64), Json::Num(n as f64)]))
        .collect();
    obj(vec![
        ("count", Json::Num(h.count() as f64)),
        ("sum", Json::Num(h.sum as f64)),
        ("max", Json::Num(h.max as f64)),
        ("p50", Json::Num(h.p50() as f64)),
        ("p95", Json::Num(h.p95() as f64)),
        ("p99", Json::Num(h.p99() as f64)),
        ("buckets", Json::Arr(buckets)),
    ])
}

fn hist_from_json(v: &Json) -> Result<Histogram> {
    let mut h = Histogram::default();
    let buckets = v
        .get("buckets")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("histogram missing buckets"))?;
    for pair in buckets {
        let pair = pair.as_arr().ok_or_else(|| anyhow!("histogram bucket must be [index, count]"))?;
        ensure!(pair.len() == 2, "histogram bucket must be [index, count]");
        let b = pair[0].as_usize().ok_or_else(|| anyhow!("bad bucket index"))?;
        ensure!(b < h.buckets.len(), "bucket index {b} out of range");
        h.buckets[b] = pair[1].as_f64().ok_or_else(|| anyhow!("bad bucket count"))? as u64;
    }
    h.sum = v.get("sum").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    h.max = v.get("max").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    Ok(h)
}

impl MetricsSnapshot {
    /// A snapshot carrying only counters (empty histograms and gauges) —
    /// what bench harnesses without a live registry embed so their
    /// `BENCH_*.json` documents still speak the pinned schema.
    pub fn from_counters(c: &Counters) -> Self {
        Self { counters: c.clone(), ..Default::default() }
    }

    /// Exact merge of another snapshot (counter addition, bucket-wise
    /// histogram addition, gauge max).
    pub fn merge(&mut self, o: &MetricsSnapshot) {
        self.counters.merge(&o.counters);
        for (a, b) in self.gauges.iter_mut().zip(&o.gauges) {
            *a = (*a).max(*b);
        }
        for (a, b) in self.stages.iter_mut().zip(&o.stages) {
            a.merge(b);
        }
        for (a, b) in self.dists.iter_mut().zip(&o.dists) {
            a.merge(b);
        }
    }

    /// The pinned-schema document. Stage latencies are nanoseconds.
    pub fn to_json(&self) -> Json {
        let slots = self.counters.slots();
        let counters: Vec<(&str, Json)> = Counters::SLOT_NAMES
            .iter()
            .zip(slots)
            .map(|(&name, v)| (name, Json::Num(v as f64)))
            .collect();
        let gauges: Vec<(&str, Json)> = Gauge::ALL
            .iter()
            .map(|g| (g.name(), Json::Num(self.gauges[g.index()] as f64)))
            .collect();
        let stages: Vec<(&str, Json)> = Stage::ALL
            .iter()
            .map(|s| (s.name(), hist_to_json(&self.stages[s.index()])))
            .collect();
        let dists: Vec<(&str, Json)> = DistKind::ALL
            .iter()
            .map(|d| (d.name(), hist_to_json(&self.dists[d.index()])))
            .collect();
        obj(vec![
            ("schema", Json::Str(SCHEMA.to_string())),
            ("stage_unit", Json::Str("ns".to_string())),
            ("counters", obj(counters)),
            ("gauges", obj(gauges)),
            ("stages", obj(stages)),
            ("dists", obj(dists)),
        ])
    }

    /// One-line wire form of [`MetricsSnapshot::to_json`].
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    /// Parse a pinned-schema document. The schema id must match; counter
    /// names absent from the document read as 0 (so a `v1` reader
    /// tolerates counters added later under the same schema).
    pub fn from_json(v: &Json) -> Result<Self> {
        let schema = v
            .get("schema")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("metrics snapshot missing schema"))?;
        ensure!(schema == SCHEMA, "unsupported metrics schema {schema:?} (want {SCHEMA:?})");
        let mut snap = MetricsSnapshot::default();
        if let Some(counters) = v.get("counters") {
            let mut slots = [0u64; Counters::SLOT_COUNT];
            for (slot, &name) in slots.iter_mut().zip(Counters::SLOT_NAMES.iter()) {
                *slot = counters.get(name).and_then(Json::as_f64).unwrap_or(0.0) as u64;
            }
            snap.counters = Counters::from_slots(&slots);
        }
        if let Some(gauges) = v.get("gauges") {
            for g in Gauge::ALL {
                snap.gauges[g.index()] =
                    gauges.get(g.name()).and_then(Json::as_f64).unwrap_or(0.0) as u64;
            }
        }
        if let Some(stages) = v.get("stages") {
            for s in Stage::ALL {
                if let Some(h) = stages.get(s.name()) {
                    snap.stages[s.index()] = hist_from_json(h)?;
                }
            }
        }
        if let Some(dists) = v.get("dists") {
            for d in DistKind::ALL {
                if let Some(h) = dists.get(d.name()) {
                    snap.dists[d.index()] = hist_from_json(h)?;
                }
            }
        }
        Ok(snap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy_snapshot() -> MetricsSnapshot {
        let mut snap = MetricsSnapshot::default();
        snap.counters.candidates = 1000;
        snap.counters.lb_kim_prunes = 400;
        snap.counters.lb_keogh_eq_prunes = 300;
        snap.counters.lb_keogh_ec_prunes = 100;
        snap.counters.dtw_calls = 200;
        snap.counters.dtw_abandons = 120;
        snap.counters.dtw_completions = 80;
        snap.counters.metric_calls[0] = 200;
        snap.gauges[Gauge::QueriesServed.index()] = 17;
        for s in Stage::ALL {
            for v in [800u64, 12_000, 250_000, 1] {
                snap.stages[s.index()].record(v);
            }
        }
        for d in DistKind::ALL {
            snap.dists[d.index()].record(4);
            snap.dists[d.index()].record(64);
        }
        snap
    }

    #[test]
    fn pinned_schema_round_trips() {
        let snap = busy_snapshot();
        let j = snap.to_json();
        assert_eq!(j.get("schema").and_then(Json::as_str), Some(SCHEMA));
        // wire round trip: print → parse → rebuild
        let line = snap.to_json_string();
        let back = MetricsSnapshot::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, snap);
    }

    #[test]
    fn schema_document_names_every_counter_stage_and_dist() {
        let j = busy_snapshot().to_json();
        let counters = j.get("counters").and_then(Json::as_obj).unwrap();
        for name in Counters::SLOT_NAMES {
            assert!(counters.contains_key(name), "missing counter {name}");
        }
        let stages = j.get("stages").and_then(Json::as_obj).unwrap();
        for name in Stage::NAMES {
            let h = &stages[name];
            assert!(h.get("p50").is_some(), "stage {name} missing p50");
            assert!(h.get("p95").is_some(), "stage {name} missing p95");
            assert!(h.get("p99").is_some(), "stage {name} missing p99");
            assert!(h.get("max").is_some(), "stage {name} missing max");
        }
        let dists = j.get("dists").and_then(Json::as_obj).unwrap();
        for name in DistKind::NAMES {
            assert!(dists.contains_key(name), "missing dist {name}");
        }
        assert_eq!(j.get("stage_unit").and_then(Json::as_str), Some("ns"));
    }

    #[test]
    fn rejects_wrong_schema() {
        assert!(MetricsSnapshot::from_json(&Json::parse("{}").unwrap()).is_err());
        let wrong = r#"{"schema":"repro.metrics.v0"}"#;
        assert!(MetricsSnapshot::from_json(&Json::parse(wrong).unwrap()).is_err());
    }

    #[test]
    fn from_counters_embeds_counters_only() {
        let mut c = Counters::new();
        c.candidates = 9;
        let snap = MetricsSnapshot::from_counters(&c);
        assert_eq!(snap.counters.candidates, 9);
        assert!(snap.stages.iter().all(Histogram::is_empty));
        let back =
            MetricsSnapshot::from_json(&Json::parse(&snap.to_json_string()).unwrap()).unwrap();
        assert_eq!(back, snap);
    }

    #[test]
    fn merge_adds_counters_and_histograms() {
        let mut a = busy_snapshot();
        let b = busy_snapshot();
        a.merge(&b);
        assert_eq!(a.counters.candidates, 2000);
        assert_eq!(a.stages[Stage::KernelEval.index()].count(), 8);
        assert_eq!(a.gauges[Gauge::QueriesServed.index()], 17);
    }
}
