//! Pipeline-wide observability (see `obs/README.md`): a lock-free sharded
//! [`MetricsRegistry`] the serving pipeline records into, and a snapshot
//! plane ([`MetricsSnapshot`]) that merges it into one pinned-schema JSON
//! document.
//!
//! Design rules, carried from the bitwise-identity contract of PRs 3–5:
//!
//! * **The hot path is untouched.** Scan internals keep mutating their
//!   plain per-job [`Counters`] exactly as before; each worker *flushes*
//!   the finished delta into its own [`ObsCell`] once per job (relaxed
//!   `fetch_add` per named slot — no locks, no allocation, no contention:
//!   one writer per cell).
//! * **Observation never steers computation.** Nothing in this module is
//!   read back by the scan; enabling the registry cannot change a single
//!   result bit. Stage timers read the clock only when a cell is attached
//!   ([`ScanObs::now`] is `None` when observability is off), so bare
//!   library calls don't even pay for `Instant::now()`.
//! * **One field list.** Counter slots are named by
//!   [`Counters::SLOT_NAMES`] — the same canonical mapping the snapshot
//!   JSON and the bench reports use.

pub mod hist;
pub mod snapshot;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::metrics::Counters;

pub use hist::{AtomicHist, Histogram, BUCKETS};
pub use snapshot::{MetricsSnapshot, SCHEMA};

/// Pipeline phases with a latency histogram (unit: nanoseconds).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Time a request spent queued in the batch coalescer before
    /// `submit_batch` saw it.
    QueueWait,
    /// Grouping a batch into same-shape cohorts.
    CohortForm,
    /// The batched LB_Kim bound pass over a strip (or the per-candidate
    /// LB_Kim hierarchy on the scalar path).
    BoundKim,
    /// The LB_Keogh query-envelope pass over a strip's survivors (or the
    /// per-candidate bound on the scalar path).
    BoundKeoghEq,
    /// The per-survivor LB_Keogh data-envelope bound.
    BoundKeoghEc,
    /// LB_Improved's role-swapped second pass over LB_Keogh survivors
    /// (per-candidate on the scalar path, per-lane on a strip).
    BoundImproved,
    /// One kernel evaluation of a cascade survivor.
    KernelEval,
    /// Collecting and merging per-shard results in the router.
    FanIn,
    /// Deadline slack: for a deadline-carrying query that completed in
    /// time, the budget remaining at response build (ns). Only recorded
    /// when a deadline was set, so the histogram's `count` equals the
    /// number of in-budget deadline queries.
    DeadlineSlack,
    /// Time the network front-end spent assembling one complete request
    /// frame from a connection's socket (first byte of the frame to its
    /// newline) — a slow client shows up here before the timeout cuts it.
    ConnRead,
    /// Time spent writing one response line back onto a connection's
    /// socket (kernel-buffer stalls show up here before backpressure
    /// disconnects the client).
    ConnWrite,
}

impl Stage {
    pub const COUNT: usize = 11;
    /// Snapshot-schema names, index-aligned with [`Stage::index`].
    pub const NAMES: [&'static str; Self::COUNT] = [
        "queue_wait",
        "cohort_form",
        "bound_kim",
        "bound_keogh_eq",
        "bound_keogh_ec",
        "bound_improved",
        "kernel_eval",
        "fan_in",
        "deadline_slack",
        "conn_read",
        "conn_write",
    ];
    pub const ALL: [Stage; Self::COUNT] = [
        Stage::QueueWait,
        Stage::CohortForm,
        Stage::BoundKim,
        Stage::BoundKeoghEq,
        Stage::BoundKeoghEc,
        Stage::BoundImproved,
        Stage::KernelEval,
        Stage::FanIn,
        Stage::DeadlineSlack,
        Stage::ConnRead,
        Stage::ConnWrite,
    ];

    #[inline]
    pub fn index(self) -> usize {
        match self {
            Stage::QueueWait => 0,
            Stage::CohortForm => 1,
            Stage::BoundKim => 2,
            Stage::BoundKeoghEq => 3,
            Stage::BoundKeoghEc => 4,
            Stage::BoundImproved => 5,
            Stage::KernelEval => 6,
            Stage::FanIn => 7,
            Stage::DeadlineSlack => 8,
            Stage::ConnRead => 9,
            Stage::ConnWrite => 10,
        }
    }

    pub fn name(self) -> &'static str {
        Self::NAMES[self.index()]
    }
}

/// Value distributions (unitless counts) the pipeline records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DistKind {
    /// Members per cohort formed by `submit_batch`.
    CohortSize,
    /// Cascade survivors per strip reaching LB-ordered evaluation.
    StripSurvivors,
    /// Top-k threshold tightenings per query (how fast the bound closed).
    TopkTighten,
    /// Lanes filled per multi-lane wavefront kernel invocation (always
    /// ≥ 2 — lone survivors take the scalar kernel). The mass of this
    /// histogram is the lane-packing efficiency the kernel_lanes bench
    /// gates on.
    LaneOccupancy,
}

impl DistKind {
    pub const COUNT: usize = 4;
    pub const NAMES: [&'static str; Self::COUNT] =
        ["cohort_size", "strip_survivors", "topk_tighten", "lane_occupancy"];
    pub const ALL: [DistKind; Self::COUNT] = [
        DistKind::CohortSize,
        DistKind::StripSurvivors,
        DistKind::TopkTighten,
        DistKind::LaneOccupancy,
    ];

    #[inline]
    pub fn index(self) -> usize {
        match self {
            DistKind::CohortSize => 0,
            DistKind::StripSurvivors => 1,
            DistKind::TopkTighten => 2,
            DistKind::LaneOccupancy => 3,
        }
    }

    pub fn name(self) -> &'static str {
        Self::NAMES[self.index()]
    }
}

/// Point-in-time gauges (set, not accumulated).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gauge {
    /// Workers executing a job right now.
    BusyWorkers,
    /// Queries served since the service started.
    QueriesServed,
    /// Requests currently waiting in the batch coalescer.
    CoalescerPending,
    /// Queries admitted and not yet answered — the value the
    /// `--max-pending` admission budget is checked against.
    PendingQueries,
    /// TCP connections currently open on the network front-end — the
    /// value the `--max-conns` registry bound is checked against.
    OpenConnections,
}

impl Gauge {
    pub const COUNT: usize = 5;
    pub const NAMES: [&'static str; Self::COUNT] = [
        "busy_workers",
        "queries_served",
        "coalescer_pending",
        "pending_queries",
        "open_connections",
    ];
    pub const ALL: [Gauge; Self::COUNT] = [
        Gauge::BusyWorkers,
        Gauge::QueriesServed,
        Gauge::CoalescerPending,
        Gauge::PendingQueries,
        Gauge::OpenConnections,
    ];

    #[inline]
    pub fn index(self) -> usize {
        match self {
            Gauge::BusyWorkers => 0,
            Gauge::QueriesServed => 1,
            Gauge::CoalescerPending => 2,
            Gauge::PendingQueries => 3,
            Gauge::OpenConnections => 4,
        }
    }

    pub fn name(self) -> &'static str {
        Self::NAMES[self.index()]
    }
}

/// One shard's slice of the registry: a flat `AtomicU64` slot per named
/// counter (index-aligned with [`Counters::SLOT_NAMES`]), the gauge
/// slots, and one atomic histogram per stage and per distribution. In
/// steady state exactly one thread writes a cell (its worker, or the
/// service thread for the service cell), so the relaxed atomics are
/// uncontended; snapshots may read concurrently at any time.
#[derive(Debug)]
pub struct ObsCell {
    counters: [AtomicU64; Counters::SLOT_COUNT],
    gauges: [AtomicU64; Gauge::COUNT],
    stages: [AtomicHist; Stage::COUNT],
    dists: [AtomicHist; DistKind::COUNT],
}

impl Default for ObsCell {
    fn default() -> Self {
        Self {
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            gauges: std::array::from_fn(|_| AtomicU64::new(0)),
            stages: std::array::from_fn(|_| AtomicHist::new()),
            dists: std::array::from_fn(|_| AtomicHist::new()),
        }
    }
}

impl ObsCell {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold a finished per-job [`Counters`] delta into the cell — the
    /// single point where scan counters enter the registry. O(slots),
    /// called once per job, skipping zero slots.
    pub fn flush_counters(&self, c: &Counters) {
        for (slot, v) in self.counters.iter().zip(c.slots()) {
            if v > 0 {
                slot.fetch_add(v, Ordering::Relaxed);
            }
        }
    }

    /// Bump one named counter slot directly (service-side events that
    /// don't flow through a scan's `Counters`).
    #[inline]
    pub fn add_counter(&self, slot: usize, v: u64) {
        self.counters[slot].fetch_add(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn set_gauge(&self, g: Gauge, v: u64) {
        self.gauges[g.index()].store(v, Ordering::Relaxed);
    }

    /// Record a stage latency in nanoseconds.
    #[inline]
    pub fn record_stage_ns(&self, s: Stage, ns: u64) {
        self.stages[s.index()].record(ns);
    }

    /// Record a distribution observation.
    #[inline]
    pub fn record_dist(&self, d: DistKind, v: u64) {
        self.dists[d.index()].record(v);
    }

    /// Merge the cell's current contents into a snapshot under
    /// construction.
    pub fn drain_into(&self, snap: &mut MetricsSnapshot) {
        let mut slots = [0u64; Counters::SLOT_COUNT];
        for (out, slot) in slots.iter_mut().zip(&self.counters) {
            *out = slot.load(Ordering::Relaxed);
        }
        snap.counters.merge(&Counters::from_slots(&slots));
        for (out, g) in snap.gauges.iter_mut().zip(&self.gauges) {
            // gauges are owned by exactly one cell; merging takes the max
            // so unset cells (0) never mask the owner's value
            *out = (*out).max(g.load(Ordering::Relaxed));
        }
        for (out, h) in snap.stages.iter_mut().zip(&self.stages) {
            out.merge(&h.snapshot());
        }
        for (out, h) in snap.dists.iter_mut().zip(&self.dists) {
            out.merge(&h.snapshot());
        }
    }
}

/// The sharded registry: one [`ObsCell`] per worker shard plus one for
/// the service thread (queue wait, cohort formation, fan-in, gauges).
/// Snapshots merge every cell; recording never crosses cells.
#[derive(Debug)]
pub struct MetricsRegistry {
    workers: Vec<Arc<ObsCell>>,
    service: Arc<ObsCell>,
}

impl MetricsRegistry {
    pub fn new(shards: usize) -> Self {
        Self {
            workers: (0..shards).map(|_| Arc::new(ObsCell::new())).collect(),
            service: Arc::new(ObsCell::new()),
        }
    }

    /// The cell handed to worker `i` at spawn time.
    pub fn worker_cell(&self, i: usize) -> Arc<ObsCell> {
        Arc::clone(&self.workers[i])
    }

    /// The service thread's own cell.
    pub fn service_cell(&self) -> &ObsCell {
        &self.service
    }

    pub fn shards(&self) -> usize {
        self.workers.len()
    }

    /// Merge every cell into one point-in-time [`MetricsSnapshot`].
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut snap = MetricsSnapshot::default();
        for cell in &self.workers {
            cell.drain_into(&mut snap);
        }
        self.service.drain_into(&mut snap);
        snap
    }
}

/// The observability handle threaded through scan internals: either a
/// cell to record into or — the default for bare library calls, benches
/// and oracles — nothing at all, in which case every method is a no-op
/// and no clock is ever read.
#[derive(Debug, Clone, Copy, Default)]
pub struct ScanObs<'a>(pub Option<&'a ObsCell>);

impl ScanObs<'_> {
    /// Observability disabled: records nothing, reads no clocks.
    pub const OFF: ScanObs<'static> = ScanObs(None);

    #[inline]
    pub fn enabled(&self) -> bool {
        self.0.is_some()
    }

    /// A timestamp — only taken when a cell is attached, so disabled
    /// scans skip the clock read entirely.
    #[inline]
    pub fn now(&self) -> Option<Instant> {
        self.0.map(|_| Instant::now())
    }

    /// Record the elapsed time since a [`ScanObs::now`] timestamp under
    /// `stage`. No-op if either side is off.
    #[inline]
    pub fn stage_since(&self, stage: Stage, t0: Option<Instant>) {
        if let (Some(cell), Some(t0)) = (self.0, t0) {
            cell.record_stage_ns(stage, t0.elapsed().as_nanos() as u64);
        }
    }

    #[inline]
    pub fn record_dist(&self, d: DistKind, v: u64) {
        if let Some(cell) = self.0 {
            cell.record_dist(d, v);
        }
    }

    /// Scoped stage timer: records on drop (or [`StageTimer::stop`]).
    #[inline]
    pub fn stage_timer(&self, stage: Stage) -> StageTimer<'_> {
        StageTimer { live: self.0.map(|cell| (cell, stage, Instant::now())) }
    }
}

/// A scoped timer over one pipeline [`Stage`]: started via
/// [`ScanObs::stage_timer`], records the elapsed nanoseconds into the
/// cell's stage histogram when dropped. Inert (no clock reads) when
/// observability is off.
#[derive(Debug)]
pub struct StageTimer<'a> {
    live: Option<(&'a ObsCell, Stage, Instant)>,
}

impl StageTimer<'_> {
    /// Stop and record now (drop does the same; this names the intent).
    pub fn stop(self) {}
}

impl Drop for StageTimer<'_> {
    fn drop(&mut self) {
        if let Some((cell, stage, t0)) = self.live.take() {
            cell.record_stage_ns(stage, t0.elapsed().as_nanos() as u64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enum_names_are_dense_and_index_aligned() {
        for (i, s) in Stage::ALL.iter().enumerate() {
            assert_eq!(s.index(), i);
            assert_eq!(s.name(), Stage::NAMES[i]);
        }
        for (i, d) in DistKind::ALL.iter().enumerate() {
            assert_eq!(d.index(), i);
            assert_eq!(d.name(), DistKind::NAMES[i]);
        }
        for (i, g) in Gauge::ALL.iter().enumerate() {
            assert_eq!(g.index(), i);
            assert_eq!(g.name(), Gauge::NAMES[i]);
        }
    }

    #[test]
    fn flush_counters_lands_in_named_slots() {
        let cell = ObsCell::new();
        let mut c = Counters::new();
        c.candidates = 10;
        c.dtw_calls = 3;
        c.cost_model_rebuilds = 1;
        cell.flush_counters(&c);
        cell.flush_counters(&c);
        let mut snap = MetricsSnapshot::default();
        cell.drain_into(&mut snap);
        assert_eq!(snap.counters.candidates, 20);
        assert_eq!(snap.counters.dtw_calls, 6);
        assert_eq!(snap.counters.cost_model_rebuilds, 2);
        assert_eq!(snap.counters.lb_kim_prunes, 0);
    }

    #[test]
    fn registry_snapshot_merges_worker_and_service_cells() {
        let reg = MetricsRegistry::new(2);
        let mut a = Counters::new();
        a.candidates = 5;
        a.dtw_calls = 2;
        reg.worker_cell(0).flush_counters(&a);
        let mut b = Counters::new();
        b.candidates = 7;
        b.dtw_abandons = 1;
        reg.worker_cell(1).flush_counters(&b);
        reg.service_cell().set_gauge(Gauge::QueriesServed, 4);
        reg.service_cell().record_stage_ns(Stage::QueueWait, 1_000);
        reg.service_cell().record_dist(DistKind::CohortSize, 3);
        let snap = reg.snapshot();
        assert_eq!(snap.counters.candidates, 12);
        assert_eq!(snap.counters.dtw_calls, 2);
        assert_eq!(snap.counters.dtw_abandons, 1);
        assert_eq!(snap.gauges[Gauge::QueriesServed.index()], 4);
        assert_eq!(snap.stages[Stage::QueueWait.index()].count(), 1);
        assert_eq!(snap.dists[DistKind::CohortSize.index()].max, 3);
    }

    #[test]
    fn disabled_scan_obs_is_inert() {
        let obs = ScanObs::OFF;
        assert!(!obs.enabled());
        assert!(obs.now().is_none());
        obs.stage_since(Stage::KernelEval, None);
        obs.record_dist(DistKind::StripSurvivors, 9);
        obs.stage_timer(Stage::BoundKim).stop();
        // nothing to assert against — the point is it cannot panic or
        // touch any cell; enabled ScanObs is covered below
    }

    #[test]
    fn stage_timer_and_stage_since_record() {
        let cell = ObsCell::new();
        let obs = ScanObs(Some(&cell));
        assert!(obs.enabled());
        let t = obs.stage_timer(Stage::KernelEval);
        std::hint::black_box((0..100).sum::<u64>());
        t.stop();
        let t0 = obs.now();
        assert!(t0.is_some());
        obs.stage_since(Stage::BoundKim, t0);
        let mut snap = MetricsSnapshot::default();
        cell.drain_into(&mut snap);
        assert_eq!(snap.stages[Stage::KernelEval.index()].count(), 1);
        assert_eq!(snap.stages[Stage::BoundKim.index()].count(), 1);
    }
}
