//! Configuration — one TOML-subset config shared by the CLI, the examples
//! and the benches, so every entry point runs the same code path with the
//! same knobs (DESIGN.md §5).
//!
//! The parser covers the subset we use: `[section]` headers, `key = value`
//! with integers, floats, strings, booleans and flat arrays. (The toml
//! crate is unavailable in this offline build.)

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

/// Experiment-grid settings: the paper's §5 design, scaled by `ref_len`.
#[derive(Debug, Clone, PartialEq)]
pub struct GridConfig {
    /// reference stream length per dataset (paper: multi-million; scaled)
    pub ref_len: usize,
    /// queries per dataset (paper: 5)
    pub queries: usize,
    /// query lengths (paper: 128, 256, 512, 1024 — prefixes of 1024)
    pub query_lengths: Vec<usize>,
    /// window ratios (paper: 0.1..=0.5)
    pub window_ratios: Vec<f64>,
    /// noise added to extracted queries, in units of excerpt std
    pub query_noise: f64,
    /// RNG seed for data generation + query extraction
    pub seed: u64,
}

impl Default for GridConfig {
    fn default() -> Self {
        Self {
            ref_len: 200_000,
            queries: 5,
            query_lengths: vec![128, 256, 512, 1024],
            window_ratios: vec![0.1, 0.2, 0.3, 0.4, 0.5],
            query_noise: 0.1,
            seed: 0xDA7A5E7,
        }
    }
}

/// Search settings for one-shot `repro search` runs.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchConfig {
    /// dataset name (FoG/Soccer/PAMAP2/ECG/REFIT/PPG) or a file path
    pub dataset: String,
    pub query_len: usize,
    pub window_ratio: f64,
    /// suite name: ucr | usp | mon | nolb | xla
    pub suite: String,
}

impl Default for SearchConfig {
    fn default() -> Self {
        Self {
            dataset: "ECG".into(),
            query_len: 256,
            window_ratio: 0.1,
            suite: "mon".into(),
        }
    }
}

/// Coordinator / serving settings.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// number of shard workers the reference is split across
    pub shards: usize,
    /// candidate panel size for the XLA prefilter (must match the AOT batch)
    pub batch: usize,
    /// where the AOT artifacts live
    pub artifacts_dir: String,
    /// bounded queue depth between router and workers
    pub queue_depth: usize,
    /// in-flight queries the serve loop coalesces into one cohort-batched
    /// submit (1 = serve each query solo)
    pub batch_window: usize,
    /// milliseconds a partial batch window may wait before it is flushed
    /// anyway (0 = no deadline: wait for the window to fill)
    pub batch_deadline_ms: u64,
    /// admitted-but-unanswered queries tolerated before new arrivals are
    /// shed with an `overloaded` error (0 = unbounded)
    pub max_pending: usize,
    /// per-query deadline budget, ms, for requests without their own
    /// `deadline_ms` (0 = none: exhaustive scans)
    pub default_deadline_ms: f64,
    /// wavefront lane width for the shard workers' kernel (1 = scalar
    /// kernel, the bitwise baseline; clamped to the kernel's MAX_LANES)
    pub lanes: usize,
    /// DP line precision: "f64" (default, bitwise-pinned) or "f32"
    /// (opt-in storage halving under the epsilon contract)
    pub precision: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            shards: 2,
            batch: 64,
            artifacts_dir: "artifacts".into(),
            queue_depth: 64,
            batch_window: 1,
            batch_deadline_ms: 0,
            max_pending: 0,
            default_deadline_ms: 0.0,
            lanes: 1,
            precision: "f64".into(),
        }
    }
}

/// TCP front-end settings (`repro serve --listen`). Every knob bounds a
/// hostile-client resource; see `rust/src/net/README.md`.
#[derive(Debug, Clone, PartialEq)]
pub struct NetConfig {
    /// default listen address for `--listen` without a value
    pub listen: String,
    /// open-connection bound; over-limit accepts are answered with an
    /// `overloaded` error and closed (0 = unbounded)
    pub max_conns: usize,
    /// per-frame length cap, bytes; larger frames answer `frame_too_large`
    pub max_frame_bytes: usize,
    /// budget for assembling one frame, ms; slower senders are cut off
    /// (0 = no budget)
    pub read_timeout_ms: u64,
    /// budget between frames, ms; idle connections are closed
    /// (0 = no budget)
    pub idle_timeout_ms: u64,
    /// bounded per-connection response queue; a client that stops
    /// reading is disconnected when it fills
    pub write_queue: usize,
    /// per-tenant token refill rate, tokens/second (0 = quotas off)
    pub quota_rate: f64,
    /// per-tenant bucket capacity (burst size)
    pub quota_burst: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:7878".into(),
            max_conns: 64,
            max_frame_bytes: 1 << 20,
            read_timeout_ms: 5_000,
            idle_timeout_ms: 300_000,
            write_queue: 64,
            quota_rate: 0.0,
            quota_burst: 8.0,
        }
    }
}

/// Top-level config.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub grid: GridConfig,
    pub search: SearchConfig,
    pub serve: ServeConfig,
    pub net: NetConfig,
}

impl Config {
    /// Parse from TOML-subset text.
    pub fn from_str(text: &str) -> Result<Self> {
        let sections = parse_toml_subset(text)?;
        let mut c = Config::default();
        for (section, kv) in &sections {
            for (key, val) in kv {
                c.apply(section, key, val)
                    .map_err(|e| anyhow!("[{section}] {key}: {e}"))?;
            }
        }
        Ok(c)
    }

    fn apply(&mut self, section: &str, key: &str, v: &TomlValue) -> Result<()> {
        match (section, key) {
            ("grid", "ref_len") => self.grid.ref_len = v.usize()?,
            ("grid", "queries") => self.grid.queries = v.usize()?,
            ("grid", "query_lengths") => self.grid.query_lengths = v.usize_array()?,
            ("grid", "window_ratios") => self.grid.window_ratios = v.f64_array()?,
            ("grid", "query_noise") => self.grid.query_noise = v.f64()?,
            ("grid", "seed") => self.grid.seed = v.usize()? as u64,
            ("search", "dataset") => self.search.dataset = v.string()?,
            ("search", "query_len") => self.search.query_len = v.usize()?,
            ("search", "window_ratio") => self.search.window_ratio = v.f64()?,
            ("search", "suite") => self.search.suite = v.string()?,
            ("serve", "shards") => self.serve.shards = v.usize()?,
            ("serve", "batch") => self.serve.batch = v.usize()?,
            ("serve", "artifacts_dir") => self.serve.artifacts_dir = v.string()?,
            ("serve", "queue_depth") => self.serve.queue_depth = v.usize()?,
            ("serve", "batch_window") => self.serve.batch_window = v.usize()?,
            ("serve", "batch_deadline_ms") => self.serve.batch_deadline_ms = v.usize()? as u64,
            ("serve", "max_pending") => self.serve.max_pending = v.usize()?,
            ("serve", "default_deadline_ms") => self.serve.default_deadline_ms = v.f64()?,
            ("serve", "lanes") => self.serve.lanes = v.usize()?,
            ("serve", "precision") => self.serve.precision = v.string()?,
            ("net", "listen") => self.net.listen = v.string()?,
            ("net", "max_conns") => self.net.max_conns = v.usize()?,
            ("net", "max_frame_bytes") => self.net.max_frame_bytes = v.usize()?,
            ("net", "read_timeout_ms") => self.net.read_timeout_ms = v.usize()? as u64,
            ("net", "idle_timeout_ms") => self.net.idle_timeout_ms = v.usize()? as u64,
            ("net", "write_queue") => self.net.write_queue = v.usize()?,
            ("net", "quota_rate") => self.net.quota_rate = v.f64()?,
            ("net", "quota_burst") => self.net.quota_burst = v.f64()?,
            _ => bail!("unknown config key"),
        }
        Ok(())
    }

    /// Load from a TOML file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        Self::from_str(&text).map_err(|e| anyhow!("{}: {e}", path.display()))
    }

    /// Load `path` if given, defaults otherwise.
    pub fn load_or_default(path: Option<&Path>) -> Result<Self> {
        match path {
            Some(p) => Self::load(p),
            None => Ok(Self::default()),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
enum TomlValue {
    Num(f64),
    Str(String),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    fn usize(&self) -> Result<usize> {
        match self {
            TomlValue::Num(v) if *v >= 0.0 && v.fract() == 0.0 => Ok(*v as usize),
            _ => bail!("expected non-negative integer"),
        }
    }
    fn f64(&self) -> Result<f64> {
        match self {
            TomlValue::Num(v) => Ok(*v),
            _ => bail!("expected number"),
        }
    }
    fn string(&self) -> Result<String> {
        match self {
            TomlValue::Str(s) => Ok(s.clone()),
            _ => bail!("expected string"),
        }
    }
    fn usize_array(&self) -> Result<Vec<usize>> {
        match self {
            TomlValue::Arr(v) => v.iter().map(|x| x.usize()).collect(),
            _ => bail!("expected array of integers"),
        }
    }
    fn f64_array(&self) -> Result<Vec<f64>> {
        match self {
            TomlValue::Arr(v) => v.iter().map(|x| x.f64()).collect(),
            _ => bail!("expected array of numbers"),
        }
    }
}

type Sections = BTreeMap<String, Vec<(String, TomlValue)>>;

fn parse_toml_subset(text: &str) -> Result<Sections> {
    let mut out: Sections = BTreeMap::new();
    let mut section = String::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = match raw.split_once('#') {
            // keep '#' inside quoted strings
            Some((before, _)) if before.matches('"').count() % 2 == 0 => before,
            _ => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.trim().to_string();
            out.entry(section.clone()).or_default();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", ln + 1))?;
        let val = parse_value(v.trim()).map_err(|e| anyhow!("line {}: {e}", ln + 1))?;
        out.entry(section.clone())
            .or_default()
            .push((k.trim().to_string(), val));
    }
    Ok(out)
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if let Some(inner) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items: Result<Vec<_>> = inner.split(',').map(|x| parse_value(x.trim())).collect();
        return Ok(TomlValue::Arr(items?));
    }
    // allow 0x hex for seeds
    if let Some(hex) = s.strip_prefix("0x") {
        let v = u64::from_str_radix(hex, 16).map_err(|e| anyhow!("bad hex {s:?}: {e}"))?;
        return Ok(TomlValue::Num(v as f64));
    }
    s.replace('_', "")
        .parse::<f64>()
        .map(TomlValue::Num)
        .map_err(|e| anyhow!("bad value {s:?}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_grid() {
        let c = Config::default();
        assert_eq!(c.grid.query_lengths, vec![128, 256, 512, 1024]);
        assert_eq!(c.grid.window_ratios, vec![0.1, 0.2, 0.3, 0.4, 0.5]);
        assert_eq!(c.grid.queries, 5);
    }

    #[test]
    fn parses_full_config() {
        let text = r#"
            # comment
            [grid]
            ref_len = 50_000
            queries = 3
            query_lengths = [128, 256]
            window_ratios = [0.1, 0.5]   # inline comment
            seed = 0xBEEF

            [search]
            dataset = "REFIT"
            suite = "nolb"

            [serve]
            shards = 4
        "#;
        let c = Config::from_str(text).unwrap();
        assert_eq!(c.grid.ref_len, 50_000);
        assert_eq!(c.grid.queries, 3);
        assert_eq!(c.grid.query_lengths, vec![128, 256]);
        assert_eq!(c.grid.window_ratios, vec![0.1, 0.5]);
        assert_eq!(c.grid.seed, 0xBEEF);
        assert_eq!(c.search.dataset, "REFIT");
        assert_eq!(c.search.suite, "nolb");
        assert_eq!(c.serve.shards, 4);
        // untouched keys keep defaults
        assert_eq!(c.serve.batch, 64);
        assert_eq!(c.serve.batch_window, 1);
        assert_eq!(c.serve.batch_deadline_ms, 0);
        assert_eq!(c.serve.max_pending, 0);
        assert_eq!(c.serve.default_deadline_ms, 0.0);
        let c2 = Config::from_str(
            "[serve]\nbatch_window = 16\nbatch_deadline_ms = 25\nmax_pending = 256\ndefault_deadline_ms = 40.5\n",
        )
        .unwrap();
        assert_eq!(c2.serve.batch_window, 16);
        assert_eq!(c2.serve.batch_deadline_ms, 25);
        assert_eq!(c2.serve.max_pending, 256);
        assert_eq!(c2.serve.default_deadline_ms, 40.5);
        // kernel tuning keeps the scalar defaults unless set...
        assert_eq!(c2.serve.lanes, 1);
        assert_eq!(c2.serve.precision, "f64");
        let c2b = Config::from_str("[serve]\nlanes = 4\nprecision = \"f32\"\n").unwrap();
        assert_eq!(c2b.serve.lanes, 4);
        assert_eq!(c2b.serve.precision, "f32");
        // untouched sections keep defaults too
        assert_eq!(c2.net, NetConfig::default());
        let c3 = Config::from_str(
            "[net]\nlisten = \"0.0.0.0:9000\"\nmax_conns = 128\nmax_frame_bytes = 65536\n\
             read_timeout_ms = 250\nidle_timeout_ms = 10_000\nwrite_queue = 8\n\
             quota_rate = 50.0\nquota_burst = 100\n",
        )
        .unwrap();
        assert_eq!(c3.net.listen, "0.0.0.0:9000");
        assert_eq!(c3.net.max_conns, 128);
        assert_eq!(c3.net.max_frame_bytes, 65536);
        assert_eq!(c3.net.read_timeout_ms, 250);
        assert_eq!(c3.net.idle_timeout_ms, 10_000);
        assert_eq!(c3.net.write_queue, 8);
        assert_eq!(c3.net.quota_rate, 50.0);
        assert_eq!(c3.net.quota_burst, 100.0);
    }

    #[test]
    fn unknown_key_errors() {
        assert!(Config::from_str("[grid]\nnope = 1\n").is_err());
    }

    #[test]
    fn type_mismatch_errors() {
        assert!(Config::from_str("[grid]\nref_len = \"x\"\n").is_err());
        assert!(Config::from_str("[grid]\nref_len = 1.5\n").is_err());
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(Config::load(Path::new("/no/such/file.toml")).is_err());
    }
}
