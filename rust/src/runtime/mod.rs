//! PJRT runtime (system S12): loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO **text** — see the aot docstring for why
//! not protos) and executes them from the Rust hot path. Python is never
//! involved at runtime.
//!
//! Pattern adapted from /opt/xla-example/load_hlo/: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`, with
//! tuple outputs unwrapped via `to_tuple1`.

pub mod engine;
pub mod manifest;

pub use engine::XlaEngine;
pub use manifest::{ArtifactEntry, Manifest};
