//! `artifacts/manifest.json` — the contract between the AOT pipeline and
//! the runtime: which graphs exist, at which shapes, in which files.
//! Parsed with the in-tree JSON reader (serde_json is unavailable offline).

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// One lowered graph.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub inputs: Vec<InputSpec>,
    pub sha256: String,
    pub bytes: usize,
}

#[derive(Debug, Clone)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// The full manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// panel (batch) size every artifact was lowered with
    pub batch: usize,
    /// query lengths covered
    pub lengths: Vec<usize>,
    pub artifacts: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let field = |k: &str| v.get(k).ok_or_else(|| anyhow!("manifest missing {k:?}"));
        let batch = field("batch")?.as_usize().ok_or_else(|| anyhow!("batch not an int"))?;
        let lengths = field("lengths")?
            .as_arr()
            .ok_or_else(|| anyhow!("lengths not an array"))?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad length")))
            .collect::<Result<Vec<_>>>()?;
        let mut artifacts = Vec::new();
        for a in field("artifacts")?.as_arr().ok_or_else(|| anyhow!("artifacts not an array"))? {
            let s = |k: &str| -> Result<String> {
                Ok(a.get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact missing {k:?}"))?
                    .to_string())
            };
            let mut inputs = Vec::new();
            for i in a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact missing inputs"))?
            {
                let shape = i
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("input missing shape"))?
                    .iter()
                    .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<Vec<_>>>()?;
                let dtype = i
                    .get("dtype")
                    .and_then(Json::as_str)
                    .unwrap_or("float32")
                    .to_string();
                inputs.push(InputSpec { shape, dtype });
            }
            artifacts.push(ArtifactEntry {
                name: s("name")?,
                file: s("file")?,
                inputs,
                sha256: a.get("sha256").and_then(Json::as_str).unwrap_or("").to_string(),
                bytes: a.get("bytes").and_then(Json::as_usize).unwrap_or(0),
            });
        }
        Ok(Self { batch, lengths, artifacts })
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let p = dir.join("manifest.json");
        let text = std::fs::read_to_string(&p).map_err(|e| {
            anyhow!(
                "read {}: {e} — run `make artifacts` first (python AOT pass)",
                p.display()
            )
        })?;
        Self::parse(&text).map_err(|e| anyhow!("{}: {e}", p.display()))
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactEntry> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Artifact name for a graph family at a query length, e.g.
    /// `prefilter_b64_n256`.
    pub fn graph_name(&self, family: &str, n: usize) -> String {
        format!("{family}_b{}_n{n}", self.batch)
    }

    /// Is a query length directly supported?
    pub fn supports_length(&self, n: usize) -> bool {
        self.lengths.contains(&n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "batch": 8, "lengths": [16, 32],
        "artifacts": [
            {"name": "prefilter_b8_n16", "file": "prefilter_b8_n16.hlo.txt",
             "sha256": "ab", "bytes": 120,
             "inputs": [{"shape": [16], "dtype": "float32"},
                        {"shape": [16], "dtype": "float32"},
                        {"shape": [8, 16], "dtype": "float32"}]}
        ]
    }"#;

    #[test]
    fn parses_and_finds() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.batch, 8);
        assert_eq!(m.lengths, vec![16, 32]);
        let a = m.find("prefilter_b8_n16").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[2].shape, vec![8, 16]);
        assert!(m.find("nope").is_none());
        assert_eq!(m.graph_name("prefilter", 16), "prefilter_b8_n16");
        assert!(m.supports_length(32));
        assert!(!m.supports_length(64));
    }

    #[test]
    fn missing_dir_mentions_make_artifacts() {
        let err = Manifest::load(Path::new("/no/such/dir")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"batch": 1, "lengths": [], "artifacts": [{}]}"#).is_err());
    }
}
