//! The XLA execution engine: one PJRT CPU client, one compiled executable
//! per artifact (compiled on first use, cached for the life of the
//! process), typed entry points for the graph families the coordinator
//! uses.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use super::manifest::Manifest;

fn lit_f32(data: &[f32], shape: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(shape)
        .map_err(|e| anyhow!("reshape to {shape:?}: {e:?}"))
}

fn lit_i32(v: i32) -> xla::Literal {
    xla::Literal::vec1(&[v])
}

/// PJRT client + executable cache over one artifacts directory.
pub struct XlaEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl std::fmt::Debug for XlaEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaEngine")
            .field("dir", &self.dir)
            .field("batch", &self.manifest.batch)
            .field("compiled", &self.exes.keys().collect::<Vec<_>>())
            .finish()
    }
}

impl XlaEngine {
    /// Open the artifacts directory (reads `manifest.json`; compiles
    /// nothing yet).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, manifest, dir: dir.to_path_buf(), exes: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Panel (batch) size all artifacts expect.
    pub fn batch(&self) -> usize {
        self.manifest.batch
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(name) {
            let entry = self
                .manifest
                .find(name)
                .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?;
            let path = self.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            self.exes.insert(name.to_string(), exe);
        }
        Ok(&self.exes[name])
    }

    /// Execute an artifact; returns the flattened f32 payload of the
    /// 1-tuple result (the AOT bridge lowers with `return_tuple=True`).
    fn run(&mut self, name: &str, lits: &[xla::Literal]) -> Result<Vec<f32>> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(lits)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {name}: {e:?}"))?;
        let out = lit.to_tuple1().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("read result of {name}: {e:?}"))
    }

    /// Batched z-norm + LB_Keogh prefilter: raw candidate panel
    /// `(batch, n)` against query envelopes `u`/`l` (n each) → `batch`
    /// lower bounds.
    pub fn prefilter(&mut self, n: usize, u: &[f32], l: &[f32], raw: &[f32]) -> Result<Vec<f32>> {
        let b = self.batch();
        anyhow::ensure!(u.len() == n && l.len() == n, "envelope length mismatch");
        anyhow::ensure!(raw.len() == b * n, "panel must be batch*n");
        let name = self.manifest.graph_name("prefilter", n);
        let lits = [
            lit_f32(u, &[n as i64])?,
            lit_f32(l, &[n as i64])?,
            lit_f32(raw, &[b as i64, n as i64])?,
        ];
        self.run(&name, &lits)
    }

    /// Batched z-norm: raw panel `(batch, n)` → z-normalised panel.
    pub fn znorm(&mut self, n: usize, raw: &[f32]) -> Result<Vec<f32>> {
        let b = self.batch();
        anyhow::ensure!(raw.len() == b * n, "panel must be batch*n");
        let name = self.manifest.graph_name("znorm", n);
        let lits = [lit_f32(raw, &[b as i64, n as i64])?];
        self.run(&name, &lits)
    }

    /// Batched LB_Keogh on an already-normalised panel.
    pub fn lb_keogh(&mut self, n: usize, u: &[f32], l: &[f32], z: &[f32]) -> Result<Vec<f32>> {
        let b = self.batch();
        anyhow::ensure!(z.len() == b * n, "panel must be batch*n");
        let name = self.manifest.graph_name("lb_keogh", n);
        let lits = [
            lit_f32(u, &[n as i64])?,
            lit_f32(l, &[n as i64])?,
            lit_f32(z, &[b as i64, n as i64])?,
        ];
        self.run(&name, &lits)
    }

    /// Batched exact wavefront DTW: z-normalised query `q` (n), window `w`
    /// (cells), z-normalised panel `(batch, n)` → `batch` exact distances.
    pub fn batched_dtw(&mut self, n: usize, q: &[f32], w: usize, z: &[f32]) -> Result<Vec<f32>> {
        let b = self.batch();
        anyhow::ensure!(q.len() == n, "query length mismatch");
        anyhow::ensure!(z.len() == b * n, "panel must be batch*n");
        let name = self.manifest.graph_name("dtw", n);
        let lits = [
            lit_f32(q, &[n as i64])?,
            lit_i32(w as i32),
            lit_f32(z, &[b as i64, n as i64])?,
        ];
        self.run(&name, &lits)
    }

    /// Fused prefilter + exact DTW on a raw panel: returns
    /// (lower bounds, exact distances), each `batch` long (ablation A3).
    pub fn prefilter_verify(
        &mut self,
        n: usize,
        q: &[f32],
        u: &[f32],
        l: &[f32],
        w: usize,
        raw: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let b = self.batch();
        anyhow::ensure!(raw.len() == b * n, "panel must be batch*n");
        let name = self.manifest.graph_name("prefilter_verify", n);
        let lits = [
            lit_f32(q, &[n as i64])?,
            lit_f32(u, &[n as i64])?,
            lit_f32(l, &[n as i64])?,
            lit_i32(w as i32),
            lit_f32(raw, &[b as i64, n as i64])?,
        ];
        let flat = self.run(&name, &lits)?;
        anyhow::ensure!(flat.len() == 2 * b, "unexpected output size {}", flat.len());
        Ok((flat[..b].to_vec(), flat[b..].to_vec()))
    }
}
