//! Keogh envelopes via Lemire's streaming min/max (monotonic deques):
//! `U[i] = max(s[i-w ..= i+w])`, `L[i] = min(...)` in O(n) regardless of
//! `w`. Used on the query (LB_Keogh "EQ") and on the raw data stream
//! (LB_Keogh "EC"); the naive O(n·w) version stays as the test oracle.

/// Compute upper and lower envelopes of `s` for window `w` into `upper` /
/// `lower` (resized to `s.len()`). Lemire 2009, "Faster retrieval with a
/// two-pass dynamic-time-warping lower bound".
pub fn envelopes_into(s: &[f64], w: usize, upper: &mut Vec<f64>, lower: &mut Vec<f64>) {
    let mut maxq = std::collections::VecDeque::new();
    let mut minq = std::collections::VecDeque::new();
    envelopes_into_with(s, w, upper, lower, &mut maxq, &mut minq);
}

/// [`envelopes_into`] with caller-owned deque scratch, so per-candidate
/// hot paths (LB_Improved's second pass) stay allocation-free. Bitwise
/// identical to [`envelopes_into`]; the deques are cleared on entry.
pub fn envelopes_into_with(
    s: &[f64],
    w: usize,
    upper: &mut Vec<f64>,
    lower: &mut Vec<f64>,
    maxq: &mut std::collections::VecDeque<usize>,
    minq: &mut std::collections::VecDeque<usize>,
) {
    let n = s.len();
    upper.clear();
    upper.resize(n, 0.0);
    lower.clear();
    lower.resize(n, 0.0);
    if n == 0 {
        return;
    }
    // Monotonic deques of indices: front is the current max (resp. min).
    maxq.clear();
    minq.clear();
    for i in 0..n + w {
        if i < n {
            while maxq.back().is_some_and(|&b| s[b] <= s[i]) {
                maxq.pop_back();
            }
            maxq.push_back(i);
            while minq.back().is_some_and(|&b| s[b] >= s[i]) {
                minq.pop_back();
            }
            minq.push_back(i);
        }
        // envelope position whose window [p-w, p+w] we just completed
        if i >= w {
            let p = i - w;
            while maxq.front().is_some_and(|&f| f + w < p) {
                maxq.pop_front();
            }
            while minq.front().is_some_and(|&f| f + w < p) {
                minq.pop_front();
            }
            upper[p] = s[*maxq.front().expect("window never empty")];
            lower[p] = s[*minq.front().expect("window never empty")];
        }
    }
}

/// Allocating convenience wrapper around [`envelopes_into`].
pub fn envelopes(s: &[f64], w: usize) -> (Vec<f64>, Vec<f64>) {
    let mut u = Vec::new();
    let mut l = Vec::new();
    envelopes_into(s, w, &mut u, &mut l);
    (u, l)
}

/// Naive O(n·w) envelopes — the oracle.
pub fn envelopes_naive(s: &[f64], w: usize) -> (Vec<f64>, Vec<f64>) {
    let n = s.len();
    let mut u = vec![0.0; n];
    let mut l = vec![0.0; n];
    for i in 0..n {
        let lo = i.saturating_sub(w);
        let hi = (i + w).min(n.saturating_sub(1));
        let win = &s[lo..=hi];
        u[i] = win.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        l[i] = win.iter().copied().fold(f64::INFINITY, f64::min);
    }
    (u, l)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xorshift(seed: u64) -> impl FnMut() -> f64 {
        let mut x = seed;
        move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 4.0 - 2.0
        }
    }

    #[test]
    fn matches_naive() {
        for seed in 1..=4u64 {
            let mut rnd = xorshift(seed);
            for n in [1usize, 2, 7, 32, 100] {
                let s: Vec<f64> = (0..n).map(|_| rnd()).collect();
                for w in [0usize, 1, 3, n / 2, n, n + 5] {
                    let (u, l) = envelopes(&s, w);
                    let (nu, nl) = envelopes_naive(&s, w);
                    assert_eq!(u, nu, "upper n={n} w={w}");
                    assert_eq!(l, nl, "lower n={n} w={w}");
                }
            }
        }
    }

    #[test]
    fn window_zero_is_identity() {
        let s = [3.0, 1.0, 4.0, 1.0, 5.0];
        let (u, l) = envelopes(&s, 0);
        assert_eq!(u, s.to_vec());
        assert_eq!(l, s.to_vec());
    }

    #[test]
    fn envelope_sandwiches_series() {
        let mut rnd = xorshift(9);
        let s: Vec<f64> = (0..50).map(|_| rnd()).collect();
        let (u, l) = envelopes(&s, 5);
        for i in 0..s.len() {
            assert!(l[i] <= s[i] && s[i] <= u[i]);
        }
    }

    #[test]
    fn wider_window_widens_envelope() {
        let mut rnd = xorshift(10);
        let s: Vec<f64> = (0..40).map(|_| rnd()).collect();
        let (u1, l1) = envelopes(&s, 2);
        let (u2, l2) = envelopes(&s, 8);
        for i in 0..s.len() {
            assert!(u2[i] >= u1[i] && l2[i] <= l1[i]);
        }
    }

    #[test]
    fn empty_series() {
        let (u, l) = envelopes(&[], 3);
        assert!(u.is_empty() && l.is_empty());
    }

    #[test]
    fn scratch_reuse_is_bitwise_identical() {
        let mut rnd = xorshift(11);
        let mut u2 = Vec::new();
        let mut l2 = Vec::new();
        let mut maxq = std::collections::VecDeque::new();
        let mut minq = std::collections::VecDeque::new();
        // reuse the same buffers across calls of varying size/window so
        // stale deque state would be caught
        for n in [1usize, 5, 33, 64, 7] {
            let s: Vec<f64> = (0..n).map(|_| rnd()).collect();
            for w in [0usize, 1, n / 2, n + 3] {
                let (u, l) = envelopes(&s, w);
                envelopes_into_with(&s, w, &mut u2, &mut l2, &mut maxq, &mut minq);
                assert_eq!(u, u2, "upper n={n} w={w}");
                assert_eq!(l, l2, "lower n={n} w={w}");
            }
        }
    }

    #[test]
    fn single_point_series_every_window() {
        for w in [0usize, 1, 2, 100] {
            let (u, l) = envelopes(&[2.5], w);
            assert_eq!(u, vec![2.5], "w={w}");
            assert_eq!(l, vec![2.5], "w={w}");
        }
    }

    #[test]
    fn window_at_least_len_is_global_min_max() {
        let mut rnd = xorshift(12);
        let s: Vec<f64> = (0..23).map(|_| rnd()).collect();
        let gmax = s.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let gmin = s.iter().copied().fold(f64::INFINITY, f64::min);
        for w in [s.len() - 1, s.len(), s.len() + 1, 10 * s.len()] {
            let (u, l) = envelopes(&s, w);
            assert!(u.iter().all(|&x| x == gmax), "w={w}");
            assert!(l.iter().all(|&x| x == gmin), "w={w}");
        }
    }

    /// Clamp `x` into `[lo, hi]` — the LB_Improved projection step.
    fn project(x: f64, lo: f64, hi: f64) -> f64 {
        x.min(hi).max(lo)
    }

    #[test]
    fn projection_onto_own_envelope_is_identity() {
        // L[i] <= s[i] <= U[i], so projecting a series onto its own
        // envelope must return the series unchanged — the degenerate case
        // of LB_Improved's second pass (h == c when q == c).
        let mut rnd = xorshift(13);
        for n in [1usize, 2, 17, 60] {
            let s: Vec<f64> = (0..n).map(|_| rnd()).collect();
            for w in [0usize, 1, n / 2, n] {
                let (u, l) = envelopes(&s, w);
                let h: Vec<f64> = (0..n).map(|i| project(s[i], l[i], u[i])).collect();
                assert_eq!(h, s, "n={n} w={w}");
            }
        }
    }
}
