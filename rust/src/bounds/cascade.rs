//! Cascade policy: which lower bounds run before the DTW core, and whether
//! their per-position contributions tighten the DTW threshold (the paper's
//! "upper bound tightening", available to every suite except MON-nolb,
//! which by construction has no LB information to tighten with).

/// Which cascade stages a suite enables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CascadePolicy {
    /// LB_KimFL (O(1), first stage)
    pub kim: bool,
    /// LB_Keogh on the query envelope
    pub keogh_eq: bool,
    /// LB_Keogh on the data envelope
    pub keogh_ec: bool,
    /// LB_Improved second pass (Lemire's two-pass bound) on survivors
    pub improved: bool,
    /// pass the cumulative LB tail into the DTW core
    pub tighten: bool,
}

impl CascadePolicy {
    /// The full UCR cascade (UCR, UCR-USP, UCR-MON).
    pub const fn full() -> Self {
        Self { kim: true, keogh_eq: true, keogh_ec: true, improved: true, tighten: true }
    }

    /// No lower bounds at all (UCR-MON-nolb): every candidate reaches DTW,
    /// and nothing is available for tightening.
    pub const fn none() -> Self {
        Self { kim: false, keogh_eq: false, keogh_ec: false, improved: false, tighten: false }
    }

    /// Does any envelope-based bound run (i.e. do we need envelopes)?
    pub fn needs_query_envelopes(&self) -> bool {
        self.keogh_eq
    }
    pub fn needs_data_envelopes(&self) -> bool {
        self.keogh_ec || self.improved
    }
    pub fn any(&self) -> bool {
        self.kim || self.keogh_eq || self.keogh_ec || self.improved
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets() {
        let f = CascadePolicy::full();
        assert!(f.kim && f.keogh_eq && f.keogh_ec && f.improved && f.tighten && f.any());
        let n = CascadePolicy::none();
        assert!(!n.kim && !n.keogh_eq && !n.keogh_ec && !n.improved && !n.tighten && !n.any());
    }

    #[test]
    fn improved_alone_needs_data_envelopes() {
        // the second pass projects the query onto the *candidate's*
        // envelope, so it depends on the data-stream envelopes even when
        // the EC stage itself is off
        let p = CascadePolicy { improved: true, ..CascadePolicy::none() };
        assert!(p.needs_data_envelopes() && p.any() && !p.needs_query_envelopes());
    }
}
