//! LB_Keogh, both directions, with the UCR suite's tricks: sorted-order
//! early abandon and per-position contributions (`cb`) whose suffix sums
//! tighten the DTW threshold line by line (paper §2.2, §5).
//!
//! * **EQ** ("envelope-query"): envelopes of the *query* vs the
//!   z-normalised candidate.
//! * **EC** ("envelope-candidate"): envelopes of the *raw data stream* vs
//!   the query — the envelope of an affine transform is the transform of
//!   the envelope, so per-candidate z-normalisation is applied to the
//!   precomputed raw envelopes on the fly.

use crate::distances::cost::sqed;
use crate::norm::znorm::znorm_point;

/// Indices of `q` sorted by `|q[i]|` descending — large-magnitude positions
/// of a z-normalised query contribute the largest envelope violations
/// first, making the early abandon in the bounds (and the UCR DTW cascade)
/// trigger sooner.
pub fn sort_order(q: &[f64]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..q.len()).collect();
    order.sort_by(|&a, &b| q[b].abs().partial_cmp(&q[a].abs()).expect("no NaN in query"));
    order
}

/// Reorder `v` by `order` (`out[k] = v[order[k]]`).
pub fn reorder(v: &[f64], order: &[usize]) -> Vec<f64> {
    order.iter().map(|&i| v[i]).collect()
}

/// LB_Keogh EQ. `uo`/`lo` are the query envelopes *already reordered* by
/// `order`; `c` is the raw candidate window with stats (mean, std);
/// `cb` (len n) receives the per-position contribution at the *original*
/// position (`cb[order[k]]`). Abandons once the bound exceeds `ub`
/// (contributions stay valid, the bound is then partial).
#[allow(clippy::too_many_arguments)]
pub fn lb_keogh_eq(
    order: &[usize],
    uo: &[f64],
    lo: &[f64],
    c: &[f64],
    mean: f64,
    std: f64,
    ub: f64,
    cb: &mut [f64],
) -> f64 {
    let n = order.len();
    debug_assert_eq!(c.len(), n);
    debug_assert_eq!(cb.len(), n);
    let mut lb = 0.0;
    for k in 0..n {
        let i = order[k];
        let x = znorm_point(c[i], mean, std);
        let d = if x > uo[k] {
            sqed(x, uo[k])
        } else if x < lo[k] {
            sqed(x, lo[k])
        } else {
            0.0
        };
        cb[i] = d;
        lb += d;
        if lb > ub {
            // zero the rest so a caller that *does* use cb after an
            // abandon still holds a valid (under-) estimate
            for &i2 in &order[k + 1..] {
                cb[i2] = 0.0;
            }
            return lb;
        }
    }
    lb
}

/// [`lb_keogh_eq`] over an **already z-normalised** candidate `zc` — the
/// strip scan's per-survivor pass, which fills the z-norm buffer once and
/// feeds both this bound and the distance kernel from it. Reading
/// `zc[i]` is IEEE-identical to the on-the-fly `znorm_point(c[i], ..)`
/// of the scalar pass, so the bound value and the `cb` contributions are
/// bit-equal to [`lb_keogh_eq`] on the raw window.
pub fn lb_keogh_eq_pre(
    order: &[usize],
    uo: &[f64],
    lo: &[f64],
    zc: &[f64],
    ub: f64,
    cb: &mut [f64],
) -> f64 {
    let n = order.len();
    debug_assert_eq!(zc.len(), n);
    debug_assert_eq!(cb.len(), n);
    let mut lb = 0.0;
    for k in 0..n {
        let i = order[k];
        let x = zc[i];
        let d = if x > uo[k] {
            sqed(x, uo[k])
        } else if x < lo[k] {
            sqed(x, lo[k])
        } else {
            0.0
        };
        cb[i] = d;
        lb += d;
        if lb > ub {
            for &i2 in &order[k + 1..] {
                cb[i2] = 0.0;
            }
            return lb;
        }
    }
    lb
}

/// LB_Keogh EC: query points vs the z-normalised *data* envelopes.
/// `u`/`l` are the raw-stream envelopes for this window (slices of the
/// precomputed reference envelopes), `qo` the query reordered by `order`.
#[allow(clippy::too_many_arguments)]
pub fn lb_keogh_ec(
    order: &[usize],
    qo: &[f64],
    u: &[f64],
    l: &[f64],
    mean: f64,
    std: f64,
    ub: f64,
    cb: &mut [f64],
) -> f64 {
    let n = order.len();
    debug_assert_eq!(u.len(), n);
    debug_assert_eq!(l.len(), n);
    debug_assert_eq!(cb.len(), n);
    let mut lb = 0.0;
    for k in 0..n {
        let i = order[k];
        let x = qo[k];
        let uz = znorm_point(u[i], mean, std);
        let d = if x > uz {
            sqed(x, uz)
        } else {
            let lz = znorm_point(l[i], mean, std);
            if x < lz {
                sqed(x, lz)
            } else {
                0.0
            }
        };
        cb[i] = d;
        lb += d;
        if lb > ub {
            for &i2 in &order[k + 1..] {
                cb[i2] = 0.0;
            }
            return lb;
        }
    }
    lb
}

/// Turn per-position contributions into the suffix-cumulative array the
/// DTW cores consume: `out[j] = sum(cb[j..])`, `out[n] = 0`.
pub fn cumulate_bound(cb: &[f64], out: &mut Vec<f64>) {
    let n = cb.len();
    out.clear();
    out.resize(n + 1, 0.0);
    let mut acc = 0.0;
    for j in (0..n).rev() {
        acc += cb[j];
        out[j] = acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::envelope::envelopes;
    use crate::distances::dtw::dtw_oracle;
    use crate::norm::znorm::znorm;

    fn xorshift(seed: u64) -> impl FnMut() -> f64 {
        let mut x = seed;
        move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 4.0 - 2.0
        }
    }

    fn stats(c: &[f64]) -> (f64, f64) {
        let n = c.len() as f64;
        let mean = c.iter().sum::<f64>() / n;
        let std = (c.iter().map(|x| x * x).sum::<f64>() / n - mean * mean)
            .max(0.0)
            .sqrt();
        (mean, std)
    }

    #[test]
    fn eq_is_lower_bound_on_windowed_dtw() {
        for seed in 1..=5u64 {
            let mut rnd = xorshift(seed);
            let n = 32;
            let q = znorm(&(0..n).map(|_| rnd()).collect::<Vec<_>>());
            let c: Vec<f64> = (0..n).map(|_| rnd() * 2.0 - 0.5).collect();
            let (mean, std) = stats(&c);
            let zc: Vec<f64> = c.iter().map(|&x| znorm_point(x, mean, std)).collect();
            for w in [1usize, 4, 10] {
                let (u, l) = envelopes(&q, w);
                let order = sort_order(&q);
                let uo = reorder(&u, &order);
                let lo = reorder(&l, &order);
                let mut cb = vec![0.0; n];
                let lb = lb_keogh_eq(&order, &uo, &lo, &c, mean, std, f64::INFINITY, &mut cb);
                let d = dtw_oracle(&q, &zc, Some(w));
                assert!(lb <= d + 1e-9, "seed={seed} w={w}: {lb} > {d}");
                // contributions sum to the bound
                let s: f64 = cb.iter().sum();
                assert!((s - lb).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn ec_is_lower_bound_on_windowed_dtw() {
        for seed in 1..=5u64 {
            let mut rnd = xorshift(seed + 100);
            let n = 32;
            let q = znorm(&(0..n).map(|_| rnd()).collect::<Vec<_>>());
            let c: Vec<f64> = (0..n).map(|_| rnd() * 3.0 + 2.0).collect();
            let (mean, std) = stats(&c);
            let zc: Vec<f64> = c.iter().map(|&x| znorm_point(x, mean, std)).collect();
            for w in [1usize, 4, 10] {
                // envelopes of the RAW data, z-normalised inside the bound
                let (u, l) = envelopes(&c, w);
                let order = sort_order(&q);
                let qo = reorder(&q, &order);
                let mut cb = vec![0.0; n];
                let lb = lb_keogh_ec(&order, &qo, &u, &l, mean, std, f64::INFINITY, &mut cb);
                let d = dtw_oracle(&q, &zc, Some(w));
                assert!(lb <= d + 1e-9, "seed={seed} w={w}: {lb} > {d}");
            }
        }
    }

    #[test]
    fn pre_normalised_pass_is_bit_identical_to_raw_pass() {
        for seed in 1..=4u64 {
            let mut rnd = xorshift(seed + 40);
            let n = 24;
            let q = znorm(&(0..n).map(|_| rnd()).collect::<Vec<_>>());
            let c: Vec<f64> = (0..n).map(|_| rnd() * 2.5 + 0.75).collect();
            let (mean, std) = stats(&c);
            let zc: Vec<f64> = c.iter().map(|&x| znorm_point(x, mean, std)).collect();
            let (u, l) = envelopes(&q, 3);
            let order = sort_order(&q);
            let uo = reorder(&u, &order);
            let lo = reorder(&l, &order);
            for ub in [f64::INFINITY, 1.0, 1e-3] {
                let mut cb1 = vec![0.0; n];
                let mut cb2 = vec![0.0; n];
                let a = lb_keogh_eq(&order, &uo, &lo, &c, mean, std, ub, &mut cb1);
                let b = lb_keogh_eq_pre(&order, &uo, &lo, &zc, ub, &mut cb2);
                assert_eq!(a.to_bits(), b.to_bits(), "seed={seed} ub={ub}");
                for (x, y) in cb1.iter().zip(&cb2) {
                    assert_eq!(x.to_bits(), y.to_bits(), "seed={seed} ub={ub}");
                }
            }
        }
    }

    #[test]
    fn candidate_inside_envelope_gives_zero() {
        let q = znorm(&[1.0, 2.0, 3.0, 2.0, 1.0, 0.0, 1.0, 2.0]);
        let (u, l) = envelopes(&q, 2);
        let order = sort_order(&q);
        let uo = reorder(&u, &order);
        let lo = reorder(&l, &order);
        let mut cb = vec![0.0; q.len()];
        // the query against itself (already normalised: mean 0, std 1)
        let lb = lb_keogh_eq(&order, &uo, &lo, &q, 0.0, 1.0, f64::INFINITY, &mut cb);
        assert_eq!(lb, 0.0);
    }

    #[test]
    fn abandon_zeroes_tail_contributions() {
        let q = znorm(&[5.0, -5.0, 5.0, -5.0, 5.0, -5.0]);
        let (u, l) = envelopes(&q, 1);
        let order = sort_order(&q);
        let uo = reorder(&u, &order);
        let lo = reorder(&l, &order);
        let c = [100.0, -100.0, 100.0, -100.0, 100.0, -100.0];
        let mut cb = vec![f64::NAN; q.len()];
        let lb = lb_keogh_eq(&order, &uo, &lo, &c, 0.0, 1.0, 1e-6, &mut cb);
        assert!(lb > 1e-6);
        assert!(cb.iter().all(|v| v.is_finite()), "tail must be zeroed, not NaN");
    }

    #[test]
    fn cumulate_bound_suffix_sums() {
        let cb = [1.0, 2.0, 3.0];
        let mut out = Vec::new();
        cumulate_bound(&cb, &mut out);
        assert_eq!(out, vec![6.0, 5.0, 3.0, 0.0]);
    }

    #[test]
    fn sort_order_is_permutation_by_magnitude() {
        let q = [0.1, -3.0, 2.0, -0.5];
        let order = sort_order(&q);
        assert_eq!(order, vec![1, 2, 3, 0]);
    }
}
