//! Lower bounds for DTW and the UCR cascade (paper §2.2, systems S7–S8).
//!
//! The UCR suite skips most DTW calls entirely with a cascade of ever more
//! expensive, ever tighter lower bounds: LB_KimFL (O(1)) → LB_Keogh on the
//! query envelope (O(n), abandonable) → LB_Keogh on the data envelope →
//! LB_Improved's second pass (Lemire's two-pass bound) on what survives.
//! Only survivors reach the DTW core — which is why the paper reports the
//! per-dataset proportion each stage prunes (Fig. 5's insets) and why
//! showing EAPrunedDTW makes the cascade *dispensable* is a headline
//! result. See `README.md` in this directory for the cascade order and
//! each stage's admissibility argument.

pub mod batch;
pub mod cascade;
pub mod envelope;
pub mod lb_improved;
pub mod lb_keogh;
pub mod lb_kim;
