//! Batched (strip/SoA) lower bounds for the strip-mined scan pipeline.
//!
//! The scalar scan interleaves bound math and control flow per candidate;
//! the strip scan instead runs each cheap bound over a whole strip of
//! candidates at once, reading structure-of-arrays scratch lanes
//! ([`StripScratch`]) so the inner loops are branch-light and
//! stable-rustc autovectorizes them (`chunks_exact(4)` + scalar
//! remainder — no `std::simd`, no nightly, no new dependencies).
//!
//! Exactness contract: every value produced here is a valid lower bound
//! of the candidate's (banded) DTW distance, and every *prune decision*
//! taken against a threshold is identical to the one the scalar cascade
//! would take at the same threshold:
//!
//! * [`batch_lb_kim_into`] runs the scalar
//!   [`crate::bounds::lb_kim::lb_kim_hierarchy`] to completion (ub = ∞)
//!   per lane, so the lane value is the **full** hierarchy bound by
//!   construction. The cascade's scalar call may exit early with a
//!   *partial* bound; since every stage only adds non-negative terms,
//!   `partial > ub ⟺ full > ub`, so the prune decision is unchanged
//!   (only the reported magnitude can differ).
//! * [`lb_keogh_eq_unordered`] is LB_Keogh EQ summed in **natural
//!   position order** (four independent accumulators) instead of the
//!   scalar pass's sorted order. The same non-negative terms are summed,
//!   so it bounds the same quantity; the sorted-order pass (which also
//!   produces the `cb` tightening tail) still runs per *survivor*, so
//!   the distance math that reaches the kernel stays IEEE-identical to
//!   the scalar scan.
//! * [`lb_keogh_ec_unordered`] is the same construction for the EC
//!   direction (query points vs the z-normalised data envelopes) — the
//!   first pass of the strip scan's batched LB_Improved stage. Because
//!   the unordered sums can sit ~n·ε relative away from the sorted
//!   scalar values, every batch prune against a threshold applies an ε
//!   discount first (see the strip scan), keeping prune decisions a
//!   strict subset of the scalar cascade's.

use crate::bounds::lb_kim::lb_kim_hierarchy;
use crate::distances::cost::sqed;
use crate::norm::znorm::znorm_point;

/// Default strip length B: long enough to amortise per-strip setup and
/// fill the SoA lanes, short enough that the strip-entry threshold stays
/// close to the freshest one (the survivors re-check a fresh threshold
/// anyway).
pub const DEFAULT_STRIP: usize = 64;

/// Structure-of-arrays scratch for one strip of candidate windows. Owned
/// by the query context and reused across strips, so the strip scan stays
/// allocation-free after the first strip.
#[derive(Debug, Clone, Default)]
pub struct StripScratch {
    /// per-lane window mean (from `WindowStats` / `BucketStats`)
    pub mean: Vec<f64>,
    /// per-lane window std
    pub std: Vec<f64>,
    /// per-lane best lower bound seen so far (max over computed stages)
    pub lb: Vec<f64>,
    /// lanes still in play after the batch bounds
    pub alive: Vec<bool>,
    /// survivor lane indices, sorted ascending by `(lb, lane)`
    pub order: Vec<u32>,
}

impl StripScratch {
    /// Size every lane for a strip of `len` candidates and reset state.
    pub fn reset(&mut self, len: usize) {
        self.mean.clear();
        self.mean.resize(len, 0.0);
        self.std.clear();
        self.std.resize(len, 0.0);
        self.lb.clear();
        self.lb.resize(len, 0.0);
        self.alive.clear();
        self.alive.resize(len, true);
        self.order.clear();
    }

    /// Lanes still alive.
    pub fn survivors(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    /// Fill `order` with the alive lanes, ascending `(lb, lane)` — the
    /// evaluation order that tightens the top-k threshold fastest. Ties
    /// (and any NaN a caller let through) resolve by lane index, so the
    /// order is total and deterministic.
    pub fn order_survivors(&mut self) {
        fill_survivor_order(&self.lb, &self.alive, &mut self.order);
    }
}

/// The shared survivor-ordering rule of every strip front-end: alive lanes
/// ascending by `(lower bound, lane index)` — total and deterministic even
/// on ties (or NaN, via `total_cmp`).
fn fill_survivor_order(lb: &[f64], alive: &[bool], order: &mut Vec<u32>) {
    order.clear();
    order.extend((0..lb.len() as u32).filter(|&i| alive[i as usize]));
    order.sort_by(|&a, &b| lb[a as usize].total_cmp(&lb[b as usize]).then(a.cmp(&b)));
}

/// One query's private lanes of a cohort strip: its lower bounds, alive
/// flags and survivor order over the strip's candidate positions. The
/// window statistics live once in the parent [`CohortScratch`] — that
/// sharing is the point of the cohort scan.
#[derive(Debug, Clone, Default)]
pub struct QueryLanes {
    /// best lower bound seen so far for each strip position
    pub lb: Vec<f64>,
    /// positions still in play for this query
    pub alive: Vec<bool>,
    /// survivor positions, ascending `(lb, lane)`
    pub order: Vec<u32>,
}

impl QueryLanes {
    /// Size the lanes for a strip of `len` positions and reset state.
    pub fn reset(&mut self, len: usize) {
        self.lb.clear();
        self.lb.resize(len, 0.0);
        self.alive.clear();
        self.alive.resize(len, true);
        self.order.clear();
    }

    /// Fill `order` with this query's alive lanes, ascending `(lb, lane)`
    /// — the same rule [`StripScratch::order_survivors`] applies.
    pub fn order_survivors(&mut self) {
        fill_survivor_order(&self.lb, &self.alive, &mut self.order);
    }
}

/// Per-position **z-normalised LB_Kim endpoint lanes** for one cohort
/// strip: the up-to-six candidate points the LB_KimFL hierarchy touches
/// (`x0..x2` from the window front, `y0..y2` from the back), normalised
/// with the position's shared `(mean, std)`. The normalised values are
/// query-independent, so one fill serves every member of the cohort —
/// the raw-sample analogue of the shared stat lanes.
#[derive(Debug, Clone, Default)]
pub struct KimLanes {
    pub x0: Vec<f64>,
    pub x1: Vec<f64>,
    pub x2: Vec<f64>,
    pub y0: Vec<f64>,
    pub y1: Vec<f64>,
    pub y2: Vec<f64>,
}

/// Candidate points the scalar LB_Kim hierarchy reads (and z-normalises)
/// per window of `n` points when run to completion: the front/back
/// endpoints, then one more pair per hierarchy level the length admits.
/// This is the per-lane unit of the `strip_sample_loads_saved` invariant.
pub fn kim_loads_per_lane(n: usize) -> u64 {
    match n {
        0 => 0,
        1 | 2 => 2,
        3 | 4 => 4,
        _ => 6,
    }
}

/// Structure-of-arrays scratch for one strip of a **query-cohort** scan:
/// the single-query [`StripScratch`] grown a query axis. The per-position
/// window statistics (`mean`, `std`) and the LB_Kim endpoint lanes
/// ([`KimLanes`]) are loaded **once per strip** and shared by every
/// member; each member keeps private [`QueryLanes`] (bounds, alive flags,
/// survivor order) because each filters against its own top-k threshold.
/// Owned by the shard worker and reused across strips, cohorts and
/// queries, so the steady state is allocation-free.
#[derive(Debug, Clone, Default)]
pub struct CohortScratch {
    /// per-position window mean, shared by all members
    pub mean: Vec<f64>,
    /// per-position window std, shared by all members
    pub std: Vec<f64>,
    /// per-position z-normalised LB_Kim endpoints, shared by all members
    /// (filled only when the cascade's LB_Kim stage runs)
    pub kim: KimLanes,
    /// one lane set per cohort member (index-aligned with the members)
    pub lanes: Vec<QueryLanes>,
}

impl CohortScratch {
    /// Ensure one lane set per cohort member. Per-member lanes are reset
    /// lazily by the scan ([`QueryLanes::reset`]) so retired members cost
    /// nothing per strip.
    pub fn ensure_members(&mut self, nq: usize) {
        if self.lanes.len() < nq {
            self.lanes.resize_with(nq, QueryLanes::default);
        }
    }

    /// Load a strip's shared stat lanes in one pass (no intermediate
    /// zero fill — this is the load the whole cohort shares).
    pub fn load_stats(&mut self, mean: &[f64], std: &[f64]) {
        debug_assert_eq!(mean.len(), std.len());
        self.mean.clear();
        self.mean.extend_from_slice(mean);
        self.std.clear();
        self.std.extend_from_slice(std);
    }

    /// Load a strip's shared LB_Kim endpoint lanes: for each of the `len`
    /// windows of `n` points starting at `strip_start`, read the
    /// hierarchy's endpoint samples once and z-normalise them with the
    /// already-loaded `(mean, std)` lanes. The values are bit-identical to
    /// what each member's own [`batch_lb_kim_into`] pass would compute, so
    /// sharing them is a pure memory-traffic optimisation.
    pub fn load_kim(&mut self, reference: &[f64], strip_start: usize, len: usize, n: usize) {
        debug_assert!(len <= self.mean.len() && len <= self.std.len());
        debug_assert!(strip_start + len + n <= reference.len() + 1);
        let kim = &mut self.kim;
        kim.x0.clear();
        kim.y0.clear();
        kim.x1.clear();
        kim.y1.clear();
        kim.x2.clear();
        kim.y2.clear();
        if n == 0 {
            return;
        }
        for i in 0..len {
            let base = strip_start + i;
            let (m, s) = (self.mean[i], self.std[i]);
            kim.x0.push(znorm_point(reference[base], m, s));
            kim.y0.push(znorm_point(reference[base + n - 1], m, s));
            if n >= 3 {
                kim.x1.push(znorm_point(reference[base + 1], m, s));
                kim.y1.push(znorm_point(reference[base + n - 2], m, s));
            }
            if n >= 5 {
                kim.x2.push(znorm_point(reference[base + 2], m, s));
                kim.y2.push(znorm_point(reference[base + n - 3], m, s));
            }
        }
    }
}

/// Batched LB_KimFL over a strip: for each lane `i`, the full hierarchy
/// bound of `q` vs the raw window starting at `strip_start + i`, using the
/// lane's `(mean, std)` for on-the-fly z-normalisation. Writes into
/// `out[..len]`. Stage arithmetic and ordering match the scalar
/// [`crate::bounds::lb_kim::lb_kim_hierarchy`] run to completion.
pub fn batch_lb_kim_into(
    q: &[f64],
    reference: &[f64],
    strip_start: usize,
    len: usize,
    mean: &[f64],
    std: &[f64],
    out: &mut [f64],
) {
    let n = q.len();
    debug_assert!(len <= mean.len() && len <= std.len() && len <= out.len());
    debug_assert!(strip_start + len + n <= reference.len() + 1);
    if n == 0 {
        out[..len].fill(0.0);
        return;
    }
    // Each lane reads its six endpoint points directly — the strip's
    // windows overlap by n - 1 positions, so consecutive lanes touch
    // adjacent memory and the whole strip's endpoint reads stay in cache.
    // ub = inf runs the scalar hierarchy to completion, so the lane value
    // is the scalar full bound by construction, not by re-implementation.
    for i in 0..len {
        let c = &reference[strip_start + i..strip_start + i + n];
        out[i] = lb_kim_hierarchy(q, c, mean[i], std[i], f64::INFINITY);
    }
}

/// Batched LB_KimFL over a strip from **pre-normalised endpoint lanes**
/// ([`KimLanes`], loaded once per cohort strip): composes the SAME stage
/// min-chains as the scalar hierarchy
/// ([`crate::bounds::lb_kim::stages`] — one copy of the arithmetic, so
/// the two paths cannot drift), with the candidate-side z-normalisation
/// factored out because it is query-independent. Bit-identical to
/// [`batch_lb_kim_into`] (pinned by a unit test below); only the
/// raw-sample reads are shared.
pub fn batch_lb_kim_pre(q: &[f64], kim: &KimLanes, len: usize, out: &mut [f64]) {
    use crate::bounds::lb_kim::stages;
    let n = q.len();
    debug_assert!(len <= out.len());
    if n == 0 {
        out[..len].fill(0.0);
        return;
    }
    debug_assert!(len <= kim.x0.len() && len <= kim.y0.len());
    for i in 0..len {
        let (x0, y0) = (kim.x0[i], kim.y0[i]);
        let mut lb = stages::ends1(q, x0, y0);
        if n < 3 {
            out[i] = lb;
            continue;
        }
        let (x1, y1) = (kim.x1[i], kim.y1[i]);
        lb += stages::front2(q, x0, x1);
        lb += stages::back2(q, y0, y1);
        if n < 5 {
            out[i] = lb;
            continue;
        }
        let (x2, y2) = (kim.x2[i], kim.y2[i]);
        lb += stages::front3(q, x0, x1, x2);
        out[i] = lb + stages::back3(q, y0, y1, y2);
    }
}

/// LB_Keogh EQ summed in natural position order with four independent
/// accumulators — the batch-stage filter of the strip scan. `u`/`l` are
/// the query envelopes in **natural** (unsorted) order; `c` is the raw
/// candidate window with stats `(mean, std)`. No early abandon and no
/// per-position contributions: this is the cheap whole-window pass, the
/// sorted `cb`-producing pass still runs on survivors.
pub fn lb_keogh_eq_unordered(u: &[f64], l: &[f64], c: &[f64], mean: f64, std: f64) -> f64 {
    let n = c.len();
    debug_assert_eq!(u.len(), n);
    debug_assert_eq!(l.len(), n);
    let mut acc = [0.0f64; 4];
    let mut iu = u.chunks_exact(4);
    let mut il = l.chunks_exact(4);
    for cc in c.chunks_exact(4) {
        let uu = iu.next().expect("envelope length");
        let ll = il.next().expect("envelope length");
        for k in 0..4 {
            let x = znorm_point(cc[k], mean, std);
            let d = if x > uu[k] {
                sqed(x, uu[k])
            } else if x < ll[k] {
                sqed(x, ll[k])
            } else {
                0.0
            };
            acc[k] += d;
        }
    }
    let mut lb = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    let rem = n - n % 4;
    for j in rem..n {
        let x = znorm_point(c[j], mean, std);
        if x > u[j] {
            lb += sqed(x, u[j]);
        } else if x < l[j] {
            lb += sqed(x, l[j]);
        }
    }
    lb
}

/// LB_Keogh EC summed in natural position order with four independent
/// accumulators — the first pass of the batched LB_Improved stage. `u`/`l`
/// are the **raw** data-stream envelope slices for this window,
/// z-normalised on the fly with the lane's `(mean, std)`; `q` is the
/// z-normalised query in natural order. Per-position penalty values are
/// IEEE-identical to the scalar [`crate::bounds::lb_keogh::lb_keogh_ec`]
/// pass (same `znorm_point`/`sqed` ops, same lazy lower-boundary
/// evaluation); only the summation order differs.
pub fn lb_keogh_ec_unordered(q: &[f64], u: &[f64], l: &[f64], mean: f64, std: f64) -> f64 {
    let n = q.len();
    debug_assert_eq!(u.len(), n);
    debug_assert_eq!(l.len(), n);
    let mut acc = [0.0f64; 4];
    let mut iu = u.chunks_exact(4);
    let mut il = l.chunks_exact(4);
    for qq in q.chunks_exact(4) {
        let uu = iu.next().expect("envelope length");
        let ll = il.next().expect("envelope length");
        for k in 0..4 {
            let x = qq[k];
            let uz = znorm_point(uu[k], mean, std);
            let d = if x > uz {
                sqed(x, uz)
            } else {
                let lz = znorm_point(ll[k], mean, std);
                if x < lz {
                    sqed(x, lz)
                } else {
                    0.0
                }
            };
            acc[k] += d;
        }
    }
    let mut lb = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    let rem = n - n % 4;
    for j in rem..n {
        let x = q[j];
        let uz = znorm_point(u[j], mean, std);
        if x > uz {
            lb += sqed(x, uz);
        } else {
            let lz = znorm_point(l[j], mean, std);
            if x < lz {
                lb += sqed(x, lz);
            }
        }
    }
    lb
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::envelope::envelopes;
    use crate::bounds::lb_keogh::{lb_keogh_eq, reorder, sort_order};
    use crate::bounds::lb_kim::lb_kim_hierarchy;
    use crate::distances::dtw::dtw_oracle;
    use crate::norm::znorm::{stats, znorm};

    fn xorshift(seed: u64) -> impl FnMut() -> f64 {
        let mut x = seed;
        move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 4.0 - 2.0
        }
    }

    #[test]
    fn batch_kim_matches_scalar_full_hierarchy() {
        for n in [2usize, 3, 4, 5, 8, 32] {
            let mut rnd = xorshift(7 + n as u64);
            let q = znorm(&(0..n).map(|_| rnd()).collect::<Vec<_>>());
            let reference: Vec<f64> = (0..n + 40).map(|_| rnd() * 3.0 + 1.0).collect();
            let len = reference.len() - n + 1;
            let (mut mean, mut std) = (vec![0.0; len], vec![0.0; len]);
            for (pos, (m, s)) in mean.iter_mut().zip(std.iter_mut()).enumerate() {
                let (bm, bs) = stats(&reference[pos..pos + n]);
                (*m, *s) = (bm, bs);
            }
            let mut out = vec![0.0; len];
            batch_lb_kim_into(&q, &reference, 0, len, &mean, &std, &mut out);
            for pos in 0..len {
                let c = &reference[pos..pos + n];
                // scalar full hierarchy (ub = inf: no early exit)
                let want = lb_kim_hierarchy(&q, c, mean[pos], std[pos], f64::INFINITY);
                assert_eq!(out[pos].to_bits(), want.to_bits(), "n={n} pos={pos}");
            }
        }
    }

    #[test]
    fn batch_kim_prune_decision_matches_staged_scalar() {
        // even when the scalar exits early (partial bound), `> ub`
        // decisions agree because stages only add non-negative terms
        let mut rnd = xorshift(99);
        let n = 16;
        let q = znorm(&(0..n).map(|_| rnd()).collect::<Vec<_>>());
        let reference: Vec<f64> = (0..n + 30).map(|_| rnd() * 5.0).collect();
        let len = reference.len() - n + 1;
        let (mut mean, mut std) = (vec![0.0; len], vec![0.0; len]);
        for pos in 0..len {
            let (bm, bs) = stats(&reference[pos..pos + n]);
            (mean[pos], std[pos]) = (bm, bs);
        }
        let mut out = vec![0.0; len];
        batch_lb_kim_into(&q, &reference, 0, len, &mean, &std, &mut out);
        for ub in [0.01, 0.5, 2.0, 10.0] {
            for pos in 0..len {
                let c = &reference[pos..pos + n];
                let staged = lb_kim_hierarchy(&q, c, mean[pos], std[pos], ub);
                assert_eq!(out[pos] > ub, staged > ub, "ub={ub} pos={pos}");
            }
        }
    }

    #[test]
    fn unordered_keogh_is_a_lower_bound_and_matches_sorted_sum() {
        for seed in 1..=6u64 {
            let mut rnd = xorshift(seed);
            for n in [5usize, 8, 31, 32, 64] {
                let q = znorm(&(0..n).map(|_| rnd()).collect::<Vec<_>>());
                let c: Vec<f64> = (0..n).map(|_| rnd() * 2.0 - 0.5).collect();
                let (mean, std) = stats(&c);
                let (u, l) = envelopes(&q, (n / 4).max(1));
                let lb = lb_keogh_eq_unordered(&u, &l, &c, mean, std);
                // same terms as the sorted scalar pass, different
                // summation order: equal within fp tolerance
                let order = sort_order(&q);
                let uo = reorder(&u, &order);
                let lo = reorder(&l, &order);
                let mut cb = vec![0.0; n];
                let sorted = lb_keogh_eq(&order, &uo, &lo, &c, mean, std, f64::INFINITY, &mut cb);
                assert!((lb - sorted).abs() < 1e-9, "seed={seed} n={n}: {lb} vs {sorted}");
                // and a valid bound on the windowed DTW
                let zc: Vec<f64> = c.iter().map(|&x| znorm_point(x, mean, std)).collect();
                let d = dtw_oracle(&q, &zc, Some((n / 4).max(1)));
                assert!(lb <= d + 1e-9, "seed={seed} n={n}: {lb} > {d}");
            }
        }
    }

    #[test]
    fn unordered_ec_is_a_lower_bound_and_matches_sorted_sum() {
        use crate::bounds::lb_keogh::lb_keogh_ec;
        for seed in 1..=6u64 {
            let mut rnd = xorshift(seed + 60);
            for n in [5usize, 8, 31, 32, 64] {
                let q = znorm(&(0..n).map(|_| rnd()).collect::<Vec<_>>());
                let c: Vec<f64> = (0..n).map(|_| rnd() * 3.0 + 1.5).collect();
                let (mean, std) = stats(&c);
                let w = (n / 4).max(1);
                // envelopes of the RAW data, z-normalised inside the bound
                let (u, l) = envelopes(&c, w);
                let lb = lb_keogh_ec_unordered(&q, &u, &l, mean, std);
                let order = sort_order(&q);
                let qo = reorder(&q, &order);
                let mut cb = vec![0.0; n];
                let sorted = lb_keogh_ec(&order, &qo, &u, &l, mean, std, f64::INFINITY, &mut cb);
                assert!((lb - sorted).abs() < 1e-9, "seed={seed} n={n}: {lb} vs {sorted}");
                let zc: Vec<f64> = c.iter().map(|&x| znorm_point(x, mean, std)).collect();
                let d = dtw_oracle(&q, &zc, Some(w));
                assert!(lb <= d + 1e-9, "seed={seed} n={n}: {lb} > {d}");
            }
        }
    }

    #[test]
    fn pre_normalised_kim_lanes_match_per_member_batch_bitwise() {
        // the shared endpoint lanes must reproduce every member's own
        // batched LB_Kim pass bit for bit, across every length regime of
        // the hierarchy (1-point, 2-point, 3-point stages)
        for n in [1usize, 2, 3, 4, 5, 8, 32] {
            let mut rnd = xorshift(11 + n as u64);
            let q = if n == 1 {
                vec![0.7]
            } else {
                znorm(&(0..n).map(|_| rnd()).collect::<Vec<_>>())
            };
            let reference: Vec<f64> = (0..n + 50).map(|_| rnd() * 3.0 + 0.5).collect();
            let strip_start = 3usize;
            let len = 40;
            let (mut mean, mut std) = (vec![0.0; len], vec![0.0; len]);
            for i in 0..len {
                let (bm, bs) = stats(&reference[strip_start + i..strip_start + i + n]);
                (mean[i], std[i]) = (bm, bs);
            }
            let mut scratch = CohortScratch::default();
            scratch.load_stats(&mean, &std);
            scratch.load_kim(&reference, strip_start, len, n);
            let mut pre = vec![0.0; len];
            batch_lb_kim_pre(&q, &scratch.kim, len, &mut pre);
            let mut want = vec![0.0; len];
            batch_lb_kim_into(&q, &reference, strip_start, len, &mean, &std, &mut want);
            for i in 0..len {
                assert_eq!(pre[i].to_bits(), want[i].to_bits(), "n={n} lane={i}");
            }
        }
        // the invariant's per-lane unit tracks the hierarchy stages
        assert_eq!(kim_loads_per_lane(0), 0);
        assert_eq!(kim_loads_per_lane(1), 2);
        assert_eq!(kim_loads_per_lane(2), 2);
        assert_eq!(kim_loads_per_lane(3), 4);
        assert_eq!(kim_loads_per_lane(4), 4);
        assert_eq!(kim_loads_per_lane(5), 6);
        assert_eq!(kim_loads_per_lane(128), 6);
    }

    #[test]
    fn cohort_scratch_shares_stats_and_keeps_lanes_private() {
        let mut s = CohortScratch::default();
        s.ensure_members(3);
        s.load_stats(&[1.0, 2.0, 3.0, 4.0], &[0.1, 0.2, 0.3, 0.4]);
        assert_eq!(s.mean, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.lanes.len(), 3);
        for lane in &mut s.lanes {
            lane.reset(4);
        }
        s.lanes[0].lb.copy_from_slice(&[2.0, 1.0, 3.0, 0.0]);
        s.lanes[1].alive[2] = false;
        s.lanes[0].order_survivors();
        s.lanes[1].order_survivors();
        assert_eq!(s.lanes[0].order, vec![3, 1, 0, 2]);
        // member 1's dead lane is private — member 0 still orders all four
        assert_eq!(s.lanes[1].order, vec![0, 1, 3]);
        // a shorter strip re-loads the shared lanes wholesale and lane
        // resets are per member (a retired member's stale lanes are fine)
        s.load_stats(&[9.0, 8.0], &[0.9, 0.8]);
        assert_eq!(s.std, vec![0.9, 0.8]);
        s.lanes[0].reset(2);
        assert_eq!(s.lanes[0].lb, vec![0.0; 2]);
        assert!(s.lanes[0].alive.iter().all(|&a| a));
        // growing never shrinks the lane table
        s.ensure_members(2);
        assert_eq!(s.lanes.len(), 3);
    }

    #[test]
    fn scratch_orders_survivors_by_bound_then_lane() {
        let mut s = StripScratch::default();
        s.reset(5);
        s.lb.copy_from_slice(&[3.0, 1.0, 2.0, 1.0, 0.5]);
        s.alive[2] = false;
        s.order_survivors();
        assert_eq!(s.order, vec![4, 1, 3, 0]);
        assert_eq!(s.survivors(), 4);
        // reset clears state
        s.reset(3);
        assert_eq!(s.lb, vec![0.0; 3]);
        assert!(s.alive.iter().all(|&a| a));
        assert!(s.order.is_empty());
    }
}
