//! LB_Improved — Lemire's two-pass lower bound (arxiv 0807.1734, refined
//! in 0811.3301). After a first LB_Keogh pass, project the outside series
//! onto the envelope it was compared against (`h[i] = clamp(x[i], L[i],
//! U[i])`), build the envelope of the projection `h`, and run a second
//! Keogh pass with roles swapped. The two passes *add*: for every warping
//! path pair `(i, j)` with `|i - j| <= w`,
//!
//! ```text
//! (x_i - y_j)^2 >= (x_i - h_i)^2 + (h_i - y_j)^2
//! ```
//!
//! because `h_i` is the envelope boundary nearest `x_i` and `y_j` lies
//! inside the envelope — so `h_i` sits between `x_i` and `y_j`. The first
//! term sums to LB_Keogh; the second is at least the penalty of `y_j`
//! against the window-`w` envelope of `h` (since `h_i` is inside that
//! envelope at `j`). Hence `LB_Keogh + tail <= DTW_w`, and the tail alone
//! is admissible too.
//!
//! The tail's penalties are indexed by the *other* series' positions, not
//! the query rows the kernel abandons on, so they deliberately do **not**
//! feed the `cb` threshold-tightening tail (doing so would be unsound —
//! see `bounds/README.md`).

use std::collections::VecDeque;

use crate::bounds::envelope::envelopes_into_with;
use crate::distances::cost::sqed;
use crate::norm::znorm::znorm_point;

/// Reusable scratch for the second pass: the projection `h`, its
/// envelopes, and the monotonic deques that build them. Lives in
/// `QueryContext` so the per-candidate hot path stays allocation-free.
#[derive(Debug, Clone, Default)]
pub struct ImprovedScratch {
    h: Vec<f64>,
    uh: Vec<f64>,
    lh: Vec<f64>,
    maxq: VecDeque<usize>,
    minq: VecDeque<usize>,
}

impl ImprovedScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Envelope `h` (already filled) and sum the second-pass penalties of
    /// the pre-normalised series `other` against it, in natural order,
    /// abandoning once the partial sum exceeds `budget` (a partial sum is
    /// still a valid under-estimate, so callers may use it freely).
    fn finish(&mut self, other: &[f64], w: usize, budget: f64) -> f64 {
        envelopes_into_with(&self.h, w, &mut self.uh, &mut self.lh, &mut self.maxq, &mut self.minq);
        let mut tail = 0.0;
        for (j, &x) in other.iter().enumerate() {
            let d = if x > self.uh[j] {
                sqed(x, self.uh[j])
            } else if x < self.lh[j] {
                sqed(x, self.lh[j])
            } else {
                0.0
            };
            tail += d;
            if tail > budget {
                return tail;
            }
        }
        tail
    }

    /// [`ImprovedScratch::finish`] over a **raw** series, z-normalised on
    /// the fly with `(mean, std)`. `znorm_point` per element is
    /// IEEE-identical to reading a pre-normalised buffer, so this returns
    /// the same bits as `finish` on the normalised copy.
    fn finish_raw(&mut self, other: &[f64], mean: f64, std: f64, w: usize, budget: f64) -> f64 {
        envelopes_into_with(&self.h, w, &mut self.uh, &mut self.lh, &mut self.maxq, &mut self.minq);
        let mut tail = 0.0;
        for (j, &raw) in other.iter().enumerate() {
            let x = znorm_point(raw, mean, std);
            let d = if x > self.uh[j] {
                sqed(x, self.uh[j])
            } else if x < self.lh[j] {
                sqed(x, self.lh[j])
            } else {
                0.0
            };
            tail += d;
            if tail > budget {
                return tail;
            }
        }
        tail
    }

    /// Fill `h` = projection of the z-normalised query onto the
    /// candidate's envelope (raw data-stream envelopes `du`/`dl`,
    /// z-normalised on the fly with the window's stats — same lazy
    /// lower-boundary evaluation as `lb_keogh_ec`).
    fn project_ec(&mut self, q: &[f64], du: &[f64], dl: &[f64], mean: f64, std: f64) {
        debug_assert_eq!(du.len(), q.len());
        debug_assert_eq!(dl.len(), q.len());
        self.h.clear();
        self.h.extend(q.iter().zip(du.iter().zip(dl)).map(|(&x, (&ur, &lr))| {
            let uz = znorm_point(ur, mean, std);
            if x > uz {
                uz
            } else {
                let lz = znorm_point(lr, mean, std);
                if x < lz {
                    lz
                } else {
                    x
                }
            }
        }));
    }
}

/// EC-side LB_Improved tail over a **pre-normalised** candidate `zc`:
/// project `q` onto the candidate's (z-normalised) envelope and sum the
/// second-pass penalties of `zc` against the projection's envelope.
/// Returns only the tail — the caller adds it onto its first-pass EC sum
/// (`lb_ec + tail <= DTW_w(q, zc)`; the tail alone is admissible when the
/// EC stage is disabled). `budget` early-abandons the tail sum.
#[allow(clippy::too_many_arguments)]
pub fn lb_improved_tail_ec(
    scratch: &mut ImprovedScratch,
    q: &[f64],
    du: &[f64],
    dl: &[f64],
    mean: f64,
    std: f64,
    zc: &[f64],
    w: usize,
    budget: f64,
) -> f64 {
    debug_assert_eq!(zc.len(), q.len());
    scratch.project_ec(q, du, dl, mean, std);
    scratch.finish(zc, w, budget)
}

/// [`lb_improved_tail_ec`] over the **raw** candidate window — the batch
/// lanes call this before any z-norm buffer exists. Bit-identical to the
/// pre-normalised variant on the same window.
#[allow(clippy::too_many_arguments)]
pub fn lb_improved_tail_ec_raw(
    scratch: &mut ImprovedScratch,
    q: &[f64],
    du: &[f64],
    dl: &[f64],
    mean: f64,
    std: f64,
    c: &[f64],
    w: usize,
    budget: f64,
) -> f64 {
    debug_assert_eq!(c.len(), q.len());
    scratch.project_ec(q, du, dl, mean, std);
    scratch.finish_raw(c, mean, std, w, budget)
}

/// EQ-side LB_Improved tail (NN1's direction, both series already
/// normalised): project the candidate `c` onto the query's envelopes
/// `u`/`l` (natural order) and sum the penalties of `q` against the
/// projection's envelope.
pub fn lb_improved_tail_eq(
    scratch: &mut ImprovedScratch,
    c: &[f64],
    u: &[f64],
    l: &[f64],
    q: &[f64],
    w: usize,
    budget: f64,
) -> f64 {
    debug_assert_eq!(u.len(), c.len());
    debug_assert_eq!(l.len(), c.len());
    debug_assert_eq!(q.len(), c.len());
    scratch.h.clear();
    scratch.h.extend(
        c.iter()
            .zip(u.iter().zip(l))
            .map(|(&x, (&ui, &li))| if x > ui { ui } else if x < li { li } else { x }),
    );
    scratch.finish(q, w, budget)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::envelope::envelopes;
    use crate::bounds::lb_keogh::{lb_keogh_ec, reorder, sort_order};
    use crate::distances::dtw::dtw_oracle;
    use crate::norm::znorm::znorm;

    fn xorshift(seed: u64) -> impl FnMut() -> f64 {
        let mut x = seed;
        move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 4.0 - 2.0
        }
    }

    fn stats(c: &[f64]) -> (f64, f64) {
        let n = c.len() as f64;
        let mean = c.iter().sum::<f64>() / n;
        let std = (c.iter().map(|x| x * x).sum::<f64>() / n - mean * mean).max(0.0).sqrt();
        (mean, std)
    }

    #[test]
    fn ec_plus_tail_is_lower_bound_on_windowed_dtw() {
        let mut scratch = ImprovedScratch::new();
        for seed in 1..=6u64 {
            let mut rnd = xorshift(seed + 500);
            let n = 32;
            let q = znorm(&(0..n).map(|_| rnd()).collect::<Vec<_>>());
            let c: Vec<f64> = (0..n).map(|_| rnd() * 2.0 + 1.0).collect();
            let (mean, std) = stats(&c);
            let zc: Vec<f64> = c.iter().map(|&x| znorm_point(x, mean, std)).collect();
            for w in [0usize, 1, 4, 10] {
                let (du, dl) = envelopes(&c, w);
                let order = sort_order(&q);
                let qo = reorder(&q, &order);
                let mut cb = vec![0.0; n];
                let ec = lb_keogh_ec(&order, &qo, &du, &dl, mean, std, f64::INFINITY, &mut cb);
                let tail = lb_improved_tail_ec(
                    &mut scratch,
                    &q,
                    &du,
                    &dl,
                    mean,
                    std,
                    &zc,
                    w,
                    f64::INFINITY,
                );
                assert!(tail >= 0.0);
                let d = dtw_oracle(&q, &zc, Some(w));
                assert!(ec + tail <= d + 1e-9, "seed={seed} w={w}: {} > {d}", ec + tail);
            }
        }
    }

    #[test]
    fn raw_variant_is_bit_identical_to_pre_normalised() {
        let mut s1 = ImprovedScratch::new();
        let mut s2 = ImprovedScratch::new();
        for seed in 1..=4u64 {
            let mut rnd = xorshift(seed + 900);
            let n = 24;
            let q = znorm(&(0..n).map(|_| rnd()).collect::<Vec<_>>());
            let c: Vec<f64> = (0..n).map(|_| rnd() * 3.0 - 1.0).collect();
            let (mean, std) = stats(&c);
            let zc: Vec<f64> = c.iter().map(|&x| znorm_point(x, mean, std)).collect();
            let (du, dl) = envelopes(&c, 3);
            for budget in [f64::INFINITY, 1.0, 1e-4] {
                let a = lb_improved_tail_ec(&mut s1, &q, &du, &dl, mean, std, &zc, 3, budget);
                let b = lb_improved_tail_ec_raw(&mut s2, &q, &du, &dl, mean, std, &c, 3, budget);
                assert_eq!(a.to_bits(), b.to_bits(), "seed={seed} budget={budget}");
            }
        }
    }

    #[test]
    fn eq_tail_is_lower_bound_for_whole_series() {
        let mut scratch = ImprovedScratch::new();
        for seed in 1..=5u64 {
            let mut rnd = xorshift(seed + 77);
            let n = 28;
            let q = znorm(&(0..n).map(|_| rnd()).collect::<Vec<_>>());
            let c = znorm(&(0..n).map(|_| rnd()).collect::<Vec<_>>());
            for w in [1usize, 5, 9] {
                let (u, l) = envelopes(&q, w);
                // first pass: candidate points vs the query envelope
                let mut first = 0.0;
                for i in 0..n {
                    let x = c[i];
                    first += if x > u[i] {
                        sqed(x, u[i])
                    } else if x < l[i] {
                        sqed(x, l[i])
                    } else {
                        0.0
                    };
                }
                let tail = lb_improved_tail_eq(&mut scratch, &c, &u, &l, &q, w, f64::INFINITY);
                let d = dtw_oracle(&q, &c, Some(w));
                assert!(first + tail <= d + 1e-9, "seed={seed} w={w}");
            }
        }
    }

    #[test]
    fn identical_series_give_zero_tail() {
        // q projected onto its own envelope is q itself, so the second
        // pass compares q against env(q): zero everywhere
        let mut scratch = ImprovedScratch::new();
        let mut rnd = xorshift(321);
        let q = znorm(&(0..20).map(|_| rnd()).collect::<Vec<_>>());
        let (u, l) = envelopes(&q, 3);
        let tail = lb_improved_tail_eq(&mut scratch, &q, &u, &l, &q, 3, f64::INFINITY);
        assert_eq!(tail, 0.0);
    }

    #[test]
    fn flat_window_yields_zero_tail() {
        // std below STD_EPS: every point normalises to 0, the projection
        // collapses to the zero series and the tail must be 0, not NaN
        let mut scratch = ImprovedScratch::new();
        let q = vec![0.5, -0.5, 0.25, -0.25];
        let c = vec![7.0; 4];
        let (du, dl) = envelopes(&c, 1);
        let tail =
            lb_improved_tail_ec_raw(&mut scratch, &q, &du, &dl, 7.0, 0.0, &c, 1, f64::INFINITY);
        assert_eq!(tail, 0.0);
    }

    #[test]
    fn abandon_returns_partial_overshoot() {
        let mut scratch = ImprovedScratch::new();
        let q = vec![0.0; 16];
        let c: Vec<f64> = (0..16).map(|i| if i % 2 == 0 { 3.0 } else { -3.0 }).collect();
        let (mean, std) = stats(&c);
        let (du, dl) = envelopes(&c, 2);
        let zc: Vec<f64> = c.iter().map(|&x| znorm_point(x, mean, std)).collect();
        let full =
            lb_improved_tail_ec(&mut scratch, &q, &du, &dl, mean, std, &zc, 2, f64::INFINITY);
        assert!(full > 1.0);
        let part = lb_improved_tail_ec(&mut scratch, &q, &du, &dl, mean, std, &zc, 2, 1.0);
        assert!(part > 1.0, "abandon must still certify the budget overshoot");
        assert!(part <= full);
    }
}
