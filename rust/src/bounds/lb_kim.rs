//! LB_KimFL hierarchy — the UCR suite's O(1)-ish first cascade stage.
//!
//! DTW anchors the first and last points of both series, so
//! `d(q_0,c_0) + d(q_{n-1},c_{n-1})` is a lower bound; the hierarchy then
//! adds the cheapest admissible alignment of the 2nd and 3rd points from
//! each end (a superset of the alignments any window allows, hence still a
//! bound), abandoning between steps once the running bound exceeds `ub`.
//!
//! Candidates arrive as *raw* stream windows plus their (mean, std): points
//! are z-normalised on the fly, so the whole cascade touches at most six
//! candidate points when it prunes.

use crate::norm::znorm::znorm_point;

/// The hierarchy's per-stage min-chains over already-normalised endpoint
/// values — ONE copy of the alignment arithmetic, composed by both the
/// lazy early-exiting scalar ([`lb_kim_hierarchy`]) and the
/// pre-normalised batch path ([`crate::bounds::batch::batch_lb_kim_pre`]),
/// so the two cannot drift apart.
pub(crate) mod stages {
    use crate::distances::cost::sqed;

    /// 1 point at front and back (always exactly aligned).
    #[inline(always)]
    pub fn ends1(q: &[f64], x0: f64, y0: f64) -> f64 {
        let n = q.len();
        sqed(x0, q[0]) + sqed(y0, q[n - 1])
    }
    /// 2 points at front.
    #[inline(always)]
    pub fn front2(q: &[f64], x0: f64, x1: f64) -> f64 {
        sqed(x1, q[0]).min(sqed(x0, q[1])).min(sqed(x1, q[1]))
    }
    /// 2 points at back.
    #[inline(always)]
    pub fn back2(q: &[f64], y0: f64, y1: f64) -> f64 {
        let n = q.len();
        sqed(y1, q[n - 1]).min(sqed(y0, q[n - 2])).min(sqed(y1, q[n - 2]))
    }
    /// 3 points at front.
    #[inline(always)]
    pub fn front3(q: &[f64], x0: f64, x1: f64, x2: f64) -> f64 {
        sqed(x0, q[2])
            .min(sqed(x1, q[2]))
            .min(sqed(x2, q[2]))
            .min(sqed(x2, q[1]))
            .min(sqed(x2, q[0]))
    }
    /// 3 points at back.
    #[inline(always)]
    pub fn back3(q: &[f64], y0: f64, y1: f64, y2: f64) -> f64 {
        let n = q.len();
        sqed(y0, q[n - 3])
            .min(sqed(y1, q[n - 3]))
            .min(sqed(y2, q[n - 3]))
            .min(sqed(y2, q[n - 2]))
            .min(sqed(y2, q[n - 1]))
    }
}

/// LB_KimFL hierarchy of `q` (z-normalised) vs the raw window `c` with
/// normalisation (mean, std). Returns a lower bound on `DTW_w(q, znorm(c))`
/// for any window `w`; once the bound exceeds `ub` it returns early (the
/// value is then a valid but partial bound).
pub fn lb_kim_hierarchy(q: &[f64], c: &[f64], mean: f64, std: f64, ub: f64) -> f64 {
    let n = q.len();
    debug_assert_eq!(n, c.len());
    if n == 0 {
        return 0.0;
    }
    let z = |i: usize| znorm_point(c[i], mean, std);
    let x0 = z(0);
    let y0 = z(n - 1);
    let mut lb = stages::ends1(q, x0, y0);
    if lb > ub || n < 3 {
        return lb;
    }
    let x1 = z(1);
    lb += stages::front2(q, x0, x1);
    if lb > ub {
        return lb;
    }
    let y1 = z(n - 2);
    lb += stages::back2(q, y0, y1);
    if lb > ub || n < 5 {
        return lb;
    }
    let x2 = z(2);
    lb += stages::front3(q, x0, x1, x2);
    if lb > ub {
        return lb;
    }
    let y2 = z(n - 3);
    lb + stages::back3(q, y0, y1, y2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::dtw::dtw_oracle;
    use crate::norm::znorm::znorm;

    fn xorshift(seed: u64) -> impl FnMut() -> f64 {
        let mut x = seed;
        move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 4.0 - 2.0
        }
    }

    #[test]
    fn is_lower_bound_for_all_windows() {
        for seed in 1..=6u64 {
            let mut rnd = xorshift(seed);
            let n = 24;
            let q_raw: Vec<f64> = (0..n).map(|_| rnd()).collect();
            let c: Vec<f64> = (0..n).map(|_| rnd() * 3.0 + 1.0).collect();
            let q = znorm(&q_raw);
            let mean = c.iter().sum::<f64>() / n as f64;
            let std = (c.iter().map(|x| x * x).sum::<f64>() / n as f64 - mean * mean).sqrt();
            let zc: Vec<f64> = c.iter().map(|&x| znorm_point(x, mean, std)).collect();
            let lb = lb_kim_hierarchy(&q, &c, mean, std, f64::INFINITY);
            for w in [1usize, 3, n / 2, n] {
                let d = dtw_oracle(&q, &zc, Some(w));
                assert!(lb <= d + 1e-9, "seed={seed} w={w}: lb={lb} > d={d}");
            }
        }
    }

    #[test]
    fn identical_series_zero() {
        let q = [0.5, -1.0, 1.5, -1.0];
        // candidate already normalised: mean 0, std 1
        let lb = lb_kim_hierarchy(&q, &q, 0.0, 1.0, f64::INFINITY);
        assert_eq!(lb, 0.0);
    }

    #[test]
    fn early_exit_is_partial_but_valid() {
        let q = [0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let c = [10.0, 10.0, 10.0, 10.0, 10.0, 20.0];
        // ub tiny: the hierarchy exits after the first pair but the value
        // returned must still be <= the full bound
        let part = lb_kim_hierarchy(&q, &c, 0.0, 1.0, 1e-9);
        let full = lb_kim_hierarchy(&q, &c, 0.0, 1.0, f64::INFINITY);
        assert!(part <= full);
        assert!(part > 1e-9);
    }

    #[test]
    fn short_series() {
        let q = [1.0, -1.0];
        let c = [1.0, -1.0];
        let lb = lb_kim_hierarchy(&q, &c, 0.0, 1.0, f64::INFINITY);
        assert_eq!(lb, 0.0);
    }
}
