//! Small in-tree utilities that stand in for crates unavailable in this
//! fully-offline build (DESIGN.md §4): a minimal JSON parser/printer (for
//! the artifact manifest and the serve protocol), a tiny CLI argument
//! helper, and the property-test harness used by `rust/tests/`.

pub mod cli;
pub mod json;
pub mod proptest;
