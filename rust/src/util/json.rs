//! Minimal JSON: enough to read `artifacts/manifest.json` and to speak the
//! coordinator's line-delimited protocol. Full parser for the standard
//! grammar (objects, arrays, strings with escapes, numbers, bools, null);
//! printer emits the subset we produce.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Compact printer.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    let _ = write!(out, "{}", *v as i64);
                } else {
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience object builder.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected , or }} at byte {}", self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => bail!("expected , or ] at byte {}", self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| anyhow!("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or_else(|| anyhow!("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let cp = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.i += 4;
                            // no surrogate-pair support: the manifest never
                            // contains astral characters
                            s.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape \\{} at byte {}", e as char, self.i),
                    }
                }
                _ => {
                    // collect the full UTF-8 sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    let chunk = self.b.get(start..end).ok_or_else(|| anyhow!("bad utf8"))?;
                    s.push_str(std::str::from_utf8(chunk)?);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self
            .peek()
            .is_some_and(|c| matches!(c, b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let t = r#"{"batch": 64, "lengths": [128, 256],
                    "artifacts": [{"name": "x", "file": "x.hlo.txt",
                    "inputs": [{"shape": [64, 128], "dtype": "float32"}]}]}"#;
        let v = Json::parse(t).unwrap();
        assert_eq!(v.get("batch").unwrap().as_usize(), Some(64));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("x"));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.len(), 2);
    }

    #[test]
    fn round_trips() {
        let cases = [
            r#"{"a":1,"b":[true,false,null],"c":"x\ny"}"#,
            r#"[1.5,-2,3e2]"#,
            r#""hello \"world\"""#,
            "{}",
            "[]",
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let s = v.to_string();
            assert_eq!(Json::parse(&s).unwrap(), v, "{c}");
        }
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("3.25").unwrap().as_f64(), Some(3.25));
        assert_eq!(Json::parse("-7").unwrap().as_f64(), Some(-7.0));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse(r#"{"a":1} extra"#).is_err());
    }

    #[test]
    fn builder() {
        let v = obj(vec![("x", Json::Num(1.0)), ("y", Json::Str("z".into()))]);
        assert_eq!(v.to_string(), r#"{"x":1,"y":"z"}"#);
    }
}
