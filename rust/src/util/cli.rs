//! Tiny CLI argument helper (clap is unavailable offline): positional
//! subcommand + `--key value` / `--flag` options with typed getters.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed arguments: one optional subcommand, then options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare -- not supported");
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.opts.insert(key.to_string(), it.next().expect("peeked"));
                } else {
                    out.flags.push(key.to_string());
                }
            } else if out.command.is_none() && out.positional.is_empty() {
                out.command = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse()
                .map(Some)
                .map_err(|e| anyhow!("--{name} {s:?}: {e}")),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.get_parse(name)?.unwrap_or(default))
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        Ok(self.get_parse(name)?.unwrap_or(default))
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        Ok(self.get_parse(name)?.unwrap_or(default))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("search --dataset ECG --qlen 256 --verbose");
        assert_eq!(a.command.as_deref(), Some("search"));
        assert_eq!(a.get("dataset"), Some("ECG"));
        assert_eq!(a.usize_or("qlen", 0).unwrap(), 256);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("bench --ratio=0.25");
        assert_eq!(a.f64_or("ratio", 0.0).unwrap(), 0.25);
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.usize_or("n", 42).unwrap(), 42);
        assert_eq!(a.get_or("suite", "mon"), "mon");
    }

    #[test]
    fn bad_value_errors() {
        let a = parse("x --n abc");
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse("x --fast");
        assert!(a.flag("fast"));
    }
}
