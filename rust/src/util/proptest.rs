//! Mini property-test harness (the proptest crate is unavailable offline):
//! deterministic random-case generation with failure reporting that prints
//! the seed + case so a failure is reproducible by construction. Used by
//! `rust/tests/prop_invariants.rs` on the coordinator/distance invariants.

use crate::data::rng::Rng;

/// Run `cases` random test cases. `gen` builds an input from the RNG,
/// `check` returns `Err(msg)` to fail. On failure, panics with the seed,
/// case index and the input's `Debug` form.
pub fn run_prop<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut check: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property {name:?} failed (seed={seed}, case={case}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Random series of length in [lo, hi], values ~ N(0,1).
pub fn arb_series(rng: &mut Rng, lo: usize, hi: usize) -> Vec<f64> {
    let n = lo + (rng.below((hi - lo + 1) as u64) as usize);
    (0..n).map(|_| rng.normal()).collect()
}

/// Random window in [0, n].
pub fn arb_window(rng: &mut Rng, n: usize) -> usize {
    rng.below((n + 1) as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_prop("trivial", 1, 50, |r| r.uniform(), |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property \"fails\"")]
    fn failing_property_reports() {
        run_prop("fails", 2, 10, |r| r.uniform(), |v| {
            if *v < 2.0 {
                Err(format!("{v} < 2"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn arb_series_in_bounds() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let s = arb_series(&mut rng, 2, 10);
            assert!((2..=10).contains(&s.len()));
        }
    }
}
