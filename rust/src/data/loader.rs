//! Plain-text series I/O — the UCR suite's format: whitespace/newline
//! separated floats. Lets users run the engine on their own recordings and
//! lets `repro gen-data` materialise the synthetic datasets for
//! inspection.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Read a series from a text file of whitespace-separated floats.
pub fn read_series(path: &Path) -> anyhow::Result<Vec<f64>> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?;
    let mut out = Vec::new();
    for (ln, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        for tok in line.split_whitespace() {
            let v: f64 = tok
                .parse()
                .map_err(|e| anyhow::anyhow!("{}:{}: bad float {tok:?}: {e}", path.display(), ln + 1))?;
            out.push(v);
        }
    }
    Ok(out)
}

/// Write a series as one float per line (UCR convention).
pub fn write_series(path: &Path, s: &[f64]) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)
        .map_err(|e| anyhow::anyhow!("create {}: {e}", path.display()))?;
    let mut w = BufWriter::new(f);
    for v in s {
        writeln!(w, "{v}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let dir = std::env::temp_dir().join("repro_loader_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("series.txt");
        let s = vec![1.5, -2.25, 0.0, 3.125e-3];
        write_series(&p, &s).unwrap();
        let r = read_series(&p).unwrap();
        assert_eq!(r, s);
    }

    #[test]
    fn whitespace_separated() {
        let dir = std::env::temp_dir().join("repro_loader_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("ws.txt");
        std::fs::write(&p, "1 2 3\n4\t5\n").unwrap();
        assert_eq!(read_series(&p).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn bad_float_errors() {
        let dir = std::env::temp_dir().join("repro_loader_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.txt");
        std::fs::write(&p, "1 two 3").unwrap();
        assert!(read_series(&p).is_err());
    }

    #[test]
    fn missing_file_errors() {
        assert!(read_series(Path::new("/nonexistent/xyz.txt")).is_err());
    }
}
