//! REFIT stand-in [12]: household electrical load — a small base load with
//! stepwise appliance activations (square pulses of assorted magnitudes
//! and durations), compressor cycling, and rare high spikes. Flat segments
//! + abrupt steps defeat envelope-based lower bounds, which is exactly why
//! the paper singles REFIT out in §5.

use crate::data::rng::Rng;

pub fn generate(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0x2EF17);
    let mut out = Vec::with_capacity(len);
    let base = rng.range(60.0, 100.0);
    // up to 4 concurrent appliances
    let mut level = [0.0f64; 4];
    let mut left = [0i64; 4];
    let mut fridge_on = false;
    let mut fridge_left = rng.below(500) as i64 + 200;
    for _ in 0..len {
        // fridge compressor duty cycle
        fridge_left -= 1;
        if fridge_left <= 0 {
            fridge_on = !fridge_on;
            fridge_left = if fridge_on {
                rng.below(600) as i64 + 300
            } else {
                rng.below(1200) as i64 + 600
            };
        }
        // appliance events
        for k in 0..4 {
            if left[k] > 0 {
                left[k] -= 1;
                if left[k] == 0 {
                    level[k] = 0.0;
                }
            } else if rng.chance(0.0004) {
                // kettle/oven/washer: big steps, varied duration
                level[k] = match rng.below(3) {
                    0 => rng.range(1800.0, 3000.0), // kettle
                    1 => rng.range(700.0, 1200.0),  // oven element
                    _ => rng.range(300.0, 600.0),   // washer
                };
                left[k] = rng.below(400) as i64 + 40;
            }
        }
        let fridge = if fridge_on { 120.0 } else { 0.0 };
        let spike = if rng.chance(0.0002) { rng.range(2000.0, 4000.0) } else { 0.0 };
        let v = base + fridge + level.iter().sum::<f64>() + spike + 3.0 * rng.normal();
        out.push(v.max(0.0));
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn stepwise_heavy_tail() {
        let s = super::generate(50_000, 9);
        let mean = s.iter().sum::<f64>() / s.len() as f64;
        let mx = s.iter().cloned().fold(0.0f64, f64::max);
        assert!(mx > 5.0 * mean, "no spikes: max={mx} mean={mean}");
        // most of the time near base load (flat-ish segments)
        let below = s.iter().filter(|&&v| v < 2.0 * mean).count();
        assert!(below as f64 / s.len() as f64 > 0.5);
    }
}
