//! Soccer stand-in: player-speed traces from the MMSys'14 position dataset
//! [13] — a mean-reverting (Ornstein-Uhlenbeck-like) base speed with
//! occasional sprint bursts and rests, non-negative.

use crate::data::rng::Rng;

pub fn generate(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0x50CC);
    let mut out = Vec::with_capacity(len);
    let mut v = 2.0f64; // jogging speed m/s
    let mut sprint_left = 0i64;
    for _ in 0..len {
        if sprint_left > 0 {
            sprint_left -= 1;
            v += 0.25 * (7.5 - v) + 0.15 * rng.normal();
        } else {
            // mean-revert to jog, sometimes rest
            v += 0.05 * (2.2 - v) + 0.12 * rng.normal();
            if rng.chance(0.002) {
                sprint_left = rng.below(80) as i64 + 20;
            }
            if rng.chance(0.001) {
                v *= 0.3; // sudden stop
            }
        }
        v = v.max(0.0);
        out.push(v);
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn non_negative_and_bursty() {
        let s = super::generate(20_000, 3);
        assert!(s.iter().all(|&x| x >= 0.0));
        let mx = s.iter().cloned().fold(0.0f64, f64::max);
        assert!(mx > 5.0, "no sprints reached: max={mx}");
        let mean = s.iter().sum::<f64>() / s.len() as f64;
        assert!(mean > 1.0 && mean < 4.0, "mean speed {mean}");
    }
}
