//! Freezing-of-Gait stand-in: ankle-accelerometer-like walking oscillation
//! (~1 Hz stride at 64 Hz sampling) whose amplitude collapses during
//! "freeze" episodes, replaced by low-amplitude trembling at 6–8 Hz — the
//! signature the FoG dataset [1] was collected to capture.

use crate::data::rng::Rng;

pub fn generate(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0xF06);
    let mut out = Vec::with_capacity(len);
    let mut phase = 0.0f64;
    let mut stride_freq = rng.range(0.9, 1.3) / 64.0; // cycles per sample
    let mut amp = rng.range(0.8, 1.2);
    let mut frozen = false;
    let mut regime_left = rng.below(2000) as i64 + 500;
    for _ in 0..len {
        regime_left -= 1;
        if regime_left <= 0 {
            frozen = !frozen;
            regime_left = if frozen {
                rng.below(400) as i64 + 100 // freezes are short
            } else {
                rng.below(3000) as i64 + 800
            };
            stride_freq = rng.range(0.9, 1.3) / 64.0;
            amp = rng.range(0.8, 1.2);
        }
        let v = if frozen {
            // trembling: 6-8 Hz, low amplitude
            phase += rng.range(6.0, 8.0) / 64.0;
            0.15 * amp * (2.0 * std::f64::consts::PI * phase).sin()
        } else {
            phase += stride_freq;
            let base = (2.0 * std::f64::consts::PI * phase).sin();
            // heel-strike harmonic
            let h = 0.35 * (4.0 * std::f64::consts::PI * phase).sin();
            amp * (base + h)
        };
        out.push(v + 0.05 * rng.normal());
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn has_bursty_structure() {
        let s = super::generate(10_000, 1);
        // rolling std should vary strongly (walk vs freeze)
        let win = 500;
        let stds: Vec<f64> = (0..s.len() - win)
            .step_by(win)
            .map(|i| crate::norm::znorm::stats(&s[i..i + win]).1)
            .collect();
        let mx = stds.iter().cloned().fold(0.0f64, f64::max);
        let mn = stds.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(mx / mn > 2.0, "no freeze/walk contrast: {mn}..{mx}");
    }
}
