//! MIT-BIH ECG stand-in [3, 10]: a periodic PQRST beat template with RR
//! interval jitter, baseline wander, and occasional arrhythmic events
//! (premature beats with distorted morphology) — the mix that makes ECG
//! similarity search both highly prunable (periodicity) and occasionally
//! hard (ectopic beats).

use crate::data::rng::Rng;

/// One PQRST complex sampled at `t` in [0,1): sum of Gaussians.
#[inline]
fn beat(t: f64, qrs_amp: f64) -> f64 {
    let g = |mu: f64, sig: f64, a: f64| a * (-((t - mu) * (t - mu)) / (2.0 * sig * sig)).exp();
    g(0.15, 0.03, 0.12)            // P
        + g(0.28, 0.012, -0.18)    // Q
        + g(0.31, 0.015, qrs_amp)  // R
        + g(0.34, 0.012, -0.25)    // S
        + g(0.55, 0.06, 0.30)      // T
}

pub fn generate(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0xEC6);
    let mut out = Vec::with_capacity(len);
    let mut t_in_beat = 0.0f64;
    let mut rr = rng.range(180.0, 220.0); // samples per beat (~72 bpm @ 250 Hz)
    let mut qrs = rng.range(0.9, 1.1);
    let mut wander_phase = 0.0f64;
    for _ in 0..len {
        t_in_beat += 1.0 / rr;
        if t_in_beat >= 1.0 {
            t_in_beat -= 1.0;
            // next beat's RR and morphology
            if rng.chance(0.03) {
                rr = rng.range(120.0, 150.0); // premature
                qrs = rng.range(1.4, 1.8); // wide/tall
            } else {
                rr = rng.range(185.0, 215.0);
                qrs = rng.range(0.9, 1.1);
            }
        }
        wander_phase += 0.002;
        let wander = 0.05 * (2.0 * std::f64::consts::PI * wander_phase).sin();
        out.push(beat(t_in_beat, qrs) + wander + 0.01 * rng.normal());
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn periodic_with_r_peaks() {
        let s = super::generate(8_000, 11);
        let mx = s.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(mx > 0.7, "no R peaks: max={mx}");
        // count threshold crossings ~ beats: 8000 samples / ~200 rr ≈ 40
        let mut beats = 0;
        let mut above = false;
        for &v in &s {
            if v > 0.5 && !above {
                beats += 1;
                above = true;
            } else if v < 0.2 {
                above = false;
            }
        }
        assert!((25..=70).contains(&beats), "beats={beats}");
    }
}
