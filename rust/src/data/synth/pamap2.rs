//! PAMAP2 stand-in: wrist-IMU magnitude during scripted activities [15] —
//! long regimes (walking, cycling, ironing, lying...) each with its own
//! fundamental frequency, harmonic mix and noise floor, switching at
//! activity boundaries.

use crate::data::rng::Rng;

pub fn generate(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0x9A3A92);
    let mut out = Vec::with_capacity(len);
    let mut phase = 0.0f64;
    // regime parameters
    let mut freq = 0.02;
    let mut amp = 1.0;
    let mut harm = 0.3;
    let mut offset = 0.0;
    let mut noise = 0.1;
    let mut left = 0i64;
    for _ in 0..len {
        left -= 1;
        if left <= 0 {
            left = rng.below(6000) as i64 + 2000; // long activities
            match rng.below(4) {
                0 => {
                    // walking: 1.8 Hz-ish, strong harmonic
                    freq = rng.range(0.025, 0.035);
                    amp = rng.range(0.9, 1.3);
                    harm = 0.5;
                    offset = 1.0;
                    noise = 0.12;
                }
                1 => {
                    // cycling: smooth, faster
                    freq = rng.range(0.04, 0.055);
                    amp = rng.range(0.5, 0.8);
                    harm = 0.1;
                    offset = 0.8;
                    noise = 0.06;
                }
                2 => {
                    // housework: irregular, mid amplitude
                    freq = rng.range(0.01, 0.02);
                    amp = rng.range(0.4, 0.9);
                    harm = 0.8;
                    offset = 0.9;
                    noise = 0.25;
                }
                _ => {
                    // lying/sitting: flat with breathing ripple
                    freq = rng.range(0.004, 0.006);
                    amp = rng.range(0.05, 0.12);
                    harm = 0.0;
                    offset = 0.2;
                    noise = 0.03;
                }
            }
        }
        phase += freq;
        let tau = 2.0 * std::f64::consts::PI * phase;
        let v = offset + amp * (tau.sin() + harm * (2.0 * tau).sin()) + noise * rng.normal();
        out.push(v);
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn regime_switching_visible() {
        let s = super::generate(30_000, 5);
        let win = 2000;
        let means: Vec<f64> = (0..s.len() - win)
            .step_by(win)
            .map(|i| s[i..i + win].iter().sum::<f64>() / win as f64)
            .collect();
        let mx = means.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mn = means.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(mx - mn > 0.3, "regimes indistinct: {mn}..{mx}");
    }
}
