//! Synthetic generators for the six evaluation datasets (DESIGN.md §4).
//!
//! Each generator targets the statistical regime that shapes how well
//! lower bounds and in-DTW pruning work on the real recording it stands in
//! for: smooth quasi-periodic signals (PPG, ECG) give tight envelopes and
//! heavy LB pruning; spiky, stepwise loads (REFIT) defeat envelopes and
//! push work into the DTW core — matching the paper's observation that
//! REFIT behaves differently from every other dataset (§5).
//!
//! All generators share the contract: `generate(len, seed) -> Vec<f64>`,
//! deterministic in `(len, seed)`, finite, non-degenerate.

pub mod ecg;
pub mod fog;
pub mod pamap2;
pub mod ppg;
pub mod refit;
pub mod soccer;
