//! PPG stand-in [4]: photoplethysmogram — smooth quasi-periodic pulses
//! (systolic peak + dicrotic notch) with slow heart-rate drift, respiratory
//! amplitude modulation and motion artefacts. The smoothest of the six —
//! the paper reports the largest UCR-MON speedup (9.72×) here.

use crate::data::rng::Rng;

/// One pulse at phase `t` in [0,1): systolic peak + dicrotic bump.
#[inline]
fn pulse(t: f64) -> f64 {
    let g = |mu: f64, sig: f64, a: f64| a * (-((t - mu) * (t - mu)) / (2.0 * sig * sig)).exp();
    g(0.25, 0.09, 1.0) + g(0.55, 0.12, 0.35)
}

pub fn generate(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0x9996);
    let mut out = Vec::with_capacity(len);
    let mut phase = 0.0f64;
    let mut hr = rng.range(55.0, 75.0); // bpm, drifts slowly
    let mut resp_phase = 0.0f64;
    let mut artefact_left = 0i64;
    let fs = 64.0; // Hz
    for _ in 0..len {
        // slow heart-rate drift
        hr += 0.002 * rng.normal();
        hr = hr.clamp(45.0, 110.0);
        phase += hr / 60.0 / fs;
        if phase >= 1.0 {
            phase -= 1.0;
        }
        resp_phase += 0.25 / fs; // ~15 breaths/min
        let resp = 1.0 + 0.15 * (2.0 * std::f64::consts::PI * resp_phase).sin();
        let mut v = resp * pulse(phase) + 0.01 * rng.normal();
        if artefact_left > 0 {
            artefact_left -= 1;
            v += 0.8 * rng.normal(); // motion artefact burst
        } else if rng.chance(0.0005) {
            artefact_left = rng.below(100) as i64 + 20;
        }
        out.push(v);
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn smooth_quasi_periodic() {
        let s = super::generate(10_000, 13);
        // smoothness: mean |first difference| well below signal std
        let diffs: f64 =
            s.windows(2).map(|w| (w[1] - w[0]).abs()).sum::<f64>() / (s.len() - 1) as f64;
        let (_, std) = crate::norm::znorm::stats(&s);
        assert!(diffs < 0.5 * std, "not smooth: d={diffs} std={std}");
    }
}
