//! Deterministic, dependency-free RNG (SplitMix64 core) with the handful
//! of draws the generators need. Seeded runs are reproducible across
//! platforms — a requirement for the experiment grid.

/// SplitMix64 — tiny, fast, good enough for synthetic data (not crypto).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// cached second Box-Muller draw
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n) (n > 0).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // modulo bias is irrelevant for synthetic data
        self.next_u64() % n
    }

    /// Standard normal (Box-Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        let (mut u1, u2) = (self.uniform(), self.uniform());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * th.sin());
        r * th.cos()
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sum2 += v * v;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
