//! Datasets (system S11): synthetic stand-ins for the paper's six sensor
//! datasets, a plain-text loader, and the query-extraction protocol of the
//! UCR-USP evaluation.
//!
//! The real recordings (FoG, Soccer, PAMAP2, MIT-BIH ECG, REFIT, PPG) are
//! licence/size-gated here; the generators reproduce the *statistical
//! regimes* that drive pruning behaviour — periodicity, spikiness,
//! regime-switching, self-similarity (DESIGN.md §4). Queries are noisy
//! excerpts of the reference, as in the paper's setup.

pub mod loader;
pub mod rng;
pub mod synth;

use rng::Rng;

/// The six datasets of the paper's evaluation (§5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Dataset {
    /// Freezing of Gait — bursty walking oscillation with freeze episodes
    FoG,
    /// Soccer player speed — mean-reverting walk with sprint bursts
    Soccer,
    /// PAMAP2 activity monitoring — regime-switching periodic patterns
    Pamap2,
    /// MIT-BIH ECG — periodic beats with RR jitter and arrhythmic events
    Ecg,
    /// REFIT electrical load — stepwise appliance loads with spikes
    Refit,
    /// Photoplethysmography — smooth quasi-periodic pulse waves
    Ppg,
}

impl Dataset {
    pub const ALL: [Dataset; 6] = [
        Dataset::FoG,
        Dataset::Soccer,
        Dataset::Pamap2,
        Dataset::Ecg,
        Dataset::Refit,
        Dataset::Ppg,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Dataset::FoG => "FoG",
            Dataset::Soccer => "Soccer",
            Dataset::Pamap2 => "PAMAP2",
            Dataset::Ecg => "ECG",
            Dataset::Refit => "REFIT",
            Dataset::Ppg => "PPG",
        }
    }

    pub fn from_name(s: &str) -> Option<Dataset> {
        Dataset::ALL.iter().copied().find(|d| d.name().eq_ignore_ascii_case(s))
    }

    /// Generate a reference stream of `len` points.
    pub fn generate(&self, len: usize, seed: u64) -> Vec<f64> {
        match self {
            Dataset::FoG => synth::fog::generate(len, seed),
            Dataset::Soccer => synth::soccer::generate(len, seed),
            Dataset::Pamap2 => synth::pamap2::generate(len, seed),
            Dataset::Ecg => synth::ecg::generate(len, seed),
            Dataset::Refit => synth::refit::generate(len, seed),
            Dataset::Ppg => synth::ppg::generate(len, seed),
        }
    }
}

/// Extract `count` queries of length `qlen` from `reference` following the
/// UCR-USP protocol: excerpts at random positions, perturbed with Gaussian
/// noise of `noise` × the excerpt's std so the best match is non-trivial
/// but findable.
pub fn extract_queries(
    reference: &[f64],
    count: usize,
    qlen: usize,
    noise: f64,
    seed: u64,
) -> Vec<Vec<f64>> {
    assert!(reference.len() > qlen, "reference shorter than query");
    let mut rng = Rng::new(seed ^ 0x9E3779B97F4A7C15);
    (0..count)
        .map(|_| {
            let pos = rng.below((reference.len() - qlen) as u64) as usize;
            let ex = &reference[pos..pos + qlen];
            let (_, std) = crate::norm::znorm::stats(ex);
            let s = if std > 0.0 { std } else { 1.0 };
            ex.iter().map(|&x| x + rng.normal() * noise * s).collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_datasets_generate_and_are_deterministic() {
        for d in Dataset::ALL {
            let a = d.generate(2048, 42);
            let b = d.generate(2048, 42);
            assert_eq!(a.len(), 2048);
            assert_eq!(a, b, "{} must be deterministic", d.name());
            let c = d.generate(2048, 43);
            assert_ne!(a, c, "{} must vary with seed", d.name());
            assert!(a.iter().all(|v| v.is_finite()), "{}", d.name());
            // non-degenerate: some variance
            let (_, std) = crate::norm::znorm::stats(&a);
            assert!(std > 1e-6, "{} is flat", d.name());
        }
    }

    #[test]
    fn names_round_trip() {
        for d in Dataset::ALL {
            assert_eq!(Dataset::from_name(d.name()), Some(d));
        }
        assert_eq!(Dataset::from_name("ecg"), Some(Dataset::Ecg));
        assert_eq!(Dataset::from_name("nope"), None);
    }

    #[test]
    fn queries_are_near_their_source() {
        let r = Dataset::Ecg.generate(8192, 7);
        let qs = extract_queries(&r, 5, 256, 0.05, 7);
        assert_eq!(qs.len(), 5);
        for q in &qs {
            assert_eq!(q.len(), 256);
            assert!(q.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    #[should_panic]
    fn query_longer_than_reference_panics() {
        let r = vec![0.0; 10];
        extract_queries(&r, 1, 20, 0.0, 1);
    }
}
