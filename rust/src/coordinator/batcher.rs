//! The batched XLA prefilter path (suite `UcrMonXla`): candidate windows
//! stream through the AOT-compiled znorm→LB_Keogh graph in panels of
//! `batch` (Layer 1/2 work), and only survivors reach the scalar
//! EAPrunedDTW core.
//!
//! This is the TPU-shaped inversion of the paper's insight (DESIGN.md
//! §Hardware-Adaptation): prune *across* candidates in a vector unit, then
//! prune *within* the survivors' DP matrices in scalar code.
//!
//! The XLA graphs run in f32 while the scalar core is f64, so bounds are
//! deflated by [`F32_SAFETY`] before being compared against the
//! best-so-far — a pruned candidate is then pruned with margin, never
//! wrongly (verified against the scalar suites in `integration_runtime`).

use anyhow::Result;

use crate::bounds::envelope::envelopes;
use crate::metrics::Counters;
use crate::norm::znorm::{znorm, znorm_point, stats};
use crate::runtime::XlaEngine;
use crate::search::subsequence::Match;
use crate::search::suite::Suite;
use crate::distances::DtwWorkspace;

/// Relative deflation applied to f32 bounds before pruning decisions.
pub const F32_SAFETY: f64 = 1e-3;

/// Search `reference` for `query_raw` with the XLA prefilter + scalar
/// EAPrunedDTW verification. `w` in cells. The query length must be one of
/// the AOT-lowered lengths (`manifest.lengths`).
pub fn xla_search(
    engine: &mut XlaEngine,
    reference: &[f64],
    query_raw: &[f64],
    w: usize,
    counters: &mut Counters,
) -> Result<Match> {
    let n = query_raw.len();
    anyhow::ensure!(
        engine.manifest().supports_length(n),
        "query length {n} not in AOT artifact set {:?} — regenerate with \
         `python -m compile.aot --lengths ... {n}`",
        engine.manifest().lengths
    );
    anyhow::ensure!(reference.len() >= n, "reference shorter than query");
    let b = engine.batch();
    let q = znorm(query_raw);
    let (u, l) = envelopes(&q, w);
    let u32v: Vec<f32> = u.iter().map(|&v| v as f32).collect();
    let l32v: Vec<f32> = l.iter().map(|&v| v as f32).collect();
    let total = reference.len() - n + 1;

    let mut bsf = f64::INFINITY;
    let mut best = Match { pos: 0, dist: f64::INFINITY };
    let mut ws = DtwWorkspace::with_capacity(n);
    let mut panel = vec![0f32; b * n];
    let mut zbuf = vec![0f64; n];

    let mut pos = 0usize;
    while pos < total {
        let count = (total - pos).min(b);
        // pack `count` consecutive raw windows; pad the tail panel by
        // repeating the last window (its result is simply ignored)
        for k in 0..b {
            let p = pos + k.min(count - 1);
            for (j, v) in reference[p..p + n].iter().enumerate() {
                panel[k * n + j] = *v as f32;
            }
        }
        let bounds = engine.prefilter(n, &u32v, &l32v, &panel)?;
        for k in 0..count {
            counters.candidates += 1;
            let lb = bounds[k] as f64 * (1.0 - F32_SAFETY);
            if lb > bsf {
                counters.xla_prunes += 1;
                continue;
            }
            // scalar verify (f64 exactness)
            let p = pos + k;
            let window = &reference[p..p + n];
            let (mean, std) = stats(window);
            zbuf.clear();
            zbuf.extend(window.iter().map(|&x| znorm_point(x, mean, std)));
            counters.dtw_calls += 1;
            let d = Suite::UcrMonXla.dtw(&q, &zbuf, w, bsf, None, &mut ws);
            if d.is_infinite() {
                counters.dtw_abandons += 1;
            } else if d < bsf {
                bsf = d;
                best = Match { pos: p, dist: d };
                counters.ub_updates += 1;
            }
        }
        pos += count;
    }
    anyhow::ensure!(best.dist.is_finite(), "no match found (empty scan?)");
    Ok(best)
}

/// Ablation A3: resolve *everything* on the XLA side — prefilter + batched
/// wavefront DTW per panel, no scalar DP at all. Exact in f32; used to
/// quantify what the scalar EAP core buys over brute-force batching.
pub fn xla_search_full(
    engine: &mut XlaEngine,
    reference: &[f64],
    query_raw: &[f64],
    w: usize,
    counters: &mut Counters,
) -> Result<Match> {
    let n = query_raw.len();
    anyhow::ensure!(
        engine.manifest().supports_length(n),
        "query length {n} not in AOT artifact set"
    );
    let b = engine.batch();
    let q = znorm(query_raw);
    let (u, l) = envelopes(&q, w);
    let q32: Vec<f32> = q.iter().map(|&v| v as f32).collect();
    let u32v: Vec<f32> = u.iter().map(|&v| v as f32).collect();
    let l32v: Vec<f32> = l.iter().map(|&v| v as f32).collect();
    let total = reference.len() - n + 1;

    let mut best = Match { pos: 0, dist: f64::INFINITY };
    let mut panel = vec![0f32; b * n];
    let mut pos = 0usize;
    while pos < total {
        let count = (total - pos).min(b);
        for k in 0..b {
            let p = pos + k.min(count - 1);
            for (j, v) in reference[p..p + n].iter().enumerate() {
                panel[k * n + j] = *v as f32;
            }
        }
        let (_lb, dist) = engine.prefilter_verify(n, &q32, &u32v, &l32v, w, &panel)?;
        for k in 0..count {
            counters.candidates += 1;
            counters.dtw_calls += 1;
            let d = dist[k] as f64;
            if d < best.dist {
                best = Match { pos: pos + k, dist: d };
                counters.ub_updates += 1;
            }
        }
        pos += count;
    }
    anyhow::ensure!(best.dist.is_finite(), "no match found");
    Ok(best)
}
