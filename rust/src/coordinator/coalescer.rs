//! Batch-window coalescing for the serve loop: gather in-flight requests
//! into one [`crate::coordinator::Service::submit_batch`] call, flushing
//! when the window fills **or** a deadline expires — so a lone query never
//! waits indefinitely for `batch_window - 1` neighbours that may not come.
//!
//! The coalescer is deliberately clock-injected (`Instant` parameters, no
//! internal `now()` calls): the serve loop passes real arrival times, the
//! tests pass synthetic ones, and both exercise the same flush logic.

use std::time::{Duration, Instant};

use crate::coordinator::protocol::QueryRequest;

/// Gathers requests into batches of at most `window`, flushing a partial
/// batch once `deadline` has elapsed since its **first** request arrived
/// (`None` = count-only coalescing, the pre-deadline behaviour).
///
/// Each flushed batch member carries its own arrival `Instant`, so the
/// service can account the queue wait per request
/// (`Service::submit_batch_timed` → `QueryResponse::queue_ms` and the
/// `queue_wait` stage histogram).
#[derive(Debug)]
pub struct BatchCoalescer {
    window: usize,
    deadline: Option<Duration>,
    pending: Vec<(QueryRequest, Instant)>,
    /// arrival time of the oldest pending request
    opened_at: Option<Instant>,
}

impl BatchCoalescer {
    pub fn new(window: usize, deadline: Option<Duration>) -> Self {
        Self { window: window.max(1), deadline, pending: Vec::new(), opened_at: None }
    }

    /// Requests currently waiting for a flush.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Has the partial window been waiting longer than the deadline?
    /// Always false with no pending requests or no deadline configured.
    pub fn due(&self, now: Instant) -> bool {
        match (self.deadline, self.opened_at) {
            (Some(d), Some(t0)) => now.duration_since(t0) >= d,
            _ => false,
        }
    }

    /// Accept one request that arrived at `now`. Returns a batch to serve
    /// when the window filled or the deadline expired — the batch may be
    /// smaller than the window (deadline flush), down to a single query.
    pub fn push(&mut self, req: QueryRequest, now: Instant) -> Option<Vec<(QueryRequest, Instant)>> {
        if self.pending.is_empty() {
            self.opened_at = Some(now);
        }
        self.pending.push((req, now));
        if self.pending.len() >= self.window || self.due(now) {
            return self.flush();
        }
        None
    }

    /// Flush the partial window if its deadline has expired — the serve
    /// loop's idle tick, so a waiting query is answered even when no new
    /// request arrives to trigger [`BatchCoalescer::push`].
    pub fn poll(&mut self, now: Instant) -> Option<Vec<(QueryRequest, Instant)>> {
        if self.due(now) {
            self.flush()
        } else {
            None
        }
    }

    /// Unconditionally flush whatever is pending (end of input / shutdown).
    pub fn flush(&mut self) -> Option<Vec<(QueryRequest, Instant)>> {
        if self.pending.is_empty() {
            return None;
        }
        self.opened_at = None;
        Some(std::mem::take(&mut self.pending))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::metric::Metric;
    use crate::search::suite::Suite;

    fn req(id: u64) -> QueryRequest {
        QueryRequest {
            id,
            query: vec![0.0, 1.0, 2.0],
            window_ratio: 0.1,
            suite: Suite::UcrMon,
            k: 1,
            metric: Metric::Cdtw,
            deadline_ms: None,
            tenant: None,
        }
    }

    #[test]
    fn full_window_flushes_immediately() {
        let mut c = BatchCoalescer::new(2, Some(Duration::from_secs(3600)));
        let t0 = Instant::now();
        assert!(c.push(req(0), t0).is_none());
        let t1 = t0 + Duration::from_millis(2);
        let batch = c.push(req(1), t1).expect("window full");
        assert_eq!(batch.iter().map(|(r, _)| r.id).collect::<Vec<_>>(), vec![0, 1]);
        // each member keeps its own arrival time for queue accounting
        assert_eq!(batch[0].1, t0);
        assert_eq!(batch[1].1, t1);
        assert_eq!(c.pending(), 0);
    }

    #[test]
    fn deadline_flushes_partial_window() {
        let mut c = BatchCoalescer::new(8, Some(Duration::from_millis(5)));
        let t0 = Instant::now();
        assert!(c.push(req(7), t0).is_none());
        assert!(!c.due(t0));
        assert!(c.poll(t0 + Duration::from_millis(4)).is_none());
        let batch = c.poll(t0 + Duration::from_millis(5)).expect("deadline flush");
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].0.id, 7);
        assert_eq!(batch[0].1, t0, "arrival time survives the deadline flush");
        // the deadline clock restarts with the next first arrival
        let t1 = t0 + Duration::from_millis(100);
        assert!(c.push(req(8), t1).is_none());
        assert!(!c.due(t1 + Duration::from_millis(4)));
        assert!(c.due(t1 + Duration::from_millis(6)));
    }

    #[test]
    fn late_push_triggers_deadline_flush_inline() {
        let mut c = BatchCoalescer::new(8, Some(Duration::from_millis(5)));
        let t0 = Instant::now();
        assert!(c.push(req(0), t0).is_none());
        // the next arrival lands after the deadline: it joins the batch
        // and flushes it, rather than waiting for a poll
        let batch = c.push(req(1), t0 + Duration::from_millis(9)).expect("due on push");
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn no_deadline_means_count_only() {
        let mut c = BatchCoalescer::new(3, None);
        let t0 = Instant::now();
        assert!(c.push(req(0), t0).is_none());
        assert!(c.poll(t0 + Duration::from_secs(100)).is_none());
        assert!(!c.due(t0 + Duration::from_secs(100)));
        // the terminal flush still drains the tail
        let batch = c.flush().expect("tail");
        assert_eq!(batch.len(), 1);
        assert!(c.flush().is_none());
    }

    #[test]
    fn zero_deadline_serves_every_query_solo() {
        let mut c = BatchCoalescer::new(8, Some(Duration::ZERO));
        let t0 = Instant::now();
        let batch = c.push(req(0), t0).expect("immediate flush");
        assert_eq!(batch.len(), 1);
    }
}
