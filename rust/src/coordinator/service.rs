//! Service lifecycle: owns the reference stream, its shared [`RefIndex`],
//! the worker pool, and (optionally, behind the `xla` feature) a dedicated
//! **engine thread** for the XLA suite; serves [`QueryRequest`]s until
//! dropped.
//!
//! Concurrency model: `submit` can be called from many client threads; the
//! scalar suites fan out across the shard workers, sharing the index's
//! stats buckets and envelope tables read-only. The PJRT client is not
//! `Send` (Rc internals in the xla crate), so the XLA engine lives on its
//! own thread and `UcrMonXla` queries are serialised through a channel —
//! PJRT CPU already parallelises internally and the box has one core
//! anyway.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

#[cfg(feature = "xla")]
use crate::coordinator::batcher;
use crate::coordinator::protocol::{is_stats_line, ErrorResponse, QueryRequest, QueryResponse};
use crate::coordinator::router::{route_cohort_topk_obs, route_query_topk_obs};
use crate::coordinator::worker::{worker_loop, WorkItem, DEFAULT_SYNC_EVERY};
use crate::distances::metric::Metric;
use crate::index::ref_index::RefIndex;
use crate::metrics::{Counters, Timer};
use crate::obs::{DistKind, Gauge, MetricsRegistry, MetricsSnapshot, ScanObs, Stage};
#[cfg(feature = "xla")]
use crate::runtime::XlaEngine;
use crate::search::subsequence::{validate_series, window_cells, Match, ScanMode};
use crate::search::suite::Suite;

/// Service construction knobs (see also [`crate::config::ServeConfig`]).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub shards: usize,
    /// positions between shared-threshold syncs in the workers
    pub sync_every: usize,
    /// scan front-end the shard workers run; the strip-mined pipeline by
    /// default, the legacy scalar loop for A/B comparison (both return
    /// bitwise-identical matches)
    pub scan_mode: ScanMode,
    /// how many in-flight wire queries the serve loop coalesces into one
    /// [`Service::submit_batch`] call (`repro serve --batch-window`);
    /// same-shape queries inside the window form cohorts that share one
    /// strip pass over the reference. 1 = serve each query solo.
    pub batch_window: usize,
    /// milliseconds a partial batch window may wait for more in-flight
    /// queries before the serve loop flushes it anyway
    /// (`repro serve --batch-deadline-ms`; 0 = no deadline, wait for the
    /// window to fill — the pre-deadline behaviour). Consumed by the
    /// serve loop's [`crate::coordinator::BatchCoalescer`]; the service
    /// itself serves whatever batch it is handed.
    pub batch_deadline_ms: u64,
    /// artifacts directory; `None` disables the XLA suite. Ignored when
    /// the crate is built without the `xla` feature.
    pub artifacts_dir: Option<std::path::PathBuf>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            shards: 2,
            sync_every: DEFAULT_SYNC_EVERY,
            scan_mode: ScanMode::default(),
            batch_window: 1,
            batch_deadline_ms: 0,
            artifacts_dir: None,
        }
    }
}

/// A unit of work for the engine thread.
#[cfg(feature = "xla")]
struct EngineJob {
    query: Vec<f64>,
    w: usize,
    /// resolve entirely on the XLA side (ablation A3) instead of
    /// prefilter + scalar verify
    full: bool,
    reply: Sender<Result<(Match, Counters)>>,
}

/// Engine thread: owns the (non-Send) PJRT client for its whole life.
#[cfg(feature = "xla")]
fn engine_loop(
    dir: std::path::PathBuf,
    reference: Arc<Vec<f64>>,
    rx: std::sync::mpsc::Receiver<EngineJob>,
) {
    let mut engine = match XlaEngine::open(&dir) {
        Ok(e) => e,
        Err(e) => {
            // report the open failure to every client that asks
            let msg = format!("{e:#}");
            while let Ok(job) = rx.recv() {
                let _ = job.reply.send(Err(anyhow!("XLA engine unavailable: {msg}")));
            }
            return;
        }
    };
    while let Ok(job) = rx.recv() {
        let mut counters = Counters::new();
        let r = if job.full {
            batcher::xla_search_full(&mut engine, &reference, &job.query, job.w, &mut counters)
        } else {
            batcher::xla_search(&mut engine, &reference, &job.query, job.w, &mut counters)
        };
        let _ = job.reply.send(r.map(|m| (m, counters)));
    }
}

/// A running similarity-search service.
pub struct Service {
    reference: Arc<Vec<f64>>,
    index: Arc<RefIndex>,
    senders: Vec<Sender<WorkItem>>,
    handles: Vec<JoinHandle<()>>,
    #[cfg(feature = "xla")]
    engine_tx: Option<Sender<EngineJob>>,
    #[cfg(feature = "xla")]
    engine_handle: Option<JoinHandle<()>>,
    sync_every: usize,
    scan_mode: ScanMode,
    batch_window: usize,
    batch_deadline_ms: u64,
    busy: Arc<AtomicU64>,
    served: AtomicU64,
    /// sharded metrics registry: one cell per worker (handed out at spawn
    /// time), one for the service thread; merged by [`Service::metrics`]
    registry: MetricsRegistry,
}

impl Service {
    /// Spawn the worker pool (and engine thread, if artifacts are given
    /// and the `xla` feature is on) over `reference`.
    pub fn new(reference: Vec<f64>, cfg: &ServiceConfig) -> Result<Self> {
        anyhow::ensure!(cfg.shards >= 1, "need at least one shard");
        // a NaN/inf point in the reference would poison every scan's
        // bounds and heaps; reject it once at construction
        validate_series("reference", &reference)?;
        let reference = Arc::new(reference);
        let index = Arc::new(RefIndex::new(Arc::clone(&reference)));
        let busy = Arc::new(AtomicU64::new(0));
        let registry = MetricsRegistry::new(cfg.shards);
        let mut senders = Vec::new();
        let mut handles = Vec::new();
        for i in 0..cfg.shards {
            let (tx, rx) = channel::<WorkItem>();
            let busy = Arc::clone(&busy);
            let cell = registry.worker_cell(i);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("shard-{i}"))
                    .spawn(move || worker_loop(rx, busy, Some(cell)))?,
            );
            senders.push(tx);
        }
        #[cfg(feature = "xla")]
        let (engine_tx, engine_handle) = match &cfg.artifacts_dir {
            Some(dir) => {
                let (tx, rx) = channel::<EngineJob>();
                let dir = dir.clone();
                let r = Arc::clone(&reference);
                let h = std::thread::Builder::new()
                    .name("xla-engine".into())
                    .spawn(move || engine_loop(dir, r, rx))?;
                (Some(tx), Some(h))
            }
            None => (None, None),
        };
        Ok(Self {
            reference,
            index,
            senders,
            handles,
            #[cfg(feature = "xla")]
            engine_tx,
            #[cfg(feature = "xla")]
            engine_handle,
            sync_every: cfg.sync_every,
            scan_mode: cfg.scan_mode,
            batch_window: cfg.batch_window.max(1),
            batch_deadline_ms: cfg.batch_deadline_ms,
            busy,
            served: AtomicU64::new(0),
            registry,
        })
    }

    /// Convenience: open artifacts if the directory exists.
    pub fn with_optional_artifacts(reference: Vec<f64>, shards: usize, dir: &Path) -> Result<Self> {
        let cfg = ServiceConfig {
            shards,
            artifacts_dir: dir.join("manifest.json").exists().then(|| dir.to_path_buf()),
            ..Default::default()
        };
        Self::new(reference, &cfg)
    }

    pub fn reference_len(&self) -> usize {
        self.reference.len()
    }

    /// The shared reference-side index (stats buckets + envelope tables).
    pub fn index(&self) -> &Arc<RefIndex> {
        &self.index
    }

    pub fn queries_served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    #[cfg(feature = "xla")]
    pub fn has_engine(&self) -> bool {
        self.engine_tx.is_some()
    }

    #[cfg(not(feature = "xla"))]
    pub fn has_engine(&self) -> bool {
        false
    }

    #[cfg(feature = "xla")]
    fn submit_xla(&self, req: &QueryRequest, w: usize, full: bool) -> Result<(Match, Counters)> {
        let tx = self
            .engine_tx
            .as_ref()
            .ok_or_else(|| anyhow!("XLA suite requested but no artifacts loaded"))?;
        let (reply_tx, reply_rx) = channel();
        tx.send(EngineJob { query: req.query.clone(), w, full, reply: reply_tx })
            .map_err(|_| anyhow!("engine thread gone"))?;
        reply_rx.recv().map_err(|_| anyhow!("engine thread died mid-query"))?
    }

    /// Serve one request to completion (blocking): top-k over the shard
    /// workers, reference-side artifacts served by the shared index.
    pub fn submit(&self, req: &QueryRequest) -> Result<QueryResponse> {
        let timer = Timer::start();
        // in-process callers can bypass the wire parser's validation, and
        // the XLA branch below never reaches the router's check — reject
        // malformed floats for every branch here
        validate_series("query", &req.query)?;
        let w = req
            .metric
            .effective_window(req.query.len(), window_cells(req.query.len(), req.window_ratio));
        let (matches, counters) = match req.suite {
            #[cfg(feature = "xla")]
            Suite::UcrMonXla => {
                // the batched prefilter path keeps a single best-so-far
                // and its LB_Keogh prefilter is DTW-specific
                anyhow::ensure!(req.k == 1, "suite {} serves k = 1 only", req.suite.name());
                anyhow::ensure!(
                    matches!(req.metric, Metric::Cdtw),
                    "suite {} serves the cdtw metric only",
                    req.suite.name()
                );
                let (m, c) = self.submit_xla(req, w, false)?;
                (vec![m], c)
            }
            #[cfg(not(feature = "xla"))]
            Suite::UcrMonXla => anyhow::bail!(
                "suite {} unavailable: this build has the `xla` feature compiled out",
                req.suite.name()
            ),
            _ => {
                // empty / oversized queries and k = 0 error inside
                // artifacts_for and route_query_topk respectively
                let mut pre = Counters::new();
                let (stats, denv) = self.index.artifacts_for(
                    req.query.len(),
                    w,
                    req.metric,
                    req.suite,
                    &mut pre,
                )?;
                // scan counters enter the registry through the worker
                // cells; the service cell takes only the index-side
                // accounting and the fan-in stage time
                let cell = self.registry.service_cell();
                cell.flush_counters(&pre);
                let (matches, mut counters) = route_query_topk_obs(
                    &self.senders,
                    &self.reference,
                    &req.query,
                    w,
                    req.metric,
                    req.suite,
                    self.scan_mode,
                    req.k,
                    self.sync_every,
                    denv,
                    Some(stats),
                    ScanObs(Some(cell)),
                )?;
                counters.merge(&pre);
                cell.record_dist(DistKind::TopkTighten, counters.topk_updates);
                (matches, counters)
            }
        };
        self.served.fetch_add(1, Ordering::Relaxed);
        Ok(Self::make_response(req.id, matches, &counters, timer.elapsed_secs() * 1e3, 1))
    }

    /// Assemble the wire response for one answered query.
    fn make_response(
        id: u64,
        matches: Vec<Match>,
        counters: &Counters,
        latency_ms: f64,
        cohort: usize,
    ) -> QueryResponse {
        let pruned = counters.lb_kim_prunes
            + counters.lb_keogh_eq_prunes
            + counters.lb_keogh_ec_prunes
            + counters.xla_prunes;
        let best = matches[0];
        QueryResponse {
            id,
            pos: best.pos,
            dist: best.dist,
            matches,
            latency_ms,
            queue_ms: None,
            candidates: counters.candidates,
            pruned,
            dtw_calls: counters.dtw_calls,
            cohort,
        }
    }

    /// Ablation A3 entry: resolve a query entirely on the XLA side.
    /// Like [`Service::submit`] with the XLA suite, this path is
    /// cDTW-only — the batched kernels know nothing of other metrics.
    #[cfg(feature = "xla")]
    pub fn submit_xla_full(&self, req: &QueryRequest) -> Result<QueryResponse> {
        let timer = Timer::start();
        validate_series("query", &req.query)?;
        anyhow::ensure!(
            matches!(req.metric, Metric::Cdtw),
            "XLA full resolution serves the cdtw metric only"
        );
        let w = window_cells(req.query.len(), req.window_ratio);
        let (m, counters) = self.submit_xla(req, w, true)?;
        self.served.fetch_add(1, Ordering::Relaxed);
        Ok(QueryResponse {
            id: req.id,
            pos: m.pos,
            dist: m.dist,
            matches: vec![m],
            latency_ms: timer.elapsed_secs() * 1e3,
            queue_ms: None,
            candidates: counters.candidates,
            pruned: counters.xla_prunes,
            dtw_calls: counters.dtw_calls,
            cohort: 1,
        })
    }

    /// Serve a window of requests together, cohort-batching where shapes
    /// allow: requests that share *(query length, effective window,
    /// metric, suite, k)* — and can run on the strip pipeline — form
    /// cohorts served by **one strip pass** over the reference each
    /// ([`route_cohort_topk`]); everything else falls back to
    /// [`Service::submit`]. One answer per request, index-for-index with
    /// the input, each bitwise-identical to what a solo `submit` of that
    /// request would return. A request that fails (validation or
    /// execution) yields its own `Err` without affecting its neighbours.
    ///
    /// Cohort-served responses report the cohort's wall-clock time as
    /// their latency (they were answered by the same scan) and carry the
    /// cohort size in [`QueryResponse::cohort`].
    pub fn submit_batch(&self, reqs: &[QueryRequest]) -> Vec<Result<QueryResponse>> {
        let obs = ScanObs(Some(self.registry.service_cell()));
        let form_timer = obs.stage_timer(Stage::CohortForm);
        let mut out: Vec<Option<Result<QueryResponse>>> = reqs.iter().map(|_| None).collect();
        // cohort key: (qlen, effective window, metric, suite, k)
        type Key = (usize, usize, Metric, Suite, usize);
        let mut cohorts: Vec<(Key, Vec<usize>)> = Vec::new();
        let mut solos: Vec<usize> = Vec::new();
        for (i, req) in reqs.iter().enumerate() {
            let eligible = self.scan_mode == ScanMode::Strip
                && req.suite != Suite::UcrMonXla
                && req.k >= 1
                && !req.query.is_empty()
                && req.query.len() <= self.reference.len()
                && validate_series("query", &req.query).is_ok()
                && req.metric.validate().is_ok();
            if !eligible {
                // solo serving reproduces every existing error/edge path
                solos.push(i);
                continue;
            }
            let n = req.query.len();
            let w = req.metric.effective_window(n, window_cells(n, req.window_ratio));
            let key: Key = (n, w, req.metric, req.suite, req.k);
            match cohorts.iter_mut().find(|(k2, _)| *k2 == key) {
                Some((_, idxs)) => idxs.push(i),
                None => cohorts.push((key, vec![i])),
            }
        }
        // the timer covers only the grouping decision, not the serving
        form_timer.stop();
        for i in solos {
            out[i] = Some(self.submit(&reqs[i]));
        }
        for ((n, w, metric, suite, k), idxs) in cohorts {
            obs.record_dist(DistKind::CohortSize, idxs.len() as u64);
            if idxs.len() == 1 {
                let qi = idxs[0];
                out[qi] = Some(self.submit(&reqs[qi]));
                continue;
            }
            match self.submit_cohort(reqs, n, w, metric, suite, k, &idxs) {
                Ok(responses) => {
                    for (&qi, resp) in idxs.iter().zip(responses) {
                        out[qi] = Some(Ok(resp));
                    }
                }
                // a cohort-level failure (e.g. worker pool gone) fails
                // every member — there is no partial answer to salvage
                Err(e) => {
                    let msg = format!("{e:#}");
                    for &qi in &idxs {
                        out[qi] = Some(Err(anyhow!("{msg}")));
                    }
                }
            }
        }
        out.into_iter().map(|r| r.expect("every request answered")).collect()
    }

    /// [`Service::submit_batch`] for a coalesced window whose members
    /// carry their enqueue times: the wait between coalescer arrival and
    /// this call is recorded under the `queue_wait` stage and reported as
    /// [`QueryResponse::queue_ms`] on each successful response. Results
    /// are otherwise bitwise-identical to `submit_batch` — queue
    /// accounting happens strictly before serving begins.
    pub fn submit_batch_timed(
        &self,
        reqs: &[(QueryRequest, std::time::Instant)],
    ) -> Vec<Result<QueryResponse>> {
        let start = std::time::Instant::now();
        let cell = self.registry.service_cell();
        let queue_ms: Vec<f64> = reqs
            .iter()
            .map(|(_, enqueued)| {
                // saturates to zero if the caller's clock reads ahead
                let waited = start.duration_since(*enqueued);
                cell.record_stage_ns(Stage::QueueWait, waited.as_nanos() as u64);
                waited.as_secs_f64() * 1e3
            })
            .collect();
        let plain: Vec<QueryRequest> = reqs.iter().map(|(r, _)| r.clone()).collect();
        let mut out = self.submit_batch(&plain);
        for (resp, waited_ms) in out.iter_mut().zip(queue_ms) {
            if let Ok(resp) = resp {
                resp.queue_ms = Some(waited_ms);
            }
        }
        out
    }

    /// One cohort through the shared strip pass: per-member index
    /// accounting (first lookup builds, the rest hit), one
    /// [`route_cohort_topk`] fan-out, one response per member.
    #[allow(clippy::too_many_arguments)]
    fn submit_cohort(
        &self,
        reqs: &[QueryRequest],
        n: usize,
        w: usize,
        metric: Metric,
        suite: Suite,
        k: usize,
        idxs: &[usize],
    ) -> Result<Vec<QueryResponse>> {
        let timer = Timer::start();
        let cell = self.registry.service_cell();
        let mut pres = Vec::with_capacity(idxs.len());
        let mut artifacts = None;
        for _ in idxs {
            let mut pre = Counters::new();
            artifacts = Some(self.index.artifacts_for(n, w, metric, suite, &mut pre)?);
            cell.flush_counters(&pre);
            pres.push(pre);
        }
        let (stats, denv) = artifacts.expect("cohort has members");
        let queries: Vec<&[f64]> = idxs.iter().map(|&qi| reqs[qi].query.as_slice()).collect();
        let per_query = route_cohort_topk_obs(
            &self.senders,
            &self.reference,
            &queries,
            w,
            metric,
            suite,
            k,
            self.sync_every,
            denv,
            stats,
            ScanObs(Some(cell)),
        )?;
        let latency_ms = timer.elapsed_secs() * 1e3;
        self.served.fetch_add(idxs.len() as u64, Ordering::Relaxed);
        Ok(idxs
            .iter()
            .zip(per_query)
            .zip(pres)
            .map(|((&qi, (matches, mut counters)), pre)| {
                counters.merge(&pre);
                cell.record_dist(DistKind::TopkTighten, counters.topk_updates);
                Self::make_response(reqs[qi].id, matches, &counters, latency_ms, idxs.len())
            })
            .collect())
    }

    /// Workers currently scanning (for backpressure/introspection).
    pub fn busy_workers(&self) -> u64 {
        self.busy.load(Ordering::Relaxed)
    }

    /// The scan front-end this service's shard workers run.
    pub fn scan_mode(&self) -> ScanMode {
        self.scan_mode
    }

    /// How many in-flight queries the serve loop coalesces per
    /// [`Service::submit_batch`] call.
    pub fn batch_window(&self) -> usize {
        self.batch_window
    }

    /// How long a partial batch window may wait before the serve loop
    /// flushes it (`None` = wait for the window to fill).
    pub fn batch_deadline(&self) -> Option<std::time::Duration> {
        (self.batch_deadline_ms > 0)
            .then(|| std::time::Duration::from_millis(self.batch_deadline_ms))
    }

    /// Point-in-time metrics: stamp the service-level gauges, then merge
    /// every registry cell into one [`MetricsSnapshot`].
    pub fn metrics(&self) -> MetricsSnapshot {
        let cell = self.registry.service_cell();
        cell.set_gauge(Gauge::BusyWorkers, self.busy_workers());
        cell.set_gauge(Gauge::QueriesServed, self.queries_served());
        self.registry.snapshot()
    }

    /// The live-stats answer (`{"cmd":"stats"}` on the wire, or
    /// `--stats-every` emission): one compact pinned-schema JSON line.
    pub fn stats_json(&self) -> String {
        self.metrics().to_json_string()
    }

    /// Serve-loop hook: requests currently waiting in the batch
    /// coalescer (the service cannot see the coalescer itself).
    pub fn set_coalescer_pending(&self, n: u64) {
        self.registry.service_cell().set_gauge(Gauge::CoalescerPending, n);
    }

    /// Answer one wire line: `{"cmd":"stats"}` with the live registry's
    /// pinned-schema snapshot, anything else as a query request (solo —
    /// a coalescing front-end should parse and batch instead). Always
    /// returns exactly one response line; failures answer with the
    /// protocol's error line rather than tearing the session down.
    pub fn handle_line(&self, line: &str) -> String {
        if is_stats_line(line) {
            return self.stats_json();
        }
        match QueryRequest::from_json(line) {
            Ok(req) => match self.submit(&req) {
                Ok(resp) => resp.to_json(),
                Err(e) => ErrorResponse::new(req.id, &e).to_json(),
            },
            // the line never parsed: there is no request id to echo
            Err(e) => ErrorResponse::new(0, &e).to_json(),
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        // closing the channels ends the worker loops
        self.senders.clear();
        #[cfg(feature = "xla")]
        {
            self.engine_tx = None;
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        #[cfg(feature = "xla")]
        if let Some(h) = self.engine_handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::distances::metric::Metric;
    use crate::search::subsequence::{
        search_subsequence, search_subsequence_topk, search_subsequence_topk_metric,
    };

    #[test]
    fn service_matches_direct_search() {
        let r = Dataset::Ecg.generate(3000, 2);
        let q = crate::data::extract_queries(&r, 1, 128, 0.1, 3).remove(0);
        let svc = Service::new(r.clone(), &ServiceConfig { shards: 3, ..Default::default() })
            .unwrap();
        let req = QueryRequest {
            id: 1,
            query: q.clone(),
            window_ratio: 0.1,
            suite: Suite::UcrMon,
            k: 1,
            metric: Metric::Cdtw,
        };
        let resp = svc.submit(&req).unwrap();
        let mut c = Counters::new();
        let want = search_subsequence(&r, &q, window_cells(q.len(), 0.1), Suite::UcrMon, &mut c);
        assert_eq!(resp.pos, want.pos);
        assert!((resp.dist - want.dist).abs() < 1e-9);
        assert_eq!(resp.candidates, c.candidates);
        assert_eq!(resp.matches.len(), 1);
        assert_eq!(svc.queries_served(), 1);
    }

    #[test]
    fn topk_submit_matches_direct_topk() {
        let r = Dataset::Refit.generate(3000, 12);
        let q = crate::data::extract_queries(&r, 1, 128, 0.1, 13).remove(0);
        let svc = Service::new(r.clone(), &ServiceConfig { shards: 4, ..Default::default() })
            .unwrap();
        let k = 5;
        let req = QueryRequest {
            id: 9,
            query: q.clone(),
            window_ratio: 0.2,
            suite: Suite::UcrMon,
            k,
            metric: Metric::Cdtw,
        };
        let resp = svc.submit(&req).unwrap();
        let mut c = Counters::new();
        let want =
            search_subsequence_topk(&r, &q, window_cells(q.len(), 0.2), k, Suite::UcrMon, &mut c);
        assert_eq!(resp.matches.len(), k);
        for (g, m) in resp.matches.iter().zip(&want) {
            assert_eq!(g.pos, m.pos);
            assert!((g.dist - m.dist).abs() < 1e-9);
        }
        assert_eq!(resp.pos, resp.matches[0].pos);
    }

    #[test]
    fn repeated_submissions_hit_the_index() {
        let r = Dataset::Ppg.generate(2000, 6);
        let svc =
            Service::new(r.clone(), &ServiceConfig { shards: 2, ..Default::default() }).unwrap();
        let qs = crate::data::extract_queries(&r, 3, 128, 0.1, 7);
        for (i, q) in qs.into_iter().enumerate() {
            let req = QueryRequest {
                id: i as u64,
                query: q,
                window_ratio: 0.1,
                suite: Suite::UcrMon,
                k: 2,
                metric: Metric::Cdtw,
            };
            svc.submit(&req).unwrap();
        }
        let (hits, misses) = svc.index().hit_counts();
        assert_eq!(misses, 2, "stats bucket + envelopes built once");
        assert_eq!(hits, 4, "…and reused by the two later queries");
    }

    #[test]
    fn concurrent_submissions() {
        let r = Dataset::Ppg.generate(2000, 4);
        let svc = Arc::new(
            Service::new(r.clone(), &ServiceConfig { shards: 2, ..Default::default() }).unwrap(),
        );
        let qs = crate::data::extract_queries(&r, 4, 128, 0.1, 9);
        let mut handles = Vec::new();
        for (i, q) in qs.into_iter().enumerate() {
            let svc = Arc::clone(&svc);
            handles.push(std::thread::spawn(move || {
                let req = QueryRequest {
                    id: i as u64,
                    query: q,
                    window_ratio: 0.2,
                    suite: Suite::UcrMon,
                    k: 1,
                    metric: Metric::Cdtw,
                };
                svc.submit(&req).unwrap()
            }));
        }
        for h in handles {
            let resp = h.join().unwrap();
            assert!(resp.dist.is_finite());
        }
        assert_eq!(svc.queries_served(), 4);
    }

    #[test]
    fn every_metric_serves_and_matches_direct_search() {
        let r = Dataset::Pamap2.generate(1500, 14);
        let q = crate::data::extract_queries(&r, 1, 64, 0.1, 15).remove(0);
        let svc =
            Service::new(r.clone(), &ServiceConfig { shards: 2, ..Default::default() }).unwrap();
        let k = 3;
        for metric in Metric::all_default() {
            let req = QueryRequest {
                id: 0,
                query: q.clone(),
                window_ratio: 0.1,
                suite: Suite::UcrMon,
                k,
                metric,
            };
            let resp = svc.submit(&req).unwrap();
            let mut c = Counters::new();
            let want = search_subsequence_topk_metric(
                &r,
                &q,
                window_cells(q.len(), 0.1),
                k,
                metric,
                Suite::UcrMon,
                &mut c,
            );
            assert_eq!(resp.matches.len(), want.len(), "{}", metric.name());
            for (g, m) in resp.matches.iter().zip(&want) {
                assert_eq!(g.pos, m.pos, "{}", metric.name());
                assert!((g.dist - m.dist).abs() < 1e-9, "{}", metric.name());
            }
        }
    }

    #[test]
    fn scalar_and_strip_services_agree_bitwise() {
        let r = Dataset::FoG.generate(2400, 21);
        let q = crate::data::extract_queries(&r, 1, 128, 0.1, 22).remove(0);
        let req = QueryRequest {
            id: 4,
            query: q,
            window_ratio: 0.1,
            suite: Suite::UcrMon,
            k: 6,
            metric: Metric::Cdtw,
        };
        let scalar_svc = Service::new(
            r.clone(),
            &ServiceConfig { shards: 3, scan_mode: ScanMode::Scalar, ..Default::default() },
        )
        .unwrap();
        let strip_svc = Service::new(
            r,
            &ServiceConfig { shards: 3, scan_mode: ScanMode::Strip, ..Default::default() },
        )
        .unwrap();
        assert_eq!(strip_svc.scan_mode(), ScanMode::Strip);
        let a = scalar_svc.submit(&req).unwrap();
        let b = strip_svc.submit(&req).unwrap();
        assert_eq!(a.matches.len(), b.matches.len());
        for (x, y) in a.matches.iter().zip(&b.matches) {
            assert_eq!(x.pos, y.pos);
            assert_eq!(x.dist.to_bits(), y.dist.to_bits());
        }
    }

    #[test]
    fn submit_batch_cohorts_match_solo_submits_bitwise() {
        let r = Dataset::Ecg.generate(2200, 33);
        let qs = crate::data::extract_queries(&r, 4, 128, 0.1, 34);
        let svc =
            Service::new(r, &ServiceConfig { shards: 2, batch_window: 8, ..Default::default() })
                .unwrap();
        assert_eq!(svc.batch_window(), 8);
        let reqs: Vec<QueryRequest> = qs
            .into_iter()
            .enumerate()
            .map(|(i, q)| QueryRequest {
                id: i as u64,
                query: q,
                window_ratio: 0.1,
                suite: Suite::UcrMon,
                k: 3,
                metric: Metric::Cdtw,
            })
            .collect();
        let batch = svc.submit_batch(&reqs);
        assert_eq!(batch.len(), reqs.len());
        for (req, got) in reqs.iter().zip(&batch) {
            let got = got.as_ref().unwrap();
            assert_eq!(got.id, req.id, "index-for-index alignment");
            assert_eq!(got.cohort, reqs.len(), "all four share one cohort");
            let want = svc.submit(req).unwrap();
            assert_eq!(got.matches.len(), want.matches.len());
            for (x, y) in got.matches.iter().zip(&want.matches) {
                assert_eq!(x.pos, y.pos);
                assert_eq!(x.dist.to_bits(), y.dist.to_bits());
            }
        }
        // 4 cohort answers + 4 solo re-checks
        assert_eq!(svc.queries_served(), 8);
        // cohort formation and size were observed by the registry
        let snap = svc.metrics();
        assert!(snap.stages[Stage::CohortForm.index()].count() >= 1);
        assert_eq!(snap.dists[DistKind::CohortSize.index()].max, 4);
        assert!(snap.dists[DistKind::StripSurvivors.index()].count() > 0);
        // the cohort scan's own bound passes and kernel evals were timed
        assert!(snap.stages[Stage::BoundKim.index()].count() > 0);
        assert!(snap.stages[Stage::BoundKeoghEq.index()].count() > 0);
        assert!(snap.stages[Stage::KernelEval.index()].count() > 0);
    }

    #[test]
    fn deadline_flush_serves_a_single_query_batch_identically_to_solo() {
        use crate::coordinator::coalescer::BatchCoalescer;
        use std::time::{Duration, Instant};

        // a service configured with a wide batch window and a deadline:
        // one lone in-flight query must not wait for seven neighbours —
        // the coalescer flushes a 1-query batch at the deadline, and the
        // answer is bitwise what a solo submit returns
        let r = Dataset::Soccer.generate(1400, 51);
        let q = crate::data::extract_queries(&r, 1, 96, 0.1, 52).remove(0);
        let svc = Service::new(
            r,
            &ServiceConfig { batch_window: 8, batch_deadline_ms: 5, ..Default::default() },
        )
        .unwrap();
        assert_eq!(svc.batch_deadline(), Some(Duration::from_millis(5)));
        let req = QueryRequest {
            id: 77,
            query: q,
            window_ratio: 0.1,
            suite: Suite::UcrMon,
            k: 3,
            metric: Metric::Cdtw,
        };
        let mut co = BatchCoalescer::new(svc.batch_window(), svc.batch_deadline());
        let t0 = Instant::now();
        assert!(co.push(req.clone(), t0).is_none(), "window of 8 must not fill");
        // no further arrivals: the deadline, not the window, flushes
        let batch = co.poll(t0 + Duration::from_millis(6)).expect("deadline flush");
        assert_eq!(batch.len(), 1, "partial window flushed as a 1-query batch");
        let got = svc.submit_batch_timed(&batch).remove(0).unwrap();
        let want = svc.submit(&req).unwrap();
        assert_eq!(got.id, 77);
        assert_eq!(got.cohort, 1);
        assert_eq!(got.matches.len(), want.matches.len());
        for (x, y) in got.matches.iter().zip(&want.matches) {
            assert_eq!(x.pos, y.pos);
            assert_eq!(x.dist.to_bits(), y.dist.to_bits());
        }
        // the coalesced response reports its queue wait; the solo one
        // never mentions it
        assert!(got.queue_ms.is_some(), "coalesced response carries queue_ms");
        assert!(got.queue_ms.unwrap() >= 0.0);
        assert_eq!(want.queue_ms, None);
        // …and the wait landed in the queue_wait stage histogram
        let snap = svc.metrics();
        assert!(snap.stages[Stage::QueueWait.index()].count() >= 1);
        // a zero deadline means "no deadline" (count-only coalescing)
        let svc0 =
            Service::new(Dataset::Soccer.generate(300, 1), &ServiceConfig::default()).unwrap();
        assert_eq!(svc0.batch_deadline(), None);
    }

    #[test]
    fn registry_observes_serving_without_changing_results() {
        let r = Dataset::Ecg.generate(2000, 71);
        let qs = crate::data::extract_queries(&r, 3, 128, 0.1, 72);
        for mode in [ScanMode::Scalar, ScanMode::Strip] {
            let svc = Service::new(
                r.clone(),
                &ServiceConfig { shards: 2, scan_mode: mode, ..Default::default() },
            )
            .unwrap();
            for (i, q) in qs.iter().enumerate() {
                let req = QueryRequest {
                    id: i as u64,
                    query: q.clone(),
                    window_ratio: 0.1,
                    suite: Suite::UcrMon,
                    k: 3,
                    metric: Metric::Cdtw,
                };
                let resp = svc.submit(&req).unwrap();
                // the registry is always attached — results must still be
                // bitwise what the bare library search returns
                let mut c = Counters::new();
                let want = search_subsequence_topk(
                    &r,
                    q,
                    window_cells(q.len(), 0.1),
                    3,
                    Suite::UcrMon,
                    &mut c,
                );
                for (g, m) in resp.matches.iter().zip(&want) {
                    assert_eq!(g.pos, m.pos, "{mode:?}");
                    assert_eq!(g.dist.to_bits(), m.dist.to_bits(), "{mode:?}");
                }
            }
            let snap = svc.metrics();
            // scan counters flowed through the worker cells exactly once
            assert!(snap.counters.candidates > 0, "{mode:?}");
            assert_eq!(
                snap.counters.dtw_calls,
                snap.counters.dtw_abandons + snap.counters.dtw_completions,
                "{mode:?}"
            );
            // stage latencies landed for the bound cascade, the kernel,
            // and the router fan-in
            for s in [Stage::BoundKim, Stage::BoundKeoghEq, Stage::KernelEval, Stage::FanIn] {
                assert!(snap.stages[s.index()].count() > 0, "{mode:?} {}", s.name());
            }
            if mode == ScanMode::Strip {
                assert!(snap.dists[DistKind::StripSurvivors.index()].count() > 0);
            }
            // one top-k tightening observation per query served
            assert_eq!(snap.dists[DistKind::TopkTighten.index()].count(), 3, "{mode:?}");
            assert_eq!(snap.gauges[Gauge::QueriesServed.index()], 3, "{mode:?}");
            // the stats line speaks the pinned schema and round-trips
            let line = svc.stats_json();
            let back = MetricsSnapshot::from_json(
                &crate::util::json::Json::parse(&line).unwrap(),
            )
            .unwrap();
            assert_eq!(back.counters.candidates, snap.counters.candidates, "{mode:?}");
            assert_eq!(
                back.stages[Stage::KernelEval.index()].count(),
                snap.stages[Stage::KernelEval.index()].count(),
                "{mode:?}"
            );
        }
    }

    #[test]
    fn submit_batch_mixes_cohorts_solos_and_errors() {
        let r = Dataset::Ppg.generate(1500, 41);
        let svc = Service::new(r.clone(), &ServiceConfig::default()).unwrap();
        let qs = crate::data::extract_queries(&r, 2, 96, 0.1, 42);
        let mk = |id: u64, query: Vec<f64>, k: usize| QueryRequest {
            id,
            query,
            window_ratio: 0.1,
            suite: Suite::UcrMon,
            k,
            metric: Metric::Cdtw,
        };
        let mut bad = qs[0].clone();
        bad[5] = f64::NAN;
        let reqs = vec![
            mk(0, qs[0].clone(), 2),                    // cohort A
            mk(1, bad, 2),                              // invalid: solo error
            mk(2, qs[1].clone(), 2),                    // cohort A
            mk(3, qs[0][..64].to_vec(), 2),             // different length: solo
        ];
        let got = svc.submit_batch(&reqs);
        assert_eq!(got.len(), 4);
        let a = got[0].as_ref().unwrap();
        let c = got[2].as_ref().unwrap();
        assert_eq!(a.cohort, 2);
        assert_eq!(c.cohort, 2);
        assert_eq!(a.id, 0);
        assert_eq!(c.id, 2);
        let err = got[1].as_ref().unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        let solo = got[3].as_ref().unwrap();
        assert_eq!(solo.cohort, 1);
        // the bad request did not poison its neighbours: spot-check one
        let want = svc.submit(&reqs[2]).unwrap();
        assert_eq!(c.pos, want.pos);
        assert_eq!(c.dist.to_bits(), want.dist.to_bits());
    }

    #[test]
    fn handle_line_serves_queries_and_answers_stats_from_the_live_registry() {
        use crate::util::json::Json;
        let r = Dataset::Ecg.generate(1200, 81);
        let q = crate::data::extract_queries(&r, 1, 96, 0.1, 82).remove(0);
        let svc = Service::new(r, &ServiceConfig::default()).unwrap();
        // a fresh service answers stats with an all-zero snapshot
        let before =
            MetricsSnapshot::from_json(&Json::parse(&svc.handle_line(r#"{"cmd":"stats"}"#)).unwrap())
                .unwrap();
        assert_eq!(before.counters.candidates, 0);
        // serve one query over the wire
        let req = QueryRequest {
            id: 5,
            query: q,
            window_ratio: 0.1,
            suite: Suite::UcrMon,
            k: 2,
            metric: Metric::Cdtw,
        };
        let resp = QueryResponse::from_json(&svc.handle_line(&req.to_json())).unwrap();
        assert_eq!(resp.id, 5);
        assert_eq!(resp.matches.len(), 2);
        // …and the stats line now reflects it
        let after =
            MetricsSnapshot::from_json(&Json::parse(&svc.handle_line(r#"{"cmd":"stats"}"#)).unwrap())
                .unwrap();
        assert_eq!(after.counters.candidates, resp.candidates);
        assert_eq!(after.gauges[Gauge::QueriesServed.index()], 1);
        // junk lines answer with the protocol's error line, not a panic
        let err = svc.handle_line("not json at all");
        assert!(crate::coordinator::protocol::ErrorResponse::is_error_line(&err), "{err}");
    }

    #[test]
    fn non_finite_inputs_error_instead_of_panicking_workers() {
        // NaN reference: rejected at construction
        let mut r = Dataset::Ecg.generate(600, 9);
        r[17] = f64::NAN;
        assert!(Service::new(r, &ServiceConfig::default()).is_err());
        // NaN / inf query: a graceful error from submit, and the service
        // keeps serving afterwards
        let r = Dataset::Ecg.generate(600, 9);
        let svc = Service::new(r.clone(), &ServiceConfig::default()).unwrap();
        for bad in [f64::NAN, f64::INFINITY] {
            let mut q = crate::data::extract_queries(&r, 1, 64, 0.1, 10).remove(0);
            q[3] = bad;
            let req = QueryRequest {
                id: 1,
                query: q,
                window_ratio: 0.1,
                suite: Suite::UcrMon,
                k: 1,
                metric: Metric::Cdtw,
            };
            let err = svc.submit(&req).unwrap_err();
            assert!(err.to_string().contains("non-finite"), "{err}");
        }
        let good = QueryRequest {
            id: 2,
            query: crate::data::extract_queries(&r, 1, 64, 0.1, 10).remove(0),
            window_ratio: 0.1,
            suite: Suite::UcrMon,
            k: 1,
            metric: Metric::Cdtw,
        };
        assert!(svc.submit(&good).is_ok());
    }

    #[test]
    fn xla_without_artifacts_errors() {
        let r = Dataset::Ecg.generate(1000, 5);
        let svc = Service::new(r.clone(), &ServiceConfig::default()).unwrap();
        let q = crate::data::extract_queries(&r, 1, 128, 0.1, 6).remove(0);
        let req = QueryRequest {
            id: 1,
            query: q,
            window_ratio: 0.1,
            suite: Suite::UcrMonXla,
            k: 1,
            metric: Metric::Cdtw,
        };
        assert!(svc.submit(&req).is_err());
        assert!(!svc.has_engine());
    }

    #[cfg(feature = "xla")]
    #[test]
    fn bad_artifacts_dir_reports_through_channel() {
        let r = Dataset::Ecg.generate(1000, 5);
        let svc = Service::new(
            r,
            &ServiceConfig {
                artifacts_dir: Some("/no/such/dir".into()),
                ..Default::default()
            },
        )
        .unwrap();
        let req = QueryRequest {
            id: 1,
            query: vec![0.0; 128],
            window_ratio: 0.1,
            suite: Suite::UcrMonXla,
            k: 1,
            metric: Metric::Cdtw,
        };
        let err = svc.submit(&req).unwrap_err();
        assert!(err.to_string().contains("unavailable"), "{err}");
    }
}
