//! Service lifecycle: owns the reference stream, its shared [`RefIndex`],
//! the worker pool, and (optionally, behind the `xla` feature) a dedicated
//! **engine thread** for the XLA suite; serves [`QueryRequest`]s until
//! dropped.
//!
//! Concurrency model: `submit` can be called from many client threads; the
//! scalar suites fan out across the shard workers, sharing the index's
//! stats buckets and envelope tables read-only. The PJRT client is not
//! `Send` (Rc internals in the xla crate), so the XLA engine lives on its
//! own thread and `UcrMonXla` queries are serialised through a channel —
//! PJRT CPU already parallelises internally and the box has one core
//! anyway.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

#[cfg(feature = "xla")]
use crate::coordinator::batcher;
use crate::coordinator::protocol::{
    is_stats_line, DeadlineExceeded, ErrorResponse, Overloaded, QueryRequest, QueryResponse,
    WorkerLost,
};
use crate::coordinator::router::{route_cohort_topk_obs, route_query_topk_obs};
use crate::coordinator::worker::{worker_loop, WorkItem, DEFAULT_SYNC_EVERY};
use crate::distances::metric::Metric;
use crate::index::ref_index::RefIndex;
use crate::metrics::{Counters, Timer};
use crate::obs::{DistKind, Gauge, MetricsRegistry, MetricsSnapshot, ScanObs, Stage};
#[cfg(feature = "xla")]
use crate::runtime::XlaEngine;
use crate::search::subsequence::{validate_series, window_cells, Match, ScanMode, ScanTuning};
use crate::search::suite::Suite;

/// Service construction knobs (see also [`crate::config::ServeConfig`]).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub shards: usize,
    /// positions between shared-threshold syncs in the workers
    pub sync_every: usize,
    /// scan front-end the shard workers run; the strip-mined pipeline by
    /// default, the legacy scalar loop for A/B comparison (both return
    /// bitwise-identical matches)
    pub scan_mode: ScanMode,
    /// how many in-flight wire queries the serve loop coalesces into one
    /// [`Service::submit_batch`] call (`repro serve --batch-window`);
    /// same-shape queries inside the window form cohorts that share one
    /// strip pass over the reference. 1 = serve each query solo.
    pub batch_window: usize,
    /// milliseconds a partial batch window may wait for more in-flight
    /// queries before the serve loop flushes it anyway
    /// (`repro serve --batch-deadline-ms`; 0 = no deadline, wait for the
    /// window to fill — the pre-deadline behaviour). Consumed by the
    /// serve loop's [`crate::coordinator::BatchCoalescer`]; the service
    /// itself serves whatever batch it is handed.
    pub batch_deadline_ms: u64,
    /// artifacts directory; `None` disables the XLA suite. Ignored when
    /// the crate is built without the `xla` feature.
    pub artifacts_dir: Option<std::path::PathBuf>,
    /// admission limit: how many admitted-but-unanswered queries the
    /// service tolerates before shedding new arrivals with an
    /// `overloaded` error (`repro serve --max-pending`; 0 = unbounded,
    /// the pre-admission behaviour).
    pub max_pending: usize,
    /// deadline budget, in milliseconds, applied to requests that carry
    /// no `deadline_ms` of their own (`repro serve --default-deadline-ms`;
    /// 0 = none — such queries scan exhaustively and read no clocks).
    pub default_deadline_ms: f64,
    /// kernel tuning the shard workers scan with: wavefront lane width
    /// (`repro serve --lanes`; 1 = scalar kernel, the default) and DP
    /// line precision (`repro serve --precision f32|f64`)
    pub tuning: ScanTuning,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            shards: 2,
            sync_every: DEFAULT_SYNC_EVERY,
            scan_mode: ScanMode::default(),
            batch_window: 1,
            batch_deadline_ms: 0,
            artifacts_dir: None,
            max_pending: 0,
            default_deadline_ms: 0.0,
            tuning: ScanTuning::default(),
        }
    }
}

/// One shard worker's channel and thread, kept together so a dead worker
/// can be respawned in place (same shard index, same registry cell).
struct WorkerSlot {
    tx: Sender<WorkItem>,
    /// `None` only if a respawn attempt itself failed; sends to the dead
    /// `tx` then error as "worker pool shut down"
    handle: Option<JoinHandle<()>>,
}

/// Admission slot for one in-flight query: decrements the pending count
/// when the query is answered (or abandoned), however the serving path
/// exits.
struct AdmitGuard<'a> {
    pending: &'a AtomicU64,
}

impl Drop for AdmitGuard<'_> {
    fn drop(&mut self) {
        self.pending.fetch_sub(1, Ordering::Relaxed);
    }
}

/// A unit of work for the engine thread.
#[cfg(feature = "xla")]
struct EngineJob {
    query: Vec<f64>,
    w: usize,
    /// resolve entirely on the XLA side (ablation A3) instead of
    /// prefilter + scalar verify
    full: bool,
    reply: Sender<Result<(Match, Counters)>>,
}

/// Engine thread: owns the (non-Send) PJRT client for its whole life.
#[cfg(feature = "xla")]
fn engine_loop(
    dir: std::path::PathBuf,
    reference: Arc<Vec<f64>>,
    rx: std::sync::mpsc::Receiver<EngineJob>,
) {
    let mut engine = match XlaEngine::open(&dir) {
        Ok(e) => e,
        Err(e) => {
            // report the open failure to every client that asks
            let msg = format!("{e:#}");
            while let Ok(job) = rx.recv() {
                let _ = job.reply.send(Err(anyhow!("XLA engine unavailable: {msg}")));
            }
            return;
        }
    };
    while let Ok(job) = rx.recv() {
        let mut counters = Counters::new();
        let r = if job.full {
            batcher::xla_search_full(&mut engine, &reference, &job.query, job.w, &mut counters)
        } else {
            batcher::xla_search(&mut engine, &reference, &job.query, job.w, &mut counters)
        };
        let _ = job.reply.send(r.map(|m| (m, counters)));
    }
}

/// A running similarity-search service.
pub struct Service {
    reference: Arc<Vec<f64>>,
    index: Arc<RefIndex>,
    /// worker pool behind a mutex so [`Service::revive_dead_workers`]
    /// can swap dead slots while other threads keep submitting; locked
    /// only to clone senders out or to respawn, never across a scan
    workers: Mutex<Vec<WorkerSlot>>,
    #[cfg(feature = "xla")]
    engine_tx: Option<Sender<EngineJob>>,
    #[cfg(feature = "xla")]
    engine_handle: Option<JoinHandle<()>>,
    sync_every: usize,
    scan_mode: ScanMode,
    tuning: ScanTuning,
    batch_window: usize,
    batch_deadline_ms: u64,
    max_pending: usize,
    default_deadline_ms: f64,
    busy: Arc<AtomicU64>,
    served: AtomicU64,
    /// queries admitted but not yet answered (the admission-control
    /// count that `max_pending` bounds)
    pending: AtomicU64,
    /// sharded metrics registry: one cell per worker (handed out at spawn
    /// time), one for the service thread; merged by [`Service::metrics`]
    registry: MetricsRegistry,
}

impl Service {
    /// Spawn the worker pool (and engine thread, if artifacts are given
    /// and the `xla` feature is on) over `reference`.
    pub fn new(reference: Vec<f64>, cfg: &ServiceConfig) -> Result<Self> {
        anyhow::ensure!(cfg.shards >= 1, "need at least one shard");
        // a NaN/inf point in the reference would poison every scan's
        // bounds and heaps; reject it once at construction
        validate_series("reference", &reference)?;
        let reference = Arc::new(reference);
        let index = Arc::new(RefIndex::new(Arc::clone(&reference)));
        let busy = Arc::new(AtomicU64::new(0));
        let registry = MetricsRegistry::new(cfg.shards);
        let mut slots = Vec::new();
        for i in 0..cfg.shards {
            slots.push(Self::spawn_worker(i, &busy, &registry)?);
        }
        #[cfg(feature = "xla")]
        let (engine_tx, engine_handle) = match &cfg.artifacts_dir {
            Some(dir) => {
                let (tx, rx) = channel::<EngineJob>();
                let dir = dir.clone();
                let r = Arc::clone(&reference);
                let h = std::thread::Builder::new()
                    .name("xla-engine".into())
                    .spawn(move || engine_loop(dir, r, rx))?;
                (Some(tx), Some(h))
            }
            None => (None, None),
        };
        Ok(Self {
            reference,
            index,
            workers: Mutex::new(slots),
            #[cfg(feature = "xla")]
            engine_tx,
            #[cfg(feature = "xla")]
            engine_handle,
            sync_every: cfg.sync_every,
            scan_mode: cfg.scan_mode,
            tuning: cfg.tuning,
            batch_window: cfg.batch_window.max(1),
            batch_deadline_ms: cfg.batch_deadline_ms,
            max_pending: cfg.max_pending,
            default_deadline_ms: cfg.default_deadline_ms,
            busy,
            served: AtomicU64::new(0),
            pending: AtomicU64::new(0),
            registry,
        })
    }

    /// Spawn one shard worker thread wired to the registry cell for its
    /// index (a respawn reuses the dead worker's cell, so its counters
    /// survive the thread).
    fn spawn_worker(
        i: usize,
        busy: &Arc<AtomicU64>,
        registry: &MetricsRegistry,
    ) -> Result<WorkerSlot> {
        let (tx, rx) = channel::<WorkItem>();
        let busy = Arc::clone(busy);
        let cell = registry.worker_cell(i);
        let handle = std::thread::Builder::new()
            .name(format!("shard-{i}"))
            .spawn(move || worker_loop(rx, busy, Some(cell)))?;
        Ok(WorkerSlot { tx, handle: Some(handle) })
    }

    /// The worker pool, poison-tolerant: a thread that panicked while
    /// holding the lock left the slots intact (the lock guards only
    /// clone/replace operations), so shutdown and respawn keep going.
    fn pool(&self) -> MutexGuard<'_, Vec<WorkerSlot>> {
        self.workers.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Snapshot of the live worker channels for one fan-out.
    fn senders(&self) -> Vec<Sender<WorkItem>> {
        self.pool().iter().map(|s| s.tx.clone()).collect()
    }

    /// Supervision sweep: join every worker thread that has died, record
    /// it, and respawn a replacement on the same shard index (same
    /// registry cell, same busy count). Returns how many were revived.
    /// Called when a fan-in reports [`WorkerLost`]; harmless when every
    /// worker is healthy.
    pub fn revive_dead_workers(&self) -> usize {
        let cell = self.registry.service_cell();
        let mut pool = self.pool();
        let mut revived = 0;
        for (i, slot) in pool.iter_mut().enumerate() {
            let dead = slot.handle.as_ref().map_or(true, |h| h.is_finished());
            if !dead {
                continue;
            }
            if let Some(h) = slot.handle.take() {
                // per-job panics are caught inside the worker; a join
                // error means a panic escaped the loop itself — record
                // it the same way
                if h.join().is_err() {
                    cell.add_counter(Counters::SLOT_WORKER_PANICS, 1);
                }
            }
            match Self::spawn_worker(i, &self.busy, &self.registry) {
                Ok(fresh) => {
                    *slot = fresh;
                    cell.add_counter(Counters::SLOT_WORKER_RESPAWNS, 1);
                    revived += 1;
                }
                // spawn failed (resource exhaustion): leave the slot
                // dead — fan-outs to it surface "worker pool shut down"
                Err(_) => {}
            }
        }
        revived
    }

    /// Admission control: claim a pending slot or shed the query with a
    /// typed [`Overloaded`] error (counted under `shed_queries`).
    fn admit(&self) -> Result<AdmitGuard<'_>> {
        let prev = self.pending.fetch_add(1, Ordering::Relaxed);
        if self.max_pending > 0 && prev >= self.max_pending as u64 {
            self.pending.fetch_sub(1, Ordering::Relaxed);
            self.registry.service_cell().add_counter(Counters::SLOT_SHED_QUERIES, 1);
            return Err(anyhow::Error::new(Overloaded {
                pending: prev,
                max_pending: self.max_pending,
            }));
        }
        Ok(AdmitGuard { pending: &self.pending })
    }

    /// The deadline budget (ms) governing `req`: its own wire field if
    /// present, else the service default; `None` means exhaustive.
    fn budget_of(&self, req: &QueryRequest) -> Option<f64> {
        req.deadline_ms
            .filter(|ms| ms.is_finite() && *ms > 0.0)
            .or_else(|| {
                (self.default_deadline_ms.is_finite() && self.default_deadline_ms > 0.0)
                    .then_some(self.default_deadline_ms)
            })
    }

    /// Convenience: open artifacts if the directory exists.
    pub fn with_optional_artifacts(reference: Vec<f64>, shards: usize, dir: &Path) -> Result<Self> {
        let cfg = ServiceConfig {
            shards,
            artifacts_dir: dir.join("manifest.json").exists().then(|| dir.to_path_buf()),
            ..Default::default()
        };
        Self::new(reference, &cfg)
    }

    pub fn reference_len(&self) -> usize {
        self.reference.len()
    }

    /// The shared reference-side index (stats buckets + envelope tables).
    pub fn index(&self) -> &Arc<RefIndex> {
        &self.index
    }

    pub fn queries_served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    #[cfg(feature = "xla")]
    pub fn has_engine(&self) -> bool {
        self.engine_tx.is_some()
    }

    #[cfg(not(feature = "xla"))]
    pub fn has_engine(&self) -> bool {
        false
    }

    #[cfg(feature = "xla")]
    fn submit_xla(&self, req: &QueryRequest, w: usize, full: bool) -> Result<(Match, Counters)> {
        let tx = self
            .engine_tx
            .as_ref()
            .ok_or_else(|| anyhow!("XLA suite requested but no artifacts loaded"))?;
        let (reply_tx, reply_rx) = channel();
        tx.send(EngineJob { query: req.query.clone(), w, full, reply: reply_tx })
            .map_err(|_| anyhow!("engine thread gone"))?;
        reply_rx.recv().map_err(|_| anyhow!("engine thread died mid-query"))?
    }

    /// Serve one request to completion (blocking): top-k over the shard
    /// workers, reference-side artifacts served by the shared index.
    ///
    /// Failure surface: sheds with a typed [`Overloaded`] error when the
    /// pending count is at `max_pending`; with a deadline budget (wire
    /// `deadline_ms` or the service default) an out-of-time query
    /// returns either a `partial: true` top-k of what was scanned or a
    /// typed [`DeadlineExceeded`] error when nothing was; a worker panic
    /// surfaces as a per-query error and a lost worker thread is
    /// respawned and the fan-out retried once.
    pub fn submit(&self, req: &QueryRequest) -> Result<QueryResponse> {
        let _admitted = self.admit()?;
        let deadline = self
            .budget_of(req)
            .map(|ms| (Instant::now() + Duration::from_secs_f64(ms / 1e3), ms));
        self.submit_admitted(req, deadline)
    }

    /// [`Service::submit`] past admission, with the resolved deadline
    /// `(expiry, budget_ms)` — `None` scans exhaustively and reads no
    /// clocks (the bitwise-pinned pre-deadline path).
    fn submit_admitted(
        &self,
        req: &QueryRequest,
        deadline: Option<(Instant, f64)>,
    ) -> Result<QueryResponse> {
        let timer = Timer::start();
        // in-process callers can bypass the wire parser's validation, and
        // the XLA branch below never reaches the router's check — reject
        // malformed floats for every branch here
        validate_series("query", &req.query)?;
        let w = req
            .metric
            .effective_window(req.query.len(), window_cells(req.query.len(), req.window_ratio));
        let (matches, counters, truncated) = match req.suite {
            #[cfg(feature = "xla")]
            Suite::UcrMonXla => {
                // the batched prefilter path keeps a single best-so-far
                // and its LB_Keogh prefilter is DTW-specific; it also
                // runs to completion — deadlines apply to the sharded
                // scalar scans only
                anyhow::ensure!(req.k == 1, "suite {} serves k = 1 only", req.suite.name());
                anyhow::ensure!(
                    matches!(req.metric, Metric::Cdtw),
                    "suite {} serves the cdtw metric only",
                    req.suite.name()
                );
                let (m, c) = self.submit_xla(req, w, false)?;
                (vec![m], c, false)
            }
            #[cfg(not(feature = "xla"))]
            Suite::UcrMonXla => anyhow::bail!(
                "suite {} unavailable: this build has the `xla` feature compiled out",
                req.suite.name()
            ),
            _ => {
                // empty / oversized queries and k = 0 error inside
                // artifacts_for and route_query_topk respectively
                let mut pre = Counters::new();
                let (stats, denv) = self.index.artifacts_for(
                    req.query.len(),
                    w,
                    req.metric,
                    req.suite,
                    &mut pre,
                )?;
                // scan counters enter the registry through the worker
                // cells; the service cell takes only the index-side
                // accounting and the fan-in stage time
                let cell = self.registry.service_cell();
                cell.flush_counters(&pre);
                let route = |senders: &[Sender<WorkItem>]| {
                    route_query_topk_obs(
                        senders,
                        &self.reference,
                        &req.query,
                        w,
                        req.metric,
                        req.suite,
                        self.scan_mode,
                        req.k,
                        self.sync_every,
                        self.tuning,
                        denv.clone(),
                        Some(Arc::clone(&stats)),
                        deadline.map(|(d, _)| d),
                        ScanObs(Some(cell)),
                    )
                };
                let routed = match route(&self.senders()) {
                    // a worker thread died without replying: supervise —
                    // join + respawn the dead shard(s) — and retry once
                    Err(e) if e.root_cause().downcast_ref::<WorkerLost>().is_some() => {
                        self.revive_dead_workers();
                        route(&self.senders())
                    }
                    r => r,
                };
                let (matches, mut counters, truncated) = routed?;
                counters.merge(&pre);
                cell.record_dist(DistKind::TopkTighten, counters.topk_updates);
                (matches, counters, truncated)
            }
        };
        self.finish_response(req.id, matches, counters, deadline, truncated, &timer, 1)
    }

    /// Shared tail of every serving path: deadline accounting (timeout
    /// error, partial flag, slack histogram), served count, response
    /// assembly.
    #[allow(clippy::too_many_arguments)]
    fn finish_response(
        &self,
        id: u64,
        matches: Vec<Match>,
        counters: Counters,
        deadline: Option<(Instant, f64)>,
        truncated: bool,
        timer: &Timer,
        cohort: usize,
    ) -> Result<QueryResponse> {
        let cell = self.registry.service_cell();
        if truncated {
            // the deadline cut the scan short: a top-k of what was
            // scanned in time goes out flagged partial; nothing scanned
            // at all is a timeout
            cell.add_counter(Counters::SLOT_DEADLINE_TIMEOUTS, 1);
            if matches.is_empty() {
                let budget_ms = deadline.map(|(_, ms)| ms).unwrap_or(0.0);
                return Err(anyhow::Error::new(DeadlineExceeded { budget_ms }));
            }
        } else if let Some((d, _)) = deadline {
            // in-budget deadline query: remaining slack at response time
            let slack = d.saturating_duration_since(Instant::now());
            cell.record_stage_ns(Stage::DeadlineSlack, slack.as_nanos() as u64);
        }
        self.served.fetch_add(1, Ordering::Relaxed);
        Ok(Self::make_response(
            id,
            matches,
            &counters,
            timer.elapsed_secs() * 1e3,
            cohort,
            truncated,
        ))
    }

    /// Assemble the wire response for one answered query.
    fn make_response(
        id: u64,
        matches: Vec<Match>,
        counters: &Counters,
        latency_ms: f64,
        cohort: usize,
        partial: bool,
    ) -> QueryResponse {
        let pruned = counters.lb_kim_prunes
            + counters.lb_keogh_eq_prunes
            + counters.lb_keogh_ec_prunes
            + counters.xla_prunes;
        let best = matches[0];
        QueryResponse {
            id,
            pos: best.pos,
            dist: best.dist,
            matches,
            latency_ms,
            queue_ms: None,
            candidates: counters.candidates,
            pruned,
            dtw_calls: counters.dtw_calls,
            cohort,
            partial,
        }
    }

    /// Ablation A3 entry: resolve a query entirely on the XLA side.
    /// Like [`Service::submit`] with the XLA suite, this path is
    /// cDTW-only — the batched kernels know nothing of other metrics.
    #[cfg(feature = "xla")]
    pub fn submit_xla_full(&self, req: &QueryRequest) -> Result<QueryResponse> {
        let timer = Timer::start();
        let _admitted = self.admit()?;
        validate_series("query", &req.query)?;
        anyhow::ensure!(
            matches!(req.metric, Metric::Cdtw),
            "XLA full resolution serves the cdtw metric only"
        );
        let w = window_cells(req.query.len(), req.window_ratio);
        let (m, counters) = self.submit_xla(req, w, true)?;
        self.served.fetch_add(1, Ordering::Relaxed);
        Ok(QueryResponse {
            id: req.id,
            pos: m.pos,
            dist: m.dist,
            matches: vec![m],
            latency_ms: timer.elapsed_secs() * 1e3,
            queue_ms: None,
            candidates: counters.candidates,
            pruned: counters.xla_prunes,
            dtw_calls: counters.dtw_calls,
            cohort: 1,
            partial: false,
        })
    }

    /// Serve a window of requests together, cohort-batching where shapes
    /// allow: requests that share *(query length, effective window,
    /// metric, suite, k)* — and can run on the strip pipeline — form
    /// cohorts served by **one strip pass** over the reference each
    /// ([`route_cohort_topk`]); everything else falls back to
    /// [`Service::submit`]. One answer per request, index-for-index with
    /// the input, each bitwise-identical to what a solo `submit` of that
    /// request would return. A request that fails (validation or
    /// execution) yields its own `Err` without affecting its neighbours.
    ///
    /// Cohort-served responses report the cohort's wall-clock time as
    /// their latency (they were answered by the same scan) and carry the
    /// cohort size in [`QueryResponse::cohort`].
    pub fn submit_batch(&self, reqs: &[QueryRequest]) -> Vec<Result<QueryResponse>> {
        self.submit_batch_inner(reqs, None)
    }

    /// [`Service::submit_batch`] with optional per-request arrival
    /// times: deadline budgets count from arrival (a query that waited
    /// out its whole budget in the coalescer times out at admission,
    /// before any scan work), and absent arrivals count from now.
    fn submit_batch_inner(
        &self,
        reqs: &[QueryRequest],
        arrivals: Option<&[Instant]>,
    ) -> Vec<Result<QueryResponse>> {
        let cell = self.registry.service_cell();
        let obs = ScanObs(Some(cell));
        // admission first: one pending slot per request, shed beyond
        // max_pending; the guards live until the whole batch is answered
        let mut shed: Vec<Option<anyhow::Error>> = Vec::with_capacity(reqs.len());
        let mut guards: Vec<Option<AdmitGuard<'_>>> = Vec::with_capacity(reqs.len());
        for _ in reqs {
            match self.admit() {
                Ok(g) => {
                    guards.push(Some(g));
                    shed.push(None);
                }
                Err(e) => {
                    guards.push(None);
                    shed.push(Some(e));
                }
            }
        }
        // deadline resolution: one clock read for the whole batch, and
        // none at all when every request is exhaustive (bitwise pin)
        let budgets: Vec<Option<f64>> = reqs.iter().map(|r| self.budget_of(r)).collect();
        let (batch_now, deadlines): (Option<Instant>, Vec<Option<(Instant, f64)>>) =
            if budgets.iter().all(Option::is_none) {
                (None, vec![None; reqs.len()])
            } else {
                let now = Instant::now();
                let ds = budgets
                    .iter()
                    .enumerate()
                    .map(|(i, b)| {
                        b.map(|ms| {
                            let arrival = arrivals.map_or(now, |a| a[i]);
                            (arrival + Duration::from_secs_f64(ms / 1e3), ms)
                        })
                    })
                    .collect();
                (Some(now), ds)
            };
        let form_timer = obs.stage_timer(Stage::CohortForm);
        let mut out: Vec<Option<Result<QueryResponse>>> = reqs.iter().map(|_| None).collect();
        // cohort key: (qlen, effective window, metric, suite, k)
        type Key = (usize, usize, Metric, Suite, usize);
        let mut cohorts: Vec<(Key, Vec<usize>)> = Vec::new();
        let mut solos: Vec<usize> = Vec::new();
        for (i, req) in reqs.iter().enumerate() {
            if let Some(e) = shed[i].take() {
                out[i] = Some(Err(e));
                continue;
            }
            if let (Some(now), Some((d, ms))) = (batch_now, deadlines[i]) {
                // budget already spent waiting (coalescer queue): a
                // timeout at admission, no scan work wasted on it
                if d <= now {
                    cell.add_counter(Counters::SLOT_DEADLINE_TIMEOUTS, 1);
                    out[i] = Some(Err(anyhow::Error::new(DeadlineExceeded { budget_ms: ms })));
                    continue;
                }
            }
            let eligible = self.scan_mode == ScanMode::Strip
                && req.suite != Suite::UcrMonXla
                && req.k >= 1
                && !req.query.is_empty()
                && req.query.len() <= self.reference.len()
                && validate_series("query", &req.query).is_ok()
                && req.metric.validate().is_ok();
            if !eligible {
                // solo serving reproduces every existing error/edge path
                solos.push(i);
                continue;
            }
            let n = req.query.len();
            let w = req.metric.effective_window(n, window_cells(n, req.window_ratio));
            let key: Key = (n, w, req.metric, req.suite, req.k);
            match cohorts.iter_mut().find(|(k2, _)| *k2 == key) {
                Some((_, idxs)) => idxs.push(i),
                None => cohorts.push((key, vec![i])),
            }
        }
        // the timer covers only the grouping decision, not the serving
        form_timer.stop();
        for i in solos {
            out[i] = Some(self.submit_admitted(&reqs[i], deadlines[i]));
        }
        for ((n, w, metric, suite, k), idxs) in cohorts {
            obs.record_dist(DistKind::CohortSize, idxs.len() as u64);
            if idxs.len() == 1 {
                let qi = idxs[0];
                out[qi] = Some(self.submit_admitted(&reqs[qi], deadlines[qi]));
                continue;
            }
            let member_deadlines: Vec<Option<(Instant, f64)>> =
                idxs.iter().map(|&qi| deadlines[qi]).collect();
            match self.submit_cohort(reqs, n, w, metric, suite, k, &idxs, &member_deadlines) {
                Ok(responses) => {
                    for (&qi, resp) in idxs.iter().zip(responses) {
                        out[qi] = Some(resp);
                    }
                }
                // a cohort-level failure (e.g. worker pool gone) fails
                // every member — there is no partial answer to salvage
                Err(e) => {
                    let msg = format!("{e:#}");
                    for &qi in &idxs {
                        out[qi] = Some(Err(anyhow!("{msg}")));
                    }
                }
            }
        }
        out.into_iter().map(|r| r.expect("every request answered")).collect()
    }

    /// [`Service::submit_batch`] for a coalesced window whose members
    /// carry their enqueue times: the wait between coalescer arrival and
    /// this call is recorded under the `queue_wait` stage and reported as
    /// [`QueryResponse::queue_ms`] on each successful response. Results
    /// are otherwise bitwise-identical to `submit_batch` — queue
    /// accounting happens strictly before serving begins.
    pub fn submit_batch_timed(
        &self,
        reqs: &[(QueryRequest, std::time::Instant)],
    ) -> Vec<Result<QueryResponse>> {
        let start = std::time::Instant::now();
        let cell = self.registry.service_cell();
        let queue_ms: Vec<f64> = reqs
            .iter()
            .map(|(_, enqueued)| {
                // saturates to zero if the caller's clock reads ahead
                let waited = start.duration_since(*enqueued);
                cell.record_stage_ns(Stage::QueueWait, waited.as_nanos() as u64);
                waited.as_secs_f64() * 1e3
            })
            .collect();
        let plain: Vec<QueryRequest> = reqs.iter().map(|(r, _)| r.clone()).collect();
        let arrivals: Vec<Instant> = reqs.iter().map(|(_, enqueued)| *enqueued).collect();
        let mut out = self.submit_batch_inner(&plain, Some(&arrivals));
        for (resp, waited_ms) in out.iter_mut().zip(queue_ms) {
            if let Ok(resp) = resp {
                resp.queue_ms = Some(waited_ms);
            }
        }
        out
    }

    /// One cohort through the shared strip pass: per-member index
    /// accounting (first lookup builds, the rest hit), one
    /// [`route_cohort_topk`] fan-out, one response per member. The outer
    /// `Result` is a cohort-level failure (worker pool gone, shard reply
    /// mismatch) that fails every member; the inner per-member `Result`s
    /// carry individual deadline timeouts.
    #[allow(clippy::too_many_arguments)]
    fn submit_cohort(
        &self,
        reqs: &[QueryRequest],
        n: usize,
        w: usize,
        metric: Metric,
        suite: Suite,
        k: usize,
        idxs: &[usize],
        deadlines: &[Option<(Instant, f64)>],
    ) -> Result<Vec<Result<QueryResponse>>> {
        let timer = Timer::start();
        let cell = self.registry.service_cell();
        let mut pres = Vec::with_capacity(idxs.len());
        let mut artifacts = None;
        for _ in idxs {
            let mut pre = Counters::new();
            artifacts = Some(self.index.artifacts_for(n, w, metric, suite, &mut pre)?);
            cell.flush_counters(&pre);
            pres.push(pre);
        }
        let (stats, denv) = artifacts.expect("cohort has members");
        let queries: Vec<&[f64]> = idxs.iter().map(|&qi| reqs[qi].query.as_slice()).collect();
        // the router wants bare expiry instants, and only when at least
        // one member has one (None keeps the exhaustive path clock-free)
        let router_deadlines: Option<Vec<Option<Instant>>> = deadlines
            .iter()
            .any(Option::is_some)
            .then(|| deadlines.iter().map(|d| d.map(|(at, _)| at)).collect());
        let route = |senders: &[Sender<WorkItem>]| {
            route_cohort_topk_obs(
                senders,
                &self.reference,
                &queries,
                w,
                metric,
                suite,
                k,
                self.sync_every,
                self.tuning,
                denv.clone(),
                Arc::clone(&stats),
                router_deadlines.as_deref(),
                ScanObs(Some(cell)),
            )
        };
        let per_query = match route(&self.senders()) {
            Err(e) if e.root_cause().downcast_ref::<WorkerLost>().is_some() => {
                self.revive_dead_workers();
                route(&self.senders())
            }
            r => r,
        }?;
        let cohort = idxs.len();
        Ok(idxs
            .iter()
            .zip(per_query)
            .zip(pres.into_iter().zip(deadlines))
            .map(|((&qi, (matches, mut counters, truncated)), (pre, &deadline))| {
                counters.merge(&pre);
                cell.record_dist(DistKind::TopkTighten, counters.topk_updates);
                self.finish_response(
                    reqs[qi].id,
                    matches,
                    counters,
                    deadline,
                    truncated,
                    &timer,
                    cohort,
                )
            })
            .collect())
    }

    /// Workers currently scanning (for backpressure/introspection).
    pub fn busy_workers(&self) -> u64 {
        self.busy.load(Ordering::Relaxed)
    }

    /// The scan front-end this service's shard workers run.
    pub fn scan_mode(&self) -> ScanMode {
        self.scan_mode
    }

    /// How many in-flight queries the serve loop coalesces per
    /// [`Service::submit_batch`] call.
    pub fn batch_window(&self) -> usize {
        self.batch_window
    }

    /// How long a partial batch window may wait before the serve loop
    /// flushes it (`None` = wait for the window to fill).
    pub fn batch_deadline(&self) -> Option<std::time::Duration> {
        (self.batch_deadline_ms > 0)
            .then(|| std::time::Duration::from_millis(self.batch_deadline_ms))
    }

    /// Admission limit (0 = unbounded).
    pub fn max_pending(&self) -> usize {
        self.max_pending
    }

    /// Deadline budget applied to requests without their own
    /// (`None` = none).
    pub fn default_deadline_ms(&self) -> Option<f64> {
        (self.default_deadline_ms.is_finite() && self.default_deadline_ms > 0.0)
            .then_some(self.default_deadline_ms)
    }

    /// Queries admitted but not yet answered.
    pub fn pending_queries(&self) -> u64 {
        self.pending.load(Ordering::Relaxed)
    }

    /// Point-in-time metrics: stamp the service-level gauges, then merge
    /// every registry cell into one [`MetricsSnapshot`].
    pub fn metrics(&self) -> MetricsSnapshot {
        let cell = self.registry.service_cell();
        cell.set_gauge(Gauge::BusyWorkers, self.busy_workers());
        cell.set_gauge(Gauge::QueriesServed, self.queries_served());
        cell.set_gauge(Gauge::PendingQueries, self.pending_queries());
        self.registry.snapshot()
    }

    /// The live-stats answer (`{"cmd":"stats"}` on the wire, or
    /// `--stats-every` emission): one compact pinned-schema JSON line.
    pub fn stats_json(&self) -> String {
        self.metrics().to_json_string()
    }

    /// Serve-loop hook: requests currently waiting in the batch
    /// coalescer (the service cannot see the coalescer itself).
    pub fn set_coalescer_pending(&self, n: u64) {
        self.registry.service_cell().set_gauge(Gauge::CoalescerPending, n);
    }

    /// Front-end hook: the service's own registry cell, so the network
    /// layer records its connection counters / gauge / stage timings
    /// into the same snapshot plane (single-entry rule: the net events
    /// never flow through a scan's `Counters`).
    pub(crate) fn obs_cell(&self) -> &crate::obs::ObsCell {
        self.registry.service_cell()
    }

    /// Answer one wire line: `{"cmd":"stats"}` with the live registry's
    /// pinned-schema snapshot, anything else as a query request (solo —
    /// a coalescing front-end should parse and batch instead). Always
    /// returns exactly one response line; failures answer with the
    /// protocol's error line rather than tearing the session down.
    pub fn handle_line(&self, line: &str) -> String {
        if is_stats_line(line) {
            return self.stats_json();
        }
        match QueryRequest::from_json(line) {
            Ok(req) => match self.submit(&req) {
                Ok(resp) => resp.to_json(),
                Err(e) => ErrorResponse::new(req.id, &e).to_json(),
            },
            // the line never parsed into a request: echo its id if the
            // JSON envelope carried one, else answer with "id":null —
            // exactly one reply per frame, always
            Err(e) => ErrorResponse::for_line(line, &e).to_json(),
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        // drain the pool first (poison-tolerant: a lock poisoned by a
        // panicking submitter must not abort shutdown), then close each
        // channel and join its thread — a panicked worker joins as Err,
        // which is recorded, never re-thrown out of drop
        let slots: Vec<WorkerSlot> = {
            let mut pool = match self.workers.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            pool.drain(..).collect()
        };
        #[cfg(feature = "xla")]
        {
            self.engine_tx = None;
        }
        let cell = self.registry.service_cell();
        for WorkerSlot { tx, handle } in slots {
            // closing the channel ends the worker loop
            drop(tx);
            if let Some(h) = handle {
                if h.join().is_err() {
                    cell.add_counter(Counters::SLOT_WORKER_PANICS, 1);
                }
            }
        }
        #[cfg(feature = "xla")]
        if let Some(h) = self.engine_handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::distances::metric::Metric;
    use crate::search::subsequence::{
        search_subsequence, search_subsequence_topk, search_subsequence_topk_metric,
    };

    #[test]
    fn service_matches_direct_search() {
        let r = Dataset::Ecg.generate(3000, 2);
        let q = crate::data::extract_queries(&r, 1, 128, 0.1, 3).remove(0);
        let svc = Service::new(r.clone(), &ServiceConfig { shards: 3, ..Default::default() })
            .unwrap();
        let req = QueryRequest {
            id: 1,
            query: q.clone(),
            window_ratio: 0.1,
            suite: Suite::UcrMon,
            k: 1,
            metric: Metric::Cdtw,
            deadline_ms: None,
            tenant: None,
        };
        let resp = svc.submit(&req).unwrap();
        let mut c = Counters::new();
        let want = search_subsequence(&r, &q, window_cells(q.len(), 0.1), Suite::UcrMon, &mut c);
        assert_eq!(resp.pos, want.pos);
        assert!((resp.dist - want.dist).abs() < 1e-9);
        assert_eq!(resp.candidates, c.candidates);
        assert_eq!(resp.matches.len(), 1);
        assert_eq!(svc.queries_served(), 1);
    }

    #[test]
    fn topk_submit_matches_direct_topk() {
        let r = Dataset::Refit.generate(3000, 12);
        let q = crate::data::extract_queries(&r, 1, 128, 0.1, 13).remove(0);
        let svc = Service::new(r.clone(), &ServiceConfig { shards: 4, ..Default::default() })
            .unwrap();
        let k = 5;
        let req = QueryRequest {
            id: 9,
            query: q.clone(),
            window_ratio: 0.2,
            suite: Suite::UcrMon,
            k,
            metric: Metric::Cdtw,
            deadline_ms: None,
            tenant: None,
        };
        let resp = svc.submit(&req).unwrap();
        let mut c = Counters::new();
        let want =
            search_subsequence_topk(&r, &q, window_cells(q.len(), 0.2), k, Suite::UcrMon, &mut c);
        assert_eq!(resp.matches.len(), k);
        for (g, m) in resp.matches.iter().zip(&want) {
            assert_eq!(g.pos, m.pos);
            assert!((g.dist - m.dist).abs() < 1e-9);
        }
        assert_eq!(resp.pos, resp.matches[0].pos);
    }

    #[test]
    fn repeated_submissions_hit_the_index() {
        let r = Dataset::Ppg.generate(2000, 6);
        let svc =
            Service::new(r.clone(), &ServiceConfig { shards: 2, ..Default::default() }).unwrap();
        let qs = crate::data::extract_queries(&r, 3, 128, 0.1, 7);
        for (i, q) in qs.into_iter().enumerate() {
            let req = QueryRequest {
                id: i as u64,
                query: q,
                window_ratio: 0.1,
                suite: Suite::UcrMon,
                k: 2,
                metric: Metric::Cdtw,
                deadline_ms: None,
                tenant: None,
            };
            svc.submit(&req).unwrap();
        }
        let (hits, misses) = svc.index().hit_counts();
        assert_eq!(misses, 2, "stats bucket + envelopes built once");
        assert_eq!(hits, 4, "…and reused by the two later queries");
    }

    #[test]
    fn concurrent_submissions() {
        let r = Dataset::Ppg.generate(2000, 4);
        let svc = Arc::new(
            Service::new(r.clone(), &ServiceConfig { shards: 2, ..Default::default() }).unwrap(),
        );
        let qs = crate::data::extract_queries(&r, 4, 128, 0.1, 9);
        let mut handles = Vec::new();
        for (i, q) in qs.into_iter().enumerate() {
            let svc = Arc::clone(&svc);
            handles.push(std::thread::spawn(move || {
                let req = QueryRequest {
                    id: i as u64,
                    query: q,
                    window_ratio: 0.2,
                    suite: Suite::UcrMon,
                    k: 1,
                    metric: Metric::Cdtw,
                    deadline_ms: None,
                    tenant: None,
                };
                svc.submit(&req).unwrap()
            }));
        }
        for h in handles {
            let resp = h.join().unwrap();
            assert!(resp.dist.is_finite());
        }
        assert_eq!(svc.queries_served(), 4);
    }

    #[test]
    fn every_metric_serves_and_matches_direct_search() {
        let r = Dataset::Pamap2.generate(1500, 14);
        let q = crate::data::extract_queries(&r, 1, 64, 0.1, 15).remove(0);
        let svc =
            Service::new(r.clone(), &ServiceConfig { shards: 2, ..Default::default() }).unwrap();
        let k = 3;
        for metric in Metric::all_default() {
            let req = QueryRequest {
                id: 0,
                query: q.clone(),
                window_ratio: 0.1,
                suite: Suite::UcrMon,
                k,
                metric,
                deadline_ms: None,
                tenant: None,
            };
            let resp = svc.submit(&req).unwrap();
            let mut c = Counters::new();
            let want = search_subsequence_topk_metric(
                &r,
                &q,
                window_cells(q.len(), 0.1),
                k,
                metric,
                Suite::UcrMon,
                &mut c,
            );
            assert_eq!(resp.matches.len(), want.len(), "{}", metric.name());
            for (g, m) in resp.matches.iter().zip(&want) {
                assert_eq!(g.pos, m.pos, "{}", metric.name());
                assert!((g.dist - m.dist).abs() < 1e-9, "{}", metric.name());
            }
        }
    }

    #[test]
    fn scalar_and_strip_services_agree_bitwise() {
        let r = Dataset::FoG.generate(2400, 21);
        let q = crate::data::extract_queries(&r, 1, 128, 0.1, 22).remove(0);
        let req = QueryRequest {
            id: 4,
            query: q,
            window_ratio: 0.1,
            suite: Suite::UcrMon,
            k: 6,
            metric: Metric::Cdtw,
            deadline_ms: None,
            tenant: None,
        };
        let scalar_svc = Service::new(
            r.clone(),
            &ServiceConfig { shards: 3, scan_mode: ScanMode::Scalar, ..Default::default() },
        )
        .unwrap();
        let strip_svc = Service::new(
            r,
            &ServiceConfig { shards: 3, scan_mode: ScanMode::Strip, ..Default::default() },
        )
        .unwrap();
        assert_eq!(strip_svc.scan_mode(), ScanMode::Strip);
        let a = scalar_svc.submit(&req).unwrap();
        let b = strip_svc.submit(&req).unwrap();
        assert_eq!(a.matches.len(), b.matches.len());
        for (x, y) in a.matches.iter().zip(&b.matches) {
            assert_eq!(x.pos, y.pos);
            assert_eq!(x.dist.to_bits(), y.dist.to_bits());
        }
    }

    #[test]
    fn submit_batch_cohorts_match_solo_submits_bitwise() {
        let r = Dataset::Ecg.generate(2200, 33);
        let qs = crate::data::extract_queries(&r, 4, 128, 0.1, 34);
        let svc =
            Service::new(r, &ServiceConfig { shards: 2, batch_window: 8, ..Default::default() })
                .unwrap();
        assert_eq!(svc.batch_window(), 8);
        let reqs: Vec<QueryRequest> = qs
            .into_iter()
            .enumerate()
            .map(|(i, q)| QueryRequest {
                id: i as u64,
                query: q,
                window_ratio: 0.1,
                suite: Suite::UcrMon,
                k: 3,
                metric: Metric::Cdtw,
                deadline_ms: None,
                tenant: None,
            })
            .collect();
        let batch = svc.submit_batch(&reqs);
        assert_eq!(batch.len(), reqs.len());
        for (req, got) in reqs.iter().zip(&batch) {
            let got = got.as_ref().unwrap();
            assert_eq!(got.id, req.id, "index-for-index alignment");
            assert_eq!(got.cohort, reqs.len(), "all four share one cohort");
            let want = svc.submit(req).unwrap();
            assert_eq!(got.matches.len(), want.matches.len());
            for (x, y) in got.matches.iter().zip(&want.matches) {
                assert_eq!(x.pos, y.pos);
                assert_eq!(x.dist.to_bits(), y.dist.to_bits());
            }
        }
        // 4 cohort answers + 4 solo re-checks
        assert_eq!(svc.queries_served(), 8);
        // cohort formation and size were observed by the registry
        let snap = svc.metrics();
        assert!(snap.stages[Stage::CohortForm.index()].count() >= 1);
        assert_eq!(snap.dists[DistKind::CohortSize.index()].max, 4);
        assert!(snap.dists[DistKind::StripSurvivors.index()].count() > 0);
        // the cohort scan's own bound passes and kernel evals were timed
        assert!(snap.stages[Stage::BoundKim.index()].count() > 0);
        assert!(snap.stages[Stage::BoundKeoghEq.index()].count() > 0);
        assert!(snap.stages[Stage::KernelEval.index()].count() > 0);
    }

    #[test]
    fn deadline_flush_serves_a_single_query_batch_identically_to_solo() {
        use crate::coordinator::coalescer::BatchCoalescer;
        use std::time::{Duration, Instant};

        // a service configured with a wide batch window and a deadline:
        // one lone in-flight query must not wait for seven neighbours —
        // the coalescer flushes a 1-query batch at the deadline, and the
        // answer is bitwise what a solo submit returns
        let r = Dataset::Soccer.generate(1400, 51);
        let q = crate::data::extract_queries(&r, 1, 96, 0.1, 52).remove(0);
        let svc = Service::new(
            r,
            &ServiceConfig { batch_window: 8, batch_deadline_ms: 5, ..Default::default() },
        )
        .unwrap();
        assert_eq!(svc.batch_deadline(), Some(Duration::from_millis(5)));
        let req = QueryRequest {
            id: 77,
            query: q,
            window_ratio: 0.1,
            suite: Suite::UcrMon,
            k: 3,
            metric: Metric::Cdtw,
            deadline_ms: None,
            tenant: None,
        };
        let mut co = BatchCoalescer::new(svc.batch_window(), svc.batch_deadline());
        let t0 = Instant::now();
        assert!(co.push(req.clone(), t0).is_none(), "window of 8 must not fill");
        // no further arrivals: the deadline, not the window, flushes
        let batch = co.poll(t0 + Duration::from_millis(6)).expect("deadline flush");
        assert_eq!(batch.len(), 1, "partial window flushed as a 1-query batch");
        let got = svc.submit_batch_timed(&batch).remove(0).unwrap();
        let want = svc.submit(&req).unwrap();
        assert_eq!(got.id, 77);
        assert_eq!(got.cohort, 1);
        assert_eq!(got.matches.len(), want.matches.len());
        for (x, y) in got.matches.iter().zip(&want.matches) {
            assert_eq!(x.pos, y.pos);
            assert_eq!(x.dist.to_bits(), y.dist.to_bits());
        }
        // the coalesced response reports its queue wait; the solo one
        // never mentions it
        assert!(got.queue_ms.is_some(), "coalesced response carries queue_ms");
        assert!(got.queue_ms.unwrap() >= 0.0);
        assert_eq!(want.queue_ms, None);
        // …and the wait landed in the queue_wait stage histogram
        let snap = svc.metrics();
        assert!(snap.stages[Stage::QueueWait.index()].count() >= 1);
        // a zero deadline means "no deadline" (count-only coalescing)
        let svc0 =
            Service::new(Dataset::Soccer.generate(300, 1), &ServiceConfig::default()).unwrap();
        assert_eq!(svc0.batch_deadline(), None);
    }

    #[test]
    fn registry_observes_serving_without_changing_results() {
        let r = Dataset::Ecg.generate(2000, 71);
        let qs = crate::data::extract_queries(&r, 3, 128, 0.1, 72);
        for mode in [ScanMode::Scalar, ScanMode::Strip] {
            let svc = Service::new(
                r.clone(),
                &ServiceConfig { shards: 2, scan_mode: mode, ..Default::default() },
            )
            .unwrap();
            for (i, q) in qs.iter().enumerate() {
                let req = QueryRequest {
                    id: i as u64,
                    query: q.clone(),
                    window_ratio: 0.1,
                    suite: Suite::UcrMon,
                    k: 3,
                    metric: Metric::Cdtw,
                    deadline_ms: None,
                    tenant: None,
                };
                let resp = svc.submit(&req).unwrap();
                // the registry is always attached — results must still be
                // bitwise what the bare library search returns
                let mut c = Counters::new();
                let want = search_subsequence_topk(
                    &r,
                    q,
                    window_cells(q.len(), 0.1),
                    3,
                    Suite::UcrMon,
                    &mut c,
                );
                for (g, m) in resp.matches.iter().zip(&want) {
                    assert_eq!(g.pos, m.pos, "{mode:?}");
                    assert_eq!(g.dist.to_bits(), m.dist.to_bits(), "{mode:?}");
                }
            }
            let snap = svc.metrics();
            // scan counters flowed through the worker cells exactly once
            assert!(snap.counters.candidates > 0, "{mode:?}");
            assert_eq!(
                snap.counters.dtw_calls,
                snap.counters.dtw_abandons + snap.counters.dtw_completions,
                "{mode:?}"
            );
            // stage latencies landed for the bound cascade, the kernel,
            // and the router fan-in
            for s in [Stage::BoundKim, Stage::BoundKeoghEq, Stage::KernelEval, Stage::FanIn] {
                assert!(snap.stages[s.index()].count() > 0, "{mode:?} {}", s.name());
            }
            if mode == ScanMode::Strip {
                assert!(snap.dists[DistKind::StripSurvivors.index()].count() > 0);
            }
            // one top-k tightening observation per query served
            assert_eq!(snap.dists[DistKind::TopkTighten.index()].count(), 3, "{mode:?}");
            assert_eq!(snap.gauges[Gauge::QueriesServed.index()], 3, "{mode:?}");
            // the stats line speaks the pinned schema and round-trips
            let line = svc.stats_json();
            let back = MetricsSnapshot::from_json(
                &crate::util::json::Json::parse(&line).unwrap(),
            )
            .unwrap();
            assert_eq!(back.counters.candidates, snap.counters.candidates, "{mode:?}");
            assert_eq!(
                back.stages[Stage::KernelEval.index()].count(),
                snap.stages[Stage::KernelEval.index()].count(),
                "{mode:?}"
            );
        }
    }

    #[test]
    fn submit_batch_mixes_cohorts_solos_and_errors() {
        let r = Dataset::Ppg.generate(1500, 41);
        let svc = Service::new(r.clone(), &ServiceConfig::default()).unwrap();
        let qs = crate::data::extract_queries(&r, 2, 96, 0.1, 42);
        let mk = |id: u64, query: Vec<f64>, k: usize| QueryRequest {
            id,
            query,
            window_ratio: 0.1,
            suite: Suite::UcrMon,
            k,
            metric: Metric::Cdtw,
            deadline_ms: None,
            tenant: None,
        };
        let mut bad = qs[0].clone();
        bad[5] = f64::NAN;
        let reqs = vec![
            mk(0, qs[0].clone(), 2),                    // cohort A
            mk(1, bad, 2),                              // invalid: solo error
            mk(2, qs[1].clone(), 2),                    // cohort A
            mk(3, qs[0][..64].to_vec(), 2),             // different length: solo
        ];
        let got = svc.submit_batch(&reqs);
        assert_eq!(got.len(), 4);
        let a = got[0].as_ref().unwrap();
        let c = got[2].as_ref().unwrap();
        assert_eq!(a.cohort, 2);
        assert_eq!(c.cohort, 2);
        assert_eq!(a.id, 0);
        assert_eq!(c.id, 2);
        let err = got[1].as_ref().unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        let solo = got[3].as_ref().unwrap();
        assert_eq!(solo.cohort, 1);
        // the bad request did not poison its neighbours: spot-check one
        let want = svc.submit(&reqs[2]).unwrap();
        assert_eq!(c.pos, want.pos);
        assert_eq!(c.dist.to_bits(), want.dist.to_bits());
    }

    #[test]
    fn handle_line_serves_queries_and_answers_stats_from_the_live_registry() {
        use crate::util::json::Json;
        let r = Dataset::Ecg.generate(1200, 81);
        let q = crate::data::extract_queries(&r, 1, 96, 0.1, 82).remove(0);
        let svc = Service::new(r, &ServiceConfig::default()).unwrap();
        // a fresh service answers stats with an all-zero snapshot
        let before =
            MetricsSnapshot::from_json(&Json::parse(&svc.handle_line(r#"{"cmd":"stats"}"#)).unwrap())
                .unwrap();
        assert_eq!(before.counters.candidates, 0);
        // serve one query over the wire
        let req = QueryRequest {
            id: 5,
            query: q,
            window_ratio: 0.1,
            suite: Suite::UcrMon,
            k: 2,
            metric: Metric::Cdtw,
            deadline_ms: None,
            tenant: None,
        };
        let resp = QueryResponse::from_json(&svc.handle_line(&req.to_json())).unwrap();
        assert_eq!(resp.id, 5);
        assert_eq!(resp.matches.len(), 2);
        // …and the stats line now reflects it
        let after =
            MetricsSnapshot::from_json(&Json::parse(&svc.handle_line(r#"{"cmd":"stats"}"#)).unwrap())
                .unwrap();
        assert_eq!(after.counters.candidates, resp.candidates);
        assert_eq!(after.gauges[Gauge::QueriesServed.index()], 1);
        // junk lines answer with the protocol's error line, not a panic
        let err = svc.handle_line("not json at all");
        assert!(crate::coordinator::protocol::ErrorResponse::is_error_line(&err), "{err}");
    }

    #[test]
    fn non_finite_inputs_error_instead_of_panicking_workers() {
        // NaN reference: rejected at construction
        let mut r = Dataset::Ecg.generate(600, 9);
        r[17] = f64::NAN;
        assert!(Service::new(r, &ServiceConfig::default()).is_err());
        // NaN / inf query: a graceful error from submit, and the service
        // keeps serving afterwards
        let r = Dataset::Ecg.generate(600, 9);
        let svc = Service::new(r.clone(), &ServiceConfig::default()).unwrap();
        for bad in [f64::NAN, f64::INFINITY] {
            let mut q = crate::data::extract_queries(&r, 1, 64, 0.1, 10).remove(0);
            q[3] = bad;
            let req = QueryRequest {
                id: 1,
                query: q,
                window_ratio: 0.1,
                suite: Suite::UcrMon,
                k: 1,
                metric: Metric::Cdtw,
                deadline_ms: None,
                tenant: None,
            };
            let err = svc.submit(&req).unwrap_err();
            assert!(err.to_string().contains("non-finite"), "{err}");
        }
        let good = QueryRequest {
            id: 2,
            query: crate::data::extract_queries(&r, 1, 64, 0.1, 10).remove(0),
            window_ratio: 0.1,
            suite: Suite::UcrMon,
            k: 1,
            metric: Metric::Cdtw,
            deadline_ms: None,
            tenant: None,
        };
        assert!(svc.submit(&good).is_ok());
    }

    #[test]
    fn generous_deadline_is_bitwise_identical_to_no_deadline() {
        let r = Dataset::Ecg.generate(2400, 91);
        let q = crate::data::extract_queries(&r, 1, 128, 0.1, 92).remove(0);
        for mode in [ScanMode::Scalar, ScanMode::Strip] {
            let svc = Service::new(
                r.clone(),
                &ServiceConfig { shards: 3, scan_mode: mode, ..Default::default() },
            )
            .unwrap();
            let base = QueryRequest {
                id: 1,
                query: q.clone(),
                window_ratio: 0.1,
                suite: Suite::UcrMon,
                k: 4,
                metric: Metric::Cdtw,
                deadline_ms: None,
                tenant: None,
            };
            let want = svc.submit(&base).unwrap();
            assert!(!want.partial);
            // a deadline no scan can plausibly hit: same results, down
            // to the bits, plus a slack observation
            let got = svc
                .submit(&QueryRequest { deadline_ms: Some(60_000.0), ..base.clone() })
                .unwrap();
            assert!(!got.partial, "{mode:?}");
            assert_eq!(got.matches.len(), want.matches.len(), "{mode:?}");
            for (x, y) in got.matches.iter().zip(&want.matches) {
                assert_eq!(x.pos, y.pos, "{mode:?}");
                assert_eq!(x.dist.to_bits(), y.dist.to_bits(), "{mode:?}");
            }
            assert_eq!(got.candidates, want.candidates, "{mode:?}");
            assert_eq!(got.dtw_calls, want.dtw_calls, "{mode:?}");
            let snap = svc.metrics();
            assert_eq!(
                snap.stages[Stage::DeadlineSlack.index()].count(),
                1,
                "{mode:?}: one in-budget deadline query, one slack sample"
            );
            assert_eq!(snap.counters.deadline_timeouts, 0, "{mode:?}");
            // the service-wide default budget takes the same path
            let dsvc = Service::new(
                r.clone(),
                &ServiceConfig {
                    shards: 3,
                    scan_mode: mode,
                    default_deadline_ms: 60_000.0,
                    ..Default::default()
                },
            )
            .unwrap();
            assert_eq!(dsvc.default_deadline_ms(), Some(60_000.0));
            let viad = dsvc.submit(&base).unwrap();
            assert!(!viad.partial);
            for (x, y) in viad.matches.iter().zip(&want.matches) {
                assert_eq!(x.pos, y.pos, "{mode:?}");
                assert_eq!(x.dist.to_bits(), y.dist.to_bits(), "{mode:?}");
            }
            assert!(dsvc.metrics().stages[Stage::DeadlineSlack.index()].count() >= 1);
        }
    }

    #[test]
    fn generous_deadline_cohorts_match_solo_bitwise() {
        let r = Dataset::Refit.generate(2200, 93);
        let qs = crate::data::extract_queries(&r, 3, 128, 0.1, 94);
        let svc = Service::new(
            r,
            &ServiceConfig { shards: 2, batch_window: 4, ..Default::default() },
        )
        .unwrap();
        let reqs: Vec<QueryRequest> = qs
            .into_iter()
            .enumerate()
            .map(|(i, q)| QueryRequest {
                id: i as u64,
                query: q,
                window_ratio: 0.1,
                suite: Suite::UcrMon,
                k: 3,
                metric: Metric::Cdtw,
                deadline_ms: Some(60_000.0),
                tenant: None,
            })
            .collect();
        let got = svc.submit_batch(&reqs);
        for (req, resp) in reqs.iter().zip(&got) {
            let resp = resp.as_ref().unwrap();
            assert!(!resp.partial);
            assert_eq!(resp.cohort, reqs.len());
            let solo = svc
                .submit(&QueryRequest { deadline_ms: None, ..req.clone() })
                .unwrap();
            assert_eq!(resp.matches.len(), solo.matches.len());
            for (x, y) in resp.matches.iter().zip(&solo.matches) {
                assert_eq!(x.pos, y.pos);
                assert_eq!(x.dist.to_bits(), y.dist.to_bits());
            }
        }
    }

    #[test]
    fn overload_sheds_with_typed_overloaded_errors() {
        use crate::coordinator::protocol::{ErrorKind, Overloaded};
        let r = Dataset::Ppg.generate(1500, 95);
        let q = crate::data::extract_queries(&r, 1, 96, 0.1, 96).remove(0);
        let svc = Service::new(
            r,
            &ServiceConfig { shards: 2, max_pending: 1, ..Default::default() },
        )
        .unwrap();
        assert_eq!(svc.max_pending(), 1);
        let req = QueryRequest {
            id: 7,
            query: q,
            window_ratio: 0.1,
            suite: Suite::UcrMon,
            k: 1,
            metric: Metric::Cdtw,
            deadline_ms: None,
            tenant: None,
        };
        // a batch admits every member up front: with one slot, the
        // first is served and the other two shed
        let got = svc.submit_batch(&[req.clone(), req.clone(), req.clone()]);
        assert!(got[0].is_ok());
        for shed in &got[1..] {
            let err = shed.as_ref().unwrap_err();
            let o = err.root_cause().downcast_ref::<Overloaded>().expect("typed shed error");
            assert_eq!(o.max_pending, 1);
            let wire = ErrorResponse::new(7, err);
            assert_eq!(wire.kind, Some(ErrorKind::Overloaded));
        }
        let snap = svc.metrics();
        assert_eq!(snap.counters.shed_queries, 2);
        assert_eq!(snap.gauges[Gauge::PendingQueries.index()], 0, "slots released");
        // capacity freed: the service keeps serving
        assert_eq!(svc.pending_queries(), 0);
        assert!(svc.submit(&req).is_ok());
    }

    #[test]
    fn expired_budget_times_out_at_admission_without_scanning() {
        use crate::coordinator::protocol::{DeadlineExceeded, ErrorKind};
        let r = Dataset::Ecg.generate(1500, 97);
        let q = crate::data::extract_queries(&r, 1, 96, 0.1, 98).remove(0);
        let svc = Service::new(r, &ServiceConfig::default()).unwrap();
        let req = QueryRequest {
            id: 3,
            query: q,
            window_ratio: 0.1,
            suite: Suite::UcrMon,
            k: 1,
            metric: Metric::Cdtw,
            deadline_ms: Some(1.0),
            tenant: None,
        };
        // the query waited out its whole 1ms budget in the coalescer
        let stale = Instant::now().checked_sub(Duration::from_millis(50)).unwrap();
        let err = svc.submit_batch_timed(&[(req, stale)]).remove(0).unwrap_err();
        let d = err.root_cause().downcast_ref::<DeadlineExceeded>().expect("typed timeout");
        assert_eq!(d.budget_ms, 1.0);
        assert_eq!(ErrorResponse::new(3, &err).kind, Some(ErrorKind::Timeout));
        let snap = svc.metrics();
        assert_eq!(snap.counters.deadline_timeouts, 1);
        assert_eq!(snap.counters.candidates, 0, "no scan work was spent on it");
        assert_eq!(svc.queries_served(), 0);
    }

    #[test]
    fn tiny_deadline_times_out_or_answers_partial_and_service_recovers() {
        use crate::coordinator::protocol::{DeadlineExceeded, ErrorKind};
        let r = Dataset::Pamap2.generate(8000, 99);
        let q = crate::data::extract_queries(&r, 1, 128, 0.1, 100).remove(0);
        let svc = Service::new(r.clone(), &ServiceConfig { shards: 2, ..Default::default() })
            .unwrap();
        let req = QueryRequest {
            id: 11,
            query: q.clone(),
            window_ratio: 0.1,
            suite: Suite::UcrMon,
            k: 2,
            metric: Metric::Cdtw,
            deadline_ms: Some(0.001),
            tenant: None,
        };
        // 1µs cannot cover an 8k-point scan: either nothing was scanned
        // in time (typed timeout) or some strips made it (partial top-k)
        match svc.submit(&req) {
            Ok(resp) => {
                assert!(resp.partial, "in-budget answer impossible at 1µs");
                assert!(!resp.matches.is_empty());
                assert!(resp.matches.iter().all(|m| m.dist.is_finite()));
            }
            Err(e) => {
                assert!(
                    e.root_cause().downcast_ref::<DeadlineExceeded>().is_some(),
                    "unexpected error: {e:#}"
                );
                assert_eq!(ErrorResponse::new(11, &e).kind, Some(ErrorKind::Timeout));
            }
        }
        assert_eq!(svc.metrics().counters.deadline_timeouts, 1);
        // the deadline hit is per-query state only: the next exhaustive
        // submit answers bitwise-normally
        let full = svc
            .submit(&QueryRequest { deadline_ms: None, ..req.clone() })
            .unwrap();
        assert!(!full.partial);
        let mut c = Counters::new();
        let want =
            search_subsequence_topk(&r, &q, window_cells(q.len(), 0.1), 2, Suite::UcrMon, &mut c);
        for (g, m) in full.matches.iter().zip(&want) {
            assert_eq!(g.pos, m.pos);
            assert_eq!(g.dist.to_bits(), m.dist.to_bits());
        }
    }

    #[test]
    fn xla_without_artifacts_errors() {
        let r = Dataset::Ecg.generate(1000, 5);
        let svc = Service::new(r.clone(), &ServiceConfig::default()).unwrap();
        let q = crate::data::extract_queries(&r, 1, 128, 0.1, 6).remove(0);
        let req = QueryRequest {
            id: 1,
            query: q,
            window_ratio: 0.1,
            suite: Suite::UcrMonXla,
            k: 1,
            metric: Metric::Cdtw,
            deadline_ms: None,
            tenant: None,
        };
        assert!(svc.submit(&req).is_err());
        assert!(!svc.has_engine());
    }

    #[cfg(feature = "xla")]
    #[test]
    fn bad_artifacts_dir_reports_through_channel() {
        let r = Dataset::Ecg.generate(1000, 5);
        let svc = Service::new(
            r,
            &ServiceConfig {
                artifacts_dir: Some("/no/such/dir".into()),
                ..Default::default()
            },
        )
        .unwrap();
        let req = QueryRequest {
            id: 1,
            query: vec![0.0; 128],
            window_ratio: 0.1,
            suite: Suite::UcrMonXla,
            k: 1,
            metric: Metric::Cdtw,
            deadline_ms: None,
            tenant: None,
        };
        let err = svc.submit(&req).unwrap_err();
        assert!(err.to_string().contains("unavailable"), "{err}");
    }
}
