//! Per-query routing: split the reference's candidate positions across the
//! shard workers, fan the job out, fan the results in, merge counters.

use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::coordinator::state::SharedUb;
use crate::coordinator::worker::Job;
use crate::metrics::Counters;
use crate::search::subsequence::{DataEnvelopes, Match, QueryContext};
use crate::search::suite::Suite;

/// Balanced shard ranges over `total` candidate positions.
pub fn shard_ranges(total: usize, shards: usize) -> Vec<(usize, usize)> {
    let shards = shards.max(1);
    (0..shards)
        .map(|s| (s * total / shards, (s + 1) * total / shards))
        .filter(|(a, b)| a < b)
        .collect()
}

/// Fan one query out over the worker channels; blocks until every shard
/// reports. Returns the best match plus aggregated counters.
#[allow(clippy::too_many_arguments)]
pub fn route_query(
    workers: &[Sender<Job>],
    reference: &Arc<Vec<f64>>,
    query_raw: &[f64],
    w: usize,
    suite: Suite,
    sync_every: usize,
) -> Result<(Match, Counters)> {
    let n = query_raw.len();
    anyhow::ensure!(reference.len() >= n, "reference shorter than query");
    let total = reference.len() - n + 1;
    let ranges = shard_ranges(total, workers.len());
    let shared = SharedUb::new(f64::INFINITY);
    let denv = suite
        .cascade()
        .needs_data_envelopes()
        .then(|| Arc::new(DataEnvelopes::new(reference, w)));
    let (reply_tx, reply_rx) = channel();
    let mut dispatched = 0usize;
    for (i, &(start, end)) in ranges.iter().enumerate() {
        let job = Job {
            reference: Arc::clone(reference),
            start,
            end,
            ctx: QueryContext::new(query_raw, w),
            denv: denv.clone(),
            suite,
            shared: Arc::clone(&shared),
            sync_every,
            reply: reply_tx.clone(),
        };
        workers[i % workers.len()]
            .send(job)
            .map_err(|_| anyhow!("worker pool shut down"))?;
        dispatched += 1;
    }
    drop(reply_tx);
    let mut best: Option<Match> = None;
    let mut counters = Counters::new();
    for _ in 0..dispatched {
        let (m, c) = reply_rx.recv().map_err(|_| anyhow!("worker died mid-query"))?;
        counters.merge(&c);
        if let Some(m) = m {
            if best.is_none_or(|b| m.dist < b.dist || (m.dist == b.dist && m.pos < b.pos)) {
                best = Some(m);
            }
        }
    }
    best.map(|m| (m, counters)).ok_or_else(|| anyhow!("no match found"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_cover_everything_once() {
        for total in [1usize, 7, 100, 101] {
            for shards in [1usize, 2, 3, 8, 200] {
                let r = shard_ranges(total, shards);
                let mut covered = vec![false; total];
                for (a, b) in r {
                    for c in covered.iter_mut().take(b).skip(a) {
                        assert!(!*c, "overlap");
                        *c = true;
                    }
                }
                assert!(covered.iter().all(|&c| c), "gap: total={total} shards={shards}");
            }
        }
    }
}
