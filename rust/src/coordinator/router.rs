//! Per-query routing: split the reference's candidate positions across the
//! shard workers, fan the job out, fan the results in, merge the shards'
//! local top-k lists and counters.
//!
//! ## Failure semantics
//!
//! Shard replies are `Result`s: a worker that panicked mid-job reports the
//! panic message instead of results, and the fan-in converts it into a
//! per-query [`WorkerPanicked`] error — one poisoned query never takes the
//! fan-in thread (or its siblings in a cohort) down with it. A reply
//! channel that disconnects before every shard reported means a worker
//! thread died without replying at all; that surfaces as [`WorkerLost`],
//! which the service treats as its cue to respawn dead workers.
//!
//! ## Deadlines
//!
//! With a `deadline`, the fan-in waits for each shard only until the
//! deadline plus a short grace period (workers self-check the deadline at
//! strip boundaries, so they normally report *truncated* results just
//! after it passes; the grace only matters when a shard is stalled). On
//! grace expiry the router cancels the query's [`CancelToken`] — shards
//! still scanning stop at their next strip boundary — and returns
//! whatever shards already reported, flagged truncated. Without a
//! deadline the fan-in blocks indefinitely, reads no clocks, and is
//! bitwise-identical to the pre-deadline behaviour.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::protocol::{WorkerLost, WorkerPanicked};
use crate::coordinator::state::{CancelToken, SharedUb};
use crate::coordinator::worker::{CohortJob, CohortShardReply, Job, ShardOk, ShardReply, WorkItem};
use crate::distances::metric::Metric;
use crate::index::ref_index::BucketStats;
use crate::metrics::Counters;
use crate::obs::{ScanObs, Stage};
use crate::search::subsequence::{
    validate_series, DataEnvelopes, Match, QueryContext, ScanMode, ScanTuning,
};
use crate::search::suite::Suite;

/// Extra wait past a query's deadline before the fan-in gives up on a
/// shard and cancels the query. Workers self-check deadlines at strip
/// boundaries, so a healthy shard reports within one strip of the
/// deadline; the grace is sized for scheduling jitter on top of that,
/// and only a genuinely stalled worker exhausts it.
const FANIN_GRACE: Duration = Duration::from_millis(250);

/// Balanced shard ranges over `total` candidate positions.
pub fn shard_ranges(total: usize, shards: usize) -> Vec<(usize, usize)> {
    let shards = shards.max(1);
    (0..shards)
        .map(|s| (s * total / shards, (s + 1) * total / shards))
        .filter(|(a, b)| a < b)
        .collect()
}

/// One shard reply: `Ok(Some(_))` on a report (which may itself be the
/// worker's panic, already unwrapped to an error here), `Ok(None)` when
/// the shard stayed silent past `deadline` + grace, `Err` when the reply
/// channel disconnected (worker thread died without replying).
fn recv_shard<T>(
    rx: &Receiver<Result<T, String>>,
    deadline: Option<Instant>,
) -> Result<Option<T>> {
    let reply = match deadline {
        // no deadline: block until the shard reports; a disconnect here
        // means a worker thread died without replying
        None => rx.recv().map_err(|_| anyhow::Error::new(WorkerLost))?,
        Some(d) => {
            let wait = d.saturating_duration_since(Instant::now()) + FANIN_GRACE;
            match rx.recv_timeout(wait) {
                Ok(r) => r,
                // shard still silent past deadline + grace: give up on it
                Err(RecvTimeoutError::Timeout) => return Ok(None),
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(anyhow::Error::new(WorkerLost))
                }
            }
        }
    };
    reply
        .map(Some)
        .map_err(|message| anyhow::Error::new(WorkerPanicked { message }))
}

/// Deterministic rank-and-cut for one query's pooled shard matches.
/// NaN distances (a malformed kernel result) are rejected as a per-query
/// error instead of panicking the fan-in thread.
fn rank_matches(all: &mut Vec<Match>, k: usize) -> Result<()> {
    anyhow::ensure!(
        all.iter().all(|m| !m.dist.is_nan()),
        "NaN distance in shard results"
    );
    // shards cover disjoint position ranges, so the union has no
    // duplicates; rank deterministically and keep the k best
    all.sort_by(|a, b| a.dist.total_cmp(&b.dist).then(a.pos.cmp(&b.pos)));
    all.truncate(k);
    Ok(())
}

/// Fan one top-k query out over the worker channels; blocks until every
/// shard reports. Returns the k best matches over the union of shards
/// (ascending `(dist, pos)`; fewer than k only if the candidate space is
/// smaller than k — `k` is clamped to the candidate count, so a hostile
/// request cannot force proportional allocation) plus aggregated
/// counters.
///
/// `metric` picks the elastic distance every shard scores candidates
/// under (`Metric::Cdtw` reproduces the pre-metric behaviour exactly);
/// `mode` picks the scan front-end every shard runs ([`ScanMode::Strip`]
/// is the serving default). With a shared `stats` table the two modes
/// return bitwise-identical matches; on the per-shard *streaming*
/// fallback the modes restart the stats recurrence at different block
/// boundaries, so — exactly like sharded-vs-full streaming scans always
/// did — results agree to fp tolerance, not bit for bit;
/// `denv` / `stats` are the reference-side artifacts: pass `Arc`s served
/// by a shared [`crate::index::RefIndex`] to amortise them across
/// queries, or `None` to fall back to per-query computation (envelopes,
/// built only when the metric's bounds can use them) and streaming
/// statistics — the seed behaviour.
///
/// Tie caveat: candidates whose distance *exactly* equals the k-th best
/// another shard already published are dropped (strict-< acceptance,
/// matching the seed's scalar rule), so on data with bit-identical
/// distances at the k-th boundary the tail of the list can depend on
/// shard timing. Distinct distances — any real-valued signal — are
/// deterministic.
#[allow(clippy::too_many_arguments)]
pub fn route_query_topk(
    workers: &[Sender<WorkItem>],
    reference: &Arc<Vec<f64>>,
    query_raw: &[f64],
    w: usize,
    metric: Metric,
    suite: Suite,
    mode: ScanMode,
    k: usize,
    sync_every: usize,
    tuning: ScanTuning,
    denv: Option<Arc<DataEnvelopes>>,
    stats: Option<Arc<BucketStats>>,
) -> Result<(Vec<Match>, Counters)> {
    let (matches, counters, _truncated) = route_query_topk_obs(
        workers, reference, query_raw, w, metric, suite, mode, k, sync_every, tuning, denv, stats,
        None, ScanObs::OFF,
    )?;
    Ok((matches, counters))
}

/// [`route_query_topk`] with a deadline and an observability handle: the
/// fan-in phase (collecting and merging per-shard results) is timed into
/// `obs`'s [`Stage::FanIn`] histogram. The service passes its registry
/// cell here.
///
/// The third element of the result is the **truncated** flag: `true` when
/// any shard stopped at its deadline (or the fan-in gave up on a stalled
/// shard), in which case the matches are a valid ranking of everything
/// scanned in time but may miss better candidates. `truncated` implies a
/// deadline was set; with `deadline: None` the scan is exhaustive, the
/// flag is always `false`, and the path reads no clocks. A truncated
/// query may legitimately return **zero** matches (nothing scanned in
/// time) — only exhaustive scans treat empty results as an error.
#[allow(clippy::too_many_arguments)]
pub fn route_query_topk_obs(
    workers: &[Sender<WorkItem>],
    reference: &Arc<Vec<f64>>,
    query_raw: &[f64],
    w: usize,
    metric: Metric,
    suite: Suite,
    mode: ScanMode,
    k: usize,
    sync_every: usize,
    tuning: ScanTuning,
    denv: Option<Arc<DataEnvelopes>>,
    stats: Option<Arc<BucketStats>>,
    deadline: Option<Instant>,
    obs: ScanObs<'_>,
) -> Result<(Vec<Match>, Counters, bool)> {
    let n = query_raw.len();
    anyhow::ensure!(n > 0, "empty query");
    anyhow::ensure!(k >= 1, "k must be >= 1");
    anyhow::ensure!(reference.len() >= n, "reference shorter than query");
    // a NaN/inf query would panic the sort-order build inside a shard
    // worker and poison the top-k heaps; reject it at admission instead
    validate_series("query", query_raw)?;
    metric.validate()?;
    // normalise the band here so the fallback envelopes below are always
    // built for the window the shards actually scan with (idempotent for
    // callers that already adjusted it — an unbanded metric with narrow-w
    // envelopes would over-prune)
    let w = metric.effective_window(n, w);
    if let Some(t) = &stats {
        anyhow::ensure!(t.qlen() == n, "stats bucket is for qlen {}, query has {n}", t.qlen());
    }
    let total = reference.len() - n + 1;
    let k = k.min(total);
    let ranges = shard_ranges(total, workers.len());
    let shared = SharedUb::new(f64::INFINITY);
    // the token exists only for deadline queries: the no-deadline path
    // allocates nothing and the workers check nothing extra
    let cancel = deadline.map(|_| CancelToken::new());
    let denv = match denv {
        Some(d) => Some(d),
        None => metric
            .wants_data_envelopes(suite)
            .then(|| Arc::new(DataEnvelopes::new(reference, w))),
    };
    let (reply_tx, reply_rx) = channel::<ShardReply>();
    let mut dispatched = 0usize;
    for (i, &(start, end)) in ranges.iter().enumerate() {
        let job = Job {
            reference: Arc::clone(reference),
            start,
            end,
            ctx: QueryContext::with_metric(query_raw, w, metric).with_tuning(tuning),
            denv: denv.clone(),
            stats: stats.clone(),
            suite,
            scan_mode: mode,
            k,
            shared: Arc::clone(&shared),
            sync_every,
            deadline,
            cancel: cancel.clone(),
            reply: reply_tx.clone(),
        };
        workers[i % workers.len()]
            .send(WorkItem::Single(job))
            .map_err(|_| anyhow!("worker pool shut down"))?;
        dispatched += 1;
    }
    drop(reply_tx);
    // fan-in: wall time from the first recv wait to the merged, ranked
    // result — this measures collection + merge, which includes waiting
    // for the slowest shard
    let t0 = obs.now();
    let mut all: Vec<Match> = Vec::new();
    let mut counters = Counters::new();
    let mut truncated = false;
    for _ in 0..dispatched {
        match recv_shard(&reply_rx, deadline)? {
            Some(ShardOk { matches, counters: c, truncated: t }) => {
                counters.merge(&c);
                truncated |= t;
                all.extend(matches);
            }
            None => {
                // a shard blew deadline + grace: stop the stragglers and
                // serve what we have (their late replies land in a
                // dropped receiver and vanish)
                if let Some(c) = &cancel {
                    c.cancel();
                }
                truncated = true;
                break;
            }
        }
    }
    rank_matches(&mut all, k)?;
    obs.stage_since(Stage::FanIn, t0);
    anyhow::ensure!(truncated || !all.is_empty(), "no match found");
    Ok((all, counters, truncated))
}

/// Fan one whole **query cohort** out over the worker channels: every
/// shard runs one strip-major pass serving all `queries` at once
/// ([`crate::search::cohort::scan_cohort_topk`]), loading each strip's
/// window-stat lanes once for the cohort instead of once per query.
/// Blocks until every shard reports; returns, **in cohort order**, each
/// query's k best matches over the union of shards (ascending
/// `(dist, pos)`, k clamped to the candidate count) with its per-query
/// counters.
///
/// Queries must share a length (the caller groups by shape); `w` and
/// `metric` apply to every member. Per-query thresholds are private — one
/// [`SharedUb`] per member — so each member's result is **bitwise
/// identical** to what a [`route_query_topk`] fan-out of that query alone
/// would return (pinned by `tests/conformance_cohort.rs`), including the
/// same cross-shard exact-tie caveat documented there.
#[allow(clippy::too_many_arguments)]
pub fn route_cohort_topk(
    workers: &[Sender<WorkItem>],
    reference: &Arc<Vec<f64>>,
    queries: &[&[f64]],
    w: usize,
    metric: Metric,
    suite: Suite,
    k: usize,
    sync_every: usize,
    tuning: ScanTuning,
    denv: Option<Arc<DataEnvelopes>>,
    stats: Arc<BucketStats>,
) -> Result<Vec<(Vec<Match>, Counters)>> {
    let per_query = route_cohort_topk_obs(
        workers, reference, queries, w, metric, suite, k, sync_every, tuning, denv, stats, None,
        ScanObs::OFF,
    )?;
    Ok(per_query.into_iter().map(|(m, c, _truncated)| (m, c)).collect())
}

/// [`route_cohort_topk`] with per-member deadlines and an observability
/// handle — fan-in timing, exactly as [`route_query_topk_obs`].
///
/// `deadlines`, when present, must be one entry per cohort member
/// (`None` entries are exhaustive members). Each member self-checks its
/// own deadline inside the shard scan; the fan-in additionally gives up
/// on stalled shards — cancelling the whole cohort's [`CancelToken`] —
/// only when **every** member carries a deadline (an exhaustive member
/// pins the fan-in to blocking recv, because giving up would truncate
/// it). Per-member truncation comes back as the third tuple element,
/// with the same semantics as the single-query variant: truncated
/// members may hold zero matches; exhaustive members never do.
#[allow(clippy::too_many_arguments)]
pub fn route_cohort_topk_obs(
    workers: &[Sender<WorkItem>],
    reference: &Arc<Vec<f64>>,
    queries: &[&[f64]],
    w: usize,
    metric: Metric,
    suite: Suite,
    k: usize,
    sync_every: usize,
    tuning: ScanTuning,
    denv: Option<Arc<DataEnvelopes>>,
    stats: Arc<BucketStats>,
    deadlines: Option<&[Option<Instant>]>,
    obs: ScanObs<'_>,
) -> Result<Vec<(Vec<Match>, Counters, bool)>> {
    anyhow::ensure!(!queries.is_empty(), "empty cohort");
    anyhow::ensure!(k >= 1, "k must be >= 1");
    let n = queries[0].len();
    anyhow::ensure!(n > 0, "empty query");
    anyhow::ensure!(
        queries.iter().all(|q| q.len() == n),
        "cohort members must share a query length"
    );
    anyhow::ensure!(reference.len() >= n, "reference shorter than query");
    for q in queries {
        validate_series("query", q)?;
    }
    metric.validate()?;
    if let Some(ds) = deadlines {
        anyhow::ensure!(ds.len() == queries.len(), "one deadline slot per cohort member");
    }
    let w = metric.effective_window(n, w);
    anyhow::ensure!(stats.qlen() == n, "stats bucket is for qlen {}, cohort has {n}", stats.qlen());
    let total = reference.len() - n + 1;
    let k = k.min(total);
    let ranges = shard_ranges(total, workers.len());
    let member_deadline = |m: usize| deadlines.and_then(|ds| ds[m]);
    // the fan-in may only give up (and cancel the shard pass) when no
    // member demands an exhaustive scan; the latest member deadline then
    // bounds the wait
    let per_member: Vec<Option<Instant>> = (0..queries.len()).map(member_deadline).collect();
    let fanin_deadline: Option<Instant> = if per_member.iter().all(|d| d.is_some()) {
        per_member.iter().flatten().copied().max()
    } else {
        None
    };
    let any_deadline = per_member.iter().any(|d| d.is_some());
    let cancel = any_deadline.then(CancelToken::new);
    // one private threshold per member: cohort batching shares reference
    // streaming, never abandon state
    let shareds: Vec<Arc<SharedUb>> =
        queries.iter().map(|_| SharedUb::new(f64::INFINITY)).collect();
    let (reply_tx, reply_rx) = channel::<CohortShardReply>();
    let mut dispatched = 0usize;
    for (i, &(start, end)) in ranges.iter().enumerate() {
        let job = CohortJob {
            reference: Arc::clone(reference),
            start,
            end,
            members: queries
                .iter()
                .zip(&shareds)
                .zip(&per_member)
                .map(|((q, s), d)| {
                    let ctx = QueryContext::with_metric_pooled(q, w, metric).with_tuning(tuning);
                    (ctx, Arc::clone(s), *d)
                })
                .collect(),
            denv: denv.clone(),
            stats: Arc::clone(&stats),
            suite,
            k,
            sync_every,
            cancel: cancel.clone(),
            reply: reply_tx.clone(),
        };
        workers[i % workers.len()]
            .send(WorkItem::Cohort(job))
            .map_err(|_| anyhow!("worker pool shut down"))?;
        dispatched += 1;
    }
    drop(reply_tx);
    let t0 = obs.now();
    let mut per_query: Vec<(Vec<Match>, Counters, bool)> =
        queries.iter().map(|_| (Vec::new(), Counters::new(), false)).collect();
    for _ in 0..dispatched {
        match recv_shard(&reply_rx, fanin_deadline)? {
            Some(shard) => {
                anyhow::ensure!(
                    shard.len() == queries.len(),
                    "cohort shard reply size mismatch"
                );
                for ((matches, counters, truncated), s) in per_query.iter_mut().zip(shard) {
                    matches.extend(s.matches);
                    counters.merge(&s.counters);
                    *truncated |= s.truncated;
                }
            }
            None => {
                // a stalled shard blew every member's deadline: cancel
                // the cohort pass and mark every member truncated (each
                // is missing that shard's range)
                if let Some(c) = &cancel {
                    c.cancel();
                }
                for (_, _, truncated) in per_query.iter_mut() {
                    *truncated = true;
                }
                break;
            }
        }
    }
    for (matches, _, truncated) in per_query.iter_mut() {
        rank_matches(matches, k)?;
        anyhow::ensure!(*truncated || !matches.is_empty(), "no match found");
    }
    obs.stage_since(Stage::FanIn, t0);
    Ok(per_query)
}

/// The scalar (`k = 1`) fan-out the seed exposed: best match + counters.
pub fn route_query(
    workers: &[Sender<WorkItem>],
    reference: &Arc<Vec<f64>>,
    query_raw: &[f64],
    w: usize,
    suite: Suite,
    sync_every: usize,
) -> Result<(Match, Counters)> {
    let (mut matches, counters) = route_query_topk(
        workers,
        reference,
        query_raw,
        w,
        Metric::Cdtw,
        suite,
        ScanMode::Scalar,
        1,
        sync_every,
        ScanTuning::default(),
        None,
        None,
    )?;
    Ok((matches.remove(0), counters))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_cover_everything_once() {
        for total in [1usize, 7, 100, 101] {
            for shards in [1usize, 2, 3, 8, 200] {
                let r = shard_ranges(total, shards);
                let mut covered = vec![false; total];
                for (a, b) in r {
                    for c in covered.iter_mut().take(b).skip(a) {
                        assert!(!*c, "overlap");
                        *c = true;
                    }
                }
                assert!(covered.iter().all(|&c| c), "gap: total={total} shards={shards}");
            }
        }
    }

    #[test]
    fn rank_matches_rejects_nan_and_sorts_ties_by_pos() {
        let mut ok = vec![
            Match { pos: 5, dist: 2.0 },
            Match { pos: 1, dist: 2.0 },
            Match { pos: 9, dist: 1.0 },
        ];
        rank_matches(&mut ok, 2).unwrap();
        assert_eq!(ok.iter().map(|m| m.pos).collect::<Vec<_>>(), vec![9, 1]);

        let mut bad = vec![Match { pos: 0, dist: f64::NAN }];
        let err = rank_matches(&mut bad, 1).unwrap_err();
        assert!(err.to_string().contains("NaN"), "{err}");
    }
}
