//! Per-query routing: split the reference's candidate positions across the
//! shard workers, fan the job out, fan the results in, merge the shards'
//! local top-k lists and counters.

use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::coordinator::state::SharedUb;
use crate::coordinator::worker::{CohortJob, Job, WorkItem};
use crate::distances::metric::Metric;
use crate::index::ref_index::BucketStats;
use crate::metrics::Counters;
use crate::obs::{ScanObs, Stage};
use crate::search::subsequence::{
    validate_series, DataEnvelopes, Match, QueryContext, ScanMode,
};
use crate::search::suite::Suite;

/// Balanced shard ranges over `total` candidate positions.
pub fn shard_ranges(total: usize, shards: usize) -> Vec<(usize, usize)> {
    let shards = shards.max(1);
    (0..shards)
        .map(|s| (s * total / shards, (s + 1) * total / shards))
        .filter(|(a, b)| a < b)
        .collect()
}

/// Fan one top-k query out over the worker channels; blocks until every
/// shard reports. Returns the k best matches over the union of shards
/// (ascending `(dist, pos)`; fewer than k only if the candidate space is
/// smaller than k — `k` is clamped to the candidate count, so a hostile
/// request cannot force proportional allocation) plus aggregated
/// counters.
///
/// `metric` picks the elastic distance every shard scores candidates
/// under (`Metric::Cdtw` reproduces the pre-metric behaviour exactly);
/// `mode` picks the scan front-end every shard runs ([`ScanMode::Strip`]
/// is the serving default). With a shared `stats` table the two modes
/// return bitwise-identical matches; on the per-shard *streaming*
/// fallback the modes restart the stats recurrence at different block
/// boundaries, so — exactly like sharded-vs-full streaming scans always
/// did — results agree to fp tolerance, not bit for bit;
/// `denv` / `stats` are the reference-side artifacts: pass `Arc`s served
/// by a shared [`crate::index::RefIndex`] to amortise them across
/// queries, or `None` to fall back to per-query computation (envelopes,
/// built only when the metric's bounds can use them) and streaming
/// statistics — the seed behaviour.
///
/// Tie caveat: candidates whose distance *exactly* equals the k-th best
/// another shard already published are dropped (strict-< acceptance,
/// matching the seed's scalar rule), so on data with bit-identical
/// distances at the k-th boundary the tail of the list can depend on
/// shard timing. Distinct distances — any real-valued signal — are
/// deterministic.
#[allow(clippy::too_many_arguments)]
pub fn route_query_topk(
    workers: &[Sender<WorkItem>],
    reference: &Arc<Vec<f64>>,
    query_raw: &[f64],
    w: usize,
    metric: Metric,
    suite: Suite,
    mode: ScanMode,
    k: usize,
    sync_every: usize,
    denv: Option<Arc<DataEnvelopes>>,
    stats: Option<Arc<BucketStats>>,
) -> Result<(Vec<Match>, Counters)> {
    route_query_topk_obs(
        workers, reference, query_raw, w, metric, suite, mode, k, sync_every, denv, stats,
        ScanObs::OFF,
    )
}

/// [`route_query_topk`] with an observability handle: the fan-in phase
/// (collecting and merging per-shard results) is timed into `obs`'s
/// [`Stage::FanIn`] histogram. The service passes its registry cell here.
#[allow(clippy::too_many_arguments)]
pub fn route_query_topk_obs(
    workers: &[Sender<WorkItem>],
    reference: &Arc<Vec<f64>>,
    query_raw: &[f64],
    w: usize,
    metric: Metric,
    suite: Suite,
    mode: ScanMode,
    k: usize,
    sync_every: usize,
    denv: Option<Arc<DataEnvelopes>>,
    stats: Option<Arc<BucketStats>>,
    obs: ScanObs<'_>,
) -> Result<(Vec<Match>, Counters)> {
    let n = query_raw.len();
    anyhow::ensure!(n > 0, "empty query");
    anyhow::ensure!(k >= 1, "k must be >= 1");
    anyhow::ensure!(reference.len() >= n, "reference shorter than query");
    // a NaN/inf query would panic the sort-order build inside a shard
    // worker and poison the top-k heaps; reject it at admission instead
    validate_series("query", query_raw)?;
    metric.validate()?;
    // normalise the band here so the fallback envelopes below are always
    // built for the window the shards actually scan with (idempotent for
    // callers that already adjusted it — an unbanded metric with narrow-w
    // envelopes would over-prune)
    let w = metric.effective_window(n, w);
    if let Some(t) = &stats {
        anyhow::ensure!(t.qlen() == n, "stats bucket is for qlen {}, query has {n}", t.qlen());
    }
    let total = reference.len() - n + 1;
    let k = k.min(total);
    let ranges = shard_ranges(total, workers.len());
    let shared = SharedUb::new(f64::INFINITY);
    let denv = match denv {
        Some(d) => Some(d),
        None => metric
            .wants_data_envelopes(suite)
            .then(|| Arc::new(DataEnvelopes::new(reference, w))),
    };
    let (reply_tx, reply_rx) = channel();
    let mut dispatched = 0usize;
    for (i, &(start, end)) in ranges.iter().enumerate() {
        let job = Job {
            reference: Arc::clone(reference),
            start,
            end,
            ctx: QueryContext::with_metric(query_raw, w, metric),
            denv: denv.clone(),
            stats: stats.clone(),
            suite,
            scan_mode: mode,
            k,
            shared: Arc::clone(&shared),
            sync_every,
            reply: reply_tx.clone(),
        };
        workers[i % workers.len()]
            .send(WorkItem::Single(job))
            .map_err(|_| anyhow!("worker pool shut down"))?;
        dispatched += 1;
    }
    drop(reply_tx);
    // fan-in: wall time from the first recv wait to the merged, ranked
    // result — this measures collection + merge, which includes waiting
    // for the slowest shard
    let t0 = obs.now();
    let mut all: Vec<Match> = Vec::new();
    let mut counters = Counters::new();
    for _ in 0..dispatched {
        let (matches, c) = reply_rx.recv().map_err(|_| anyhow!("worker died mid-query"))?;
        counters.merge(&c);
        all.extend(matches);
    }
    // shards cover disjoint position ranges, so the union has no
    // duplicates; rank deterministically and keep the k best
    all.sort_by(|a, b| {
        a.dist
            .partial_cmp(&b.dist)
            .expect("no NaN distances")
            .then(a.pos.cmp(&b.pos))
    });
    all.truncate(k);
    obs.stage_since(Stage::FanIn, t0);
    anyhow::ensure!(!all.is_empty(), "no match found");
    Ok((all, counters))
}

/// Fan one whole **query cohort** out over the worker channels: every
/// shard runs one strip-major pass serving all `queries` at once
/// ([`crate::search::cohort::scan_cohort_topk`]), loading each strip's
/// window-stat lanes once for the cohort instead of once per query.
/// Blocks until every shard reports; returns, **in cohort order**, each
/// query's k best matches over the union of shards (ascending
/// `(dist, pos)`, k clamped to the candidate count) with its per-query
/// counters.
///
/// Queries must share a length (the caller groups by shape); `w` and
/// `metric` apply to every member. Per-query thresholds are private — one
/// [`SharedUb`] per member — so each member's result is **bitwise
/// identical** to what a [`route_query_topk`] fan-out of that query alone
/// would return (pinned by `tests/conformance_cohort.rs`), including the
/// same cross-shard exact-tie caveat documented there.
#[allow(clippy::too_many_arguments)]
pub fn route_cohort_topk(
    workers: &[Sender<WorkItem>],
    reference: &Arc<Vec<f64>>,
    queries: &[&[f64]],
    w: usize,
    metric: Metric,
    suite: Suite,
    k: usize,
    sync_every: usize,
    denv: Option<Arc<DataEnvelopes>>,
    stats: Arc<BucketStats>,
) -> Result<Vec<(Vec<Match>, Counters)>> {
    route_cohort_topk_obs(
        workers, reference, queries, w, metric, suite, k, sync_every, denv, stats, ScanObs::OFF,
    )
}

/// [`route_cohort_topk`] with an observability handle — fan-in timing,
/// exactly as [`route_query_topk_obs`].
#[allow(clippy::too_many_arguments)]
pub fn route_cohort_topk_obs(
    workers: &[Sender<WorkItem>],
    reference: &Arc<Vec<f64>>,
    queries: &[&[f64]],
    w: usize,
    metric: Metric,
    suite: Suite,
    k: usize,
    sync_every: usize,
    denv: Option<Arc<DataEnvelopes>>,
    stats: Arc<BucketStats>,
    obs: ScanObs<'_>,
) -> Result<Vec<(Vec<Match>, Counters)>> {
    anyhow::ensure!(!queries.is_empty(), "empty cohort");
    anyhow::ensure!(k >= 1, "k must be >= 1");
    let n = queries[0].len();
    anyhow::ensure!(n > 0, "empty query");
    anyhow::ensure!(
        queries.iter().all(|q| q.len() == n),
        "cohort members must share a query length"
    );
    anyhow::ensure!(reference.len() >= n, "reference shorter than query");
    for q in queries {
        validate_series("query", q)?;
    }
    metric.validate()?;
    let w = metric.effective_window(n, w);
    anyhow::ensure!(stats.qlen() == n, "stats bucket is for qlen {}, cohort has {n}", stats.qlen());
    let total = reference.len() - n + 1;
    let k = k.min(total);
    let ranges = shard_ranges(total, workers.len());
    // one private threshold per member: cohort batching shares reference
    // streaming, never abandon state
    let shareds: Vec<Arc<SharedUb>> =
        queries.iter().map(|_| SharedUb::new(f64::INFINITY)).collect();
    let (reply_tx, reply_rx) = channel();
    let mut dispatched = 0usize;
    for (i, &(start, end)) in ranges.iter().enumerate() {
        let job = CohortJob {
            reference: Arc::clone(reference),
            start,
            end,
            members: queries
                .iter()
                .zip(&shareds)
                .map(|(q, s)| (QueryContext::with_metric_pooled(q, w, metric), Arc::clone(s)))
                .collect(),
            denv: denv.clone(),
            stats: Arc::clone(&stats),
            suite,
            k,
            sync_every,
            reply: reply_tx.clone(),
        };
        workers[i % workers.len()]
            .send(WorkItem::Cohort(job))
            .map_err(|_| anyhow!("worker pool shut down"))?;
        dispatched += 1;
    }
    drop(reply_tx);
    let t0 = obs.now();
    let mut per_query: Vec<(Vec<Match>, Counters)> =
        queries.iter().map(|_| (Vec::new(), Counters::new())).collect();
    for _ in 0..dispatched {
        let shard = reply_rx.recv().map_err(|_| anyhow!("worker died mid-cohort"))?;
        anyhow::ensure!(shard.len() == queries.len(), "cohort shard reply size mismatch");
        for ((matches, counters), (m, c)) in per_query.iter_mut().zip(shard) {
            matches.extend(m);
            counters.merge(&c);
        }
    }
    for (matches, _) in per_query.iter_mut() {
        // shards cover disjoint ranges: no duplicates; rank and cut
        matches.sort_by(|a, b| {
            a.dist
                .partial_cmp(&b.dist)
                .expect("no NaN distances")
                .then(a.pos.cmp(&b.pos))
        });
        matches.truncate(k);
        anyhow::ensure!(!matches.is_empty(), "no match found");
    }
    obs.stage_since(Stage::FanIn, t0);
    Ok(per_query)
}

/// The scalar (`k = 1`) fan-out the seed exposed: best match + counters.
pub fn route_query(
    workers: &[Sender<WorkItem>],
    reference: &Arc<Vec<f64>>,
    query_raw: &[f64],
    w: usize,
    suite: Suite,
    sync_every: usize,
) -> Result<(Match, Counters)> {
    let (mut matches, counters) = route_query_topk(
        workers,
        reference,
        query_raw,
        w,
        Metric::Cdtw,
        suite,
        ScanMode::Scalar,
        1,
        sync_every,
        None,
        None,
    )?;
    Ok((matches.remove(0), counters))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_cover_everything_once() {
        for total in [1usize, 7, 100, 101] {
            for shards in [1usize, 2, 3, 8, 200] {
                let r = shard_ranges(total, shards);
                let mut covered = vec![false; total];
                for (a, b) in r {
                    for c in covered.iter_mut().take(b).skip(a) {
                        assert!(!*c, "overlap");
                        *c = true;
                    }
                }
                assert!(covered.iter().all(|&c| c), "gap: total={total} shards={shards}");
            }
        }
    }
}
