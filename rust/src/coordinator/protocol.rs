//! Wire protocol of the serve loop: line-delimited JSON requests and
//! responses (one object per line), so the service can be driven from a
//! socket, a pipe, or in-process.
//!
//! Requests carry an optional `k` (top-k result count, default 1) and an
//! optional `metric` object (`{"name":"erp","gap":0.5}`; absent ⇒ cDTW,
//! so every pre-metric request line parses and behaves exactly as
//! before); responses carry the ranked `matches` list; the scalar
//! `pos`/`dist` fields always mirror the best match, so pre-top-k clients
//! keep working unchanged.

use std::fmt;

use anyhow::{anyhow, Result};

use crate::distances::metric::Metric;
use crate::search::subsequence::Match;
use crate::search::suite::Suite;
use crate::util::json::{obj, Json};

/// A similarity-search request.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryRequest {
    pub id: u64,
    /// raw (un-normalised) query points
    pub query: Vec<f64>,
    /// warping window as a ratio of the query length
    pub window_ratio: f64,
    pub suite: Suite,
    /// how many ranked matches to return (>= 1)
    pub k: usize,
    /// elastic metric to score candidates under (wire default: cDTW)
    pub metric: Metric,
    /// optional deadline budget in milliseconds: the service abandons the
    /// scan at the next strip boundary once the budget is spent, answering
    /// with a `timeout` error (no matches yet) or a `partial: true` top-k.
    /// `None` (absent on the wire) means no deadline — that path reads no
    /// clocks and stays bitwise-identical to the pre-deadline service.
    pub deadline_ms: Option<f64>,
    /// optional tenant key for the network front-end's per-tenant
    /// token-bucket quotas. `None` (absent on the wire) bills the
    /// anonymous bucket; the scan itself never reads it, so tenant-less
    /// request lines stay byte-identical to the pre-quota wire format.
    pub tenant: Option<String>,
}

impl QueryRequest {
    pub fn to_json(&self) -> String {
        let mut fields = vec![
            ("id", Json::Num(self.id as f64)),
            ("window_ratio", Json::Num(self.window_ratio)),
            ("suite", Json::Str(self.suite.name().to_string())),
            ("k", Json::Num(self.k as f64)),
            ("metric", self.metric.to_json()),
        ];
        // emitted only when set: deadline-free request lines stay
        // byte-identical to the pre-deadline wire format
        if let Some(d) = self.deadline_ms {
            fields.push(("deadline_ms", Json::Num(d)));
        }
        // emitted only when set: tenant-less request lines stay
        // byte-identical to the pre-quota wire format
        if let Some(t) = &self.tenant {
            fields.push(("tenant", Json::Str(t.clone())));
        }
        fields.push((
            "query",
            Json::Arr(self.query.iter().map(|&v| Json::Num(v)).collect()),
        ));
        obj(fields).to_string()
    }

    pub fn from_json(line: &str) -> Result<Self> {
        let v = Json::parse(line)?;
        let id = v
            .get("id")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("request missing id"))? as u64;
        let window_ratio = v
            .get("window_ratio")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("request missing window_ratio"))?;
        // oversized exponents parse to ±inf; a non-finite or negative
        // ratio has no meaning and must not reach the window math
        anyhow::ensure!(
            window_ratio.is_finite() && window_ratio >= 0.0,
            "window_ratio must be finite and >= 0, got {window_ratio}"
        );
        let suite_name = v
            .get("suite")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("request missing suite"))?;
        let suite = Suite::from_name(suite_name)
            .ok_or_else(|| anyhow!("unknown suite {suite_name:?}"))?;
        // absent k = 1: the pre-top-k wire format stays valid
        let k = match v.get("k") {
            Some(x) => x.as_f64().ok_or_else(|| anyhow!("non-numeric k"))? as usize,
            None => 1,
        };
        anyhow::ensure!(k >= 1, "k must be >= 1");
        // absent metric = cDTW: pre-metric request lines stay valid and
        // behave bit-identically to the pre-metric service
        let metric = match v.get("metric") {
            Some(m) => Metric::from_json(m)?,
            None => Metric::Cdtw,
        };
        let query = v
            .get("query")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("request missing query"))?
            .iter()
            .map(|x| x.as_f64().ok_or_else(|| anyhow!("non-numeric query point")))
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(!query.is_empty(), "empty query");
        // JSON has no NaN literal but oversized exponents ("1e999") parse
        // to ±inf — reject them here so a malformed request line can
        // never reach (and panic) a shard worker
        crate::search::subsequence::validate_series("query", &query)?;
        // absent deadline = none: the pre-deadline wire format stays valid
        let deadline_ms = match v.get("deadline_ms") {
            Some(x) => {
                let d = x.as_f64().ok_or_else(|| anyhow!("non-numeric deadline_ms"))?;
                anyhow::ensure!(
                    d.is_finite() && d > 0.0,
                    "deadline_ms must be finite and > 0, got {d}"
                );
                Some(d)
            }
            None => None,
        };
        // absent tenant = anonymous: the pre-quota wire format stays valid
        let tenant = match v.get("tenant") {
            Some(t) => {
                let t = t.as_str().ok_or_else(|| anyhow!("non-string tenant"))?;
                anyhow::ensure!(!t.is_empty(), "tenant must be non-empty when present");
                Some(t.to_string())
            }
            None => None,
        };
        Ok(Self { id, query, window_ratio, suite, k, metric, deadline_ms, tenant })
    }
}

/// Is this line the live-stats command (`{"cmd":"stats"}`)? The serve
/// loop answers it with the registry's pinned-schema snapshot
/// (`Service::stats_json`) without touching the query pipeline.
pub fn is_stats_line(line: &str) -> bool {
    Json::parse(line).is_ok_and(|v| v.get("cmd").and_then(Json::as_str) == Some("stats"))
}

/// Machine-readable classification of an [`ErrorResponse`], so clients
/// can branch on the failure class (retry later, back off, alert)
/// without parsing the human-readable message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// The query's deadline budget expired before any match was found.
    Timeout,
    /// Admission control shed the query: the pending-work budget
    /// (`--max-pending`) was exhausted. The query was never scanned;
    /// retrying after backoff is safe.
    Overloaded,
    /// A server-side fault (worker panic, lost worker thread): the query
    /// failed through no fault of the request.
    Internal,
    /// The tenant's token bucket is empty: the query was shed before any
    /// scan work. The error line carries `retry_after_ms`; retrying after
    /// that long is guaranteed to find at least one token.
    Quota,
    /// The request frame exceeded the server's `--max-frame-bytes` cap.
    /// The oversized line was discarded without being buffered whole;
    /// resend a smaller frame.
    FrameTooLarge,
}

impl ErrorKind {
    pub fn name(self) -> &'static str {
        match self {
            ErrorKind::Timeout => "timeout",
            ErrorKind::Overloaded => "overloaded",
            ErrorKind::Internal => "internal",
            ErrorKind::Quota => "quota",
            ErrorKind::FrameTooLarge => "frame_too_large",
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "timeout" => Some(ErrorKind::Timeout),
            "overloaded" => Some(ErrorKind::Overloaded),
            "internal" => Some(ErrorKind::Internal),
            "quota" => Some(ErrorKind::Quota),
            "frame_too_large" => Some(ErrorKind::FrameTooLarge),
            _ => None,
        }
    }
}

/// Typed error: the deadline budget expired before any match was found.
/// [`ErrorResponse::new`] maps it to [`ErrorKind::Timeout`].
#[derive(Debug, Clone, PartialEq)]
pub struct DeadlineExceeded {
    pub budget_ms: f64,
}

impl fmt::Display for DeadlineExceeded {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "deadline of {}ms exceeded", self.budget_ms)
    }
}

impl std::error::Error for DeadlineExceeded {}

/// Typed error: admission control shed the query.
/// [`ErrorResponse::new`] maps it to [`ErrorKind::Overloaded`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Overloaded {
    pub pending: u64,
    pub max_pending: usize,
}

impl fmt::Display for Overloaded {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "overloaded: {} queries pending (max {})",
            self.pending, self.max_pending
        )
    }
}

impl std::error::Error for Overloaded {}

/// Typed error: a shard worker panicked while executing this query's
/// job. [`ErrorResponse::new`] maps it to [`ErrorKind::Internal`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkerPanicked {
    pub message: String,
}

impl fmt::Display for WorkerPanicked {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "shard worker panicked: {}", self.message)
    }
}

impl std::error::Error for WorkerPanicked {}

/// Typed error: a shard worker's channel closed mid-query (the thread
/// died without replying). [`ErrorResponse::new`] maps it to
/// [`ErrorKind::Internal`]; the service respawns the worker and retries
/// once before surfacing this.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkerLost;

impl fmt::Display for WorkerLost {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "shard worker lost (thread died without replying)")
    }
}

impl std::error::Error for WorkerLost {}

/// Typed error: the tenant's token bucket had no token for this query,
/// which was shed before any scan work. [`ErrorResponse::new`] maps it
/// to [`ErrorKind::Quota`] and hoists `retry_after_ms` onto the wire.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QuotaExceeded {
    pub tenant: String,
    /// milliseconds until the bucket is guaranteed to hold ≥ 1 token
    pub retry_after_ms: u64,
}

impl fmt::Display for QuotaExceeded {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "quota exhausted for tenant {:?}: retry after {}ms",
            self.tenant, self.retry_after_ms
        )
    }
}

impl std::error::Error for QuotaExceeded {}

/// Typed error: a request frame exceeded the bounded reader's length
/// cap and was discarded without being buffered whole.
/// [`ErrorResponse::new`] maps it to [`ErrorKind::FrameTooLarge`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameTooLarge {
    /// bytes seen before the frame was cut off (≥ `limit`)
    pub len: usize,
    pub limit: usize,
}

impl fmt::Display for FrameTooLarge {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "frame of >= {} bytes exceeds the {}-byte limit", self.len, self.limit)
    }
}

impl std::error::Error for FrameTooLarge {}

/// The wire form of a request that failed — validation or execution:
/// `{"id":N,"error":"...","kind":"..."}`. The serve loop answers the
/// failing line with this and keeps serving instead of tearing the whole
/// session down. `kind` is emitted only for classified failures;
/// validation errors carry no kind, so pre-robustness error lines stay
/// byte-identical. `id` is `null` on the wire when the failing frame
/// never yielded a request id (unparseable JSON) — a client still gets
/// exactly one reply per frame. `retry_after_ms` rides along on quota
/// sheds so clients can back off precisely.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ErrorResponse {
    /// the failing request's id; `None` (wire `null`) when the frame
    /// was too malformed to carry one
    pub id: Option<u64>,
    pub error: String,
    pub kind: Option<ErrorKind>,
    /// set on [`ErrorKind::Quota`] sheds: milliseconds until a retry is
    /// guaranteed a token. Absent otherwise.
    pub retry_after_ms: Option<u64>,
}

impl ErrorResponse {
    /// Build from an error chain, classifying the root cause: the typed
    /// robustness errors ([`DeadlineExceeded`], [`Overloaded`],
    /// [`WorkerPanicked`], [`WorkerLost`], [`QuotaExceeded`],
    /// [`FrameTooLarge`]) map to their wire kind; any other error
    /// (validation, parse) carries no kind.
    pub fn new(id: u64, err: &anyhow::Error) -> Self {
        Self::classify(Some(id), err)
    }

    /// Build the reply for a frame that failed before a request was
    /// parsed: recovers the `id` field if the line is well-formed JSON
    /// with a numeric id (e.g. a valid envelope with a bad query), else
    /// answers with `"id":null` — one reply per frame, always.
    pub fn for_line(line: &str, err: &anyhow::Error) -> Self {
        let id = Json::parse(line)
            .ok()
            .and_then(|v| v.get("id").and_then(Json::as_f64))
            .map(|n| n as u64);
        Self::classify(id, err)
    }

    fn classify(id: Option<u64>, err: &anyhow::Error) -> Self {
        let root = err.root_cause();
        let mut retry_after_ms = None;
        let kind = if root.downcast_ref::<DeadlineExceeded>().is_some() {
            Some(ErrorKind::Timeout)
        } else if root.downcast_ref::<Overloaded>().is_some() {
            Some(ErrorKind::Overloaded)
        } else if root.downcast_ref::<WorkerPanicked>().is_some()
            || root.downcast_ref::<WorkerLost>().is_some()
        {
            Some(ErrorKind::Internal)
        } else if let Some(q) = root.downcast_ref::<QuotaExceeded>() {
            retry_after_ms = Some(q.retry_after_ms);
            Some(ErrorKind::Quota)
        } else if root.downcast_ref::<FrameTooLarge>().is_some() {
            Some(ErrorKind::FrameTooLarge)
        } else {
            None
        };
        Self { id, error: format!("{err:#}"), kind, retry_after_ms }
    }

    pub fn to_json(&self) -> String {
        let id = match self.id {
            Some(id) => Json::Num(id as f64),
            None => Json::Null,
        };
        let mut fields = vec![("id", id), ("error", Json::Str(self.error.clone()))];
        // emitted only for classified failures: validation error lines
        // stay byte-identical to the pre-robustness wire format
        if let Some(kind) = self.kind {
            fields.push(("kind", Json::Str(kind.name().to_string())));
        }
        // emitted only on quota sheds
        if let Some(ms) = self.retry_after_ms {
            fields.push(("retry_after_ms", Json::Num(ms as f64)));
        }
        obj(fields).to_string()
    }

    pub fn from_json(line: &str) -> Result<Self> {
        let v = Json::parse(line)?;
        // a numeric id echoes the failing request; a JSON null means the
        // frame never carried one. A missing field is still an error —
        // every reply names its request, even if only as "unknown".
        let id = match v.get("id") {
            Some(Json::Null) => None,
            Some(x) => {
                Some(x.as_f64().ok_or_else(|| anyhow!("non-numeric error response id"))? as u64)
            }
            None => return Err(anyhow!("error response missing id")),
        };
        let error = v
            .get("error")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("error response missing error"))?
            .to_string();
        // absent kind = unclassified: pre-robustness lines stay valid;
        // an unknown kind name is rejected, not silently dropped
        let kind = match v.get("kind").and_then(Json::as_str) {
            Some(name) => Some(
                ErrorKind::from_name(name)
                    .ok_or_else(|| anyhow!("unknown error kind {name:?}"))?,
            ),
            None => None,
        };
        // absent on non-quota errors: parses as None
        let retry_after_ms = v.get("retry_after_ms").and_then(Json::as_f64).map(|n| n as u64);
        Ok(Self { id, error, kind, retry_after_ms })
    }

    /// Does this line carry an error response (vs a result)?
    pub fn is_error_line(line: &str) -> bool {
        Json::parse(line).is_ok_and(|v| v.get("error").is_some())
    }
}

/// The located matches plus serving metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryResponse {
    pub id: u64,
    /// best match position (== `matches[0].pos`)
    pub pos: usize,
    /// best match distance (== `matches[0].dist`)
    pub dist: f64,
    /// the k best matches, ascending `(dist, pos)`
    pub matches: Vec<Match>,
    /// wall-clock service latency in milliseconds
    pub latency_ms: f64,
    /// milliseconds the request waited in the serve loop's batch
    /// coalescer before service began. `None` (absent on the wire) for
    /// solo submits and pre-observability servers — so every old
    /// response line still parses, and old clients ignore the new field.
    pub queue_ms: Option<f64>,
    /// candidates examined / pruned / DTW calls (aggregated over shards)
    pub candidates: u64,
    pub pruned: u64,
    pub dtw_calls: u64,
    /// how many queries shared the scan that served this response
    /// (cohort-batched serving); 1 = served solo. Absent on the wire for
    /// pre-cohort responses, which parse as 1.
    pub cohort: usize,
    /// true when the deadline budget expired mid-scan and the top-k was
    /// assembled from the strips completed in time — a valid but possibly
    /// non-optimal ranking. Absent on the wire when false, so complete
    /// responses stay byte-identical to the pre-deadline format.
    pub partial: bool,
}

impl QueryResponse {
    pub fn to_json(&self) -> String {
        let mut fields = vec![
            ("id", Json::Num(self.id as f64)),
            ("pos", Json::Num(self.pos as f64)),
            ("dist", Json::Num(self.dist)),
            (
                "matches",
                Json::Arr(
                    self.matches
                        .iter()
                        .map(|m| {
                            obj(vec![
                                ("pos", Json::Num(m.pos as f64)),
                                ("dist", Json::Num(m.dist)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("latency_ms", Json::Num(self.latency_ms)),
            ("candidates", Json::Num(self.candidates as f64)),
            ("pruned", Json::Num(self.pruned as f64)),
            ("dtw_calls", Json::Num(self.dtw_calls as f64)),
            ("cohort", Json::Num(self.cohort as f64)),
        ];
        // emitted only when measured: solo responses stay byte-identical
        // to the pre-observability wire format
        if let Some(q) = self.queue_ms {
            fields.push(("queue_ms", Json::Num(q)));
        }
        // emitted only when the deadline truncated the scan: complete
        // responses stay byte-identical to the pre-deadline wire format
        if self.partial {
            fields.push(("partial", Json::Bool(true)));
        }
        obj(fields).to_string()
    }

    pub fn from_json(line: &str) -> Result<Self> {
        let v = Json::parse(line)?;
        let num = |k: &str| -> Result<f64> {
            v.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow!("response missing {k:?}"))
        };
        let pos = num("pos")? as usize;
        let dist = num("dist")?;
        let matches = match v.get("matches").and_then(Json::as_arr) {
            Some(arr) => arr
                .iter()
                .map(|m| {
                    Ok(Match {
                        pos: m
                            .get("pos")
                            .and_then(Json::as_usize)
                            .ok_or_else(|| anyhow!("match missing pos"))?,
                        dist: m
                            .get("dist")
                            .and_then(Json::as_f64)
                            .ok_or_else(|| anyhow!("match missing dist"))?,
                    })
                })
                .collect::<Result<Vec<_>>>()?,
            // pre-top-k responses: the scalar fields are the only match
            None => vec![Match { pos, dist }],
        };
        Ok(Self {
            id: num("id")? as u64,
            pos,
            dist,
            matches,
            latency_ms: num("latency_ms")?,
            // absent on solo / pre-observability lines: parses as None
            queue_ms: v.get("queue_ms").and_then(Json::as_f64),
            candidates: num("candidates")? as u64,
            pruned: num("pruned")? as u64,
            dtw_calls: num("dtw_calls")? as u64,
            // pre-cohort responses have no field: they were served solo
            cohort: v.get("cohort").and_then(Json::as_usize).unwrap_or(1),
            // absent on complete / pre-deadline lines: parses as false
            partial: matches!(v.get("partial"), Some(Json::Bool(true))),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_round_trip() {
        let r = QueryRequest {
            id: 7,
            query: vec![1.0, -2.5, 3.0],
            window_ratio: 0.2,
            suite: Suite::UcrMon,
            k: 5,
            metric: Metric::Cdtw,
            deadline_ms: None,
            tenant: None,
        };
        let back = QueryRequest::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
        // a deadline-free request never mentions the field…
        assert!(!r.to_json().contains("deadline_ms"));
        // …and a budgeted one round-trips it
        let d = QueryRequest { deadline_ms: Some(250.0), ..r };
        assert_eq!(QueryRequest::from_json(&d.to_json()).unwrap(), d);
    }

    #[test]
    fn tenant_round_trips_and_absence_is_byte_identical() {
        let anon = QueryRequest {
            id: 7,
            query: vec![1.0, 2.0],
            window_ratio: 0.2,
            suite: Suite::UcrMon,
            k: 1,
            metric: Metric::Cdtw,
            deadline_ms: None,
            tenant: None,
        };
        // a tenant-less request never mentions the field: old clients'
        // lines are what this server emits too
        assert!(!anon.to_json().contains("tenant"));
        // …and the pre-quota wire format parses with tenant == None
        let legacy =
            QueryRequest::from_json(r#"{"id":1,"window_ratio":0.1,"suite":"mon","query":[1,2]}"#)
                .unwrap();
        assert_eq!(legacy.tenant, None);
        // a tenanted one round-trips
        let t = QueryRequest { tenant: Some("acme".into()), ..anon };
        assert!(t.to_json().contains("\"tenant\":\"acme\""));
        assert_eq!(QueryRequest::from_json(&t.to_json()).unwrap(), t);
        // non-string / empty tenants are rejected, not silently dropped
        for bad in ["7", "\"\"", "[\"a\"]"] {
            let line = format!(
                r#"{{"id":1,"window_ratio":0.1,"suite":"mon","tenant":{bad},"query":[1]}}"#
            );
            assert!(QueryRequest::from_json(&line).is_err(), "{line}");
        }
    }

    #[test]
    fn rejects_bad_deadlines_on_the_wire() {
        for bad in ["0", "-5", "1e999", "\"fast\""] {
            let line = format!(
                r#"{{"id":1,"window_ratio":0.1,"suite":"mon","deadline_ms":{bad},"query":[1,2]}}"#
            );
            assert!(QueryRequest::from_json(&line).is_err(), "{line}");
        }
    }

    #[test]
    fn request_round_trips_every_metric() {
        for metric in [
            Metric::Dtw,
            Metric::Wdtw { g: 0.1 },
            Metric::Erp { gap: 0.25 },
            Metric::Msm { cost: 1.5 },
            Metric::Twe { nu: 0.01, lambda: 0.5 },
        ] {
            let r = QueryRequest {
                id: 3,
                query: vec![0.5, 1.0],
                window_ratio: 0.3,
                suite: Suite::UcrMon,
                k: 2,
                metric,
                deadline_ms: None,
                tenant: None,
            };
            let line = r.to_json();
            assert!(line.contains(&format!("\"name\":\"{}\"", metric.name())), "{line}");
            assert_eq!(QueryRequest::from_json(&line).unwrap(), r, "{}", metric.name());
        }
    }

    #[test]
    fn request_without_k_defaults_to_1() {
        let r = QueryRequest::from_json(
            r#"{"id":1,"window_ratio":0.1,"suite":"mon","query":[1,2]}"#,
        )
        .unwrap();
        assert_eq!(r.k, 1);
    }

    #[test]
    fn request_without_metric_defaults_to_cdtw() {
        // the entire PR-1 wire format: no metric object anywhere
        let r = QueryRequest::from_json(
            r#"{"id":1,"window_ratio":0.1,"suite":"mon","k":2,"query":[1,2]}"#,
        )
        .unwrap();
        assert_eq!(r.metric, Metric::Cdtw);
    }

    #[test]
    fn metric_defaults_fill_missing_parameters_on_the_wire() {
        let r = QueryRequest::from_json(
            r#"{"id":1,"window_ratio":0.1,"suite":"mon","metric":{"name":"twe"},"query":[1,2]}"#,
        )
        .unwrap();
        assert!(matches!(r.metric, Metric::Twe { .. }));
    }

    #[test]
    fn response_round_trip() {
        let r = QueryResponse {
            id: 1,
            pos: 42,
            dist: 3.5,
            matches: vec![Match { pos: 42, dist: 3.5 }, Match { pos: 7, dist: 4.25 }],
            latency_ms: 12.25,
            queue_ms: None,
            candidates: 100,
            pruned: 90,
            dtw_calls: 10,
            cohort: 4,
            partial: false,
        };
        assert_eq!(QueryResponse::from_json(&r.to_json()).unwrap(), r);
        // a solo response (no queue wait) never mentions the field
        assert!(!r.to_json().contains("queue_ms"));
        // a complete response never mentions partial
        assert!(!r.to_json().contains("partial"));
        // …and a coalesced one round-trips it
        let q = QueryResponse { queue_ms: Some(1.5), ..r.clone() };
        assert_eq!(QueryResponse::from_json(&q.to_json()).unwrap().queue_ms, Some(1.5));
        // …and a deadline-truncated one round-trips the partial marker
        let p = QueryResponse { partial: true, ..r };
        assert!(p.to_json().contains("\"partial\":true"));
        assert!(QueryResponse::from_json(&p.to_json()).unwrap().partial);
    }

    #[test]
    fn legacy_response_without_matches_parses() {
        let line = r#"{"id":1,"pos":42,"dist":3.5,"latency_ms":1,"candidates":10,"pruned":9,"dtw_calls":1}"#;
        let r = QueryResponse::from_json(line).unwrap();
        assert_eq!(r.matches, vec![Match { pos: 42, dist: 3.5 }]);
        // pre-cohort lines carry no cohort field: served solo
        assert_eq!(r.cohort, 1);
        // …and no queue_ms field: never coalesced
        assert_eq!(r.queue_ms, None);
    }

    #[test]
    fn stats_command_line_is_recognised() {
        assert!(is_stats_line(r#"{"cmd":"stats"}"#));
        assert!(!is_stats_line(r#"{"cmd":"quit"}"#));
        assert!(!is_stats_line(r#"{"id":1,"window_ratio":0.1,"suite":"mon","query":[1]}"#));
        assert!(!is_stats_line("not json"));
    }

    #[test]
    fn error_response_round_trips_and_is_distinguishable() {
        let e = ErrorResponse::new(9, &anyhow::anyhow!("query contains a non-finite value"));
        // an unclassified (validation) error carries no kind and never
        // mentions the field on the wire
        assert_eq!(e.kind, None);
        let line = e.to_json();
        assert!(!line.contains("kind"));
        assert_eq!(ErrorResponse::from_json(&line).unwrap(), e);
        assert!(ErrorResponse::is_error_line(&line));
        let ok = QueryResponse {
            id: 1,
            pos: 0,
            dist: 1.0,
            matches: vec![Match { pos: 0, dist: 1.0 }],
            latency_ms: 0.5,
            queue_ms: None,
            candidates: 1,
            pruned: 0,
            dtw_calls: 1,
            cohort: 1,
            partial: false,
        };
        assert!(!ErrorResponse::is_error_line(&ok.to_json()));
    }

    #[test]
    fn typed_errors_classify_onto_wire_kinds() {
        for (err, kind, name) in [
            (
                anyhow::Error::new(DeadlineExceeded { budget_ms: 50.0 }),
                ErrorKind::Timeout,
                "timeout",
            ),
            (
                anyhow::Error::new(Overloaded { pending: 65, max_pending: 64 }),
                ErrorKind::Overloaded,
                "overloaded",
            ),
            (
                anyhow::Error::new(WorkerPanicked { message: "index oob".into() }),
                ErrorKind::Internal,
                "internal",
            ),
            (anyhow::Error::new(WorkerLost), ErrorKind::Internal, "internal"),
            (
                anyhow::Error::new(QuotaExceeded { tenant: "acme".into(), retry_after_ms: 40 }),
                ErrorKind::Quota,
                "quota",
            ),
            (
                anyhow::Error::new(FrameTooLarge { len: 70_000, limit: 65_536 }),
                ErrorKind::FrameTooLarge,
                "frame_too_large",
            ),
        ] {
            // classification survives context wrapping: new() inspects
            // the root cause, not the outermost layer
            let wrapped = err.context("query 9 failed");
            let e = ErrorResponse::new(9, &wrapped);
            assert_eq!(e.kind, Some(kind), "{e:?}");
            let line = e.to_json();
            assert!(line.contains(&format!("\"kind\":\"{name}\"")), "{line}");
            assert_eq!(ErrorResponse::from_json(&line).unwrap(), e);
        }
        // unknown kinds are rejected, absent kinds parse as None
        assert!(ErrorResponse::from_json(r#"{"id":1,"error":"x","kind":"zzz"}"#).is_err());
        let legacy = ErrorResponse::from_json(r#"{"id":1,"error":"x"}"#).unwrap();
        assert_eq!(legacy.kind, None);
        // …and absent retry_after_ms parses as None
        assert_eq!(legacy.retry_after_ms, None);
    }

    #[test]
    fn quota_sheds_carry_retry_after_ms_on_the_wire() {
        let err =
            anyhow::Error::new(QuotaExceeded { tenant: "acme".into(), retry_after_ms: 125 });
        let e = ErrorResponse::new(4, &err.context("query 4 shed"));
        assert_eq!(e.retry_after_ms, Some(125));
        let line = e.to_json();
        assert!(line.contains("\"retry_after_ms\":125"), "{line}");
        assert_eq!(ErrorResponse::from_json(&line).unwrap(), e);
        // non-quota errors never mention the field
        let plain = ErrorResponse::new(4, &anyhow::anyhow!("bad query"));
        assert!(!plain.to_json().contains("retry_after_ms"));
    }

    #[test]
    fn unparseable_frames_answer_with_a_null_id() {
        // no recoverable id: the reply pins id to JSON null
        let e = ErrorResponse::for_line("not json at all", &anyhow::anyhow!("parse failed"));
        assert_eq!(e.id, None);
        let line = e.to_json();
        assert!(line.contains("\"id\":null"), "{line}");
        let back = ErrorResponse::from_json(&line).unwrap();
        assert_eq!(back.id, None);
        assert!(ErrorResponse::is_error_line(&line));
        // a well-formed envelope with a bad payload still echoes its id
        let e = ErrorResponse::for_line(
            r#"{"id":31,"window_ratio":"wide"}"#,
            &anyhow::anyhow!("request missing window_ratio"),
        );
        assert_eq!(e.id, Some(31));
        // an id-bearing reply never reads as null
        assert!(!e.to_json().contains("null"), "{}", e.to_json());
        // a reply with no id field at all is malformed — rejected
        assert!(ErrorResponse::from_json(r#"{"error":"x"}"#).is_err());
    }

    #[test]
    fn rejects_non_finite_query_points_on_the_wire() {
        // "1e999" is valid JSON but parses to +inf — must not be admitted
        let line = r#"{"id":1,"window_ratio":0.1,"suite":"mon","query":[1,1e999,2]}"#;
        let err = QueryRequest::from_json(line).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(QueryRequest::from_json("{}").is_err());
        assert!(QueryRequest::from_json(r#"{"id":1,"window_ratio":0.1,"suite":"zzz","query":[1]}"#).is_err());
        assert!(QueryRequest::from_json(r#"{"id":1,"window_ratio":0.1,"suite":"mon","query":[]}"#).is_err());
        assert!(QueryRequest::from_json(
            r#"{"id":1,"window_ratio":0.1,"suite":"mon","k":0,"query":[1]}"#
        )
        .is_err());
        // unknown / malformed metric objects are rejected, not defaulted
        assert!(QueryRequest::from_json(
            r#"{"id":1,"window_ratio":0.1,"suite":"mon","metric":{"name":"zzz"},"query":[1]}"#
        )
        .is_err());
        assert!(QueryRequest::from_json(
            r#"{"id":1,"window_ratio":0.1,"suite":"mon","metric":{"name":"msm","cost":-1},"query":[1]}"#
        )
        .is_err());
    }
}
