//! Wire protocol of the serve loop: line-delimited JSON requests and
//! responses (one object per line), so the service can be driven from a
//! socket, a pipe, or in-process.
//!
//! Requests carry an optional `k` (top-k result count, default 1) and an
//! optional `metric` object (`{"name":"erp","gap":0.5}`; absent ⇒ cDTW,
//! so every pre-metric request line parses and behaves exactly as
//! before); responses carry the ranked `matches` list; the scalar
//! `pos`/`dist` fields always mirror the best match, so pre-top-k clients
//! keep working unchanged.

use anyhow::{anyhow, Result};

use crate::distances::metric::Metric;
use crate::search::subsequence::Match;
use crate::search::suite::Suite;
use crate::util::json::{obj, Json};

/// A similarity-search request.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryRequest {
    pub id: u64,
    /// raw (un-normalised) query points
    pub query: Vec<f64>,
    /// warping window as a ratio of the query length
    pub window_ratio: f64,
    pub suite: Suite,
    /// how many ranked matches to return (>= 1)
    pub k: usize,
    /// elastic metric to score candidates under (wire default: cDTW)
    pub metric: Metric,
}

impl QueryRequest {
    pub fn to_json(&self) -> String {
        obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("window_ratio", Json::Num(self.window_ratio)),
            ("suite", Json::Str(self.suite.name().to_string())),
            ("k", Json::Num(self.k as f64)),
            ("metric", self.metric.to_json()),
            ("query", Json::Arr(self.query.iter().map(|&v| Json::Num(v)).collect())),
        ])
        .to_string()
    }

    pub fn from_json(line: &str) -> Result<Self> {
        let v = Json::parse(line)?;
        let id = v
            .get("id")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("request missing id"))? as u64;
        let window_ratio = v
            .get("window_ratio")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("request missing window_ratio"))?;
        // oversized exponents parse to ±inf; a non-finite or negative
        // ratio has no meaning and must not reach the window math
        anyhow::ensure!(
            window_ratio.is_finite() && window_ratio >= 0.0,
            "window_ratio must be finite and >= 0, got {window_ratio}"
        );
        let suite_name = v
            .get("suite")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("request missing suite"))?;
        let suite = Suite::from_name(suite_name)
            .ok_or_else(|| anyhow!("unknown suite {suite_name:?}"))?;
        // absent k = 1: the pre-top-k wire format stays valid
        let k = match v.get("k") {
            Some(x) => x.as_f64().ok_or_else(|| anyhow!("non-numeric k"))? as usize,
            None => 1,
        };
        anyhow::ensure!(k >= 1, "k must be >= 1");
        // absent metric = cDTW: pre-metric request lines stay valid and
        // behave bit-identically to the pre-metric service
        let metric = match v.get("metric") {
            Some(m) => Metric::from_json(m)?,
            None => Metric::Cdtw,
        };
        let query = v
            .get("query")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("request missing query"))?
            .iter()
            .map(|x| x.as_f64().ok_or_else(|| anyhow!("non-numeric query point")))
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(!query.is_empty(), "empty query");
        // JSON has no NaN literal but oversized exponents ("1e999") parse
        // to ±inf — reject them here so a malformed request line can
        // never reach (and panic) a shard worker
        crate::search::subsequence::validate_series("query", &query)?;
        Ok(Self { id, query, window_ratio, suite, k, metric })
    }
}

/// Is this line the live-stats command (`{"cmd":"stats"}`)? The serve
/// loop answers it with the registry's pinned-schema snapshot
/// (`Service::stats_json`) without touching the query pipeline.
pub fn is_stats_line(line: &str) -> bool {
    Json::parse(line).is_ok_and(|v| v.get("cmd").and_then(Json::as_str) == Some("stats"))
}

/// The wire form of a request that failed — validation or execution:
/// `{"id":N,"error":"..."}`. The serve loop answers the failing line with
/// this and keeps serving instead of tearing the whole session down.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ErrorResponse {
    pub id: u64,
    pub error: String,
}

impl ErrorResponse {
    pub fn new(id: u64, err: &anyhow::Error) -> Self {
        Self { id, error: format!("{err:#}") }
    }

    pub fn to_json(&self) -> String {
        obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("error", Json::Str(self.error.clone())),
        ])
        .to_string()
    }

    pub fn from_json(line: &str) -> Result<Self> {
        let v = Json::parse(line)?;
        let id = v
            .get("id")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("error response missing id"))? as u64;
        let error = v
            .get("error")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("error response missing error"))?
            .to_string();
        Ok(Self { id, error })
    }

    /// Does this line carry an error response (vs a result)?
    pub fn is_error_line(line: &str) -> bool {
        Json::parse(line).is_ok_and(|v| v.get("error").is_some())
    }
}

/// The located matches plus serving metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryResponse {
    pub id: u64,
    /// best match position (== `matches[0].pos`)
    pub pos: usize,
    /// best match distance (== `matches[0].dist`)
    pub dist: f64,
    /// the k best matches, ascending `(dist, pos)`
    pub matches: Vec<Match>,
    /// wall-clock service latency in milliseconds
    pub latency_ms: f64,
    /// milliseconds the request waited in the serve loop's batch
    /// coalescer before service began. `None` (absent on the wire) for
    /// solo submits and pre-observability servers — so every old
    /// response line still parses, and old clients ignore the new field.
    pub queue_ms: Option<f64>,
    /// candidates examined / pruned / DTW calls (aggregated over shards)
    pub candidates: u64,
    pub pruned: u64,
    pub dtw_calls: u64,
    /// how many queries shared the scan that served this response
    /// (cohort-batched serving); 1 = served solo. Absent on the wire for
    /// pre-cohort responses, which parse as 1.
    pub cohort: usize,
}

impl QueryResponse {
    pub fn to_json(&self) -> String {
        let mut fields = vec![
            ("id", Json::Num(self.id as f64)),
            ("pos", Json::Num(self.pos as f64)),
            ("dist", Json::Num(self.dist)),
            (
                "matches",
                Json::Arr(
                    self.matches
                        .iter()
                        .map(|m| {
                            obj(vec![
                                ("pos", Json::Num(m.pos as f64)),
                                ("dist", Json::Num(m.dist)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("latency_ms", Json::Num(self.latency_ms)),
            ("candidates", Json::Num(self.candidates as f64)),
            ("pruned", Json::Num(self.pruned as f64)),
            ("dtw_calls", Json::Num(self.dtw_calls as f64)),
            ("cohort", Json::Num(self.cohort as f64)),
        ];
        // emitted only when measured: solo responses stay byte-identical
        // to the pre-observability wire format
        if let Some(q) = self.queue_ms {
            fields.push(("queue_ms", Json::Num(q)));
        }
        obj(fields).to_string()
    }

    pub fn from_json(line: &str) -> Result<Self> {
        let v = Json::parse(line)?;
        let num = |k: &str| -> Result<f64> {
            v.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow!("response missing {k:?}"))
        };
        let pos = num("pos")? as usize;
        let dist = num("dist")?;
        let matches = match v.get("matches").and_then(Json::as_arr) {
            Some(arr) => arr
                .iter()
                .map(|m| {
                    Ok(Match {
                        pos: m
                            .get("pos")
                            .and_then(Json::as_usize)
                            .ok_or_else(|| anyhow!("match missing pos"))?,
                        dist: m
                            .get("dist")
                            .and_then(Json::as_f64)
                            .ok_or_else(|| anyhow!("match missing dist"))?,
                    })
                })
                .collect::<Result<Vec<_>>>()?,
            // pre-top-k responses: the scalar fields are the only match
            None => vec![Match { pos, dist }],
        };
        Ok(Self {
            id: num("id")? as u64,
            pos,
            dist,
            matches,
            latency_ms: num("latency_ms")?,
            // absent on solo / pre-observability lines: parses as None
            queue_ms: v.get("queue_ms").and_then(Json::as_f64),
            candidates: num("candidates")? as u64,
            pruned: num("pruned")? as u64,
            dtw_calls: num("dtw_calls")? as u64,
            // pre-cohort responses have no field: they were served solo
            cohort: v.get("cohort").and_then(Json::as_usize).unwrap_or(1),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_round_trip() {
        let r = QueryRequest {
            id: 7,
            query: vec![1.0, -2.5, 3.0],
            window_ratio: 0.2,
            suite: Suite::UcrMon,
            k: 5,
            metric: Metric::Cdtw,
        };
        let back = QueryRequest::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn request_round_trips_every_metric() {
        for metric in [
            Metric::Dtw,
            Metric::Wdtw { g: 0.1 },
            Metric::Erp { gap: 0.25 },
            Metric::Msm { cost: 1.5 },
            Metric::Twe { nu: 0.01, lambda: 0.5 },
        ] {
            let r = QueryRequest {
                id: 3,
                query: vec![0.5, 1.0],
                window_ratio: 0.3,
                suite: Suite::UcrMon,
                k: 2,
                metric,
            };
            let line = r.to_json();
            assert!(line.contains(&format!("\"name\":\"{}\"", metric.name())), "{line}");
            assert_eq!(QueryRequest::from_json(&line).unwrap(), r, "{}", metric.name());
        }
    }

    #[test]
    fn request_without_k_defaults_to_1() {
        let r = QueryRequest::from_json(
            r#"{"id":1,"window_ratio":0.1,"suite":"mon","query":[1,2]}"#,
        )
        .unwrap();
        assert_eq!(r.k, 1);
    }

    #[test]
    fn request_without_metric_defaults_to_cdtw() {
        // the entire PR-1 wire format: no metric object anywhere
        let r = QueryRequest::from_json(
            r#"{"id":1,"window_ratio":0.1,"suite":"mon","k":2,"query":[1,2]}"#,
        )
        .unwrap();
        assert_eq!(r.metric, Metric::Cdtw);
    }

    #[test]
    fn metric_defaults_fill_missing_parameters_on_the_wire() {
        let r = QueryRequest::from_json(
            r#"{"id":1,"window_ratio":0.1,"suite":"mon","metric":{"name":"twe"},"query":[1,2]}"#,
        )
        .unwrap();
        assert!(matches!(r.metric, Metric::Twe { .. }));
    }

    #[test]
    fn response_round_trip() {
        let r = QueryResponse {
            id: 1,
            pos: 42,
            dist: 3.5,
            matches: vec![Match { pos: 42, dist: 3.5 }, Match { pos: 7, dist: 4.25 }],
            latency_ms: 12.25,
            queue_ms: None,
            candidates: 100,
            pruned: 90,
            dtw_calls: 10,
            cohort: 4,
        };
        assert_eq!(QueryResponse::from_json(&r.to_json()).unwrap(), r);
        // a solo response (no queue wait) never mentions the field
        assert!(!r.to_json().contains("queue_ms"));
        // …and a coalesced one round-trips it
        let q = QueryResponse { queue_ms: Some(1.5), ..r };
        assert_eq!(QueryResponse::from_json(&q.to_json()).unwrap().queue_ms, Some(1.5));
    }

    #[test]
    fn legacy_response_without_matches_parses() {
        let line = r#"{"id":1,"pos":42,"dist":3.5,"latency_ms":1,"candidates":10,"pruned":9,"dtw_calls":1}"#;
        let r = QueryResponse::from_json(line).unwrap();
        assert_eq!(r.matches, vec![Match { pos: 42, dist: 3.5 }]);
        // pre-cohort lines carry no cohort field: served solo
        assert_eq!(r.cohort, 1);
        // …and no queue_ms field: never coalesced
        assert_eq!(r.queue_ms, None);
    }

    #[test]
    fn stats_command_line_is_recognised() {
        assert!(is_stats_line(r#"{"cmd":"stats"}"#));
        assert!(!is_stats_line(r#"{"cmd":"quit"}"#));
        assert!(!is_stats_line(r#"{"id":1,"window_ratio":0.1,"suite":"mon","query":[1]}"#));
        assert!(!is_stats_line("not json"));
    }

    #[test]
    fn error_response_round_trips_and_is_distinguishable() {
        let e = ErrorResponse::new(9, &anyhow::anyhow!("query contains a non-finite value"));
        let line = e.to_json();
        assert_eq!(ErrorResponse::from_json(&line).unwrap(), e);
        assert!(ErrorResponse::is_error_line(&line));
        let ok = QueryResponse {
            id: 1,
            pos: 0,
            dist: 1.0,
            matches: vec![Match { pos: 0, dist: 1.0 }],
            latency_ms: 0.5,
            queue_ms: None,
            candidates: 1,
            pruned: 0,
            dtw_calls: 1,
            cohort: 1,
        };
        assert!(!ErrorResponse::is_error_line(&ok.to_json()));
    }

    #[test]
    fn rejects_non_finite_query_points_on_the_wire() {
        // "1e999" is valid JSON but parses to +inf — must not be admitted
        let line = r#"{"id":1,"window_ratio":0.1,"suite":"mon","query":[1,1e999,2]}"#;
        let err = QueryRequest::from_json(line).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(QueryRequest::from_json("{}").is_err());
        assert!(QueryRequest::from_json(r#"{"id":1,"window_ratio":0.1,"suite":"zzz","query":[1]}"#).is_err());
        assert!(QueryRequest::from_json(r#"{"id":1,"window_ratio":0.1,"suite":"mon","query":[]}"#).is_err());
        assert!(QueryRequest::from_json(
            r#"{"id":1,"window_ratio":0.1,"suite":"mon","k":0,"query":[1]}"#
        )
        .is_err());
        // unknown / malformed metric objects are rejected, not defaulted
        assert!(QueryRequest::from_json(
            r#"{"id":1,"window_ratio":0.1,"suite":"mon","metric":{"name":"zzz"},"query":[1]}"#
        )
        .is_err());
        assert!(QueryRequest::from_json(
            r#"{"id":1,"window_ratio":0.1,"suite":"mon","metric":{"name":"msm","cost":-1},"query":[1]}"#
        )
        .is_err());
    }
}
