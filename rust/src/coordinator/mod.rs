//! Layer-3 serving coordinator (system S13): the similarity-search engine
//! packaged as a service — query admission, sharded top-k scanning with a
//! shared k-th-best threshold, reference-side artifacts served by the
//! shared [`crate::index::RefIndex`], and (behind the `xla` feature) the
//! batched XLA prefilter path.
//!
//! Note on runtime: the image's vendored crate set has no async runtime,
//! so the event loop is OS threads + channels (`std::sync::mpsc`) instead
//! of tokio tasks; the architecture (router → bounded queues → shard
//! workers → aggregation) is the same (DESIGN.md §4).
//!
//! * [`protocol`] — request/response types + JSON wire format (top-k
//!   aware: requests carry `k`, responses a ranked `matches` list)
//! * [`state`] — the shared threshold (the serving analogue of the
//!   paper's upper-bound tightening: every shard's k-th-best improvement
//!   immediately tightens every other shard's abandon threshold)
//! * [`worker`] — shard scan workers, each collecting a local top-k;
//!   a worker serves single-query shards and whole query *cohorts*
//!   (one strip pass over its shard answering a batch of same-shape
//!   queries, each with a private threshold)
//! * [`batcher`] — panels of candidates through the AOT XLA prefilter
//! * [`router`] — per-query fan-out/fan-in with deterministic
//!   `(dist, pos)` merge of the shards' result heaps
//! * [`coalescer`] — batch-window gathering for the serve loop, with
//!   count-based *and* deadline-based flushing (`--batch-deadline-ms`)
//! * [`service`] — lifecycle: spawn, submit, drain, shutdown — plus the
//!   failure model: admission control (`max_pending` sheds with a typed
//!   `overloaded` error), per-query deadline budgets (`deadline_ms` on
//!   the wire or a service default; out-of-time queries answer
//!   `partial: true` or a typed `timeout`), and worker supervision
//!   (per-job panic domains, dead-thread respawn with a single retry).
//!   See `README.md` in this directory for the full failure model.

#[cfg(feature = "xla")]
pub mod batcher;
pub mod coalescer;
pub mod protocol;
pub mod router;
pub mod service;
pub mod state;
pub mod worker;

pub use coalescer::BatchCoalescer;
pub use protocol::{ErrorKind, ErrorResponse, QueryRequest, QueryResponse};
pub use service::{Service, ServiceConfig};
