//! Shard workers: each scans a slice of the reference with the suite's
//! cascade + DTW core, collecting its local top-k and abandoning against
//! the *global* shared threshold (the k-th best distance any shard has
//! published).
//!
//! Shards overlap by `qlen - 1` positions implicitly: a shard owns the
//! candidate *start positions* `[start, end)`, while its windows read up to
//! `end + qlen - 1` points — so every window is scanned by exactly one
//! shard and none is missed (tested in `integration_coordinator`).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use crate::bounds::batch::{CohortScratch, DEFAULT_STRIP};
use crate::coordinator::state::{CancelToken, SharedUb};
use crate::fault;
use crate::index::ref_index::BucketStats;
use crate::index::topk::TopK;
use crate::metrics::Counters;
use crate::obs::{ObsCell, ScanObs};
use crate::search::cohort::{scan_cohort_topk_obs, CohortMember, CohortPool};
use crate::search::subsequence::{
    scan_topk_policy_mode_obs, DataEnvelopes, Match, QueryContext, ScanMode, ScanStats,
};
use crate::search::suite::Suite;

/// How many candidate positions a worker scans between synchronisations
/// with the shared threshold (scalar mode; strip mode syncs per strip).
pub const DEFAULT_SYNC_EVERY: usize = 1024;

/// Scan shard `[start, end)` in blocks, collecting the local top-k and
/// syncing the threshold with `shared` between blocks: a full local heap
/// publishes its k-th best (a valid upper bound on the global k-th best,
/// since the union already holds k results at or below it), and adopts
/// whatever tighter value other shards published — the serving analogue
/// of the paper's upper-bound tightening, generalised to k results.
///
/// In [`ScanMode::Strip`] the sync block *is* the strip: every strip
/// adopts the freshest cross-shard threshold for its batch bounds and
/// publishes its tightened k-th best as soon as its survivors are scored,
/// so LB-ordered tightening propagates across shards at strip granularity.
/// Note that without a `stats` table the streaming recurrence restarts at
/// every block boundary (64 positions here vs `sync_every` in scalar
/// mode), so the streaming fallback's window statistics — and therefore
/// distances — match the scalar shard's only to fp tolerance; pass the
/// shared [`BucketStats`] (the engine/service path always does) for
/// mode-independent, bit-identical results.
#[allow(clippy::too_many_arguments)]
pub fn scan_shard_topk(
    reference: &[f64],
    start: usize,
    end: usize,
    ctx: &mut QueryContext,
    denv: Option<&DataEnvelopes>,
    stats: Option<&BucketStats>,
    suite: Suite,
    mode: ScanMode,
    k: usize,
    shared: &SharedUb,
    sync_every: usize,
    counters: &mut Counters,
) -> TopK {
    scan_shard_topk_obs(
        reference,
        start,
        end,
        ctx,
        denv,
        stats,
        suite,
        mode,
        k,
        shared,
        sync_every,
        counters,
        None,
        None,
        ScanObs::OFF,
    )
    .0
}

/// [`scan_shard_topk`] with an observability handle, an optional
/// deadline and an optional cancellation token — the worker-loop entry,
/// so scan-stage latencies land in the worker's registry cell. Attaching
/// a cell changes no result bit.
///
/// Deadline and cancellation are honoured at block boundaries — in
/// [`ScanMode::Strip`] the block *is* the strip, so this is the strip
/// boundary the deadline contract names; in [`ScanMode::Scalar`] the
/// granularity is `sync_every` positions. Every block that ran is
/// complete, so counter conservation holds on truncated scans. Returns
/// the local top-k plus whether the scan was truncated. With
/// `deadline = None` and `cancel = None` no clock is read and the scan
/// is bitwise-identical to the pre-deadline worker.
#[allow(clippy::too_many_arguments)]
pub fn scan_shard_topk_obs(
    reference: &[f64],
    start: usize,
    end: usize,
    ctx: &mut QueryContext,
    denv: Option<&DataEnvelopes>,
    stats: Option<&BucketStats>,
    suite: Suite,
    mode: ScanMode,
    k: usize,
    shared: &SharedUb,
    sync_every: usize,
    counters: &mut Counters,
    deadline: Option<Instant>,
    cancel: Option<&CancelToken>,
    obs: ScanObs<'_>,
) -> (TopK, bool) {
    let n = ctx.len();
    let end = end.min(reference.len().saturating_sub(n) + 1);
    let block = match mode {
        ScanMode::Scalar => sync_every.max(1),
        ScanMode::Strip => DEFAULT_STRIP.min(sync_every.max(1)),
    };
    let mut topk = TopK::new(k);
    let mut truncated = false;
    let mut block_start = start;
    while block_start < end {
        if deadline.is_some_and(|d| Instant::now() >= d)
            || cancel.is_some_and(|c| c.is_cancelled())
        {
            truncated = true;
            break;
        }
        let block_end = (block_start + block).min(end);
        topk.set_bound(shared.get());
        let src = match stats {
            Some(table) => ScanStats::Indexed(table),
            None => ScanStats::Streaming,
        };
        scan_topk_policy_mode_obs(
            reference,
            block_start,
            block_end,
            ctx,
            denv,
            src,
            suite,
            suite.cascade(),
            mode,
            &mut topk,
            counters,
            obs,
        );
        if let Some(kth) = topk.kth_dist() {
            shared.tighten(kth);
        }
        block_start = block_end;
    }
    (topk, truncated)
}

/// The scalar (`k = 1`) shard scan the seed exposed; returns the shard's
/// best match strictly below the bounds seen, or `None`.
#[allow(clippy::too_many_arguments)]
pub fn scan_shard(
    reference: &[f64],
    start: usize,
    end: usize,
    ctx: &mut QueryContext,
    denv: Option<&DataEnvelopes>,
    suite: Suite,
    shared: &SharedUb,
    sync_every: usize,
    counters: &mut Counters,
) -> Option<Match> {
    scan_shard_topk(
        reference,
        start,
        end,
        ctx,
        denv,
        None,
        suite,
        ScanMode::Scalar,
        1,
        shared,
        sync_every,
        counters,
    )
    .into_sorted()
    .into_iter()
    .next()
}

/// A unit of work dispatched to a worker thread: one shard of one query,
/// or one shard of a whole query cohort.
pub enum WorkItem {
    Single(Job),
    Cohort(CohortJob),
}

/// A shard's successful contribution to one query: its local top-k
/// (ascending), its counters, and whether a deadline or cancellation
/// truncated its scan at a block boundary.
pub struct ShardOk {
    pub matches: Vec<Match>,
    pub counters: Counters,
    pub truncated: bool,
}

/// What a worker sends back for one shard of a single-query job: `Ok` is
/// the shard's result (possibly truncated), `Err` carries the panic
/// message when the scan panicked inside the worker's panic domain — so
/// fan-in always receives `shards` replies and can never deadlock on a
/// poisoned worker.
pub type ShardReply = Result<ShardOk, String>;

/// The cohort analogue of [`ShardReply`]: one [`ShardOk`] per member (in
/// cohort order), or the panic message that took the whole shard pass
/// down.
pub type CohortShardReply = Result<Vec<ShardOk>, String>;

/// One shard of a **query-cohort** scan: the worker runs one strip-major
/// pass over `[start, end)` serving every member at once
/// ([`crate::search::cohort::scan_cohort_topk`]); each member carries its
/// own private cross-shard threshold, so per-query semantics are exactly
/// those of a [`Job`]-per-query fan-out.
pub struct CohortJob {
    pub reference: Arc<Vec<f64>>,
    pub start: usize,
    pub end: usize,
    /// one (fresh context, cross-shard threshold, deadline) triple per
    /// cohort member, in cohort order — contexts are built pooled
    /// ([`QueryContext::with_metric_pooled`]): the worker's shared
    /// [`CohortPool`] provides the kernel buffers. A member's deadline is
    /// checked at its strip boundaries; `None` reads no clock.
    pub members: Vec<(QueryContext, Arc<SharedUb>, Option<Instant>)>,
    /// reference envelopes served by the shared index (cohorts always
    /// run over an indexed reference)
    pub denv: Option<Arc<DataEnvelopes>>,
    /// precomputed window stats — mandatory: the shared strip loads are
    /// the point of the cohort scan
    pub stats: Arc<BucketStats>,
    pub suite: Suite,
    /// how many results each member wants
    pub k: usize,
    pub sync_every: usize,
    /// set by the router when it gives up on this cohort's fan-in: the
    /// scan stops at its next strip boundary
    pub cancel: Option<Arc<CancelToken>>,
    /// one [`ShardOk`] per member in cohort order, or the panic message
    pub reply: Sender<CohortShardReply>,
}

/// A unit of shard work dispatched to a worker thread.
pub struct Job {
    pub reference: Arc<Vec<f64>>,
    pub start: usize,
    pub end: usize,
    /// fresh context for this query (each worker owns its buffers)
    pub ctx: QueryContext,
    /// reference envelopes — per-query or served by the shared index
    pub denv: Option<Arc<DataEnvelopes>>,
    /// precomputed window stats from the shared index (`None` = stream)
    pub stats: Option<Arc<BucketStats>>,
    pub suite: Suite,
    /// scan front-end this shard runs (strip-mined or the legacy scalar)
    pub scan_mode: ScanMode,
    /// how many results the query wants
    pub k: usize,
    pub shared: Arc<SharedUb>,
    pub sync_every: usize,
    /// optional deadline budget, checked at block boundaries
    pub deadline: Option<Instant>,
    /// set by the router when it gives up on this query's fan-in
    pub cancel: Option<Arc<CancelToken>>,
    /// this shard's result (or the panic message that killed the job)
    pub reply: Sender<ShardReply>,
}

/// Decrements the busy gauge on drop, so it survives panics unwinding
/// through the job body and early returns (injected worker death).
struct BusyGuard<'a>(&'a AtomicU64);

impl<'a> BusyGuard<'a> {
    fn enter(busy: &'a AtomicU64) -> Self {
        busy.fetch_add(1, Ordering::Relaxed);
        Self(busy)
    }
}

impl Drop for BusyGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Human-readable form of a panic payload (panics carry `&str` or
/// `String` in practice; anything else gets a placeholder).
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run one single-query shard job to completion. Factored out of the
/// loop so [`worker_loop`] can wrap it in a panic domain; counters are
/// flushed to the cell only on success, so a panicked job contributes
/// nothing to the registry and the conservation identities stay intact.
fn run_single(mut job: Job, cell: &Option<Arc<ObsCell>>) -> ShardOk {
    if fault::fire(fault::WORKER_PANIC) {
        panic!("injected fault: {}", fault::WORKER_PANIC);
    }
    let obs = ScanObs(cell.as_deref());
    let mut counters = Counters::new();
    let (topk, truncated) = scan_shard_topk_obs(
        &job.reference,
        job.start,
        job.end,
        &mut job.ctx,
        job.denv.as_deref(),
        job.stats.as_deref(),
        job.suite,
        job.scan_mode,
        job.k,
        &job.shared,
        job.sync_every,
        &mut counters,
        job.deadline,
        job.cancel.as_deref(),
        obs,
    );
    if let Some(cell) = cell {
        cell.flush_counters(&counters);
    }
    ShardOk { matches: topk.into_sorted(), counters, truncated }
}

/// Run one cohort shard job to completion (the cohort analogue of
/// [`run_single`]).
fn run_cohort(
    job: CohortJob,
    pool: &mut CohortPool,
    scratch: &mut CohortScratch,
    cell: &Option<Arc<ObsCell>>,
) -> Vec<ShardOk> {
    if fault::fire(fault::WORKER_PANIC) {
        panic!("injected fault: {}", fault::WORKER_PANIC);
    }
    let obs = ScanObs(cell.as_deref());
    let mut members: Vec<CohortMember> = job
        .members
        .into_iter()
        .map(|(ctx, shared, deadline)| {
            CohortMember::with_shared(ctx, job.k, shared).with_deadline(deadline)
        })
        .collect();
    scan_cohort_topk_obs(
        &job.reference,
        job.start,
        job.end,
        &mut members,
        &job.stats,
        job.denv.as_deref(),
        job.suite,
        job.sync_every,
        scratch,
        pool,
        job.cancel.as_deref(),
        obs,
    );
    if let Some(cell) = cell {
        for m in &members {
            cell.flush_counters(&m.counters);
        }
    }
    members
        .into_iter()
        .map(|m| ShardOk {
            matches: m.topk.into_sorted(),
            counters: m.counters,
            truncated: m.timed_out,
        })
        .collect()
}

/// Worker loop: run jobs until the channel closes. The worker owns one
/// [`CohortPool`] (kernel workspace + z-buffer) and one [`CohortScratch`]
/// (shared stat lanes + per-query bound lanes), reused across every cohort
/// — and every query of every cohort — it ever serves, so the steady
/// state allocates nothing per query.
///
/// `cell` is the worker's shard of the service's
/// [`crate::obs::MetricsRegistry`] (or `None` outside a registry-backed
/// service): the scan records stage latencies through it, and the finished
/// per-job [`Counters`] delta is flushed into it once per job — the single
/// point where scan counters enter the registry.
///
/// **Panic domain.** Each job executes inside `catch_unwind`: a panic in
/// the scan is converted into an `Err(message)` reply (so the router's
/// fan-in completes and maps it to an `internal` error for that query
/// alone), `worker_panics` is bumped on the cell, and the loop keeps
/// serving the next job on the same thread. The pool and scratch buffers
/// are plain capacity with no invariants across jobs — every scan resets
/// them before use — so reusing them after an unwind is sound.
pub fn worker_loop(rx: Receiver<WorkItem>, busy: Arc<AtomicU64>, cell: Option<Arc<ObsCell>>) {
    let mut pool = CohortPool::default();
    let mut scratch = CohortScratch::default();
    while let Ok(item) = rx.recv() {
        let _busy = BusyGuard::enter(&busy);
        // fault sites modelling genuine worker death: the thread returns
        // (its channel closes) or the job is dropped without a reply —
        // either way fan-in sees a disconnected channel, not a hang
        if fault::fire(fault::WORKER_EXIT) {
            return;
        }
        if fault::fire(fault::REPLY_DROP) {
            continue;
        }
        match item {
            WorkItem::Single(job) => {
                // the reply handle survives the panic domain so a panicked
                // job still answers its shard
                let reply = job.reply.clone();
                let outcome = catch_unwind(AssertUnwindSafe(|| run_single(job, &cell)));
                let reply_value = match outcome {
                    Ok(ok) => Ok(ok),
                    Err(payload) => {
                        if let Some(cell) = &cell {
                            cell.add_counter(Counters::SLOT_WORKER_PANICS, 1);
                        }
                        Err(panic_message(payload))
                    }
                };
                // receiver may have given up (service shutdown): ignore
                // send errors
                let _ = reply.send(reply_value);
            }
            WorkItem::Cohort(job) => {
                let reply = job.reply.clone();
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    run_cohort(job, &mut pool, &mut scratch, &cell)
                }));
                let reply_value = match outcome {
                    Ok(oks) => Ok(oks),
                    Err(payload) => {
                        if let Some(cell) = &cell {
                            cell.add_counter(Counters::SLOT_WORKER_PANICS, 1);
                        }
                        Err(panic_message(payload))
                    }
                };
                let _ = reply.send(reply_value);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::distances::metric::Metric;
    use crate::search::subsequence::{
        search_subsequence, search_subsequence_topk, search_subsequence_topk_metric,
    };

    #[test]
    fn scan_shard_with_shared_ub_matches_plain_search() {
        let r = Dataset::Ppg.generate(4000, 3);
        let q = crate::data::extract_queries(&r, 1, 128, 0.1, 5).remove(0);
        let w = 12;
        let suite = Suite::UcrMon;
        let mut cfull = Counters::new();
        let want = search_subsequence(&r, &q, w, suite, &mut cfull);

        let shared = SharedUb::new(f64::INFINITY);
        let denv = DataEnvelopes::new(&r, w);
        let nshards = 4;
        let total = r.len() - q.len() + 1;
        let mut best: Option<Match> = None;
        let mut counters = Counters::new();
        for s in 0..nshards {
            let start = s * total / nshards;
            let end = (s + 1) * total / nshards;
            let mut ctx = QueryContext::new(&q, w);
            if let Some(m) = scan_shard(
                &r, start, end, &mut ctx, Some(&denv), suite, &shared, 256, &mut counters,
            ) {
                if best.is_none_or(|b| m.dist < b.dist) {
                    best = Some(m);
                }
            }
        }
        let got = best.expect("found");
        assert_eq!(got.pos, want.pos);
        assert!((got.dist - want.dist).abs() < 1e-9);
        // shared bound lets later shards prune at least as hard
        assert!(counters.dtw_calls <= cfull.dtw_calls + (nshards as u64) * 4);
    }

    #[test]
    fn sharded_topk_union_equals_full_topk() {
        let r = Dataset::Ecg.generate(3000, 17);
        let q = crate::data::extract_queries(&r, 1, 96, 0.1, 18).remove(0);
        let w = 9;
        let k = 6;
        let suite = Suite::UcrMon;
        let mut cfull = Counters::new();
        let want = search_subsequence_topk(&r, &q, w, k, suite, &mut cfull);

        let table = BucketStats::build(&r, q.len());
        let shared = SharedUb::new(f64::INFINITY);
        let denv = DataEnvelopes::new(&r, w);
        let total = r.len() - q.len() + 1;
        let mut merged = TopK::new(k);
        let mut counters = Counters::new();
        for s in 0..3 {
            let start = s * total / 3;
            let end = (s + 1) * total / 3;
            let mut ctx = QueryContext::new(&q, w);
            let local = scan_shard_topk(
                &r,
                start,
                end,
                &mut ctx,
                Some(&denv),
                Some(&table),
                suite,
                ScanMode::Scalar,
                k,
                &shared,
                512,
                &mut counters,
            );
            merged.merge(local);
        }
        let got = merged.into_sorted();
        assert_eq!(got.len(), want.len());
        for (g, m) in got.iter().zip(&want) {
            assert_eq!(g.pos, m.pos);
            assert!((g.dist - m.dist).abs() < 1e-9);
        }
    }

    #[test]
    fn strip_mode_shards_match_full_scalar_topk() {
        // shards scanning strip-wise (publishing the threshold per strip)
        // return the same union as the full scalar scan, bitwise
        let r = Dataset::Ppg.generate(2600, 31);
        let q = crate::data::extract_queries(&r, 1, 96, 0.1, 32).remove(0);
        let w = 9;
        let k = 5;
        let suite = Suite::UcrMon;
        let mut cfull = Counters::new();
        let want = search_subsequence_topk(&r, &q, w, k, suite, &mut cfull);

        let table = BucketStats::build(&r, q.len());
        let shared = SharedUb::new(f64::INFINITY);
        let denv = DataEnvelopes::new(&r, w);
        let total = r.len() - q.len() + 1;
        let mut merged = TopK::new(k);
        let mut counters = Counters::new();
        for s in 0..3 {
            let start = s * total / 3;
            let end = (s + 1) * total / 3;
            let mut ctx = QueryContext::new(&q, w);
            let local = scan_shard_topk(
                &r,
                start,
                end,
                &mut ctx,
                Some(&denv),
                Some(&table),
                suite,
                ScanMode::Strip,
                k,
                &shared,
                512,
                &mut counters,
            );
            merged.merge(local);
        }
        let got = merged.into_sorted();
        assert_eq!(got.len(), want.len());
        for (g, m) in got.iter().zip(&want) {
            assert_eq!(g.pos, m.pos);
            assert_eq!(g.dist.to_bits(), m.dist.to_bits());
        }
        assert!(counters.strip_batches > 0);
        assert_eq!(counters.candidates, total as u64);
    }

    #[test]
    fn sharded_scan_is_metric_generic() {
        // a bound-free metric through the shard workers: union of local
        // top-k heaps equals the full single-threaded metric scan
        let r = Dataset::FoG.generate(2000, 27);
        let q = crate::data::extract_queries(&r, 1, 64, 0.1, 28).remove(0);
        let w = 6;
        let k = 4;
        let metric = Metric::Twe { nu: 0.05, lambda: 1.0 };
        let suite = Suite::UcrMon;
        let mut cfull = Counters::new();
        let want = search_subsequence_topk_metric(&r, &q, w, k, metric, suite, &mut cfull);
        assert_eq!(want.len(), k);

        let table = BucketStats::build(&r, q.len());
        let shared = SharedUb::new(f64::INFINITY);
        let total = r.len() - q.len() + 1;
        let mut merged = TopK::new(k);
        let mut counters = Counters::new();
        for s in 0..3 {
            let start = s * total / 3;
            let end = (s + 1) * total / 3;
            // no envelopes: the metric cannot use them
            let mut ctx = QueryContext::with_metric(&q, w, metric);
            let local = scan_shard_topk(
                &r,
                start,
                end,
                &mut ctx,
                None,
                Some(&table),
                suite,
                ScanMode::Strip,
                k,
                &shared,
                256,
                &mut counters,
            );
            merged.merge(local);
        }
        let got = merged.into_sorted();
        assert_eq!(got.len(), want.len());
        for (g, m) in got.iter().zip(&want) {
            assert_eq!(g.pos, m.pos);
            assert!((g.dist - m.dist).abs() < 1e-9);
        }
        // all kernel work was tallied under the right metric
        assert_eq!(counters.metric_calls[metric.index()], counters.dtw_calls);
        assert_eq!(counters.lb_kim_prunes + counters.lb_keogh_eq_prunes, 0);
    }
}
