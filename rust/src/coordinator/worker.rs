//! Shard workers: each scans a slice of the reference with the suite's
//! cascade + DTW core, abandoning against the *global* shared upper bound.
//!
//! Shards overlap by `qlen - 1` positions implicitly: a shard owns the
//! candidate *start positions* `[start, end)`, while its windows read up to
//! `end + qlen - 1` points — so every window is scanned by exactly one
//! shard and none is missed (tested in `integration_coordinator`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use crate::coordinator::state::SharedUb;
use crate::metrics::Counters;
use crate::search::subsequence::{scan, DataEnvelopes, Match, QueryContext};
use crate::search::suite::Suite;

/// How many candidate positions a worker scans between synchronisations
/// with the shared upper bound.
pub const DEFAULT_SYNC_EVERY: usize = 1024;

/// Scan shard `[start, end)` in blocks, syncing the upper bound with
/// `shared` between blocks: improvements flow both ways (the serving
/// analogue of upper-bound tightening).
#[allow(clippy::too_many_arguments)]
pub fn scan_shard(
    reference: &[f64],
    start: usize,
    end: usize,
    ctx: &mut QueryContext,
    denv: Option<&DataEnvelopes>,
    suite: Suite,
    shared: &SharedUb,
    sync_every: usize,
    counters: &mut Counters,
) -> Option<Match> {
    let n = ctx.len();
    let end = end.min(reference.len().saturating_sub(n) + 1);
    let mut best: Option<Match> = None;
    let mut block_start = start;
    while block_start < end {
        let block_end = (block_start + sync_every).min(end);
        // local best-so-far = global, tightened by our own best
        let bsf = shared.get().min(best.map_or(f64::INFINITY, |m| m.dist));
        if let Some(m) = scan(
            reference, block_start, block_end, ctx, denv, suite, bsf, counters,
        ) {
            if best.is_none_or(|b| m.dist < b.dist) {
                best = Some(m);
                shared.tighten(m.dist);
            }
        }
        block_start = block_end;
    }
    best
}

/// A unit of shard work dispatched to a worker thread.
pub struct Job {
    pub reference: Arc<Vec<f64>>,
    pub start: usize,
    pub end: usize,
    /// fresh context for this query (each worker owns its buffers)
    pub ctx: QueryContext,
    pub denv: Option<Arc<DataEnvelopes>>,
    pub suite: Suite,
    pub shared: Arc<SharedUb>,
    pub sync_every: usize,
    pub reply: Sender<(Option<Match>, Counters)>,
}

/// Worker loop: run jobs until the channel closes.
pub fn worker_loop(rx: Receiver<Job>, busy: Arc<AtomicU64>) {
    while let Ok(mut job) = rx.recv() {
        busy.fetch_add(1, Ordering::Relaxed);
        let mut counters = Counters::new();
        let m = scan_shard(
            &job.reference,
            job.start,
            job.end,
            &mut job.ctx,
            job.denv.as_deref(),
            job.suite,
            &job.shared,
            job.sync_every,
            &mut counters,
        );
        // receiver may have given up (service shutdown): ignore send errors
        let _ = job.reply.send((m, counters));
        busy.fetch_sub(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::search::subsequence::search_subsequence;

    #[test]
    fn scan_shard_with_shared_ub_matches_plain_search() {
        let r = Dataset::Ppg.generate(4000, 3);
        let q = crate::data::extract_queries(&r, 1, 128, 0.1, 5).remove(0);
        let w = 12;
        let suite = Suite::UcrMon;
        let mut cfull = Counters::new();
        let want = search_subsequence(&r, &q, w, suite, &mut cfull);

        let shared = SharedUb::new(f64::INFINITY);
        let denv = DataEnvelopes::new(&r, w);
        let nshards = 4;
        let total = r.len() - q.len() + 1;
        let mut best: Option<Match> = None;
        let mut counters = Counters::new();
        for s in 0..nshards {
            let start = s * total / nshards;
            let end = (s + 1) * total / nshards;
            let mut ctx = QueryContext::new(&q, w);
            if let Some(m) = scan_shard(
                &r, start, end, &mut ctx, Some(&denv), suite, &shared, 256, &mut counters,
            ) {
                if best.is_none_or(|b| m.dist < b.dist) {
                    best = Some(m);
                }
            }
        }
        let got = best.expect("found");
        assert_eq!(got.pos, want.pos);
        assert!((got.dist - want.dist).abs() < 1e-9);
        // shared bound lets later shards prune at least as hard
        assert!(counters.dtw_calls <= cfull.dtw_calls + (nshards as u64) * 4);
    }
}
