//! Shared search state: the global abandon threshold and the
//! cross-thread cancellation flag.
//!
//! [`SharedUb`] is the serving-layer analogue of the paper's upper-bound
//! tightening, generalised to top-k: every shard worker abandons against
//! the tightest *k-th best* distance any shard has published (a shard
//! whose local heap holds k results publishes its k-th best — the union
//! of all shards then has at least k results at or below it, so the
//! value is a valid global cutoff; with k = 1 this degenerates to the
//! seed's shared best-so-far). Implemented as an atomic f64 (bits in an
//! `AtomicU64`) — lock-free on the hot path.
//!
//! [`CancelToken`] extends the same idea from distances to whole
//! queries: when the router gives up on a query (its deadline expired
//! during fan-in), it cancels the token so shards still scanning for it
//! stop at their next strip boundary instead of finishing work nobody
//! will read.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Lock-free shared upper bound (monotonically non-increasing).
#[derive(Debug)]
pub struct SharedUb {
    bits: AtomicU64,
}

impl SharedUb {
    pub fn new(init: f64) -> Arc<Self> {
        Arc::new(Self { bits: AtomicU64::new(init.to_bits()) })
    }

    /// Current bound.
    #[inline]
    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Acquire))
    }

    /// Tighten to `v` if it improves the bound; returns `true` if this call
    /// lowered it. Monotonicity is preserved under races (CAS loop).
    pub fn tighten(&self, v: f64) -> bool {
        let mut cur = self.bits.load(Ordering::Acquire);
        loop {
            if v >= f64::from_bits(cur) {
                return false;
            }
            match self.bits.compare_exchange_weak(
                cur,
                v.to_bits(),
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return true,
                Err(now) => cur = now,
            }
        }
    }
}

/// One-way cross-thread cancellation flag: set once by the router when a
/// query's deadline expires mid-fan-in, observed by shard workers at
/// strip boundaries. Relaxed ordering is sufficient — cancellation is
/// advisory (a shard that misses the flag merely finishes its strip) and
/// carries no data dependency.
#[derive(Debug, Default)]
pub struct CancelToken {
    flag: AtomicBool,
}

impl CancelToken {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Request cancellation (idempotent).
    #[inline]
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Relaxed);
    }

    #[inline]
    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tighten_monotone() {
        let ub = SharedUb::new(f64::INFINITY);
        assert!(ub.tighten(10.0));
        assert!(!ub.tighten(12.0));
        assert!(ub.tighten(5.0));
        assert_eq!(ub.get(), 5.0);
    }

    #[test]
    fn concurrent_tighten_keeps_minimum() {
        let ub = SharedUb::new(f64::INFINITY);
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let ub = ub.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    ub.tighten(((t * 1000 + i) % 977) as f64 + 1.0);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(ub.get(), 1.0);
    }

    #[test]
    fn cancel_token_is_one_way_and_visible_across_threads() {
        let token = CancelToken::new();
        assert!(!token.is_cancelled());
        let t = {
            let token = Arc::clone(&token);
            std::thread::spawn(move || token.cancel())
        };
        t.join().unwrap();
        assert!(token.is_cancelled());
        token.cancel(); // idempotent
        assert!(token.is_cancelled());
    }
}
