//! Network front-ends for the search service.
//!
//! Two wire front-ends share one newline-delimited JSON protocol and one
//! hostile-input discipline:
//!
//! * [`serve_frames`] — the `--stdin` loop: frames from any `Read`
//!   through the bounded [`FrameReader`], each answered with exactly one
//!   line via `Service::handle_line` (oversized frames get a typed
//!   `frame_too_large` error and the reader resyncs at the next newline).
//! * [`NetServer`] — the `--listen` TCP server: bounded connection
//!   registry, per-connection read/idle budgets, write backpressure,
//!   per-tenant token-bucket quotas ([`TenantQuotas`]) and graceful
//!   drain. See `net/README.md` for the lifecycle and `server.rs` for
//!   the thread layout.
//!
//! Both paths end in the same coalescer → `Service::submit_batch_timed`
//! pipeline, so responses are byte-identical to in-process serving
//! (wall-clock timing fields aside).

pub mod frame;
pub mod quota;
pub mod server;

pub use frame::{FrameEvent, FrameReader};
pub use quota::TenantQuotas;
pub use server::{NetConfig, NetServer};

use std::io::{Read, Write};

use crate::coordinator::protocol::ErrorResponse;
use crate::coordinator::Service;

/// Serve newline-delimited frames from `input`, answering each with
/// exactly one line on `output` — the hardened replacement for a bare
/// `read_line` loop. Frames over `max_frame_bytes` are answered with a
/// typed `frame_too_large` error line (`"id":null`) and the stream
/// resyncs at the next newline; blank frames are skipped. With
/// `stats_every > 0` a metrics snapshot goes to stderr after every that
/// many responses and once more at end of input. Returns the number of
/// frames answered.
pub fn serve_frames<R: Read, W: Write>(
    svc: &Service,
    input: R,
    output: &mut W,
    max_frame_bytes: usize,
    stats_every: usize,
) -> std::io::Result<u64> {
    let mut fr = FrameReader::new(input, max_frame_bytes);
    let mut answered = 0u64;
    let mut since_stats = 0usize;
    loop {
        let reply = match fr.next_frame()? {
            FrameEvent::Frame(line) => {
                if line.is_empty() {
                    continue;
                }
                svc.handle_line(&line)
            }
            FrameEvent::TooLarge(e) => {
                // one reply per frame holds even for a frame we refused
                // to buffer; there is no id to echo, so it answers null
                ErrorResponse::for_line("", &anyhow::Error::new(e)).to_json()
            }
            FrameEvent::Eof => break,
        };
        writeln!(output, "{reply}")?;
        output.flush()?;
        answered += 1;
        since_stats += 1;
        if stats_every > 0 && since_stats >= stats_every {
            eprintln!("{}", svc.stats_json());
            since_stats = 0;
        }
    }
    if stats_every > 0 {
        eprintln!("{}", svc.stats_json());
    }
    Ok(answered)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{ErrorKind, QueryRequest, QueryResponse};
    use crate::coordinator::ServiceConfig;
    use crate::data::Dataset;
    use crate::distances::metric::Metric;
    use crate::search::suite::Suite;
    use std::io::Cursor;

    #[test]
    fn serve_frames_answers_every_frame_exactly_once() {
        let r = Dataset::Ecg.generate(1500, 91);
        let q = crate::data::extract_queries(&r, 1, 64, 0.1, 92).remove(0);
        let svc = Service::new(r, &ServiceConfig::default()).unwrap();
        let req = QueryRequest {
            id: 3,
            query: q,
            window_ratio: 0.1,
            suite: Suite::UcrMon,
            k: 1,
            metric: Metric::Cdtw,
            deadline_ms: None,
            tenant: None,
        };
        let oversized = format!("{{\"id\":1,\"pad\":\"{}\"}}", "x".repeat(300));
        let session = format!(
            "{}\nnot json\n\n{}\n{{\"cmd\":\"stats\"}}\n{}",
            req.to_json(),
            oversized,
            req.to_json(), // unterminated final line still gets served
        );
        let mut out = Vec::new();
        let n = serve_frames(&svc, Cursor::new(session.into_bytes()), &mut out, 256, 0).unwrap();
        let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().trim_end().lines().collect();
        // 5 non-blank frames in, exactly 5 replies out (blank line: none)
        assert_eq!(n, 5);
        assert_eq!(lines.len(), 5);
        // 1: served, identical to the in-process path modulo wall clocks
        let normalized = |line: &str| match crate::util::json::Json::parse(line).unwrap() {
            crate::util::json::Json::Obj(mut m) => {
                m.remove("latency_ms");
                m.remove("queue_ms");
                crate::util::json::Json::Obj(m).to_string()
            }
            other => other.to_string(),
        };
        assert_eq!(normalized(lines[0]), normalized(&svc.handle_line(&req.to_json())));
        assert_eq!(QueryResponse::from_json(lines[0]).unwrap().id, 3);
        // 2: junk answers id:null, session continues
        let junk = ErrorResponse::from_json(lines[1]).unwrap();
        assert_eq!(junk.id, None);
        // 3: the oversized frame answers frame_too_large without growing
        // the buffer, and the reader resyncs
        let big = ErrorResponse::from_json(lines[2]).unwrap();
        assert_eq!(big.kind, Some(ErrorKind::FrameTooLarge), "{}", lines[2]);
        assert_eq!(big.id, None);
        // 4: stats from the live registry
        assert!(lines[3].contains("repro.metrics.v1"));
        // 5: the unterminated tail query is still answered
        assert_eq!(QueryResponse::from_json(lines[4]).unwrap().id, 3);
    }
}
