//! Bounded newline framing over any `Read` — the one framed reader both
//! wire front-ends share (`--listen` TCP connections and the `--stdin`
//! serve loop), so the hostile-input guarantees hold identically on both
//! paths:
//!
//! * **Memory is bounded.** The internal buffer never holds more than
//!   `max_frame_bytes` + one read chunk: a frame that exceeds the cap is
//!   reported as [`FrameEvent::TooLarge`] the moment the cap is crossed
//!   and its remaining bytes are *discarded*, never accumulated — an
//!   unbounded line cannot grow a buffer the way a bare
//!   `BufRead::read_line` would.
//! * **Timeouts are resumable.** A read error (`WouldBlock` /
//!   `TimedOut` from a socket read-timeout tick) propagates to the
//!   caller with all buffered progress preserved; the caller decides
//!   whether the connection is idle, mid-frame within budget, or due to
//!   be cut, then calls [`FrameReader::next_frame`] again.
//! * **Resync is automatic.** After a `TooLarge` report the reader is in
//!   skip mode: subsequent calls discard bytes (without buffering) until
//!   the oversized frame's terminating newline, then resume normal
//!   framing — the stdin loop keeps serving, while the TCP path simply
//!   closes the connection instead.
//!
//! A trailing `\r` is stripped from each frame (telnet-friendliness) and
//! an unterminated final line before EOF is delivered as a frame, the
//! same behaviour `read_line` gave the legacy loop.

use std::io::{self, Read};

use crate::coordinator::protocol::FrameTooLarge;

/// One step of the framed reader.
#[derive(Debug, PartialEq, Eq)]
pub enum FrameEvent {
    /// A complete frame (delimiter stripped).
    Frame(String),
    /// The current frame crossed `max_frame_bytes`; the oversized bytes
    /// were discarded and the reader will resync at the next newline.
    TooLarge(FrameTooLarge),
    /// End of input.
    Eof,
}

/// Bounded, resumable newline framer. See the module docs for the
/// guarantees.
#[derive(Debug)]
pub struct FrameReader<R> {
    inner: R,
    /// bytes read but not yet framed (≤ max_frame + one chunk)
    buf: Vec<u8>,
    /// prefix of `buf` already scanned and known newline-free
    scanned: usize,
    max_frame: usize,
    /// discarding an oversized frame through its terminating newline
    skipping: bool,
    /// bytes discarded so far in skip mode (for the error report)
    discarded: usize,
    eof: bool,
}

impl<R: Read> FrameReader<R> {
    pub fn new(inner: R, max_frame_bytes: usize) -> Self {
        Self {
            inner,
            buf: Vec::new(),
            scanned: 0,
            max_frame: max_frame_bytes.max(1),
            skipping: false,
            discarded: 0,
            eof: false,
        }
    }

    /// Bytes buffered toward an incomplete frame.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Is the reader holding a partial frame (including an oversized one
    /// still being discarded)? Timeout policy branches on this: buffered
    /// progress means a slow *frame* (read-timeout budget), an empty
    /// buffer means an idle connection (idle-timeout budget).
    pub fn mid_frame(&self) -> bool {
        !self.buf.is_empty() || self.skipping
    }

    /// Read until one [`FrameEvent`] is available. I/O errors (including
    /// socket timeout ticks) propagate with buffered progress intact.
    pub fn next_frame(&mut self) -> io::Result<FrameEvent> {
        let mut chunk = [0u8; 4096];
        loop {
            // resolve what is already buffered before reading more
            if let Some(rel) = self.buf[self.scanned..].iter().position(|&b| b == b'\n') {
                let pos = self.scanned + rel;
                let mut line: Vec<u8> = self.buf.drain(..=pos).collect();
                self.scanned = 0;
                line.pop(); // the \n
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                if self.skipping {
                    // the oversized frame (already reported) just ended:
                    // resync complete, resume normal framing
                    self.skipping = false;
                    self.discarded = 0;
                    continue;
                }
                if line.len() > self.max_frame {
                    // whole frame arrived in one gulp but is over the cap
                    return Ok(FrameEvent::TooLarge(FrameTooLarge {
                        len: line.len(),
                        limit: self.max_frame,
                    }));
                }
                return Ok(FrameEvent::Frame(String::from_utf8_lossy(&line).into_owned()));
            }
            // no newline buffered
            if self.skipping {
                // keep memory flat while discarding the oversized frame
                self.discarded = self.discarded.saturating_add(self.buf.len());
                self.buf.clear();
                self.scanned = 0;
            } else if self.buf.len() > self.max_frame {
                // cap crossed with no delimiter in sight: report now,
                // discard what we hold, resync from the next newline
                let len = self.buf.len();
                self.buf.clear();
                self.scanned = 0;
                self.skipping = true;
                self.discarded = len;
                return Ok(FrameEvent::TooLarge(FrameTooLarge { len, limit: self.max_frame }));
            } else {
                self.scanned = self.buf.len();
            }
            if self.eof {
                return Ok(FrameEvent::Eof);
            }
            let n = self.inner.read(&mut chunk)?;
            if n == 0 {
                self.eof = true;
                if self.skipping {
                    // the oversized frame (already reported) never ended
                    self.skipping = false;
                    self.discarded = 0;
                    return Ok(FrameEvent::Eof);
                }
                if !self.buf.is_empty() {
                    // unterminated final line: deliver it as a frame,
                    // matching read_line's end-of-input behaviour
                    let mut line = std::mem::take(&mut self.buf);
                    self.scanned = 0;
                    if line.last() == Some(&b'\r') {
                        line.pop();
                    }
                    if line.len() > self.max_frame {
                        return Ok(FrameEvent::TooLarge(FrameTooLarge {
                            len: line.len(),
                            limit: self.max_frame,
                        }));
                    }
                    return Ok(FrameEvent::Frame(String::from_utf8_lossy(&line).into_owned()));
                }
                return Ok(FrameEvent::Eof);
            }
            self.buf.extend_from_slice(&chunk[..n]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn frames(input: &str, cap: usize) -> Vec<FrameEvent> {
        let mut fr = FrameReader::new(Cursor::new(input.as_bytes().to_vec()), cap);
        let mut out = Vec::new();
        loop {
            let ev = fr.next_frame().unwrap();
            let done = ev == FrameEvent::Eof;
            out.push(ev);
            if done {
                return out;
            }
        }
    }

    #[test]
    fn frames_split_on_newlines_and_strip_cr() {
        assert_eq!(
            frames("a\nbb\r\nccc", 100),
            vec![
                FrameEvent::Frame("a".into()),
                FrameEvent::Frame("bb".into()),
                // unterminated tail is still a frame, like read_line
                FrameEvent::Frame("ccc".into()),
                FrameEvent::Eof,
            ]
        );
        assert_eq!(frames("", 100), vec![FrameEvent::Eof]);
        // empty frames are delivered (the serve loop decides what to do)
        assert_eq!(
            frames("\n", 100),
            vec![FrameEvent::Frame(String::new()), FrameEvent::Eof]
        );
    }

    #[test]
    fn oversized_frame_reports_once_and_resyncs() {
        let evs = frames("ok\nxxxxxxxxxxxxxxxxxxxx\nafter\n", 8);
        assert_eq!(evs[0], FrameEvent::Frame("ok".into()));
        match &evs[1] {
            FrameEvent::TooLarge(e) => {
                assert!(e.len >= 8, "{e:?}");
                assert_eq!(e.limit, 8);
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
        // exactly one report, then the stream resumes cleanly
        assert_eq!(evs[2], FrameEvent::Frame("after".into()));
        assert_eq!(evs[3], FrameEvent::Eof);
        assert_eq!(evs.len(), 4);
    }

    #[test]
    fn oversized_frame_memory_stays_bounded() {
        // a 1 MiB newline-free flood against a 64-byte cap: the buffer
        // must never hold more than cap + one chunk
        let flood = vec![b'z'; 1 << 20];
        let mut fr = FrameReader::new(Cursor::new(flood), 64);
        let mut saw_too_large = false;
        loop {
            match fr.next_frame().unwrap() {
                FrameEvent::TooLarge(_) => saw_too_large = true,
                FrameEvent::Eof => break,
                FrameEvent::Frame(f) => panic!("no frame expected, got {} bytes", f.len()),
            }
            assert!(fr.buffered() <= 64 + 4096, "buffer grew to {}", fr.buffered());
        }
        assert!(saw_too_large);
        assert!(fr.buffered() <= 64 + 4096);
    }

    /// A reader that yields its scripted chunks one at a time, with a
    /// WouldBlock "timeout" between them — the socket-tick shape.
    struct Chunked {
        chunks: Vec<Option<Vec<u8>>>, // None = timeout tick
        i: usize,
    }

    impl Read for Chunked {
        fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
            if self.i >= self.chunks.len() {
                return Ok(0);
            }
            let i = self.i;
            self.i += 1;
            match &self.chunks[i] {
                None => Err(io::Error::new(io::ErrorKind::WouldBlock, "tick")),
                Some(c) => {
                    let n = c.len().min(out.len());
                    out[..n].copy_from_slice(&c[..n]);
                    assert_eq!(n, c.len(), "test chunks fit the read buffer");
                    Ok(n)
                }
            }
        }
    }

    #[test]
    fn timeouts_preserve_partial_frames_across_calls() {
        let mut fr = FrameReader::new(
            Chunked {
                chunks: vec![
                    Some(b"{\"id\":".to_vec()),
                    None, // tick mid-frame
                    Some(b"1}\nrest\n".to_vec()),
                ],
                i: 0,
            },
            100,
        );
        // first call buffers the partial frame, then surfaces the tick
        let err = fr.next_frame().unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::WouldBlock);
        assert!(fr.mid_frame());
        assert_eq!(fr.buffered(), 6);
        // the retry completes the frame from the preserved progress
        assert_eq!(fr.next_frame().unwrap(), FrameEvent::Frame("{\"id\":1}".into()));
        assert_eq!(fr.next_frame().unwrap(), FrameEvent::Frame("rest".into()));
        assert_eq!(fr.next_frame().unwrap(), FrameEvent::Eof);
    }

    #[test]
    fn idle_ticks_report_no_frame_in_progress() {
        let mut fr = FrameReader::new(Chunked { chunks: vec![None], i: 0 }, 100);
        assert_eq!(fr.next_frame().unwrap_err().kind(), io::ErrorKind::WouldBlock);
        assert!(!fr.mid_frame(), "nothing buffered: the connection is idle, not slow");
    }

    #[test]
    fn unterminated_oversized_tail_reports_then_eof() {
        let evs = frames("tiny\nwaaaaaaaaaaaay-too-long-no-newline", 8);
        assert_eq!(evs[0], FrameEvent::Frame("tiny".into()));
        assert!(matches!(evs[1], FrameEvent::TooLarge(_)), "{evs:?}");
        assert_eq!(evs[2], FrameEvent::Eof);
    }
}
