//! Per-tenant token-bucket quotas for the network front-end.
//!
//! Each tenant (the optional `tenant` wire field; absent = the anonymous
//! bucket) gets a bucket of `burst` tokens refilled continuously at
//! `rate` tokens/second. A query costs one token; an empty bucket sheds
//! the query *before any scan work* with a typed `quota` error carrying
//! `retry_after_ms` — the milliseconds until the bucket is guaranteed to
//! hold a whole token again, so a client honouring it never burns a
//! retry.
//!
//! The table is clock-injected (`Instant` parameters, no internal
//! `now()` calls) like the batch coalescer, so tests drive it with
//! synthetic time. It is also *bounded*: a hostile client minting fresh
//! tenant names cannot grow the map past [`TenantQuotas::MAX_TENANTS`] —
//! beyond that the stalest bucket is evicted, which is lossless for the
//! evicted tenant (an untouched bucket refills to full long before it
//! is stale enough to evict, so it comes back full).

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

#[derive(Debug, Clone, Copy)]
struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Shared token-bucket table; `try_acquire` is called by every
/// connection reader thread, so the map sits behind one mutex (held for
/// a few arithmetic ops per frame — far off any scan path).
#[derive(Debug)]
pub struct TenantQuotas {
    /// tokens per second; <= 0 disables quotas entirely
    rate: f64,
    /// bucket capacity (burst size), >= 1 when enabled
    burst: f64,
    buckets: Mutex<HashMap<String, Bucket>>,
}

impl TenantQuotas {
    /// Hard cap on tracked tenants (hostile-client bound).
    pub const MAX_TENANTS: usize = 10_000;

    pub fn new(rate_per_sec: f64, burst: f64) -> Self {
        Self {
            rate: if rate_per_sec.is_finite() { rate_per_sec } else { 0.0 },
            burst: burst.max(1.0),
            buckets: Mutex::new(HashMap::new()),
        }
    }

    /// Quotas configured at all? When false, `try_acquire` is free and
    /// always admits.
    pub fn enabled(&self) -> bool {
        self.rate > 0.0
    }

    /// Spend one token from `tenant`'s bucket at time `now`. On refusal
    /// returns the milliseconds after which a retry is guaranteed to
    /// find a whole token (>= 1).
    pub fn try_acquire(&self, tenant: &str, now: Instant) -> Result<(), u64> {
        if !self.enabled() {
            return Ok(());
        }
        let mut map = self.buckets.lock().unwrap_or_else(|p| p.into_inner());
        if map.len() >= Self::MAX_TENANTS && !map.contains_key(tenant) {
            // evict the stalest bucket to stay bounded; O(n) but only on
            // the shed-adjacent path of a pathological tenant flood
            if let Some(stalest) = map.iter().min_by_key(|(_, b)| b.last).map(|(k, _)| k.clone())
            {
                map.remove(&stalest);
            }
        }
        let b = map
            .entry(tenant.to_string())
            .or_insert(Bucket { tokens: self.burst, last: now });
        let dt = now.saturating_duration_since(b.last).as_secs_f64();
        b.tokens = (b.tokens + dt * self.rate).min(self.burst);
        b.last = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            Ok(())
        } else {
            let ms = ((1.0 - b.tokens) / self.rate * 1000.0).ceil() as u64;
            Err(ms.max(1))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn burst_then_shed_then_refill() {
        let q = TenantQuotas::new(10.0, 3.0); // 10 tokens/s, burst 3
        assert!(q.enabled());
        let t0 = Instant::now();
        // a fresh bucket starts full: the burst is admitted
        for _ in 0..3 {
            assert_eq!(q.try_acquire("acme", t0), Ok(()));
        }
        // the 4th query at the same instant is shed, with the exact
        // refill horizon: 1 token at 10/s = 100ms
        assert_eq!(q.try_acquire("acme", t0), Err(100));
        // honouring retry_after_ms is sufficient: the retry is admitted
        assert_eq!(q.try_acquire("acme", t0 + Duration::from_millis(100)), Ok(()));
        // …and the bucket never exceeds its burst, however long idle
        let later = t0 + Duration::from_secs(3600);
        for _ in 0..3 {
            assert_eq!(q.try_acquire("acme", later), Ok(()));
        }
        assert!(q.try_acquire("acme", later).is_err());
    }

    #[test]
    fn tenants_have_independent_buckets() {
        let q = TenantQuotas::new(1.0, 1.0);
        let t0 = Instant::now();
        assert_eq!(q.try_acquire("a", t0), Ok(()));
        assert!(q.try_acquire("a", t0).is_err(), "a is spent");
        // b (and the anonymous bucket "") are unaffected
        assert_eq!(q.try_acquire("b", t0), Ok(()));
        assert_eq!(q.try_acquire("", t0), Ok(()));
    }

    #[test]
    fn disabled_quotas_admit_everything() {
        let q = TenantQuotas::new(0.0, 5.0);
        assert!(!q.enabled());
        let t0 = Instant::now();
        for _ in 0..1000 {
            assert_eq!(q.try_acquire("anyone", t0), Ok(()));
        }
    }

    #[test]
    fn retry_after_is_never_zero() {
        // rate high enough that the naive horizon rounds to 0ms
        let q = TenantQuotas::new(1e6, 1.0);
        let t0 = Instant::now();
        assert_eq!(q.try_acquire("t", t0), Ok(()));
        match q.try_acquire("t", t0) {
            Err(ms) => assert!(ms >= 1, "retry_after_ms must be >= 1, got {ms}"),
            Ok(()) => {
                // burst 1 spent at the same instant: must shed
                panic!("expected shed");
            }
        }
    }

    #[test]
    fn tenant_flood_stays_bounded() {
        let q = TenantQuotas::new(5.0, 2.0);
        let t0 = Instant::now();
        for i in 0..(TenantQuotas::MAX_TENANTS + 50) {
            let _ = q.try_acquire(&format!("tenant-{i}"), t0 + Duration::from_micros(i as u64));
        }
        let len = q.buckets.lock().unwrap().len();
        assert!(len <= TenantQuotas::MAX_TENANTS, "map grew to {len}");
        // old, evicted tenants come back with a full (fresh) bucket
        assert_eq!(q.try_acquire("tenant-0", t0 + Duration::from_secs(1)), Ok(()));
    }
}
