//! The TCP front-end: a bounded, drainable thread-per-connection server
//! over the newline-delimited wire protocol, designed around hostile
//! clients. See `net/README.md` for the full lifecycle; the shape:
//!
//! * **accept thread** — owns the listener and the bounded connection
//!   registry. Over-limit accepts are answered with a typed `overloaded`
//!   error line and closed immediately; nothing about them is buffered.
//! * **per-connection reader thread** — drives a [`FrameReader`] over
//!   the socket with a short read-timeout tick, enforcing the mid-frame
//!   read budget (slow-loris cut) and the idle budget. Stats lines,
//!   parse errors and quota sheds are answered inline (zero scan work);
//!   well-formed queries are handed to the dispatcher.
//! * **per-connection writer thread** — drains a *bounded* response
//!   queue onto the socket. A client that stops reading fills the queue;
//!   the next response for it kills the connection instead of buffering
//!   forever (backpressure disconnect).
//! * **dispatcher thread** — owns the one [`BatchCoalescer`] every
//!   connection feeds, so TCP serving reuses the exact coalescing →
//!   `Service::submit_batch_timed` path (cohorts, deadlines, admission
//!   control, worker supervision) the in-process serve loop uses.
//!   Responses are pinned to the same wire bytes `Service::handle_line`
//!   produces (timing fields aside — wall clocks differ by definition).
//!
//! Graceful drain ([`NetServer::drain`]): stop accepting, cut every
//! connection's *read* half (no new frames), let the dispatcher finish
//! every in-flight query under its deadline budget, deliver every
//! response, then join all threads. No response is lost or half-written.

use std::collections::VecDeque;
use std::io::Write;
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::protocol::{
    is_stats_line, ErrorKind, ErrorResponse, Overloaded, QueryRequest, QuotaExceeded,
};
use crate::coordinator::{BatchCoalescer, Service};
use crate::fault;
use crate::metrics::Counters;
use crate::obs::{Gauge, Stage};

use super::frame::{FrameEvent, FrameReader};
use super::quota::TenantQuotas;

/// Socket poll tick: the read timeout handed to the kernel, NOT the
/// hostile-client budget — each tick the reader re-checks its read/idle
/// budgets and the shutdown flag, so cut-off latency is bounded by this
/// regardless of the configured budgets.
const TICK: Duration = Duration::from_millis(25);

/// Front-end knobs (`repro serve --listen` flags / the `[net]` config
/// section). Every bound exists to keep a hostile client from pinning a
/// thread or growing a buffer.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// open-connection bound; accepts beyond it are answered with a
    /// typed `overloaded` error and closed (0 = unbounded)
    pub max_conns: usize,
    /// per-frame length cap for the bounded reader
    pub max_frame_bytes: usize,
    /// budget for assembling one frame once its first byte arrived;
    /// a frame incomplete past this is cut off (0 = no budget)
    pub read_timeout_ms: u64,
    /// budget between frames; a connection idle past this is closed
    /// (0 = no budget)
    pub idle_timeout_ms: u64,
    /// bounded per-connection response queue; a response that finds the
    /// queue full disconnects the non-reading client
    pub write_queue: usize,
    /// per-tenant token refill rate, tokens/second (0 = quotas off)
    pub quota_rate: f64,
    /// per-tenant bucket capacity (burst size)
    pub quota_burst: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            max_conns: 64,
            max_frame_bytes: 1 << 20,
            read_timeout_ms: 5_000,
            idle_timeout_ms: 300_000,
            write_queue: 64,
            quota_rate: 0.0,
            quota_burst: 8.0,
        }
    }
}

/// State shared by the accept loop and every connection thread.
struct Inner {
    svc: Arc<Service>,
    cfg: NetConfig,
    quotas: TenantQuotas,
    shutdown: AtomicBool,
    conns: Mutex<Vec<Conn>>,
}

struct Conn {
    stream: Arc<TcpStream>,
    reader: JoinHandle<()>,
    writer: JoinHandle<()>,
}

/// One query in flight from a connection to the dispatcher.
struct Dispatch {
    req: QueryRequest,
    arrival: Instant,
    reply: ReplyHandle,
}

/// Where a response line goes: the owning connection's bounded writer
/// queue, plus the socket so a full queue can kill the connection.
#[derive(Clone)]
struct ReplyHandle {
    tx: SyncSender<String>,
    stream: Arc<TcpStream>,
}

impl ReplyHandle {
    /// Enqueue one response line; a full queue means the client stopped
    /// reading — disconnect it (both halves, so its reader and writer
    /// threads wind down) instead of buffering without bound.
    fn send_or_kill(&self, line: String) {
        match self.tx.try_send(line) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => {
                let _ = self.stream.shutdown(Shutdown::Both);
            }
            Err(TrySendError::Disconnected(_)) => {}
        }
    }
}

/// A running TCP front-end. Construct with [`NetServer::start`]; stop
/// with [`NetServer::drain`] (dropping the server drains it too).
pub struct NetServer {
    inner: Arc<Inner>,
    local_addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    dispatcher: Option<JoinHandle<()>>,
    /// the master dispatch sender; dropped during drain so the channel
    /// closes once every connection reader has exited
    dispatch_tx: Option<SyncSender<Dispatch>>,
}

impl NetServer {
    /// Bind `listen` (e.g. `"127.0.0.1:7878"`, port 0 for ephemeral) and
    /// start serving the `svc` pipeline over it.
    pub fn start(svc: Arc<Service>, listen: &str, cfg: NetConfig) -> Result<NetServer> {
        let listener =
            TcpListener::bind(listen).with_context(|| format!("binding {listen:?}"))?;
        let local_addr = listener.local_addr()?;
        let quotas = TenantQuotas::new(cfg.quota_rate, cfg.quota_burst);
        let inner = Arc::new(Inner {
            svc,
            cfg,
            quotas,
            shutdown: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
        });
        // bounded dispatcher inbox: enough for the window plus headroom;
        // a full inbox blocks readers (TCP backpressure to the client),
        // never the dispatcher
        let depth = inner.svc.max_pending().max(inner.svc.batch_window() * 2).max(64);
        let (dispatch_tx, dispatch_rx) = mpsc::sync_channel::<Dispatch>(depth);
        let dispatcher = {
            let svc = Arc::clone(&inner.svc);
            std::thread::Builder::new()
                .name("net-dispatch".into())
                .spawn(move || dispatcher_loop(&svc, dispatch_rx))
                .context("spawning dispatcher")?
        };
        let accept = {
            let inner = Arc::clone(&inner);
            let dispatch_tx = dispatch_tx.clone();
            std::thread::Builder::new()
                .name("net-accept".into())
                .spawn(move || accept_loop(listener, &inner, &dispatch_tx))
                .context("spawning accept loop")?
        };
        Ok(NetServer {
            inner,
            local_addr,
            accept: Some(accept),
            dispatcher: Some(dispatcher),
            dispatch_tx: Some(dispatch_tx),
        })
    }

    /// The bound address (useful with an ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Graceful shutdown: stop accepting, stop reading new frames, finish
    /// and deliver every in-flight query, join every thread.
    pub fn drain(mut self) {
        self.drain_impl();
    }

    fn drain_impl(&mut self) {
        if self.accept.is_none() {
            return; // already drained
        }
        self.inner.shutdown.store(true, Ordering::SeqCst);
        // wake the blocking accept() with a loopback poke; the loop sees
        // the flag and exits
        let poke: IpAddr = match self.local_addr {
            SocketAddr::V4(_) => Ipv4Addr::LOCALHOST.into(),
            SocketAddr::V6(_) => Ipv6Addr::LOCALHOST.into(),
        };
        let _ = TcpStream::connect_timeout(
            &SocketAddr::new(poke, self.local_addr.port()),
            Duration::from_secs(1),
        );
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // no more frames: cut every connection's read half (in-flight
        // replies still go out the write half), then join the readers
        let conns: Vec<Conn> = std::mem::take(&mut *self.inner.conns.lock().unwrap());
        for c in &conns {
            let _ = c.stream.shutdown(Shutdown::Read);
        }
        let mut writers = Vec::with_capacity(conns.len());
        for c in conns {
            let _ = c.reader.join();
            writers.push(c.writer);
        }
        // every reader's dispatch sender is gone; dropping the master
        // clone closes the channel, so the dispatcher flushes the
        // coalescer tail, serves it, delivers the replies and exits
        self.dispatch_tx = None;
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
        // all reply senders are dropped now: writers drain what remains
        // on their queues and exit — nothing is lost or half-written
        for w in writers {
            let _ = w.join();
        }
        self.inner.svc.obs_cell().set_gauge(Gauge::OpenConnections, 0);
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.drain_impl();
    }
}

fn accept_loop(listener: TcpListener, inner: &Arc<Inner>, dispatch_tx: &SyncSender<Dispatch>) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _peer)) => stream,
            Err(_) => {
                if inner.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                continue; // transient accept error (EMFILE, ECONNABORTED…)
            }
        };
        if inner.shutdown.load(Ordering::SeqCst) {
            return; // the drain poke (or a late real client): stop here
        }
        if fault::fire(fault::ACCEPT_FAIL) {
            continue; // injected transient failure: socket dropped unreplied
        }
        let cell = inner.svc.obs_cell();
        let mut conns = inner.conns.lock().unwrap();
        // reap connections whose threads have finished, so closed
        // sessions free their registry slots without a background sweeper
        let mut i = 0;
        while i < conns.len() {
            if conns[i].reader.is_finished() && conns[i].writer.is_finished() {
                let c = conns.swap_remove(i);
                let _ = c.reader.join();
                let _ = c.writer.join();
            } else {
                i += 1;
            }
        }
        if inner.cfg.max_conns > 0 && conns.len() >= inner.cfg.max_conns {
            cell.add_counter(Counters::SLOT_CONNS_REJECTED, 1);
            cell.set_gauge(Gauge::OpenConnections, conns.len() as u64);
            drop(conns); // don't hold the registry over the reject write
            let err = Overloaded {
                pending: inner.cfg.max_conns as u64,
                max_pending: inner.cfg.max_conns,
            };
            let reply = ErrorResponse {
                id: None,
                error: format!("connection refused: {err}"),
                kind: Some(ErrorKind::Overloaded),
                retry_after_ms: None,
            };
            let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
            let mut s = &stream;
            let _ = s.write_all(format!("{}\n", reply.to_json()).as_bytes());
            continue; // stream drops: closed
        }
        cell.add_counter(Counters::SLOT_CONNS_ACCEPTED, 1);
        let _ = stream.set_read_timeout(Some(TICK));
        let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
        let _ = stream.set_nodelay(true);
        let stream = Arc::new(stream);
        let (resp_tx, resp_rx) = mpsc::sync_channel::<String>(inner.cfg.write_queue.max(1));
        let writer = {
            let inner = Arc::clone(inner);
            let stream = Arc::clone(&stream);
            std::thread::Builder::new()
                .name("net-conn-writer".into())
                .spawn(move || writer_loop(&inner, &stream, resp_rx))
        };
        let reader = {
            let inner = Arc::clone(inner);
            let stream = Arc::clone(&stream);
            let dispatch_tx = dispatch_tx.clone();
            std::thread::Builder::new()
                .name("net-conn-reader".into())
                .spawn(move || reader_loop(&inner, &stream, resp_tx, &dispatch_tx))
        };
        match (reader, writer) {
            (Ok(reader), Ok(writer)) => {
                conns.push(Conn { stream, reader, writer });
                cell.set_gauge(Gauge::OpenConnections, conns.len() as u64);
            }
            // spawn failure (thread exhaustion): drop the socket; any
            // half-spawned thread winds down on its closed channel
            (r, w) => {
                let _ = stream.shutdown(Shutdown::Both);
                if let Ok(h) = r {
                    let _ = h.join();
                }
                if let Ok(h) = w {
                    let _ = h.join();
                }
            }
        }
    }
}

/// Drain the bounded response queue onto the socket. Exits when every
/// sender (reader + in-flight dispatcher replies) is gone, or on the
/// first write failure — in which case the socket is shut down so the
/// reader stops accepting frames that could never be answered.
fn writer_loop(inner: &Inner, stream: &Arc<TcpStream>, rx: Receiver<String>) {
    let cell = inner.svc.obs_cell();
    for mut line in rx {
        let t0 = Instant::now();
        line.push('\n');
        let mut s: &TcpStream = stream;
        if s.write_all(line.as_bytes()).and_then(|()| s.flush()).is_err() {
            let _ = stream.shutdown(Shutdown::Both);
            return;
        }
        cell.record_stage_ns(Stage::ConnWrite, t0.elapsed().as_nanos() as u64);
    }
    // every sender is gone — the last response this connection will ever
    // get has been written; close, so the client sees a clean FIN
    let _ = stream.shutdown(Shutdown::Both);
}

/// Why the reader stopped consuming a connection.
enum ConnEnd {
    /// client closed / drain cut the read half / client misbehaved
    Closed,
    /// a frame stayed incomplete past the read budget (slow loris)
    ReadTimeout,
}

fn reader_loop(
    inner: &Inner,
    stream: &Arc<TcpStream>,
    resp_tx: SyncSender<String>,
    dispatch_tx: &SyncSender<Dispatch>,
) {
    let end = read_frames(inner, stream, &resp_tx, dispatch_tx);
    match end {
        // hostile cut: nothing owed to this client, close both halves so
        // the slow sender cannot keep the socket (or a thread) pinned
        ConnEnd::ReadTimeout => {
            inner.svc.obs_cell().add_counter(Counters::SLOT_CONN_READ_TIMEOUTS, 1);
            let _ = stream.shutdown(Shutdown::Both);
        }
        // orderly end: stop reading, but leave the write half open — the
        // writer closes it after the in-flight replies have gone out
        ConnEnd::Closed => {
            let _ = stream.shutdown(Shutdown::Read);
        }
    }
    // resp_tx drops here: the writer exits once in-flight replies (which
    // hold their own senders) have been delivered
}

fn read_frames(
    inner: &Inner,
    stream: &Arc<TcpStream>,
    resp_tx: &SyncSender<String>,
    dispatch_tx: &SyncSender<Dispatch>,
) -> ConnEnd {
    let cell = inner.svc.obs_cell();
    let read_budget = Duration::from_millis(inner.cfg.read_timeout_ms);
    let idle_budget = Duration::from_millis(inner.cfg.idle_timeout_ms);
    let reply = ReplyHandle { tx: resp_tx.clone(), stream: Arc::clone(stream) };
    let mut fr = FrameReader::new(&**stream, inner.cfg.max_frame_bytes);
    // when the first byte of the frame being assembled was seen
    let mut frame_start: Option<Instant> = None;
    let mut last_frame = Instant::now();
    loop {
        if inner.shutdown.load(Ordering::SeqCst) {
            return ConnEnd::Closed;
        }
        let call_start = Instant::now();
        match fr.next_frame() {
            Ok(FrameEvent::Frame(line)) => {
                let t0 = frame_start.take().unwrap_or(call_start);
                cell.record_stage_ns(Stage::ConnRead, t0.elapsed().as_nanos() as u64);
                last_frame = Instant::now();
                // pipelined bytes already buffered belong to the next frame
                if fr.mid_frame() {
                    frame_start = Some(last_frame);
                }
                if line.is_empty() {
                    continue; // blank keep-alive line, nothing to answer
                }
                fault::fire_stall(fault::CONN_STALL);
                if fault::fire(fault::CONN_DROP) {
                    return ConnEnd::Closed; // injected vanish mid-session
                }
                if is_stats_line(&line) {
                    reply.send_or_kill(inner.svc.stats_json());
                    continue;
                }
                let req = match QueryRequest::from_json(&line) {
                    Ok(req) => req,
                    Err(e) => {
                        // exactly one reply per frame, parseable or not
                        reply.send_or_kill(ErrorResponse::for_line(&line, &e).to_json());
                        continue;
                    }
                };
                if inner.quotas.enabled() {
                    let tenant = req.tenant.as_deref().unwrap_or("");
                    if let Err(retry_after_ms) = inner.quotas.try_acquire(tenant, Instant::now())
                    {
                        // shed before any scan work, with the backoff
                        // horizon on the wire
                        cell.add_counter(Counters::SLOT_QUOTA_SHED_QUERIES, 1);
                        let err = anyhow::Error::new(QuotaExceeded {
                            tenant: if tenant.is_empty() {
                                "anonymous".to_string()
                            } else {
                                tenant.to_string()
                            },
                            retry_after_ms,
                        });
                        reply.send_or_kill(ErrorResponse::new(req.id, &err).to_json());
                        continue;
                    }
                }
                let msg = Dispatch { req, arrival: Instant::now(), reply: reply.clone() };
                if dispatch_tx.send(msg).is_err() {
                    return ConnEnd::Closed; // dispatcher gone: draining
                }
            }
            Ok(FrameEvent::TooLarge(e)) => {
                // answer the typed error, then cut the connection — a
                // client this far out of contract doesn't get a resync
                let err = anyhow::Error::new(e);
                reply.send_or_kill(ErrorResponse::for_line("", &err).to_json());
                return ConnEnd::Closed;
            }
            Ok(FrameEvent::Eof) => return ConnEnd::Closed,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // the socket tick: re-check the budgets
                if fr.mid_frame() {
                    let t0 = *frame_start.get_or_insert(call_start);
                    if !read_budget.is_zero() && t0.elapsed() >= read_budget {
                        return ConnEnd::ReadTimeout; // slow loris: cut
                    }
                } else {
                    frame_start = None;
                    if !idle_budget.is_zero() && last_frame.elapsed() >= idle_budget {
                        return ConnEnd::Closed; // idle past budget
                    }
                }
            }
            Err(_) => return ConnEnd::Closed, // connection reset etc.
        }
    }
}

/// The single consumer of every connection's queries: feeds the shared
/// [`BatchCoalescer`] and serves flushed batches through
/// `Service::submit_batch_timed`, exactly like the in-process serve
/// loop. Reply handles queue in arrival order; the coalescer flushes
/// FIFO, so handle k always belongs to batch member k.
fn dispatcher_loop(svc: &Arc<Service>, rx: Receiver<Dispatch>) {
    let mut coalescer = BatchCoalescer::new(svc.batch_window(), svc.batch_deadline());
    let mut replies: VecDeque<ReplyHandle> = VecDeque::new();
    // poll often enough to honour the batch deadline; with no deadline
    // the coalescer only flushes on a full window (or at drain), so the
    // tick only bounds shutdown latency
    let tick = match svc.batch_deadline() {
        Some(d) => d.clamp(Duration::from_millis(1), Duration::from_millis(10)),
        None => Duration::from_secs(3600),
    };
    let serve = |batch: Vec<(QueryRequest, Instant)>, replies: &mut VecDeque<ReplyHandle>| {
        let results = svc.submit_batch_timed(&batch);
        for ((req, _), result) in batch.iter().zip(results) {
            let reply = replies.pop_front().expect("one reply handle per coalesced request");
            let line = match result {
                Ok(resp) => resp.to_json(),
                Err(e) => ErrorResponse::new(req.id, &e).to_json(),
            };
            reply.send_or_kill(line);
        }
    };
    loop {
        match rx.recv_timeout(tick) {
            Ok(Dispatch { req, arrival, reply }) => {
                replies.push_back(reply);
                if let Some(batch) = coalescer.push(req, arrival) {
                    serve(batch, &mut replies);
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if let Some(batch) = coalescer.poll(Instant::now()) {
                    serve(batch, &mut replies);
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                // drain: every reader is gone; flush and serve the tail
                if let Some(batch) = coalescer.flush() {
                    serve(batch, &mut replies);
                }
                svc.set_coalescer_pending(0);
                return;
            }
        }
        svc.set_coalescer_pending(coalescer.pending() as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::QueryResponse;
    use crate::coordinator::ServiceConfig;
    use crate::data::Dataset;
    use crate::distances::metric::Metric;
    use crate::search::suite::Suite;
    use crate::util::json::Json;
    use std::io::{BufRead, BufReader};

    fn service(shards: usize, window: usize) -> Arc<Service> {
        let r = Dataset::Ecg.generate(1500, 91);
        Arc::new(
            Service::new(
                r,
                &ServiceConfig {
                    shards,
                    batch_window: window,
                    batch_deadline_ms: if window > 1 { 5 } else { 0 },
                    ..Default::default()
                },
            )
            .unwrap(),
        )
    }

    fn request_line(id: u64) -> String {
        let r = Dataset::Ecg.generate(1500, 91);
        let q = crate::data::extract_queries(&r, 1, 64, 0.1, 92 + id).remove(0);
        QueryRequest {
            id,
            query: q,
            window_ratio: 0.1,
            suite: Suite::UcrMon,
            k: 2,
            metric: Metric::Cdtw,
            deadline_ms: None,
            tenant: None,
        }
        .to_json()
    }

    struct Client {
        stream: TcpStream,
        reader: BufReader<TcpStream>,
    }

    impl Client {
        fn connect(addr: SocketAddr) -> Client {
            let stream = TcpStream::connect(addr).unwrap();
            stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
            let reader = BufReader::new(stream.try_clone().unwrap());
            Client { stream, reader }
        }

        fn send(&mut self, line: &str) {
            self.stream.write_all(line.as_bytes()).unwrap();
            if !line.ends_with('\n') {
                self.stream.write_all(b"\n").unwrap();
            }
        }

        fn recv(&mut self) -> String {
            let mut line = String::new();
            self.reader.read_line(&mut line).unwrap();
            line.trim_end().to_string()
        }
    }

    /// Strip the wall-clock fields that cannot match across processes,
    /// keeping everything else for exact comparison.
    fn normalized(line: &str) -> String {
        match Json::parse(line).unwrap() {
            Json::Obj(mut m) => {
                m.remove("latency_ms");
                m.remove("queue_ms");
                Json::Obj(m).to_string()
            }
            other => other.to_string(),
        }
    }

    #[test]
    fn tcp_responses_match_in_process_handle_line() {
        let svc = service(2, 1);
        let server = NetServer::start(Arc::clone(&svc), "127.0.0.1:0", NetConfig::default())
            .unwrap();
        let mut c = Client::connect(server.local_addr());
        let line = request_line(7);
        c.send(&line);
        let over_wire = c.recv();
        let in_process = svc.handle_line(&line);
        assert_eq!(normalized(&over_wire), normalized(&in_process));
        // sanity: it really is a result with matches
        let resp = QueryResponse::from_json(&over_wire).unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.matches.len(), 2);
        // a stats line answers from the same live registry
        c.send("{\"cmd\":\"stats\"}");
        let stats = c.recv();
        assert!(stats.contains("repro.metrics.v1"), "{stats}");
        // junk answers id:null, and the session keeps serving
        c.send("not json at all");
        let err = c.recv();
        assert!(ErrorResponse::is_error_line(&err), "{err}");
        assert_eq!(ErrorResponse::from_json(&err).unwrap().id, None);
        c.send(&request_line(8));
        assert_eq!(QueryResponse::from_json(&c.recv()).unwrap().id, 8);
        server.drain();
    }

    #[test]
    fn over_limit_connections_are_rejected_with_overloaded() {
        let svc = service(1, 1);
        let cfg = NetConfig { max_conns: 1, ..NetConfig::default() };
        let server = NetServer::start(Arc::clone(&svc), "127.0.0.1:0", cfg).unwrap();
        let mut first = Client::connect(server.local_addr());
        // prove the first session is live (and its registry slot taken)
        first.send(&request_line(1));
        let _ = first.recv();
        let mut second = Client::connect(server.local_addr());
        let reply = second.recv();
        let err = ErrorResponse::from_json(&reply).unwrap();
        assert_eq!(err.kind, Some(ErrorKind::Overloaded), "{reply}");
        assert_eq!(err.id, None);
        // the rejected socket is closed: EOF follows
        let mut line = String::new();
        assert_eq!(second.reader.read_line(&mut line).unwrap(), 0);
        let snap = svc.metrics();
        assert_eq!(snap.counters.conns_rejected, 1);
        assert!(snap.counters.conns_accepted >= 1);
        server.drain();
    }

    #[test]
    fn quota_exhaustion_sheds_with_retry_after_and_no_scan_work() {
        let svc = service(1, 1);
        let cfg = NetConfig { quota_rate: 1.0, quota_burst: 2.0, ..NetConfig::default() };
        let server = NetServer::start(Arc::clone(&svc), "127.0.0.1:0", cfg).unwrap();
        let mut c = Client::connect(server.local_addr());
        let line = request_line(0);
        // burst of 2 admitted…
        for id in 0..2u64 {
            c.send(&line.replace("\"id\":0", &format!("\"id\":{id}")));
            assert!(QueryResponse::from_json(&c.recv()).is_ok());
        }
        let candidates_before = svc.metrics().counters.candidates;
        // …the third is shed before any scan work
        c.send(&line.replace("\"id\":0", "\"id\":99"));
        let shed = ErrorResponse::from_json(&c.recv()).unwrap();
        assert_eq!(shed.kind, Some(ErrorKind::Quota));
        assert_eq!(shed.id, Some(99));
        let retry = shed.retry_after_ms.expect("quota sheds carry retry_after_ms");
        assert!(retry >= 1);
        let snap = svc.metrics();
        assert_eq!(snap.counters.quota_shed_queries, 1);
        assert_eq!(snap.counters.candidates, candidates_before, "shed did zero scan work");
        // a different tenant is unaffected
        c.send(&line.replace("\"id\":0", "\"id\":5,\"tenant\":\"other\""));
        assert_eq!(QueryResponse::from_json(&c.recv()).unwrap().id, 5);
        server.drain();
    }

    #[test]
    fn drain_answers_in_flight_then_joins_everything() {
        let svc = service(2, 4);
        let server = NetServer::start(Arc::clone(&svc), "127.0.0.1:0", NetConfig::default())
            .unwrap();
        let addr = server.local_addr();
        let mut clients: Vec<Client> = (0..3).map(|_| Client::connect(addr)).collect();
        for (i, c) in clients.iter_mut().enumerate() {
            c.send(&request_line(i as u64));
        }
        // wait until every query has been served (a frame still sitting
        // unread in a kernel buffer is legitimately dropped by drain);
        // the responses may still be anywhere between the dispatcher and
        // the writer queues — drain must deliver every one of them
        let t0 = Instant::now();
        while svc.queries_served() < 3 {
            assert!(t0.elapsed() < Duration::from_secs(30), "queries never served");
            std::thread::sleep(Duration::from_millis(2));
        }
        server.drain();
        for (i, c) in clients.iter_mut().enumerate() {
            let resp = QueryResponse::from_json(&c.recv()).unwrap();
            assert_eq!(resp.id, i as u64);
            // …and the connection is cleanly closed afterwards
            let mut line = String::new();
            assert_eq!(c.reader.read_line(&mut line).unwrap(), 0);
        }
        assert_eq!(svc.metrics().gauges[Gauge::OpenConnections.index()], 0);
    }
}
