//! Z-normalisation — batch and streaming (system S9).
//!
//! Every UCR-style comparison happens between z-normalised windows; over a
//! long stream the per-window stats are maintained incrementally with
//! periodic refreshes against floating-point drift.

pub mod znorm;
