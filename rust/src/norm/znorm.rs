//! Z-normalisation primitives and the streaming window statistics of the
//! UCR subsequence search.

/// Windows with std below this are treated as flat: all points normalise
/// to 0 (matches `python/compile/kernels/ref.py::STD_EPS`).
pub const STD_EPS: f64 = 1e-8;

/// Z-normalise one point given window stats.
#[inline(always)]
pub fn znorm_point(x: f64, mean: f64, std: f64) -> f64 {
    if std > STD_EPS {
        (x - mean) / std
    } else {
        0.0
    }
}

/// Mean and std (UCR running-stats formula `sqrt(E[x^2]-E[x]^2)`).
pub fn stats(s: &[f64]) -> (f64, f64) {
    if s.is_empty() {
        return (0.0, 0.0);
    }
    let n = s.len() as f64;
    let mut ex = 0.0;
    let mut ex2 = 0.0;
    for &x in s {
        ex += x;
        ex2 += x * x;
    }
    let mean = ex / n;
    let std = (ex2 / n - mean * mean).max(0.0).sqrt();
    (mean, std)
}

/// Z-normalise a whole series into a fresh vector.
pub fn znorm(s: &[f64]) -> Vec<f64> {
    let (mean, std) = stats(s);
    s.iter().map(|&x| znorm_point(x, mean, std)).collect()
}

/// Z-normalise into a caller-provided buffer.
pub fn znorm_into(s: &[f64], out: &mut Vec<f64>) {
    let (mean, std) = stats(s);
    out.clear();
    out.extend(s.iter().map(|&x| znorm_point(x, mean, std)));
}

/// Streaming statistics of a sliding window over a reference stream:
/// O(1) advance via running sums, with a periodic full refresh to bound
/// floating-point drift (the UCR suite resets per chunk; we refresh every
/// [`WindowStats::REFRESH_EVERY`] advances).
#[derive(Debug, Clone)]
pub struct WindowStats<'a> {
    s: &'a [f64],
    n: usize,
    pos: usize,
    ex: f64,
    ex2: f64,
    since_refresh: u32,
}

impl<'a> WindowStats<'a> {
    pub const REFRESH_EVERY: u32 = 1 << 17;

    /// Stats of windows of length `n` over `s`, starting at position 0.
    /// Panics if `s.len() < n` or `n == 0`.
    pub fn new(s: &'a [f64], n: usize) -> Self {
        assert!(n > 0 && s.len() >= n, "stream shorter than window");
        let mut ws = Self { s, n, pos: 0, ex: 0.0, ex2: 0.0, since_refresh: 0 };
        ws.refresh();
        ws
    }

    /// Current window start position.
    #[inline]
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Current window as a slice.
    #[inline]
    pub fn window(&self) -> &'a [f64] {
        &self.s[self.pos..self.pos + self.n]
    }

    /// (mean, std) of the current window.
    #[inline]
    pub fn mean_std(&self) -> (f64, f64) {
        let n = self.n as f64;
        let mean = self.ex / n;
        let std = (self.ex2 / n - mean * mean).max(0.0).sqrt();
        (mean, std)
    }

    /// Advance the window one position; `false` when the stream is
    /// exhausted (the window would run off the end).
    #[inline]
    pub fn advance(&mut self) -> bool {
        if self.pos + self.n >= self.s.len() {
            return false;
        }
        let out = self.s[self.pos];
        let inc = self.s[self.pos + self.n];
        self.ex += inc - out;
        self.ex2 += inc * inc - out * out;
        self.pos += 1;
        self.since_refresh += 1;
        if self.since_refresh >= Self::REFRESH_EVERY {
            self.refresh();
        }
        true
    }

    /// Recompute the sums exactly from the window.
    pub fn refresh(&mut self) {
        let (mut ex, mut ex2) = (0.0, 0.0);
        for &x in self.window() {
            ex += x;
            ex2 += x * x;
        }
        self.ex = ex;
        self.ex2 = ex2;
        self.since_refresh = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn znorm_unit_stats() {
        let s = [1.0, 2.0, 3.0, 4.0, 5.0];
        let z = znorm(&s);
        let (m, d) = stats(&z);
        assert!(m.abs() < 1e-12);
        assert!((d - 1.0).abs() < 1e-12);
    }

    #[test]
    fn flat_window_normalises_to_zero() {
        let z = znorm(&[4.2; 8]);
        assert!(z.iter().all(|&v| v == 0.0));
        assert_eq!(znorm_point(4.2, 4.2, 0.0), 0.0);
    }

    #[test]
    fn streaming_matches_batch() {
        let mut x = 3u64;
        let s: Vec<f64> = (0..500)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                (x as f64 / u64::MAX as f64) * 10.0 - 5.0
            })
            .collect();
        let n = 32;
        let mut ws = WindowStats::new(&s, n);
        loop {
            let (m1, d1) = ws.mean_std();
            let (m2, d2) = stats(ws.window());
            assert!((m1 - m2).abs() < 1e-8, "pos={}", ws.pos());
            assert!((d1 - d2).abs() < 1e-8, "pos={}", ws.pos());
            if !ws.advance() {
                break;
            }
        }
        assert_eq!(ws.pos(), s.len() - n);
    }

    #[test]
    fn znorm_into_reuses_buffer() {
        let mut buf = vec![9.0; 3];
        znorm_into(&[1.0, 2.0, 3.0], &mut buf);
        assert_eq!(buf.len(), 3);
        assert!(buf[0] < 0.0 && buf[2] > 0.0);
    }

    #[test]
    #[should_panic]
    fn window_longer_than_stream_panics() {
        WindowStats::new(&[1.0, 2.0], 3);
    }
}
