//! # repro — EAPrunedDTW similarity search
//!
//! A production-shaped reproduction of *"Early Abandoning PrunedDTW and its
//! application to similarity search"* (Herrmann & Webb, 2020).
//!
//! The crate is the Layer-3 Rust coordinator of a three-layer stack:
//!
//! * **Layer 3 (this crate)** — the scalar distance zoo ([`distances`]:
//!   one unified EAPruned band kernel, [`distances::kernel`], serving the
//!   paper's [`distances::eap_dtw`] and every elastic extension as
//!   cost-model instantiations), the UCR-style
//!   lower-bound cascade ([`bounds`]), the subsequence search engine
//!   ([`search`]), the reference-side index + top-k multi-query engine
//!   ([`index`]: per-stream window-stats buckets and shared envelopes,
//!   a bounded top-k heap whose k-th best distance replaces the scalar
//!   best-so-far, and `Engine::search_batch` amortising the index across
//!   query batches — all generic over an elastic [`distances::metric::Metric`]:
//!   cDTW/DTW with the envelope cascade, WDTW/ERP/MSM/TWE through the
//!   bound-free generalised EAPruned kernel), synthetic stand-ins for the
//!   paper's six datasets
//!   ([`data`]), and a serving layer ([`coordinator`]) that shards a
//!   long reference across workers and batches candidates for the XLA
//!   prefilter.
//! * **Layer 2/1 (build-time Python, `python/compile/`)** — JAX graphs and
//!   Pallas kernels (batched z-norm, LB_Keogh, wavefront DTW), AOT-lowered
//!   to HLO text in `artifacts/` and executed by the `runtime` module via
//!   PJRT (compiled in with the `xla` cargo feature). Python never runs
//!   on the request path.
//!
//! Quickstart:
//!
//! ```no_run
//! use repro::distances::eap_dtw::eap_dtw;
//! let a = [3.0, 1.0, 4.0, 4.0, 1.0, 1.0];
//! let b = [1.0, 3.0, 2.0, 1.0, 2.0, 2.0];
//! // paper worked example: DTW = 9
//! assert_eq!(eap_dtw(&a, &b, f64::INFINITY), 9.0);
//! ```
//!
//! See `DESIGN.md` for the full system inventory and the experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod bench_support;
pub mod bounds;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod distances;
pub mod fault;
pub mod index;
pub mod metrics;
pub mod net;
pub mod norm;
pub mod obs;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod search;
pub mod util;

/// Convenience re-exports for the common entry points.
pub mod prelude {
    pub use crate::bounds::cascade::CascadePolicy;
    pub use crate::config::SearchConfig;
    pub use crate::data::Dataset;
    pub use crate::distances::eap_dtw::{eap_cdtw, eap_dtw};
    pub use crate::distances::metric::Metric;
    pub use crate::index::{Engine, EngineConfig, Query, RefIndex, TopK, TopKResult};
    pub use crate::metrics::Counters;
    pub use crate::obs::{MetricsRegistry, MetricsSnapshot};
    pub use crate::search::subsequence::{
        search_subsequence, search_subsequence_topk, search_subsequence_topk_metric,
        search_subsequence_topk_metric_mode, Match, ScanMode,
    };
    pub use crate::search::suite::Suite;
}
