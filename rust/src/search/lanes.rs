//! Survivor **lane packing** for the multi-candidate wavefront kernel.
//!
//! The strip and cohort scans evaluate cascade survivors one at a time
//! through the scalar kernel. When lane evaluation is enabled
//! (`ScanTuning::lanes >= 2` on a DTW-family metric under an EAPruned
//! suite core), survivors are instead *deferred* into a [`LanePacker`]:
//! each survivor's z-normalised window, cumulative-bound tail and
//! pack-time threshold are copied into the next free lane, and when the
//! group is full — or the strip's survivor list ends — the whole group
//! advances in row lockstep through
//! [`crate::distances::kernel::eap_kernel_multi_dyn`]. A group holding a
//! single survivor at flush time falls through to the scalar
//! [`crate::distances::kernel::eap_kernel`] — the bitwise-pinned oracle —
//! so lone survivors cost exactly what they always did.
//!
//! Groups are per-member and never span strips, so all lanes share one
//! `(qlen, w)` shape by construction. Thresholds are frozen per lane at
//! pack time and re-tightened from the owner's [`crate::index::topk::TopK`]
//! at flush; because DP cell values never depend on the threshold, the
//! deferred evaluation returns bitwise-identical distances for every
//! completed candidate, and the final top-k contents match sequential
//! evaluation exactly (`tests/conformance_lanes.rs`).

use crate::distances::kernel::{
    eap_kernel, eap_kernel_f32, eap_kernel_multi_dyn, DtwCost, KernelEval, MultiWorkspace,
    Precision, MAX_LANES,
};

/// Accumulates deferred survivors into lanes and evaluates them as one
/// wavefront group. Owned by a `QueryContext`; all buffers are reused
/// across groups so the steady-state scan never allocates.
#[derive(Debug, Clone)]
pub struct LanePacker {
    /// configured group width (1 = lane evaluation off)
    width: usize,
    precision: Precision,
    /// per-lane copies of the survivor's z-normalised window
    zbufs: Vec<Vec<f64>>,
    /// per-lane copies of the cumulative-bound tail (valid when `has_cb`)
    cbs: Vec<Vec<f64>>,
    has_cb: Vec<bool>,
    /// per-lane pack-time thresholds (tightened again at flush)
    ubs: Vec<f64>,
    /// per-lane candidate start positions
    positions: Vec<usize>,
    /// lanes currently pending
    len: usize,
    mws: MultiWorkspace,
    out: Vec<KernelEval>,
}

impl Default for LanePacker {
    fn default() -> Self {
        Self {
            width: 1,
            precision: Precision::F64,
            zbufs: Vec::new(),
            cbs: Vec::new(),
            has_cb: Vec::new(),
            ubs: Vec::new(),
            positions: Vec::new(),
            len: 0,
            mws: MultiWorkspace::new(),
            out: Vec::new(),
        }
    }
}

impl LanePacker {
    /// Set the group width (clamped to `1..=MAX_LANES`) and the DP line
    /// precision. Width 1 disables deferral entirely — the scans check
    /// [`LanePacker::width`] before routing survivors here.
    pub fn configure(&mut self, lanes: usize, precision: Precision) {
        self.width = lanes.clamp(1, MAX_LANES);
        self.precision = precision;
        debug_assert_eq!(self.len, 0, "reconfigure with lanes pending");
    }

    #[inline]
    pub fn width(&self) -> usize {
        self.width
    }

    #[inline]
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Lanes pending evaluation.
    #[inline]
    pub fn lanes_pending(&self) -> usize {
        self.len
    }

    /// Defer one survivor into the next free lane, copying its
    /// z-normalised window, optional cumulative-bound tail and current
    /// threshold. Returns `true` when the group is now full and must be
    /// flushed before the next push.
    pub fn push(&mut self, pos: usize, zwin: &[f64], cb: Option<&[f64]>, ub: f64) -> bool {
        let k = self.len;
        debug_assert!(k < self.width, "push into a full lane group");
        if k == 0 {
            // idempotent, non-counting warm-up so the multi workspace
            // never registers a regrow mid-scan
            self.mws.warm(self.width, zwin.len(), self.precision);
        }
        if self.zbufs.len() <= k {
            self.zbufs.push(Vec::with_capacity(zwin.len()));
            self.cbs.push(Vec::new());
            self.has_cb.push(false);
            self.ubs.push(f64::INFINITY);
            self.positions.push(0);
        }
        self.zbufs[k].clear();
        self.zbufs[k].extend_from_slice(zwin);
        self.cbs[k].clear();
        match cb {
            Some(cb) => {
                self.cbs[k].extend_from_slice(cb);
                self.has_cb[k] = true;
            }
            None => self.has_cb[k] = false,
        }
        self.ubs[k] = ub;
        self.positions[k] = pos;
        self.len += 1;
        self.len >= self.width
    }

    /// Evaluate every pending lane against query `q` under band `w`.
    /// `fresh` is the owner's *current* top-k threshold: each lane's
    /// pack-time bound is tightened to it first (monotone — sibling
    /// completions since pack time can only have shrunk it), which is the
    /// flush-time half of the staleness fix; the in-kernel
    /// `LANE_REFRESH_ROWS` hook is the row-cadence half. Results are read
    /// back with [`LanePacker::result`].
    pub fn eval(&mut self, q: &[f64], w: usize, fresh: f64) {
        let len = self.len;
        self.out.clear();
        if len == 0 {
            return;
        }
        for ub in &mut self.ubs[..len] {
            if fresh < *ub {
                *ub = fresh;
            }
        }
        let Self { zbufs, cbs, has_cb, ubs, mws, out, precision, .. } = self;
        if len == 1 {
            // lone survivor: the scalar kernel, bitwise the pre-lane path
            let model = DtwCost { li: q, co: &zbufs[0] };
            let cb = has_cb[0].then(|| cbs[0].as_slice());
            let ws = mws.lane_ws(0);
            let e = match precision {
                Precision::F64 => eap_kernel(&model, w, ubs[0], cb, ws),
                Precision::F32 => eap_kernel_f32(&model, w, ubs[0], cb, ws),
            };
            out.push(e);
            return;
        }
        let mut models: [DtwCost<'_>; MAX_LANES] =
            std::array::from_fn(|_| DtwCost { li: q, co: &[] });
        let mut cb_slices = [None::<&[f64]>; MAX_LANES];
        for i in 0..len {
            models[i].co = &zbufs[i];
            if has_cb[i] {
                cb_slices[i] = Some(cbs[i].as_slice());
            }
        }
        // thresholds were just refreshed and no top-k offer can land
        // mid-flush, so the row-cadence refresh closure is a no-op here
        // (the conformance suite drives it with genuinely tightening
        // closures)
        let ub_now: &[f64] = &ubs[..len];
        match precision {
            Precision::F64 => eap_kernel_multi_dyn::<f64, _>(
                &models[..len],
                w,
                ub_now,
                &cb_slices[..len],
                mws,
                |l| ub_now[l],
                out,
            ),
            Precision::F32 => eap_kernel_multi_dyn::<f32, _>(
                &models[..len],
                w,
                ub_now,
                &cb_slices[..len],
                mws,
                |l| ub_now[l],
                out,
            ),
        }
    }

    /// Lane `k`'s (position, outcome) after [`LanePacker::eval`].
    #[inline]
    pub fn result(&self, k: usize) -> (usize, KernelEval) {
        (self.positions[k], self.out[k])
    }

    /// Drop the evaluated group; the buffers stay warm for the next one.
    #[inline]
    pub fn clear(&mut self) {
        self.len = 0;
        self.out.clear();
    }

    /// Total line-regrowth events across the lane workspaces (0 after the
    /// push-time warm-up — the pool-hygiene invariant).
    pub fn regrows(&self) -> u64 {
        self.mws.regrows()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::DtwWorkspace;

    fn xorshift(seed: u64) -> impl FnMut() -> f64 {
        let mut x = seed;
        move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 2.0 - 1.0
        }
    }

    #[test]
    fn packed_groups_match_scalar_evaluation_bitwise() {
        let mut rnd = xorshift(0xA11E);
        let n = 19;
        let w = 4;
        let q: Vec<f64> = (0..n).map(|_| rnd()).collect();
        let cands: Vec<Vec<f64>> = (0..7).map(|_| (0..n).map(|_| rnd()).collect()).collect();
        let mut packer = LanePacker::default();
        packer.configure(3, Precision::F64);
        let mut ws = DtwWorkspace::default();
        let mut scalar = Vec::new();
        let mut packed = Vec::new();
        for (pos, c) in cands.iter().enumerate() {
            scalar.push(eap_kernel(
                &DtwCost { li: &q, co: c },
                w,
                f64::INFINITY,
                None,
                &mut ws,
            ));
            if packer.push(pos, c, None, f64::INFINITY) {
                packer.eval(&q, w, f64::INFINITY);
                for k in 0..packer.lanes_pending() {
                    packed.push(packer.result(k));
                }
                packer.clear();
            }
        }
        // 7 = 3 + 3 + a lone trailing survivor through the scalar branch
        assert_eq!(packer.lanes_pending(), 1);
        packer.eval(&q, w, f64::INFINITY);
        packed.push(packer.result(0));
        packer.clear();
        assert_eq!(packed.len(), cands.len());
        for (k, (pos, e)) in packed.iter().enumerate() {
            assert_eq!(*pos, k);
            assert_eq!(e.dist.to_bits(), scalar[k].dist.to_bits(), "lane {k}");
            assert_eq!(e.abandoned, scalar[k].abandoned, "lane {k}");
        }
        assert_eq!(packer.regrows(), 0, "push-time warm must pre-size the lanes");
    }

    #[test]
    fn flush_time_refresh_only_tightens() {
        let mut rnd = xorshift(0x7157);
        let n = 11;
        let q: Vec<f64> = (0..n).map(|_| rnd()).collect();
        let c: Vec<f64> = (0..n).map(|_| rnd()).collect();
        let mut ws = DtwWorkspace::default();
        let exact = eap_kernel(&DtwCost { li: &q, co: &c }, n, f64::INFINITY, None, &mut ws).dist;
        let mut packer = LanePacker::default();
        packer.configure(2, Precision::F64);
        // packed loose, flushed tight: the fresh threshold must win
        packer.push(0, &c, None, f64::INFINITY);
        packer.eval(&q, n, exact * 0.5);
        assert!(packer.result(0).1.abandoned);
        packer.clear();
        // packed tight, flushed loose: the pack-time bound must survive
        packer.push(0, &c, None, exact * 0.5);
        packer.eval(&q, n, f64::INFINITY);
        assert!(packer.result(0).1.abandoned);
        packer.clear();
    }
}
