//! The similarity-search engine (system S10): the UCR-style subsequence
//! search loop, the four suite variants of the paper's evaluation (plus our
//! XLA-prefilter variant), and whole-series NN1 search.

pub mod nn1;
pub mod subsequence;
pub mod suite;
