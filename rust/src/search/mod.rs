//! The similarity-search engine (system S10): the UCR-style subsequence
//! search loop, the four suite variants of the paper's evaluation (plus our
//! XLA-prefilter variant), whole-series NN1 search, and the query-cohort
//! batch scan ([`cohort`]) that serves many same-shape queries from one
//! strip pass over the reference.

pub mod cohort;
pub mod nn1;
pub mod subsequence;
pub mod suite;
