//! The similarity-search engine (system S10): the UCR-style subsequence
//! search loop, the four suite variants of the paper's evaluation (plus our
//! XLA-prefilter variant), whole-series NN1 search, the query-cohort
//! batch scan ([`cohort`]) that serves many same-shape queries from one
//! strip pass over the reference, and the survivor lane packing
//! ([`lanes`]) that feeds the multi-candidate wavefront kernel.

pub mod cohort;
pub mod lanes;
pub mod nn1;
pub mod subsequence;
pub mod suite;
