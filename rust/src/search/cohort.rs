//! Query-cohort scan: one strip-major pass over the reference serving a
//! whole batch of same-shape queries.
//!
//! [`crate::index::engine::Engine::search_batch`] used to be query-major —
//! Q queries streamed the reference (window-stat lanes, envelope views,
//! raw samples) Q separate times through cache. This module inverts the
//! loop: queries that share a *(query length, warping window, metric)*
//! shape form a **cohort**, and the cohort runs ONE strip pass in which
//! every 64-position strip loads its `(mean, std)` lanes **once**
//! ([`crate::bounds::batch::CohortScratch`]) and then each member filters
//! the strip against its own private top-k threshold, evaluating its
//! survivors in the established ascending-(lower bound, position) order.
//!
//! Exactness contract: a cohort scan is **bitwise-identical**, per query,
//! to Q independent `search_one` calls (pinned by
//! `tests/conformance_cohort.rs`). Per-query thresholds are private, every
//! per-candidate decision reuses the single-query strip pipeline's code
//! ([`crate::search::subsequence`]'s batched bounds + `eval_survivor`),
//! and [`crate::index::topk::TopK`]'s lexicographic tie rule makes the
//! final set independent of evaluation order — so sharing the strip walk
//! is a pure memory-bandwidth optimisation, never a semantic one.
//!
//! Three additional amortisations ride the inverted loop:
//!
//! * **Shared LB_Kim endpoint lanes** — the up-to-six raw samples the
//!   LB_Kim hierarchy reads per window z-normalise with the *shared*
//!   `(mean, std)`, so they are query-independent: one
//!   [`crate::bounds::batch::KimLanes`] fill per strip serves every
//!   member's batched bound bit-identically
//!   (`strip_sample_loads_saved`).
//! * **Retirement** — a member whose k-th best distance reaches 0 can
//!   never accept a later candidate ([`TopK::exhausted`]), so it drops
//!   out of every remaining strip and late strips shrink. Exact-match
//!   heavy workloads stop paying for queries that are already answered.
//! * **Workspace pooling** ([`CohortPool`]) — one kernel workspace + one
//!   z-normalisation buffer per shard worker serve every member of every
//!   cohort, instead of each query context allocating its own; a debug
//!   assertion pins that capacity is reused, not regrown, within a
//!   cohort.

use std::sync::Arc;
use std::time::Instant;

use crate::bounds::batch::{
    batch_lb_kim_pre, kim_loads_per_lane, lb_keogh_ec_unordered, lb_keogh_eq_unordered,
    CohortScratch, DEFAULT_STRIP,
};
use crate::bounds::cascade::CascadePolicy;
use crate::coordinator::state::{CancelToken, SharedUb};
use crate::fault;
use crate::distances::KernelWorkspace;
use crate::index::ref_index::BucketStats;
use crate::index::topk::TopK;
use crate::metrics::Counters;
use crate::obs::{DistKind, ScanObs, Stage};
use crate::search::subsequence::{eval_survivor, flush_lane_group, DataEnvelopes, QueryContext};
use crate::search::suite::Suite;

/// One query's state through a cohort scan: its context, its private
/// top-k collector, an optional cross-shard threshold, its counters and
/// the retirement flag.
#[derive(Debug)]
pub struct CohortMember {
    pub ctx: QueryContext,
    pub topk: TopK,
    /// this query's cross-shard threshold (`None` for single-shard scans)
    pub shared: Option<Arc<SharedUb>>,
    pub counters: Counters,
    /// set once the member can never accept another candidate — later
    /// strips skip it entirely
    pub retired: bool,
    /// optional deadline: checked before the member's bound lanes run on
    /// each strip; past it the member is force-retired with `timed_out`
    /// set (its top-k is whatever the completed strips produced). `None`
    /// means no deadline — no clock is ever read for this member.
    pub deadline: Option<Instant>,
    /// true iff the member was retired by its deadline (or a cancelled
    /// scan) rather than by threshold exhaustion — the caller turns this
    /// into a `partial: true` response or a `timeout` error
    pub timed_out: bool,
}

impl CohortMember {
    /// Member for a single-shard (no cross-shard threshold) cohort scan.
    pub fn new(ctx: QueryContext, k: usize) -> Self {
        Self {
            ctx,
            topk: TopK::new(k),
            shared: None,
            counters: Counters::new(),
            retired: false,
            deadline: None,
            timed_out: false,
        }
    }

    /// Member whose threshold syncs with `shared` at every strip, exactly
    /// as [`crate::coordinator::worker::scan_shard_topk`] syncs per block.
    pub fn with_shared(ctx: QueryContext, k: usize, shared: Arc<SharedUb>) -> Self {
        Self { shared: Some(shared), ..Self::new(ctx, k) }
    }

    /// Attach a deadline budget (builder-style, used by cohort jobs).
    pub fn with_deadline(mut self, deadline: Option<Instant>) -> Self {
        self.deadline = deadline;
        self
    }
}

/// One kernel workspace + one z-normalisation buffer, owned by a shard
/// worker and swapped into each member's context while its survivors are
/// scored. All members of a cohort share a query length, so after
/// [`CohortPool::warm`] the buffers never regrow within a cohort (debug
/// asserted by the scan).
#[derive(Debug, Default)]
pub struct CohortPool {
    ws: KernelWorkspace,
    zbuf: Vec<f64>,
}

impl CohortPool {
    /// Ensure capacity for queries of `n` points, so the scan's hot path
    /// never reallocates.
    pub fn warm(&mut self, n: usize) {
        if self.zbuf.capacity() < n {
            self.zbuf.reserve(n - self.zbuf.len());
        }
        // the DP lines hold n + 1 cells
        if self.ws.prev.capacity() < n + 1 {
            self.ws.prev.reserve(n + 1 - self.ws.prev.len());
        }
        if self.ws.curr.capacity() < n + 1 {
            self.ws.curr.reserve(n + 1 - self.ws.curr.len());
        }
        // the f32 lines too: a few KB keeps the opt-in `--precision f32`
        // path inside the same no-regrow contract as the default
        self.ws.warm32(n);
    }

    /// Capacity fingerprint for the regrowth debug assertion.
    fn caps(&self) -> (usize, usize, usize) {
        (self.zbuf.capacity(), self.ws.prev.capacity(), self.ws.curr.capacity())
    }

    /// The pooled workspace's own regrowth tally (see
    /// [`crate::metrics::Counters::kernel_workspace_regrows`]).
    fn regrows(&self) -> u64 {
        self.ws.regrows()
    }

    /// Swap the pool's buffers with `ctx`'s (called in pairs around a
    /// member's survivor evaluation).
    fn swap_into(&mut self, ctx: &mut QueryContext) {
        ctx.swap_kernel_buffers(&mut self.ws, &mut self.zbuf);
    }
}

/// Scan candidate positions `[start, end)` strip-major for a whole cohort:
/// each strip loads its window-stat lanes once from the shared `stats`
/// table and every live member filters + scores it against its own
/// threshold. Members must share query length, window and metric (the
/// definition of a cohort); their results land in `members[i].topk` /
/// `members[i].counters`.
///
/// Threshold discipline mirrors the single-query sharded scan exactly:
/// per strip, a member adopts the freshest cross-shard bound before its
/// batch bounds run, and publishes its k-th best as soon as its survivors
/// are scored — the strip is the sync block, as in
/// [`crate::coordinator::worker::scan_shard_topk`]'s strip mode
/// (`sync_every` caps the strip length the same way).
#[allow(clippy::too_many_arguments)]
pub fn scan_cohort_topk(
    reference: &[f64],
    start: usize,
    end: usize,
    members: &mut [CohortMember],
    stats: &BucketStats,
    denv: Option<&DataEnvelopes>,
    suite: Suite,
    sync_every: usize,
    scratch: &mut CohortScratch,
    pool: &mut CohortPool,
) {
    scan_cohort_topk_obs(
        reference,
        start,
        end,
        members,
        stats,
        denv,
        suite,
        sync_every,
        scratch,
        pool,
        None,
        ScanObs::OFF,
    );
}

/// [`scan_cohort_topk`] with an observability handle and an optional
/// cancellation token — what a shard worker serving a cohort job calls
/// so bound-stage latencies and the per-strip survivor distribution land
/// in its registry cell. Recording is write-only: results stay bitwise
/// identical with a cell attached.
///
/// Cancellation and per-member deadlines (see
/// [`CohortMember::deadline`]) are honoured at strip boundaries only, so
/// every strip a member did process is complete — the counter
/// conservation identities hold on truncated scans exactly as on full
/// ones. With no token and no member deadlines this path reads no clocks
/// and behaves bitwise-identically to the pre-deadline scan.
#[allow(clippy::too_many_arguments)]
pub fn scan_cohort_topk_obs(
    reference: &[f64],
    start: usize,
    end: usize,
    members: &mut [CohortMember],
    stats: &BucketStats,
    denv: Option<&DataEnvelopes>,
    suite: Suite,
    sync_every: usize,
    scratch: &mut CohortScratch,
    pool: &mut CohortPool,
    cancel: Option<&CancelToken>,
    obs: ScanObs<'_>,
) {
    if members.is_empty() {
        return;
    }
    let n = members[0].ctx.len();
    let w = members[0].ctx.w;
    let metric = members[0].ctx.metric;
    assert!(n > 0, "empty query");
    assert!(reference.len() >= n, "reference shorter than query");
    assert!(
        members.iter().all(|m| m.ctx.len() == n && m.ctx.w == w && m.ctx.metric == metric),
        "cohort members must share (query length, window, metric)"
    );
    debug_assert_eq!(stats.qlen(), n, "stats bucket / cohort length mismatch");
    let end = end.min(reference.len() - n + 1);
    if start >= end {
        return;
    }
    let cascade = if metric.uses_envelopes() { suite.cascade() } else { CascadePolicy::none() };
    debug_assert!(
        !cascade.needs_data_envelopes() || denv.is_some(),
        "suite {suite:?} needs data envelopes"
    );
    pool.warm(n);
    let warm_caps = pool.caps();
    let mut regrows_seen = pool.regrows();
    scratch.ensure_members(members.len());
    // raw-sample reads one member's full LB_Kim hierarchy makes per lane —
    // the unit of the shared-endpoint-lane saving below
    let kim_loads = kim_loads_per_lane(n);
    // same block length as the single-query strip shard scan, so per-query
    // strip boundaries (and thus threshold sync points) are identical
    let strip_len = DEFAULT_STRIP.min(sync_every.max(1));
    let mut strip_start = start;
    while strip_start < end {
        if members.iter().all(|m| m.retired) {
            break;
        }
        // a cancelled scan (the router gave up on this cohort's fan-in)
        // stops at the strip boundary: every live member is force-retired
        // as timed out, keeping whatever its completed strips produced
        if cancel.is_some_and(|c| c.is_cancelled()) {
            for m in members.iter_mut().filter(|m| !m.retired) {
                m.timed_out = true;
                m.retired = true;
            }
            break;
        }
        fault::fire_stall(fault::STRIP_STALL);
        let len = (end - strip_start).min(strip_len);
        // the strip's shared stat lanes: loaded once, read by every member
        let (ms, ss) = stats.strip(strip_start, len);
        scratch.load_stats(ms, ss);
        if cascade.kim {
            // ...and the strip's z-normalised LB_Kim endpoint lanes: the
            // normalised values are query-independent, so one read of the
            // raw samples serves every member's batched LB_Kim pass
            scratch.load_kim(reference, strip_start, len, n);
        }
        let CohortScratch { mean, std, kim, lanes } = &mut *scratch;
        let mut first_live = true;
        for (mi, m) in members.iter_mut().enumerate() {
            if m.retired {
                continue;
            }
            // deadline check at the member's strip boundary: a member past
            // its budget keeps its completed-strip top-k and drops out of
            // every remaining strip. Members without a deadline never read
            // the clock.
            if m.deadline.is_some_and(|d| Instant::now() >= d) {
                m.timed_out = true;
                m.retired = true;
                continue;
            }
            if first_live {
                // the member that "paid" for the shared load
                m.counters.cohort_strips += 1;
                first_live = false;
            } else {
                // served from the cohort's shared lanes for free
                m.counters.strip_stat_loads_saved += len as u64;
                if cascade.kim {
                    m.counters.strip_sample_loads_saved += kim_loads * len as u64;
                }
            }
            if let Some(shared) = &m.shared {
                m.topk.set_bound(shared.get());
            }
            m.counters.strip_batches += 1;
            m.counters.candidates += len as u64;
            // lanes reset per live member only: retired members cost
            // nothing per strip
            let lane = &mut lanes[mi];
            lane.reset(len);
            // constant for the batch stages, like the single-query strip
            let bsf_strip = m.topk.threshold();
            if cascade.kim {
                let t0 = obs.now();
                batch_lb_kim_pre(&m.ctx.q, kim, len, &mut lane.lb);
                for i in 0..len {
                    if lane.lb[i] > bsf_strip {
                        lane.alive[i] = false;
                        m.counters.lb_kim_prunes += 1;
                        m.counters.batch_lb_prunes += 1;
                    }
                }
                obs.stage_since(Stage::BoundKim, t0);
            }
            if cascade.keogh_eq {
                let t0 = obs.now();
                let (u, l) = m.ctx.envelopes_natural();
                for i in 0..len {
                    if !lane.alive[i] {
                        continue;
                    }
                    let pos = strip_start + i;
                    let lb = lb_keogh_eq_unordered(
                        u,
                        l,
                        &reference[pos..pos + n],
                        mean[i],
                        std[i],
                    );
                    if lb > lane.lb[i] {
                        lane.lb[i] = lb;
                    }
                    // same summation-order discount as the single-query
                    // strip scan: never prune what the sorted pass keeps
                    if lb * (1.0 - 1e-9) > bsf_strip {
                        lane.alive[i] = false;
                        m.counters.lb_keogh_eq_prunes += 1;
                        m.counters.batch_lb_prunes += 1;
                    }
                }
                obs.stage_since(Stage::BoundKeoghEq, t0);
            }
            if cascade.improved {
                let denv = denv.expect("data envelopes required");
                let t0 = obs.now();
                for i in 0..len {
                    if !lane.alive[i] {
                        continue;
                    }
                    let pos = strip_start + i;
                    let (du, dl) = denv.strip(pos, n);
                    // same structure as the single-query strip scan: an
                    // unordered EC first pass (attributed to the EC stage),
                    // then the projection tail on top of it
                    let mut base = 0.0;
                    if cascade.keogh_ec {
                        let ec = lb_keogh_ec_unordered(&m.ctx.q, du, dl, mean[i], std[i]);
                        if ec * (1.0 - 1e-9) > bsf_strip {
                            lane.alive[i] = false;
                            m.counters.lb_keogh_ec_prunes += 1;
                            m.counters.batch_lb_prunes += 1;
                            continue;
                        }
                        base = ec;
                    }
                    let tail = m.ctx.improved_tail_raw(
                        du,
                        dl,
                        mean[i],
                        std[i],
                        &reference[pos..pos + n],
                        bsf_strip - base,
                    );
                    let lb = base + tail;
                    if lb * (1.0 - 1e-9) > bsf_strip {
                        lane.alive[i] = false;
                        m.counters.lb_improved_prunes += 1;
                        m.counters.batch_lb_prunes += 1;
                        continue;
                    }
                    if lb > lane.lb[i] {
                        lane.lb[i] = lb;
                    }
                }
                obs.stage_since(Stage::BoundImproved, t0);
            }
            lane.order_survivors();
            obs.record_dist(DistKind::StripSurvivors, lane.order.len() as u64);
            pool.swap_into(&mut m.ctx);
            for &i in &lane.order {
                let i = i as usize;
                let pos = strip_start + i;
                eval_survivor(
                    pos,
                    &reference[pos..pos + n],
                    mean[i],
                    std[i],
                    bsf_strip,
                    &mut m.ctx,
                    denv,
                    suite,
                    cascade,
                    true,
                    &mut m.topk,
                    &mut m.counters,
                    obs,
                );
            }
            // lane groups never span strips: a partial group left by this
            // member's survivor list is evaluated now, against the
            // member's freshest private threshold
            flush_lane_group(&mut m.ctx, &mut m.topk, &mut m.counters, obs);
            pool.swap_into(&mut m.ctx);
            debug_assert_eq!(
                pool.caps(),
                warm_caps,
                "cohort pool must reuse capacity within a cohort, not regrow"
            );
            // the workspace itself also tracks regrowth: zero within a
            // cohort in debug builds, and surfaced as a counter so a
            // warm-up regression is visible in release telemetry too
            let regrows_now = pool.regrows();
            m.counters.kernel_workspace_regrows += regrows_now - regrows_seen;
            debug_assert_eq!(
                regrows_now, regrows_seen,
                "kernel workspace must not regrow within a cohort"
            );
            regrows_seen = regrows_now;
            if let Some(shared) = &m.shared {
                if let Some(kth) = m.topk.kth_dist() {
                    shared.tighten(kth);
                }
            }
            if m.topk.exhausted() {
                m.retired = true;
                m.counters.cohort_retired_queries += 1;
            }
        }
        strip_start += len;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{extract_queries, Dataset};
    use crate::distances::metric::Metric;
    use crate::search::subsequence::{
        search_subsequence_topk_metric_mode, window_cells, ScanMode,
    };

    fn run_cohort(
        r: &[f64],
        queries: &[Vec<f64>],
        w: usize,
        k: usize,
        metric: Metric,
        suite: Suite,
    ) -> Vec<CohortMember> {
        let n = queries[0].len();
        let stats = BucketStats::build(r, n);
        let weff = metric.effective_window(n, w);
        let denv = metric
            .wants_data_envelopes(suite)
            .then(|| DataEnvelopes::new(r, weff));
        let mut members: Vec<CohortMember> = queries
            .iter()
            .map(|q| CohortMember::new(QueryContext::with_metric_pooled(q, w, metric), k))
            .collect();
        let mut scratch = CohortScratch::default();
        let mut pool = CohortPool::default();
        scan_cohort_topk(
            r,
            0,
            r.len() - n + 1,
            &mut members,
            &stats,
            denv.as_ref(),
            suite,
            1024,
            &mut scratch,
            &mut pool,
        );
        members
    }

    #[test]
    fn cohort_matches_independent_strip_scans_bitwise() {
        let r = Dataset::Ecg.generate(1200, 3);
        let queries = extract_queries(&r, 4, 96, 0.1, 9);
        let w = window_cells(96, 0.1);
        for metric in [
            Metric::Cdtw,
            Metric::Msm { cost: 0.5 },
            // the two metrics with per-query cost-model tables: the cohort
            // path must serve them rebuild-free too (PR 5 follow-up)
            Metric::Wdtw { g: 0.05 },
            Metric::Erp { gap: 0.5 },
        ] {
            let members = run_cohort(&r, &queries, w, 3, metric, Suite::UcrMon);
            for (q, m) in queries.iter().zip(members) {
                let mut c = Counters::new();
                let want = search_subsequence_topk_metric_mode(
                    &r, q, w, 3, metric, Suite::UcrMon, ScanMode::Strip, &mut c,
                );
                let got = m.topk.into_sorted();
                assert_eq!(got.len(), want.len(), "{}", metric.name());
                for (g, x) in got.iter().zip(&want) {
                    assert_eq!(g.pos, x.pos, "{}", metric.name());
                    assert_eq!(g.dist.to_bits(), x.dist.to_bits(), "{}", metric.name());
                }
                // the cohort member examined the whole candidate space
                assert_eq!(m.counters.candidates, c.candidates, "{}", metric.name());
                // per-query cost-model tables are built once at context
                // build — never per candidate, in either path
                assert_eq!(m.counters.cost_model_rebuilds, 0, "{}", metric.name());
                assert_eq!(c.cost_model_rebuilds, 0, "{}", metric.name());
                assert_eq!(
                    m.counters.dtw_calls,
                    m.counters.dtw_abandons + m.counters.dtw_completions,
                    "{}",
                    metric.name()
                );
            }
        }
    }

    #[test]
    fn stat_load_accounting_balances() {
        // with no retirement: loads performed + loads saved = loads a
        // sequential batch would make, exactly
        let r = Dataset::Ppg.generate(900, 5);
        let queries = extract_queries(&r, 3, 64, 0.1, 6);
        let members = run_cohort(&r, &queries, 6, 2, Metric::Cdtw, Suite::UcrMon);
        let mut total = Counters::new();
        for m in &members {
            assert!(!m.retired);
            total.merge(&m.counters);
        }
        let total_candidates = (r.len() - 64 + 1) as u64 * queries.len() as u64;
        assert_eq!(total.candidates, total_candidates);
        assert!(total.cohort_strips > 0);
        assert!(total.strip_stat_loads_saved > 0);
        // Q members, one load per strip: saved = candidates × (Q−1)/Q
        assert_eq!(
            total.strip_stat_loads_saved * queries.len() as u64,
            total.candidates * (queries.len() as u64 - 1)
        );
        // the same invariant extended to LB_Kim's raw-sample reads: the
        // shared endpoint lanes save 6 normalised reads per lane for each
        // member beyond the first (qlen 64 ⇒ the full hierarchy)
        assert_eq!(
            total.strip_sample_loads_saved,
            total.strip_stat_loads_saved * 6,
            "sample saving is 6 endpoint reads per shared stat-lane read"
        );
        // and the pooled kernel workspace never regrew inside the cohort,
        // nor did any member rebuild its cost-model tables
        assert_eq!(total.kernel_workspace_regrows, 0);
        assert_eq!(total.cost_model_rebuilds, 0);
    }

    #[test]
    fn bound_free_metric_shares_no_sample_loads() {
        // a metric without envelope bounds never runs LB_Kim, so the
        // sample-load counter must stay zero (the invariant is gated on
        // the cascade, not on cohort membership)
        let r = Dataset::Ppg.generate(700, 15);
        let queries = extract_queries(&r, 2, 48, 0.1, 16);
        let members = run_cohort(&r, &queries, 5, 2, Metric::Msm { cost: 0.5 }, Suite::UcrMon);
        for m in &members {
            assert_eq!(m.counters.strip_sample_loads_saved, 0);
            assert_eq!(m.counters.lb_kim_prunes, 0);
            assert_eq!(m.counters.kernel_workspace_regrows, 0);
        }
    }

    #[test]
    fn exact_match_query_retires_mid_scan() {
        let r = Dataset::FoG.generate(2000, 8);
        // member 0 is an exact window copy planted early: its k = 1 best
        // is 0, so it retires after the strip that finds it
        let exact = r[64..64 + 96].to_vec();
        let noisy = extract_queries(&r, 1, 96, 0.1, 4).remove(0);
        let queries = vec![exact.clone(), noisy.clone()];
        let members = run_cohort(&r, &queries, 9, 1, Metric::Cdtw, Suite::UcrMon);
        assert!(members[0].retired);
        assert_eq!(members[0].counters.cohort_retired_queries, 1);
        assert!(
            members[0].counters.candidates < (r.len() - 96 + 1) as u64,
            "retired member must skip late strips"
        );
        assert!(!members[1].retired);
        // ...and the retired member's answer is still exactly right
        let mut c = Counters::new();
        let want = search_subsequence_topk_metric_mode(
            &r, &exact, 9, 1, Metric::Cdtw, Suite::UcrMon, ScanMode::Strip, &mut c,
        );
        let got = members[0].topk.to_sorted();
        assert_eq!(got[0].pos, want[0].pos);
        assert_eq!(got[0].dist.to_bits(), want[0].dist.to_bits());
        assert_eq!(got[0].dist, 0.0);
    }

    #[test]
    #[should_panic(expected = "share (query length, window, metric)")]
    fn mixed_shape_cohort_is_rejected() {
        let r = Dataset::Ecg.generate(400, 1);
        let stats = BucketStats::build(&r, 32);
        let mut members = vec![
            CohortMember::new(QueryContext::new(&r[0..32], 3), 1),
            CohortMember::new(QueryContext::new(&r[0..48], 3), 1),
        ];
        scan_cohort_topk(
            &r,
            0,
            10,
            &mut members,
            &stats,
            None,
            Suite::UcrMonNoLb,
            1024,
            &mut CohortScratch::default(),
            &mut CohortPool::default(),
        );
    }
}
